"""Physics spec validation: the shared validator rejects invalid DONN
geometries on every entry path — statically (``validate_config``), at
plan-build time (``plan_from_config``), and through the DSL JSON spec
round-trip (``from_spec`` / ``to_spec``)."""
import dataclasses
import json
import pathlib

import pytest

import repro.core.dsl as lr
from repro.core import DONNConfig, LayerSpec, PhysicsValidationError
from repro.models.config import get_config
from repro.core.physics import (
    PhysicsWarning,
    band_limit_frequency,
    critical_distance,
    fresnel_number,
    validate_config,
)
from repro.core.propagation import plan_from_config

FIXTURES = pathlib.Path(__file__).resolve().parent / "lightlint_fixtures"


def aliased_config(**overrides):
    """Unmasked angular spectrum far past the sampling limit
    (z_crit ~ 0.156 m for n=64, dx=36um, 532nm)."""
    kw = dict(name="aliased", n=64, pixel_size=36e-6, distance=1.0,
              band_limit=False)
    kw.update(overrides)
    return DONNConfig(**kw)


class TestStaticPath:
    def test_sampling_criterion_flagged(self):
        violations = validate_config(aliased_config())
        assert violations, "expected sampling-aliasing violations"
        assert all(v.criterion == "sampling-aliasing" for v in violations)
        assert all(v.severity == "error" for v in violations)

    def test_violation_message_names_criterion_and_numbers(self):
        v = validate_config(aliased_config())[0]
        s = str(v)
        assert "sampling-aliasing" in s
        assert "z_crit" in s and "0.1559" in s

    def test_stitch_undersample_flagged(self):
        cfg = DONNConfig(
            name="stitch", n=64, depth=2, distance=0.05,
            layers=(LayerSpec(distance=0.05, size=64, pixel_size=12e-6),
                    LayerSpec(distance=0.05, size=64, pixel_size=36e-6)),
        )
        crits = {v.criterion for v in validate_config(cfg)}
        assert "stitch-undersample" in crits

    def test_device_levels_flagged(self):
        cfg = DONNConfig(name="flat", n=64, distance=0.05, codesign="qat",
                         device_levels=1)
        crits = {v.criterion for v in validate_config(cfg)}
        assert crits == {"device-levels"}

    def test_registered_archs_all_valid(self):
        from repro.configs import DONN_ARCHS

        for name in DONN_ARCHS:
            for smoke in (False, True):
                cfg = get_config(name, smoke=smoke)
                assert validate_config(cfg) == [], name

    def test_helper_formulas(self):
        # z_crit = N_eff * dx^2 / lambda (pad doubles N_eff)
        z = critical_distance(64, 36e-6, 532e-9, pad=False)
        assert z == pytest.approx(64 * 36e-6**2 / 532e-9)
        zp = critical_distance(64, 36e-6, 532e-9, pad=True)
        assert zp == pytest.approx(2 * z)
        # Fresnel number F = a^2 / (lambda z), a = n*dx/2
        a = 64 * 36e-6 / 2
        assert fresnel_number(64, 36e-6, 0.05, 532e-9) == pytest.approx(
            a * a / (532e-9 * 0.05))
        assert band_limit_frequency(64, 36e-6, 0.05, 532e-9, pad=False) > 0


class TestPlanBuildPath:
    def test_plan_from_config_raises_domain_error(self):
        with pytest.raises(PhysicsValidationError) as exc:
            plan_from_config(aliased_config(name="aliased-plan"), 1.0)
        assert "sampling-aliasing" in str(exc.value)
        assert exc.value.violations

    def test_valid_config_builds_plan(self):
        cfg = get_config("donn-mnist-3l", smoke=True)
        assert plan_from_config(cfg, 1.0) is not None

    def test_fraunhofer_near_field_warns(self):
        cfg = dataclasses.replace(
            get_config("donn-mnist-3l", smoke=True),
            name="fraunhofer-near", approximation="fraunhofer",
            band_limit=False,
        )
        with pytest.warns(PhysicsWarning, match="fraunhofer-far-field"):
            plan_from_config(cfg, 1.0)


class TestSpecPath:
    def test_from_spec_rejects_invalid_artifact(self):
        spec = json.loads((FIXTURES / "lr202_bad_spec.json").read_text())
        with pytest.raises(PhysicsValidationError, match="sampling-aliasing"):
            lr.from_spec(spec)

    def test_from_spec_accepts_valid_artifact(self):
        spec = json.loads((FIXTURES / "lr202_good_spec.json").read_text())
        model, cfg = lr.from_spec(spec)
        assert model is not None and cfg.depth == 2

    def test_to_spec_rejects_invalid_config(self):
        with pytest.raises(PhysicsValidationError, match="sampling-aliasing"):
            lr.to_spec(aliased_config(name="aliased-export"))

    def test_sequential_rejects_invalid_stack(self):
        det = lr.layers.detector(num_classes=10, det_size=12, distance=1.0)
        stack = [lr.layers.diffractlayer(distance=1.0, size=64,
                                         pixel_size=36e-6, band_limit=False)]
        with pytest.raises(PhysicsValidationError, match="sampling-aliasing"):
            lr.models.sequential(stack, det)

    def test_spec_to_config_skips_validation(self):
        # the lint-time entry point assembles without raising so the
        # linter can report violations as findings instead of crashing
        spec = json.loads((FIXTURES / "lr202_bad_spec.json").read_text())
        cfg = lr.spec_to_config(spec)
        assert any(v.criterion == "sampling-aliasing"
                   for v in validate_config(cfg))
