"""HLO cost analyzer: validated against XLA on loop-free programs and on
hand-computable trip-counted scans (subprocess: needs >1 device for the
collective cases)."""
import json
import textwrap

import jax
import jax.numpy as jnp

from conftest import run_subprocess
from repro.compat import compiled_cost_analysis
from repro.runtime.hlo_analysis import analyze, parse_hlo


class TestLoopFree:
    def test_matches_xla_cost_analysis(self):
        def f(x, w):
            return jnp.sum(jax.nn.relu(x @ w) ** 2)

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((128, 256), jnp.float32),
            jax.ShapeDtypeStruct((256, 512), jnp.float32),
        ).compile()
        xla = compiled_cost_analysis(c)
        mine = analyze(c.as_text())
        assert abs(mine.flops - xla["flops"]) / xla["flops"] < 0.05
        assert abs(mine.bytes - xla["bytes accessed"]) / xla[
            "bytes accessed"] < 0.10

    def test_dot_flops_exact(self):
        def f(x, w):
            return x @ w

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32),
            jax.ShapeDtypeStruct((128, 32), jnp.float32),
        ).compile()
        mine = analyze(c.as_text())
        assert mine.dot_flops == 2 * 64 * 128 * 32


class TestTripCounting:
    def test_scan_multiplies_body(self):
        def f(w, x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=13)
            return jnp.sum(y)

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((8, 32), jnp.float32),
        ).compile()
        mine = analyze(c.as_text())
        assert mine.dot_flops == 13 * 2 * 8 * 32 * 32

    def test_nested_scans(self):
        def f(w, x):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ w, None
                c, _ = jax.lax.scan(inner, c, None, length=3)
                return c, None
            y, _ = jax.lax.scan(outer, x, None, length=5)
            return jnp.sum(y)

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((16, 16), jnp.float32),
            jax.ShapeDtypeStruct((4, 16), jnp.float32),
        ).compile()
        mine = analyze(c.as_text())
        assert mine.dot_flops == 15 * 2 * 4 * 16 * 16

    def test_xla_does_not_trip_count(self):
        """The reason this module exists: XLA reports ~1 iteration."""
        def f(w, x):
            def body(c, _):
                return c @ w, None
            y, _ = jax.lax.scan(body, x, None, length=50)
            return jnp.sum(y)

        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32),
            jax.ShapeDtypeStruct((8, 32), jnp.float32),
        ).compile()
        xla = compiled_cost_analysis(c)["flops"]
        mine = analyze(c.as_text()).dot_flops
        assert mine > 10 * xla  # mine trip-counts, XLA doesn't


COLLECTIVE_SUITE = textwrap.dedent("""
    import json
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.compat import make_mesh
    from repro.runtime.hlo_analysis import analyze

    mesh = make_mesh((4,), ("model",), axis_types=("auto",))
    results = {}

    # per scan iteration the model-sharded dot output (32,16) is gathered
    # back to the replicated carry (32,64): 7 * 32*64*4 * (g-1)/g bytes
    def f(w, x):
        def body(c, _):
            return jax.nn.relu(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=7)
        return jnp.sum(y)
    c = jax.jit(f, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                 NamedSharding(mesh, P()))).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((32, 64), jnp.float32)).compile()
    m = analyze(c.as_text())
    results["ag_bytes"] = m.collective_breakdown.get("all-gather", 0)
    results["ag_expected"] = 7 * 32 * 64 * 4 * 3 / 4

    # all-reduce: contracting-dim sharded matmul
    def g(x, w):
        return jnp.sum(x @ w)
    c2 = jax.jit(g, in_shardings=(NamedSharding(mesh, P(None, "model")),
                                  NamedSharding(mesh, P("model", None)))
                 ).lower(jax.ShapeDtypeStruct((16, 64), jnp.float32),
                         jax.ShapeDtypeStruct((64, 32), jnp.float32)).compile()
    m2 = analyze(c2.as_text())
    results["ar_bytes"] = m2.collective_breakdown.get("all-reduce", 0)
    results["ar_expected_min"] = 16 * 32 * 4 * 2 * 3 / 4  # 2(g-1)/g * out
    print("RESULTS:" + json.dumps(results))
""")


def test_collective_byte_model():
    proc = run_subprocess(COLLECTIVE_SUITE, device_count=4)
    assert proc.returncode == 0, proc.stderr[-2000:]
    res = json.loads(
        [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")][0][8:]
    )
    assert abs(res["ag_bytes"] - res["ag_expected"]) / res["ag_expected"] < 0.1
    assert res["ar_bytes"] >= res["ar_expected_min"] * 0.9


class TestParser:
    def test_parses_tuple_types_with_index_comments(self):
        txt = (
            "%c (p: (s32[], f32[4,4])) -> (s32[], f32[4,4]) {\n"
            "  %p = (s32[], f32[4,4]{1,0}, /*index=5*/f32[2,2]{1,0}) parameter(0)\n"
            "  %w = (s32[], f32[4,4]) while(%p), condition=%cond, body=%c2\n"
            "}\n"
            "ENTRY %main () -> f32[] {\n"
            "  %k = f32[] constant(0)\n"
            "}\n"
        )
        comps = parse_hlo(txt)
        ops = comps["c"].ops
        assert any(o.opcode == "while" for o in ops)
