"""LightRidge-DSE: GBDT regressor + analytical-model exploration (paper §4)."""
import numpy as np
import pytest

from repro.core.dse import (
    GradientBoostingRegressor, LightRidgeDSE, rank_layouts,
    sensitivity_analysis,
)


class TestGBDT:
    def test_fits_nonlinear_function(self):
        r = np.random.default_rng(0)
        X = r.uniform(-2, 2, size=(200, 2))
        y = np.sin(X[:, 0]) * X[:, 1] ** 2 + 0.05 * r.normal(size=200)
        m = GradientBoostingRegressor(n_estimators=300, learning_rate=0.1,
                                      max_depth=3)
        m.fit(X, y)
        pred = m.predict(X)
        rmse = np.sqrt(np.mean((pred - y) ** 2))
        assert rmse < 0.1

    def test_generalizes(self):
        r = np.random.default_rng(1)
        X = r.uniform(-2, 2, size=(300, 2))
        y = X[:, 0] ** 2 + X[:, 1]
        m = GradientBoostingRegressor(n_estimators=200, learning_rate=0.1,
                                      max_depth=3).fit(X[:200], y[:200])
        pred = m.predict(X[200:])
        rmse = np.sqrt(np.mean((pred - y[200:]) ** 2))
        assert rmse < 0.25

    def test_paper_hyperparameters_run(self):
        """The paper's exact config (3500 trees, lr .2, depth 3) must work."""
        r = np.random.default_rng(25)
        X = r.uniform(0, 1, size=(121, 3))
        y = np.cos(3 * X[:, 0]) + X[:, 1] * X[:, 2]
        m = GradientBoostingRegressor(n_estimators=3500, learning_rate=0.2,
                                      max_depth=3, random_state=25).fit(X, y)
        assert np.sqrt(np.mean((m.predict(X) - y) ** 2)) < 0.05


def _landscape(lam, d, D):
    """Synthetic DONN accuracy landscape peaking where d/lam and the
    Fresnel coupling hit sweet spots (mimics paper Fig. 5 structure)."""
    a = np.exp(-((d / lam - 68) ** 2) / 400.0)
    b = np.exp(-((d * d / (lam * D) - 0.008) ** 2) / 2e-5)
    return float(np.clip(0.1 + 0.9 * a * b, 0, 1))


class TestLightRidgeDSE:
    def _grid(self, lam):
        ds = np.linspace(10 * lam, 110 * lam, 11)
        Ds = np.linspace(0.1, 0.6, 11)
        pts, accs = [], []
        for d in ds:
            for D in Ds:
                pts.append((lam, d, D))
                accs.append(_landscape(lam, d, D))
        return pts, accs

    def test_transfer_to_new_wavelength(self):
        """Train on 432nm+632nm grids, predict 532nm (paper Fig. 5 flow)."""
        pts, accs = [], []
        for lam in (432e-9, 632e-9):
            p, a = self._grid(lam)
            pts += p
            accs += a
        dse = LightRidgeDSE(n_estimators=300).fit(pts, accs)
        lam = 532e-9
        cand = [(d, D) for d in np.linspace(10 * lam, 110 * lam, 11)
                for D in np.linspace(0.1, 0.6, 11)]
        res = dse.explore(lam, cand, emulate=lambda p: _landscape(*p), top_k=2)
        true_best = max(_landscape(lam, d, D) for d, D in cand)
        assert res.verified_acc >= true_best - 0.05
        assert res.speedup >= 50  # paper reports ~60x

    def test_validity_range_refusal(self):
        """Theory-violating extrapolation (visible->IR) must be refused."""
        pts, accs = self._grid(432e-9)
        p2, a2 = self._grid(632e-9)
        dse = LightRidgeDSE(n_estimators=50).fit(pts + p2, accs + a2)
        with pytest.raises(ValueError):
            dse.predict([(10e-6, 36e-6, 0.3)])  # IR wavelength

    def test_explore_with_batched_emulation(self):
        """emulate_batch verifies all top-k points in one call."""
        pts, accs = [], []
        for lam in (432e-9, 632e-9):
            p, a = self._grid(lam)
            pts += p
            accs += a
        dse = LightRidgeDSE(n_estimators=300).fit(pts, accs)
        lam = 532e-9
        cand = [(d, D) for d in np.linspace(10 * lam, 110 * lam, 11)
                for D in np.linspace(0.1, 0.6, 11)]
        calls = []

        def emulate_batch(points):
            calls.append(list(points))
            return [_landscape(*p) for p in points]

        res_b = dse.explore(lam, cand, emulate_batch=emulate_batch, top_k=3)
        res_s = dse.explore(lam, cand, emulate=lambda p: _landscape(*p),
                            top_k=3)
        assert len(calls) == 1 and len(calls[0]) == 3
        assert res_b.best_point == res_s.best_point
        assert res_b.verified_acc == res_s.verified_acc

    def test_explore_requires_an_emulator(self):
        dse = LightRidgeDSE(n_estimators=10).fit(*self._grid(432e-9))
        with pytest.raises(ValueError):
            dse.explore(432e-9, [(36e-6, 0.3)])

    def test_explore_rejects_short_batch_result(self):
        dse = LightRidgeDSE(n_estimators=10).fit(*self._grid(432e-9))
        cand = [(36e-6, 0.3), (30e-6, 0.25), (40e-6, 0.35)]
        with pytest.raises(ValueError, match="scores"):
            dse.explore(432e-9, cand, emulate_batch=lambda pts: [0.5],
                        top_k=2)

    def test_sensitivity_analysis_shape(self):
        out = sensitivity_analysis(lambda p: _landscape(*p),
                                   (532e-9, 36e-6, 0.3))
        assert set(out) == {"wavelength", "unit_size", "distance"}
        for rows in out.values():
            assert len(rows) == 5
        # unit size is the most sensitive parameter (paper Table 3)
        def drop(name):
            rows = dict(out[name])
            return rows[0.0] - min(rows[-0.05], rows[0.05])
        assert drop("unit_size") >= drop("distance") - 1e-9

    def test_sensitivity_analysis_batched_matches_sequential(self):
        best = (532e-9, 36e-6, 0.3)
        calls = []

        def emulate_batch(points):
            calls.append(list(points))
            return [_landscape(*p) for p in points]

        out_b = sensitivity_analysis(None, best, emulate_batch=emulate_batch)
        out_s = sensitivity_analysis(lambda p: _landscape(*p), best)
        assert len(calls) == 1 and len(calls[0]) == 15  # 3 params x 5 deltas
        assert out_b == out_s
        with pytest.raises(ValueError):
            sensitivity_analysis(None, best)


class TestShardingDSE:
    def test_rank_layouts(self):
        recs = [
            {"name": "a", "terms": {"compute_s": 1.0, "memory_s": 5.0,
                                    "collective_s": 2.0}},
            {"name": "b", "terms": {"compute_s": 1.0, "memory_s": 2.0,
                                    "collective_s": 1.5}},
            {"name": "c", "terms": {"compute_s": 3.0, "memory_s": 3.0,
                                    "collective_s": 0.1}},
        ]
        ranked = rank_layouts(recs)
        assert [r["name"] for r in ranked] == ["b", "c", "a"]
