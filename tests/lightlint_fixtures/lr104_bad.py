"""LR104 bad fixture: fresh jit per loop iteration."""
import jax


def sweep(models, params, x):
    outs = []
    for model in models:
        fn = jax.jit(lambda p, xb: model.apply(p, xb))  # BUG: re-jits
        outs.append(fn(params, x))
    return outs
