"""LR104 good fixture: hoisted jit / executable-cache routing."""
import jax

from repro.core import propagation as pp


def sweep(apply_fn, params, xs):
    fn = jax.jit(apply_fn)  # traced once, reused across the loop
    return [fn(params, x) for x in xs]


def sweep_cached(skey, apply_fn, params, xs):
    outs = []
    for x in xs:
        ex = pp.cached_executable(skey, apply_fn, params, x)
        outs.append(ex(params, x))
    return outs
