"""LR102 good fixture: the live idiom — copy once, then rebind."""
import jax
import jax.numpy as jnp

from repro.core import propagation as pp


def train(params, opt_state, chunks, step_impl, skey):
    # donated state: copy so the caller's reference stays valid
    params = jax.tree.map(jnp.array, params)
    opt_state = jax.tree.map(jnp.array, opt_state)
    for xb, yb in chunks:
        ex = pp.cached_executable(skey, step_impl, params, opt_state, xb,
                                  yb, donate_argnums=(0, 1))
        params, opt_state = ex(params, opt_state, xb, yb)
    return params, opt_state
