"""LR103 bad fixture: host syncs inside a scan body and a jitted fn."""
import jax
import jax.numpy as jnp
import numpy as np


def chunk(params, xs):
    def body(carry, xb):
        loss = jnp.mean(carry * xb)
        print("loss", loss)  # BUG: host sync inside the scan body
        return carry + float(loss), loss  # BUG: float() on a tracer

    return jax.lax.scan(body, params, xs)


@jax.jit
def evaluate(params, xb):
    logits = params @ xb
    return np.asarray(logits).sum()  # BUG: device->host inside jit
