"""LR106 good fixture: the live _spectral_mul idiom — upcast then math."""
import jax.numpy as jnp


def spectral_mul(tf_plane, field):
    tfr = tf_plane.astype(jnp.bfloat16)  # bf16 is the *storage* dtype
    prod = tfr.astype(jnp.float32) * field  # accumulate in f32
    return jnp.sum(prod)


def energy(plane):
    p = plane.astype(jnp.bfloat16)
    return jnp.sum(p, dtype=jnp.float32)
