"""LR201 good fixture: the paper's MNIST geometry (valid everywhere)."""
from repro.core import DONNConfig, LayerSpec

MNIST3 = DONNConfig(name="donn-mnist-3l", n=200, pixel_size=36e-6,
                    wavelength=532e-9, distance=0.28, depth=3)

HETERO = DONNConfig(
    name="hetero", n=48, pixel_size=48e-6, depth=2, distance=0.05,
    layers=(LayerSpec(distance=0.05, size=64, pixel_size=36e-6),
            LayerSpec(distance=0.05, size=48, pixel_size=48e-6)),
)
