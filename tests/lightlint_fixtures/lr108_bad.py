"""LR108 bad: while-True retry loops that swallow failures unpaced."""
import queue


def serve_forever(engine, work: queue.Queue):
    while True:
        group = work.get()
        try:
            engine.infer(group)
        except Exception:
            work.put(group)  # requeue and spin: no budget, no backoff


def restart_until_up(supervisor):
    while True:
        try:
            supervisor.restart()
        except Exception:
            continue  # tight restart spin against a dead artifact
