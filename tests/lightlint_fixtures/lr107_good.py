"""LR107 good fixture: pairs stay split; lax.complex only at FFT edges."""
import jax
import jax.numpy as jnp


@jax.jit
def hop(sr, si, hr, hi):
    # the fused-kernel idiom: split-plane complex multiply, no promotion
    out_r = sr * hr - si * hi
    out_i = sr * hi + si * hr
    return out_r, out_i


def run(planes, u):
    def body(carry, plane):
        pr, pi = plane
        cr = carry.real * pr - carry.imag * pi
        ci = carry.real * pi + carry.imag * pr
        # the one genuinely-complex boundary uses lax.complex, not 1j*
        carry = jnp.fft.fft2(jax.lax.complex(cr, ci))
        return carry, None

    out, _ = jax.lax.scan(body, u, planes)
    return jnp.abs(out)


def assemble_cold(pr, pi):
    # outside any hot body: promotion is fine (e.g. cached TF constants)
    return pr + 1j * pi
