"""LR105 good fixture: the post-PR-2 idiom — cached model, array args."""
import jax
import jax.numpy as jnp

from repro.core import cached_model


def make_loss(cfg):
    model = cached_model(cfg)  # hoisted out of the loss closure

    def loss_fn(params, xb, onehot):
        logits = model.apply(params, xb)
        return jnp.mean((logits - onehot) ** 2)

    return jax.jit(loss_fn)


def run(cfg, params, xb, labels):
    loss = make_loss(cfg)
    return loss(params, xb, jnp.asarray(labels))
