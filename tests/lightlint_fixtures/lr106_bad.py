"""LR106 bad fixture: bf16 planes combined/reduced without f32."""
import jax.numpy as jnp


def spectral_mul(tf_plane, field):
    tfr = tf_plane.astype(jnp.bfloat16)
    fr = field.astype(jnp.bfloat16)
    prod = tfr * fr  # BUG: bf16 x bf16 accumulates in bf16
    return jnp.sum(prod)


def energy(plane):
    p = plane.astype(jnp.bfloat16)
    return jnp.sum(p)  # BUG: bf16 reduction without dtype=f32
