"""LR109 bad: hand-built specs and ad-hoc meshes outside the rules table."""
import jax
import jax.sharding
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import make_mesh


def dispatch_specs(ndev):
    # hard-coded axis strings: the rules table should resolve these
    x_spec = P("data", None, None)
    out_spec = jax.sharding.PartitionSpec("data", None)
    return x_spec, out_spec


def build_mesh(devices):
    mesh = make_mesh((2, 4), ("data", "model"))  # ad-hoc axis spelling
    raw = Mesh(devices, ("dp", "tp"))  # a third spelling of the same axes
    return mesh, raw
