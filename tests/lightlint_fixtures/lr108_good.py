"""LR108 good: bounded or paced retry loops."""
import queue
import time


def serve_with_backoff(engine, work: queue.Queue):
    while True:
        group = work.get()
        try:
            engine.infer(group)
        except Exception:
            _backoff_and_requeue(work, group)  # exponential backoff inside


def _backoff_and_requeue(work, group):
    time.sleep(0.05)
    work.put(group)


def restart_with_budget(supervisor, max_restarts: int = 3):
    attempts = 0
    while True:
        try:
            supervisor.restart()
            return
        except Exception:
            attempts += 1
            if attempts > max_restarts:
                raise  # budget exhausted: the failure propagates


def paced_poll(cv, pending):
    while True:
        with cv:
            try:
                return pending.pop(0)
            except IndexError:
                cv.wait(timeout=0.1)  # paced, not a busy-spin
