"""LR107 bad fixture: complex pair assembly inside hot bodies."""
import jax
import jax.numpy as jnp


@jax.jit
def hop(sr, si, hr, hi):
    s = sr + 1j * si  # BUG: promotes the split pair inside a jit body
    out = s * (hr - 1j * hi)  # BUG: and again for the TF pair
    return out.real, out.imag


def run(planes, u):
    def body(carry, plane):
        pr, pi = plane
        carry = carry * (pr + 1j * pi)  # BUG: promotion inside a scan body
        return carry, None

    out, _ = jax.lax.scan(body, u, planes)
    return jnp.abs(out)
