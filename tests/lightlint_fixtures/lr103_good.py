"""LR103 good fixture: accumulate on device, sync once outside."""
import jax
import jax.numpy as jnp
import numpy as np


def chunk(params, xs):
    def body(carry, xb):
        loss = jnp.mean(carry * xb)
        return carry + loss, loss

    return jax.lax.scan(body, params, xs)


@jax.jit
def evaluate(params, xb):
    return params @ xb


def run(params, xs):
    params, losses = chunk(params, xs)
    losses = np.asarray(losses)  # one host sync per chunk, outside the jit
    print("mean loss", losses.mean())
    return params
