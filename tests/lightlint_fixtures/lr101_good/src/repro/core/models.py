"""LR101 good fixture: asdict consumes every field (the live idiom)."""
import dataclasses


def config_static_key(cfg):
    d = dataclasses.asdict(cfg)
    d.pop("name")
    return tuple(sorted(d.items()))


def model_cache_key(model):
    return config_static_key(model.cfg)
