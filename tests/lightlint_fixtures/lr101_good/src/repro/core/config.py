"""LR101 good fixture: same dataclasses as the bad tree."""
import dataclasses


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    distance: float = 0.3
    size: int = 64
    pixel_size: float = 36e-6


@dataclasses.dataclass(frozen=True)
class DONNConfig:
    name: str = "donn"
    n: int = 200
    pixel_size: float = 36e-6
    wavelength: float = 532e-9
    distance: float = 0.30
    remat: str = "none"
