"""LR101 good fixture: per-layer tuple reads every LayerSpec field."""


def plan_cache_key(cfg, gamma):
    per_layer = tuple(
        (l.size, l.pixel_size, l.distance) for l in cfg.layers
    )
    return (per_layer, cfg.n, cfg.pixel_size, cfg.wavelength, cfg.distance,
            cfg.remat, float(gamma))
