"""LR201 bad fixture: physically invalid literal DONNConfig sites."""
from repro.core import DONNConfig, LayerSpec

# unmasked angular spectrum far past the sampling limit (z_crit ~ 0.156 m)
ALIASED = DONNConfig(name="aliased", n=64, pixel_size=36e-6, distance=1.0,
                     band_limit=False)

# a 3x coarser stitch between adjacent planes
UNDERSAMPLED = DONNConfig(
    name="stitch", n=64, depth=2, distance=0.05,
    layers=(LayerSpec(distance=0.05, size=64, pixel_size=12e-6),
            LayerSpec(distance=0.05, size=64, pixel_size=36e-6)),
)

# quantized codesign with a single phase level
ONE_LEVEL = DONNConfig(name="flat", n=64, distance=0.05, codesign="qat",
                       device_levels=1)
