"""LR102 bad fixture: donated buffer read after donation."""
import jax.numpy as jnp

from repro.core import propagation as pp


def train(params, opt_state, xb, yb, step_impl, skey):
    ex = pp.cached_executable(skey, step_impl, params, opt_state, xb, yb,
                              donate_argnums=(0, 1))
    new_params, new_opt = ex(params, opt_state, xb, yb)
    # BUG: `params` was donated above — its buffer is gone
    drift = jnp.sum(new_params - params)
    return new_params, new_opt, drift
