"""LR101 bad fixture: manual key enumeration missing fields."""


def config_static_key(cfg):
    # misses `remat` (and LayerSpec.pixel_size in plan_cache_key below)
    return (cfg.n, cfg.pixel_size, cfg.wavelength, cfg.distance)


def model_cache_key(model):
    return config_static_key(model.cfg)
