"""LR101 bad fixture: per-layer tuple missing LayerSpec.pixel_size."""


def plan_cache_key(cfg, gamma):
    per_layer = tuple((l.size, l.distance) for l in cfg.layers)
    return (per_layer, cfg.n, cfg.pixel_size, cfg.wavelength, cfg.distance,
            float(gamma))
