"""LR109 good: specs and meshes routed through the one rules table."""
from repro.runtime import sharding as shd


def dispatch_specs(mesh):
    rules = shd.donn_rules()
    x_spec = shd.rules_pspec(("batch", "field_h", "field_w"), rules, mesh)
    out_spec = shd.dim0_pspec("data", 2)
    return x_spec, out_spec


def build_mesh():
    return shd.make_mesh_2d(data=2, model=4)
