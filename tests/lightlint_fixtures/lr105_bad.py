"""LR105 bad fixture: the pre-PR-2 donn_steps bug shape.

A loss closure that rebuilds the model and captures a fresh jnp array:
every outer call creates a new closure identity, so jit retraces.
"""
import jax
import jax.numpy as jnp

from repro.core import build_model


def make_loss(cfg, labels):
    onehot = jnp.asarray(labels)

    def loss_fn(params, xb):
        model = build_model(cfg)  # BUG: rebuilt per trace
        logits = model.apply(params, xb)
        return jnp.mean((logits - onehot) ** 2)  # BUG: captured array

    return jax.jit(loss_fn)
