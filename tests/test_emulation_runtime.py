"""Compile-once emulation runtime: batched multi-candidate emulation,
model/plan/executable caches (the DSE verification hot path)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    DONNConfig,
    build_model,
    cached_apply,
    cached_model,
    clear_plan_cache,
    emulate_batch,
    plan_cache_stats,
)
from repro.core import models as mmod
from repro.data import synth_digits, synth_rgb_scenes, synth_seg

BASE = dict(n=48, depth=3, det_size=6)
GEOS = [(36e-6, 532e-9, 0.30), (30e-6, 432e-9, 0.25), (40e-6, 632e-9, 0.35)]


def _cls_cfgs(**extra):
    return [
        DONNConfig(name=f"c{i}", pixel_size=ps, wavelength=wl, distance=D,
                   **{**BASE, **extra})
        for i, (ps, wl, D) in enumerate(GEOS)
    ]


def _digits(k=4, seed=0):
    xs, _ = synth_digits(k, seed=seed)
    return jnp.asarray(xs)


class TestEmulateBatch:
    def test_classify_matches_sequential(self):
        cfgs = _cls_cfgs()
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        x = _digits()
        seq = [build_model(c).apply(params, x) for c in cfgs]
        bat = emulate_batch(cfgs, params, x)
        assert bat.shape == (len(cfgs),) + seq[0].shape
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-5)

    def test_per_candidate_params(self):
        cfgs = _cls_cfgs()
        m0 = build_model(cfgs[0])
        plist = [m0.init(jax.random.PRNGKey(k)) for k in range(len(cfgs))]
        x = _digits(seed=1)
        seq = [build_model(c).apply(p, x) for c, p in zip(cfgs, plist)]
        bat = emulate_batch(cfgs, plist, x)
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-5)

    def test_rng_split_matches_sequential(self):
        cfgs = _cls_cfgs(codesign="gumbel", device_levels=16)
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        x = _digits(seed=2)
        rng = jax.random.PRNGKey(7)
        rngs = jax.random.split(rng, len(cfgs))
        seq = [build_model(c).apply(params, x, r) for c, r in zip(cfgs, rngs)]
        bat = emulate_batch(cfgs, params, x, rng=rng)
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-5)

    def test_multichannel_matches_sequential(self):
        cfgs = [
            DONNConfig(name=f"m{i}", n=64, depth=3, det_size=6, channels=3,
                       num_classes=6, pixel_size=ps, distance=D)
            for i, (ps, D) in enumerate([(36e-6, 0.05), (30e-6, 0.04)])
        ]
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        xs, _ = synth_rgb_scenes(4, seed=0)
        x = jnp.asarray(xs)
        seq = [build_model(c).apply(params, x) for c in cfgs]
        bat = emulate_batch(cfgs, params, x)
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-5)

    def test_segmentation_skip_train_matches_sequential(self):
        cfgs = [
            DONNConfig(name=f"s{i}", n=64, depth=3, segmentation=True,
                       skip_from=0, layer_norm=True, pixel_size=ps,
                       distance=D)
            for i, (ps, D) in enumerate([(36e-6, 0.05), (32e-6, 0.045)])
        ]
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(1))
        xs, _ = synth_seg(4, seed=0)
        x = jnp.asarray(xs)
        seq = [build_model(c).apply(params, x, train=True) for c in cfgs]
        bat = emulate_batch(cfgs, params, x, train=True)
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-4)

    def test_pallas_matches_sequential(self):
        cfgs = _cls_cfgs(use_pallas=True)
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        x = _digits(seed=3)
        seq = [build_model(c).apply(params, x) for c in cfgs]
        bat = emulate_batch(cfgs, params, x)
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=2e-4, atol=2e-4)

    def test_statics_mismatch_raises(self):
        cfgs = _cls_cfgs()
        bad = dataclasses.replace(cfgs[1], num_classes=6)
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="statics"):
            emulate_batch([cfgs[0], bad], params, _digits())

    def test_mixed_depth_needs_per_candidate_params(self):
        # depth is a *geometry* axis now (depth-padded + masked stacks),
        # but a single shared params pytree cannot cover two depths
        cfgs = _cls_cfgs()
        deeper = dataclasses.replace(cfgs[1], depth=4)
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="per-candidate params"):
            emulate_batch([cfgs[0], deeper], params, _digits())

    def test_empty_and_param_count_checks(self):
        cfgs = _cls_cfgs()
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError):
            emulate_batch([], params, _digits())
        with pytest.raises(ValueError):
            emulate_batch(cfgs, [params], _digits())

    def test_executable_reused_across_calls(self):
        clear_plan_cache()
        cfgs = _cls_cfgs()
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        x = _digits(seed=4)
        emulate_batch(cfgs, params, x)
        s0 = plan_cache_stats()
        emulate_batch(cfgs, params, x)
        s1 = plan_cache_stats()
        # second call: all plans and the compiled executable are hits
        assert s1["exec_misses"] == s0["exec_misses"]
        assert s1["exec_hits"] == s0["exec_hits"] + 1
        assert s1["misses"] == s0["misses"]

    def test_batched_inputs_memoized(self):
        mmod.clear_emulation_caches()
        cfgs = _cls_cfgs()
        params = build_model(cfgs[0]).init(jax.random.PRNGKey(0))
        x = _digits(seed=7)
        emulate_batch(cfgs, params, x)
        misses = mmod._BATCH_INPUT_STATS["misses"]
        emulate_batch(cfgs, params, x)  # warm: stacked inputs come from memo
        assert mmod._BATCH_INPUT_STATS["misses"] == misses
        assert mmod._BATCH_INPUT_STATS["hits"] >= 1
        emulate_batch(cfgs[:2], params, x)  # new candidate set: one rebuild
        assert mmod._BATCH_INPUT_STATS["misses"] == misses + 1


class TestCachedApply:
    def test_matches_model_apply(self):
        cfg = DONNConfig(name="ca", **BASE)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        x = _digits(seed=5)
        fn = cached_apply(cfg)
        np.testing.assert_allclose(
            fn(params, x), model.apply(params, x), rtol=1e-6, atol=1e-6
        )

    def test_compiles_once_per_shape(self):
        clear_plan_cache()
        cfg = DONNConfig(name="ca2", **BASE)
        params = cached_model(cfg).init(jax.random.PRNGKey(0))
        fn = cached_apply(cfg)
        fn(params, _digits(4, seed=0))
        s0 = plan_cache_stats()
        fn(params, _digits(4, seed=1))  # same shape: executable reused
        s1 = plan_cache_stats()
        assert s1["exec_misses"] == s0["exec_misses"]
        assert s1["exec_hits"] == s0["exec_hits"] + 1
        fn(params, _digits(8, seed=0))  # new shape: one more compile
        assert plan_cache_stats()["exec_misses"] == s0["exec_misses"] + 1

    def test_rng_variant(self):
        cfg = DONNConfig(name="ca3", codesign="qat", device_levels=32, **BASE)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        x = _digits(seed=6)
        rng = jax.random.PRNGKey(3)
        fn = cached_apply(cfg)
        np.testing.assert_allclose(
            fn(params, x, rng), model.apply(params, x, rng),
            rtol=1e-6, atol=1e-6,
        )


class TestCachedModel:
    def test_same_config_shares_instance(self):
        cfg = DONNConfig(name="cm", **BASE)
        assert cached_model(cfg) is cached_model(DONNConfig(name="cm", **BASE))

    def test_name_is_cosmetic(self):
        # a DSE sweep naming candidates uniquely still compiles once
        a = cached_model(DONNConfig(name="x1", **BASE))
        b = cached_model(DONNConfig(name="x2", **BASE))
        assert a is b

    def test_distinct_config_distinct_instance(self):
        a = cached_model(DONNConfig(name="cm2", **BASE))
        b = cached_model(DONNConfig(name="cm2", distance=0.31, **BASE))
        assert a is not b

    def test_explicit_laser_bypasses_cache(self):
        from repro.core import Laser

        cfg = DONNConfig(name="cm3", **BASE)
        a = cached_model(cfg, laser=Laser(wavelength=cfg.wavelength))
        assert a is not cached_model(cfg, laser=Laser(wavelength=cfg.wavelength))


class TestPlanSharing:
    def test_models_share_cached_plan(self):
        clear_plan_cache()
        cfg = DONNConfig(name="ps", **BASE)
        p1 = build_model(cfg).plan
        p2 = build_model(cfg).plan
        assert p1 is p2
        assert plan_cache_stats()["hits"] >= 1

    def test_config_statics_key_normalizes_distances(self):
        cfg_list = DONNConfig(name="k", distances=[0.1, 0.1, 0.1, 0.1], **BASE)
        cfg_tup = DONNConfig(name="k", distances=(0.1, 0.1, 0.1, 0.1), **BASE)
        assert (mmod.config_static_key(cfg_list)
                == mmod.config_static_key(cfg_tup))
        hash(mmod.config_static_key(cfg_list))  # must be hashable
