"""Pallas selective-scan kernel vs the model's chunked-scan oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops


def _inputs(B, S, D, N, seed=0):
    r = np.random.default_rng(seed)
    dt = jnp.asarray(np.abs(r.normal(0.05, 0.02, (B, S, D))), jnp.float32)
    x = jnp.asarray(r.normal(size=(B, S, D)), jnp.float32)
    bs = jnp.asarray(r.normal(size=(B, S, N)), jnp.float32)
    cs = jnp.asarray(r.normal(size=(B, S, N)), jnp.float32)
    a = -jnp.exp(jnp.asarray(r.normal(0, 0.5, (D, N)), jnp.float32))
    return dt, x, bs, cs, a


@pytest.mark.parametrize("shape", [(1, 16, 128, 16), (2, 33, 256, 16),
                                   (2, 8, 100, 4)])
def test_matches_oracle(shape):
    B, S, D, N = shape
    dt, x, bs, cs, a = _inputs(B, S, D, N, seed=B + S)
    got = ops.selective_scan(dt, x, bs, cs, a)
    want = ops.selective_scan_ref(dt, x, bs, cs, a)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_state_stability():
    """Negative A => bounded state; outputs stay finite over long seq."""
    dt, x, bs, cs, a = _inputs(1, 256, 128, 16, seed=7)
    y = ops.selective_scan(dt, x, bs, cs, a)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_causality():
    """Changing x_t must not affect y_{<t}."""
    dt, x, bs, cs, a = _inputs(1, 32, 128, 8, seed=9)
    y1 = ops.selective_scan(dt, x, bs, cs, a)
    x2 = x.at[:, 20:].add(10.0)
    y2 = ops.selective_scan(dt, x2, bs, cs, a)
    np.testing.assert_allclose(y1[:, :20], y2[:, :20], rtol=1e-5, atol=1e-5)
    assert float(jnp.max(jnp.abs(y1[:, 20:] - y2[:, 20:]))) > 1e-3
