"""Launcher integration: training loop, checkpoint-resume continuity,
preemption (SIGTERM) recovery, batched serving."""
import json
import os
import signal
import subprocess
import sys
import time

import numpy as np

from conftest import SRC


def _run_train(args, timeout=560):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        env=env, capture_output=True, text=True, timeout=timeout,
    )


BASE = ["--arch", "glm4-9b", "--smoke", "--batch", "4", "--seq", "64",
        "--lr", "1e-2", "--warmup", "5", "--log-every", "5"]


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        out = tmp_path / "m.json"
        p = _run_train(BASE + ["--steps", "40", "--metrics-out", str(out)])
        assert p.returncode == 0, p.stderr[-2000:]
        losses = json.loads(out.read_text())["losses"]
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3

    def test_resume_continues_exactly(self, tmp_path):
        """Train 10 straight vs train 5 + resume 5: identical final loss."""
        out_a = tmp_path / "a.json"
        p = _run_train(BASE + ["--steps", "10", "--metrics-out", str(out_a)])
        assert p.returncode == 0, p.stderr[-2000:]

        ck = tmp_path / "ck"
        out_b1 = tmp_path / "b1.json"
        p = _run_train(BASE + ["--steps", "5", "--ckpt-dir", str(ck),
                               "--ckpt-every", "5",
                               "--metrics-out", str(out_b1)])
        assert p.returncode == 0, p.stderr[-2000:]
        out_b2 = tmp_path / "b2.json"
        p = _run_train(BASE + ["--steps", "10", "--ckpt-dir", str(ck),
                               "--ckpt-every", "100",
                               "--metrics-out", str(out_b2)])
        assert p.returncode == 0, p.stderr[-2000:]
        la = json.loads(out_a.read_text())["losses"]
        lb1 = json.loads(out_b1.read_text())["losses"]
        lb2 = json.loads(out_b2.read_text())["losses"]
        # steps 5..9 of the resumed run must match the uninterrupted run
        np.testing.assert_allclose(la[:5], lb1, rtol=1e-5)
        np.testing.assert_allclose(la[5:], lb2, rtol=1e-3, atol=1e-3)


class TestPreemption:
    def test_sigterm_checkpoints_and_resumes(self, tmp_path):
        """Kill training mid-run; restart must resume from the checkpoint."""
        ck = tmp_path / "ck"
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC)
        proc = subprocess.Popen(
            [sys.executable, "-m", "repro.launch.train"] + BASE +
            ["--steps", "1000", "--ckpt-dir", str(ck), "--ckpt-every", "3"],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True,
        )
        # wait until some steps logged, then preempt
        deadline = time.time() + 500
        seen = ""
        while time.time() < deadline:
            line = proc.stdout.readline()
            seen += line
            if "step    10" in line or "step 10 " in line or "step    15" in line:
                break
        proc.send_signal(signal.SIGTERM)
        rc = proc.wait(timeout=500)
        assert rc == 143, f"rc={rc}\n{seen[-2000:]}"
        from repro import checkpoint as ckpt

        last = ckpt.latest_step(ck)
        assert last is not None and last >= 3
        # resume for a few more steps
        out = tmp_path / "resumed.json"
        p = _run_train(BASE + ["--steps", str(last + 3), "--ckpt-dir",
                               str(ck), "--ckpt-every", "100",
                               "--metrics-out", str(out)])
        assert p.returncode == 0, p.stderr[-2000:]
        assert f"resuming from step {last}" in p.stdout


class TestServe:
    def test_batched_serving(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = str(SRC)
        p = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "musicgen-medium", "--smoke", "--slots", "4", "--requests", "6",
             "--prompt-len", "4", "--max-new", "8", "--cache-len", "64"],
            env=env, capture_output=True, text=True, timeout=560,
        )
        assert p.returncode == 0, p.stderr[-2000:]
        assert "6/6 requests" in p.stdout
