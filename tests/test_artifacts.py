"""Validate dry-run / perf artifact schemas (skipped when absent).

These guard the roofline pipeline: every 'ok' cell must carry the three
terms, memory accounting, and a positive roofline fraction; skips must be
the documented long_500k full-attention exclusions.
"""
import json
import pathlib

import pytest

ART = pathlib.Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

REQUIRED = [
    "arch", "shape", "kind", "mesh", "status",
]
OK_REQUIRED = [
    "chips", "n_params", "model_flops", "hlo_flops_per_dev",
    "hlo_bytes_per_dev", "collective_bytes_per_dev", "terms", "dominant",
    "roofline_fraction", "memory",
]


def _records():
    if not ART.exists():
        pytest.skip("no dry-run artifacts present")
    recs = [json.loads(f.read_text()) for f in sorted(ART.glob("*.json"))]
    if not recs:
        pytest.skip("no dry-run artifacts present")
    return recs


def test_schema():
    for r in _records():
        for k in REQUIRED:
            assert k in r, (r.get("arch"), k)
        if r["status"] == "ok":
            for k in OK_REQUIRED:
                assert k in r, (r["arch"], r["shape"], k)
            t = r["terms"]
            assert set(t) == {"compute_s", "memory_s", "collective_s"}
            assert all(v >= 0 for v in t.values())
            assert r["roofline_fraction"] >= 0
            assert r["memory"]["per_device_bytes"] > 0


def test_no_failures():
    bad = [
        (r["arch"], r["shape"], r["mesh"], r["status"][:60])
        for r in _records()
        if r["status"] != "ok" and not r["status"].startswith("SKIP")
    ]
    assert not bad, bad


def test_skips_are_documented_long_context_exclusions():
    for r in _records():
        if str(r["status"]).startswith("SKIP"):
            assert r["shape"] == "long_500k"
            assert r["arch"] in {
                "glm4-9b", "granite-8b", "qwen1.5-4b", "qwen2.5-14b",
                "arctic-480b", "llama-3.2-vision-11b", "musicgen-medium",
            }


def test_both_meshes_present():
    recs = _records()
    pods = {r["mesh"] for r in recs}
    assert pods == {"pod1-256", "pod2-512"}


def test_moe_active_params_less_than_total():
    for r in _records():
        if r.get("status") == "ok" and r["arch"] in ("mixtral-8x7b",
                                                     "arctic-480b"):
            assert r["n_active_params"] < r["n_params"]
