"""Checkpoint store: roundtrip, atomic commit, GC, async, integrity."""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import checkpoint as ckpt


def _state(seed=0):
    r = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(r.normal(size=(17, 5)), jnp.float32),
                   "b": jnp.asarray(r.normal(size=(5,)), jnp.bfloat16)},
        "mu": {"w": jnp.zeros((17, 5)), "b": jnp.zeros((5,))},
        "step": jnp.asarray(7, jnp.int32),
    }


class TestRoundtrip:
    def test_save_restore_identical(self, tmp_path):
        s = _state()
        ckpt.save(tmp_path, 7, s)
        r = ckpt.restore(tmp_path, 7, s)
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))
            assert a.dtype == b.dtype

    def test_latest_pointer(self, tmp_path):
        s = _state()
        ckpt.save(tmp_path, 3, s)
        ckpt.save(tmp_path, 9, s)
        assert ckpt.latest_step(tmp_path) == 9

    def test_chunked_large_leaf(self, tmp_path, monkeypatch):
        import repro.checkpoint.store as store

        monkeypatch.setattr(store, "CHUNK_BYTES", 256)
        s = {"big": jnp.arange(1000, dtype=jnp.float32).reshape(100, 10)}
        store.save(tmp_path, 1, s)
        files = list((tmp_path / "step_00000001").glob("leaf_00000.c*.npy"))
        assert len(files) > 1  # actually chunked
        r = store.restore(tmp_path, 1, s)
        np.testing.assert_array_equal(np.asarray(r["big"]), np.asarray(s["big"]))

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 50))
    def test_random_pytrees(self, seed):
        import tempfile

        r = np.random.default_rng(seed)
        tree = {
            f"k{i}": jnp.asarray(r.normal(size=tuple(r.integers(1, 7, 2))),
                                 jnp.float32)
            for i in range(int(r.integers(1, 5)))
        }
        d = pathlib.Path(tempfile.mkdtemp()) / f"h{seed}"
        ckpt.save(d, 0, tree)
        back = ckpt.restore(d, 0, tree)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDurability:
    def test_gc_keeps_last_k(self, tmp_path):
        s = _state()
        for i in range(6):
            ckpt.save(tmp_path, i, s, keep=2)
        dirs = sorted(d.name for d in tmp_path.iterdir() if d.is_dir())
        assert dirs == ["step_00000004", "step_00000005"]

    def test_partial_tmp_dir_is_ignored(self, tmp_path):
        s = _state()
        ckpt.save(tmp_path, 1, s)
        # simulate a crash mid-write of step 2
        (tmp_path / "step_00000002.tmp").mkdir()
        (tmp_path / "step_00000002.tmp" / "leaf_00000.c000.npy").write_bytes(
            b"garbage")
        assert ckpt.latest_step(tmp_path) == 1
        r = ckpt.restore(tmp_path, 1, s)
        assert int(r["step"]) == 7

    def test_corruption_detected(self, tmp_path):
        s = _state()
        ckpt.save(tmp_path, 1, s)
        d = tmp_path / "step_00000001"
        # flip bytes in one chunk
        f = sorted(d.glob("*.npy"))[0]
        data = bytearray(f.read_bytes())
        data[-4] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError):
            ckpt.restore(tmp_path, 1, s, verify=True)

    def test_corruption_detected_by_default(self, tmp_path):
        """restore() verifies checksums unless explicitly opted out."""
        s = _state()
        ckpt.save(tmp_path, 1, s)
        f = sorted((tmp_path / "step_00000001").glob("*.npy"))[0]
        data = bytearray(f.read_bytes())
        data[-4] ^= 0xFF
        f.write_bytes(bytes(data))
        with pytest.raises(IOError):
            ckpt.restore(tmp_path, 1, s)  # no verify kwarg: default on

    def test_structure_mismatch_raises(self, tmp_path):
        s = _state()
        ckpt.save(tmp_path, 1, s)
        with pytest.raises(ValueError):
            ckpt.restore(tmp_path, 1, {"only": jnp.zeros(3)})


class TestAsync:
    def test_async_commit(self, tmp_path):
        s = _state()
        saver = ckpt.AsyncCheckpointer(tmp_path, keep=2)
        for i in range(3):
            saver.save(i, s)
        saver.wait()
        assert ckpt.latest_step(tmp_path) == 2

    def test_async_snapshot_consistency(self, tmp_path):
        """Mutating state after save() must not affect the snapshot."""
        s = {"w": jnp.ones((4,))}
        saver = ckpt.AsyncCheckpointer(tmp_path)
        saver.save(0, s)
        s["w"] = s["w"] * 100  # rebind after snapshot
        saver.wait()
        r = ckpt.restore(tmp_path, 0, s)
        np.testing.assert_array_equal(np.asarray(r["w"]), np.ones(4))

    def test_async_worker_error_reraised(self, tmp_path):
        """A failed background commit surfaces on the next save()/wait(),
        never silently — callers must not believe a checkpoint exists."""
        blocker = tmp_path / "blocker"
        blocker.write_text("a file where the ckpt dir should go")
        saver = ckpt.AsyncCheckpointer(blocker / "ck")
        s = {"w": jnp.ones((4,))}
        saver.save(0, s)  # worker fails: parent path is a file
        with pytest.raises(OSError):
            saver.save(1, s)
        # the error is consumed once; the saver is usable for a postmortem
        saver.wait()
