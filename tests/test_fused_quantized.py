"""PR-8 perf surfaces: fused spectral hop, quantized frozen planes,
rfft first hop, artifact format 2.

Four invariants:

- **fused hop == jnp reference** at rtol <= 1e-5, values *and* gradients,
  at the kernel level and through every plan path that fuses
  (``use_pallas`` x {trainable, frozen, masked} x {cls, rgb, seg},
  heterogeneous segments, rng codesign);
- **quantized frozen planes** (``freeze(plane_dtype=...)``): the f32 path
  stays bit-identical to the default, bf16 stays within the documented
  5e-2 output tolerance, int8 is finite and close, and every dtype
  round-trips through ``save_deployed``/``load_deployed`` and serves
  through ``InferenceEngine`` bit-identically to its own ``freeze``;
- **rfft first hop** (``freeze(rfft_first=True)``): half-spectrum entry
  agrees with the full-spectrum forward, invalid deployments are rejected
  eagerly, and the engine output is bit-identical to the deployed
  forward;
- **artifact format 2**: format-1 artifacts still load, unknown formats
  are rejected with a clear error before any deserialization.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DONNConfig, build_model
from repro.core import propagation as pp
from repro.core.config import LayerSpec
from repro.kernels import ops
from repro.runtime.inference import InferenceEngine, freeze
from repro.runtime.resilience import (
    ARTIFACT_FILE, load_deployed, save_deployed,
)

TINY = dict(name="fq", n=32, depth=3, distance=0.05, det_size=6)


def _rand(shape, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape), jnp.float32
    )


def _model(seed=0, **kw):
    cfg = DONNConfig(**{**TINY, **kw})
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


def _digits(b, shape=(28, 28), seed=0):
    return np.random.default_rng(seed).random((b,) + shape, np.float32)


# --------------------------------------------------------------------------
class TestFusedHopKernel:
    """fused_spectral_hop vs the unfused jnp reference."""

    def _planes(self, pshape, seed=0):
        r = np.random.default_rng(seed)
        th_h = jnp.asarray(r.uniform(0, 2 * np.pi, pshape), jnp.float32)
        amp_h = jnp.asarray(r.uniform(0.2, 1.0, pshape), jnp.float32)
        th_m = jnp.asarray(r.uniform(0, 2 * np.pi, pshape), jnp.float32)
        amp_m = jnp.asarray(r.uniform(0.2, 1.0, pshape), jnp.float32)
        return th_h, amp_h, th_m, amp_m

    @pytest.mark.parametrize("shape", [(2, 32, 32), (1, 24, 40), (3, 17, 33)])
    def test_matches_ref(self, shape):
        planes = self._planes(shape[-2:])
        xr, xi = _rand(shape, 1), _rand(shape, 2)
        gr, gi = ops.fused_spectral_hop(xr, xi, *planes)
        want = ops.fused_spectral_hop_ref(
            jax.lax.complex(xr, xi), *planes
        )
        np.testing.assert_allclose(gr, want.real, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gi, want.imag, rtol=1e-5, atol=1e-5)

    def test_2d_input(self):
        planes = self._planes((16, 16))
        xr, xi = _rand((16, 16), 3), _rand((16, 16), 4)
        gr, gi = ops.fused_spectral_hop(xr, xi, *planes)
        assert gr.shape == (16, 16)
        want = ops.fused_spectral_hop_ref(jax.lax.complex(xr, xi), *planes)
        np.testing.assert_allclose(gr, want.real, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gi, want.imag, rtol=1e-5, atol=1e-5)

    def test_plane_stack_broadcast(self):
        """(H,W) TF planes + (C,H,W) modulation planes, x (B,C,H,W)."""
        th_h, amp_h, _, _ = self._planes((16, 16), seed=5)
        _, _, th_m, amp_m = self._planes((3, 16, 16), seed=6)
        xr, xi = _rand((2, 3, 16, 16), 7), _rand((2, 3, 16, 16), 8)
        gr, gi = ops.fused_spectral_hop(xr, xi, th_h, amp_h, th_m, amp_m)
        want = ops.fused_spectral_hop_ref(
            jax.lax.complex(xr, xi), th_h, amp_h, th_m, amp_m
        )
        np.testing.assert_allclose(gr, want.real, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gi, want.imag, rtol=1e-5, atol=1e-5)

    def test_gradients_match_ref(self):
        planes = self._planes((16, 16), seed=9)
        xr, xi = _rand((2, 16, 16), 10), _rand((2, 16, 16), 11)

        def loss(xr, xi, th_m):
            gr, gi = ops.fused_spectral_hop(
                xr, xi, planes[0], planes[1], th_m, planes[3]
            )
            return jnp.sum(gr**2 + 0.5 * gi**2)

        def loss_ref(xr, xi, th_m):
            w = ops.fused_spectral_hop_ref(
                jax.lax.complex(xr, xi), planes[0], planes[1], th_m,
                planes[3],
            )
            return jnp.sum(w.real**2 + 0.5 * w.imag**2)

        got = jax.grad(loss, argnums=(0, 1, 2))(xr, xi, planes[2])
        want = jax.grad(loss_ref, argnums=(0, 1, 2))(xr, xi, planes[2])
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------
class TestFusedPlanAgreement:
    """use_pallas (fused hop) vs the jnp scan, through build_model."""

    CASES = [
        ("classify", dict(), (28, 28)),
        ("rgb", dict(channels=3, num_classes=6), (3, 28, 28)),
        ("segmentation", dict(segmentation=True, skip_from=0,
                              layer_norm=True), (28, 28)),
        ("qat", dict(codesign="qat", device_levels=64), (28, 28)),
    ]

    @pytest.mark.parametrize("label,extra,x_shape",
                             CASES, ids=[c[0] for c in CASES])
    def test_forward_agreement(self, label, extra, x_shape):
        m_jnp, p = _model(name=f"fp-{label}", **extra)
        m_fused, _ = _model(name=f"fp-{label}", use_pallas=True, **extra)
        x = jnp.asarray(_digits(3, x_shape))
        np.testing.assert_allclose(
            m_fused.apply(p, x), m_jnp.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_gradients_agreement(self):
        m_jnp, p = _model(name="fp-grad")
        m_fused, _ = _model(name="fp-grad", use_pallas=True)
        x = jnp.asarray(_digits(3))
        g1 = jax.grad(lambda p: jnp.sum(m_fused.apply(p, x) ** 2))(p)
        g2 = jax.grad(lambda p: jnp.sum(m_jnp.apply(p, x) ** 2))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)

    def test_rng_codesign_agreement(self):
        """Stochastic codesign: same rng chain on both paths."""
        extra = dict(codesign="gumbel", device_levels=16)
        m_jnp, p = _model(name="fp-rng", **extra)
        m_fused, _ = _model(name="fp-rng", use_pallas=True, **extra)
        x = jnp.asarray(_digits(3))
        rng = jax.random.PRNGKey(7)
        np.testing.assert_allclose(
            m_fused.apply(p, x, rng), m_jnp.apply(p, x, rng),
            rtol=1e-5, atol=1e-5,
        )

    def test_hetero_segments_agreement(self):
        layers = (LayerSpec(0.05, size=40), LayerSpec(0.05, size=40),
                  LayerSpec(0.05, codesign="qat", device_levels=4))
        m_jnp, p = _model(name="fp-het", layers=layers)
        m_fused, _ = _model(name="fp-het", use_pallas=True, layers=layers)
        x = jnp.asarray(_digits(2))
        np.testing.assert_allclose(
            m_fused.apply(p, x), m_jnp.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_frozen_fused_agreement(self):
        """The frozen serving scan also fuses under use_pallas."""
        m_jnp, p = _model(name="fp-frozen", codesign="qat")
        m_fused, _ = _model(name="fp-frozen", use_pallas=True,
                            codesign="qat")
        x = _digits(2)
        a = freeze(m_fused, p)
        b = freeze(m_jnp, p)
        np.testing.assert_allclose(
            np.asarray(a.forward(jnp.asarray(x))),
            np.asarray(b.forward(jnp.asarray(x))),
            rtol=1e-5, atol=1e-5,
        )

    def test_fraunhofer_and_padded_plans_do_not_fuse(self):
        """Fusion is gated off where the hop is not fft->tf->ifft."""
        plan_fr = pp.plan_from_config(
            DONNConfig(**{**TINY, "approximation": "fraunhofer",
                          "band_limit": False, "distance": 2.5,
                          "use_pallas": True}), 1.0)
        plan_pad = pp.plan_from_config(
            DONNConfig(**{**TINY, "pad": True, "use_pallas": True}), 1.0)
        assert not plan_fr._fuse
        assert not plan_pad._fuse


# --------------------------------------------------------------------------
class TestQuantizedPlanes:
    def test_invalid_dtype_rejected(self):
        model, params = _model(name="qp-bad")
        with pytest.raises(ValueError, match="plane_dtype"):
            freeze(model, params, plane_dtype="float16")

    def test_f32_path_bit_identical_to_default(self):
        model, params = _model(name="qp-f32", codesign="qat")
        x = jnp.asarray(_digits(3))
        a = freeze(model, params)
        b = freeze(model, params, plane_dtype="float32")
        assert a.plane_dtype == b.plane_dtype == "float32"
        np.testing.assert_array_equal(
            np.asarray(a.forward(x)), np.asarray(b.forward(x))
        )

    @pytest.mark.parametrize("dtype,tol", [("bfloat16", 5e-2),
                                           ("int8", 2e-1)])
    def test_quantized_delta_bounded(self, dtype, tol):
        model, params = _model(name="qp-delta", codesign="qat")
        x = jnp.asarray(_digits(4))
        ref = np.asarray(freeze(model, params).forward(x))
        got = np.asarray(freeze(model, params, plane_dtype=dtype).forward(x))
        assert np.all(np.isfinite(got))
        delta = np.max(np.abs(got - ref)) / max(np.max(np.abs(ref)), 1e-12)
        assert delta <= tol, f"{dtype}: {delta:.3e} > {tol}"
        # class predictions survive the quantization at this scale
        np.testing.assert_array_equal(
            np.argmax(got, -1), np.argmax(ref, -1)
        )

    @pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
    def test_roundtrip_and_serving_bit_identical(self, dtype, tmp_path):
        model, params = _model(name="qp-rt", codesign="qat")
        x = _digits(2)
        dep = freeze(model, params, plane_dtype=dtype)
        assert dep.plane_dtype == dtype
        ref = np.asarray(dep.forward(jnp.asarray(x)))
        save_deployed(dep, tmp_path)
        dep2 = load_deployed(tmp_path)
        assert dep2.plane_dtype == dtype
        np.testing.assert_array_equal(
            np.asarray(dep2.forward(jnp.asarray(x))), ref
        )
        # engine vs the *jitted* forward: both sides compiled, bit-exact
        eng = InferenceEngine(dep2, buckets=(2,))
        np.testing.assert_array_equal(
            eng.infer(x), np.asarray(jax.jit(dep2.forward)(jnp.asarray(x)))
        )

    def test_segmented_quantized_planes(self):
        layers = (LayerSpec(0.05, size=40), LayerSpec(0.05, size=40),
                  LayerSpec(0.05, codesign="qat", device_levels=4))
        model, params = _model(name="qp-het", layers=layers)
        x = jnp.asarray(_digits(2))
        ref = np.asarray(freeze(model, params).forward(x))
        got = np.asarray(
            freeze(model, params, plane_dtype="bfloat16").forward(x)
        )
        delta = np.max(np.abs(got - ref)) / max(np.max(np.abs(ref)), 1e-12)
        assert delta <= 5e-2


# --------------------------------------------------------------------------
class TestRfftFirstHop:
    def test_agrees_with_full_spectrum(self):
        model, params = _model(name="rf-agree", codesign="qat")
        x = jnp.asarray(_digits(3))
        ref = np.asarray(freeze(model, params).forward(x))
        got = np.asarray(freeze(model, params, rfft_first=True).forward(x))
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-5)

    def test_engine_bit_identical_to_deployed_forward(self):
        model, params = _model(name="rf-eng")
        x = _digits(2)
        dep = freeze(model, params, rfft_first=True)
        ref = np.asarray(jax.jit(dep.forward)(jnp.asarray(x)))
        eng = InferenceEngine(dep, buckets=(2,))
        np.testing.assert_array_equal(eng.infer(x), ref)

    def test_engine_distinct_from_plain_executable(self):
        """rfft and plain deployments must not share cached executables."""
        model, params = _model(name="rf-key")
        assert (freeze(model, params).static_key()
                != freeze(model, params, rfft_first=True).static_key())

    def test_roundtrip_preserves_rfft_flag(self, tmp_path):
        model, params = _model(name="rf-rt", codesign="qat")
        x = _digits(2)
        dep = freeze(model, params, rfft_first=True, plane_dtype="int8")
        ref = np.asarray(dep.forward(jnp.asarray(x)))
        save_deployed(dep, tmp_path)
        dep2 = load_deployed(tmp_path)
        assert dep2.rfft_first and dep2.plane_dtype == "int8"
        np.testing.assert_array_equal(
            np.asarray(dep2.forward(jnp.asarray(x))), ref
        )

    def test_heterogeneous_rejected(self):
        layers = (LayerSpec(0.05, size=40), LayerSpec(0.05, size=40),
                  LayerSpec(0.05,))
        model, params = _model(name="rf-het", layers=layers)
        with pytest.raises(ValueError, match="rfft"):
            freeze(model, params, rfft_first=True)

    def test_unsupported_plan_rejected(self):
        model, params = _model(name="rf-pad", pad=True)
        with pytest.raises(ValueError, match="rfft"):
            freeze(model, params, rfft_first=True)


# --------------------------------------------------------------------------
class TestArtifactFormat:
    def test_format_field_is_current(self, tmp_path):
        model, params = _model(name="af-cur")
        save_deployed(freeze(model, params), tmp_path)
        meta = json.loads((tmp_path / ARTIFACT_FILE).read_text())
        assert meta["format"] == 2
        assert meta["plane_dtype"] == "float32"
        assert meta["rfft_first"] is False

    def test_unknown_format_rejected_with_clear_error(self, tmp_path):
        model, params = _model(name="af-unk")
        save_deployed(freeze(model, params), tmp_path)
        meta_path = tmp_path / ARTIFACT_FILE
        meta = json.loads(meta_path.read_text())
        meta["format"] = 99
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(ValueError, match=r"format 99.*reads formats"):
            load_deployed(tmp_path)

    def test_format_1_artifact_still_loads(self, tmp_path):
        """Legacy metas (no plane_dtype/rfft_first) imply f32 pairs."""
        model, params = _model(name="af-v1", codesign="qat")
        x = _digits(2)
        dep = freeze(model, params)
        ref = np.asarray(dep.forward(jnp.asarray(x)))
        save_deployed(dep, tmp_path)
        meta_path = tmp_path / ARTIFACT_FILE
        meta = json.loads(meta_path.read_text())
        meta["format"] = 1
        del meta["plane_dtype"], meta["rfft_first"]
        meta_path.write_text(json.dumps(meta))
        dep2 = load_deployed(tmp_path)
        assert dep2.plane_dtype == "float32" and not dep2.rfft_first
        np.testing.assert_array_equal(
            np.asarray(dep2.forward(jnp.asarray(x))), ref
        )
