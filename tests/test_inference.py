"""Deployment inference engine tests (ISSUE-5).

Pins the three serving invariants:
- **frozen bit-identity**: the frozen-plane fast path reproduces the
  training-path (codesign) forward bit-for-bit at eval, for every model
  family, codesign mode, kernel backend and heterogeneous stacks;
- **bucket-padding numerics**: padded rows of a micro-batch never perturb
  the real rows (per-sample agreement at rtol <= 1e-5; bit-exact here);
- **donation safety**: donated request buffers never alias a live caller
  array.

Multi-device dispatch runs in a subprocess with a forced 4-device host
platform (like tests/test_distributed.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_subprocess
from repro.core import DONNConfig, build_model
from repro.core import propagation as pp
from repro.core.config import LayerSpec
from repro.data.pipeline import bucket_for, pad_batch
from repro.runtime.inference import (
    DeployedDONN, InferenceEngine, MicroBatcher, freeze,
)

RNG = np.random.default_rng(0)


def _digits(b, shape=(28, 28), seed=0):
    return np.random.default_rng(seed).random((b,) + shape, np.float32)


def _model(seed=0, **kw):
    kw.setdefault("n", 32)
    kw.setdefault("depth", 3)
    kw.setdefault("distance", 0.05)
    kw.setdefault("det_size", 6)
    cfg = DONNConfig(**kw)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


class TestFrozenBitIdentity:
    """frozen-plane inference == the codesign forward, bitwise."""

    @pytest.mark.parametrize("kw", [
        dict(name="fz-none"),
        dict(name="fz-qat", codesign="qat"),
        dict(name="fz-qat-nl", codesign="qat", response_gamma=1.2),
        dict(name="fz-gum", codesign="gumbel"),
        dict(name="fz-ptq", codesign="ptq", device_levels=16),
    ])
    def test_classify_modes(self, kw):
        model, params = _model(**kw)
        x = _digits(4)
        ref = np.asarray(jax.jit(lambda p, xx: model.apply(p, xx))(params, x))
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(4,))
        np.testing.assert_array_equal(eng.infer(x), ref)

    def test_classify_pallas(self):
        model, params = _model(name="fz-pl", depth=2, codesign="qat",
                               use_pallas=True)
        x = _digits(2)
        ref = np.asarray(jax.jit(lambda p, xx: model.apply(p, xx))(params, x))
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(2,))
        np.testing.assert_array_equal(eng.infer(x), ref)

    def test_multi_channel(self):
        model, params = _model(name="fz-rgb", channels=3, det_size=4)
        x = _digits(3, shape=(3, 28, 28))
        ref = np.asarray(jax.jit(lambda p, xx: model.apply(p, xx))(params, x))
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(4,))
        np.testing.assert_array_equal(eng.infer(x), ref)

    def test_segmentation_with_skip(self):
        model, params = _model(name="fz-seg", segmentation=True, skip_from=0,
                               layer_norm=True, codesign="qat")
        x = _digits(3)
        # eval reference: train=False (no layer norm) — the serving path
        ref = np.asarray(jax.jit(lambda p, xx: model.apply(p, xx))(params, x))
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(4,))
        np.testing.assert_array_equal(eng.infer(x), ref)

    def test_heterogeneous_segmented_plan(self):
        model, params = _model(
            name="fz-het",
            layers=(LayerSpec(0.05, size=40), LayerSpec(0.05, size=40),
                    LayerSpec(0.05, codesign="qat", device_levels=4)),
        )
        x = _digits(2)
        ref = np.asarray(jax.jit(lambda p, xx: model.apply(p, xx))(params, x))
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(2,))
        np.testing.assert_array_equal(eng.infer(x), ref)

    def test_frozen_fast_path_skips_codesign(self):
        """forward(frozen=...) must not re-quantize the folded planes."""
        model, params = _model(name="fz-skipq", codesign="qat")
        plan = model.plan
        fz = plan.frozen_modulation(model.stacked_phases(params))
        u = model.encode(jnp.asarray(_digits(1)))
        out = plan.apply(None, u, frozen=fz)
        # reference: codesign applied exactly once, then a plain forward
        eff = plan._codesign_stack(model.stacked_phases(params), None)
        cfg_none = DONNConfig(**{**model.cfg.__dict__, "codesign": "none"})
        plain = pp.plan_from_config(cfg_none, model.gamma)
        want = plain.apply(eff, u)
        # the fold precomputes exp under jit while this eager reference
        # runs it op-by-op — agreement at the repo's standard tolerance
        # (the *jitted* end-to-end comparison above is bit-exact)
        np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


class TestBucketPadding:
    def test_bucket_for(self):
        assert bucket_for(1, (1, 2, 4)) == 1
        assert bucket_for(3, (1, 2, 4)) == 4
        assert bucket_for(9, (1, 2, 4)) == 4  # over the top: largest bucket
        with pytest.raises(ValueError):
            bucket_for(0, (1, 2))

    def test_pad_batch_fresh_buffer(self):
        x = np.ones((2, 4, 4), np.float32)
        out = pad_batch(x, 4)
        assert out.shape == (4, 4, 4)
        assert np.all(out[2:] == 0.0) and np.all(out[:2] == 1.0)
        # fresh buffer even when already at bucket size (donation safety)
        same = pad_batch(x, 2)
        assert same is not x and not np.shares_memory(same, x)
        with pytest.raises(ValueError):
            pad_batch(x, 1)

    def test_padded_rows_match_per_sample_apply(self):
        """Every partially-filled bucket agrees with unbatched apply."""
        model, params = _model(name="bp", codesign="qat")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(4, 8))
        apply1 = jax.jit(lambda p, xx: model.apply(p, xx))
        for b in (1, 3, 5, 8, 11):
            x = _digits(b, seed=b)
            got = eng.infer(x)
            ref = np.concatenate(
                [np.asarray(apply1(params, x[i:i + 1])) for i in range(b)]
            )
            np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-7)

    def test_micro_batcher_matches_direct_apply(self):
        model, params = _model(name="mb", codesign="qat")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(2, 8))
        eng.warmup()
        mb = MicroBatcher(eng, max_wait_ms=5.0)
        x = _digits(5, seed=7)
        futs = [mb.submit(x[i]) for i in range(5)]
        got = np.stack([f.result(timeout=60) for f in futs])
        mb.close()
        ref = np.asarray(
            jax.jit(lambda p, xx: model.apply(p, xx))(params, x)
        )
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-7)
        assert eng.stats["requests"] == 5

    def test_micro_batcher_rejects_malformed_at_submit(self):
        """Validation fails bad requests at the door, before batching."""
        model, params = _model(name="mbx")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(2,))
        mb = MicroBatcher(eng, max_wait_ms=50.0)
        with pytest.raises(ValueError):
            mb.submit(np.zeros((14, 14), np.float32))  # wrong image shape
        with pytest.raises(TypeError):
            mb.submit(np.array([["a"] * 28] * 28))  # non-numeric dtype
        good = mb.submit(_digits(1)[0])  # rejects never reach the worker
        out = good.result(timeout=60)
        mb.close()
        assert out.shape == (model.cfg.num_classes,)
        assert mb.stats["submitted"] == 1 and mb.stats["failed"] == 0

    def test_micro_batcher_bisects_poisoned_group(self):
        """With validation off, a poison request that breaks the whole
        group fails only its own future; neighbors still get results."""
        model, params = _model(name="mbp")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(4,))
        mb = MicroBatcher(eng, max_wait_ms=150.0, validate=False)
        x = _digits(2, seed=8)
        # a 0-d scalar can't stack with images AND fails when served alone
        good1 = mb.submit(x[0])
        poison = mb.submit(np.float32(0.5))
        good2 = mb.submit(x[1])
        with pytest.raises(Exception):
            poison.result(timeout=60)
        ref = np.asarray(
            jax.jit(lambda p, xx: model.apply(p, xx))(params, x)
        )
        np.testing.assert_allclose(good1.result(timeout=60), ref[0],
                                   rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(good2.result(timeout=60), ref[1],
                                   rtol=1e-5, atol=1e-7)
        mb.close()
        assert mb.stats["failed"] == 1 and mb.stats["served"] == 2

    def test_micro_batcher_deadline_flush(self):
        """Fewer requests than the largest bucket still get served."""
        model, params = _model(name="mbd")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(32,))
        mb = MicroBatcher(eng, max_wait_ms=1.0)
        fut = mb.submit(_digits(1)[0])
        out = fut.result(timeout=60)
        mb.close()
        assert out.shape == (model.cfg.num_classes,)
        assert eng.stats["padded_rows"] == 31


class TestDonationSafety:
    def test_donation_never_aliases_live_request_buffers(self):
        """Caller arrays survive a donated inference, even at exact bucket
        size, and repeated calls with the same array work."""
        model, params = _model(name="dn")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(4,), donate=True)
        x_host = _digits(4, seed=3)
        x_dev = jnp.asarray(x_host)  # a live, caller-owned device buffer
        out1 = eng.infer(x_dev)
        # the caller's buffer must still be readable and unchanged
        np.testing.assert_array_equal(np.asarray(x_dev), x_host)
        out2 = eng.infer(x_dev)
        np.testing.assert_array_equal(out1, out2)

    def test_donate_matches_nondonate(self):
        model, params = _model(name="dn2", codesign="qat")
        dep = freeze(model, params)
        x = _digits(4, seed=4)
        a = InferenceEngine(dep, buckets=(4,), donate=True).infer(x)
        b = InferenceEngine(dep, buckets=(4,), donate=False).infer(x)
        np.testing.assert_array_equal(a, b)


class TestWarmupAndCaching:
    def test_warmup_pays_all_compiles(self):
        """After warmup, serving adds no new executable-cache misses."""
        model, params = _model(name="wu")
        dep = freeze(model, params)
        eng = InferenceEngine(dep, buckets=(2, 4))
        eng.warmup()
        misses = pp.plan_cache_stats()["exec_misses"]
        eng.infer(_digits(2))
        eng.infer(_digits(4))
        eng.infer(_digits(3))  # pads into the 4-bucket
        assert pp.plan_cache_stats()["exec_misses"] == misses

    def test_same_arch_shares_executables_across_params(self):
        """Frozen planes are traced inputs: two deployments of one
        architecture share one compiled program per bucket."""
        model, p1 = _model(name="sh1", codesign="qat", seed=1)
        _, p2 = _model(name="sh2", codesign="qat", seed=2)
        e1 = InferenceEngine(freeze(model, p1), buckets=(2,))
        e1.warmup()
        misses = pp.plan_cache_stats()["exec_misses"]
        e2 = InferenceEngine(freeze(model, p2), buckets=(2,))
        e2.warmup()
        assert pp.plan_cache_stats()["exec_misses"] == misses
        x = _digits(2, seed=9)
        r1, r2 = e1.infer(x), e2.infer(x)
        assert not np.allclose(r1, r2)  # different params, different outputs


class TestMultiDevice:
    def test_dp_dispatch_matches_single_device(self):
        code = """
import jax, numpy as np
from repro.core import DONNConfig, build_model
from repro.runtime.inference import freeze, InferenceEngine

assert jax.device_count() == 4
cfg = DONNConfig(name="dp", n=32, depth=3, distance=0.05, det_size=6,
                 codesign="qat")
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
dep = freeze(model, params)
x = np.random.default_rng(0).random((8, 28, 28), np.float32)
ref = InferenceEngine(dep, buckets=(8,)).infer(x)
got = InferenceEngine(dep, buckets=(8,), mesh_devices=4,
                      dp_min_bucket=4).infer(x)
rel = float(np.max(np.abs(got - ref)) / np.max(np.abs(ref)))
assert rel <= 1e-5, rel
# small buckets stay single-device (below dp_min_bucket)
e = InferenceEngine(dep, buckets=(2, 8), mesh_devices=4, dp_min_bucket=8)
small = e.infer(x[:2])
np.testing.assert_allclose(small, ref[:2], rtol=1e-5, atol=1e-7)
print("DP_OK", rel)
"""
        r = run_subprocess(code, device_count=4)
        assert r.returncode == 0, r.stderr
        assert "DP_OK" in r.stdout


class TestFreezeValidation:
    def test_freeze_rejects_non_models(self):
        with pytest.raises(TypeError):
            freeze(object(), {})

    def test_static_key_drops_name(self):
        model, params = _model(name="a-name")
        model2, _ = _model(name="b-name")
        assert (freeze(model, params).static_key()
                == freeze(model2, params).static_key())

    def test_engine_validates_buckets_and_devices(self):
        model, params = _model(name="val")
        dep = freeze(model, params)
        with pytest.raises(ValueError):
            InferenceEngine(dep, buckets=())
        with pytest.raises(ValueError):
            InferenceEngine(dep, buckets=(0, 2))
        with pytest.raises(ValueError):
            InferenceEngine(dep, mesh_devices=jax.device_count() + 1)
        assert isinstance(dep, DeployedDONN)
