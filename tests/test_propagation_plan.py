"""Propagation-plan engine: scan path vs eager loop, TF cache, fused kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DONNConfig, build_model
from repro.core import diffraction as df
from repro.core import propagation as pp
from repro.data import synth_digits, synth_rgb_scenes, synth_seg
from repro.kernels import ops

TINY = dict(name="t", n=64, depth=3, distance=0.05, det_size=8)


def _pair(cfg_kw):
    cfg = DONNConfig(**cfg_kw)
    return build_model(cfg), build_model(
        dataclasses.replace(cfg, engine="eager")
    )


class TestScanMatchesEager:
    @pytest.mark.parametrize(
        "extra",
        [
            {},
            {"approximation": "fresnel"},
            {"pad": True},
            {"approximation": "fraunhofer", "band_limit": False},
            {"use_pallas": True},
            {"codesign": "qat", "device_levels": 64},
            {"distances": (0.04, 0.05, 0.06, 0.08)},
        ],
        ids=["rs", "fresnel", "padded", "fraunhofer", "pallas", "qat",
             "heterogeneous"],
    )
    def test_classify_forward(self, extra):
        m_scan, m_eager = _pair({**TINY, **extra})
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_classify_gradients_match(self):
        m_scan, m_eager = _pair(TINY)
        p = m_scan.init(jax.random.PRNGKey(1))
        xs, _ = synth_digits(4, seed=1)
        x = jnp.asarray(xs)
        g1 = jax.grad(lambda p: jnp.sum(m_scan.apply(p, x) ** 2))(p)
        g2 = jax.grad(lambda p: jnp.sum(m_eager.apply(p, x) ** 2))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_segmentation_with_skip(self):
        m_scan, m_eager = _pair(
            {**TINY, "segmentation": True, "skip_from": 0, "layer_norm": True}
        )
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_seg(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x, train=True), m_eager.apply(p, x, train=True),
            rtol=1e-5, atol=1e-5,
        )

    def test_jit_apply(self):
        m_scan, m_eager = _pair(TINY)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=2)
        x = jnp.asarray(xs)
        got = jax.jit(lambda p, x: m_scan.apply(p, x))(p, x)
        np.testing.assert_allclose(got, m_eager.apply(p, x), rtol=1e-5,
                                   atol=1e-5)


class TestTFCache:
    def test_repeated_geometry_hits(self):
        pp.clear_tf_cache()
        cfg = DONNConfig(**TINY)
        build_model(cfg)
        s0 = pp.tf_cache_stats()
        assert s0["misses"] > 0
        build_model(cfg)  # identical geometry: everything served from cache
        s1 = pp.tf_cache_stats()
        assert s1["misses"] == s0["misses"]
        assert s1["hits"] > s0["hits"]

    def test_distinct_geometry_misses(self):
        pp.clear_tf_cache()
        g = df.Grid(32, 36e-6)
        pp.transfer_planes(g, 0.05, 532e-9)
        before = pp.tf_cache_stats()["misses"]
        pp.transfer_planes(g, 0.06, 532e-9)  # different z
        pp.transfer_planes(g, 0.05, 633e-9)  # different wavelength
        assert pp.tf_cache_stats()["misses"] == before + 2

    def test_cached_planes_match_direct_computation(self):
        g = df.Grid(32, 36e-6)
        h = df.transfer_function(g, 0.05, 532e-9, df.RS, True)
        planes = pp.transfer_planes(g, 0.05, 532e-9, df.RS, True)
        np.testing.assert_array_equal(planes["hr"], h.real)
        np.testing.assert_array_equal(planes["hi"], h.imag)
        np.testing.assert_allclose(
            planes["amp"] * np.exp(1j * planes["theta"]), h, atol=1e-6
        )


class TestMultiChannelBatched:
    def test_batched_matches_per_channel_reference(self):
        cfg = DONNConfig(**{**TINY, "channels": 3, "num_classes": 6})
        m_scan, m_eager = _pair(cfg.__dict__)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_rgb_scenes(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_batched_gradients_match(self):
        cfg = DONNConfig(**{**TINY, "channels": 3, "num_classes": 6})
        m_scan, m_eager = _pair(cfg.__dict__)
        p = m_scan.init(jax.random.PRNGKey(2))
        xs, _ = synth_rgb_scenes(4, seed=1)
        x = jnp.asarray(xs)
        g1 = jax.grad(lambda p: jnp.sum(m_scan.apply(p, x) ** 2))(p)
        g2 = jax.grad(lambda p: jnp.sum(m_eager.apply(p, x) ** 2))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_batched_pallas_readout(self):
        cfg_kw = {**TINY, "channels": 3, "num_classes": 6, "use_pallas": True}
        m_scan, m_eager = _pair(cfg_kw)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_rgb_scenes(4, seed=2)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=2e-4, atol=2e-4
        )


class TestPhaseTFApplyKernel:
    def _rand(self, shape, seed):
        r = np.random.default_rng(seed)
        return jnp.asarray(r.normal(size=shape), jnp.float32)

    @pytest.mark.parametrize("shape", [(1, 8, 128), (3, 37, 111), (2, 64, 64)])
    def test_forward_matches_ref(self, shape):
        B, H, W = shape
        xr, xi = self._rand(shape, 1), self._rand(shape, 2)
        th, am = self._rand((H, W), 3), jnp.abs(self._rand((H, W), 4))
        got = ops.phase_tf_apply(xr, xi, th, am)
        want = ops.phase_tf_apply_ref(xr, xi, th, am)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    def test_per_plane_forward(self):
        P, B, H, W = 3, 4, 16, 64
        xr, xi = self._rand((B, P, H, W), 5), self._rand((B, P, H, W), 6)
        th = self._rand((P, H, W), 7)
        am = jnp.abs(self._rand((P, H, W), 8))
        got = ops.phase_tf_apply(xr, xi, th, am)
        want = ops.phase_tf_apply_ref(xr, xi, th, am)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    def test_gradients_match_ref(self):
        B, H, W = 2, 33, 65
        xr, xi = self._rand((B, H, W), 9), self._rand((B, H, W), 10)
        th, am = self._rand((H, W), 11), jnp.abs(self._rand((H, W), 12))

        def loss(fn, xr, xi, th):
            a, b = fn(xr, xi, th, am)
            return jnp.sum(a**2 + 2.0 * b)

        g1 = jax.grad(lambda *a: loss(ops.phase_tf_apply, *a),
                      argnums=(0, 1, 2))(xr, xi, th)
        g2 = jax.grad(lambda *a: loss(ops.phase_tf_apply_ref, *a),
                      argnums=(0, 1, 2))(xr, xi, th)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_unit_amp_matches_phase_apply(self):
        B, H, W = 2, 16, 128
        xr, xi = self._rand((B, H, W), 13), self._rand((B, H, W), 14)
        th = self._rand((H, W), 15)
        got = ops.phase_tf_apply(xr, xi, th, jnp.ones((H, W), jnp.float32))
        want = ops.phase_apply(xr, xi, th, 1.0)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
