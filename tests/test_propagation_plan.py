"""Propagation-plan engine: scan path vs eager loop, TF cache, fused kernel."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DONNConfig, build_model
from repro.core import diffraction as df
from repro.core import propagation as pp
from repro.data import synth_digits, synth_rgb_scenes, synth_seg
from repro.kernels import ops

TINY = dict(name="t", n=64, depth=3, distance=0.05, det_size=8)


def _pair(cfg_kw):
    cfg = DONNConfig(**cfg_kw)
    return build_model(cfg), build_model(
        dataclasses.replace(cfg, engine="eager")
    )


class TestScanMatchesEager:
    @pytest.mark.parametrize(
        "extra",
        [
            {},
            {"approximation": "fresnel"},
            {"pad": True},
            # Fraunhofer needs the far field: at TINY's default z=0.05 the
            # Fresnel number is ~50 (physics validator flags it); z=2.5
            # puts every hop at F <= 1 where the single-FFT pattern holds
            {"approximation": "fraunhofer", "band_limit": False,
             "distance": 2.5},
            {"use_pallas": True},
            {"codesign": "qat", "device_levels": 64},
            {"distances": (0.04, 0.05, 0.06, 0.08)},
        ],
        ids=["rs", "fresnel", "padded", "fraunhofer", "pallas", "qat",
             "heterogeneous"],
    )
    def test_classify_forward(self, extra):
        m_scan, m_eager = _pair({**TINY, **extra})
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_classify_gradients_match(self):
        m_scan, m_eager = _pair(TINY)
        p = m_scan.init(jax.random.PRNGKey(1))
        xs, _ = synth_digits(4, seed=1)
        x = jnp.asarray(xs)
        g1 = jax.grad(lambda p: jnp.sum(m_scan.apply(p, x) ** 2))(p)
        g2 = jax.grad(lambda p: jnp.sum(m_eager.apply(p, x) ** 2))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_segmentation_with_skip(self):
        m_scan, m_eager = _pair(
            {**TINY, "segmentation": True, "skip_from": 0, "layer_norm": True}
        )
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_seg(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x, train=True), m_eager.apply(p, x, train=True),
            rtol=1e-5, atol=1e-5,
        )

    def test_jit_apply(self):
        m_scan, m_eager = _pair(TINY)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=2)
        x = jnp.asarray(xs)
        got = jax.jit(lambda p, x: m_scan.apply(p, x))(p, x)
        np.testing.assert_allclose(got, m_eager.apply(p, x), rtol=1e-5,
                                   atol=1e-5)


class TestForwardSlicing:
    """forward(start/stop) composition — beyond the segmentation skip path."""

    def _plan_and_inputs(self, extra=None, seed=0):
        cfg = DONNConfig(**{**TINY, **(extra or {})})
        plan = pp.plan_from_config(cfg, 1.0)
        r = np.random.default_rng(seed)
        phis = jnp.asarray(
            r.uniform(0, 2 * np.pi, (cfg.depth, cfg.n, cfg.n)), jnp.float32
        )
        u = jnp.asarray(
            r.normal(size=(2, cfg.n, cfg.n))
            + 1j * r.normal(size=(2, cfg.n, cfg.n)),
            jnp.complex64,
        )
        return cfg, plan, phis, u

    @pytest.mark.parametrize("cut", [1, 2])
    def test_slices_compose_to_full_forward(self, cut):
        _, plan, phis, u = self._plan_and_inputs()
        full = plan.forward(phis, u)
        head = plan.forward(phis, u, stop=cut)
        tail = plan.forward(phis, head, start=cut)
        np.testing.assert_allclose(tail, full, rtol=1e-5, atol=1e-6)

    def test_slices_compose_with_codesign_rngs(self):
        cfg, plan, phis, u = self._plan_and_inputs(
            {"codesign": "gumbel", "device_levels": 16}, seed=1
        )
        rngs = jax.random.split(jax.random.PRNGKey(3), cfg.depth)
        full = plan.forward(phis, u, rngs)
        head = plan.forward(phis, u, rngs, stop=1)
        tail = plan.forward(phis, head, rngs, start=1)
        # codesign quantizes the full stack, so layer-i rng alignment is
        # independent of the slice boundaries
        np.testing.assert_allclose(tail, full, rtol=1e-5, atol=1e-6)

    def test_empty_slice_is_identity(self):
        _, plan, phis, u = self._plan_and_inputs()
        np.testing.assert_array_equal(plan.forward(phis, u, start=2, stop=2), u)

    def test_external_tfs_match_baked_constants(self):
        _, plan, phis, u = self._plan_and_inputs(seed=2)
        tfs = plan._tf_pair()
        np.testing.assert_allclose(
            plan.apply(phis, u, tfs=tfs), plan.apply(phis, u),
            rtol=1e-6, atol=1e-6,
        )


class TestApplyBatch:
    def test_matches_stacked_sequential(self):
        cfg = DONNConfig(**TINY)
        plan = pp.plan_from_config(cfg, 1.0)
        r = np.random.default_rng(0)
        K = 3
        phis = jnp.asarray(
            r.uniform(0, 2 * np.pi, (K, cfg.depth, cfg.n, cfg.n)), jnp.float32
        )
        u = jnp.asarray(
            r.normal(size=(2, cfg.n, cfg.n))
            + 1j * r.normal(size=(2, cfg.n, cfg.n)),
            jnp.complex64,
        )
        got = plan.apply_batch(phis, u)
        for k in range(K):
            np.testing.assert_allclose(
                got[k], plan.apply(phis[k], u), rtol=1e-5, atol=1e-6
            )

    def test_per_candidate_inputs_and_rng(self):
        cfg = DONNConfig(**{**TINY, "codesign": "gumbel", "device_levels": 8})
        plan = pp.plan_from_config(cfg, 1.0)
        r = np.random.default_rng(1)
        K = 2
        phis = jnp.asarray(
            r.uniform(0, 2 * np.pi, (K, cfg.depth, cfg.n, cfg.n)), jnp.float32
        )
        u = jnp.asarray(
            r.normal(size=(K, 2, cfg.n, cfg.n))
            + 1j * r.normal(size=(K, 2, cfg.n, cfg.n)),
            jnp.complex64,
        )
        rng = jax.random.PRNGKey(5)
        got = plan.apply_batch(phis, u, rng=rng, per_candidate_inputs=True)
        rngs = jax.random.split(rng, K)
        for k in range(K):
            np.testing.assert_allclose(
                got[k], plan.apply(phis[k], u[k], rngs[k]),
                rtol=1e-5, atol=1e-6,
            )


class TestScanUnroll:
    @pytest.mark.parametrize("unroll", [1, 2, 3])
    def test_unroll_matches_eager(self, unroll):
        m_scan, m_eager = _pair({**TINY, "scan_unroll": unroll})
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_default_heuristic(self):
        assert pp.default_scan_unroll(3) == 3
        assert pp.default_scan_unroll(8) == 8
        assert pp.default_scan_unroll(16) == 8
        assert pp.default_scan_unroll(64) == 8

    def test_invalid_unroll_rejected(self):
        with pytest.raises(ValueError):
            DONNConfig(**{**TINY, "scan_unroll": 0})


class TestTFDtype:
    def test_bf16_storage_agrees_loosely(self):
        """bf16 TF planes, f32 accumulation: documented looser tolerance."""
        m_bf16, m_eager = _pair({**TINY, "tf_dtype": "bfloat16"})
        p = m_bf16.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=0)
        x = jnp.asarray(xs)
        got = m_bf16.apply(p, x)
        want = m_eager.apply(p, x)
        np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)
        assert got.dtype == jnp.float32  # accumulation stays f32
        # the bf16 storage must actually engage: outputs differ from the
        # f32 scan path beyond float32 roundoff
        f32 = build_model(DONNConfig(**{**TINY, "tf_dtype": "float32"}))
        assert not np.allclose(got, f32.apply(p, x), rtol=1e-6, atol=1e-6)

    def test_invalid_tf_dtype_rejected(self):
        with pytest.raises(ValueError):
            DONNConfig(**{**TINY, "tf_dtype": "float16"})


class TestTFCache:
    def test_repeated_geometry_hits(self):
        pp.clear_tf_cache()
        cfg = DONNConfig(**TINY)
        build_model(cfg)
        s0 = pp.tf_cache_stats()
        assert s0["misses"] > 0
        build_model(cfg)  # identical geometry: everything served from cache
        s1 = pp.tf_cache_stats()
        assert s1["misses"] == s0["misses"]
        assert s1["hits"] > s0["hits"]

    def test_distinct_geometry_misses(self):
        pp.clear_tf_cache()
        g = df.Grid(32, 36e-6)
        pp.transfer_planes(g, 0.05, 532e-9)
        before = pp.tf_cache_stats()["misses"]
        pp.transfer_planes(g, 0.06, 532e-9)  # different z
        pp.transfer_planes(g, 0.05, 633e-9)  # different wavelength
        assert pp.tf_cache_stats()["misses"] == before + 2

    def test_cached_planes_match_direct_computation(self):
        g = df.Grid(32, 36e-6)
        h = df.transfer_function(g, 0.05, 532e-9, df.RS, True)
        planes = pp.transfer_planes(g, 0.05, 532e-9, df.RS, True)
        np.testing.assert_array_equal(planes["hr"], h.real)
        np.testing.assert_array_equal(planes["hi"], h.imag)
        np.testing.assert_allclose(
            planes["amp"] * np.exp(1j * planes["theta"]), h, atol=1e-6
        )

    def test_lru_refresh_on_hit(self, monkeypatch):
        """A hit must refresh recency: alternating sweeps keep hot entries."""
        pp.clear_tf_cache()
        monkeypatch.setattr(pp, "_TF_CACHE_MAX", 3)
        g = df.Grid(8, 36e-6)
        zs = [0.01, 0.02, 0.03]
        for z in zs:
            pp.transfer_planes(g, z, 532e-9)
        pp.transfer_planes(g, zs[0], 532e-9)  # hit: refresh z=0.01
        pp.transfer_planes(g, 0.04, 532e-9)  # evicts z=0.02 (now oldest)
        keys = {k[2] for k in pp._TF_CACHE}
        assert 0.01 in keys and 0.02 not in keys
        assert 0.03 in keys and 0.04 in keys

    def test_eviction_bounds_size(self, monkeypatch):
        pp.clear_tf_cache()
        monkeypatch.setattr(pp, "_TF_CACHE_MAX", 4)
        g = df.Grid(8, 36e-6)
        for i in range(10):
            pp.transfer_planes(g, 0.01 + 0.001 * i, 532e-9)
        assert len(pp._TF_CACHE) <= 4
        assert pp.tf_cache_stats()["misses"] == 10


class TestPlanCache:
    def test_repeated_config_hits(self):
        pp.clear_plan_cache()
        cfg = DONNConfig(**TINY)
        p1 = pp.plan_from_config(cfg, 1.0)
        s0 = pp.plan_cache_stats()
        p2 = pp.plan_from_config(DONNConfig(**TINY), 1.0)
        s1 = pp.plan_cache_stats()
        assert p1 is p2
        assert s1["hits"] == s0["hits"] + 1
        assert s1["misses"] == s0["misses"]

    def test_geometry_change_misses(self):
        pp.clear_plan_cache()
        cfg = DONNConfig(**TINY)
        pp.plan_from_config(cfg, 1.0)
        pp.plan_from_config(dataclasses.replace(cfg, distance=0.06), 1.0)
        pp.plan_from_config(dataclasses.replace(cfg, scan_unroll=2), 1.0)
        pp.plan_from_config(cfg, 0.9)  # gamma is part of the key
        assert pp.plan_cache_stats()["misses"] == 4

    def test_eviction_lru(self, monkeypatch):
        pp.clear_plan_cache()
        monkeypatch.setattr(pp, "_PLAN_CACHE_MAX", 2)
        cfg = DONNConfig(**TINY)
        a = pp.plan_from_config(cfg, 1.0)
        pp.plan_from_config(dataclasses.replace(cfg, distance=0.06), 1.0)
        assert pp.plan_from_config(cfg, 1.0) is a  # hit refreshes recency
        pp.plan_from_config(dataclasses.replace(cfg, distance=0.07), 1.0)
        # the refreshed entry survived; the middle one was evicted
        assert pp.plan_from_config(cfg, 1.0) is a
        assert len(pp._PLAN_CACHE) <= 2

    def test_clear_resets_stats_and_executables(self):
        pp.clear_plan_cache()
        cfg = DONNConfig(**TINY)
        pp.plan_from_config(cfg, 1.0)
        pp.clear_plan_cache()
        s = pp.plan_cache_stats()
        assert s == {"hits": 0, "misses": 0, "size": 0,
                     "exec_hits": 0, "exec_misses": 0, "exec_size": 0}


class TestMultiChannelBatched:
    def test_batched_matches_per_channel_reference(self):
        cfg = DONNConfig(**{**TINY, "channels": 3, "num_classes": 6})
        m_scan, m_eager = _pair(cfg.__dict__)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_rgb_scenes(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_batched_gradients_match(self):
        cfg = DONNConfig(**{**TINY, "channels": 3, "num_classes": 6})
        m_scan, m_eager = _pair(cfg.__dict__)
        p = m_scan.init(jax.random.PRNGKey(2))
        xs, _ = synth_rgb_scenes(4, seed=1)
        x = jnp.asarray(xs)
        g1 = jax.grad(lambda p: jnp.sum(m_scan.apply(p, x) ** 2))(p)
        g2 = jax.grad(lambda p: jnp.sum(m_eager.apply(p, x) ** 2))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_batched_pallas_readout(self):
        cfg_kw = {**TINY, "channels": 3, "num_classes": 6, "use_pallas": True}
        m_scan, m_eager = _pair(cfg_kw)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_rgb_scenes(4, seed=2)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=2e-4, atol=2e-4
        )


class TestPhaseTFApplyKernel:
    def _rand(self, shape, seed):
        r = np.random.default_rng(seed)
        return jnp.asarray(r.normal(size=shape), jnp.float32)

    @pytest.mark.parametrize("shape", [(1, 8, 128), (3, 37, 111), (2, 64, 64)])
    def test_forward_matches_ref(self, shape):
        B, H, W = shape
        xr, xi = self._rand(shape, 1), self._rand(shape, 2)
        th, am = self._rand((H, W), 3), jnp.abs(self._rand((H, W), 4))
        got = ops.phase_tf_apply(xr, xi, th, am)
        want = ops.phase_tf_apply_ref(xr, xi, th, am)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    def test_per_plane_forward(self):
        P, B, H, W = 3, 4, 16, 64
        xr, xi = self._rand((B, P, H, W), 5), self._rand((B, P, H, W), 6)
        th = self._rand((P, H, W), 7)
        am = jnp.abs(self._rand((P, H, W), 8))
        got = ops.phase_tf_apply(xr, xi, th, am)
        want = ops.phase_tf_apply_ref(xr, xi, th, am)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    def test_multi_axis_plane_broadcast(self):
        """(K, C, H, W) plane stacks flatten to one plane-major axis."""
        K, C, B, H, W = 2, 3, 4, 16, 64
        xr = self._rand((B, K, C, H, W), 20)
        xi = self._rand((B, K, C, H, W), 21)
        th = self._rand((K, C, H, W), 22)
        am = jnp.abs(self._rand((K, C, H, W), 23))
        got = ops.phase_tf_apply(xr, xi, th, am)
        want = ops.phase_tf_apply_ref(xr, xi, th, am)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
        # leading batch axis absent: (K, C, H, W) fields squeeze through too
        got2 = ops.phase_tf_apply(xr[0], xi[0], th, am)
        for g, w in zip(got2, (want[0][0], want[1][0])):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)

    def test_mismatched_plane_axes_raise(self):
        xr = self._rand((4, 2, 16, 64), 24)
        th = self._rand((3, 16, 64), 25)
        with pytest.raises(ValueError, match="plane axes"):
            ops.phase_tf_apply(xr, xr, th, jnp.abs(th))

    def test_gradients_match_ref(self):
        B, H, W = 2, 33, 65
        xr, xi = self._rand((B, H, W), 9), self._rand((B, H, W), 10)
        th, am = self._rand((H, W), 11), jnp.abs(self._rand((H, W), 12))

        def loss(fn, xr, xi, th):
            a, b = fn(xr, xi, th, am)
            return jnp.sum(a**2 + 2.0 * b)

        g1 = jax.grad(lambda *a: loss(ops.phase_tf_apply, *a),
                      argnums=(0, 1, 2))(xr, xi, th)
        g2 = jax.grad(lambda *a: loss(ops.phase_tf_apply_ref, *a),
                      argnums=(0, 1, 2))(xr, xi, th)
        for a, b in zip(g1, g2):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_unit_amp_matches_phase_apply(self):
        B, H, W = 2, 16, 128
        xr, xi = self._rand((B, H, W), 13), self._rand((B, H, W), 14)
        th = self._rand((H, W), 15)
        got = ops.phase_tf_apply(xr, xi, th, jnp.ones((H, W), jnp.float32))
        want = ops.phase_apply(xr, xi, th, 1.0)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)
