"""Multi-device tests (run in a subprocess with 8 host-platform devices so
the main pytest process keeps a single device — see the dry-run rules)."""
import json
import textwrap

import pytest

from conftest import run_subprocess

SUITE = textwrap.dedent("""
    import json, dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    results = {}

    # ---------------------------------------------------------- setup
    assert len(jax.devices()) == 8, len(jax.devices())
    from repro.launch.mesh import make_mesh
    from repro.models import get_config, lm
    from repro.runtime import sharding as shd, steps as steps_mod
    from repro.optim import AdamW

    mesh = make_mesh((2, 4), ("data", "model"))
    cfg = dataclasses.replace(get_config("glm4-9b", smoke=True),
                              dtype=jnp.float32, d_model=64, n_layers=2)

    # 1. sharded train step runs; loss matches single-device exactly-ish
    B, S = 4, 32
    batch_specs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
                   "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    opt = AdamW(lr=1e-2)
    fn, s_shard, b_shard, sspecs = steps_mod.compile_train_step(
        cfg, mesh, batch_specs, optimizer=opt)
    state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(0), opt)
    state_sh = jax.device_put(state, s_shard)
    r = np.random.default_rng(0)
    toks = r.integers(0, cfg.vocab, (B, S)).astype(np.int32)
    batch = {"tokens": toks, "labels": np.roll(toks, -1, 1)}
    batch_sh = jax.device_put(batch, b_shard)
    losses_sharded = []
    for i in range(3):
        state_sh, m = fn(state_sh, batch_sh)
        losses_sharded.append(float(m["loss"]))

    # single-device reference
    base = steps_mod.make_train_step(cfg, opt)
    state1 = steps_mod.init_train_state(cfg, jax.random.PRNGKey(0), opt)
    losses_single = []
    for i in range(3):
        state1, m1 = jax.jit(base)(state1, batch)
        losses_single.append(float(m1["loss"]))
    results["dp_tp_matches_single"] = bool(
        np.allclose(losses_sharded, losses_single, rtol=5e-4, atol=5e-4))
    results["losses"] = [losses_sharded, losses_single]

    # 2. pencil FFT vs fft2 — the supported in-scan entry
    # (local_spectral_pair composed under an explicit shard_map); the
    # standalone pencil_fft2 wrapper is deprecated but works one cycle
    import warnings
    from repro.compat import shard_map
    from repro.runtime.pencil_fft import local_spectral_pair, pencil_fft2
    mesh8 = make_mesh((8,), ("model",))
    rr = np.random.default_rng(1)
    u = jnp.asarray(rr.normal(size=(2, 64, 128))
                    + 1j * rr.normal(size=(2, 64, 128)), jnp.complex64)
    fft2_loc, ifft2_loc = local_spectral_pair("model", 8)
    row_spec = shd.rules_pspec((None, "field_h", None),
                               {"field_h": "model"})
    got = shard_map(fft2_loc, mesh=mesh8, in_specs=row_spec,
                    out_specs=row_spec, check_vma=False)(u)
    want = jnp.fft.fft2(u)
    results["pencil_fft_ok"] = bool(np.allclose(np.asarray(got),
                                                np.asarray(want),
                                                rtol=2e-3, atol=2e-3))
    back = shard_map(ifft2_loc, mesh=mesh8, in_specs=row_spec,
                     out_specs=row_spec, check_vma=False)(got)
    results["pencil_ifft_ok"] = bool(np.allclose(np.asarray(back),
                                                 np.asarray(u),
                                                 rtol=2e-3, atol=2e-3))
    with warnings.catch_warnings(record=True) as wrec:
        warnings.simplefilter("always")
        dep_out = pencil_fft2(u, mesh8)
    results["pencil_fft2_deprecated"] = bool(
        any(issubclass(w.category, DeprecationWarning) for w in wrec)
        and np.allclose(np.asarray(dep_out), np.asarray(want),
                        rtol=2e-3, atol=2e-3))

    # 2b. pencil FFT gradients: value_and_grad of the distributed
    # angular-spectrum hop agrees with the single-device spectral hop
    from repro.runtime.pencil_fft import propagate_tf_distributed
    h = jnp.asarray(rr.normal(size=(64, 128))
                    + 1j * rr.normal(size=(64, 128)), jnp.complex64)

    def loss_dist(v):
        return jnp.sum(jnp.abs(propagate_tf_distributed(v, h, mesh8)) ** 2)

    def loss_ref(v):
        return jnp.sum(jnp.abs(jnp.fft.ifft2(jnp.fft.fft2(v) * h)) ** 2)

    vd, gd = jax.value_and_grad(loss_dist)(u)
    vr, gr = jax.value_and_grad(loss_ref)(u)
    g_scale = float(jnp.max(jnp.abs(gr)))
    results["pencil_grad_val_rel_err"] = abs(float(vd) - float(vr)) / abs(
        float(vr))
    results["pencil_grad_max_rel_err"] = float(
        jnp.max(jnp.abs(gd - gr))) / g_scale
    results["pencil_grad_ok"] = bool(
        results["pencil_grad_val_rel_err"] <= 1e-5
        and results["pencil_grad_max_rel_err"] <= 1e-5)

    # 2c. in-scan usage: the spatially-sharded DONN training loss (pencil
    # FFT inside the fused layer scan, row-sharded planes) matches the
    # single-device step — loss and grads to rtol <= 1e-5, and one
    # compiled spatial train step tracks the reference step
    from repro.core.config import DONNConfig
    from repro.core.models import cached_model
    from repro.core.train_utils import mse_softmax_loss
    from repro.nn import init_params
    from repro.runtime import donn_steps as ds

    cfg_sp = DONNConfig(name="sp", n=64, depth=4, distance=0.05, det_size=8)
    sspecs_sp = ds.donn_state_specs(cfg_sp)
    state_sp = init_params(sspecs_sp, jax.random.PRNGKey(0))
    rsp = np.random.default_rng(3)
    batch_sp = {
        "images": rsp.uniform(0, 1, (8, 28, 28)).astype(np.float32),
        "labels": rsp.integers(0, 10, (8,)).astype(np.int32),
    }
    loss_sp = ds.make_donn_spatial_loss(cfg_sp, mesh8)
    donn = cached_model(cfg_sp)
    loss_1d = lambda p, b: mse_softmax_loss(
        donn.apply(p, b["images"]), b["labels"], cfg_sp.num_classes)
    v1, g1 = jax.jit(jax.value_and_grad(loss_1d))(state_sp["params"],
                                                  batch_sp)
    v2, g2 = jax.jit(jax.value_and_grad(loss_sp))(state_sp["params"],
                                                  batch_sp)
    gmax = max(float(jnp.max(jnp.abs(g)))
               for g in jax.tree.leaves(g1))
    results["spatial_loss_rel_err"] = abs(float(v1) - float(v2)) / abs(
        float(v1))
    results["spatial_grad_max_rel_err"] = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2))
    ) / gmax
    results["spatial_loss_grads_ok"] = bool(
        results["spatial_loss_rel_err"] <= 1e-5
        and results["spatial_grad_max_rel_err"] <= 1e-5)

    from repro.optim import AdamW as _AdamW
    fn_sp, s_sh_sp, b_sh_sp, _ = ds.compile_donn_train_step_spatial(
        cfg_sp, mesh8, optimizer=_AdamW(lr=0.05))
    st_sp = jax.device_put(jax.tree.map(jnp.array, state_sp), s_sh_sp)
    b_dev = jax.device_put(batch_sp, b_sh_sp)
    ref_step = jax.jit(ds.make_donn_train_step(cfg_sp, _AdamW(lr=0.05)))
    st_ref = jax.tree.map(jnp.array, state_sp)
    sp_losses, ref_losses = [], []
    for _ in range(2):
        st_sp, m_sp = fn_sp(st_sp, b_dev)
        st_ref, m_ref = ref_step(st_ref, batch_sp)
        sp_losses.append(float(m_sp["loss"]))
        ref_losses.append(float(m_ref["loss"]))
    p_scale = max(float(jnp.max(jnp.abs(p)))
                  for p in jax.tree.leaves(st_ref["params"]))
    results["spatial_step_param_rel_err"] = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(st_sp["params"]),
            jax.tree.leaves(st_ref["params"]))
    ) / p_scale
    # losses track at the grad tolerance; the *param* tolerance is looser
    # because Adam's normalized update amplifies O(1e-6) grad differences
    # to O(lr) wherever the gradient is near zero (sign flips in
    # mh/sqrt(vh)) — inherent to Adam, not to the sharded forward
    results["spatial_step_ok"] = bool(
        np.allclose(sp_losses, ref_losses, rtol=1e-5, atol=1e-7)
        and results["spatial_step_param_rel_err"] <= 2e-3)

    # 3. compressed psum over a pod axis (shard_map)
    from repro.compat import shard_map
    from repro.optim.compression import compressed_psum_mean
    mesh_pod = make_mesh((2, 4), ("pod", "data"))
    x = jnp.asarray(rr.normal(size=(2, 256)), jnp.float32)  # per-pod rows
    f = shard_map(lambda v: compressed_psum_mean(v, "pod"),
                  mesh=mesh_pod, in_specs=P("pod", None),
                  out_specs=P("pod", None), check_vma=False)
    got = f(x)
    want = jnp.broadcast_to(jnp.mean(x, 0, keepdims=True), x.shape)
    err = float(jnp.max(jnp.abs(got - want)))
    results["compressed_psum_err"] = err
    results["compressed_psum_ok"] = bool(err < np.abs(x).max() / 100)

    # 4. elastic checkpoint: save under mesh A, restore under mesh B
    import tempfile, pathlib
    from repro import checkpoint as ckpt
    d = tempfile.mkdtemp()
    ckpt.save(d, 5, state_sh)
    meshB = make_mesh((4, 2), ("data", "model"))
    s_shardB = shd.tree_shardings(sspecs, meshB)
    restored = ckpt.restore(d, 5, shd.abstract_like(sspecs),
                            shardings=s_shardB)
    ok = True
    for a, b in zip(jax.tree.leaves(state_sh), jax.tree.leaves(restored)):
        ok &= bool(jnp.allclose(jnp.asarray(a, jnp.float32),
                                jnp.asarray(b, jnp.float32)))
    results["elastic_reshard_ok"] = ok

    # 5. decode step under sharding: runs + finite
    fn_d, p_sh, c_sh, cspecs = steps_mod.compile_decode_step(cfg, mesh, 4, 32)
    params = jax.device_put(lm.init(cfg, jax.random.PRNGKey(0)), p_sh)
    cache = jax.device_put(lm.init_cache(cfg, 4, 32), c_sh)
    logits, cache = fn_d(params, cache, jnp.zeros((4, 1), jnp.int32),
                         jnp.int32(0))
    results["sharded_decode_finite"] = bool(
        jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    print("RESULTS:" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def suite_results():
    proc = run_subprocess(SUITE, device_count=8)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, proc.stdout
    return json.loads(line[0][len("RESULTS:"):])


def test_dp_tp_matches_single_device(suite_results):
    assert suite_results["dp_tp_matches_single"], suite_results["losses"]


def test_pencil_fft_matches_fft2(suite_results):
    assert suite_results["pencil_fft_ok"]
    assert suite_results["pencil_ifft_ok"]


def test_pencil_fft2_standalone_deprecated_but_working(suite_results):
    assert suite_results["pencil_fft2_deprecated"]


def test_pencil_fft_gradients_match_single_device(suite_results):
    assert suite_results["pencil_grad_ok"], (
        suite_results["pencil_grad_val_rel_err"],
        suite_results["pencil_grad_max_rel_err"],
    )


def test_spatial_train_loss_and_grads_match(suite_results):
    assert suite_results["spatial_loss_grads_ok"], (
        suite_results["spatial_loss_rel_err"],
        suite_results["spatial_grad_max_rel_err"],
    )


def test_spatial_train_step_tracks_reference(suite_results):
    assert suite_results["spatial_step_ok"], suite_results[
        "spatial_step_param_rel_err"]


def test_compressed_psum(suite_results):
    assert suite_results["compressed_psum_ok"], suite_results[
        "compressed_psum_err"]


def test_elastic_checkpoint_reshard(suite_results):
    assert suite_results["elastic_reshard_ok"]


def test_sharded_decode(suite_results):
    assert suite_results["sharded_decode_finite"]


# ---------------------------------------------------------------------------
# Suite 2: the unified 2-D (data, model) mesh — spatial x DP parity for
# every DONN family, the compiled sharded train step, rules-table edge
# cases, and row-sharded frozen serving (ISSUE 10).
# ---------------------------------------------------------------------------
SUITE2 = textwrap.dedent("""
    import json, warnings
    import numpy as np
    import jax, jax.numpy as jnp
    results = {}
    assert len(jax.devices()) == 8, len(jax.devices())

    from repro.core.config import DONNConfig, LayerSpec
    from repro.core.models import cached_model
    from repro.core.train_utils import (
        bce_segmentation_loss, mse_softmax_loss,
    )
    from repro.nn import init_params
    from repro.optim import AdamW
    from repro.runtime import donn_steps as ds
    from repro.runtime import sharding as shd

    mesh = shd.make_mesh_2d(data=2, model=4)
    key = jax.random.PRNGKey(0)

    # ---- 1. spatial x DP parity vs single device, all model families
    def parity(tag, cfg, batch):
        m = cached_model(cfg)
        params = m.init(key)
        loss_fn = ds.make_donn_sharded_loss(cfg, mesh)

        def ref_fn(p, b):
            if cfg.segmentation:
                return bce_segmentation_loss(
                    m.apply(p, b["images"], train=True), b["masks"])
            return mse_softmax_loss(
                m.apply(p, b["images"]), b["labels"], cfg.num_classes)

        l1, g1 = jax.jit(jax.value_and_grad(loss_fn))(params, batch)
        l0, g0 = jax.jit(jax.value_and_grad(ref_fn))(params, batch)
        rel_l = abs(float(l1) - float(l0)) / max(abs(float(l0)), 1e-12)
        rel_g = max(
            float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-12))
            for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g0)))
        results[tag] = {"rel_loss": rel_l, "max_rel_grad": rel_g,
                        "ok": bool(rel_l <= 1e-5 and rel_g <= 1e-5)}

    imgs = jax.random.uniform(key, (8, 28, 28))
    labels = jnp.arange(8) % 10
    cfg_cls = DONNConfig(name="cls2d", n=64, depth=4, distance=0.05,
                         det_size=8)
    parity("cls", cfg_cls, {"images": imgs, "labels": labels})
    parity("rgb",
           DONNConfig(name="rgb2d", n=64, depth=2, distance=0.05,
                      det_size=8, channels=3),
           {"images": jax.random.uniform(key, (8, 3, 28, 28)),
            "labels": labels})
    parity("seg",
           DONNConfig(name="seg2d", n=64, depth=3, distance=0.05,
                      segmentation=True, skip_from=0, layer_norm=True),
           {"images": imgs,
            "masks": (jax.random.uniform(key, (8, 64, 64)) > 0.5)
            .astype(jnp.float32)})
    # heterogeneous SegmentedPlan (64 -> 48 grids): one shard_map per
    # segment, the resampling stitches resharded between manual regions
    parity("het",
           DONNConfig(name="het2d", n=64, depth=3, distance=0.05,
                      det_size=8,
                      layers=(LayerSpec(distance=0.05, size=64),
                              LayerSpec(distance=0.05, size=48),
                              LayerSpec(distance=0.05, size=48))),
           {"images": imgs, "labels": labels})

    # ---- 2. compiled sharded train step tracks the reference step
    fn2, s_sh2, b_sh2, _ = ds.compile_donn_train_step_sharded(
        cfg_cls, mesh, optimizer=AdamW(lr=0.05), global_batch=8)
    st0 = init_params(ds.donn_state_specs(cfg_cls), jax.random.PRNGKey(1))
    batch_cls = {"images": np.asarray(imgs, np.float32),
                 "labels": np.asarray(labels, np.int32)}
    st2 = jax.device_put(jax.tree.map(jnp.array, st0), s_sh2)
    b_dev = jax.device_put(batch_cls, b_sh2)
    ref_step = jax.jit(ds.make_donn_train_step(cfg_cls, AdamW(lr=0.05)))
    st_ref = jax.tree.map(jnp.array, st0)
    l2, lref = [], []
    for _ in range(2):
        st2, m2 = fn2(st2, b_dev)
        st_ref, mref = ref_step(st_ref, batch_cls)
        l2.append(float(m2["loss"]))
        lref.append(float(mref["loss"]))
    pscale = max(float(jnp.max(jnp.abs(p)))
                 for p in jax.tree.leaves(st_ref["params"]))
    perr = max(
        float(jnp.max(jnp.abs(a - b))) for a, b in
        zip(jax.tree.leaves(st2["params"]),
            jax.tree.leaves(st_ref["params"]))) / pscale
    # same Adam-amplification caveat as the 1-D spatial step above:
    # losses at grad tolerance, params at 2e-3
    results["sharded_step"] = {
        "losses": [l2, lref], "param_rel_err": perr,
        "ok": bool(np.allclose(l2, lref, rtol=1e-5, atol=1e-7)
                   and perr <= 2e-3)}

    # ---- 3. rules-table edge cases (typed, not silent)
    sp = shd.resolve_pspec((66, 64), ("field_h", "field_w"), mesh,
                           shd.donn_rules())
    results["nondivisible_replicated"] = bool(tuple(sp) == ())
    try:
        shd.check_rules({**shd.donn_rules(), "field_h": "data"})
        results["check_rules_raises"] = False
    except shd.ShardingRulesError:
        results["check_rules_raises"] = True
    try:
        shd.resolve_pspec((8, 64, 64), ("batch", "field_h", "field_w"),
                          mesh, {**shd.DEFAULT_RULES, "batch": "model",
                                 "field_h": "model"})
        results["resolve_collision_raises"] = False
    except shd.ShardingRulesError:
        results["resolve_collision_raises"] = True
    try:
        shd.rules_pspec(("field_h", "field_h"), shd.donn_rules(), mesh)
        results["rules_dup_raises"] = False
    except shd.ShardingRulesError:
        results["rules_dup_raises"] = True

    # ---- 4. frozen-plane row-sharded serving: parity + bit-consistency
    from repro.runtime.inference import freeze, InferenceEngine
    model = cached_model(cfg_cls)
    params = model.init(key)
    dep = freeze(model, params)
    x = np.random.default_rng(7).random((8, 28, 28), np.float32)
    ref = InferenceEngine(dep, buckets=(8,)).infer(x)
    eng = InferenceEngine(dep, buckets=(8,), mesh_devices=2,
                          model_devices=4, dp_min_bucket=8)
    got = eng.infer(x)
    rel = float(np.max(np.abs(got - ref)) / np.max(np.abs(ref)))
    results["serving"] = {
        "rel_err": rel,
        "bit_consistent": bool(np.array_equal(got, eng.infer(x))),
        "ok": bool(rel <= 1e-5)}

    print("RESULTS:" + json.dumps(results))
""")


@pytest.fixture(scope="module")
def suite2_results():
    proc = run_subprocess(SUITE2, device_count=8)
    assert proc.returncode == 0, f"stderr:\n{proc.stderr[-3000:]}"
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULTS:")]
    assert line, proc.stdout
    return json.loads(line[0][len("RESULTS:"):])


@pytest.mark.parametrize("family", ["cls", "rgb", "seg", "het"])
def test_2d_mesh_parity(suite2_results, family):
    assert suite2_results[family]["ok"], suite2_results[family]


def test_2d_mesh_sharded_train_step_tracks_reference(suite2_results):
    assert suite2_results["sharded_step"]["ok"], suite2_results[
        "sharded_step"]


def test_nondivisible_field_h_drops_to_replicated(suite2_results):
    assert suite2_results["nondivisible_replicated"]


def test_rules_table_collisions_raise_typed_errors(suite2_results):
    assert suite2_results["check_rules_raises"]
    assert suite2_results["resolve_collision_raises"]
    assert suite2_results["rules_dup_raises"]


def test_row_sharded_serving_parity_and_bit_consistency(suite2_results):
    assert suite2_results["serving"]["ok"], suite2_results["serving"]
    assert suite2_results["serving"]["bit_consistent"]
