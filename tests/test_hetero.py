"""Heterogeneous per-layer architectures: LayerSpec config, segmented scan
plans, mixed-precision codesign, ragged-depth batched DSE, and the
plan/static cache-key guard."""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core.dsl as lr
from repro.core import (
    DONNConfig,
    LayerSpec,
    PropagationPlan,
    SegmentedPlan,
    build_model,
    emulate_batch,
)
from repro.core import codesign as cd
from repro.core import diffraction as df
from repro.core import models as mmod
from repro.core import propagation as pp
from repro.data import synth_digits, synth_rgb_scenes, synth_seg

BASE = dict(n=48, depth=3, distance=0.05, det_size=6)

# 2 distinct precisions (256-level SLM front, 4-level printed back) and
# 2 distinct plane sizes — the acceptance-criteria architecture
MIXED = (
    LayerSpec(distance=0.04, size=48, device_levels=256, codesign="qat"),
    LayerSpec(distance=0.05, size=48, device_levels=256, codesign="qat"),
    LayerSpec(distance=0.05, size=32, pixel_size=54e-6, device_levels=4,
              codesign="qat"),
)

# same shape of mix, sized for the 64x64 rgb/segmentation synth data
MIXED64 = (
    LayerSpec(distance=0.04, size=64, device_levels=256, codesign="qat"),
    LayerSpec(distance=0.05, size=64, device_levels=256, codesign="qat"),
    LayerSpec(distance=0.05, size=48, pixel_size=48e-6, device_levels=4,
              codesign="qat"),
)


def _pair(cfg_kw):
    cfg = DONNConfig(**cfg_kw)
    return build_model(cfg), build_model(
        dataclasses.replace(cfg, engine="eager")
    )


def _digits(k=4, seed=0):
    xs, _ = synth_digits(k, seed=seed)
    return jnp.asarray(xs)


class TestConfigValidation:
    def test_bad_distances_length_fails_at_construction(self):
        with pytest.raises(ValueError, match="distances"):
            DONNConfig(**{**BASE, "distances": (0.05, 0.05)})

    def test_layers_length_mismatch_names_field(self):
        with pytest.raises(ValueError, match="layers"):
            DONNConfig(**{**BASE, "layers": (LayerSpec(),)})

    def test_layers_and_distances_mutually_exclusive(self):
        with pytest.raises(ValueError, match="mutually exclusive"):
            DONNConfig(**{**BASE, "layers": (LayerSpec(),) * 3,
                          "distances": (0.05,) * 4})

    def test_layer_spec_validates_enums(self):
        with pytest.raises(ValueError, match="approximation"):
            LayerSpec(approximation="angular")
        with pytest.raises(ValueError, match="codesign"):
            LayerSpec(codesign="quantize")

    def test_gap_distances_with_layers(self):
        cfg = DONNConfig(**{**BASE, "layers": MIXED})
        assert cfg.gap_distances() == (0.04, 0.05, 0.05, 0.05)


class TestCanonicalization:
    def test_uniform_layers_fold_to_scalar_form(self):
        cfg = DONNConfig(**{**BASE,
                            "layers": (LayerSpec(distance=0.05),) * 3})
        canon = cfg.canonical()
        assert canon.layers is None
        assert canon.gap_distances() == cfg.gap_distances()

    def test_uniform_layers_hit_identical_plan_cache_entry(self):
        pp.clear_plan_cache()
        scalar = DONNConfig(**BASE)
        spelled = DONNConfig(**{**BASE,
                                "layers": (LayerSpec(distance=0.05),) * 3})
        assert (pp.plan_cache_key(scalar, 1.0)
                == pp.plan_cache_key(spelled, 1.0))
        assert pp.plan_from_config(scalar, 1.0) is pp.plan_from_config(
            spelled, 1.0
        )
        assert isinstance(pp.plan_from_config(spelled, 1.0), PropagationPlan)

    def test_uniform_layers_fold_onto_common_values_not_scalars(self):
        """Layers equal to *each other* fold even when the inheritance
        scalars differ — e.g. an all-4-level-qat stack spelled per layer
        is the same architecture as the scalar qat config."""
        scalar = DONNConfig(**BASE, codesign="qat", device_levels=4)
        spelled = DONNConfig(
            **BASE,
            layers=tuple(
                LayerSpec(distance=0.05, codesign="qat", device_levels=4)
                for _ in range(3)
            ),
        )
        canon = spelled.canonical()
        assert canon.layers is None
        assert canon.codesign == "qat" and canon.device_levels == 4
        assert (pp.plan_cache_key(spelled, 1.0)
                == pp.plan_cache_key(scalar, 1.0))
        # and emulate_batch accepts it as a uniform candidate
        params = build_model(scalar).init(jax.random.PRNGKey(0))
        out = emulate_batch([spelled, scalar], params, _digits())
        np.testing.assert_allclose(out[0], out[1], rtol=1e-6, atol=1e-6)

    def test_layers_off_detector_grid_stay_segmented(self):
        # all layers equal each other but live on a smaller plane than the
        # detector grid: not expressible as a scalar config
        cfg = DONNConfig(**{**BASE,
                            "layers": (LayerSpec(distance=0.05, size=32),) * 3})
        assert cfg.canonical().layers is not None

    def test_heterogeneous_config_gets_segmented_plan(self):
        cfg = DONNConfig(**{**BASE, "layers": MIXED})
        plan = pp.plan_from_config(cfg, 1.0)
        assert isinstance(plan, SegmentedPlan)
        assert plan.segment_slices == ((0, 2), (2, 3))

    def test_inherited_none_fields_resolve_from_scalars(self):
        cfg = DONNConfig(**{**BASE, "codesign": "qat", "device_levels": 16,
                            "layers": (LayerSpec(distance=0.04),
                                       LayerSpec(distance=0.05,
                                                 device_levels=4),
                                       LayerSpec(distance=0.05))})
        r = cfg.resolved_layers()
        assert [l.device_levels for l in r] == [16, 4, 16]
        assert all(l.size == cfg.n and l.codesign == "qat" for l in r)


class TestHeterogeneousForward:
    @pytest.mark.parametrize(
        "layers",
        [
            MIXED,
            # mixed approximation methods, uniform grid
            (LayerSpec(distance=0.04, approximation="rs"),
             LayerSpec(distance=0.05, approximation="fresnel"),
             LayerSpec(distance=0.05, approximation="rs")),
            # mixed pixel size only (same n: pure resampling stitch)
            (LayerSpec(distance=0.04),
             LayerSpec(distance=0.05, pixel_size=54e-6),
             LayerSpec(distance=0.05, pixel_size=54e-6)),
        ],
        ids=["mixed_size_precision", "mixed_method", "mixed_pitch"],
    )
    def test_classify_scan_matches_eager(self, layers):
        m_scan, m_eager = _pair({**BASE, "layers": layers})
        p = m_scan.init(jax.random.PRNGKey(0))
        x = _digits()
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_gradients_match(self):
        m_scan, m_eager = _pair({**BASE, "layers": MIXED})
        p = m_scan.init(jax.random.PRNGKey(1))
        x = _digits(seed=1)
        g1 = jax.grad(lambda p: jnp.sum(m_scan.apply(p, x) ** 2))(p)
        g2 = jax.grad(lambda p: jnp.sum(m_eager.apply(p, x) ** 2))(p)
        for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)

    def test_ragged_param_shapes(self):
        m, _ = _pair({**BASE, "layers": MIXED})
        p = m.init(jax.random.PRNGKey(0))
        shapes = [p["phase"][f"layer_{i}"].shape for i in range(3)]
        assert shapes == [(48, 48), (48, 48), (32, 32)]
        phis = m.stacked_phases(p)
        assert isinstance(phis, tuple) and len(phis) == 2
        assert phis[0].shape == (2, 48, 48) and phis[1].shape == (1, 32, 32)

    def test_rng_codesign_alignment(self):
        layers = (
            LayerSpec(distance=0.04, device_levels=16, codesign="gumbel"),
            LayerSpec(distance=0.05, size=32, pixel_size=54e-6,
                      device_levels=8, codesign="gumbel"),
            LayerSpec(distance=0.05, size=32, pixel_size=54e-6,
                      device_levels=8, codesign="gumbel"),
        )
        m_scan, m_eager = _pair({**BASE, "layers": layers})
        p = m_scan.init(jax.random.PRNGKey(0))
        x = _digits(seed=2)
        rng = jax.random.PRNGKey(7)
        np.testing.assert_allclose(
            m_scan.apply(p, x, rng), m_eager.apply(p, x, rng),
            rtol=1e-5, atol=1e-5,
        )

    def test_multichannel_heterogeneous(self):
        cfg_kw = {**BASE, "n": 64, "channels": 3, "num_classes": 6,
                  "layers": MIXED64}
        m_scan, m_eager = _pair(cfg_kw)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_rgb_scenes(4, seed=0)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(
            m_scan.apply(p, x), m_eager.apply(p, x), rtol=1e-5, atol=1e-5
        )

    def test_segmentation_skip_heterogeneous(self):
        cfg_kw = {**BASE, "n": 64, "segmentation": True, "skip_from": 0,
                  "layer_norm": True, "layers": MIXED64}
        m_scan, m_eager = _pair(cfg_kw)
        p = m_scan.init(jax.random.PRNGKey(0))
        xs, _ = synth_seg(4, seed=0)
        x = jnp.asarray(xs)
        got = m_scan.apply(p, x, train=True)
        assert got.shape == (4, 64, 64)  # detector/system grid
        np.testing.assert_allclose(
            got, m_eager.apply(p, x, train=True), rtol=1e-5, atol=1e-4
        )

    def test_jit_apply(self):
        m_scan, m_eager = _pair({**BASE, "layers": MIXED})
        p = m_scan.init(jax.random.PRNGKey(0))
        x = _digits(seed=3)
        got = jax.jit(lambda p, x: m_scan.apply(p, x))(p, x)
        np.testing.assert_allclose(got, m_eager.apply(p, x), rtol=1e-5,
                                   atol=1e-5)

    def test_train_step(self):
        """A heterogeneous model trains a step through the runtime path."""
        from repro.nn import init_params
        from repro.optim import AdamW
        from repro.runtime.donn_steps import (
            donn_state_specs, make_donn_train_step,
        )

        cfg = DONNConfig(**{**BASE, "layers": MIXED})
        state = init_params(donn_state_specs(cfg), jax.random.PRNGKey(0))
        step = jax.jit(make_donn_train_step(cfg, AdamW(lr=0.05)))
        xs, ys = synth_digits(8, seed=0)
        batch = {"images": jnp.asarray(xs), "labels": jnp.asarray(ys)}
        new_state, metrics = step(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        moved = [
            float(jnp.max(jnp.abs(a - b)))
            for a, b in zip(jax.tree.leaves(new_state["params"]),
                            jax.tree.leaves(state["params"]))
        ]
        assert all(m > 0 for m in moved)


class TestSegmentedSlicing:
    def _plan_and_inputs(self, seed=0):
        cfg = DONNConfig(**{**BASE, "layers": MIXED})
        plan = pp.plan_from_config(cfg, 1.0)
        r = np.random.default_rng(seed)
        phases = [
            jnp.asarray(r.uniform(0, 2 * np.pi, (s.size, s.size)),
                        jnp.float32)
            for s in cfg.resolved_layers()
        ]
        u = jnp.asarray(
            r.normal(size=(2, 48, 48)) + 1j * r.normal(size=(2, 48, 48)),
            jnp.complex64,
        )
        return plan, plan.stack_phases(phases), u

    @pytest.mark.parametrize("cut", [1, 2])  # mid-segment and boundary
    def test_slices_compose_to_full_forward(self, cut):
        plan, phis, u = self._plan_and_inputs()
        full = plan.forward(phis, u)
        head = plan.forward(phis, u, stop=cut)
        tail = plan.forward(phis, head, start=cut)
        np.testing.assert_allclose(tail, full, rtol=1e-5, atol=1e-6)

    def test_full_apply_shape_on_detector_grid(self):
        plan, phis, u = self._plan_and_inputs(seed=1)
        out = plan.apply(phis, u)
        assert out.shape == (2, 48, 48)  # resampled back to detector grid


class TestResampling:
    def test_equal_grids_identity(self):
        g = df.Grid(32, 36e-6)
        u = jnp.ones((32, 32), jnp.complex64)
        assert df.resample_field(u, g, g) is u

    def test_equal_pitch_is_exact_crop_pad(self):
        g_in, g_out = df.Grid(32, 36e-6), df.Grid(48, 36e-6)
        r = np.random.default_rng(0)
        u = jnp.asarray(r.normal(size=(32, 32)), jnp.float32)
        up = df.resample_field(u, g_in, g_out)
        back = df.resample_field(up, g_out, g_in)
        np.testing.assert_allclose(back, u, atol=1e-6)  # pad then crop
        A = df.resample_matrix(g_in, g_out)
        assert set(np.unique(A)) <= {0.0, 1.0}

    def test_rows_are_partition_of_unity_inside_aperture(self):
        A = df.resample_matrix(df.Grid(48, 36e-6), df.Grid(32, 54e-6))
        sums = A.sum(axis=1)
        interior = sums[2:-2]
        np.testing.assert_allclose(interior, 1.0, atol=1e-6)

    def test_matrix_cache_is_bounded_lru(self, monkeypatch):
        df._RESAMPLE_CACHE.clear()
        monkeypatch.setattr(df, "_RESAMPLE_CACHE_MAX", 3)
        grids = [df.Grid(8 + i, 36e-6) for i in range(5)]
        out = df.Grid(16, 36e-6)
        for g in grids[:3]:
            df.resample_matrix(g, out)
        a = df.resample_matrix(grids[0], out)  # hit: refresh recency
        df.resample_matrix(grids[3], out)  # evicts grids[1] (oldest)
        assert len(df._RESAMPLE_CACHE) <= 3
        assert df.resample_matrix(grids[0], out) is a  # survived eviction


class TestMixedDepthEmulateBatch:
    def _cfgs(self, depths=(2, 3, 5), **extra):
        return [
            DONNConfig(name=f"d{d}", n=48, det_size=6, depth=d,
                       distance=0.05, **extra)
            for d in depths
        ]

    def test_matches_sequential_per_candidate(self):
        cfgs = self._cfgs()
        plist = [build_model(c).init(jax.random.PRNGKey(i))
                 for i, c in enumerate(cfgs)]
        x = _digits()
        seq = [build_model(c).apply(p, x) for c, p in zip(cfgs, plist)]
        bat = emulate_batch(cfgs, plist, x)
        assert bat.shape == (len(cfgs),) + seq[0].shape
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-5)

    def test_qat_codesign_mixed_depth(self):
        cfgs = self._cfgs(codesign="qat", device_levels=16)
        plist = [build_model(c).init(jax.random.PRNGKey(i))
                 for i, c in enumerate(cfgs)]
        x = _digits(seed=1)
        seq = [build_model(c).apply(p, x) for c, p in zip(cfgs, plist)]
        bat = emulate_batch(cfgs, plist, x)
        for i, want in enumerate(seq):
            np.testing.assert_allclose(bat[i], want, rtol=1e-5, atol=1e-5)

    def test_mixed_depth_and_geometry(self):
        cfgs = [
            DONNConfig(name="a", n=48, det_size=6, depth=2, distance=0.04,
                       wavelength=532e-9),
            DONNConfig(name="b", n=48, det_size=6, depth=4, distance=0.06,
                       wavelength=633e-9, pixel_size=30e-6),
        ]
        plist = [build_model(c).init(jax.random.PRNGKey(i))
                 for i, c in enumerate(cfgs)]
        x = _digits(seed=2)
        bat = emulate_batch(cfgs, plist, x)
        for i, (c, p) in enumerate(zip(cfgs, plist)):
            np.testing.assert_allclose(
                bat[i], build_model(c).apply(p, x), rtol=1e-5, atol=1e-5
            )

    def test_executable_reused_across_mixed_depth_sets(self):
        mmod.clear_emulation_caches()
        cfgs = self._cfgs()
        plist = [build_model(c).init(jax.random.PRNGKey(i))
                 for i, c in enumerate(cfgs)]
        x = _digits(seed=4)
        emulate_batch(cfgs, plist, x)
        s0 = pp.plan_cache_stats()
        # same depth *profile*, different distances: same padded program
        cfgs2 = [dataclasses.replace(c, distance=0.045) for c in cfgs]
        emulate_batch(cfgs2, plist, x)
        s1 = pp.plan_cache_stats()
        assert s1["exec_misses"] == s0["exec_misses"]
        assert s1["exec_hits"] == s0["exec_hits"] + 1

    def test_skip_from_ignored_without_segmentation(self):
        # DONN classifiers ignore skip_from; the batched path must too
        cfgs = [
            dataclasses.replace(c, skip_from=5)
            for c in self._cfgs(depths=(2, 3))
        ]
        plist = [build_model(c).init(jax.random.PRNGKey(i))
                 for i, c in enumerate(cfgs)]
        x = _digits(seed=6)
        bat = emulate_batch(cfgs, plist, x)
        for i, (c, p) in enumerate(zip(cfgs, plist)):
            np.testing.assert_allclose(
                bat[i], build_model(c).apply(p, x), rtol=1e-5, atol=1e-5
            )

    def test_heterogeneous_layer_configs_rejected(self):
        cfg = DONNConfig(**{**BASE, "layers": MIXED})
        params = build_model(cfg).init(jax.random.PRNGKey(0))
        with pytest.raises(ValueError, match="per-candidate-uniform"):
            emulate_batch([cfg], [params], _digits())

    def test_dse_explore_with_depth_candidates(self):
        from repro.core.dse import LightRidgeDSE

        rng = np.random.default_rng(0)
        pts, accs = [], []
        for lam in (500e-9, 600e-9):
            for d in (20e-6, 36e-6):
                for D in (0.05, 0.1):
                    for depth in (2, 4):
                        pts.append((lam, d, D, depth))
                        accs.append(0.5 + 0.05 * depth
                                    + rng.uniform(0, 0.01))
        dse = LightRidgeDSE(n_estimators=40)
        dse.fit(pts, accs)
        seen = {}

        def emulate_batch_fn(points):
            seen["pts"] = points
            return [0.9] * len(points)

        res = dse.explore(
            550e-9,
            [(20e-6, 0.05, 2), (36e-6, 0.1, 4), (20e-6, 0.1, 4)],
            top_k=2, emulate_batch=emulate_batch_fn,
        )
        assert len(seen["pts"]) == 2 and len(seen["pts"][0]) == 4
        assert "depth" in res.best_point

    def test_mixed_tuple_arity_rejected(self):
        from repro.core.dse import LightRidgeDSE

        dse = LightRidgeDSE(n_estimators=10)
        with pytest.raises(ValueError, match="3- and 4-tuple"):
            dse.fit([(500e-9, 20e-6, 0.05), (500e-9, 20e-6, 0.05, 2)],
                    [0.5, 0.6])


class TestSpecRoundTrip:
    @pytest.mark.parametrize(
        "cfg",
        [
            DONNConfig(name="u", **BASE, codesign="qat", device_levels=64),
            DONNConfig(name="h", **{**BASE, "layers": MIXED}),
            DONNConfig(name="s", **{**BASE, "segmentation": True,
                                    "skip_from": 0, "layer_norm": True}),
            DONNConfig(name="d", **BASE,
                       distances=None, scan_unroll=2, tf_dtype="bfloat16",
                       engine="eager", channels=3, num_classes=6),
            # uniform layers living off the detector grid: still needs the
            # layers form on the from_spec side (scalar can't express it)
            DONNConfig(name="og", **{**BASE,
                                     "layers": (LayerSpec(distance=0.05,
                                                          size=32),) * 3}),
        ],
        ids=["uniform_qat", "heterogeneous", "segmentation", "runtime_knobs",
             "uniform_off_detector_grid"],
    )
    def test_roundtrip_preserves_architecture(self, cfg):
        spec = lr.to_spec(cfg)
        json.loads(json.dumps(spec))  # JSON-able
        _, cfg2 = lr.from_spec(spec)
        assert cfg2.resolved_layers() == cfg.resolved_layers()
        assert cfg2.gap_distances() == cfg.gap_distances()
        assert mmod.config_static_key(cfg2) == mmod.config_static_key(cfg)
        assert pp.plan_cache_key(cfg2, 1.0) == pp.plan_cache_key(cfg, 1.0)

    def test_roundtrip_preserves_laser_profile(self):
        from repro.core import Laser

        cfg = DONNConfig(name="l", **BASE)
        src = Laser(wavelength=532e-9, profile="gaussian", waist=1e-3,
                    power=2.0)
        spec = lr.to_spec(cfg, src)
        json.loads(json.dumps(spec))
        model, _ = lr.from_spec(spec)
        ref = build_model(cfg, src)
        p = ref.init(jax.random.PRNGKey(0))
        x = _digits(seed=6)
        np.testing.assert_allclose(model.apply(p, x), ref.apply(p, x),
                                   rtol=1e-6, atol=1e-6)

    def test_roundtrip_preserves_detector_grid(self):
        """The detector grid (cfg.n/pixel_size) is carried explicitly, not
        inferred from the first layer: a stack whose planes are smaller
        than the detector round-trips to the same outputs."""
        cfg = DONNConfig(
            name="dg", n=64, depth=2, distance=0.05, det_size=8,
            layers=(LayerSpec(distance=0.05, size=48),
                    LayerSpec(distance=0.05, size=32, pixel_size=54e-6)),
        )
        _, cfg2 = lr.from_spec(lr.to_spec(cfg))
        assert (cfg2.n, cfg2.pixel_size) == (cfg.n, cfg.pixel_size)
        assert mmod.config_static_key(cfg2) == mmod.config_static_key(cfg)
        m1, m2 = build_model(cfg), build_model(cfg2)
        p = m1.init(jax.random.PRNGKey(0))
        x = _digits(seed=5)
        np.testing.assert_allclose(m1.apply(p, x), m2.apply(p, x),
                                   rtol=1e-6, atol=1e-6)


# one alternate value per DONNConfig field; None marks cosmetic fields that
# legitimately stay out of the numerics keys.  Adding a config field without
# extending this table fails the guard below — the stale-cache tripwire.
_GUARD_BASE = dict(n=48, depth=3, distance=0.05, det_size=6)
_FIELD_ALTERNATES = {
    "name": None,  # cosmetic: never reaches the compiled program
    "n": 32,
    "pixel_size": 40e-6,
    "wavelength": 633e-9,
    "distance": 0.07,
    "distances": (0.04, 0.05, 0.06, 0.07),
    "depth": 4,
    "approximation": "fresnel",
    "band_limit": False,
    "pad": True,
    "num_classes": 6,
    "det_size": 8,
    "detector_layout": "ring",
    "gamma": 0.9,
    "codesign": "qat",
    "device_levels": 64,
    "response_gamma": 1.2,
    "channels": 3,
    "segmentation": True,
    "skip_from": 1,
    "layer_norm": True,
    "layers": (LayerSpec(distance=0.05, size=32),) * 3,
    "use_pallas": True,
    "engine": "eager",
    "input_size": 14,
    "scan_unroll": 2,
    "tf_dtype": "bfloat16",
    "remat": "layer",
}

# fields whose change must also re-key the *plan* (propagation numerics);
# the rest only affect the model/executable level (config_static_key)
_PLAN_FIELDS = (
    "n", "pixel_size", "wavelength", "distance", "distances", "depth",
    "approximation", "band_limit", "pad", "codesign", "device_levels",
    "response_gamma", "layers", "use_pallas", "scan_unroll", "tf_dtype",
    "remat",
)


class TestCacheKeyGuard:
    def test_every_config_field_has_a_guard_entry(self):
        fields = {f.name for f in dataclasses.fields(DONNConfig)}
        missing = fields - set(_FIELD_ALTERNATES)
        assert not missing, (
            f"new DONNConfig field(s) {sorted(missing)} lack cache-key "
            "guard coverage: add an alternate value to _FIELD_ALTERNATES "
            "and make sure config_static_key/plan_cache_key see the field"
        )
        stale = set(_FIELD_ALTERNATES) - fields
        assert not stale, f"guard table has stale entries: {sorted(stale)}"

    @pytest.mark.parametrize("field", sorted(_FIELD_ALTERNATES))
    def test_field_reaches_config_static_key(self, field):
        alt = _FIELD_ALTERNATES[field]
        base = DONNConfig(**_GUARD_BASE)
        if alt is None:  # cosmetic: must NOT re-key (shared executables)
            assert (mmod.config_static_key(dataclasses.replace(base,
                                                               name="other"))
                    == mmod.config_static_key(base))
            return
        changed = dataclasses.replace(base, **{field: alt})
        assert mmod.config_static_key(changed) != mmod.config_static_key(
            base
        ), f"{field} does not reach config_static_key: stale-cache hazard"

    @pytest.mark.parametrize("field", _PLAN_FIELDS)
    def test_plan_affecting_field_reaches_plan_cache_key(self, field):
        base = DONNConfig(**_GUARD_BASE)
        if field in ("device_levels", "response_gamma"):
            # device knobs only reach the propagation numerics when a
            # codesign mode consumes them
            base = dataclasses.replace(base, codesign="qat")
        changed = dataclasses.replace(base,
                                      **{field: _FIELD_ALTERNATES[field]})
        assert pp.plan_cache_key(changed, 1.0) != pp.plan_cache_key(
            base, 1.0
        ), f"{field} does not reach plan_cache_key: stale-plan hazard"

    def test_gamma_argument_rekeys_plan(self):
        base = DONNConfig(**_GUARD_BASE)
        assert pp.plan_cache_key(base, 1.0) != pp.plan_cache_key(base, 0.9)


class TestPerLayerDevices:
    def test_presets(self):
        assert cd.slm().levels == 256
        assert cd.printed_mask().levels == 4
        assert cd.device_for_layer("none", 256) is None
        dev = cd.device_for_layer("qat", 4, 1.2)
        assert dev.levels == 4 and dev.response_gamma == 1.2

    def test_mixed_devices_quantize_to_their_own_levels(self):
        """Front layers quantize to 256 SLM levels, back layer to 4."""
        cfg = DONNConfig(**{**BASE, "layers": MIXED})
        m = build_model(cfg)
        devs = [l.device for l in m.layers]
        assert [d.levels for d in devs] == [256, 256, 4]
        phi = jnp.asarray(
            np.random.default_rng(0).uniform(0, 2 * np.pi, (16, 16)),
            jnp.float32,
        )
        q4 = cd.quantize_qat(phi, devs[2])
        assert len(np.unique(np.asarray(q4))) <= 4
