"""Unit tests: sharding rule resolution, input specs, laser, nn module."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.laser import Laser, data_to_cplex, resize_to_grid
from repro.core.diffraction import Grid
from repro.launch.specs import cell_status, input_specs, shapes_for
from repro.models.config import LM_SHAPES, get_config
from repro.nn import ParamSpec, init_params, param_bytes, param_count
from repro.runtime.sharding import batch_sharding, resolve_pspec


def _mesh(shape, axes):
    devs = np.array(jax.devices()[:1] * int(np.prod(shape))).reshape(shape)
    return Mesh(devs, axes)


MESH = _mesh((2, 16, 16), ("pod", "data", "model"))
MESH1 = _mesh((16, 16), ("data", "model"))


class TestResolvePspec:
    def test_basic_tp(self):
        spec = resolve_pspec((4096, 16384), ("embed", "mlp"), MESH1)
        assert spec == P(("data",), "model") or spec == P("data", "model")

    def test_non_divisible_drops(self):
        # kv_heads=2 can't shard 16 ways -> replicated
        spec = resolve_pspec((40, 2, 128), ("layers", "kv_heads", "head"),
                             MESH1)
        assert spec[1] is None
        assert spec[2] == "model"  # head-dim fallback engages

    def test_duplicate_axis_first_wins(self):
        # both kv_heads and head map to model; kv divisible -> head dropped
        spec = resolve_pspec((16, 128), ("kv_heads", "head"), MESH1)
        assert spec[0] == "model"
        assert len(spec) < 2 or spec[1] is None

    def test_missing_mesh_axis_filtered(self):
        spec = resolve_pspec((256, 4096), ("batch", None), MESH1)
        # ("pod","data") rule -> only data exists on the single-pod mesh
        assert spec[0] in ("data", ("data",))

    def test_multi_axis_embed_zero(self):
        spec = resolve_pspec((4096,), ("embed",), MESH)
        assert spec[0] == ("data", "pod")


class TestBatchSharding:
    def test_divisible(self):
        s = batch_sharding(MESH, 2, batch_size=256)
        assert s.spec[0] == ("pod", "data")

    def test_batch_one_replicates(self):
        s = batch_sharding(MESH, 2, batch_size=1)
        assert s.spec == P(None, None) or all(x is None for x in s.spec)

    def test_partial_drop(self):
        # 2 divides pod but not pod*data
        s = batch_sharding(MESH, 2, batch_size=2)
        assert s.spec[0] in ("pod", ("pod",))


class TestInputSpecs:
    @pytest.mark.parametrize("arch", ["glm4-9b", "falcon-mamba-7b",
                                      "donn-mnist-5l"])
    def test_specs_are_abstract(self, arch):
        cfg = get_config(arch)
        for cell in shapes_for(cfg):
            if cell_status(cfg, cell):
                continue
            _, _, kind, specs = input_specs(arch, cell.name)
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_long_500k_skips_full_attention(self):
        cfg = get_config("glm4-9b")
        cell = [c for c in LM_SHAPES if c.name == "long_500k"][0]
        assert cell_status(cfg, cell) is not None
        for a in ("mixtral-8x7b", "falcon-mamba-7b", "recurrentgemma-9b"):
            assert cell_status(get_config(a), cell) is None

    def test_decode_cache_rolling_for_swa(self):
        _, _, kind, specs = input_specs("mixtral-8x7b", "long_500k")
        assert kind == "decode"
        # rolling buffer: physical cache = window, not 524288
        assert specs["cache"]["k"].shape[2] == 4096

    def test_vlm_vision_stub(self):
        cfg, cell, kind, specs = input_specs("llama-3.2-vision-11b",
                                             "train_4k")
        assert specs["vision"].shape == (256, 1600, 4096)


class TestLaser:
    def test_gaussian_profile_peak_center(self):
        g = Grid(64, 10e-6)
        f = Laser(profile="gaussian", waist=100e-6).field(g)
        assert np.argmax(np.abs(f)) == 64 * 32 + 32 or np.abs(f)[32, 32] >= \
            np.abs(f).max() - 1e-6

    def test_plane_unit(self):
        f = Laser(profile="plane").field(Grid(16, 1e-5))
        np.testing.assert_allclose(np.abs(f), 1.0)

    def test_data_to_cplex_zero_phase(self):
        x = jnp.asarray(np.random.default_rng(0).random((2, 28, 28)),
                        jnp.float32)
        u = data_to_cplex(x, 64)
        assert u.dtype == jnp.complex64
        np.testing.assert_allclose(np.asarray(jnp.imag(u)), 0.0)

    def test_resize_embed_mode(self):
        x = jnp.ones((1, 8, 8))
        out = resize_to_grid(x, 16, mode="embed")
        assert out.shape == (1, 16, 16)
        assert float(out.sum()) == 64.0  # embedded, not scaled


class TestNNModule:
    def test_init_shapes_and_dtypes(self):
        specs = {
            "a": ParamSpec((4, 8), jnp.float32, ("embed", "mlp")),
            "b": ParamSpec((8,), jnp.bfloat16, ("mlp",), init="zeros"),
        }
        p = init_params(specs, jax.random.PRNGKey(0))
        assert p["a"].shape == (4, 8) and p["b"].dtype == jnp.bfloat16

    def test_param_count_and_bytes(self):
        specs = {"a": ParamSpec((4, 8), jnp.float32, ())}
        assert param_count(specs) == 32
        assert param_bytes(specs) == 128

    def test_uniform_phase_range(self):
        s = ParamSpec((64, 64), jnp.float32, (), init="uniform_phase")
        p = init_params({"x": s}, jax.random.PRNGKey(1))["x"]
        assert float(p.min()) >= 0.0 and float(p.max()) <= 2 * np.pi

    def test_logical_axes_rank_check(self):
        with pytest.raises(ValueError):
            ParamSpec((4, 8), jnp.float32, ("embed",))
