import os
import pathlib
import subprocess
import sys

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
SRC = REPO / "src"

try:  # pragma: no cover - depends on the environment
    import hypothesis  # noqa: F401
except ImportError:
    # Register the local fallback so `from hypothesis import given, ...`
    # works in every test module (see tests/_hypothesis_compat.py).
    import _hypothesis_compat

    sys.modules["hypothesis"] = _hypothesis_compat


def run_subprocess(code: str, device_count: int = 8, timeout: int = 560):
    """Run python code in a fresh process with N host platform devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={device_count}"
    env["PYTHONPATH"] = str(SRC)
    return subprocess.run(
        [sys.executable, "-c", code], env=env, capture_output=True,
        text=True, timeout=timeout,
    )


@pytest.fixture(scope="session")
def repo_root():
    return REPO
