"""Physics-level tests of the scalar-diffraction kernels (paper §3.1)."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import diffraction as df

WL = 532e-9
PX = 36e-6


def _rand_field(n, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(
        r.normal(size=(n, n)) + 1j * r.normal(size=(n, n)), jnp.complex64
    )


class TestEnergyConservation:
    def test_rs_unitary_without_band_limit(self):
        g = df.Grid(64, PX)
        u = _rand_field(64)
        v = df.propagate(u, g, 0.01, WL, df.RS, band_limit=False)
        np.testing.assert_allclose(
            float(jnp.sum(df.intensity(u))), float(jnp.sum(df.intensity(v))),
            rtol=1e-4,
        )

    def test_fresnel_unitary(self):
        g = df.Grid(64, PX)
        u = _rand_field(64, 1)
        v = df.propagate(u, g, 0.05, WL, df.FRESNEL, band_limit=False)
        np.testing.assert_allclose(
            float(jnp.sum(df.intensity(u))), float(jnp.sum(df.intensity(v))),
            rtol=1e-4,
        )

    def test_band_limit_only_removes_energy(self):
        g = df.Grid(64, PX)
        u = _rand_field(64, 2)
        v = df.propagate(u, g, 0.3, WL, df.RS, band_limit=True)
        assert float(jnp.sum(df.intensity(v))) <= float(
            jnp.sum(df.intensity(u))
        ) * (1 + 1e-5)


class TestComposition:
    @pytest.mark.parametrize("method", [df.RS, df.FRESNEL])
    def test_two_hops_equal_one(self, method):
        g = df.Grid(48, PX)
        u = _rand_field(48, 3)
        z1, z2 = 0.013, 0.021
        v2 = df.propagate(
            df.propagate(u, g, z1, WL, method, band_limit=False),
            g, z2, WL, method, band_limit=False,
        )
        v1 = df.propagate(u, g, z1 + z2, WL, method, band_limit=False)
        np.testing.assert_allclose(np.asarray(v1), np.asarray(v2),
                                   rtol=2e-3, atol=2e-3)

    def test_forward_backward_identity(self):
        g = df.Grid(48, PX)
        u = _rand_field(48, 4)
        v = df.propagate(
            df.propagate(u, g, 0.02, WL, df.RS, band_limit=False),
            g, -0.02, WL, df.RS, band_limit=False,
        )
        np.testing.assert_allclose(np.asarray(u), np.asarray(v),
                                   rtol=2e-3, atol=2e-3)


class TestGaussianBeamAnalytic:
    def test_waist_expansion_matches_theory(self):
        """w(z) = w0 sqrt(1 + (z/zR)^2) for a Gaussian beam."""
        n, px = 256, 8e-6
        g = df.Grid(n, px)
        w0 = 120e-6
        c = g.coords()
        xx, yy = np.meshgrid(c, c, indexing="ij")
        u0 = jnp.asarray(np.exp(-(xx**2 + yy**2) / w0**2), jnp.complex64)
        zr = math.pi * w0**2 / WL
        z = 1.5 * zr
        uz = df.propagate(u0, g, z, WL, df.RS, band_limit=False)
        inten = np.asarray(df.intensity(uz))
        # I ~ exp(-2 r^2/w^2) => <x^2> = w^2/4 => w = 2 sqrt(<x^2>)
        tot = inten.sum()
        x2 = (inten * xx**2).sum() / tot
        w_meas = 2.0 * math.sqrt(x2)
        w_theory = w0 * math.sqrt(1 + (z / zr) ** 2)
        assert abs(w_meas - w_theory) / w_theory < 0.05

    def test_fresnel_matches_rs_in_paraxial_regime(self):
        n, px = 128, 16e-6
        g = df.Grid(n, px)
        w0 = 200e-6
        c = g.coords()
        xx, yy = np.meshgrid(c, c, indexing="ij")
        u0 = jnp.asarray(np.exp(-(xx**2 + yy**2) / w0**2), jnp.complex64)
        z = 0.05
        i_rs = np.asarray(df.intensity(df.propagate(u0, g, z, WL, df.RS)))
        i_fr = np.asarray(df.intensity(df.propagate(u0, g, z, WL, df.FRESNEL)))
        corr = np.corrcoef(i_rs.ravel(), i_fr.ravel())[0, 1]
        assert corr > 0.999


class TestLinearity:
    @settings(max_examples=10, deadline=None)
    @given(a=st.floats(-2, 2), b=st.floats(-2, 2))
    def test_superposition(self, a, b):
        g = df.Grid(32, PX)
        u1, u2 = _rand_field(32, 5), _rand_field(32, 6)
        p = lambda u: df.propagate(u, g, 0.02, WL, df.RS)
        lhs = np.asarray(p(a * u1 + b * u2))
        rhs = np.asarray(a * p(u1) + b * p(u2))
        np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


class TestFraunhofer:
    def test_far_field_of_slit_is_sinc(self):
        n, px = 256, 10e-6
        g = df.Grid(n, px)
        slit_w = 20  # pixels
        u = np.zeros((n, n), np.complex64)
        u[:, n // 2 - slit_w // 2 : n // 2 + slit_w // 2] = 1.0
        z = 2.0  # far field
        far = df.fraunhofer(jnp.asarray(u), g, z, WL)
        inten = np.asarray(df.intensity(far))
        row = inten[n // 2]
        # central maximum at center; first zeros at x = lambda z / slit width
        assert row.argmax() == n // 2
        fx = np.fft.fftshift(np.fft.fftfreq(n, d=px))
        x = fx * WL * z
        zero_x = WL * z / (slit_w * px)
        iz = int(np.argmin(np.abs(x - zero_x)))
        assert row[iz] < 0.01 * row[n // 2]


class TestGradients:
    def test_phase_gradients_flow(self):
        g = df.Grid(32, PX)
        u = _rand_field(32, 7)
        h = jnp.asarray(df.transfer_function(g, 0.02, WL, df.RS))

        def f(phi):
            v = df.propagate_tf(u * jnp.exp(1j * phi.astype(jnp.complex64)), h)
            return jnp.sum(df.intensity(v)[:8, :8])

        grad = jax.grad(f)(jnp.zeros((32, 32), jnp.float32))
        assert bool(jnp.all(jnp.isfinite(grad))) and float(
            jnp.max(jnp.abs(grad))
        ) > 0


class TestFresnelShiftPrefold:
    """The cached Fresnel TF pre-folds the fftshift/ifftshift pair.

    The textbook centered-plane hop spends two shifts per layer:
    ``ifft2(ifftshift(H_c * fftshift(fft2(u))))``.  The TF cache stores
    ``ifftshift(H_c)`` instead, so the runtime hop is shift-free — these
    tests pin both the value fold and the hop parity.
    """

    def test_cached_plane_is_preshifted_centered_plane(self):
        g = df.Grid(64, PX)
        hc = df.fresnel_tf_centered(g, 0.05, WL)
        h = df.transfer_function(g, 0.05, WL, df.FRESNEL, band_limit=False)
        # the shift is a pure permutation: the fold is bit-exact
        np.testing.assert_array_equal(np.fft.ifftshift(hc), h)

    def test_fresnel_prefolded_shift_pair(self):
        g = df.Grid(64, PX)
        u = _rand_field(64, 11)
        z = 0.05
        hc = df.fresnel_tf_centered(g, z, WL)
        # the unshifted (explicit shift-pair, centered-plane) reference hop
        spec = np.fft.fftshift(np.fft.fft2(np.asarray(u)))
        ref = np.fft.ifft2(np.fft.ifftshift(spec * hc))
        got = np.asarray(
            df.propagate(u, g, z, WL, df.FRESNEL, band_limit=False)
        )
        np.testing.assert_allclose(got, ref, rtol=1e-5, atol=1e-6)

    def test_padded_plane_preshifted_too(self):
        g = df.Grid(32, PX)
        hc = df.fresnel_tf_centered(g, 0.02, WL, pad=True)
        h = df.transfer_function(g, 0.02, WL, df.FRESNEL, band_limit=False,
                                 pad=True)
        np.testing.assert_array_equal(np.fft.ifftshift(hc), h)
