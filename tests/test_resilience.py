"""Fault-tolerance suite (ISSUE-7): the failure drills, end to end.

Driven by the injectors in ``repro.testing.faults``, this pins the
resilience contracts:

- **artifact sufficiency**: a killed engine recovered from a serialized
  artifact (``save_deployed``/``load_deployed``) serves bit-identically
  to the original ``freeze()`` — for every model family, including
  heterogeneous segmented plans; corrupted artifacts (bit-rot or falsified
  checksums) are rejected at load, never served;
- **overload behavior**: a full admission queue sheds with
  ``OverloadedError``; an expired deadline fails only its own future
  while the rest of the traffic is served; an unclean shutdown fails the
  stranded futures instead of abandoning their callers;
- **training guardrails**: a poisoned (NaN) batch is skipped device-side
  as an exact no-op — final params bit-identical to a run that never saw
  the batch — and a fully-poisoned chunk rolls back to the last good
  checkpoint with the same guarantee.
"""
import json
import threading
import time

import jax
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.core import DONNConfig, build_model
from repro.core.config import LayerSpec
from repro.core.train_utils import train_classifier
from repro.data import batch_iterator, synth_digits
from repro.runtime.inference import InferenceEngine, MicroBatcher, freeze
from repro.runtime.resilience import (
    ARTIFACT_FILE, PLANES_DIR, DeadlineExceededError, EngineSupervisor,
    OverloadedError, load_deployed, save_deployed,
)
from repro.testing import (
    FlakyEngine, SlowEngine, corrupt_chunk, flip_crc, perturb_frozen,
    poison_batches,
)


def _digits(b, shape=(28, 28), seed=0):
    return np.random.default_rng(seed).random((b,) + shape, np.float32)


def _model(seed=0, **kw):
    kw.setdefault("n", 32)
    kw.setdefault("depth", 3)
    kw.setdefault("distance", 0.05)
    kw.setdefault("det_size", 6)
    cfg = DONNConfig(**kw)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


# --------------------------------------------------------------------------
# Serialized frozen artifacts
# --------------------------------------------------------------------------
class TestArtifactRoundTrip:
    @pytest.mark.parametrize("kw", [
        dict(name="ar-qat", codesign="qat"),
        dict(name="ar-pl", depth=2, codesign="qat", use_pallas=True),
    ])
    def test_save_load_bit_identical(self, tmp_path, kw):
        model, params = _model(**kw)
        dep = freeze(model, params)
        x = _digits(2)
        ref = InferenceEngine(dep, buckets=(2,)).infer(x)
        save_deployed(dep, tmp_path)
        dep2 = load_deployed(tmp_path)
        assert dep2.family == dep.family
        np.testing.assert_array_equal(
            InferenceEngine(dep2, buckets=(2,)).infer(x), ref
        )

    def test_heterogeneous_roundtrip(self, tmp_path):
        model, params = _model(
            name="ar-het",
            layers=(LayerSpec(0.05, size=40), LayerSpec(0.05, size=40),
                    LayerSpec(0.05, codesign="qat", device_levels=4)),
        )
        dep = freeze(model, params)
        x = _digits(2)
        ref = InferenceEngine(dep, buckets=(2,)).infer(x)
        save_deployed(dep, tmp_path)
        dep2 = load_deployed(tmp_path)
        assert dep2.heterogeneous and len(dep2.frozen) == len(dep.frozen)
        np.testing.assert_array_equal(
            InferenceEngine(dep2, buckets=(2,)).infer(x), ref
        )

    def test_multi_channel_roundtrip(self, tmp_path):
        model, params = _model(name="ar-rgb", channels=3, det_size=4)
        dep = freeze(model, params)
        x = _digits(2, shape=(3, 28, 28))
        ref = InferenceEngine(dep, buckets=(2,)).infer(x)
        save_deployed(dep, tmp_path)
        np.testing.assert_array_equal(
            InferenceEngine(load_deployed(tmp_path), buckets=(2,)).infer(x),
            ref,
        )

    def test_corrupt_chunk_rejected_at_load(self, tmp_path):
        model, params = _model(name="ar-rot")
        save_deployed(freeze(model, params), tmp_path)
        corrupt_chunk(tmp_path / PLANES_DIR, 0)
        with pytest.raises(IOError):
            load_deployed(tmp_path)

    def test_flipped_crc_rejected_at_load(self, tmp_path):
        model, params = _model(name="ar-crc")
        save_deployed(freeze(model, params), tmp_path)
        flip_crc(tmp_path / PLANES_DIR, 0)
        with pytest.raises(IOError):
            load_deployed(tmp_path)

    def test_missing_and_foreign_artifacts_rejected(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            load_deployed(tmp_path / "nope")
        model, params = _model(name="ar-fmt")
        save_deployed(freeze(model, params), tmp_path)
        meta_path = tmp_path / ARTIFACT_FILE
        meta = json.loads(meta_path.read_text())
        meta["format"] = 99
        meta_path.write_text(json.dumps(meta))
        with pytest.raises(ValueError):
            load_deployed(tmp_path)


# --------------------------------------------------------------------------
# Engine supervision
# --------------------------------------------------------------------------
class TestSupervisor:
    def test_killed_engine_recovers_bit_identical(self, tmp_path):
        """Kill the engine; the supervisor must restart it from the
        artifact and serve the retried request identically to freeze()."""
        model, params = _model(name="sup", codesign="qat")
        dep = freeze(model, params)
        x = _digits(2)
        ref = InferenceEngine(dep, buckets=(2,)).infer(x)
        save_deployed(dep, tmp_path)

        current = {}

        def factory(deployed):
            current["engine"] = FlakyEngine(
                InferenceEngine(deployed, buckets=(2,))
            )
            return current["engine"]

        sup = EngineSupervisor(tmp_path, engine_factory=factory,
                               max_restarts=2).start()
        assert sup.ready and sup.health_check()
        np.testing.assert_array_equal(sup.infer(x), ref)
        current["engine"].kill()
        assert not sup.health_check()
        # the failed request restarts from disk and is retried once
        np.testing.assert_array_equal(sup.infer(x), ref)
        s = sup.stats()
        assert s["restarts"] == 1 and s["ready"]
        assert s["errors"] >= 1 and 0 < s["error_rate"] < 1

    def test_restart_budget_exhausted(self, tmp_path):
        model, params = _model(name="sup-b")
        save_deployed(freeze(model, params), tmp_path)

        def factory(deployed):
            eng = FlakyEngine(InferenceEngine(deployed, buckets=(1,)))
            eng.kill()  # every replacement is born dead
            return eng

        sup = EngineSupervisor(tmp_path, engine_factory=factory,
                               max_restarts=0).start()
        with pytest.raises(RuntimeError):
            sup.infer(_digits(1)[0])
        assert not sup.ready


# --------------------------------------------------------------------------
# Hardened micro-batching
# --------------------------------------------------------------------------
def _slow_batcher(delay_s: float, **kw):
    model, params = _model(name="mb-slow", depth=2)
    eng = InferenceEngine(freeze(model, params), buckets=(1,))
    eng.warmup()
    return MicroBatcher(SlowEngine(eng, delay_s), **kw), model


class TestMicroBatcherResilience:
    def test_overload_sheds(self):
        mb, _ = _slow_batcher(0.3, max_wait_ms=1.0, max_queue=2)
        first = mb.submit(_digits(1)[0])
        time.sleep(0.1)  # the worker takes `first` in-flight
        admitted = [mb.submit(_digits(1, seed=s)[0]) for s in (1, 2)]
        with pytest.raises(OverloadedError):
            mb.submit(_digits(1, seed=3)[0])
        assert mb.stats["shed"] == 1
        for f in [first] + admitted:
            assert f.result(timeout=60) is not None
        assert mb.close()

    def test_deadline_fails_only_its_own_future(self):
        mb, model = _slow_batcher(0.3, max_wait_ms=1.0)
        blocker = mb.submit(_digits(1)[0])
        time.sleep(0.1)  # worker is now busy for ~0.3s
        ok = mb.submit(_digits(1, seed=1)[0])
        doomed = mb.submit(_digits(1, seed=2)[0], timeout_ms=50.0)
        with pytest.raises(DeadlineExceededError):
            doomed.result(timeout=60)
        # neighbors are unaffected: both still serve normally
        assert blocker.result(timeout=60).shape == (model.cfg.num_classes,)
        assert ok.result(timeout=60).shape == (model.cfg.num_classes,)
        assert mb.stats["expired"] == 1
        mb.close()

    def test_unclean_close_fails_stranded_futures(self):
        mb, _ = _slow_batcher(2.0, max_wait_ms=1.0)
        inflight = mb.submit(_digits(1)[0])
        time.sleep(0.1)
        pending = mb.submit(_digits(1, seed=1)[0])
        assert mb.close(timeout=0.2) is False  # worker wedged in the call
        for f in (inflight, pending):
            with pytest.raises(RuntimeError):
                f.result(timeout=1)

    def test_submit_after_close_raises(self):
        model, params = _model(name="mb-cl", depth=2)
        mb = MicroBatcher(InferenceEngine(freeze(model, params),
                                          buckets=(1,)))
        assert mb.close()
        with pytest.raises(RuntimeError):
            mb.submit(_digits(1)[0])

    def test_concurrent_submit_many_threads(self):
        model, params = _model(name="mb-thr", codesign="qat")
        eng = InferenceEngine(freeze(model, params), buckets=(2, 8))
        eng.warmup()
        mb = MicroBatcher(eng, max_wait_ms=2.0)
        x = _digits(24, seed=11)
        results = np.zeros((24, model.cfg.num_classes), np.float32)

        def worker(lo):
            futs = [(i, mb.submit(x[i])) for i in range(lo, lo + 6)]
            for i, f in futs:
                results[i] = f.result(timeout=60)

        threads = [threading.Thread(target=worker, args=(lo,))
                   for lo in range(0, 24, 6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert mb.close()
        ref = np.asarray(jax.jit(lambda p, xx: model.apply(p, xx))(params, x))
        np.testing.assert_allclose(results, ref, rtol=1e-5, atol=1e-7)
        assert mb.stats["submitted"] == 24 and mb.stats["served"] == 24


# --------------------------------------------------------------------------
# Training guardrails: skip / rollback
# --------------------------------------------------------------------------
def _train(model, params, stream, steps, **kw):
    return train_classifier(model, params, stream, steps=steps, lr=0.2,
                            steps_per_call=4, prefetch=0, **kw)


def _stream(xs, ys, skip_steps=()):
    it = batch_iterator(xs, ys, 16, seed=1)
    return (b for i, b in enumerate(it) if i not in set(skip_steps))


class TestTrainGuardrails:
    def test_poisoned_step_skipped_bit_identical(self):
        """A NaN batch is a device-side no-op: final params match a run
        that never saw the batch, bit for bit."""
        model, params = _model(name="tg-skip", codesign="qat")
        xs, ys = synth_digits(256, seed=0)
        res = _train(model, params,
                     poison_batches(_stream(xs, ys), [2]), 8, guard=True)
        assert res.skipped_steps == 1 and res.rollbacks == 0
        assert np.isnan(res.losses[2]) and len(res.losses) == 8
        ref = _train(model, params, _stream(xs, ys, skip_steps=[2]), 7)
        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_fully_poisoned_chunk_rolls_back(self, tmp_path):
        """A whole-chunk NaN storm restores the last good checkpoint and
        resumes — final params match a run without those batches."""
        model, params = _model(name="tg-roll", codesign="qat")
        xs, ys = synth_digits(256, seed=0)
        res = _train(model, params,
                     poison_batches(_stream(xs, ys), [4, 5, 6, 7]), 12,
                     guard=True, ckpt_dir=tmp_path, ckpt_every=4)
        assert res.rollbacks == 1
        assert len(res.losses) == 8  # rolled-back chunk's metrics dropped
        ref = _train(model, params,
                     _stream(xs, ys, skip_steps=[4, 5, 6, 7]), 8)
        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_rollback_budget_exhausted_raises(self, tmp_path):
        model, params = _model(name="tg-bud", codesign="qat")
        xs, ys = synth_digits(256, seed=0)
        with pytest.raises(RuntimeError):
            _train(model, params,
                   poison_batches(_stream(xs, ys), range(4, 20)), 20,
                   guard=True, ckpt_dir=tmp_path, ckpt_every=4,
                   max_rollbacks=1)

    def test_guard_requires_chunked_driver(self):
        model, params = _model(name="tg-one")
        xs, ys = synth_digits(64, seed=0)
        with pytest.raises(ValueError):
            train_classifier(model, params, _stream(xs, ys), steps=2,
                             guard=True, steps_per_call=1)

    def test_guarded_clean_run_matches_unguarded(self):
        """With no faults the guard must be numerically invisible."""
        model, params = _model(name="tg-clean", codesign="qat")
        xs, ys = synth_digits(256, seed=0)
        res = _train(model, params, _stream(xs, ys), 8, guard=True)
        ref = _train(model, params, _stream(xs, ys), 8)
        assert res.skipped_steps == 0
        for a, b in zip(jax.tree.leaves(res.params),
                        jax.tree.leaves(ref.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Physics faults on frozen planes
# --------------------------------------------------------------------------
class TestPerturbFrozen:
    def test_zero_faults_is_identity(self):
        model, params = _model(name="pf-id", codesign="qat")
        dep = freeze(model, params)
        same = perturb_frozen(dep)
        assert same.frozen[0] is dep.frozen[0]
        assert same.frozen[1] is dep.frozen[1]

    @pytest.mark.parametrize("kw", [
        dict(phase_sigma=0.5), dict(dead_frac=0.3), dict(shift_px=2),
    ])
    def test_faults_change_outputs_not_the_original(self, kw):
        model, params = _model(name="pf-ch", codesign="qat")
        dep = freeze(model, params)
        x = _digits(2)
        ref = InferenceEngine(dep, buckets=(2,)).infer(x)
        pert = perturb_frozen(dep, seed=3, **kw)
        got = InferenceEngine(pert, buckets=(2,)).infer(x)
        assert not np.array_equal(got, ref)
        # the original deployment is untouched by the perturbation
        np.testing.assert_array_equal(
            InferenceEngine(dep, buckets=(2,)).infer(x), ref
        )

    def test_pallas_polar_convention(self):
        """Phase noise on the polar (pallas) planes leaves amplitudes
        untouched — only the theta plane moves."""
        model, params = _model(name="pf-pl", depth=2, codesign="qat",
                               use_pallas=True)
        dep = freeze(model, params)
        pert = perturb_frozen(dep, phase_sigma=0.4, seed=5)
        np.testing.assert_array_equal(np.asarray(pert.frozen[1]),
                                      np.asarray(dep.frozen[1]))
        assert not np.array_equal(np.asarray(pert.frozen[0]),
                                  np.asarray(dep.frozen[0]))

    def test_jnp_cartesian_preserves_amplitude(self):
        """In the cartesian convention phase noise must move both split
        planes while preserving |gamma * exp(j theta)|."""
        model, params = _model(name="pf-amp", codesign="qat")
        dep = freeze(model, params)
        pert = perturb_frozen(dep, phase_sigma=0.4, seed=5)
        amp0 = np.hypot(np.asarray(dep.frozen[0]), np.asarray(dep.frozen[1]))
        amp1 = np.hypot(np.asarray(pert.frozen[0]),
                        np.asarray(pert.frozen[1]))
        np.testing.assert_allclose(amp1, amp0, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# Checkpoint discovery under damage (latest_step fallback)
# --------------------------------------------------------------------------
class TestLatestStepFallback:
    def test_dangling_pointer_falls_back_to_newest_valid(self, tmp_path):
        s = {"w": np.arange(4, dtype=np.float32)}
        ckpt.save(tmp_path, 1, s)
        ckpt.save(tmp_path, 2, s)
        # damage the newest step's manifest: LATEST now dangles
        (tmp_path / "step_00000002" / "MANIFEST.json").write_text("not json")
        assert ckpt.latest_step(tmp_path) == 1
        assert ckpt.valid_steps(tmp_path) == [1]

    def test_missing_pointer_scans_directories(self, tmp_path):
        s = {"w": np.arange(4, dtype=np.float32)}
        ckpt.save(tmp_path, 3, s)
        ckpt.save(tmp_path, 5, s)
        (tmp_path / "LATEST").unlink()
        assert ckpt.latest_step(tmp_path) == 5

    def test_empty_dir_is_none(self, tmp_path):
        assert ckpt.latest_step(tmp_path) is None
        assert ckpt.valid_steps(tmp_path / "missing") == []
