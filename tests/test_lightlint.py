"""lightlint rule coverage: every rule fires on its bad fixture and stays
silent on the corresponding good idiom, plus a meta-test that the live
tree is clean (the same invocation CI runs)."""
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
if str(REPO / "tools") not in sys.path:
    sys.path.insert(0, str(REPO / "tools"))

from lightlint import lint_paths  # noqa: E402
from lightlint.core import Finding, parse_suppressions  # noqa: E402

FIXTURES = REPO / "tests" / "lightlint_fixtures"


def lint_fixture(name):
    path = FIXTURES / name
    return lint_paths([str(path)], root=str(FIXTURES))


def rule_ids(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------- LR101
class TestCacheKeyCompleteness:
    def lint_tree(self, sub):
        root = FIXTURES / sub
        return lint_paths([str(root)], root=str(root))

    def test_fires_on_stale_key_tree(self):
        findings = self.lint_tree("lr101_bad")
        assert rule_ids(findings) == {"LR101"}
        messages = " ".join(f.message for f in findings)
        # the two seeded gaps: DONNConfig.remat missing from every key fn,
        # LayerSpec.pixel_size missing from plan_cache_key's per-layer tuple
        assert "remat" in messages
        assert "pixel_size" in messages
        # findings anchor at the dataclass field definitions
        assert all(f.path.endswith("config.py") for f in findings)

    def test_silent_on_asdict_idiom(self):
        assert self.lint_tree("lr101_good") == []


# ---------------------------------------------------------------- LR102
class TestDonationAliasing:
    def test_fires_on_read_after_donate(self):
        findings = lint_fixture("lr102_bad.py")
        assert rule_ids(findings) == {"LR102"}
        (f,) = findings
        assert "params" in f.message and "donated" in f.message

    def test_silent_on_rebind_idiom(self):
        assert lint_fixture("lr102_good.py") == []


# ---------------------------------------------------------------- LR103
class TestHostSyncInHotPath:
    def test_fires_on_sync_in_scan_and_jit(self):
        findings = lint_fixture("lr103_bad.py")
        assert rule_ids(findings) == {"LR103"}
        messages = [f.message for f in findings]
        assert any("print" in m for m in messages)
        assert any("float()" in m for m in messages)
        assert any("np.asarray" in m for m in messages)

    def test_silent_on_device_accumulation(self):
        assert lint_fixture("lr103_good.py") == []


# ---------------------------------------------------------------- LR104
class TestJitInLoop:
    def test_fires_on_jit_in_loop(self):
        findings = lint_fixture("lr104_bad.py")
        assert rule_ids(findings) == {"LR104"}

    def test_silent_on_hoisted_and_cached(self):
        assert lint_fixture("lr104_good.py") == []


# ---------------------------------------------------------------- LR105
class TestClosureRetraceHazard:
    def test_fires_on_build_in_closure_and_captured_array(self):
        findings = lint_fixture("lr105_bad.py")
        assert rule_ids(findings) == {"LR105"}
        messages = " ".join(f.message for f in findings)
        assert "build_model" in messages
        assert "onehot" in messages

    def test_silent_on_cached_model_idiom(self):
        assert lint_fixture("lr105_good.py") == []


# ---------------------------------------------------------------- LR106
class TestBf16Accumulation:
    def test_fires_on_bf16_product_and_reduction(self):
        findings = lint_fixture("lr106_bad.py")
        assert rule_ids(findings) == {"LR106"}
        messages = " ".join(f.message for f in findings)
        assert "astype(jnp.float32)" in messages
        assert "dtype=jnp.float32" in messages

    def test_silent_on_upcast_idiom(self):
        assert lint_fixture("lr106_good.py") == []


# ---------------------------------------------------------------- LR107
class TestComplexPromotionInHotPath:
    def test_fires_on_pair_assembly_in_jit_and_scan(self):
        findings = lint_fixture("lr107_bad.py")
        assert rule_ids(findings) == {"LR107"}
        # both jit-body assemblies plus the scan-body one
        assert len(findings) == 3
        assert all("lax.complex" in f.message for f in findings)

    def test_silent_on_split_pair_and_lax_complex(self):
        assert lint_fixture("lr107_good.py") == []


# ---------------------------------------------------------------- LR108
class TestUnboundedRetryLoop:
    def test_fires_on_unpaced_swallowing_retry_loops(self):
        findings = lint_fixture("lr108_bad.py")
        assert rule_ids(findings) == {"LR108"}
        # both the requeue-spin and the restart-spin fire
        assert len(findings) == 2
        assert all("budget or backoff" in f.message for f in findings)

    def test_silent_on_bounded_or_paced_retries(self):
        assert lint_fixture("lr108_good.py") == []


# ---------------------------------------------------------------- LR109
class TestAdHocPartitionSpec:
    def test_fires_on_raw_specs_and_meshes(self):
        findings = lint_fixture("lr109_bad.py")
        assert rule_ids(findings) == {"LR109"}
        # P(...) alias + dotted PartitionSpec + make_mesh + raw Mesh
        assert len(findings) == 4
        msgs = " ".join(f.message for f in findings)
        assert "rules table" in msgs
        assert "make_mesh_2d" in msgs

    def test_silent_on_rules_table_helpers(self):
        assert lint_fixture("lr109_good.py") == []

    def test_allowlists_the_rules_table_itself(self):
        # the same constructions inside runtime/sharding.py are the
        # implementation, not drift — linted clean
        path = REPO / "src" / "repro" / "runtime" / "sharding.py"
        findings = [f for f in lint_paths([str(path)], root=str(REPO))
                    if f.rule == "LR109"]
        assert findings == []


# ---------------------------------------------------------------- LR201
class TestPhysicsConfigValidity:
    def test_fires_on_invalid_literal_configs(self):
        findings = lint_fixture("lr201_bad.py")
        assert rule_ids(findings) == {"LR201"}
        criteria = " ".join(f.message for f in findings)
        assert "sampling-aliasing" in criteria
        assert "stitch-undersample" in criteria
        assert "device-levels" in criteria

    def test_silent_on_paper_geometry(self):
        assert lint_fixture("lr201_good.py") == []


# ---------------------------------------------------------------- LR202
class TestSpecArtifactValidity:
    def test_fires_on_aliased_spec_artifact(self):
        findings = lint_fixture("lr202_bad_spec.json")
        assert rule_ids(findings) == {"LR202"}
        assert any("sampling-aliasing" in f.message for f in findings)

    def test_silent_on_valid_spec_artifact(self):
        assert lint_fixture("lr202_good_spec.json") == []


# ---------------------------------------------------------- suppressions
class TestSuppressions:
    def test_line_suppression_silences_rule(self, tmp_path):
        src = (FIXTURES / "lr104_bad.py").read_text()
        src = src.replace(
            "fn = jax.jit(lambda p, xb: model.apply(p, xb))  # BUG: re-jits",
            "fn = jax.jit(lambda p, xb: model.apply(p, xb))"
            "  # lightlint: disable=LR104 -- fixture",
        )
        p = tmp_path / "suppressed.py"
        p.write_text(src)
        assert lint_paths([str(p)], root=str(tmp_path)) == []

    def test_file_suppression_silences_rule(self, tmp_path):
        src = ("# lightlint: disable-file=LR104\n"
               + (FIXTURES / "lr104_bad.py").read_text())
        p = tmp_path / "suppressed.py"
        p.write_text(src)
        assert lint_paths([str(p)], root=str(tmp_path)) == []

    def test_parse_suppressions(self):
        per_line, per_file = parse_suppressions(
            "x = 1  # lightlint: disable=LR104,LR105 -- why\n"
            "# lightlint: disable-file=LR201\n"
        )
        assert per_line == {1: {"LR104", "LR105"}}
        assert per_file == {"LR201"}

    def test_unsuppressed_rule_still_fires(self, tmp_path):
        src = ("# lightlint: disable-file=LR103\n"
               + (FIXTURES / "lr104_bad.py").read_text())
        p = tmp_path / "partial.py"
        p.write_text(src)
        findings = lint_paths([str(p)], root=str(tmp_path))
        assert rule_ids(findings) == {"LR104"}


# ------------------------------------------------------------ framework
class TestFramework:
    def test_syntax_error_reported_not_raised(self, tmp_path):
        p = tmp_path / "broken.py"
        p.write_text("def f(:\n")
        findings = lint_paths([str(p)], root=str(tmp_path))
        assert rule_ids(findings) == {"LR000"}

    def test_finding_format_and_dict(self):
        f = Finding(path="a/b.py", line=3, rule="LR104",
                    severity="error", message="msg")
        assert f.format() == "a/b.py:3: LR104 [error] msg"
        assert f.to_dict()["rule"] == "LR104"


# ------------------------------------------------------------- meta-test
def test_live_tree_is_clean(repo_root):
    """The exact surface CI lints must stay clean (exit 0)."""
    paths = [str(repo_root / d) for d in ("src", "tools", "benchmarks")
             if (repo_root / d).exists()]
    examples = repo_root / "examples"
    if examples.exists():
        paths.append(str(examples))
    findings = lint_paths(paths, root=str(repo_root))
    assert findings == [], "\n".join(f.format() for f in findings)
