"""Minimal stand-in for ``hypothesis`` when the real package is absent.

The tier-1 suite uses a small slice of the hypothesis API — ``@given`` with
``st.integers`` / ``st.floats`` range strategies and ``@settings`` — for
light property sweeps.  The container image does not ship hypothesis, and
installing packages is off-limits, so ``conftest.py`` registers this module
as ``sys.modules["hypothesis"]`` when the import fails.

Degradation semantics: each strategy yields a small fixed set of
deterministic examples (range endpoints + interior points); ``@given``
runs the test once per example tuple (zipping strategies, cycling the
shorter ones); ``@settings`` is a no-op that preserves the wrapped
function.  No shrinking, no randomization — just enough coverage that the
property bodies execute on several distinct inputs everywhere.
"""
from __future__ import annotations

import types


class _Strategy:
    """A fixed list of example values standing in for a search strategy."""

    def __init__(self, examples):
        self.examples = list(examples)


def _integers(min_value, max_value):
    span = max_value - min_value
    ex = [min_value, max_value, min_value + span // 2,
          min_value + span // 3, min_value + (2 * span) // 3]
    seen, out = set(), []
    for v in ex:
        if v not in seen:
            seen.add(v)
            out.append(v)
    return _Strategy(out)


def _floats(min_value, max_value, **_kw):
    lo, hi = float(min_value), float(max_value)
    ex = [lo, hi, 0.5 * (lo + hi), lo + 0.25 * (hi - lo), lo + 0.75 * (hi - lo)]
    seen, out = set(), []
    for v in ex:
        if v not in seen:
            seen.add(v)
            out.append(v)
    return _Strategy(out)


strategies = types.SimpleNamespace(integers=_integers, floats=_floats)


def given(**strats):
    """Run the wrapped test once per example tuple (no search, no shrink)."""
    n = max(len(s.examples) for s in strats.values())

    def deco(fn):
        def wrapper(*args, **kwargs):
            for i in range(n):
                ex = {k: s.examples[i % len(s.examples)]
                      for k, s in strats.items()}
                fn(*args, **ex, **kwargs)

        # Copy identity but NOT __wrapped__: pytest must see the argless
        # wrapper signature, or it would resolve the strategy parameters
        # as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.hypothesis_compat = True
        return wrapper

    return deco


def settings(**_kw):
    """Accepted for compatibility; example counts are fixed here."""

    def deco(fn):
        return fn

    return deco
