"""Decode-with-cache must reproduce teacher-forced prefill logits."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.models import get_config, lm

ARCHS = [
    "glm4-9b", "granite-8b", "qwen1.5-4b", "qwen2.5-14b", "mixtral-8x7b",
    "arctic-480b", "llama-3.2-vision-11b", "musicgen-medium",
    "falcon-mamba-7b", "recurrentgemma-9b",
]


def _decode_vs_prefill(arch, S=18, cache_len=24):
    cfg = dataclasses.replace(get_config(arch, smoke=True), dtype=jnp.float32)
    if cfg.family == "moe":
        # avoid capacity drops so the comparison is exact
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    key = jax.random.PRNGKey(1)
    params = lm.init(cfg, key)
    B = 2
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    vision = (jax.random.normal(key, (B, cfg.vision_seq, cfg.d_model),
                                cfg.dtype) if cfg.family == "vlm" else None)
    full = lm.logits_fn(params, tokens, cfg, vision)
    cache = lm.init_cache(cfg, B, cache_len)
    if cfg.family == "vlm":
        wk = params["cross_blocks"]["xattn"]["wk"].astype(cfg.dtype)
        wv = params["cross_blocks"]["xattn"]["wv"].astype(cfg.dtype)
        cache["xk"] = jnp.einsum("bsd,ldk->lbsk", vision, wk).reshape(
            cache["xk"].shape)
        cache["xv"] = jnp.einsum("bsd,ldk->lbsk", vision, wv).reshape(
            cache["xv"].shape)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, 1)
    denom = float(jnp.max(jnp.abs(full))) + 1e-9
    return float(jnp.max(jnp.abs(dec - full))) / denom


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    assert _decode_vs_prefill(arch) < 1e-4


def test_rolling_window_cache():
    """SWA decode beyond the window with a rolling buffer stays exact."""
    cfg = dataclasses.replace(get_config("mixtral-8x7b", smoke=True),
                              dtype=jnp.float32, capacity_factor=8.0)
    assert cfg.window == 16
    key = jax.random.PRNGKey(2)
    params = lm.init(cfg, key)
    S = 40  # > 2x window: buffer wraps
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab)
    full = lm.logits_fn(params, tokens, cfg)
    cache = lm.init_cache(cfg, 2, cfg.window)  # physical = window
    assert cache["k"].shape[2] == cfg.window
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 1e-4


def test_hybrid_rolling_window():
    cfg = dataclasses.replace(get_config("recurrentgemma-9b", smoke=True),
                              dtype=jnp.float32)
    key = jax.random.PRNGKey(3)
    params = lm.init(cfg, key)
    S = 40
    tokens = jax.random.randint(key, (2, S), 0, cfg.vocab)
    full = lm.logits_fn(params, tokens, cfg)
    cache = lm.init_cache(cfg, 2, cfg.window)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    outs = []
    for t in range(S):
        logits, cache = step(params, cache, tokens[:, t:t + 1], jnp.int32(t))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - full))) / float(jnp.max(jnp.abs(full)))
    assert rel < 1e-4


def test_ssm_constant_state_long_decode():
    """Mamba decode state stays O(1): no growth, finite after many steps."""
    cfg = dataclasses.replace(get_config("falcon-mamba-7b", smoke=True),
                              dtype=jnp.float32)
    params = lm.init(cfg, jax.random.PRNGKey(0))
    cache = lm.init_cache(cfg, 1, 8)
    step = jax.jit(lambda p, c, t, pos: lm.decode_step(p, c, t, pos, cfg))
    tok = jnp.zeros((1, 1), jnp.int32)
    for t in range(60):
        logits, cache = step(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, :1], -1).astype(jnp.int32)
    assert bool(jnp.all(jnp.isfinite(cache["h"])))
    assert cache["h"].shape == (cfg.n_layers, 1, cfg.d_inner, cfg.ssm_state)
