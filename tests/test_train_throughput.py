"""Training-throughput engine: chunked drivers, remat, prefetch, caching.

The contract under test (ISSUE 4): the donated multi-step scanned drivers
are *numerically identical* to the seed-style per-step loop (same rng
chain, same optimizer trajectory), ``DONNConfig.remat`` changes memory
behavior but not values, the device prefetcher preserves stream order,
and training programs stop re-tracing across model rebuilds.
"""
import dataclasses
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import DONNConfig, LayerSpec, build_model
from repro.core import propagation as pp
from repro.core.train_utils import (
    make_train_chunk, make_train_step, optimizer_cache_key, train_classifier,
)
from repro.data import batch_iterator, synth_digits
from repro.data.pipeline import device_prefetch, stack_batches
from repro.optim import AdamW

TINY = dict(n=48, depth=3, distance=0.05, det_size=6)


def _params_close(a, b, rtol=1e-5, atol=1e-7):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


class TestChunkedClassifier:
    def _run(self, cfg, steps, steps_per_call, needs_rng=False, **kw):
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        xs, ys = synth_digits(256, seed=0)
        res = train_classifier(
            model, params, batch_iterator(xs, ys, 8, seed=1), steps=steps,
            lr=0.3, needs_rng=needs_rng, rng=jax.random.PRNGKey(3),
            steps_per_call=steps_per_call, **kw,
        )
        return res

    def test_chunked_matches_per_step(self):
        cfg = DONNConfig(name="tc", **TINY)
        ref = self._run(cfg, steps=10, steps_per_call=1)
        got = self._run(cfg, steps=10, steps_per_call=5)
        assert np.allclose(ref.losses, got.losses, rtol=1e-6, atol=1e-8)
        assert np.allclose(ref.accs, got.accs)
        _params_close(got.params, ref.params)

    def test_partial_final_chunk(self):
        cfg = DONNConfig(name="tp", **TINY)
        ref = self._run(cfg, steps=7, steps_per_call=1)
        got = self._run(cfg, steps=7, steps_per_call=4)  # 4 + 3 remainder
        assert len(got.losses) == 7
        assert np.allclose(ref.losses, got.losses, rtol=1e-6, atol=1e-8)
        _params_close(got.params, ref.params)

    def test_rng_codesign_chain_aligned(self):
        cfg = DONNConfig(name="tg", **TINY, codesign="gumbel")
        ref = self._run(cfg, steps=6, steps_per_call=1, needs_rng=True)
        got = self._run(cfg, steps=6, steps_per_call=3, needs_rng=True)
        assert np.allclose(ref.losses, got.losses, rtol=1e-6, atol=1e-8)
        _params_close(got.params, ref.params)

    def test_no_prefetch_same_result(self):
        cfg = DONNConfig(name="tn", **TINY)
        a = self._run(cfg, steps=6, steps_per_call=3, prefetch=0)
        b = self._run(cfg, steps=6, steps_per_call=3, prefetch=2)
        assert np.allclose(a.losses, b.losses)
        _params_close(a.params, b.params)

    def test_caller_params_survive_donation(self):
        cfg = DONNConfig(name="td", **TINY)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        xs, ys = synth_digits(128, seed=0)
        train_classifier(model, params, batch_iterator(xs, ys, 8, seed=1),
                         steps=4, steps_per_call=2)
        # the chunk driver donates its state; the caller's tree must stay
        # readable (train_classifier copies before donating)
        assert bool(jnp.all(jnp.isfinite(
            jax.tree.leaves(params)[0].astype(jnp.float32))))


class TestDonnStepsChunk:
    def test_segmentation_chunk_matches_sequential(self):
        from repro.launch.mesh import make_mesh
        from repro.nn import init_params
        from repro.runtime import donn_steps as ds

        cfg = DONNConfig(name="sc", n=48, depth=3, distance=0.05,
                         segmentation=True, skip_from=0, layer_norm=True)
        opt = AdamW(lr=0.05)
        r = np.random.default_rng(0)
        batches = [
            {"images": r.uniform(0, 1, (4, 28, 28)).astype(np.float32),
             "masks": (r.uniform(0, 1, (4, 48, 48)) > 0.5).astype(
                 np.float32)}
            for _ in range(4)
        ]
        sspecs = ds.donn_state_specs(cfg)
        st1 = init_params(sspecs, jax.random.PRNGKey(0))
        step = jax.jit(ds.make_donn_train_step(cfg, opt))
        ref_losses = []
        for b in batches:
            st1, m = step(st1, b)
            ref_losses.append(float(m["loss"]))

        mesh = make_mesh((1,), ("data",))
        fn, s_sh, b_sh, _ = ds.compile_donn_train_chunk(cfg, mesh,
                                                        optimizer=opt)
        st2 = jax.device_put(init_params(sspecs, jax.random.PRNGKey(0)),
                             s_sh)
        losses = []
        for chunk in stack_batches(iter(batches), 2):
            st2, m = fn(st2, chunk)
            losses.extend(np.asarray(m["loss"]).tolist())
        assert np.allclose(ref_losses, losses, rtol=1e-6, atol=1e-8)
        _params_close(st2["params"], st1["params"])


class TestRemat:
    def test_layer_remat_values_and_grads_match(self):
        cfg0 = DONNConfig(name="r0", **TINY)
        cfgr = dataclasses.replace(cfg0, name="r1", remat="layer")
        m0, mr = build_model(cfg0), build_model(cfgr)
        p = m0.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(4, seed=2)
        x = jnp.asarray(xs)
        np.testing.assert_allclose(m0.apply(p, x), mr.apply(p, x),
                                   rtol=1e-6, atol=1e-7)
        loss = lambda m: (lambda q: jnp.sum(m.apply(q, x)))
        g0 = jax.grad(loss(m0))(p)
        gr = jax.grad(loss(mr))(p)
        _params_close(gr, g0, rtol=1e-6)

    def test_layer_remat_reaches_backward_jaxpr(self):
        cfgr = DONNConfig(name="rj", **TINY, remat="layer")
        m = build_model(cfgr)
        p = m.init(jax.random.PRNGKey(0))
        x = jnp.zeros((2, 28, 28), jnp.float32)
        jx = str(jax.make_jaxpr(
            jax.grad(lambda q: jnp.sum(m.apply(q, x)))
        )(p))
        assert "remat" in jx or "checkpoint" in jx

    def test_segment_remat_heterogeneous(self):
        layers = (
            LayerSpec(distance=0.05, size=48),
            LayerSpec(distance=0.05, size=48),
            LayerSpec(distance=0.05, size=32, pixel_size=54e-6),
        )
        base = DONNConfig(name="rh", n=48, depth=3, distance=0.05,
                          det_size=6, layers=layers)
        cfgr = dataclasses.replace(base, remat="segment")
        m0, mr = build_model(base), build_model(cfgr)
        p = m0.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(2, seed=3)
        x = jnp.asarray(xs)
        g0 = jax.grad(lambda q: jnp.sum(m0.apply(q, x)))(p)
        gr = jax.grad(lambda q: jnp.sum(mr.apply(q, x)))(p)
        _params_close(gr, g0, rtol=1e-6)

    def test_invalid_remat_rejected(self):
        with pytest.raises(ValueError, match="remat"):
            DONNConfig(name="bad", remat="everything")

    def test_remat_survives_spec_round_trip(self):
        import repro.core.dsl as lr
        from repro.core.models import config_static_key

        cfg = DONNConfig(name="rt", **TINY, remat="layer")
        _, cfg2 = lr.from_spec(lr.to_spec(cfg))
        assert cfg2.remat == "layer"
        assert config_static_key(cfg2) == config_static_key(cfg)
        assert pp.plan_cache_key(cfg2, 1.0) == pp.plan_cache_key(cfg, 1.0)


class TestPipelineHelpers:
    def test_stack_batches_shapes_and_total(self):
        it = iter([(np.full((2, 3), i, np.float32), np.full((2,), i))
                   for i in range(10)])
        chunks = list(stack_batches(it, 4, total=9))
        assert [c[0].shape[0] for c in chunks] == [4, 4, 1]
        assert chunks[0][0].shape == (4, 2, 3)
        # order preserved: chunk 1 carries batches 4..7
        assert np.all(chunks[1][1][0] == 4)

    def test_device_prefetch_preserves_order(self):
        batches = [{"x": np.full((2,), i, np.float32)} for i in range(5)]
        out = list(device_prefetch(iter(batches), size=2))
        assert len(out) == 5
        for i, b in enumerate(out):
            assert isinstance(b["x"], jax.Array)
            assert float(b["x"][0]) == i

    def test_device_prefetch_rejects_bad_size(self):
        with pytest.raises(ValueError):
            list(device_prefetch(iter([]), size=0))


class TestExecutableReuse:
    def test_train_step_shared_across_model_rebuilds(self):
        cfg = DONNConfig(name="xr", **TINY)
        xs, ys = synth_digits(16, seed=0)
        xb, yb = jnp.asarray(xs[:8]), jnp.asarray(ys[:8])
        opt = AdamW(lr=0.1)

        def one_run():
            model = build_model(cfg)  # fresh model object each run
            params = model.init(jax.random.PRNGKey(0))
            step = make_train_step(model, opt, 10)
            s = opt.init(params)
            step(params, s, jnp.asarray(0), xb, yb, jax.random.PRNGKey(0))

        one_run()
        before = pp.plan_cache_stats()
        one_run()
        after = pp.plan_cache_stats()
        assert after["exec_hits"] > before["exec_hits"]
        assert after["exec_misses"] == before["exec_misses"]

    def test_chunk_driver_uses_executable_cache(self):
        cfg = DONNConfig(name="xc", **TINY)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=0.1)
        xs = jnp.zeros((2, 4, 28, 28), jnp.float32)
        ys = jnp.zeros((2, 4), jnp.int32)
        before = pp.plan_cache_stats()
        chunk = make_train_chunk(model, opt, 10)
        p, s, rng, *_ = chunk(params, opt.init(params), 0, xs, ys,
                              jax.random.PRNGKey(0))
        chunk2 = make_train_chunk(build_model(cfg), opt, 10)
        chunk2(p, s, 2, xs, ys, rng)
        after = pp.plan_cache_stats()
        assert after["exec_misses"] == before["exec_misses"] + 1
        assert after["exec_hits"] > before["exec_hits"]

    def test_unkeyable_optimizer_falls_back(self):
        assert optimizer_cache_key(AdamW(lr=0.1)) is not None
        assert optimizer_cache_key(AdamW(lr=lambda s: 0.1)) is None
        # schedule-driven optimizer still trains (plain jit path)
        cfg = DONNConfig(name="xs", **TINY)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = AdamW(lr=lambda s: 0.1)
        chunk = make_train_chunk(model, opt, 10)
        xs = jnp.zeros((2, 4, 28, 28), jnp.float32)
        ys = jnp.zeros((2, 4), jnp.int32)
        p, *_ = chunk(params, opt.init(params), 0, xs, ys,
                      jax.random.PRNGKey(0))
        assert bool(jnp.all(jnp.isfinite(jax.tree.leaves(p)[0])))


class TestSpatialGates:
    """Unsupported configs must be rejected loudly (single-device mesh)."""

    def _mesh(self):
        from repro.runtime.sharding import make_mesh_2d

        return make_mesh_2d(model=1)

    @pytest.mark.parametrize("kw", [
        dict(pad=True),
        dict(approximation="fraunhofer"),
        dict(codesign="gumbel"),
        dict(use_pallas=True),
        dict(tf_dtype="bfloat16"),
    ])
    def test_unsupported_config_raises(self, kw):
        from repro.runtime.donn_steps import make_donn_spatial_loss

        cfg = DONNConfig(name="g", n=48, depth=3, distance=0.05, **kw)
        with pytest.raises(NotImplementedError):
            make_donn_spatial_loss(cfg, self._mesh())

    @pytest.mark.parametrize("kw", [
        dict(segmentation=True, skip_from=0),
        dict(channels=3),
        dict(layers=(LayerSpec(distance=0.05, size=32),) * 3),
    ])
    def test_formerly_gated_families_now_build(self, kw):
        # seg-with-skip, RGB and hetero SegmentedPlan moved off the
        # reject list when the rules-table loss took over (ISSUE 10)
        from repro.runtime.donn_steps import make_donn_sharded_loss

        cfg = DONNConfig(name="g3", n=48, depth=3, distance=0.05, **kw)
        assert callable(make_donn_sharded_loss(cfg, self._mesh()))

    def test_indivisible_rows_raise(self):
        import jax as _jax

        if len(_jax.devices()) != 1:
            pytest.skip("single-device gate test")
        # n % k check needs k > 1; emulate via a fake mesh shape
        from repro.runtime.donn_steps import make_donn_spatial_loss

        class FakeMesh:
            shape = {"model": 5}

        cfg = DONNConfig(name="g2", n=48, depth=2, distance=0.05)
        with pytest.raises(ValueError, match="divide"):
            make_donn_spatial_loss(cfg, FakeMesh())


class TestBenchRollupCheck:
    def _run_mod(self):
        path = (pathlib.Path(__file__).resolve().parent.parent
                / "benchmarks" / "run.py")
        spec = importlib.util.spec_from_file_location("bench_run", path)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    def test_stale_tier1_flags_stale_and_missing(self):
        mod = self._run_mod()
        fresh = {s: {"stale": False} for s in mod.TIER1_SUITES}
        assert mod.stale_tier1(fresh) == []
        fresh["hetero"]["stale"] = True
        del fresh["dse_batched"]
        assert mod.stale_tier1(fresh) == ["dse_batched", "hetero"]

    def test_committed_summary_has_fresh_tier1(self):
        mod = self._run_mod()
        root = pathlib.Path(__file__).resolve().parent.parent
        summary = (root / "BENCH_summary.json")
        if not summary.exists():
            pytest.skip("no committed summary")
        import json

        assert mod.stale_tier1(json.loads(summary.read_text())) == []
