"""Data pipeline: determinism, host sharding, prefetch, straggler monitor."""
import time

import numpy as np
import pytest

from repro.data import batch_iterator, synth_digits, synth_rgb_scenes, synth_seg
from repro.data.pipeline import Prefetcher, StepMonitor
from repro.data.synthetic import synth_tokens, token_batch_iterator


class TestDeterminism:
    def test_digits_deterministic(self):
        a, la = synth_digits(16, seed=3)
        b, lb = synth_digits(16, seed=3)
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(la, lb)

    def test_seeds_differ(self):
        a, _ = synth_digits(8, seed=1)
        b, _ = synth_digits(8, seed=2)
        assert np.abs(a - b).max() > 0

    def test_tokens_deterministic_and_learnable(self):
        t1 = synth_tokens(2, 64, 256, seed=5)
        t2 = synth_tokens(2, 64, 256, seed=5)
        np.testing.assert_array_equal(t1, t2)
        # planted bigram: successor entropy far below uniform
        seqs = synth_tokens(20, 256, 64, seed=0)
        pairs = {}
        for s in seqs:
            for a, b in zip(s[:-1], s[1:]):
                pairs.setdefault(int(a), []).append(int(b))
        agree = np.mean([
            np.mean([b == max(set(bs), key=bs.count) for b in bs])
            for a, bs in pairs.items() if len(bs) > 5
        ])
        assert agree > 0.5  # dominated by the planted table

    def test_all_classes_present(self):
        _, ys = synth_digits(200, seed=0)
        assert len(set(ys.tolist())) == 10

    def test_rgb_and_seg_shapes(self):
        xs, ys = synth_rgb_scenes(4, size=32)
        assert xs.shape == (4, 3, 32, 32) and ys.shape == (4,)
        xi, mi = synth_seg(4, size=32)
        assert xi.shape == mi.shape == (4, 32, 32)
        assert set(np.unique(mi)) <= {0.0, 1.0}


class TestHostSharding:
    def test_disjoint_host_shards(self):
        xs, ys = synth_digits(64, seed=0)
        it0 = batch_iterator(xs, ys, 8, seed=0, host_id=0, num_hosts=2)
        it1 = batch_iterator(xs, ys, 8, seed=0, host_id=1, num_hosts=2)
        x0, _ = next(it0)
        x1, _ = next(it1)
        # host shards draw from disjoint index sets
        flat0 = {x.tobytes() for x in x0}
        flat1 = {x.tobytes() for x in x1}
        assert not (flat0 & flat1)

    def test_token_iterator_batches(self):
        it = token_batch_iterator(4, 32, 128, seed=0)
        b = next(it)
        assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestPrefetcher:
    def test_order_preserved(self):
        out = list(Prefetcher(iter(range(20)), depth=3))
        assert out == list(range(20))

    def test_transform_applied(self):
        out = list(Prefetcher(iter([1, 2, 3]), transform=lambda x: x * 10))
        assert out == [10, 20, 30]

    def test_error_propagates(self):
        def gen():
            yield 1
            raise RuntimeError("boom")

        it = Prefetcher(gen())
        assert next(it) == 1
        with pytest.raises(RuntimeError):
            list(it)

    def test_overlaps_producer(self):
        def slow():
            for i in range(5):
                time.sleep(0.02)
                yield i

        it = Prefetcher(slow(), depth=4)
        time.sleep(0.15)  # producer fills the queue meanwhile
        t0 = time.perf_counter()
        _ = [next(it) for _ in range(4)]
        assert time.perf_counter() - t0 < 0.05


class TestStepMonitor:
    def test_flags_straggler(self):
        m = StepMonitor(z_thresh=3.0)
        for _ in range(30):
            m.record(0.1 + np.random.default_rng(0).normal() * 1e-4)
        m.record(1.0)  # 9000-sigma straggler
        assert len(m.stragglers) == 1
        assert m.stragglers[0]["z"] > 3

    def test_no_false_positives_on_steady(self):
        m = StepMonitor()
        r = np.random.default_rng(1)
        for _ in range(100):
            m.record(0.1 + 1e-3 * r.normal())
        assert m.straggler_fraction < 0.05

    def test_ema_tracks(self):
        m = StepMonitor(alpha=0.5)
        for dt in (1.0, 2.0, 3.0):
            m.record(dt)
        assert 1.0 < m.ema < 3.0
