"""Per-architecture smoke tests + component oracles for the LM substrate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import LM_ARCHS
from repro.models import get_config, lm
from repro.models.attention import chunked_attention
from repro.models.moe import apply_moe, moe_spec
from repro.models.rglru import apply_rglru_block, rglru_spec
from repro.models.ssm import apply_mamba, mamba_spec
from repro.nn import init_params, param_count


@pytest.mark.parametrize("arch", LM_ARCHS)
class TestArchSmoke:
    def test_forward_train_step(self, arch):
        """Reduced config: one forward/train step, shapes + no NaNs."""
        cfg = get_config(arch, smoke=True)
        key = jax.random.PRNGKey(0)
        params = lm.init(cfg, key)
        B, S = 2, 32
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
        batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, 1)}
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                key, (B, cfg.vision_seq, cfg.d_model), cfg.dtype
            )
        logits = lm.logits_fn(params, tokens, cfg, batch.get("vision"))
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        loss, grads = jax.value_and_grad(
            lambda p: lm.lm_loss(p, batch, cfg)
        )(params)
        assert bool(jnp.isfinite(loss))
        gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
        assert np.isfinite(gnorm) and gnorm > 0


class TestFullConfigShapes:
    """FULL configs are exercised via the dry-run; here we only verify the
    parameter math matches the published sizes (no allocation)."""

    @pytest.mark.parametrize("arch,lo,hi", [
        ("glm4-9b", 8e9, 10.5e9),
        ("granite-8b", 7e9, 9e9),
        ("qwen1.5-4b", 3e9, 5e9),
        ("qwen2.5-14b", 13e9, 16e9),
        ("mixtral-8x7b", 45e9, 49e9),
        ("arctic-480b", 450e9, 500e9),
        ("llama-3.2-vision-11b", 8.5e9, 11.5e9),
        ("musicgen-medium", 1.2e9, 2.2e9),
        ("falcon-mamba-7b", 6.5e9, 8e9),
        ("recurrentgemma-9b", 8e9, 10.5e9),
    ])
    def test_param_count(self, arch, lo, hi):
        cfg = get_config(arch)
        n = param_count(lm.param_specs(cfg))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range"


class TestChunkedAttention:
    def _oracle(self, q, k, v, window=0):
        B, S, H, D = q.shape
        KV = k.shape[2]
        G = H // KV
        qg = q.reshape(B, S, KV, G, D)
        s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k) / np.sqrt(D)
        qi, ki = jnp.arange(S)[:, None], jnp.arange(S)[None, :]
        mask = ki <= qi
        if window:
            mask &= ki > qi - window
        s = jnp.where(mask, s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqt,btkd->bkgqd", p, v)
        return jnp.moveaxis(o, 3, 1).reshape(B, S, H, D)

    @pytest.mark.parametrize("chunk", [4, 16, 64])
    @pytest.mark.parametrize("window", [0, 8])
    def test_vs_oracle(self, chunk, window):
        r = np.random.default_rng(0)
        B, S, H, KV, D = 2, 48, 4, 2, 16
        q = jnp.asarray(r.normal(size=(B, S, H, D)), jnp.float32)
        k = jnp.asarray(r.normal(size=(B, S, KV, D)), jnp.float32)
        v = jnp.asarray(r.normal(size=(B, S, KV, D)), jnp.float32)
        got = chunked_attention(q, k, v, causal=True, window=window,
                                chunk=chunk)
        want = self._oracle(q * (D**-0.5) * np.sqrt(D), k, v, window)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_gradients_finite(self):
        r = np.random.default_rng(1)
        q = jnp.asarray(r.normal(size=(1, 32, 4, 16)), jnp.float32)
        k = jnp.asarray(r.normal(size=(1, 32, 2, 16)), jnp.float32)
        g = jax.grad(
            lambda q_: jnp.sum(chunked_attention(q_, k, k, chunk=8) ** 2)
        )(q)
        assert bool(jnp.all(jnp.isfinite(g)))


class TestMamba:
    def test_scan_matches_naive_recurrence(self):
        cfg = dataclasses.replace(get_config("falcon-mamba-7b", smoke=True),
                                  dtype=jnp.float32, scan_chunk=4)
        p = init_params(mamba_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 11, cfg.d_model))
        out, _ = apply_mamba(p, x, cfg)
        cfg1 = dataclasses.replace(cfg, scan_chunk=1)
        out1, _ = apply_mamba(p, x, cfg1)
        np.testing.assert_allclose(out, out1, rtol=1e-4, atol=1e-5)

    def test_state_carrying_decode(self):
        cfg = dataclasses.replace(get_config("falcon-mamba-7b", smoke=True),
                                  dtype=jnp.float32)
        p = init_params(mamba_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 9, cfg.d_model))
        full, _ = apply_mamba(p, x, cfg)
        conv = jnp.zeros((2, cfg.d_conv - 1, cfg.d_inner))
        h = jnp.zeros((2, cfg.d_inner, cfg.ssm_state))
        outs = []
        for t in range(9):
            y, (conv, h) = apply_mamba(p, x[:, t:t + 1], cfg,
                                       conv_state=conv, ssm_state=h)
            outs.append(y[:, 0])
        np.testing.assert_allclose(jnp.stack(outs, 1), full,
                                   rtol=1e-4, atol=1e-5)


class TestRGLRU:
    def test_chunked_equals_stepwise(self):
        cfg = dataclasses.replace(get_config("recurrentgemma-9b", smoke=True),
                                  dtype=jnp.float32)
        p = init_params(rglru_spec(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 9, cfg.d_model))
        full, _ = apply_rglru_block(p, x, cfg)
        conv = jnp.zeros((2, cfg.d_conv - 1, cfg.lru_width))
        h = jnp.zeros((2, cfg.lru_width))
        outs = []
        for t in range(9):
            y, (conv, h) = apply_rglru_block(p, x[:, t:t + 1], cfg,
                                             conv_state=conv, lru_state=h)
            outs.append(y[:, 0])
        np.testing.assert_allclose(jnp.stack(outs, 1), full,
                                   rtol=1e-4, atol=1e-5)

    def test_state_decay_bounded(self):
        """RG-LRU gate a_t must stay in (0, 1) — stability invariant."""
        cfg = dataclasses.replace(get_config("recurrentgemma-9b", smoke=True),
                                  dtype=jnp.float32)
        p = init_params(rglru_spec(cfg), jax.random.PRNGKey(1))
        a = jax.nn.sigmoid(p["lam"])
        assert float(a.min()) > 0.5 and float(a.max()) < 1.0


class TestMoE:
    def _loop_oracle(self, p, x, cfg):
        """Dense per-token loop using the same top-k choices (no capacity)."""
        logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
        probs = jax.nn.softmax(logits, -1)
        w, idx = jax.lax.top_k(probs, cfg.top_k)
        w = w / jnp.sum(w, -1, keepdims=True)
        out = jnp.zeros_like(x)
        for e in range(cfg.n_experts):
            h = jax.nn.silu(x @ p["w_gate"][e].astype(x.dtype)) * (
                x @ p["w_up"][e].astype(x.dtype)
            )
            eo = h @ p["w_down"][e].astype(x.dtype)
            for k in range(cfg.top_k):
                sel = (idx[..., k] == e).astype(x.dtype)[..., None]
                out = out + eo * sel * w[..., k : k + 1].astype(x.dtype)
        return out

    def test_dispatch_matches_loop_oracle(self):
        cfg = dataclasses.replace(
            get_config("mixtral-8x7b", smoke=True), dtype=jnp.float32,
            capacity_factor=8.0,  # no drops => exact match expected
        )
        p = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
        got, aux = apply_moe(p, x, cfg)
        want = self._loop_oracle(p, x, cfg)
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
        assert float(aux) > 0

    def test_capacity_drops_bounded(self):
        cfg = dataclasses.replace(
            get_config("mixtral-8x7b", smoke=True), dtype=jnp.float32,
            capacity_factor=1.0,
        )
        p = init_params(moe_spec(cfg), jax.random.PRNGKey(0))
        x = 0.1 * jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
        got, _ = apply_moe(p, x, cfg)
        # dropped tokens produce zero output, not NaN
        assert bool(jnp.all(jnp.isfinite(got)))

    def test_arctic_dense_residual_present(self):
        cfg = get_config("arctic-480b", smoke=True)
        spec = moe_spec(cfg)
        assert "dense" in spec


class TestChunkedXent:
    def test_matches_direct(self):
        cfg = dataclasses.replace(get_config("glm4-9b", smoke=True),
                                  dtype=jnp.float32)
        params = lm.init(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, cfg.d_model))
        labels = jax.random.randint(jax.random.PRNGKey(2), (2, 24), 0,
                                    cfg.vocab)
        got = lm.chunked_xent(params, x, labels, cfg, chunk=8)
        from repro.models.layers import unembed

        logits = unembed(params["embed"], x, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
        want = jnp.mean(lse - gold)
        np.testing.assert_allclose(float(got), float(want), rtol=1e-5)

    def test_padding_labels_ignored(self):
        cfg = dataclasses.replace(get_config("glm4-9b", smoke=True),
                                  dtype=jnp.float32)
        params = lm.init(cfg, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, cfg.d_model))
        labels = jnp.array([[1, 2, 3, 4, 5, -1, -1, -1, -1, -1]])
        l1 = lm.chunked_xent(params, x, labels, cfg, chunk=4)
        l2 = lm.chunked_xent(params, x[:, :5], labels[:, :5], cfg, chunk=4)
        np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
