"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, grads, properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops

SHAPES = [(1, 8, 128), (2, 64, 128), (3, 200, 200), (1, 37, 111), (2, 17, 513)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rand(shape, dtype, seed=0):
    r = np.random.default_rng(seed)
    return jnp.asarray(r.normal(size=shape), dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=1e-5, atol=1e-5
    )


class TestComplexMul:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_matches_oracle(self, shape, dtype):
        B, H, W = shape
        ar, ai = _rand(shape, dtype, 1), _rand(shape, dtype, 2)
        br, bi = _rand((H, W), dtype, 3), _rand((H, W), dtype, 4)
        got = ops.complex_mul(ar, ai, br, bi)
        want = ops.complex_mul_ref(ar, ai, br, bi)
        for g, w in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(w, np.float32),
                **_tol(dtype),
            )

    def test_2d_input(self):
        ar, ai = _rand((16, 128), jnp.float32, 5), _rand((16, 128), jnp.float32, 6)
        got = ops.complex_mul(ar, ai, ar, ai)
        want = ops.complex_mul_ref(ar, ai, ar, ai)
        np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 100))
    def test_conjugate_product_is_magnitude(self, seed):
        """a * conj(a) = |a|^2 (pure real)."""
        ar, ai = _rand((1, 16, 128), jnp.float32, seed), _rand(
            (1, 16, 128), jnp.float32, seed + 1
        )
        re, im = ops.complex_mul(ar, ai, ar[0], -ai[0])
        np.testing.assert_allclose(re, ar * ar + ai * ai, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(im, np.zeros_like(im), atol=1e-5)


class TestPhaseApply:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_matches_oracle(self, shape):
        B, H, W = shape
        ur, ui = _rand(shape, jnp.float32, 1), _rand(shape, jnp.float32, 2)
        phi = jnp.asarray(
            np.random.default_rng(3).uniform(0, 6.28, (H, W)), jnp.float32
        )
        got = ops.phase_apply(ur, ui, phi, 1.3)
        want = ops.phase_apply_ref(ur, ui, phi, 1.3)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-4)

    def test_unitary_when_gamma_one(self):
        ur, ui = _rand((2, 32, 128), jnp.float32, 4), _rand(
            (2, 32, 128), jnp.float32, 5
        )
        phi = _rand((32, 128), jnp.float32, 6)
        our, oui = ops.phase_apply(ur, ui, phi, 1.0)
        np.testing.assert_allclose(
            our**2 + oui**2, ur**2 + ui**2, rtol=1e-4, atol=1e-4
        )

    def test_gradients_match_reference(self):
        ur, ui = _rand((2, 24, 96), jnp.float32, 7), _rand(
            (2, 24, 96), jnp.float32, 8
        )
        phi = _rand((24, 96), jnp.float32, 9)

        def f(fn, p):
            a, b = fn(ur, ui, p, 1.1)
            return jnp.sum(jnp.sin(a) + b * b)

        g1 = jax.grad(lambda p: f(ops.phase_apply, p))(phi)
        g2 = jax.grad(lambda p: f(ops.phase_apply_ref, p))(phi)
        np.testing.assert_allclose(g1, g2, rtol=2e-4, atol=2e-4)


class TestIntensityReadout:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("classes", [3, 10])
    def test_matches_oracle(self, shape, classes):
        B, H, W = shape
        ur, ui = _rand(shape, jnp.float32, 1), _rand(shape, jnp.float32, 2)
        masks = jnp.asarray(
            (np.random.default_rng(3).random((classes, H, W)) < 0.1),
            jnp.float32,
        )
        got = ops.intensity_readout(ur, ui, masks)
        want = ops.intensity_readout_ref(ur, ui, masks)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_partition_sums_to_total(self):
        """Masks that partition the plane => per-class sums add to total."""
        B, H, W = 2, 32, 128
        ur, ui = _rand((B, H, W), jnp.float32, 4), _rand((B, H, W), jnp.float32, 5)
        labels = np.random.default_rng(6).integers(0, 4, (H, W))
        masks = jnp.asarray(
            np.stack([(labels == c) for c in range(4)]), jnp.float32
        )
        out = ops.intensity_readout(ur, ui, masks)
        total = jnp.sum(ur**2 + ui**2, axis=(1, 2))
        np.testing.assert_allclose(jnp.sum(out, -1), total, rtol=1e-4)

    def test_gradients(self):
        ur, ui = _rand((2, 16, 128), jnp.float32, 7), _rand(
            (2, 16, 128), jnp.float32, 8
        )
        masks = jnp.ones((2, 16, 128), jnp.float32)
        g1 = jax.grad(
            lambda u: jnp.sum(ops.intensity_readout(u, ui, masks))
        )(ur)
        g2 = jax.grad(
            lambda u: jnp.sum(ops.intensity_readout_ref(u, ui, masks))
        )(ur)
        np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-4)


class TestChannelIntensityReadout:
    """The fused multi-channel detector accumulation (ISSUE-5 audit)."""

    def test_matches_einsum_fallback(self):
        r = np.random.default_rng(0)
        ur = jnp.asarray(r.normal(size=(2, 3, 40, 40)), jnp.float32)
        ui = jnp.asarray(r.normal(size=(2, 3, 40, 40)), jnp.float32)
        masks = jnp.asarray(
            (r.random((5, 40, 40)) > 0.7).astype(np.float32)
        )
        got = ops.channel_intensity_readout(ur, ui, masks)
        inten = ur**2 + ui**2
        want = jnp.einsum("bdhw,chw->bc", inten, masks)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_single_sample(self):
        r = np.random.default_rng(1)
        ur = jnp.asarray(r.normal(size=(3, 16, 16)), jnp.float32)
        ui = jnp.asarray(r.normal(size=(3, 16, 16)), jnp.float32)
        masks = jnp.ones((2, 16, 16), jnp.float32)
        got = ops.channel_intensity_readout(ur, ui, masks)
        want = jnp.sum(ur**2 + ui**2)
        np.testing.assert_allclose(got, jnp.full((2,), want), rtol=1e-4)

    def test_gradients_flow_through_channel_sum(self):
        r = np.random.default_rng(2)
        ur = jnp.asarray(r.normal(size=(1, 2, 16, 16)), jnp.float32)
        ui = jnp.asarray(r.normal(size=(1, 2, 16, 16)), jnp.float32)
        masks = jnp.ones((1, 16, 16), jnp.float32)

        def f(a, b):
            return jnp.sum(ops.channel_intensity_readout(a, b, masks))

        da, db = jax.grad(f, argnums=(0, 1))(ur, ui)
        np.testing.assert_allclose(da, 2 * ur, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(db, 2 * ui, rtol=1e-4, atol=1e-5)

    def test_eager_multichannel_model_routes_through_kernel(self):
        """Eager RGB path: pallas readout agrees with the jnp einsum."""
        from repro.core import DONNConfig, build_model

        x = np.random.default_rng(3).random((2, 3, 24, 24), np.float32)
        outs = {}
        for up in (False, True):
            cfg = DONNConfig(name=f"mc-eager-{up}", n=24, depth=2,
                             distance=0.05, det_size=4, channels=3,
                             engine="eager", use_pallas=up)
            m = build_model(cfg)
            params = m.init(jax.random.PRNGKey(0))
            outs[up] = np.asarray(m.apply(params, x))
        np.testing.assert_allclose(outs[True], outs[False], rtol=1e-5,
                                   atol=1e-5)


class TestRope:
    @pytest.mark.parametrize("shape", [(2, 16, 64), (4, 33, 128), (1, 7, 32)])
    def test_matches_oracle(self, shape):
        x = _rand(shape, jnp.float32, 1)
        ang = np.random.default_rng(2).normal(size=(shape[-2], shape[-1] // 2))
        c, s = jnp.cos(ang).astype(jnp.float32), jnp.sin(ang).astype(jnp.float32)
        np.testing.assert_allclose(
            ops.apply_rope(x, c, s), ops.rope_ref(x, c, s),
            rtol=1e-5, atol=1e-5,
        )

    def test_norm_preserving(self):
        x = _rand((2, 16, 64), jnp.float32, 3)
        ang = np.random.default_rng(4).normal(size=(16, 32))
        out = ops.apply_rope(x, jnp.cos(ang).astype(jnp.float32),
                             jnp.sin(ang).astype(jnp.float32))
        np.testing.assert_allclose(
            jnp.sum(out**2, -1), jnp.sum(x**2, -1), rtol=1e-4
        )

    def test_inverse_rotation(self):
        x = _rand((2, 16, 64), jnp.float32, 5)
        ang = np.random.default_rng(6).normal(size=(16, 32))
        c = jnp.cos(ang).astype(jnp.float32)
        s = jnp.sin(ang).astype(jnp.float32)
        back = ops.apply_rope(ops.apply_rope(x, c, s), c, -s)
        np.testing.assert_allclose(back, x, rtol=1e-4, atol=1e-5)
