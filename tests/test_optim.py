"""Optimizer + schedules + gradient compression numerics."""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.optim import AdamW, constant, global_norm, warmup_cosine
from repro.optim.adamw import clip_by_global_norm
from repro.optim.compression import (
    compression_ratio, dequantize_int8, ef_quantize, quantize_int8,
)


class TestAdamW:
    def test_matches_numpy_reference(self):
        lr, b1, b2, eps = 0.01, 0.9, 0.999, 1e-8
        opt = AdamW(lr=lr, b1=b1, b2=b2, eps=eps)
        r = np.random.default_rng(0)
        p = {"w": jnp.asarray(r.normal(size=(5, 3)), jnp.float32)}
        state = opt.init(p)
        m = np.zeros((5, 3)); v = np.zeros((5, 3))
        pn = np.asarray(p["w"]).copy()
        for step in range(5):
            g = r.normal(size=(5, 3)).astype(np.float32)
            p, state = opt.update({"w": jnp.asarray(g)}, state, p,
                                  jnp.asarray(step))
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            mh = m / (1 - b1 ** (step + 1))
            vh = v / (1 - b2 ** (step + 1))
            pn = pn - lr * mh / (np.sqrt(vh) + eps)
        np.testing.assert_allclose(np.asarray(p["w"]), pn, rtol=1e-5, atol=1e-6)

    def test_weight_decay_shrinks(self):
        opt = AdamW(lr=0.1, weight_decay=0.5)
        p = {"w": jnp.ones((4,))}
        state = opt.init(p)
        p2, _ = opt.update({"w": jnp.zeros((4,))}, state, p, jnp.asarray(0))
        assert float(p2["w"][0]) < 1.0

    def test_converges_on_quadratic(self):
        opt = AdamW(lr=0.1)
        p = {"w": jnp.asarray([5.0, -3.0])}
        state = opt.init(p)
        for i in range(300):
            g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
            p, state = opt.update(g, state, p, jnp.asarray(i))
        assert float(jnp.max(jnp.abs(p["w"]))) < 1e-2

    def test_bf16_state_dtype_halves_memory(self):
        opt = AdamW(lr=0.1, state_dtype=jnp.bfloat16)
        p = {"w": jnp.ones((8,), jnp.float32)}
        st_ = opt.init(p)
        assert st_.mu["w"].dtype == jnp.bfloat16

    def test_grad_clip(self):
        g = {"a": jnp.full((4,), 100.0)}
        c = clip_by_global_norm(g, 1.0)
        assert abs(float(global_norm(c)) - 1.0) < 1e-5


class TestSchedules:
    def test_warmup_cosine_shape(self):
        fn = warmup_cosine(1.0, 10, 100, final_frac=0.1)
        assert float(fn(0)) < 0.2
        assert abs(float(fn(10)) - 1.0) < 0.02
        assert float(fn(99)) < 0.2
        assert float(fn(99)) >= 0.1 * 0.99

    def test_constant(self):
        assert float(constant(0.5)(123)) == 0.5


class TestCompression:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 1000), scale=st.floats(1e-4, 1e4))
    def test_quantization_error_bound(self, seed, scale):
        r = np.random.default_rng(seed)
        x = jnp.asarray(scale * r.normal(size=(1000,)), jnp.float32)
        q, s, n = quantize_int8(x)
        deq = dequantize_int8(q, s, n, x.shape, jnp.float32)
        # per-block error bounded by scale/2 = max|block|/254
        err = np.abs(np.asarray(deq - x))
        bound = np.asarray(s).max() * 0.5 + 1e-9
        assert err.max() <= bound * 1.001

    def test_compression_ratio_near_4x(self):
        x = jnp.asarray(np.random.default_rng(0).normal(size=(100000,)),
                        jnp.float32)
        assert compression_ratio(x) > 3.5

    def test_error_feedback_preserves_signal(self):
        """Sum of dequantized transmissions + final error == sum of inputs."""
        r = np.random.default_rng(1)
        err = jnp.zeros((512,), jnp.float32)
        xs = [jnp.asarray(r.normal(size=(512,)), jnp.float32) for _ in range(20)]
        sent = jnp.zeros((512,), jnp.float32)
        for x in xs:
            q, s, n, err = ef_quantize(x, err)
            sent = sent + dequantize_int8(q, s, n, x.shape, jnp.float32)
        total = sum(xs)
        np.testing.assert_allclose(np.asarray(sent + err), np.asarray(total),
                                   rtol=1e-4, atol=1e-4)

    def test_ef_sgd_converges_like_exact(self):
        """EF-compressed gradients converge on a quadratic ~ as exact SGD."""
        w = jnp.asarray([4.0, -2.0, 1.0] * 100)
        err = jnp.zeros_like(w)
        w_exact = w
        for _ in range(200):
            g = 2 * w
            q, s, n, err = ef_quantize(g, err)
            g_hat = dequantize_int8(q, s, n, g.shape, jnp.float32)
            w = w - 0.01 * g_hat
            w_exact = w_exact - 0.01 * (2 * w_exact)
        assert float(jnp.max(jnp.abs(w))) < 0.1
        assert float(jnp.max(jnp.abs(w - w_exact))) < 0.05
