"""Continuous-batching fleet suite (ISSUE-9): admission + failover drills.

Pins the ``runtime.fleet`` contracts on top of the PR-7 resilience layer:

- **continuous admission**: an idle fleet dispatches immediately
  (batch 1); arrivals during an in-flight batch coalesce into the open
  slot and ride the next free replica as one group; submit-during-drain
  is rejected with the typed ``DrainingError``; a deadline that expires
  while the request is still queued in an open slot fails only that
  future with ``DeadlineExceededError``;
- **failover, zero drops**: a mid-run replica kill re-serves its
  in-flight group on a healthy replica **bit-identically**; N-1 dead
  replicas still serve everything; a poison request isolates via group
  splits and exhausts only its *own* retry budget
  (``RetriesExhaustedError``) while its group-mates are served;
- **drain + warm swap**: ``drain()`` flushes every queued request,
  ``swap_artifact`` validates the new artifact first, rolls replicas one
  at a time under live traffic, and drops nothing;
- **supervisor backoff** (satellite): ``EngineSupervisor.restart``
  sleeps an exponential backoff with jitter and records attempt/backoff
  history in ``stats()``.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core import DONNConfig, build_model
from repro.runtime.fleet import ContinuousBatcher, FleetRouter
from repro.runtime.inference import InferenceEngine, freeze
from repro.runtime.resilience import (
    ARTIFACT_FILE, DeadlineExceededError, DrainingError, EngineSupervisor,
    OverloadedError, RetriesExhaustedError, save_deployed, validate_artifact,
)
from repro.testing import CrashingEngine, FlakyEngine, kill_replica


def _digits(b, shape=(28, 28), seed=0):
    return np.random.default_rng(seed).random((b,) + shape, np.float32)


def _model(seed=0, **kw):
    kw.setdefault("n", 32)
    kw.setdefault("depth", 2)
    kw.setdefault("distance", 0.05)
    kw.setdefault("det_size", 6)
    kw.setdefault("name", "fleet")
    cfg = DONNConfig(**kw)
    model = build_model(cfg)
    return model, model.init(jax.random.PRNGKey(seed))


class FakeEngine:
    """Engine-like double: deterministic row sums, optional stall."""

    buckets = (1, 2, 4, 8)
    deployed = None

    def __init__(self, delay_s=0.0):
        self.delay_s = delay_s
        self.group_sizes = []

    def infer(self, x):
        if self.delay_s:
            time.sleep(self.delay_s)
        self.group_sizes.append(int(x.shape[0]))
        return np.sum(np.asarray(x), axis=(1, 2))[:, None]


class PoisonEngine(FakeEngine):
    """Fails any group containing the poison marker value."""

    MARKER = -777.0

    def infer(self, x):
        if np.any(np.asarray(x) == self.MARKER):
            raise RuntimeError("poison request in group")
        return super().infer(x)


def _submit_all(router, xs, timeout_ms=None):
    return [router.submit(x, timeout_ms=timeout_ms) for x in xs]


def _results(futs, timeout=30):
    return [f.result(timeout=timeout) for f in futs]


# --------------------------------------------------------------------------
# Continuous admission
# --------------------------------------------------------------------------
class TestContinuousAdmission:
    def test_idle_engine_dispatches_immediately(self):
        eng = FakeEngine()
        cb = ContinuousBatcher(eng, validate=False)
        try:
            f = cb.submit(np.ones((4, 4), np.float32))
            assert np.allclose(f.result(timeout=10), 16.0)
            # no deadline was waited out: the first dispatch is batch 1
            assert eng.group_sizes[0] == 1
        finally:
            assert cb.close()

    def test_arrivals_coalesce_into_open_slot(self):
        eng = FakeEngine(delay_s=0.15)
        cb = ContinuousBatcher(eng, validate=False)
        try:
            first = cb.submit(np.zeros((4, 4), np.float32))
            time.sleep(0.05)  # first is in flight; these join the open slot
            rest = _submit_all(
                cb, [np.full((4, 4), i, np.float32) for i in range(1, 5)]
            )
            outs = _results([first] + rest)
            assert all(np.allclose(o, 16.0 * i) for i, o in enumerate(outs))
            # the 4 arrivals rode the next dispatch as one group
            assert eng.group_sizes == [1, 4]
        finally:
            cb.close()

    def test_groups_respect_bucket_max(self):
        eng = FakeEngine(delay_s=0.1)
        cb = ContinuousBatcher(eng, validate=False)
        try:
            first = cb.submit(np.zeros((4, 4), np.float32))
            time.sleep(0.03)
            rest = _submit_all(
                cb, [np.zeros((4, 4), np.float32) for _ in range(12)]
            )
            _results([first] + rest)
            assert all(g <= max(eng.buckets) for g in eng.group_sizes)
        finally:
            cb.close()

    def test_submit_during_drain_typed_rejection(self):
        eng = FakeEngine(delay_s=0.05)
        cb = ContinuousBatcher(eng, validate=False)
        try:
            futs = _submit_all(
                cb, [np.zeros((4, 4), np.float32) for _ in range(6)]
            )
            done = threading.Event()
            drained = {}

            def drain():
                drained["ok"] = cb.drain(timeout=20)
                done.set()

            threading.Thread(target=drain, daemon=True).start()
            time.sleep(0.01)
            with pytest.raises(DrainingError):
                cb.submit(np.zeros((4, 4), np.float32))
            assert done.wait(20) and drained["ok"]
            # the drain flushed everything already admitted: zero drops
            _results(futs)
            assert cb.stats()["rejected_draining"] == 1
            cb.resume()
            f = cb.submit(np.ones((4, 4), np.float32))
            assert np.allclose(f.result(timeout=10), 16.0)
        finally:
            cb.close()

    def test_deadline_expiry_while_queued_in_open_slot(self):
        eng = FakeEngine(delay_s=0.4)
        cb = ContinuousBatcher(eng, validate=False)
        try:
            blocker = cb.submit(np.zeros((4, 4), np.float32))
            time.sleep(0.1)  # blocker dispatched; the engine is busy
            doomed = cb.submit(np.ones((4, 4), np.float32), timeout_ms=50)
            ok = cb.submit(np.full((4, 4), 2.0, np.float32))
            with pytest.raises(DeadlineExceededError):
                doomed.result(timeout=10)
            # only the expired future failed; its slot-mates are served
            assert np.allclose(ok.result(timeout=10), 32.0)
            assert np.allclose(blocker.result(timeout=10), 0.0)
            assert cb.stats()["expired"] == 1
        finally:
            cb.close()

    def test_admission_bound_sheds_typed(self):
        eng = FakeEngine(delay_s=0.2)
        cb = ContinuousBatcher(eng, validate=False, max_queue=2)
        try:
            first = cb.submit(np.zeros((4, 4), np.float32))
            time.sleep(0.05)
            kept = _submit_all(
                cb, [np.zeros((4, 4), np.float32) for _ in range(2)]
            )
            with pytest.raises(OverloadedError):
                cb.submit(np.zeros((4, 4), np.float32))
            _results([first] + kept)
            assert cb.stats()["shed"] == 1
        finally:
            cb.close()

    def test_request_validation_at_the_door(self):
        model, params = _model()
        dep = freeze(model, params)
        cb = ContinuousBatcher(InferenceEngine(dep, buckets=(1, 2)))
        try:
            with pytest.raises(ValueError):
                cb.submit(np.zeros((3, 3), np.float32))
            with pytest.raises(TypeError):
                cb.submit(np.zeros((28, 28), dtype="U4"))
        finally:
            cb.close()


# --------------------------------------------------------------------------
# Fleet failover
# --------------------------------------------------------------------------
class TestFleetFailover:
    def test_midrun_kill_zero_drops_bit_identical(self):
        model, params = _model()
        dep = freeze(model, params)
        xs = _digits(24)
        ref = InferenceEngine(dep, buckets=(8,)).infer(xs)
        mk = lambda: FlakyEngine(
            InferenceEngine(dep, buckets=(8,)))  # noqa: E731
        router = FleetRouter([mk(), mk()], seed=3,
                             backoff_base_ms=1.0)
        try:
            futs = _submit_all(router, list(xs))
            kill_replica(router)  # mid-run crash: stays down
            outs = np.stack(_results(futs))
            np.testing.assert_array_equal(outs, ref)
            s = router.stats()
            assert s["served"] == 24 and s["failed"] == 0
        finally:
            router.close()

    def test_n_minus_1_failures_still_serve(self):
        engines = [CrashingEngine(FakeEngine(), crash_after=0)
                   for _ in range(2)] + [FakeEngine()]
        router = FleetRouter(engines, seed=1, backoff_base_ms=1.0,
                             validate=False)
        try:
            futs = _submit_all(
                router,
                [np.full((4, 4), i, np.float32) for i in range(16)],
            )
            outs = _results(futs)
            assert all(np.allclose(o, 16.0 * i) for i, o in enumerate(outs))
            s = router.stats()
            assert s["failed"] == 0
            assert s["replica_failures"] >= 1  # the dead replicas were hit
        finally:
            router.close()

    def test_poison_request_fails_alone(self):
        eng = PoisonEngine(delay_s=0.1)
        router = FleetRouter([eng], seed=2, max_retries=1,
                             backoff_base_ms=1.0, validate=False)
        try:
            # occupy the replica so poison + mates coalesce into one group
            blocker = router.submit(np.zeros((4, 4), np.float32))
            time.sleep(0.03)
            good = [np.full((4, 4), i, np.float32) for i in range(1, 6)]
            poison = np.full((4, 4), PoisonEngine.MARKER, np.float32)
            futs = _submit_all(router, good[:2] + [poison] + good[2:])
            bad_fut = futs[2]
            assert np.allclose(blocker.result(timeout=30), 0.0)
            with pytest.raises(RetriesExhaustedError):
                bad_fut.result(timeout=30)
            others = [f.result(timeout=30)
                      for i, f in enumerate(futs) if i != 2]
            expect = [16.0 * i for i in range(1, 6)]
            assert all(np.allclose(o, e) for o, e in zip(others, expect))
            s = router.stats()
            assert s["failed"] == 1 and s["served"] == 6
            assert s["splits"] >= 1  # the poison isolated via group splits
        finally:
            router.close()

    def test_retry_exhaustion_is_typed_and_bounded(self):
        dead = CrashingEngine(FakeEngine(), crash_after=0)
        router = FleetRouter([dead], max_retries=2, backoff_base_ms=1.0,
                             seed=4, validate=False)
        try:
            f = router.submit(np.zeros((4, 4), np.float32))
            with pytest.raises(RetriesExhaustedError):
                f.result(timeout=30)
            s = router.stats()
            # 1 initial dispatch + max_retries retries, then a typed fail
            assert s["failed"] == 1
            assert s["replica_failures"] == 3
        finally:
            router.close()

    def test_least_loaded_placement_spreads_over_idle_replicas(self):
        e1, e2 = FakeEngine(delay_s=0.05), FakeEngine(delay_s=0.05)
        router = FleetRouter([e1, e2], validate=False)
        try:
            # more than one bucket's worth: the overflow group must land
            # on the other idle replica, not queue behind the first
            futs = _submit_all(
                router, [np.zeros((4, 4), np.float32) for _ in range(16)]
            )
            _results(futs)
            assert e1.group_sizes and e2.group_sizes  # both replicas served
        finally:
            router.close()

    def test_unclean_close_fails_stranded_futures(self):
        dead = CrashingEngine(FakeEngine(), crash_after=0)
        router = FleetRouter([dead], max_retries=50,
                             backoff_base_ms=200.0, backoff_max_ms=5000.0,
                             seed=5, validate=False)
        f = router.submit(np.zeros((4, 4), np.float32))
        assert not router.close(timeout=0.3)
        with pytest.raises(RuntimeError):
            f.result(timeout=10)


# --------------------------------------------------------------------------
# Drain + warm swap from artifacts
# --------------------------------------------------------------------------
class TestDrainAndSwap:
    def _two_artifacts(self, tmp_path):
        model, p0 = _model(seed=0)
        _, p1 = _model(seed=1)
        d0, d1 = freeze(model, p0), freeze(model, p1)
        a0, a1 = tmp_path / "art0", tmp_path / "art1"
        save_deployed(d0, a0)
        save_deployed(d1, a1)
        return d0, d1, a0, a1

    def test_from_artifact_serves_and_swaps_zero_drops(self, tmp_path):
        d0, d1, a0, a1 = self._two_artifacts(tmp_path)
        xs = _digits(8)
        ref0 = InferenceEngine(d0, buckets=(8,)).infer(xs)
        ref1 = InferenceEngine(d1, buckets=(8,)).infer(xs)
        assert not np.array_equal(ref0, ref1)  # the swap is observable
        # single serving bucket: every group pads to the same compiled
        # program, so per-row outputs are bit-comparable to the reference
        router = FleetRouter.from_artifact(a0, replicas=2, buckets=(8,))
        try:
            np.testing.assert_array_equal(
                np.stack(_results(_submit_all(router, list(xs)))), ref0
            )
            stop = threading.Event()
            live, errs = [], []

            def pump():
                while not stop.is_set():
                    try:
                        live.append(router.submit(xs[0]))
                    except DrainingError:
                        errs.append("draining")  # rolling swap never drains
                    time.sleep(0.002)

            t = threading.Thread(target=pump, daemon=True)
            t.start()
            meta = router.swap_artifact(a1, rolling=True)
            stop.set()
            t.join(timeout=10)
            assert meta["format"] >= 2 and not errs
            outs = _results(live)
            # every in-swap request was served by exactly one of the two
            # models — zero drops, no torn outputs
            for o in outs:
                assert (np.array_equal(o, ref0[0])
                        or np.array_equal(o, ref1[0]))
            np.testing.assert_array_equal(
                np.stack(_results(_submit_all(router, list(xs)))), ref1
            )
            assert router.stats()["failed"] == 0
            assert router.stats()["swaps"] == 1
        finally:
            router.close()

    def test_swap_validates_before_touching_replicas(self, tmp_path):
        d0, _, a0, _ = self._two_artifacts(tmp_path)
        router = FleetRouter.from_artifact(a0, replicas=1, buckets=(1, 4))
        try:
            bad = tmp_path / "nonsense"
            bad.mkdir()
            with pytest.raises(FileNotFoundError):
                router.swap_artifact(bad)
            # fleet still serves the old model untouched
            x = _digits(1)[0]
            ref = InferenceEngine(d0, buckets=(1,)).infer(x[None])[0]
            np.testing.assert_array_equal(
                router.submit(x).result(timeout=30), ref
            )
        finally:
            router.close()

    def test_swap_requires_build_factories(self, tmp_path):
        d0, _, a0, _ = self._two_artifacts(tmp_path)
        router = FleetRouter([FakeEngine()], validate=False)
        try:
            with pytest.raises(RuntimeError, match="build factory"):
                router.swap_artifact(a0)
        finally:
            router.close()

    def test_nonrolling_swap_drains_then_resumes(self, tmp_path):
        _, d1, a0, a1 = self._two_artifacts(tmp_path)
        router = FleetRouter.from_artifact(a0, replicas=1, buckets=(1, 4))
        try:
            router.swap_artifact(a1, rolling=False)
            assert not router.draining  # admission reopened
            x = _digits(1)[0]
            ref = InferenceEngine(d1, buckets=(1,)).infer(x[None])[0]
            np.testing.assert_array_equal(
                router.submit(x).result(timeout=30), ref
            )
        finally:
            router.close()


# --------------------------------------------------------------------------
# Artifact pre-validation (satellite: serve_donn --artifact)
# --------------------------------------------------------------------------
class TestValidateArtifact:
    def test_good_artifact_passes(self, tmp_path):
        model, params = _model()
        save_deployed(freeze(model, params), tmp_path)
        meta = validate_artifact(tmp_path)
        assert meta["family"] == "cls"

    def test_missing_dir_and_manifest(self, tmp_path):
        with pytest.raises(FileNotFoundError):
            validate_artifact(tmp_path / "nope")
        (tmp_path / "empty").mkdir()
        with pytest.raises(FileNotFoundError):
            validate_artifact(tmp_path / "empty")

    def test_unknown_format_rejected(self, tmp_path):
        import json

        model, params = _model()
        save_deployed(freeze(model, params), tmp_path)
        mpath = tmp_path / ARTIFACT_FILE
        meta = json.loads(mpath.read_text())
        meta["format"] = 99
        mpath.write_text(json.dumps(meta))
        with pytest.raises(ValueError, match="format"):
            validate_artifact(tmp_path)

    def test_broken_spec_rejected(self, tmp_path):
        import json

        model, params = _model()
        save_deployed(freeze(model, params), tmp_path)
        mpath = tmp_path / ARTIFACT_FILE
        meta = json.loads(mpath.read_text())
        meta["spec"]["n"] = -4
        mpath.write_text(json.dumps(meta))
        with pytest.raises(ValueError):
            validate_artifact(tmp_path)


# --------------------------------------------------------------------------
# Supervisor restart backoff (satellite)
# --------------------------------------------------------------------------
class TestSupervisorBackoff:
    def test_backoff_schedule_exponential_capped(self):
        sup = EngineSupervisor("/nonexistent", backoff_base_ms=10.0,
                               backoff_max_ms=40.0, backoff_jitter=0.0,
                               seed=0)
        waits = [sup.restart_backoff_s(a) for a in (1, 2, 3, 4, 5)]
        assert waits == [0.01, 0.02, 0.04, 0.04, 0.04]
        jittered = EngineSupervisor("/nonexistent", backoff_base_ms=10.0,
                                    backoff_jitter=0.5, seed=0)
        w = jittered.restart_backoff_s(1)
        assert 0.01 <= w <= 0.015

    def test_restart_records_history(self, tmp_path):
        model, params = _model()
        save_deployed(freeze(model, params), tmp_path)
        engines = []

        def factory(deployed):
            eng = FlakyEngine(InferenceEngine(deployed, buckets=(1,)))
            engines.append(eng)
            return eng

        sup = EngineSupervisor(tmp_path, engine_factory=factory,
                               max_restarts=2, backoff_base_ms=1.0,
                               seed=0).start()
        engines[-1].kill()
        sup.infer(_digits(1)[0])  # restart + retry succeeds
        hist = sup.stats()["restart_history"]
        assert len(hist) == 1
        assert hist[0]["attempt"] == 1
        assert hist[0]["backoff_s"] >= 0.001
        assert hist[0]["rebuild_s"] > 0


# --------------------------------------------------------------------------
# Fault injectors (satellite: CrashingEngine / kill_replica)
# --------------------------------------------------------------------------
class TestCrashInjectors:
    def test_crashing_engine_dies_after_k_and_stays_dead(self):
        eng = CrashingEngine(FakeEngine(), crash_after=2)
        x = np.zeros((1, 4, 4), np.float32)
        eng.infer(x)
        eng.infer(x)
        with pytest.raises(RuntimeError):
            eng.infer(x)
        with pytest.raises(RuntimeError):
            eng.infer(x)  # permanently down, unlike FlakyEngine

    def test_crash_on_drain_arms_lazily(self):
        eng = CrashingEngine(FakeEngine(), crash_after=1,
                             crash_on_drain=True)
        x = np.zeros((1, 4, 4), np.float32)
        for _ in range(5):
            eng.infer(x)  # unarmed: unlimited calls
        eng.arm()
        eng.infer(x)
        with pytest.raises(RuntimeError):
            eng.infer(x)

    def test_kill_replica_picks_first_killable(self):
        killable = FlakyEngine(FakeEngine())
        router = FleetRouter([FakeEngine(), killable], validate=False)
        try:
            assert kill_replica(router) is killable
            with pytest.raises(ValueError):
                kill_replica(router)  # no live killable replica left
        finally:
            router.close()
