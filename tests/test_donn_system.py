"""End-to-end DONN behaviour: training works, advanced archs, DSL, codesign."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.core.dsl as lr
from repro.core import DONNConfig, build_model
from repro.core import codesign as cd
from repro.core.baselines import LightPipesLikeEngine
from repro.core.diffraction import Grid
from repro.core.regularization import calibrate_gamma
from repro.core.train_utils import evaluate_classifier, train_classifier
from repro.data import batch_iterator, synth_digits, synth_rgb_scenes, synth_seg

TINY = dict(n=64, depth=2, distance=0.05, det_size=8)


class TestDONNTraining:
    def test_training_improves_accuracy(self):
        cfg = DONNConfig(name="t", **TINY)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        xs, ys = synth_digits(512, seed=0)
        it = batch_iterator(xs, ys, 64, seed=1)
        acc0 = evaluate_classifier(model, params, batch_iterator(xs, ys, 64), 4)
        res = train_classifier(model, params, it, steps=60, lr=0.3)
        acc1 = evaluate_classifier(model, res.params,
                                   batch_iterator(xs, ys, 64), 4)
        assert acc1 > acc0 + 0.15, f"{acc0} -> {acc1}"

    def test_pallas_path_equals_jnp_path(self):
        cfg = DONNConfig(name="t", **TINY, use_pallas=True)
        cfg2 = dataclasses.replace(cfg, use_pallas=False)
        m1, m2 = build_model(cfg), build_model(cfg2)
        p = m1.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(8, seed=2)
        np.testing.assert_allclose(
            m1.apply(p, jnp.asarray(xs)), m2.apply(p, jnp.asarray(xs)),
            rtol=2e-4, atol=2e-4,
        )

    def test_gamma_calibration_hits_target_scale(self):
        """gamma rebalances detector-logit scale (inverse softmax temp)."""
        xs, _ = synth_digits(8, seed=3)
        base = build_model(DONNConfig(name="b", n=64, depth=5, distance=0.05,
                                      det_size=8))
        p = base.init(jax.random.PRNGKey(0))
        g = calibrate_gamma(base, p, jnp.asarray(xs), target_logit=2.0)
        reg = build_model(DONNConfig(name="r", n=64, depth=5, distance=0.05,
                                     det_size=8, gamma=g))
        m = float(jnp.mean(reg.apply(p, jnp.asarray(xs))))
        assert abs(m - 2.0) < 0.2

    def test_gamma_regularization_improves_shallow_accuracy(self):
        """Paper Fig 7: the D=1 DONN gains large accuracy from gamma."""
        xs, ys = synth_digits(512, seed=0)
        cfg = DONNConfig(name="g1", n=64, depth=1, distance=0.05, det_size=8)
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        g = calibrate_gamma(m, p, jnp.asarray(xs[:16]))
        m2 = build_model(dataclasses.replace(cfg, gamma=g))
        accs = {}
        for name, mm in (("base", m), ("gamma", m2)):
            res = train_classifier(mm, p, batch_iterator(xs, ys, 64, seed=1),
                                   steps=50, lr=0.5)
            accs[name] = evaluate_classifier(
                mm, res.params, batch_iterator(xs, ys, 64, seed=2), 4)
        assert accs["gamma"] > accs["base"] + 0.15, accs

    def test_prop_view_intermediate_fields(self):
        cfg = DONNConfig(name="t", **TINY)
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(2, seed=4)
        views = m.prop_view(p, jnp.asarray(xs))
        assert len(views) == cfg.depth + 2  # encode + per-layer + detector
        assert all(v.shape[-2:] == (64, 64) for v in views)


class TestAdvancedArchitectures:
    def test_multichannel_rgb_forward_and_train(self):
        cfg = DONNConfig(name="rgb", n=64, depth=2, distance=0.05, det_size=8,
                         channels=3, num_classes=6)
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        xs, ys = synth_rgb_scenes(96, seed=0)
        g = calibrate_gamma(m, p, jnp.asarray(xs[:8]))
        m = build_model(dataclasses.replace(cfg, gamma=g))
        it = batch_iterator(xs, ys, 16, seed=1)
        res = train_classifier(m, p, it, steps=30, lr=0.3, num_classes=6)
        assert res.losses[-1] < 0.5 * res.losses[0]

    def test_segmentation_with_skip(self):
        cfg = DONNConfig(name="seg", n=64, depth=3, distance=0.05,
                         segmentation=True, skip_from=0, layer_norm=True)
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        xs, ms = synth_seg(8, seed=0)
        out = m.apply(p, jnp.asarray(xs), train=True)
        assert out.shape == (8, 64, 64)
        assert bool(jnp.all(jnp.isfinite(out)))
        # skip connection adds a second optical path
        assert m.skip_hop is not None

    def test_segmentation_trains(self):
        from repro.core.train_utils import bce_segmentation_loss
        from repro.optim import AdamW

        cfg = DONNConfig(name="seg", n=64, depth=2, distance=0.05,
                         segmentation=True, skip_from=0, layer_norm=True)
        m = build_model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        xs, msk = synth_seg(64, seed=1)
        opt = AdamW(lr=0.05)
        state = opt.init(params)

        @jax.jit
        def step(params, state, i, xb, mb):
            def loss(p):
                return bce_segmentation_loss(m.apply(p, xb, train=True), mb)
            l, g = jax.value_and_grad(loss)(params)
            params, state = opt.update(g, state, params, i)
            return params, state, l

        losses = []
        for i in range(25):
            s = (i * 16) % 48
            params, state, l = step(params, state, jnp.asarray(i),
                                    jnp.asarray(xs[s:s+16]),
                                    jnp.asarray(msk[s:s+16]))
            losses.append(float(l))
        assert losses[-1] < losses[0]


class TestDSL:
    def test_sequential_builds_paper_system(self):
        src = lr.laser(wavelength=532e-9)
        layers = [lr.layers.diffractlayer(distance=0.05, pixel_size=36e-6,
                                          size=64, precision=256)
                  for _ in range(3)]
        det = lr.layers.detector(num_classes=10, det_size=8, distance=0.05)
        model, cfg = lr.models.sequential(layers, det, laser=src)
        assert cfg.depth == 3 and cfg.codesign == "qat"
        p = model.init(jax.random.PRNGKey(0))
        xs, _ = synth_digits(2, seed=0)
        assert model.apply(p, jnp.asarray(xs)).shape == (2, 10)

    def test_from_spec_json_roundtrip(self):
        spec = {
            "name": "donn-json",
            "laser": {"wavelength": 532e-9},
            "layers": [{"distance": 0.05, "pixel_size": 36e-6, "size": 64}] * 2,
            "detector": {"num_classes": 10, "det_size": 8, "distance": 0.05},
        }
        model, cfg = lr.from_spec(spec)
        assert cfg.name == "donn-json" and cfg.depth == 2

    def test_heterogeneous_distances(self):
        layers = [lr.layers.diffractlayer_raw(distance=d, size=64)
                  for d in (0.04, 0.06)]
        det = lr.layers.detector(det_size=8, distance=0.08)
        model, cfg = lr.models.sequential(layers, det)
        assert cfg.gap_distances() == (0.04, 0.06, 0.08)


class TestCodesign:
    def test_qat_quantizes_to_device_levels(self):
        dev = cd.DeviceSpec(levels=16)
        phi = jnp.asarray(np.random.default_rng(0).uniform(0, 6.28, (32, 32)),
                          jnp.float32)
        q = cd.quantize_qat(phi, dev)
        levels = dev.level_phases()
        d = np.abs(np.asarray(q)[..., None] - levels)
        assert float(d.min(-1).max()) < 1e-5

    def test_qat_straight_through_gradient(self):
        dev = cd.DeviceSpec(levels=16)
        phi = jnp.asarray([1.0, 2.0, 3.0])
        g = jax.grad(lambda p: jnp.sum(cd.quantize_qat(p, dev) ** 2))(phi)
        assert bool(jnp.all(jnp.abs(g) > 0))  # STE passes gradients

    def test_gumbel_hard_matches_ptq_at_low_tau(self):
        dev = cd.DeviceSpec(levels=8)
        phi = jnp.asarray(np.random.default_rng(1).uniform(0, 6.28, (16,)),
                          jnp.float32)
        hard = cd.quantize_gumbel(phi, dev, rng=None, tau=0.01, hard=True)
        _, ptq = cd.weight_fab(phi, dev)
        np.testing.assert_allclose(hard, ptq, atol=1e-5)

    def test_nonlinear_response_curve(self):
        dev = cd.DeviceSpec(levels=256, response_gamma=1.2)
        lv = dev.level_phases()
        assert np.all(np.diff(lv) >= 0) and lv[-1] <= 2 * np.pi + 1e-6
        mid = lv[128] / lv[-1]
        assert mid < 0.5  # gamma>1 bends the curve below linear

    def test_weight_fab_export(self):
        dev = cd.DeviceSpec(levels=256)
        phi = jnp.asarray(np.random.default_rng(2).uniform(0, 6.28, (8, 8)),
                          jnp.float32)
        img = cd.to_slm(phi, dev)
        assert img.dtype == np.uint8 and img.shape == (8, 8)
        thick = cd.to_3d_render(phi, 532e-9)
        assert thick.max() <= 532e-9 / 0.52 + 1e-9

    def test_quantized_model_trains(self):
        cfg = DONNConfig(name="q", **TINY, codesign="qat", device_levels=64)
        m = build_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        xs, ys = synth_digits(256, seed=5)
        res = train_classifier(m, p, batch_iterator(xs, ys, 32), steps=30,
                               lr=0.3)
        assert res.losses[-1] < res.losses[0]


class TestBaselineEngine:
    def test_lightpipes_like_matches_physics(self):
        """The deliberately-slow baseline must still be *correct*."""
        g = Grid(48, 36e-6)
        eng = LightPipesLikeEngine(g, 532e-9)
        r = np.random.default_rng(0)
        u = (r.normal(size=(2, 48, 48)) + 1j * r.normal(size=(2, 48, 48)))
        from repro.core.diffraction import propagate

        ours = np.asarray(propagate(jnp.asarray(u, jnp.complex64), g, 0.02,
                                    532e-9, "rs", band_limit=False))
        theirs = eng.propagate_batch(u, 0.02)
        np.testing.assert_allclose(ours, theirs.astype(np.complex64),
                                   rtol=5e-3, atol=5e-3)
