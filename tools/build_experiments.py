"""Assemble EXPERIMENTS.md from the dry-run / perf artifacts.

    PYTHONPATH=src python tools/build_experiments.py

Narrative sections are authored here; tables render from
artifacts/dryrun/*.json and artifacts/perf/*.json so the document always
matches the latest sweep.
"""
import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent
DRY = ROOT / "artifacts" / "dryrun"
PERF = ROOT / "artifacts" / "perf"


def load(d):
    out = {}
    for f in sorted(d.glob("*.json")):
        out[f.stem] = json.loads(f.read_text())
    return out


def fmt_s(x):
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def dryrun_table(recs, mesh):
    rows = [
        "| cell | kind | status | bytes/dev | fits 16G | compile |",
        "|---|---|---|---|---|---|",
    ]
    for k in sorted(recs):
        r = recs[k]
        if not k.endswith(mesh):
            continue
        cell = f"{r['arch']}/{r['shape']}"
        st = r.get("status", "?")
        if st == "ok":
            m = r["memory"]
            rows.append(
                f"| {cell} | {r['kind']} | ok | "
                f"{m['per_device_bytes']/1e9:.1f} GB | "
                f"{'yes' if m['fits_16GiB_hbm'] else 'NO'} | "
                f"{r.get('compile_wall_s', 0):.0f}s |"
            )
        else:
            short = "SKIP(full-attention)" if st.startswith("SKIP") else st[:40]
            rows.append(f"| {cell} | {r['kind']} | {short} | — | — | — |")
    return "\n".join(rows)


def roofline_table(recs):
    rows = [
        "| cell | compute | memory | collective | dominant | frac | "
        "6ND/HLO |",
        "|---|---|---|---|---|---|---|",
    ]
    for k in sorted(recs):
        r = recs[k]
        if not k.endswith("pod1") or r.get("status") != "ok":
            continue
        t = r["terms"]
        rows.append(
            f"| {r['arch']}/{r['shape']} | {fmt_s(t['compute_s'])} | "
            f"{fmt_s(t['memory_s'])} | {fmt_s(t['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['roofline_fraction']:.3g} | "
            f"{r.get('model_over_hlo_flops', 0):.2f} |"
        )
    return "\n".join(rows)


def perf_table(precs):
    rows = [
        "| cell | mesh | variant | bound | dominant | frac | mem/dev | fits |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for k in sorted(precs):
        r = precs[k]
        if "terms" not in r:
            rows.append(f"| {r.get('cell','?')} | ? | {r.get('variant','?')} "
                        f"| {r.get('status','FAIL')[:40]} | — | — | — | — |")
            continue
        rows.append(
            f"| {r['cell']} | {r['mesh']} | {r['variant']} | "
            f"{fmt_s(r['bound_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['roofline_fraction']:.3g} | "
            f"{r['memory_per_dev_GB']:.1f} GB | "
            f"{'yes' if r['fits_16GiB'] else 'NO'} |"
        )
    return "\n".join(rows)


def main():
    recs = load(DRY)
    precs = load(PERF)
    n_ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    n_skip = sum(1 for r in recs.values()
                 if str(r.get("status", "")).startswith("SKIP"))
    n_fail = len(recs) - n_ok - n_skip

    doc = TEMPLATE.format(
        n_cells=len(recs), n_ok=n_ok, n_skip=n_skip, n_fail=n_fail,
        pod1_table=dryrun_table(recs, "pod1"),
        pod2_table=dryrun_table(recs, "pod2"),
        roofline=roofline_table(recs),
        perf=perf_table(precs),
    )
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"EXPERIMENTS.md written ({n_ok} ok / {n_skip} skip / {n_fail} fail)")


TEMPLATE = """\
# EXPERIMENTS

All numbers in this file regenerate from `artifacts/` via
`python tools/build_experiments.py`.  Hardware model: TPU v5e — 197 TFLOP/s
bf16/chip, 819 GB/s HBM, ~50 GB/s/link ICI; single pod = 16x16 (data,
model) = 256 chips, multi-pod = (2,16,16) = 512 chips.

## §Paper-claims validation (benchmarks, see bench_output.txt)

Reproduced against the paper's own experiments on the offline procedural
datasets (DESIGN.md §6; accuracy claims are *relative*: our method vs the
reproduced [34,67]-style baseline under identical data):

| paper artifact | claim | our result |
|---|---|---|
| Fig 7 (gamma reg.) | +31% acc at depth 1; deep DONNs match regardless of depth | +41 pts at depth 1 (0.37->0.78); +62/+59 pts at depths 3/5 (both ~0.99 with gamma); confirms both claims |
| Fig 8 (runtime) | up to 6.4x CPU vs LightPipes | 4.9-8.1x vs the reproduced per-sample eager baseline across sizes 64-256 and depths 1-5 (jit+batch+cached TF) — same magnitude class |
| Fig 9 (breakdown) | FFT2 11x / iFFT2 10x / MM 4x | FFT2 5.0x / iFFT2 4.9x / ComplexMM 52x (batched c64+jit vs per-sample c128; the Pallas ComplexMM row is interpret-mode on CPU — TPU-only wall-clock) |
| Fig 10 (scaling) | runtime ~linear in depth | linear fit R^2 = 0.9996 over depths 5-30 |
| Fig 5 (DSE) | ~60x fewer emulations | 12.5x on the reduced 5x5 grid (25 -> 2 verifications), best point recovered within 0.05 acc |
| Table 3 | unit size most sensitive | largest acc drop under +-10% perturbation is unit_size (`table3/*`) |
| Table 4 | DONN ~995 fps/W, ~2 orders over CPU | analytical DONN model 995 fps/W vs measured CPU MLP/CNN fps/W (`table4/*`) |
| Table 5 (RGB) | +29% top-1 vs single-channel | +0.81 top-1 (0.19->1.00) vs gray-scaled single-channel baseline on the procedural RGB set |
| Fig 13 (segmentation) | skip+LN improves masks | IoU 0.12 -> 0.36 (+0.24) with optical skip + train-time LN |

## §Dry-run

{n_cells} compiled cells: {n_ok} ok, {n_skip} documented skips
(long_500k on pure full-attention archs — DESIGN.md §5), {n_fail} failures.
Every cell is `jax.jit(step).lower(...).compile()` on the production mesh
with ShapeDtypeStruct inputs (no allocation); `memory_analysis()` per-device
bytes and the collective schedule feed §Roofline.

Memory-feasibility overrides (recorded per-artifact under `overrides`):
microbatched gradient accumulation for mixtral/llama-vision/arctic/
recurrentgemma train cells, bf16 params+moments for arctic on the single
pod, bf16 serving params for arctic prefill (`dryrun.OVERRIDES` /
`PREFILL_OVERRIDES`).  The multi-pod mesh shards optimizer state across
pods too (ZeRO-style, rule `embed -> ("data","pod")`).

**Capacity statements** (cells that exceed 16 GiB/chip even after
overrides — reported, not hidden): `arctic-480b` train (32.6 GB pod1 /
29.2 GB pod2 — exact-f32 expert transients at batch 256x4096 need more
chips or int8 expert compute) and `arctic-480b` prefill (32x32k tokens in
one shot; production serving splits the batch across prefill passes, which
the continuous-batching server in `launch/serve.py` does naturally).
Every other of the 78 compiled cells fits v5e HBM.

### single pod (16x16 = 256 chips)

{pod1_table}

### multi-pod (2x16x16 = 512 chips)

{pod2_table}

## §Roofline (single-pod; per-device terms from the compiled HLO)

Method: FLOPs / HBM bytes / collective bytes are re-derived from
`compiled.as_text()` with **while-loop trip counting** (XLA's own
`cost_analysis()` counts scan bodies once — `runtime/hlo_analysis.py`,
validated against XLA on loop-free programs and against hand-computed
scans in `tests/test_hlo_analysis.py`).  Byte model: fusion-boundary
accounting, in-place dynamic-slice/update windows, dtype-cast traffic
excluded (native-bf16 on TPU; XLA:CPU materializes converts).
Collective bytes use ring-transfer factors ((g-1)/g etc.).
`frac` = MODEL_FLOPS(6ND or 6N_active*D; 2ND prefill; 2N*B decode) /
(chips * peak * bound).  `6ND/HLO` = MODEL_FLOPS / (HLO FLOPs * chips):
< 1 from remat recompute (+1/3), attention, MoE dispatch einsums, and
dead-padding; decode/prefill cells are bandwidth-bound by nature, so their
compute fraction is structurally tiny — the bound (dominant term) is the
score that matters there.

{roofline}

**The microbatching/collective trade** (visible in the table): gradient
accumulation divides activation memory by `accum` but multiplies per-step
FSDP/SP gather traffic by it — llama-vision train pod1 (accum 8, fits at
15.6 GB) pays a 54s collective term, while its pod2 row (twice the chips,
accum 2) is 4x cheaper on collectives.  At fleet scale the right fix is
more chips, not more microbatches; the overrides pick the fit-on-256
point and the pod2 rows show the scaled-out point.

Per-cell bottleneck notes (what would move the dominant term):
- dense train (glm4/granite/qwen*): memory-bound — dominated by FSDP f32
  weight re-gathers across fwd/remat/bwd and attention score traffic;
  bf16 gathers (§Perf glm4) cut both.
- moe train: memory/collective from expert weight movement; resident
  EP-sharded experts + d-sharded dispatched activations (apply_moe
  constraints) moved arctic collective 59s -> 21s.
- decode cells: cache-bandwidth-bound (reading the KV/state cache once per
  token is the floor); collective term is the Dh-sharded score all-reduce.
- ssm/hybrid: sequential-scan elementwise traffic dominates — the jnp
  path materializes per-chunk discretization tensors.  A Pallas
  selective-scan forward kernel now covers the inference path (private
  VMEM state per d_inner block; `kernels/selective_scan.py`, validated vs
  the chunked-scan oracle); the fused backward remains backlog.
- donn cells: FFT arithmetic intensity is low — after the shard_map fix
  (§Perf) they are HBM-bound at the FFT's natural intensity.

## §Perf — hillclimb log (3 cells)

Cells chosen per the brief: `donn-xl-500/train_b256` (paper-representative),
`arctic-480b/train_4k` (worst fraction + most collective-bound),
`glm4-9b/train_4k` (representative dense train).  The paper-faithful
baseline (its single-device emulation semantics, auto-sharded) is recorded
first; beyond-paper optimized variants are separate rows.

{perf}

### Iteration log (hypothesis -> change -> before -> after -> verdict)

**donn-xl-500/train_b256** (the paper's large-scale emulation workload,
Fig 10, distributed — beyond the paper's single-GPU scope):
1. H: collective term 1.24s for a 30MB-parameter model means GSPMD is
   moving *fields*, not gradients. Attribution: `all-gather
   c64[256,500,500]` at every `fft` — GSPMD cannot partition the FFT HLO
   even over batch dims, so the auto-sharded step gathers the global batch
   per FFT2/iFFT2 (62 GB/step/device).
   C: shard_map DP — each device runs the whole optical step on its local
   batch shard (local FFTs); only phase-gradients psum.
   B: bound 1.244s (collective), 16.5 GB/dev, frac ~0.
   A: bound 0.002s (memory), 0.2 GB/dev — **~620x**; dominant term is now
   the FFT's own HBM traffic (low arithmetic intensity — honest floor).
   VERDICT: confirmed. The paper's "multi-GPU support" future-work item is
   exactly this: never let the partitioner touch the FFT.
2. H: remaining memory term is c64 field traffic; bf16 split-plane fields
   would halve it but break the physics oracle tolerances (complex64 is
   the paper's precision). Not taken — recorded as a rejected option.

**arctic-480b/train_4k**:
1. H: 1.7TB/step of all-gathers traced to the vocab-sharded embedding
   table + FSDP-sharded unembed being re-gathered *inside the xent chunk
   scan* (and per microbatch).
   C: embed table sharded on embed-dim only (gather-free token lookup);
   unembed resident vocab-sharded (local TP matmul + small logsumexp AR).
   B: collective 59.5s -> A: 21.5s. VERDICT: confirmed (helps every arch).
2. H: FSDP-gathering 1.67 GB/layer of expert weights per microbatch is the
   remaining collective; with experts resident (EP on model axis) and
   *dispatched activations* d-sharded, expert matmuls become local
   partials + ~200MB ARs.
   C: sharding constraints on dispatch/xd/h/u/eo in `apply_moe`.
   B: collective 59.5 -> A: 21.5 combined with (1); frac 0.011 -> 0.071.
   VERDICT: confirmed.
3. H: optimizer f32 working copies of 100B-leaf tensors dominate temps.
   C: blocked in-place fori_loop update (<=32 axis-0 blocks). A scan-based
   first attempt REGRESSED (+15GB: scan xs/ys double-buffers the stacked
   tensor) — kept the hypothesis, fixed the mechanism (carry + dynamic
   update, like the decode cache).  B: 50.6 (scan attempt) -> A: 32.6GB;
   memory term 36.5 -> 27.5s, frac 0.011 -> 0.071 (6.7x vs the session
   start).  VERDICT: confirmed after the fori re-implementation; the scan
   attempt is the recorded refutation.
4. C: capacity_factor 1.25 -> 1.0: bound 27.5 -> 25.8s (-6%, frac 0.075);
   moe_group 2048: no further change (dispatch tensors were not the
   bottleneck — refuted); accum 8 -> 16: bound WORSE (31.4s): halving
   activations doubles per-step FSDP gathers — refuted, kept accum 8.
5. Generalization guard: the EP-resident constraints are all-or-nothing
   (`require="expert"`): applied unconditionally they destroyed mixtral's
   f-TP layout (15.5 -> 55.8GB) because E=8 < TP=16 maps partially —
   recorded refutation; mixtral restored to 15.7GB after gating.
6. Remaining: per-device 32.6GB even with bf16 params+moments+accum — the
   transient expert activations (f32 partial-sum buffers) at batch
   256x4096 are the floor on 256 chips.  Arctic train wants >=512 chips
   (pod2 row: ZeRO-across-pods) or int8 expert compute — recorded as a
   capacity statement, not hidden.

**glm4-9b/train_4k**:
1. H: scan-over-layers saves model-axis-replicated activations
   (40 x 537MB/dev) — sequence-parallelism shards them 16x.
   C: `_seq_shard` constraint on the residual stream at layer boundaries
   (Megatron-SP; GSPMD inserts the AG/RS pair).
   B: 98.6 GB/dev (doesn't fit), memory 114s -> A: 7.9 GB/dev, memory
   7.3s, frac 0.010 -> 0.161. VERDICT: confirmed — the single biggest win.
2. H: byte term inflated by XLA:CPU materializing bf16<->f32 casts that
   TPU does natively in the MXU path.
   C: analyzer excludes pure-cast traffic (documented assumption).
   VERDICT: confirmed (CPU-lowering artifact, not model traffic).
3. H: FSDP gathers move f32 masters; casting params to bf16 *before* the
   forward halves weight-gather collective + memory traffic.
   C: `cast_params_to=bf16` step option (grads still flow to f32 masters).
   B: 7.399s -> A: 7.396s. VERDICT: REFUTED as a memory lever — byte
   attribution shows the memory term is ~40% attention-probability (p)
   round-trips (f32 (B,KV,G,Sq/16,chunk) blocks, ~250GB each x fwd/
   remat/bwd x 40 layers), not weight gathers.  Kept anyway (it halves
   the *collective* weight-gather bytes).
4. H: larger attention KV chunks amortize the online-softmax scan carries.
   C: attn_chunk 1024 -> 2048: bound 7.40 -> 7.19 (frac 0.163), mem
   8.9 -> 11.4GB (still fits). attn_chunk 4096: bound 14.6s — REFUTED
   hard (single-chunk attention materializes full f32 scores).
5. H: storing p in bf16 for the PV matmul halves the dominant p-traffic
   (predicted ~-20% memory term).
   C: `attn_p_bf16` knob. A: 7.18s alone / 6.98s with chunk2048 (-5.7%
   total, frac 0.168). VERDICT: direction confirmed, magnitude refuted:
   the f32 p still crosses a fusion boundary before the cast.  The full
   win — keeping p resident in VMEM — needs a fused (Pallas) flash
   attention kernel: modeled effect is memory_s 7.4 -> ~4.5s (frac ~0.26),
   recorded as the top backlog item since a Mosaic kernel's traffic cannot
   be validated through CPU-interpret HLO.
   Stopping rule: last three changes gave <5% each on the dominant term.

### Analyzer fixes that changed earlier numbers (recorded refutations)
- XLA `cost_analysis()` does not trip-count while loops: all scan-heavy
  cells under-reported ~n_layers x until `hlo_analysis` landed.
- A max-constant trip-count heuristic over-counted XLA "wide" loop bounds
  by ~30x on glm4 (memory 7.3s misread as 243s) — fixed by reading the
  constant operand of the root compare.
- `dynamic-update-slice` inside fusions must be charged at window size
  (in-place on TPU), or decode memory reads 10x too high.

## §Multi-pod notes
- pod2 cells compile with the "pod" axis sharding batch (DP) and optimizer
  state (ZeRO); arctic/mixtral per-device memory drops accordingly
  (tables above).
- Cross-pod gradient traffic is 4x-compressible with the int8
  error-feedback path (`optim/compression.py`, convergence-tested); wired
  into the shard_map pod-axis reduction demo in tests/test_distributed.py.
- Elasticity: checkpoints restore onto different meshes
  (tests/test_distributed.py::test_elastic_checkpoint_reshard); training
  survives SIGTERM/kill and resumes bit-continuously
  (tests/test_launchers.py).
"""


if __name__ == "__main__":
    main()
