"""Finding reporters: human-readable lines and machine-readable JSON."""
from __future__ import annotations

import json
import sys
from collections import Counter
from typing import Sequence

from lightlint.core import Finding


def human(findings: Sequence[Finding], stream=None) -> None:
    stream = stream or sys.stdout
    for f in findings:
        stream.write(f.format() + "\n")
    by_sev = Counter(f.severity for f in findings)
    if findings:
        parts = ", ".join(f"{n} {sev}{'s' if n != 1 else ''}"
                          for sev, n in sorted(by_sev.items()))
        stream.write(f"lightlint: {len(findings)} finding"
                     f"{'s' if len(findings) != 1 else ''} ({parts})\n")
    else:
        stream.write("lightlint: clean\n")


def json_report(findings: Sequence[Finding], stream=None) -> None:
    stream = stream or sys.stdout
    json.dump([f.to_dict() for f in findings], stream, indent=2)
    stream.write("\n")
