#!/usr/bin/env python
"""lightlint CLI.

    python tools/lightlint/cli.py src tools benchmarks examples
    python tools/lightlint/cli.py --format json src
    python tools/lightlint/cli.py --select LR104,LR201 benchmarks

Exit status: 0 when clean, 1 when any unsuppressed finding remains,
2 on usage errors.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

_REPO = pathlib.Path(__file__).resolve().parents[2]
for _p in (_REPO / "tools", _REPO / "src"):
    if _p.is_dir() and str(_p) not in sys.path:
        sys.path.insert(0, str(_p))

from lightlint import lint_paths, reporters  # noqa: E402
from lightlint.rules import default_rules, rules_by_id  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="lightlint",
        description="JAX-aware static analysis + physics spec validation",
    )
    ap.add_argument("paths", nargs="+", help="files or directories to lint")
    ap.add_argument("--format", choices=("human", "json"), default="human")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule ids (default: all)")
    ap.add_argument("--root", default=None,
                    help="project root for cross-file rules "
                         "(default: current directory)")
    args = ap.parse_args(argv)

    rules = default_rules()
    if args.select:
        rules = rules_by_id(r.strip() for r in args.select.split(","))
        if not rules:
            ap.error(f"no rules match --select {args.select!r}")
    missing = [p for p in args.paths if not pathlib.Path(p).exists()]
    if missing:
        ap.error(f"no such path: {', '.join(missing)}")

    findings = lint_paths(args.paths, root=args.root, rules=rules)
    if args.format == "json":
        reporters.json_report(findings)
    else:
        reporters.human(findings)
    return 1 if findings else 0


if __name__ == "__main__":
    raise SystemExit(main())
