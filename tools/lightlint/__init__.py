"""lightlint — JAX-aware static analysis + physics spec validation.

Repo-specific lint layer on top of ``ruff``: the generic style rules live
in ``pyproject.toml`` / ruff; lightlint carries only the rules that need
to understand this codebase (cache-key completeness, donation aliasing,
host syncs in hot paths, recompile hazards, bf16 accumulation
discipline) and the physics-validity criteria shared with build time
(``repro.core.physics``).

Run it:

    python tools/lightlint/cli.py src tools benchmarks examples

Suppress a finding:

    fwd = jax.jit(f)  # lightlint: disable=LR104 -- measured baseline

Add a rule: subclass ``lightlint.core.Rule``, implement
``visit(tree, ctx)`` (per-file) or ``finalize(project)`` (whole-tree),
register it in ``lightlint.rules.ALL_RULES`` and add a fixture pair
under ``tests/lightlint_fixtures/``.
"""
from lightlint.core import (  # noqa: F401
    Finding,
    FileContext,
    Project,
    Rule,
    lint_paths,
)
from lightlint.rules import ALL_RULES, default_rules  # noqa: F401
