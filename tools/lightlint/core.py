"""lightlint engine: findings, suppressions, rule protocol, runner."""
from __future__ import annotations

import ast
import dataclasses
import os
import pathlib
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

ERROR = "error"
WARNING = "warning"

# trailing `# lightlint: disable=LR104` silences that line;
# `# lightlint: disable-file=LR104` anywhere silences the whole file.
# An optional ` -- rationale` tail documents why.
_SUPPRESS_RE = re.compile(
    r"#\s*lightlint:\s*disable(?P<file>-file)?\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_*]+(?:\s*,\s*[A-Za-z0-9_*]+)*)"
)


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation at a file location."""

    path: str  # repo-relative where possible
    line: int
    rule: str  # e.g. "LR104"
    severity: str  # "error" | "warning"
    message: str

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.severity}] {self.message}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def parse_suppressions(source: str) -> Tuple[Dict[int, Set[str]], Set[str]]:
    """(line -> rule-ids, file-level rule-ids) from suppression comments."""
    per_line: Dict[int, Set[str]] = {}
    per_file: Set[str] = set()
    for i, line in enumerate(source.splitlines(), 1):
        m = _SUPPRESS_RE.search(line)
        if m is None:
            continue
        rules = {r.strip() for r in m.group("rules").split(",") if r.strip()}
        if m.group("file"):
            per_file |= rules
        else:
            per_line.setdefault(i, set()).update(rules)
    return per_line, per_file


class FileContext:
    """One parsed source file handed to per-file rules."""

    def __init__(self, path: os.PathLike, source: str,
                 root: Optional[os.PathLike] = None):
        self.path = str(path)
        self.root = str(root) if root is not None else None
        try:
            rel = os.path.relpath(self.path, self.root or os.getcwd())
        except ValueError:  # different drive (windows)
            rel = self.path
        self.rel = rel if not rel.startswith("..") else self.path
        self.source = source
        self.lines = source.splitlines()
        self.line_suppressions, self.file_suppressions = parse_suppressions(
            source
        )

    def finding(self, rule: "Rule", node_or_line, message: str,
                severity: Optional[str] = None) -> Finding:
        line = getattr(node_or_line, "lineno", node_or_line)
        return Finding(self.rel, int(line), rule.rule_id,
                       severity or rule.severity, message)

    def suppressed(self, finding: Finding) -> bool:
        ids = {finding.rule, "*"}
        if ids & self.file_suppressions:
            return True
        return bool(ids & self.line_suppressions.get(finding.line, set()))


class Project:
    """Whole-tree view handed to project-scope rules after the file pass."""

    def __init__(self, root: os.PathLike, contexts: Sequence[FileContext],
                 json_files: Sequence[os.PathLike] = ()):
        self.root = pathlib.Path(root)
        self.contexts = list(contexts)
        self.json_files = [pathlib.Path(p) for p in json_files]
        self._by_rel = {c.rel.replace(os.sep, "/"): c for c in self.contexts}

    def context_for(self, rel: str) -> Optional[FileContext]:
        """Context for a repo-relative path ('src/repro/core/config.py')."""
        return self._by_rel.get(rel)

    def tree_for(self, rel: str) -> Optional[ast.AST]:
        ctx = self.context_for(rel)
        if ctx is None:
            return None
        try:
            return ast.parse(ctx.source, filename=ctx.path)
        except SyntaxError:
            return None


class Rule:
    """Base rule: implement ``visit`` (per file), ``finalize`` (per tree).

    ``visit(tree, ctx)`` receives the parsed ``ast`` module and the
    :class:`FileContext`; return an iterable of findings (use
    ``ctx.finding(self, node, msg)``).  ``finalize(project)`` runs once
    after every file was visited — for rules that need to correlate
    several files (e.g. LR101 cache-key completeness).
    """

    rule_id = "LR000"
    title = ""
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        return ()

    def finalize(self, project: Project) -> Iterable[Finding]:
        return ()


_SKIP_DIRS = {"__pycache__", ".git", ".ruff_cache", ".pytest_cache",
              "node_modules", ".venv", "venv"}


def discover(paths: Sequence[os.PathLike]):
    """(.py files, .json files) under the given files/directories."""
    py: List[pathlib.Path] = []
    js: List[pathlib.Path] = []
    for p in paths:
        p = pathlib.Path(p)
        if p.is_file():
            (py if p.suffix == ".py" else js if p.suffix == ".json"
             else []).append(p)
            continue
        for dirpath, dirnames, filenames in os.walk(p):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS
                                 and not d.startswith("."))
            for f in sorted(filenames):
                if f.endswith(".py"):
                    py.append(pathlib.Path(dirpath) / f)
                elif f.endswith(".json"):
                    js.append(pathlib.Path(dirpath) / f)
    return py, js


def lint_paths(paths: Sequence[os.PathLike],
               root: Optional[os.PathLike] = None,
               rules: Optional[Sequence[Rule]] = None) -> List[Finding]:
    """Run every rule over the given paths; suppressed findings dropped."""
    if rules is None:
        from lightlint.rules import default_rules

        rules = default_rules()
    root = pathlib.Path(root) if root is not None else pathlib.Path.cwd()
    py_files, json_files = discover(paths)
    contexts: List[FileContext] = []
    findings: List[Finding] = []
    for f in py_files:
        try:
            source = f.read_text()
        except (OSError, UnicodeDecodeError) as e:
            findings.append(Finding(str(f), 1, "LR000", ERROR,
                                    f"unreadable source: {e}"))
            continue
        ctx = FileContext(f, source, root)
        try:
            tree = ast.parse(source, filename=str(f))
        except SyntaxError as e:
            findings.append(Finding(ctx.rel, e.lineno or 1, "LR000", ERROR,
                                    f"syntax error: {e.msg}"))
            continue
        contexts.append(ctx)
        for rule in rules:
            for fd in rule.visit(tree, ctx):
                if not ctx.suppressed(fd):
                    findings.append(fd)
    project = Project(root, contexts, json_files)
    for rule in rules:
        for fd in rule.finalize(project):
            ctx = project.context_for(fd.path.replace(os.sep, "/"))
            if ctx is None or not ctx.suppressed(fd):
                findings.append(fd)
    return sorted(findings)
