"""Sharding-layout rules (LR109+).

One rules table (``repro/runtime/sharding.py``) owns the mapping from
logical axis names to mesh axes; everything else asks it.  Hand-built
``PartitionSpec`` literals and ad-hoc mesh constructions scattered
through runtime/bench code are how the pre-PR-10 tree grew two disjoint
parallel paths (row-sharded training vs batch-sharded serving) with
silently different axis-name spellings — the class of drift this rule
pins down.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from lightlint.core import ERROR, FileContext, Finding, Rule
from lightlint.rules.jax_rules import call_name

# the one rules table and the shims that exist to *define* mesh/spec
# construction (everything else routes through sharding.* helpers)
_ALLOWED_SUFFIXES = (
    "repro/runtime/sharding.py",
    "repro/launch/mesh.py",
    "repro/compat.py",
    "repro/nn/module.py",
)


class AdHocPartitionSpec(Rule):
    """LR109: raw ``PartitionSpec``/mesh construction outside the rules table.

    Flags, outside ``repro/runtime/sharding.py`` (and the compat/mesh
    shims that implement it):

    - ``PartitionSpec(...)`` construction — including ``P(...)`` via a
      ``from jax.sharding import PartitionSpec as P`` alias and dotted
      ``jax.sharding.PartitionSpec(...)`` — which hard-codes mesh-axis
      strings the rules table should resolve
      (``sharding.rules_pspec`` / ``resolve_pspec`` / ``dim0_pspec``);
    - ``Mesh(...)`` / ``make_mesh(...)`` ad-hoc mesh construction —
      axis names spelled per call site; use ``sharding.make_mesh_2d``.
    """

    rule_id = "LR109"
    title = "ad-hoc PartitionSpec/mesh construction outside runtime/sharding"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        rel = ctx.rel.replace("\\", "/")
        if any(rel.endswith(sfx) for sfx in _ALLOWED_SUFFIXES):
            return []
        # names locally bound to the flagged constructors via imports
        aliases = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.ImportFrom) and node.module and (
                    node.module == "jax.sharding"
                    or node.module.endswith(".sharding")):
                for a in node.names:
                    if a.name in ("PartitionSpec", "Mesh"):
                        aliases[a.asname or a.name] = a.name
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node) or ""
            head, tail = name.split(".")[0], name.split(".")[-1]
            if head in aliases:
                kind = aliases[head]
            elif tail in ("PartitionSpec", "Mesh") and "." in name:
                kind = tail
            elif tail == "make_mesh":
                kind = "make_mesh"
            else:
                continue
            fix = ("sharding.make_mesh_2d + the donn_rules table"
                   if kind in ("Mesh", "make_mesh") else
                   "sharding.rules_pspec/resolve_pspec/dim0_pspec")
            out.append(ctx.finding(
                self, node,
                f"ad-hoc {kind}(...) hard-codes mesh-axis layout outside "
                f"the rules table; route through {fix}",
            ))
        return out
