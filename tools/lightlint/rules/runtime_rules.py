"""Runtime-robustness rules (LR108+).

Serving-loop hazards rather than JAX-correctness ones: the fleet /
supervisor layer (``repro.runtime``) retries failed work by contract
with a bounded budget and exponential backoff, and a bare ``while True``
that swallows exceptions undoes both — a dead replica turns into a
busy-spin that pins a core and retries a poisoned request forever.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from lightlint.core import ERROR, FileContext, Finding, Rule
from lightlint.rules.jax_rules import call_name


def _is_true_const(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is True


def _walk_no_defs(node):
    """Walk without descending into nested function/class definitions."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef, ast.Lambda)):
            stack.extend(ast.iter_child_nodes(n))


# a call whose name carries one of these is treated as pacing/backoff:
# time.sleep, self._backoff_and_requeue, cv.wait / wait_for, ...
_PACING_MARKERS = ("sleep", "backoff", "wait")


def _has_pacing_call(node) -> bool:
    for n in _walk_no_defs(node):
        if isinstance(n, ast.Call):
            tail = (call_name(n) or "").split(".")[-1].lower()
            if any(m in tail for m in _PACING_MARKERS):
                return True
    return False


def _handler_swallows(handler: ast.ExceptHandler) -> bool:
    """True when the except body neither re-raises nor exits the loop."""
    for n in handler.body:
        for m in [n, *_walk_no_defs(n)]:
            if isinstance(m, (ast.Raise, ast.Break, ast.Return)):
                return False
    return True


class UnboundedRetryLoop(Rule):
    """LR108: ``while True`` retry loop without a budget or backoff.

    A ``while True:`` loop whose ``try/except`` swallows the failure
    (no ``raise``/``break``/``return`` in the handler) and whose body
    never paces itself (no ``sleep``/``backoff``/``wait``-named call in
    the loop) retries a persistent failure as fast as the CPU allows:
    a crashed replica becomes a busy-spin, a poisoned request is
    redispatched forever, and the error budget the serving contract
    promises (``max_retries`` + exponential backoff with jitter, see
    ``runtime/fleet.py``) silently never engages.  Either bound the
    attempts and re-raise on exhaustion, or route the failure through a
    backoff helper (a call with ``sleep``/``backoff``/``wait`` in its
    name satisfies the rule).
    """

    rule_id = "LR108"
    title = "unbounded while-True retry loop"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not (isinstance(node, ast.While)
                    and _is_true_const(node.test)):
                continue
            if _has_pacing_call(node):
                continue
            for n in _walk_no_defs(node):
                if not isinstance(n, ast.Try):
                    continue
                swallowing = [h for h in n.handlers if _handler_swallows(h)]
                if swallowing:
                    out.append(ctx.finding(
                        self, swallowing[0],
                        "while True retries swallowed failures with no "
                        "attempt budget or backoff — a persistent fault "
                        "busy-spins forever; bound the retries (re-raise "
                        "on exhaustion) or pace them (sleep/backoff)",
                    ))
                    break
        return out
