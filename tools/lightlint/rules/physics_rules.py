"""Physics-validity rules (LR201-LR202).

Both delegate to ``repro.core.physics.validate_config`` — the same
validator ``plan_from_config`` and ``dsl.from_spec`` run at build time —
so lint-time and runtime criteria cannot drift.
"""
from __future__ import annotations

import ast
import json
import os
from typing import Iterable, List

from lightlint.core import ERROR, FileContext, Finding, Project, Rule


def _import_repro():
    """(config module, physics module) or None when repro is unavailable."""
    try:
        from repro.core import config as cfg_mod
        from repro.core import physics
    except Exception:
        return None
    return cfg_mod, physics


class _Unevaluable(Exception):
    pass


def _literal(node, cfg_mod):
    """Literal-evaluate a config kwarg (constants, tuples, LayerSpec)."""
    if isinstance(node, ast.Constant):
        return node.value
    if isinstance(node, (ast.Tuple, ast.List)):
        return tuple(_literal(e, cfg_mod) for e in node.elts)
    if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.USub):
        v = _literal(node.operand, cfg_mod)
        if isinstance(v, (int, float)):
            return -v
        raise _Unevaluable
    if isinstance(node, ast.BinOp) and isinstance(node.op, ast.Mult):
        # (LayerSpec(...),) * 3 and literal arithmetic
        left = _literal(node.left, cfg_mod)
        right = _literal(node.right, cfg_mod)
        try:
            return left * right
        except TypeError:
            raise _Unevaluable from None
    if isinstance(node, ast.Call):
        name = node.func
        tail = (name.attr if isinstance(name, ast.Attribute)
                else name.id if isinstance(name, ast.Name) else "")
        if tail == "LayerSpec" and not node.args:
            kwargs = {}
            for kw in node.keywords:
                if kw.arg is None:
                    raise _Unevaluable
                kwargs[kw.arg] = _literal(kw.value, cfg_mod)
            return cfg_mod.LayerSpec(**kwargs)
    raise _Unevaluable


class PhysicsConfigValidity(Rule):
    """LR201: statically validate literal ``DONNConfig(...)`` call sites.

    Evaluates config constructors whose kwargs are literals (constants,
    tuples, literal ``LayerSpec`` calls) and runs the shared physics
    validator over the resulting value — the same criteria
    ``plan_from_config`` enforces at build time, surfaced at lint time
    for ``examples/``, ``src/repro/configs/donn.py`` and the benches.
    Call sites with runtime-computed kwargs are skipped (the build-time
    hook still covers them).
    """

    rule_id = "LR201"
    title = "physics-config validity"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        calls = [
            n for n in ast.walk(tree)
            if isinstance(n, ast.Call) and (
                (isinstance(n.func, ast.Name)
                 and n.func.id == "DONNConfig")
                or (isinstance(n.func, ast.Attribute)
                    and n.func.attr == "DONNConfig"))
        ]
        if not calls:
            return []
        mods = _import_repro()
        if mods is None:
            return []
        cfg_mod, physics = mods
        out: List[Finding] = []
        for call in calls:
            if call.args:
                continue  # positional form: skip, cannot map reliably
            kwargs = {}
            try:
                for kw in call.keywords:
                    if kw.arg is None:
                        raise _Unevaluable
                    kwargs[kw.arg] = _literal(kw.value, cfg_mod)
            except _Unevaluable:
                continue
            try:
                cfg = cfg_mod.DONNConfig(**kwargs)
            except Exception:
                continue  # constructor errors are __post_init__'s job
            for v in physics.validate_config(cfg):
                out.append(ctx.finding(self, call, str(v),
                                       severity=v.severity))
        return out


class SpecArtifactValidity(Rule):
    """LR202: JSON ``to_spec`` artifacts must describe valid physics.

    Any scanned ``*.json`` that looks like a DONN spec (has ``layers``
    and ``detector`` keys) is assembled into a ``DONNConfig`` via
    ``dsl.spec_to_config`` (no model build) and run through the shared
    validator — an artifact that would fail ``from_spec`` at load time
    fails lint now.
    """

    rule_id = "LR202"
    title = "spec artifact validity"
    severity = ERROR

    def finalize(self, project: Project) -> Iterable[Finding]:
        if not project.json_files:
            return []
        try:
            from repro.core import dsl, physics
        except Exception:
            return []
        out: List[Finding] = []
        for path in project.json_files:
            try:
                data = json.loads(path.read_text())
            except (OSError, ValueError):
                continue  # not a readable JSON document: not our concern
            if not (isinstance(data, dict) and "layers" in data
                    and "detector" in data):
                continue
            try:
                rel = os.path.relpath(path, project.root)
            except ValueError:
                rel = str(path)
            try:
                cfg = dsl.spec_to_config(data)
            except Exception as e:
                out.append(Finding(rel, 1, self.rule_id, ERROR,
                                   f"unloadable DONN spec: {e}"))
                continue
            for v in physics.validate_config(cfg):
                out.append(Finding(rel, 1, self.rule_id, v.severity, str(v)))
        return out
