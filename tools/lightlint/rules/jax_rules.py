"""JAX-correctness rules (LR101-LR106).

Each rule codifies a hazard this codebase has actually hit: a config
field missing from a cache key, a donated buffer read after donation, a
host sync inside a compiled region, jit re-construction in loops, model
builds / captured device arrays inside loss closures, and bf16
arithmetic without an f32 accumulator.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from lightlint.core import ERROR, FileContext, Finding, Project, Rule


def dotted(node) -> Optional[str]:
    """'jax.lax.scan' for an Attribute/Name chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted(node.func)


def _walk_no_defs(node):
    """Walk an AST without descending into nested function definitions."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(n))


_JIT_NAMES = {"jax.jit", "jit", "pjit", "jax.pjit",
              "jax.experimental.pjit.pjit"}


# --------------------------------------------------------------------------
# LR101 — cache-key completeness
# --------------------------------------------------------------------------

CONFIG_REL = "src/repro/core/config.py"
MODELS_REL = "src/repro/core/models.py"
PROPAGATION_REL = "src/repro/core/propagation.py"

# config methods whose call covers a known field subset (the method body
# reads them; tracked here so attribute-level consumption stays local)
_METHOD_COVER = {
    "gap_distances": {"distance", "distances", "depth", "layers"},
    "resolved_layers": {"distance", "distances", "depth", "layers",
                        "approximation", "codesign", "device_levels",
                        "response_gamma", "n", "pixel_size"},
}

# cosmetic, explicitly non-identifying (config_static_key pops it)
_EXEMPT_FIELDS = {"name"}

_KEY_FUNCTIONS = ("config_static_key", "model_cache_key", "plan_cache_key")


def _dataclass_fields(tree: ast.AST, class_name: str) -> List[Tuple[str, int]]:
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef) and node.name == class_name:
            return [
                (stmt.target.id, stmt.lineno)
                for stmt in node.body
                if isinstance(stmt, ast.AnnAssign)
                and isinstance(stmt.target, ast.Name)
            ]
    return []


class _KeyFnConsumption:
    """Fields a cache-key function consumes from its config parameter."""

    def __init__(self, fn: ast.FunctionDef):
        self.fn = fn
        self.full = False  # asdict/__dict__: every field consumed
        self.attrs: Set[str] = set()
        self.layer_attrs: Set[str] = set()  # attrs on `for l in cfg.layers`
        self.delegates: Set[str] = set()  # other key fns called on the param
        if not fn.args.args:
            return
        param = fn.args.args[0].arg
        layer_vars: Set[str] = set()
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute) and isinstance(
                    node.value, ast.Name) and node.value.id == param:
                self.attrs.add(node.attr)
            if isinstance(node, ast.Call):
                name = call_name(node) or ""
                tail = name.split(".")[-1]
                arg_is_param = any(
                    isinstance(a, ast.Name) and a.id == param
                    for a in node.args
                )
                if tail == "asdict" and arg_is_param:
                    self.full = True
                if tail in _KEY_FUNCTIONS and arg_is_param:
                    self.delegates.add(tail)
            if isinstance(node, ast.Attribute) and node.attr == "__dict__":
                self.full = True
            # `for l in cfg.layers` / comprehensions over cfg.layers
            target_iter = None
            if isinstance(node, (ast.For, ast.AsyncFor)):
                target_iter = (node.target, node.iter)
            elif isinstance(node, ast.comprehension):
                target_iter = (node.target, node.iter)
            if target_iter is not None:
                tgt, it = target_iter
                if (isinstance(it, ast.Attribute)
                        and isinstance(it.value, ast.Name)
                        and it.value.id == param and it.attr == "layers"
                        and isinstance(tgt, ast.Name)):
                    layer_vars.add(tgt.id)
        for node in ast.walk(fn):
            if isinstance(node, ast.Attribute) and isinstance(
                    node.value, ast.Name) and node.value.id in layer_vars:
                self.layer_attrs.add(node.attr)

    def config_fields(self) -> Set[str]:
        out = set(self.attrs)
        for m, cover in _METHOD_COVER.items():
            if m in self.attrs:
                out |= cover
        return out


class CacheKeyCompleteness(Rule):
    """LR101: every DONNConfig/LayerSpec field must feed a cache key.

    A field consumed by none of ``config_static_key`` /
    ``model_cache_key`` / ``plan_cache_key`` means two configs differing
    only in that field share cache entries — the stale-plan/stale-
    executable hazard the runtime guard test in tests/test_hetero.py
    checks dynamically; this rule pins it statically.
    """

    rule_id = "LR101"
    title = "cache-key completeness"
    severity = ERROR

    def finalize(self, project: Project) -> Iterable[Finding]:
        cfg_tree = project.tree_for(CONFIG_REL)
        models_tree = project.tree_for(MODELS_REL)
        prop_tree = project.tree_for(PROPAGATION_REL)
        if cfg_tree is None or (models_tree is None and prop_tree is None):
            return []
        donn_fields = _dataclass_fields(cfg_tree, "DONNConfig")
        layer_fields = _dataclass_fields(cfg_tree, "LayerSpec")
        if not donn_fields:
            return []
        cons: Dict[str, _KeyFnConsumption] = {}
        for tree in (models_tree, prop_tree):
            if tree is None:
                continue
            for node in ast.walk(tree):
                if (isinstance(node, ast.FunctionDef)
                        and node.name in _KEY_FUNCTIONS):
                    cons[node.name] = _KeyFnConsumption(node)
        if not cons:
            return []
        # resolve one level of delegation (model_cache_key ->
        # config_static_key)
        for c in cons.values():
            for d in c.delegates:
                if d in cons:
                    c.full = c.full or cons[d].full
                    c.attrs |= cons[d].attrs
                    c.layer_attrs |= cons[d].layer_attrs
        full = any(c.full for c in cons.values())
        consumed: Set[str] = set()
        layer_consumed: Set[str] = set()
        for c in cons.values():
            consumed |= c.config_fields()
            layer_consumed |= c.layer_attrs
        out = []
        for field, line in donn_fields:
            if field in _EXEMPT_FIELDS or full or field in consumed:
                continue
            out.append(Finding(
                CONFIG_REL, line, self.rule_id, self.severity,
                f"DONNConfig.{field} is not consumed by any cache-key "
                f"function ({'/'.join(sorted(cons))}): configs differing "
                f"only in this field would share plan/executable cache "
                f"entries"))
        if layer_fields and cons.get("plan_cache_key") is not None:
            plan = cons["plan_cache_key"]
            for field, line in layer_fields:
                if full or plan.full or field in layer_consumed:
                    continue
                out.append(Finding(
                    CONFIG_REL, line, self.rule_id, self.severity,
                    f"LayerSpec.{field} is not consumed by plan_cache_key's "
                    f"per-layer tuple: heterogeneous stacks differing only "
                    f"in this field would share a plan"))
        return out


# --------------------------------------------------------------------------
# LR102 — donation aliasing
# --------------------------------------------------------------------------

def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Donated arg positions of a cached_executable/jit call, else None."""
    name = call_name(call) or ""
    tail = name.split(".")[-1]
    if tail not in {"cached_executable", "jit", "pjit"}:
        return None
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            vals = []
            for e in v.elts:
                if not (isinstance(e, ast.Constant)
                        and isinstance(e.value, int)):
                    return None  # non-literal: cannot track
                vals.append(e.value)
            return tuple(vals)
        return None  # variable donate_argnums: cannot track
    return None


class DonationAliasing(Rule):
    """LR102: reading a buffer after it was donated to a compiled call.

    ``donate_argnums`` hands the argument's device buffer to XLA; the
    old array is invalid afterwards.  The safe idiom rebinds the name
    from the call's result (``params, opt = step(params, opt, ...)``) or
    copies first (``params = jax.tree.map(jnp.array, params)``).
    """

    rule_id = "LR102"
    title = "donation aliasing"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        for fn in ast.walk(tree):
            if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                out.extend(self._check_fn(fn, ctx))
        return out

    def _check_fn(self, fn, ctx: FileContext) -> List[Finding]:
        donators: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Name)
                    and isinstance(node.value, ast.Call)):
                pos = _donate_positions(node.value)
                if pos:
                    donators[node.targets[0].id] = pos
        if not donators:
            return []
        loads: Dict[str, List[int]] = {}
        stores: Dict[str, List[int]] = {}
        for node in ast.walk(fn):
            if isinstance(node, ast.Name):
                (loads if isinstance(node.ctx, ast.Load)
                 else stores).setdefault(node.id, []).append(node.lineno)
        loops = [(n.lineno, n.end_lineno or n.lineno)
                 for n in ast.walk(fn)
                 if isinstance(n, (ast.For, ast.AsyncFor, ast.While))]
        out: List[Finding] = []
        for call in ast.walk(fn):
            if not (isinstance(call, ast.Call)
                    and isinstance(call.func, ast.Name)
                    and call.func.id in donators):
                continue
            positions = donators[call.func.id]
            donated: Set[str] = set()
            if any(isinstance(a, ast.Starred) for a in call.args):
                # ex(*args): every name feeding the call is possibly donated
                for a in call.args:
                    for n in ast.walk(a):
                        if isinstance(n, ast.Name):
                            donated.add(n.id)
            else:
                for p in positions:
                    if p < len(call.args) and isinstance(call.args[p],
                                                         ast.Name):
                        donated.add(call.args[p].id)
            c0, c1 = call.lineno, call.end_lineno or call.lineno
            loop = next(((l0, l1) for l0, l1 in sorted(
                loops, key=lambda r: r[1] - r[0])
                if l0 <= c0 <= l1), None)
            for name in sorted(donated):
                if loop is not None:
                    l0, l1 = loop
                    if any(l0 <= s <= l1 for s in stores.get(name, ())):
                        continue  # rebound somewhere in the loop: safe
                    bad = [ln for ln in loads.get(name, ())
                           if l0 <= ln <= l1 and not (c0 <= ln <= c1)]
                else:
                    rebinds = [s for s in stores.get(name, ()) if s > c1]
                    first_rebind = min(rebinds) if rebinds else float("inf")
                    bad = [ln for ln in loads.get(name, ())
                           if c1 < ln < first_rebind]
                if bad:
                    out.append(ctx.finding(
                        self, min(bad),
                        f"'{name}' is read after being donated to "
                        f"'{call.func.id}' (line {c0}): the donated buffer "
                        f"is invalid; rebind the name from the call result "
                        f"or copy before donating"))
        return out


# --------------------------------------------------------------------------
# LR103 — host sync in hot path
# --------------------------------------------------------------------------

_HOST_SYNC_CALLS = {
    "jax.device_get": "jax.device_get forces a device->host transfer",
    "np.asarray": "np.asarray on a traced value forces a host sync",
    "np.array": "np.array on a traced value forces a host sync",
    "numpy.asarray": "numpy.asarray on a traced value forces a host sync",
    "numpy.array": "numpy.array on a traced value forces a host sync",
    "print": "print inside a compiled region syncs (or burns in) values",
}


class HostSyncInHotPath(Rule):
    """LR103: host synchronization inside compiled/scanned code.

    Hot regions: functions decorated with jit, bodies handed to
    ``jax.lax.scan``, functions compiled via ``cached_executable``, and
    their nested defs.  ``.item()``, ``float()``/``int()``,
    ``np.asarray``, ``jax.device_get`` and ``print`` there either crash
    on tracers or silently serialize the device stream.  In
    ``benchmarks/``, printing between a ``time.perf_counter()`` start
    and its read also fires (it distorts the timed region).
    """

    rule_id = "LR103"
    title = "host sync in hot path"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        hot_names = self._hot_function_names(tree)
        hot_fns: List[Tuple[ast.AST, Set[str]]] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name in hot_names or any(
                        self._is_jit_decorator(d) for d in node.decorator_list
                ):
                    hot_fns.append((node, self._static_args(node)))
        # lambdas passed directly to jit are hot too
        for node in ast.walk(tree):
            if isinstance(node, ast.Call) and (call_name(node) or "").split(
                    ".")[-1] in {"jit", "pjit"}:
                for a in node.args:
                    if isinstance(a, ast.Lambda):
                        hot_fns.append((a, set()))
        seen: Set[int] = set()
        for fn, statics in hot_fns:
            for f in self._check_hot_body(fn, ctx, statics):
                key = (f.line, hash(f.message))
                if key not in seen:
                    seen.add(key)
                    out.append(f)
        if ctx.rel.replace("\\", "/").startswith("benchmarks/"):
            out.extend(self._check_timed_regions(tree, ctx))
        return out

    @staticmethod
    def _is_jit_decorator(dec) -> bool:
        if dotted(dec) in _JIT_NAMES:
            return True
        if isinstance(dec, ast.Call):
            if dotted(dec.func) in _JIT_NAMES:
                return True
            if (dotted(dec.func) or "").split(".")[-1] == "partial":
                return bool(dec.args) and dotted(dec.args[0]) in _JIT_NAMES
        return False

    @staticmethod
    def _hot_function_names(tree) -> Set[str]:
        hot: Set[str] = set()
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            name = (call_name(node) or "")
            tail = name.split(".")[-1]
            if name.endswith("lax.scan") and node.args and isinstance(
                    node.args[0], ast.Name):
                hot.add(node.args[0].id)
            elif tail == "cached_executable" and len(node.args) >= 2 and \
                    isinstance(node.args[1], ast.Name):
                hot.add(node.args[1].id)
            elif tail in {"jit", "pjit"} and node.args and isinstance(
                    node.args[0], ast.Name):
                hot.add(node.args[0].id)
            elif tail in {"checkpoint", "remat"} and node.args and isinstance(
                    node.args[0], ast.Name):
                hot.add(node.args[0].id)
        return hot

    @staticmethod
    def _static_args(fn) -> Set[str]:
        """Arg names marked static in a jit decorator (trace-time values)."""
        statics: Set[str] = set()
        arg_names = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        for dec in fn.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            kws = list(dec.keywords)
            # partial(jax.jit, static_argnames=...) carries the kwargs too
            for kw in kws:
                if kw.arg == "static_argnames":
                    v = kw.value
                    elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                        else [v]
                    statics |= {e.value for e in elts
                                if isinstance(e, ast.Constant)
                                and isinstance(e.value, str)}
                elif kw.arg == "static_argnums":
                    v = kw.value
                    elts = v.elts if isinstance(v, (ast.Tuple, ast.List)) \
                        else [v]
                    for e in elts:
                        if isinstance(e, ast.Constant) and isinstance(
                                e.value, int) and e.value < len(arg_names):
                            statics.add(arg_names[e.value])
        return statics

    def _check_hot_body(self, fn, ctx: FileContext,
                        statics: Set[str] = frozenset()) -> List[Finding]:
        out: List[Finding] = []
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        nodes = []
        for stmt in body:
            nodes.append(stmt)
            nodes.extend(ast.walk(stmt))
        for node in nodes:
            if not isinstance(node, ast.Call):
                continue
            name = call_name(node) or ""
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr == "item" and not node.args:
                out.append(ctx.finding(
                    self, node, ".item() inside a compiled region blocks on "
                    "the device stream; return the array and sync outside"))
            elif name in _HOST_SYNC_CALLS:
                out.append(ctx.finding(
                    self, node, f"{_HOST_SYNC_CALLS[name]} inside a "
                    f"compiled region; hoist it out of the hot path"))
            elif name in {"float", "int"} and node.args and not isinstance(
                    node.args[0], ast.Constant) and not (
                    isinstance(node.args[0], ast.Name)
                    and node.args[0].id in statics):
                out.append(ctx.finding(
                    self, node, f"{name}() on a traced value inside a "
                    f"compiled region raises ConcretizationTypeError (or "
                    f"silently burns in a trace-time constant)"))
        return out

    def _check_timed_regions(self, tree, ctx: FileContext) -> List[Finding]:
        out: List[Finding] = []
        for fn in ast.walk(tree):
            if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            starts: List[Tuple[str, int]] = []
            for node in _walk_no_defs(fn):
                if (isinstance(node, ast.Assign)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Call)
                        and (call_name(node.value) or "") in
                        {"time.perf_counter", "time.monotonic"}):
                    starts.append((node.targets[0].id, node.lineno))
            if not starts:
                continue
            loads: Dict[str, List[int]] = {}
            prints: List[int] = []
            for node in _walk_no_defs(fn):
                if isinstance(node, ast.Name) and isinstance(
                        node.ctx, ast.Load):
                    loads.setdefault(node.id, []).append(node.lineno)
                if isinstance(node, ast.Call) and call_name(node) == "print":
                    prints.append(node.lineno)
            for var, line in starts:
                later = [ln for ln in loads.get(var, ()) if ln > line]
                if not later:
                    continue
                end = min(later)
                for p in prints:
                    if line < p < end:
                        out.append(ctx.finding(
                            self, p, f"print inside the timed region "
                            f"started by '{var}' at line {line} distorts "
                            f"the measurement; move it past the stop "
                            f"read"))
        return out


# --------------------------------------------------------------------------
# LR104 — jit constructed inside a loop
# --------------------------------------------------------------------------

class JitInLoop(Rule):
    """LR104: ``jax.jit(...)`` evaluated per loop iteration.

    Each evaluation creates a fresh jit wrapper with an empty compile
    cache keyed by the (often fresh) closure — every iteration retraces
    and recompiles.  Hoist the jit out of the loop or route through
    ``repro.core.propagation.cached_executable`` (process-wide cache
    keyed by config statics + avals).
    """

    rule_id = "LR104"
    title = "jit in loop"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        seen: Set[int] = set()
        for loop in ast.walk(tree):
            if not isinstance(loop, (ast.For, ast.AsyncFor, ast.While)):
                continue
            for stmt in loop.body + loop.orelse:
                for node in [stmt, *_walk_no_defs(stmt)]:
                    if (isinstance(node, ast.Call)
                            and call_name(node) in _JIT_NAMES
                            and id(node) not in seen):
                        seen.add(id(node))
                        out.append(ctx.finding(
                            self, node,
                            "jax.jit constructed inside a loop retraces and "
                            "recompiles every iteration; hoist it out of "
                            "the loop or route through cached_executable"))
        return out


# --------------------------------------------------------------------------
# LR105 — retrace hazards from closures
# --------------------------------------------------------------------------

_TRACE_ENTRY_TAILS = {"jit", "pjit", "grad", "value_and_grad",
                      "cached_executable"}


class ClosureRetraceHazard(Rule):
    """LR105: model builds / captured device arrays inside closures.

    The bug PR 2 fixed by hand in ``runtime/donn_steps``: a loss closure
    that (re)builds a model — or captures a freshly created ``jnp``
    array — defeats jit caching, because each call produces a new
    closure identity and retraces.  Build through ``cached_model`` /
    ``cached_apply`` and pass arrays as arguments instead.
    """

    rule_id = "LR105"
    title = "closure retrace hazard"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        fns = [n for n in ast.walk(tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]
        # (a) build_model inside a nested def (a closure): every call of
        # the closure rebuilds layers/plans and retraces
        for outer in fns:
            for inner in ast.walk(outer):
                if inner is outer or not isinstance(
                        inner, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                for node in ast.walk(inner):
                    if isinstance(node, ast.Call) and (
                            call_name(node) or "").split(".")[-1] == \
                            "build_model":
                        out.append(ctx.finding(
                            self, node,
                            "build_model inside a closure rebuilds the "
                            "model (plans, TF planes) on every call and "
                            "retraces; use cached_model/cached_apply"))
        # (b) nested def passed to jit/grad capturing a jnp array bound
        # in the enclosing function
        for outer in fns:
            jnp_bindings: Dict[str, int] = {}
            for node in _walk_no_defs(outer):
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and isinstance(node.value, ast.Call)
                        and (call_name(node.value) or "") in
                        {"jnp.array", "jnp.asarray", "jax.numpy.array",
                         "jax.numpy.asarray"}):
                    jnp_bindings[node.targets[0].id] = node.lineno
            if not jnp_bindings:
                continue
            inner_defs = {
                n.name: n for n in _walk_no_defs(outer)
                if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
            }
            traced: Set[str] = set()
            for node in _walk_no_defs(outer):
                if isinstance(node, ast.Call) and (
                        call_name(node) or "").split(".")[-1] in \
                        _TRACE_ENTRY_TAILS:
                    for a in node.args:
                        if isinstance(a, ast.Name) and a.id in inner_defs:
                            traced.add(a.id)
            for name in sorted(traced):
                inner = inner_defs[name]
                params = {a.arg for a in inner.args.args
                          + inner.args.kwonlyargs + inner.args.posonlyargs}
                assigned = {n.id for n in ast.walk(inner)
                            if isinstance(n, ast.Name)
                            and isinstance(n.ctx, ast.Store)}
                for node in ast.walk(inner):
                    if (isinstance(node, ast.Name)
                            and isinstance(node.ctx, ast.Load)
                            and node.id in jnp_bindings
                            and node.id not in params
                            and node.id not in assigned):
                        out.append(ctx.finding(
                            self, jnp_bindings[node.id],
                            f"'{node.id}' is a jnp array captured by "
                            f"closure '{name}' handed to a trace entry "
                            f"point: each fresh closure retraces; pass it "
                            f"as an argument or hoist to a module "
                            f"constant"))
                        break
        return out


# --------------------------------------------------------------------------
# LR106 — bf16 arithmetic without f32 accumulation
# --------------------------------------------------------------------------

_BF16_REDUCTIONS = {"jnp.sum", "jnp.mean", "jnp.dot", "jnp.matmul",
                    "jnp.einsum", "jnp.tensordot"}
_ACCUM_KWARGS = {"dtype", "preferred_element_type"}


def _mentions_bf16(node) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr == "bfloat16":
            return True
        if isinstance(n, ast.Constant) and n.value == "bfloat16":
            return True
    return False


class Bf16Accumulation(Rule):
    """LR106: bf16 values combined/reduced without an f32 accumulator.

    The ``tf_dtype`` contract: bf16 is a *storage* dtype for modulation
    and TF planes; arithmetic must upcast to float32 first (the
    ``a.astype(jnp.float32) * b`` idiom in ``core/propagation.py``) and
    reductions must carry an explicit f32 accumulator dtype, or half the
    mantissa silently disappears from the interference pattern.
    """

    rule_id = "LR106"
    title = "bf16 accumulation discipline"
    severity = ERROR

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        scopes = [tree] + [
            n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))
        ]
        seen: Set[int] = set()
        for scope in scopes:
            bf16: Set[str] = set()
            for node in _walk_no_defs(scope):
                if (isinstance(node, ast.Assign) and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)
                        and _mentions_bf16(node.value)):
                    bf16.add(node.targets[0].id)
            if not bf16:
                continue
            for node in _walk_no_defs(scope):
                if id(node) in seen:
                    continue
                if (isinstance(node, ast.BinOp)
                        and isinstance(node.op, (ast.Add, ast.Sub, ast.Mult))
                        and isinstance(node.left, ast.Name)
                        and isinstance(node.right, ast.Name)
                        and node.left.id in bf16 and node.right.id in bf16):
                    seen.add(id(node))
                    out.append(ctx.finding(
                        self, node,
                        f"'{node.left.id}' and '{node.right.id}' are bf16; "
                        f"their product/sum stays bf16 — upcast one operand "
                        f"with .astype(jnp.float32) so accumulation runs "
                        f"in f32"))
                if (isinstance(node, ast.Call)
                        and (call_name(node) or "") in _BF16_REDUCTIONS
                        and any(isinstance(a, ast.Name) and a.id in bf16
                                for a in node.args)
                        and not any(kw.arg in _ACCUM_KWARGS
                                    for kw in node.keywords)):
                    seen.add(id(node))
                    out.append(ctx.finding(
                        self, node,
                        f"{call_name(node)} reduces a bf16 array without an "
                        f"explicit f32 accumulator; pass dtype=jnp.float32 "
                        f"(or preferred_element_type)"))
        return out


# --------------------------------------------------------------------------
# LR107 — complex promotion of split real/imag pairs in hot bodies
# --------------------------------------------------------------------------
class ComplexPromotionInHotPath(Rule):
    """LR107: ``a + 1j*b`` pair assembly inside compiled/scanned code.

    The propagation engine carries fields as split real/imag planes so
    the elementwise sites stay fused (``phase_tf_apply``,
    ``fused_spectral_hop``).  Re-assembling a complex array from the
    split pair inside a scan body or jitted function (``a + 1j*b`` /
    ``a - 1j*b``) materializes an interleaved complex temporary between
    kernels — exactly the promotion the fused spectral-hop kernel exists
    to avoid — and silently widens every downstream op to complex
    arithmetic.  Use ``jax.lax.complex(a, b)`` at the single FFT
    boundary that genuinely needs a complex operand, or keep the pair
    split through the fused kernels.

    Hot regions are discovered exactly like LR103: scan bodies,
    jit/pjit'd and remat'd functions, ``cached_executable`` targets, and
    their nested defs.
    """

    rule_id = "LR107"
    title = "complex pair promotion in hot path"
    severity = ERROR

    @staticmethod
    def _is_imag_mult(node) -> bool:
        """``1j * x`` / ``x * 1j`` (any complex constant coefficient)."""
        if not (isinstance(node, ast.BinOp)
                and isinstance(node.op, ast.Mult)):
            return False
        return any(isinstance(s, ast.Constant) and isinstance(s.value, complex)
                   for s in (node.left, node.right))

    def visit(self, tree: ast.AST, ctx: FileContext) -> Iterable[Finding]:
        out: List[Finding] = []
        hot_names = HostSyncInHotPath._hot_function_names(tree)
        hot_fns: List[ast.AST] = []
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                if node.name in hot_names or any(
                        HostSyncInHotPath._is_jit_decorator(d)
                        for d in node.decorator_list
                ):
                    hot_fns.append(node)
            elif isinstance(node, ast.Call) and (
                    call_name(node) or "").split(".")[-1] in {"jit", "pjit"}:
                hot_fns.extend(a for a in node.args
                               if isinstance(a, ast.Lambda))
        seen: Set[int] = set()
        for fn in hot_fns:
            body = fn.body if isinstance(fn.body, list) else [fn.body]
            nodes = []
            for stmt in body:
                nodes.append(stmt)
                nodes.extend(ast.walk(stmt))
            for node in nodes:
                if id(node) in seen:
                    continue
                if (isinstance(node, ast.BinOp)
                        and isinstance(node.op, (ast.Add, ast.Sub))
                        and (self._is_imag_mult(node.left)
                             or self._is_imag_mult(node.right))):
                    seen.add(id(node))
                    out.append(ctx.finding(
                        self, node,
                        "complex pair assembly (a +/- 1j*b) inside a "
                        "compiled region promotes split real/imag planes "
                        "to an interleaved complex temporary; use "
                        "jax.lax.complex(a, b) at the FFT boundary or "
                        "keep the pair split through the fused kernels"))
        return out
