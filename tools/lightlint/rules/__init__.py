"""Rule registry: every rule ships here + a fixture pair under
``tests/lightlint_fixtures/``."""
from lightlint.rules.jax_rules import (
    Bf16Accumulation,
    CacheKeyCompleteness,
    ClosureRetraceHazard,
    ComplexPromotionInHotPath,
    DonationAliasing,
    HostSyncInHotPath,
    JitInLoop,
)
from lightlint.rules.physics_rules import (
    PhysicsConfigValidity,
    SpecArtifactValidity,
)
from lightlint.rules.runtime_rules import UnboundedRetryLoop
from lightlint.rules.sharding_rules import AdHocPartitionSpec

ALL_RULES = (
    CacheKeyCompleteness,  # LR101
    DonationAliasing,  # LR102
    HostSyncInHotPath,  # LR103
    JitInLoop,  # LR104
    ClosureRetraceHazard,  # LR105
    Bf16Accumulation,  # LR106
    ComplexPromotionInHotPath,  # LR107
    UnboundedRetryLoop,  # LR108
    AdHocPartitionSpec,  # LR109
    PhysicsConfigValidity,  # LR201
    SpecArtifactValidity,  # LR202
)


def default_rules():
    return [cls() for cls in ALL_RULES]


def rules_by_id(ids):
    sel = set(ids)
    return [cls() for cls in ALL_RULES if cls.rule_id in sel]
