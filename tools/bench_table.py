"""Render the README perf-trajectory table from BENCH_summary.json.

Reads the rolled-up benchmark summary (written by ``benchmarks/run.py``)
and prints a GitHub-markdown table of the headline speedup per tier-1
suite — the source of the table embedded in README.md.

    PYTHONPATH=src:. python tools/bench_table.py [path/to/BENCH_summary.json]
"""
from __future__ import annotations

import json
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

def _pick(meta: dict, *keys) -> dict:
    """{cell: first present numeric key} over a suite's speedups meta."""
    out = {}
    for cell, v in meta.get("speedups", {}).items():
        if not isinstance(v, dict):
            if isinstance(v, (int, float)):
                out[cell] = v
            continue
        for k in keys:
            if isinstance(v.get(k), (int, float)):
                out[cell] = v[k]
                break
    return out


def _resilience_headline(meta: dict) -> str:
    """Not a speedup suite: headline the resilience numbers directly."""
    s = meta.get("summary", {})
    parts = []
    cold = s.get("cold_start", {}).get("load_warm_ms")
    if isinstance(cold, (int, float)):
        parts.append(f"cold_start {cold:g}ms")
    shed = s.get("overload", {}).get("shed_rate")
    if isinstance(shed, (int, float)):
        parts.append(f"shed_rate {shed:g}")
    noise = s.get("phase_noise", {})
    clean, worst = noise.get("clean"), noise.get("1.0")
    if isinstance(clean, (int, float)) and isinstance(worst, (int, float)):
        parts.append(f"acc {clean:g}->{worst:g} @ sigma 1.0")
    return ", ".join(parts)


def _serving_fleet_headline(meta: dict) -> str:
    """Latency-under-load + fault outcomes, not a speedup suite."""
    s = meta.get("summary", {})
    parts = []
    r2 = s.get("poisson", {}).get("r2", {})
    if isinstance(r2.get("p50_ms"), (int, float)):
        parts.append(f"p50 {r2['p50_ms']:g}ms / p99 {r2['p99_ms']:g}ms (r2)")
    win = s.get("continuous_vs_deadline", {}).get("p50_win")
    if isinstance(win, (int, float)):
        parts.append(f"continuous {win:g}x vs deadline")
    fk = s.get("failover_kill", {})
    if fk.get("dropped") == 0:
        parts.append("kill: 0 dropped")
    if s.get("drain_swap", {}).get("dropped") == 0:
        parts.append("swap: 0 dropped")
    return ", ".join(parts)


def _roofline_headline(meta: dict) -> str:
    """Peak fraction + binding roof per measured cell."""
    parts = []
    for cell, v in sorted(meta.get("cells", {}).items()):
        frac = v.get("fraction") if isinstance(v, dict) else None
        if isinstance(frac, (int, float)):
            parts.append(f"{cell} {frac:.2f}({v.get('bound', '?')})")
    return ", ".join(parts)


# suite -> (PR, headline metric extractor, description)
HEADLINES = {
    "propagation_plan": (
        "1-2", lambda m: _fmt_map(_pick(m, "steady"), "x"),
        "fused scan forward vs eager (steady state)"),
    "dse_batched": (
        "2", lambda m: _fmt_map(_pick(m, "speedup"), "x"),
        "K-candidate batched emulation vs sequential build+jit+run (cold)"),
    "hetero": (
        "3", lambda m: _fmt_map(_pick(m, "cold", "steady"), "x"),
        "ragged-depth batched DSE + segmented-plan forward"),
    "train_throughput": (
        "4", lambda m: _fmt_map(_pick(m, "steady", "speedup"), "x"),
        "chunked donated training vs seed-style per-step loop"),
    "inference_throughput": (
        "5", lambda m: _fmt_map(_pick(m, "steady_b32"), "x"),
        "frozen bucketed serving vs per-request apply (batch 32)"),
    "resilience": (
        "7", _resilience_headline,
        "overload shedding, artifact cold-start, phase-noise robustness"),
    "serving_fleet": (
        "9", _serving_fleet_headline,
        "continuous-batching fleet: Poisson latency, failover, warm swap"),
    "kernel_breakdown": (
        "8", lambda m: _fmt_map(_pick(m), "x"),
        "per-operator batched-jit vs per-sample numpy (Fig. 9)"),
    "roofline": (
        "8", _roofline_headline,
        "achieved vs measured machine peak per tier-1 cell"),
}


def _fmt_map(d: dict, suffix: str = "") -> str:
    items = [(k, v) for k, v in d.items() if isinstance(v, (int, float))]
    return ", ".join(f"{k} {v:g}{suffix}" for k, v in sorted(items))


def render(summary_path: pathlib.Path) -> str:
    summary = json.loads(summary_path.read_text())
    lines = [
        "| PR | suite | headline speedups | what it measures |",
        "|----|-------|-------------------|------------------|",
    ]
    order = sorted(HEADLINES, key=lambda s: HEADLINES[s][0])
    for suite in order:
        pr, extract, desc = HEADLINES[suite]
        cell = summary.get(suite)
        if cell is None:
            continue
        head = extract(cell.get("meta", {})) or "—"
        stale = " (stale)" if cell.get("stale") else ""
        lines.append(f"| {pr} | `{suite}`{stale} | {head} | {desc} |")
    return "\n".join(lines)


def render_plane_dtype(summary_path: pathlib.Path) -> str:
    """Quantized-plane serving table (family x plane dtype)."""
    summary = json.loads(summary_path.read_text())
    meta = summary.get("inference_throughput", {}).get("meta", {})
    cells = meta.get("speedups", {}).get("plane_dtype", {})
    lines = [
        "| family | plane dtype | req/s (b32) | max output delta vs f32 |",
        "|--------|-------------|-------------|-------------------------|",
    ]
    for family in sorted(cells):
        for dtype in ("float32", "bfloat16", "int8"):
            v = cells[family].get(dtype)
            if not isinstance(v, dict):
                continue
            rps = v.get("req_per_sec")
            delta = v.get("max_rel_delta")
            lines.append(
                f"| {family} | `{dtype}` | {rps:g} | {delta:.1e} |"
            )
    return "\n".join(lines) if len(lines) > 2 else ""


def render_serving_fleet(summary_path: pathlib.Path) -> str:
    """Latency-under-load table (scenario x p50/p99/outcome)."""
    summary = json.loads(summary_path.read_text())
    s = summary.get("serving_fleet", {}).get("meta", {}).get("summary", {})
    if not s:
        return ""
    inf = (summary.get("inference_throughput", {}).get("meta", {})
           .get("speedups", {}).get("latency_under_load", {}))
    lines = [
        "| scenario | p50 | p99 | outcome |",
        "|----------|-----|-----|---------|",
    ]

    def add(label, cell, outcome):
        p50, p99 = cell.get("p50_ms"), cell.get("p99_ms")
        if not isinstance(p50, (int, float)):
            return
        lines.append(f"| {label} | {p50:g}ms | {p99:g}ms | {outcome} |")

    if inf:
        add(f"50% util, 1 replica ({inf.get('rate_hz', '?'):g} req/s)",
            inf, "open-loop Poisson baseline")
    add("Poisson, 1 replica", s.get("poisson", {}).get("r1", {}), "healthy")
    add("Poisson, 2 replicas", s.get("poisson", {}).get("r2", {}), "healthy")
    cvd = s.get("continuous_vs_deadline", {})
    if isinstance(cvd.get("p50_continuous_ms"), (int, float)):
        lines.append(
            f"| continuous vs deadline batching "
            f"| {cvd['p50_continuous_ms']:g}ms vs "
            f"{cvd['p50_deadline_ms']:g}ms | — "
            f"| p50 win {cvd.get('p50_win', '?'):g}x |")
    fk = s.get("failover_kill", {})
    add("mid-run replica kill", fk,
        f"{fk.get('dropped', '?')} dropped, bit-identical retries")
    add("1 slow replica (25ms stall)", s.get("slow_replica", {}),
        "probation keeps the tail")
    ds = s.get("drain_swap", {})
    if isinstance(ds.get("swap_ms"), (int, float)):
        lines.append(
            f"| drain + rolling warm swap | swap {ds['swap_ms']:g}ms | — "
            f"| {ds.get('dropped', '?')} dropped, no admission gap |")
    return "\n".join(lines) if len(lines) > 2 else ""


START = "<!-- bench-table:start -->"
END = "<!-- bench-table:end -->"
PD_START = "<!-- plane-dtype-table:start -->"
PD_END = "<!-- plane-dtype-table:end -->"
FLEET_START = "<!-- serving-fleet-table:start -->"
FLEET_END = "<!-- serving-fleet-table:end -->"


def inject_readme(table: str, readme: pathlib.Path,
                  start: str = START, end: str = END) -> None:
    """Replace the marked block in README.md with the rendered table."""
    text = readme.read_text()
    if start not in text or end not in text:
        raise SystemExit(f"no {start}/{end} markers in {readme}")
    head, rest = text.split(start, 1)
    _, tail = rest.split(end, 1)
    readme.write_text(f"{head}{start}\n{table}\n{end}{tail}")
    print(f"# updated {readme} ({start})")


def main() -> None:
    args = [a for a in sys.argv[1:] if not a.startswith("-")]
    path = pathlib.Path(args[0]) if args else REPO / "BENCH_summary.json"
    table = render(path)
    pd_table = render_plane_dtype(path)
    fleet_table = render_serving_fleet(path)
    if "--write-readme" in sys.argv:
        inject_readme(table, REPO / "README.md")
        if pd_table:
            inject_readme(pd_table, REPO / "README.md", PD_START, PD_END)
        if fleet_table:
            inject_readme(fleet_table, REPO / "README.md",
                          FLEET_START, FLEET_END)
    else:
        print(table)
        for t in (pd_table, fleet_table):
            if t:
                print()
                print(t)


if __name__ == "__main__":
    main()
