"""repro: LightRidge (DONN compilation framework) reproduction in JAX.

Subpackages:
- core:    the paper's contribution (optical physics kernels, DSL, DSE, codesign)
- kernels: Pallas TPU kernels for the paper's hot spots (ComplexMM, readout)
- models:  assigned LM-family architectures (dense/MoE/VLM/audio/SSM/hybrid)
- runtime: distributed runtime (sharding rules, train/serve steps)
- optim:   optimizers, schedules, gradient compression
- checkpoint: sharded fault-tolerant checkpointing
- data:    deterministic synthetic data pipelines
- configs: one config per assigned architecture (+ the paper's own DONNs)
- launch:  mesh / dryrun / train / serve entry points
"""
__version__ = "1.0.0"
