"""Test-support package: fault injectors for resilience testing.

Importable from production benchmarks as well as the test suite (it ships
in ``src`` so ``benchmarks/bench_resilience.py`` and operators' chaos
drills can use the same injectors the tests do), but nothing in the
serving or training hot paths imports it.
"""
from repro.testing.faults import (
    CrashingEngine,
    FlakyEngine,
    SlowEngine,
    corrupt_chunk,
    flip_crc,
    kill_replica,
    perturb_frozen,
    poison_batches,
)

__all__ = [
    "CrashingEngine",
    "FlakyEngine",
    "SlowEngine",
    "corrupt_chunk",
    "flip_crc",
    "kill_replica",
    "perturb_frozen",
    "poison_batches",
]
