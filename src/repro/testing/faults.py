"""Fault injectors: software failures and device physics faults.

One harness drives both the resilience test suite
(``tests/test_resilience.py``) and ``benchmarks/bench_resilience.py``,
covering the failure modes a deployed DONN actually faces:

**Software faults**
- ``FlakyEngine`` — engine proxy that raises on chosen calls or after
  ``kill()`` (crashed-replica scenario for ``EngineSupervisor``);
- ``SlowEngine`` — engine proxy that stalls each call (deadline-expiry
  scenario for ``MicroBatcher.submit(timeout_ms=...)``);
- ``CrashingEngine`` — engine proxy that dies permanently after K
  requests, optionally only once a drain begins (mid-run replica-crash
  scenario for ``FleetRouter``); ``kill_replica`` kills the first live
  crashable replica of a running fleet;
- ``corrupt_chunk`` / ``flip_crc`` — bit-rot a checkpoint chunk file /
  falsify its manifest checksum (restore-time integrity scenario);
- ``poison_batches`` — inject NaN batches into a training stream
  (non-finite guardrail scenario for ``make_train_chunk(guard=True)``).

**Physics faults** (frozen-plane non-idealities of real SLM / printed
hardware — the codesign line, arXiv 2209.14252)
- ``perturb_frozen`` — Gaussian phase noise, dead (phase-stuck) SLM
  pixels and integer-pixel lateral misalignment applied directly to a
  ``DeployedDONN``'s precomputed modulation planes, returning a new
  deployable artifact; drives accuracy-vs-noise robustness curves.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Iterable, Iterator, Optional

import numpy as np


# --------------------------------------------------------------------------
# Software faults: flaky / slow engines
# --------------------------------------------------------------------------
class FlakyEngine:
    """Engine proxy raising on selected calls (1-indexed) or after kill().

    Wraps anything with an ``infer`` method; every other attribute
    (``deployed``, ``buckets``, ``stats``, ``warmup``...) delegates to the
    wrapped engine, so it drops into ``MicroBatcher`` and
    ``EngineSupervisor`` unchanged.
    """

    def __init__(self, engine, fail_calls: Iterable[int] = (),
                 exc_type=RuntimeError):
        self._engine = engine
        self.fail_calls = set(int(c) for c in fail_calls)
        self.exc_type = exc_type
        self.calls = 0
        self.dead = False

    def kill(self):
        """Fail every call from now on (a crashed / wedged replica)."""
        self.dead = True

    def infer(self, x):
        self.calls += 1
        if self.dead:
            raise self.exc_type("engine is dead")
        if self.calls in self.fail_calls:
            raise self.exc_type(f"injected failure on call {self.calls}")
        return self._engine.infer(x)

    def __getattr__(self, name):
        return getattr(self._engine, name)


class CrashingEngine:
    """Engine proxy that dies permanently after ``crash_after`` requests.

    Unlike ``FlakyEngine`` (which fails selected calls and then recovers),
    a crashed replica stays down until something external rebuilds it —
    the mid-run replica-crash scenario for ``FleetRouter``: every request
    in flight on this replica must be retried on a healthy one, with zero
    drops.  With ``crash_on_drain=True`` the countdown only starts once
    ``arm()`` is called (the fleet bench arms it as the drain begins, so
    the crash lands during the flush).  ``kill()`` crashes it immediately.
    """

    def __init__(self, engine, crash_after: int = 1,
                 crash_on_drain: bool = False, exc_type=RuntimeError):
        self._engine = engine
        self.crash_after = int(crash_after)
        self.crash_on_drain = bool(crash_on_drain)
        self.exc_type = exc_type
        self.calls = 0
        self.armed = not crash_on_drain
        self.dead = False

    def arm(self):
        """Start the crash countdown (drain has begun)."""
        self.armed = True
        self.calls = 0

    def kill(self):
        """Crash immediately and stay down."""
        self.dead = True

    def infer(self, x):
        if self.dead:
            raise self.exc_type("replica crashed (stays down)")
        if self.armed:
            self.calls += 1
            if self.calls > self.crash_after:
                self.dead = True
                raise self.exc_type(
                    f"replica crashed after {self.crash_after} request(s)"
                )
        return self._engine.infer(x)

    def __getattr__(self, name):
        return getattr(self._engine, name)


def kill_replica(router, index: Optional[int] = None):
    """Kill one replica of a live fleet; returns the killed engine proxy.

    Picks replica ``index`` (default: the first whose engine exposes
    ``kill()`` and is not already dead) and crashes it in place — the
    mid-run fleet failover scenario.  Raises ``ValueError`` when no
    replica is killable.
    """
    reps = router.replicas
    if index is not None:
        candidates = [reps[index]]
    else:
        candidates = [r for r in reps
                      if hasattr(r.engine, "kill")
                      and not getattr(r.engine, "dead", False)]
    for rep in candidates:
        if hasattr(rep.engine, "kill"):
            rep.engine.kill()
            return rep.engine
    raise ValueError("no killable replica (wrap engines in FlakyEngine / "
                     "CrashingEngine to enable kill_replica)")


class SlowEngine:
    """Engine proxy adding ``delay_s`` of stall to every call."""

    def __init__(self, engine, delay_s: float):
        self._engine = engine
        self.delay_s = float(delay_s)

    def infer(self, x):
        time.sleep(self.delay_s)
        return self._engine.infer(x)

    def __getattr__(self, name):
        return getattr(self._engine, name)


# --------------------------------------------------------------------------
# Software faults: checkpoint corruption
# --------------------------------------------------------------------------
def _chunk_path(ckpt_dir, step: int, leaf: int, chunk: int) -> pathlib.Path:
    return (pathlib.Path(ckpt_dir) / f"step_{step:08d}"
            / f"leaf_{leaf:05d}.c{chunk:03d}.npy")


def corrupt_chunk(ckpt_dir, step: int, leaf: int = 0, chunk: int = 0):
    """Flip the last payload byte of a checkpoint chunk file (bit-rot).

    The manifest's crc32 is left intact, so a verifying restore must
    reject the chunk; a non-verifying restore would silently load garbage.
    """
    path = _chunk_path(ckpt_dir, step, leaf, chunk)
    raw = bytearray(path.read_bytes())
    raw[-1] ^= 0xFF
    path.write_bytes(bytes(raw))
    return path


def flip_crc(ckpt_dir, step: int, leaf: int = 0, chunk: int = 0):
    """Falsify a chunk's manifest crc32 (metadata corruption).

    The chunk data stays valid but no longer matches its recorded
    checksum — a verifying restore must refuse it.
    """
    mpath = pathlib.Path(ckpt_dir) / f"step_{step:08d}" / "MANIFEST.json"
    manifest = json.loads(mpath.read_text())
    entry = manifest["leaves"][leaf]["chunks"][chunk]
    entry["crc32"] = (entry["crc32"] or 0) ^ 1
    mpath.write_text(json.dumps(manifest))
    return mpath


# --------------------------------------------------------------------------
# Software faults: poisoned training data
# --------------------------------------------------------------------------
def poison_batches(it: Iterator, poison_steps: Iterable[int],
                   value: float = np.nan) -> Iterator:
    """Replace the inputs of selected batches (0-indexed) with ``value``.

    Yields ``(xb, yb)`` pairs unchanged except at ``poison_steps``, where
    ``xb`` becomes a full-``value`` array — the NaN-batch scenario the
    guarded train chunk must skip.
    """
    poison = set(int(s) for s in poison_steps)
    for i, (xb, yb) in enumerate(it):
        if i in poison:
            xb = np.full_like(np.asarray(xb), value)
        yield xb, yb


# --------------------------------------------------------------------------
# Physics faults: frozen modulation-plane non-idealities
# --------------------------------------------------------------------------
def _perturb_pair(pair, rng, use_pallas: bool, phase_sigma: float,
                  dead_frac: float, shift_px: int):
    a, b = (np.asarray(p) for p in pair)
    if phase_sigma or dead_frac:
        # recover (phase, amplitude): the pallas convention stores them
        # directly; the jnp convention stores cartesian gamma*exp(j theta)
        if use_pallas:
            theta, amp = a.astype(np.float64), b.astype(np.float64)
        else:
            theta = np.arctan2(b.astype(np.float64), a.astype(np.float64))
            amp = np.hypot(a, b).astype(np.float64)
        if phase_sigma:
            theta = theta + rng.normal(0.0, phase_sigma, theta.shape)
        if dead_frac:
            # dead SLM pixels: stuck at phase 0, amplitude response intact
            theta = np.where(rng.random(theta.shape) < dead_frac, 0.0, theta)
        if use_pallas:
            a, b = theta, amp
        else:
            a, b = amp * np.cos(theta), amp * np.sin(theta)
    if shift_px:
        # lateral misalignment: roll both planes along the last axis —
        # identical in either split convention
        a = np.roll(a, shift_px, axis=-1)
        b = np.roll(b, shift_px, axis=-1)
    return (np.asarray(a, np.float32), np.asarray(b, np.float32))


def perturb_frozen(deployed, *, phase_sigma: float = 0.0,
                   dead_frac: float = 0.0, shift_px: int = 0,
                   seed: Optional[int] = 0):
    """Device non-idealities applied to a frozen artifact's planes.

    - ``phase_sigma``: i.i.d. Gaussian phase noise (radians) per plane
      element — SLM phase-response jitter / calibration error;
    - ``dead_frac``: fraction of plane elements stuck at phase 0 (dead
      SLM pixels, amplitude response preserved);
    - ``shift_px``: whole-plane lateral misalignment, in pixels.

    Returns a **new** ``DeployedDONN`` sharing the plan/detector with the
    original (the original's planes are untouched); with all faults zero
    the planes are returned bit-identical, so robustness sweeps have an
    exact baseline.
    """
    import jax.numpy as jnp

    from repro.runtime.inference import DeployedDONN

    rng = np.random.default_rng(seed)
    use_pallas = bool(deployed.cfg.use_pallas)

    def one(pair):
        if not (phase_sigma or dead_frac or shift_px):
            return pair
        a, b = _perturb_pair(pair, rng, use_pallas, phase_sigma,
                             dead_frac, shift_px)
        return (jnp.asarray(a), jnp.asarray(b))

    if deployed.heterogeneous:
        frozen = tuple(one(p) for p in deployed.frozen)
    else:
        frozen = one(deployed.frozen)
    return DeployedDONN(
        deployed.cfg, deployed.family, deployed.plan, frozen,
        deployed.source, deployed.in_n, detector=deployed.detector,
        skip_from=deployed.skip_from, skip_hop=deployed.skip_hop,
        out_grid=deployed.out_grid,
    )
