from repro.nn.module import (
    ParamSpec,
    abstract_params,
    cast_tree,
    init_params,
    is_spec,
    logical_to_pspec,
    param_bytes,
    param_count,
    specs_to_pspecs,
    specs_to_shardings,
)

__all__ = [
    "ParamSpec",
    "abstract_params",
    "cast_tree",
    "init_params",
    "is_spec",
    "logical_to_pspec",
    "param_bytes",
    "param_count",
    "specs_to_pspecs",
    "specs_to_shardings",
]
