"""Minimal functional parameter system used across the framework.

Parameters are plain pytrees (nested dicts) of jnp arrays.  Every model
exposes ``param_specs(cfg) -> pytree[ParamSpec]`` describing shapes, dtypes,
initializers and *logical sharding axes*, and ``apply(params, ...)``.
``init_params`` materializes a spec tree; ``specs_to_shardings`` maps logical
axes to a mesh via user-supplied rules (MaxText-style).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Shape/dtype/init/logical-axes description of one parameter."""

    shape: tuple
    dtype: Any = jnp.float32
    logical_axes: tuple = ()
    init: str = "fan_in"  # fan_in | normal | zeros | ones | uniform_phase | embed
    scale: float = 1.0

    def __post_init__(self):
        if self.logical_axes and len(self.logical_axes) != len(self.shape):
            raise ValueError(
                f"logical_axes {self.logical_axes} rank != shape {self.shape}"
            )


def _initialize(spec: ParamSpec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "uniform_phase":  # phases in [0, 2pi) — DONN layers
        return jax.random.uniform(
            key, spec.shape, jnp.float32, 0.0, 2.0 * math.pi
        ).astype(spec.dtype) * spec.scale
    if spec.init == "normal":
        return (spec.scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(
            spec.dtype
        )
    if spec.init == "embed":
        return (spec.scale * jax.random.normal(key, spec.shape, jnp.float32)).astype(
            spec.dtype
        )
    if spec.init == "fan_in":
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = spec.scale / math.sqrt(max(fan_in, 1))
        return (std * jax.random.normal(key, spec.shape, jnp.float32)).astype(
            spec.dtype
        )
    if spec.init == "s4d_a_log":  # mamba A_log: log(1..state) per channel row
        state = spec.shape[-1]
        row = jnp.log(jnp.arange(1, state + 1, dtype=jnp.float32))
        return jnp.broadcast_to(row, spec.shape).astype(spec.dtype)
    if spec.init == "rglru_lambda":  # a = sigmoid(L) uniform in [0.9, 0.999]
        a = jax.random.uniform(key, spec.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(a / (1.0 - a)).astype(spec.dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    """Materialize a ParamSpec pytree into concrete arrays."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_initialize(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(specs):
    """ShapeDtypeStruct tree matching a spec tree (for .lower / dry-runs)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def logical_to_pspec(
    logical_axes: Sequence[Optional[str]],
    rules: Mapping[str, Any],
) -> P:
    """Map logical axis names to mesh axes via rules. None -> replicated dim."""
    out = []
    for name in logical_axes:
        if name is None:
            out.append(None)
        else:
            out.append(rules.get(name))
    # trim trailing Nones for a tidy spec
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def specs_to_pspecs(specs, rules: Mapping[str, Any]):
    return jax.tree.map(
        lambda s: logical_to_pspec(s.logical_axes or (None,) * len(s.shape), rules),
        specs,
        is_leaf=is_spec,
    )


def specs_to_shardings(specs, rules: Mapping[str, Any], mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(
            mesh, logical_to_pspec(s.logical_axes or (None,) * len(s.shape), rules)
        ),
        specs,
        is_leaf=is_spec,
    )


def param_count(tree) -> int:
    leaves = jax.tree.leaves(tree)
    n = 0
    for x in leaves:
        if isinstance(x, ParamSpec):
            n += math.prod(x.shape)
        else:
            n += x.size
    return n


def param_bytes(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    n = 0
    for x in leaves:
        if isinstance(x, ParamSpec):
            n += math.prod(x.shape) * jnp.dtype(x.dtype).itemsize
        else:
            n += x.size * x.dtype.itemsize
    return n


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )
