from repro.checkpoint.store import (
    AsyncCheckpointer,
    latest_step,
    restore,
    save,
    valid_steps,
)

__all__ = ["AsyncCheckpointer", "latest_step", "restore", "save",
           "valid_steps"]
