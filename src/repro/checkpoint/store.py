"""Sharded, atomic, integrity-checked checkpoint store.

Layout (one directory per step):

    ckpt_dir/
      step_000042/
        MANIFEST.json        # leaf paths, shapes, dtypes, chunking, crc32
        leaf_00000.c00.npy   # chunk files (split along axis 0, ~64MB each)
        ...
      LATEST                 # atomically-updated pointer file

Commit protocol: write everything into ``step_N.tmp/``, fsync, rename to
``step_N/`` (atomic on POSIX), then rewrite LATEST via tmp+rename.  A crash
at any point leaves either the old or the new checkpoint fully valid.

Restore is *elastic*: chunk files reassemble the full logical array, which
is then ``device_put`` with whatever sharding the current mesh prescribes —
restoring a 16x16 checkpoint into a 4x2 mesh (or vice versa) just reslices.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import zlib
from typing import Optional

import jax
import numpy as np

try:  # bf16/f8 etc. aren't native numpy dtypes
    import ml_dtypes
except ImportError:  # pragma: no cover
    ml_dtypes = None

CHUNK_BYTES = 64 << 20


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        if ml_dtypes is not None and hasattr(ml_dtypes, name):
            return np.dtype(getattr(ml_dtypes, name))
        raise


def _save_chunk(path, chunk: np.ndarray):
    """Serialize via raw bytes: robust for ml_dtypes (bf16) round-trips."""
    np.save(path, np.frombuffer(
        np.ascontiguousarray(chunk).tobytes(), np.uint8
    ))


def _load_chunk(path, dtype: str, shape) -> np.ndarray:
    buf = np.load(path)
    return np.frombuffer(buf.tobytes(), dtype=_np_dtype(dtype)).reshape(shape)


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _leaf_paths(tree):
    paths = []
    for kp, _ in jax.tree_util.tree_flatten_with_path(tree)[0]:
        paths.append(jax.tree_util.keystr(kp))
    return paths


def save(ckpt_dir, step: int, state, *, keep: int = 3, verify: bool = True):
    """Blocking save with atomic commit. Returns the final directory."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        import shutil

        shutil.rmtree(tmp)
    tmp.mkdir()

    host_state = jax.device_get(state)
    leaves, _ = _flatten(host_state)
    names = _leaf_paths(host_state)
    manifest = {"step": step, "leaves": []}
    for i, (name, leaf) in enumerate(zip(names, leaves)):
        arr = np.asarray(leaf)
        entry = {
            "name": name,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "chunks": [],
        }
        if arr.ndim == 0 or arr.nbytes <= CHUNK_BYTES:
            splits = [(0, arr.shape[0] if arr.ndim else 0, arr)]
        else:
            rows_per = max(1, int(CHUNK_BYTES / max(arr.nbytes / arr.shape[0], 1)))
            splits = [
                (r, min(r + rows_per, arr.shape[0]),
                 arr[r : min(r + rows_per, arr.shape[0])])
                for r in range(0, arr.shape[0], rows_per)
            ]
        for ci, (r0, r1, chunk) in enumerate(splits):
            fname = f"leaf_{i:05d}.c{ci:03d}.npy"
            _save_chunk(tmp / fname, chunk)
            entry["chunks"].append({
                "file": fname, "row0": int(r0), "row1": int(r1),
                "shape": list(np.shape(chunk)),
                "crc32": (zlib.crc32(np.ascontiguousarray(chunk).tobytes())
                          if verify else None),
            })
        manifest["leaves"].append(entry)
    with open(tmp / "MANIFEST.json", "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():  # idempotent re-save of the same step
        import shutil

        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit
    _write_latest(ckpt_dir, final.name)
    _gc(ckpt_dir, keep)
    return final


def _write_latest(ckpt_dir: pathlib.Path, name: str):
    tmp = ckpt_dir / "LATEST.tmp"
    tmp.write_text(name)
    os.rename(tmp, ckpt_dir / "LATEST")


def _gc(ckpt_dir: pathlib.Path, keep: int):
    steps = sorted(d for d in ckpt_dir.iterdir()
                   if d.is_dir() and d.name.startswith("step_")
                   and not d.name.endswith(".tmp"))
    import shutil

    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(d, ignore_errors=True)


def _manifest_ok(step_dir: pathlib.Path) -> bool:
    """A checkpoint directory is usable iff its manifest parses."""
    try:
        json.loads((step_dir / "MANIFEST.json").read_text())
        return True
    except (OSError, ValueError):
        return False


def valid_steps(ckpt_dir) -> list:
    """All step numbers with a parseable MANIFEST.json, ascending."""
    ckpt_dir = pathlib.Path(ckpt_dir)
    if not ckpt_dir.is_dir():
        return []
    out = []
    for d in ckpt_dir.iterdir():
        if (d.is_dir() and d.name.startswith("step_")
                and not d.name.endswith(".tmp") and _manifest_ok(d)):
            try:
                out.append(int(d.name.split("_")[1]))
            except (IndexError, ValueError):
                continue
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    """Newest usable checkpoint step, or None.

    Follows the LATEST pointer when it names a directory with a valid
    manifest; when the pointer is missing, dangling or points at a corrupt
    directory, falls back to scanning for the newest ``step_*`` directory
    whose MANIFEST.json parses — older valid checkpoints stay reachable
    even after the newest one is damaged.
    """
    ckpt_dir = pathlib.Path(ckpt_dir)
    ptr = ckpt_dir / "LATEST"
    if ptr.exists():
        name = ptr.read_text().strip()
        if _manifest_ok(ckpt_dir / name):
            return int(name.split("_")[1])
    steps = valid_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir, step: int, target_tree, *, shardings=None,
            verify: bool = True):
    """Restore into the structure of ``target_tree``.

    ``target_tree`` provides the pytree structure (values ignored);
    ``shardings`` (same structure, optional) gives per-leaf shardings for
    elastic placement onto the current mesh.

    ``verify`` (default on, matching ``save``) recomputes each chunk's
    crc32 against the manifest and raises ``IOError`` on mismatch — silent
    bit-rot never reaches the restored pytree.  Pass ``verify=False`` only
    to skip the checksum pass on trusted local storage.
    """
    d = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "MANIFEST.json").read_text())
    leaves, treedef = _flatten(target_tree)
    if len(manifest["leaves"]) != len(leaves):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target expects {len(leaves)}"
        )
    shard_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None
        else [None] * len(leaves)
    )
    out = []
    for entry, sh in zip(manifest["leaves"], shard_leaves):
        shape = tuple(entry["shape"])
        arr = np.empty(shape, _np_dtype(entry["dtype"]))
        for ch in entry["chunks"]:
            chunk = _load_chunk(d / ch["file"], entry["dtype"],
                                tuple(ch.get("shape", shape)))
            if verify and ch.get("crc32") is not None:
                crc = zlib.crc32(np.ascontiguousarray(chunk).tobytes())
                if crc != ch["crc32"]:
                    raise IOError(f"crc mismatch in {ch['file']}")
            if arr.ndim == 0:
                arr = chunk
            else:
                arr[ch["row0"] : ch["row1"]] = chunk
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.numpy.asarray(arr))
    return treedef.unflatten(out)


class AsyncCheckpointer:
    """Snapshot on the caller thread, serialize/commit on a worker thread."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = pathlib.Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state):
        self.wait()
        host_state = jax.device_get(state)  # consistent snapshot

        def work():
            try:
                save(self.ckpt_dir, step, host_state, keep=self.keep)
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
