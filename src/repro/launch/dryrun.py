import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.

import argparse
import json
import math
import pathlib
import sys
import time

import jax.numpy as jnp

from repro.compat import compiled_cost_analysis
from repro.configs import DONN_ARCHS, LM_ARCHS
from repro.core.config import DONNConfig
from repro.launch import mesh as mesh_mod
from repro.launch.specs import cell_status, input_specs, shapes_for
from repro.models import lm
from repro.models.config import get_config
from repro.nn import param_count
from repro.runtime import sharding as shd
from repro.runtime import steps as steps_mod
from repro.runtime.hlo_analysis import analyze

HBM_PER_CHIP = 16e9  # TPU v5e

# Per-cell memory-feasibility overrides (documented in EXPERIMENTS.md):
# microbatched gradient accumulation and/or reduced-precision optimizer
# state for the cells whose exact-f32 footprint exceeds v5e HBM on the
# single pod.  Keys: (arch, shape, multi_pod) — pod2 gets ZeRO-across-pods
# from the ("data", "pod") FSDP rule and usually needs no override.
OVERRIDES = {
    ("mixtral-8x7b", "train_4k", False): dict(accum_steps=2),
    ("mixtral-8x7b", "train_4k", True): dict(accum_steps=2),
    ("llama-3.2-vision-11b", "train_4k", False): dict(
        accum_steps=8, state_dtype=jnp.bfloat16,
        param_dtype=jnp.bfloat16,  # halves the 8x-microbatched gathers
        # (cast-at-use keeps f32 gathers: GSPMD gathers before converting)
    ),
    ("llama-3.2-vision-11b", "train_4k", True): dict(accum_steps=2),
    ("recurrentgemma-9b", "train_4k", False): dict(accum_steps=2),
    ("arctic-480b", "train_4k", False): dict(
        accum_steps=8, param_dtype=jnp.bfloat16, state_dtype=jnp.bfloat16,
        accum_dtype=jnp.bfloat16,
    ),
    ("arctic-480b", "train_4k", True): dict(accum_steps=4),
}

# Inference-side overrides: serving holds bf16 params (no f32 masters).
PREFILL_OVERRIDES = {
    ("arctic-480b", "prefill_32k"): dict(param_dtype=jnp.bfloat16),
}


# ----------------------------------------------------------- model flops
def lm_model_flops(cfg, kind: str, cell) -> tuple:
    """(N_total, N_active, MODEL_FLOPS) for the 6ND convention."""
    n = param_count(lm.param_specs(cfg))
    n_active = n
    if cfg.family == "moe":
        f = cfg.expert_d_ff or cfg.d_ff
        expert_params = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model * f
        n_active = n - expert_params * (cfg.n_experts - cfg.top_k) / cfg.n_experts
    tokens = {
        "train": cell.global_batch * cell.seq_len,
        "prefill": cell.global_batch * cell.seq_len,
        "decode": cell.global_batch,  # one new token per sequence
    }[kind]
    mult = 6.0 if kind == "train" else 2.0
    return n, n_active, mult * n_active * tokens


def donn_model_flops(cfg: DONNConfig, batch: int) -> tuple:
    """FFT2+iFFT2+ComplexMM per layer, x3 for fwd+bwd (train)."""
    n = cfg.n
    fft2 = 10.0 * n * n * math.log2(max(n, 2))  # ~5 N log N per 1-D line, 2N lines
    per_layer = 2.0 * fft2 + 6.0 * n * n  # FFT2 + iFFT2 + complex multiply
    hops = cfg.depth + 1
    chans = max(cfg.channels, 1)
    n_params = cfg.depth * n * n * chans
    flops = 3.0 * batch * chans * hops * per_layer  # train: fwd + ~2x bwd
    return n_params, n_params, flops


# ------------------------------------------------------------- one cell
def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: pathlib.Path,
             smoke: bool = False) -> dict:
    t0 = time.time()
    mesh_name = "pod2-512" if multi_pod else "pod1-256"
    cfg, cell, kind, specs = input_specs(arch, shape, smoke=smoke)
    rec = {
        "arch": arch, "shape": shape, "kind": kind, "mesh": mesh_name,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
    }
    skip = cell_status(cfg, cell)
    if skip:
        rec["status"] = skip
        return rec

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    is_donn = isinstance(cfg, DONNConfig)

    with mesh:
        if is_donn:
            # production DONN path: shard_map DP (local FFTs) — the
            # auto-sharded pjit variant is preserved as the §Perf baseline
            from repro.runtime.donn_steps import (
                compile_donn_train_step_shardmap,
            )

            fn, s_shard, b_shard, sspecs = compile_donn_train_step_shardmap(
                cfg, mesh, global_batch=cell.global_batch
            )
            state_abs = shd.abstract_like(sspecs)
            lowered = fn.lower(state_abs, specs)
        elif kind == "train":
            over = OVERRIDES.get((arch, shape, multi_pod), {})
            if over:
                rec["overrides"] = {
                    k: getattr(v, "__name__", str(v)) for k, v in over.items()
                }
            fn, s_shard, b_shard, sspecs = steps_mod.compile_train_step(
                cfg, mesh, specs, **over
            )
            state_abs = shd.abstract_like(sspecs)
            lowered = fn.lower(state_abs, specs)
        elif kind == "prefill":
            pover = PREFILL_OVERRIDES.get((arch, shape), {})
            if pover:
                rec["overrides"] = {
                    k: getattr(v, "__name__", str(v)) for k, v in pover.items()
                }
            fn, p_shard, b_shard, pspecs = steps_mod.compile_prefill_step(
                cfg, mesh, specs, **pover
            )
            params_abs = shd.abstract_like(pspecs)
            lowered = fn.lower(params_abs, specs)
        else:  # decode
            L = specs["cache"]["k"].shape[2] if "k" in specs["cache"] else 0
            fn, p_shard, c_shard, cspecs = steps_mod.compile_decode_step(
                cfg, mesh, cell.global_batch, cell.seq_len
            )
            params_abs = shd.abstract_like(lm.param_specs(cfg))
            lowered = fn.lower(
                params_abs, specs["cache"], specs["tokens"], specs["pos"]
            )
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    print(mem)  # proves it fits (per-device bytes)
    xla_cost = compiled_cost_analysis(compiled)
    print({k: xla_cost[k] for k in ("flops", "bytes accessed") if k in xla_cost})
    hlo = analyze(compiled.as_text())

    if is_donn:
        n_total, n_active, model_flops = donn_model_flops(cfg, cell.global_batch)
    else:
        n_total, n_active, model_flops = lm_model_flops(cfg, kind, cell)

    per_dev_bytes = (
        mem.argument_size_in_bytes + mem.temp_size_in_bytes
        + mem.output_size_in_bytes - mem.alias_size_in_bytes
    )
    compute_s = hlo.flops / mesh_mod.PEAK_FLOPS_BF16
    memory_s = hlo.bytes / mesh_mod.HBM_BW
    collective_s = hlo.collective_bytes / mesh_mod.ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    rec.update({
        "status": "ok",
        "chips": chips,
        "n_params": n_total,
        "n_active_params": n_active,
        "model_flops": model_flops,
        "hlo_flops_per_dev": hlo.flops,
        "hlo_dot_flops_per_dev": hlo.dot_flops,
        "hlo_bytes_per_dev": hlo.bytes,
        "collective_bytes_per_dev": hlo.collective_bytes,
        "collective_breakdown": hlo.collective_breakdown,
        "terms": terms,
        "dominant": dominant,
        "roofline_fraction": (
            (model_flops / chips / mesh_mod.PEAK_FLOPS_BF16) / bound_s
            if bound_s > 0 else 0.0
        ),
        "model_over_hlo_flops": (
            model_flops / (hlo.flops * chips) if hlo.flops else 0.0
        ),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_bytes": per_dev_bytes,
            "fits_16GiB_hbm": bool(per_dev_bytes <= HBM_PER_CHIP),
        },
        "xla_cost_raw": {
            "flops_no_tripcount": xla_cost.get("flops"),
            "bytes_no_tripcount": xla_cost.get("bytes accessed"),
        },
        "compile_wall_s": time.time() - t0,
    })
    return rec


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run driver")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = []
        for arch in LM_ARCHS + DONN_ARCHS:
            cfg = get_config(arch)
            for cell in shapes_for(cfg):
                cells.append((arch, cell.name))
    else:
        if not args.arch or not args.shape:
            ap.error("need --arch and --shape (or --all)")
        cells = [(args.arch, args.shape)]

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    failures = 0
    for arch, shape in cells:
        for multi in meshes:
            tag = f"{arch}__{shape}__{'pod2' if multi else 'pod1'}"
            path = out_dir / f"{tag}.json"
            if path.exists():
                print(f"[skip-cached] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = run_cell(arch, shape, multi, out_dir, smoke=args.smoke)
            except Exception as e:  # noqa: BLE001 — record, keep sweeping
                rec = {
                    "arch": arch, "shape": shape,
                    "mesh": "pod2-512" if multi else "pod1-256",
                    "status": f"FAIL: {type(e).__name__}: {e}",
                }
                failures += 1
            path.write_text(json.dumps(rec, indent=2, default=float))
            print(f"[done] {tag}: {rec.get('status')}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
