"""ShapeDtypeStruct input stand-ins for every (arch x shape) dry-run cell.

No device allocation: everything here is abstract.  Frontend stubs per the
assignment: vlm cells get precomputed patch embeddings, audio cells get
EnCodec token ids (which are just int tokens — the backbone is token-in).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.config import DONNConfig
from repro.models import lm
from repro.models.config import LM_SHAPES, LMConfig, ShapeCell, get_config
from repro.runtime import sharding as shd

# DONN cells use their own shape list (training emulation workloads).
DONN_SHAPES = (
    ShapeCell("train_b1024", 0, 1024, "train"),
    ShapeCell("train_b256", 0, 256, "train"),
)


def shapes_for(cfg) -> tuple:
    if isinstance(cfg, DONNConfig):
        return (DONN_SHAPES[1],) if cfg.n >= 500 else (DONN_SHAPES[0],)
    return LM_SHAPES


def cell_status(cfg, cell: ShapeCell) -> Optional[str]:
    """None if the cell runs; otherwise a documented skip reason."""
    if isinstance(cfg, DONNConfig):
        return None
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return (
            "SKIP(full-attention): 524k dense-KV decode is the quadratic-"
            "attention regime this cell excludes (DESIGN.md §5)"
        )
    return None


def lm_train_specs(cfg: LMConfig, cell: ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        specs["vision"] = jax.ShapeDtypeStruct(
            (B, cfg.vision_seq, cfg.d_model), cfg.dtype
        )
    return specs


def lm_prefill_specs(cfg: LMConfig, cell: ShapeCell):
    specs = {
        "tokens": jax.ShapeDtypeStruct((cell.global_batch, cell.seq_len), jnp.int32)
    }
    if cfg.family == "vlm":
        specs["vision"] = jax.ShapeDtypeStruct(
            (cell.global_batch, cfg.vision_seq, cfg.d_model), cfg.dtype
        )
    return specs


def lm_decode_specs(cfg: LMConfig, cell: ShapeCell):
    B = cell.global_batch
    cache = shd.abstract_like(lm.cache_specs(cfg, B, cell.seq_len))
    return {
        "tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
        "cache": cache,
    }


def donn_train_specs(cfg: DONNConfig, cell: ShapeCell):
    B = cell.global_batch
    if cfg.segmentation:
        return {
            "images": jax.ShapeDtypeStruct((B, cfg.n, cfg.n), jnp.float32),
            "masks": jax.ShapeDtypeStruct((B, cfg.n, cfg.n), jnp.float32),
        }
    if cfg.channels > 1:
        return {
            "images": jax.ShapeDtypeStruct(
                (B, cfg.channels, cfg.n, cfg.n), jnp.float32
            ),
            "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
    return {
        "images": jax.ShapeDtypeStruct((B, cfg.n, cfg.n), jnp.float32),
        "labels": jax.ShapeDtypeStruct((B,), jnp.int32),
    }


def input_specs(arch: str, shape_name: str, smoke: bool = False):
    """(arch, shape) -> (cfg, cell, kind, specs dict)."""
    cfg = get_config(arch, smoke=smoke)
    cells = {c.name: c for c in shapes_for(cfg)}
    if shape_name not in cells:
        raise KeyError(f"{arch}: unknown shape {shape_name!r} (has {list(cells)})")
    cell = cells[shape_name]
    if isinstance(cfg, DONNConfig):
        return cfg, cell, "train", donn_train_specs(cfg, cell)
    if cell.kind == "train":
        return cfg, cell, "train", lm_train_specs(cfg, cell)
    if cell.kind == "prefill":
        return cfg, cell, "prefill", lm_prefill_specs(cfg, cell)
    return cfg, cell, "decode", lm_decode_specs(cfg, cell)
