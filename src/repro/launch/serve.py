"""Batched serving launcher: continuous-batching-style decode loop.

Maintains a fixed pool of decode slots; finished sequences (EOS or length
budget) are immediately refilled from the request queue — the slot-level
"continuous batching" scheme of modern LLM servers, expressed over the
pjit decode step (the cache is donated, so slot refills are in-place).

Offline demo: requests are synthetic prompts; prefill runs through the
decode path token-by-token for simplicity at small scale (a separate
prefill step exists for the 32k cells in the dry-run).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import mesh as mesh_mod
from repro.models import get_config, lm
from repro.runtime import steps as steps_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    data, model = (int(x) for x in args.mesh.split("x"))
    mesh = mesh_mod.make_host_mesh(data, model)
    step_fn, p_shard, c_shard, cspecs = steps_mod.compile_decode_step(
        cfg, mesh, args.slots, args.cache_len, donate=False
    )
    params = jax.device_put(
        lm.init(cfg, jax.random.PRNGKey(args.seed)), p_shard
    )
    cache = jax.device_put(lm.init_cache(cfg, args.slots, args.cache_len),
                           c_shard)

    rng = np.random.default_rng(args.seed)
    queue = [rng.integers(0, cfg.vocab, size=args.prompt_len).astype(np.int32)
             for _ in range(args.requests)]
    slot_state = [None] * args.slots  # (request_id, tokens, emitted)
    completed, served_tokens = [], 0
    next_req = 0
    t0 = time.perf_counter()
    pos = 0

    # NOTE: single shared position counter => simple lockstep batching demo;
    # per-slot positions would need per-slot rope offsets (future work).
    current = np.zeros((args.slots, 1), np.int32)
    while len(completed) < args.requests and pos < args.cache_len - 1:
        for s in range(args.slots):
            if slot_state[s] is None and next_req < args.requests:
                slot_state[s] = [next_req, list(queue[next_req]), 0]
                current[s, 0] = slot_state[s][1][0]
                next_req += 1
        logits, cache = step_fn(params, cache, jnp.asarray(current),
                                jnp.int32(pos))
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for s in range(args.slots):
            st = slot_state[s]
            if st is None:
                continue
            rid, toks, emitted = st
            consumed = pos + 1 - (0 if emitted else 0)
            if consumed < len(toks):  # still prefill: feed next prompt token
                current[s, 0] = toks[min(consumed, len(toks) - 1)]
            else:
                current[s, 0] = int(nxt[s])
                st[2] += 1
                served_tokens += 1
                if st[2] >= args.max_new:
                    completed.append(rid)
                    slot_state[s] = None
        pos += 1
    dt = time.perf_counter() - t0
    print(f"[serve] {len(completed)}/{args.requests} requests, "
          f"{served_tokens} tokens in {dt:.2f}s "
          f"({served_tokens / max(dt, 1e-9):.1f} tok/s, "
          f"{args.slots} slots, mesh {args.mesh})")
    return served_tokens


if __name__ == "__main__":
    main()
