"""Production mesh construction (multi-pod dry-run §1).

Defined as functions (not module constants) so importing never touches jax
device state.  Production target: TPU v5e, 256 chips/pod, 16x16 (data, model)
per pod; the multi-pod mesh adds a leading "pod" axis (2 pods = 512 chips).
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh as _compat_make_mesh

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape, axes, axis_types=None):
    return _compat_make_mesh(shape, axes, axis_types=axis_types)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (host) devices exist — tests/examples."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, have {n}")
    return _compat_make_mesh((data, model), ("data", "model"))
