import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (same rule as dryrun.py).

"""§Perf hillclimb driver: named experiment variants for the three chosen
cells, each re-lowered and re-analysed like a dry-run cell.

  python -m repro.launch.perf --cell glm4 [--variant NAME] [--out DIR]

Cells (chosen per the §Perf brief):
  donn   — donn-xl-500/train_b256: most representative of the paper's
           technique; baseline is catastrophically collective-bound
           (GSPMD all-gathers the global field for every FFT).
  glm4   — glm4-9b/train_4k: representative dense-LM train, memory-bound.
  arctic — arctic-480b/train_4k: worst roofline fraction + most
           collective-bound train cell.
"""
import argparse
import dataclasses
import json
import math
import pathlib
import time

import jax.numpy as jnp

from repro.launch import mesh as mesh_mod
from repro.launch.dryrun import OVERRIDES, donn_model_flops, lm_model_flops
from repro.launch.specs import input_specs
from repro.runtime import sharding as shd
from repro.runtime import steps as steps_mod
from repro.runtime.donn_steps import (
    compile_donn_train_step, compile_donn_train_step_shardmap,
)
from repro.runtime.hlo_analysis import analyze

# variant := (name, cfg_patch, step_kwargs, use_shardmap)
VARIANTS = {
    "donn": {
        "arch": "donn-xl-500", "shape": "train_b256",
        "variants": [
            ("baseline_pjit", {}, {}, False),
            ("shardmap_dp", {}, {}, True),
        ],
    },
    "glm4": {
        "arch": "glm4-9b", "shape": "train_4k",
        "variants": [
            ("baseline", {}, {}, False),
            ("bf16_gather", {}, {"cast_params_to": jnp.bfloat16}, False),
            ("bf16_gather_chunk2048", {"attn_chunk": 2048},
             {"cast_params_to": jnp.bfloat16}, False),
            ("bf16_gather_chunk4096", {"attn_chunk": 4096},
             {"cast_params_to": jnp.bfloat16}, False),
            ("bf16_gather_accum2", {},
             {"cast_params_to": jnp.bfloat16, "accum_steps": 2}, False),
            ("bf16_gather_chunk2048_pbf16",
             {"attn_chunk": 2048, "attn_p_bf16": True},
             {"cast_params_to": jnp.bfloat16}, False),
            ("pbf16_only", {"attn_p_bf16": True}, {}, False),
        ],
    },
    "arctic": {
        "arch": "arctic-480b", "shape": "train_4k",
        "variants": [
            ("baseline_overrides", {}, {}, False),
            ("cap1.0", {"capacity_factor": 1.0}, {}, False),
            ("cap1.0_group2048",
             {"capacity_factor": 1.0, "moe_group": 2048}, {}, False),
            ("cap1.0_accum16", {"capacity_factor": 1.0},
             {"accum_steps": 16}, False),
        ],
    },
}


def run_variant(cell_key: str, name, cfg_patch, step_kwargs, use_shardmap,
                multi_pod=False):
    spec = VARIANTS[cell_key]
    arch, shape = spec["arch"], spec["shape"]
    t0 = time.time()
    cfg, cell, kind, specs = input_specs(arch, shape)
    if cfg_patch:
        cfg = dataclasses.replace(cfg, **cfg_patch)
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.devices.shape)
    is_donn = not hasattr(cfg, "family")

    with mesh:
        if is_donn:
            compile_fn = (compile_donn_train_step_shardmap if use_shardmap
                          else compile_donn_train_step)
            fn, s_shard, b_shard, sspecs = compile_fn(
                cfg, mesh, global_batch=cell.global_batch
            )
            lowered = fn.lower(shd.abstract_like(sspecs), specs)
        else:
            over = dict(OVERRIDES.get((arch, shape, multi_pod), {}))
            over.update(step_kwargs)
            fn, s_shard, b_shard, sspecs = steps_mod.compile_train_step(
                cfg, mesh, specs, **over
            )
            lowered = fn.lower(shd.abstract_like(sspecs), specs)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    hlo = analyze(compiled.as_text())
    if is_donn:
        _, _, model_flops = donn_model_flops(cfg, cell.global_batch)
    else:
        _, _, model_flops = lm_model_flops(cfg, kind, cell)
    terms = {
        "compute_s": hlo.flops / mesh_mod.PEAK_FLOPS_BF16,
        "memory_s": hlo.bytes / mesh_mod.HBM_BW,
        "collective_s": hlo.collective_bytes / mesh_mod.ICI_BW,
    }
    bound = max(terms.values())
    per_dev = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
               + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "cell": f"{arch}/{shape}", "variant": name,
        "mesh": "pod2-512" if multi_pod else "pod1-256",
        "terms": terms, "dominant": max(terms, key=terms.get),
        "bound_s": bound,
        "roofline_fraction": (model_flops / chips / mesh_mod.PEAK_FLOPS_BF16)
        / bound if bound > 0 else 0.0,
        "collective_breakdown": hlo.collective_breakdown,
        "memory_per_dev_GB": per_dev / 1e9,
        "fits_16GiB": bool(per_dev <= 16e9),
        "compile_wall_s": time.time() - t0,
    }
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(VARIANTS) + ["all"], default="all")
    ap.add_argument("--variant", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="artifacts/perf")
    args = ap.parse_args()
    out = pathlib.Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    cells = list(VARIANTS) if args.cell == "all" else [args.cell]
    for ck in cells:
        for v in VARIANTS[ck]["variants"]:
            name, cfg_patch, step_kwargs, use_sm = v[:4]
            if args.variant and name != args.variant:
                continue
            tag = f"{ck}__{name}__{'pod2' if args.multi_pod else 'pod1'}"
            path = out / f"{tag}.json"
            if path.exists():
                print(f"[skip-cached] {tag}")
                continue
            print(f"[perf] {tag} ...", flush=True)
            try:
                rec = run_variant(ck, name, cfg_patch, step_kwargs, use_sm,
                                  args.multi_pod)
            except Exception as e:  # noqa: BLE001
                rec = {"cell": ck, "variant": name,
                       "status": f"FAIL: {type(e).__name__}: {e}"}
            path.write_text(json.dumps(rec, indent=2, default=float))
            t = rec.get("terms")
            print(f"[done] {tag}: "
                  + (f"bound={rec['bound_s']:.3f}s dom={rec['dominant']} "
                     f"frac={rec['roofline_fraction']:.4f} "
                     f"mem={rec['memory_per_dev_GB']:.1f}GB"
                     if t else rec.get("status", "")), flush=True)


if __name__ == "__main__":
    main()
