"""Fault-tolerant training launcher.

Runs real training of any registered architecture (reduced or full config)
on whatever devices exist, with:
- checkpoint/restart: atomic sharded checkpoints every --ckpt-every steps,
  automatic resume from LATEST (elastic: the restore reslices to the
  current mesh, so you can restart on a different device count);
- preemption safety: SIGTERM/SIGINT triggers save-and-exit(143);
- non-finite guardrail: a NaN/inf loss rolls the run back to the last
  good checkpoint and resumes (bounded by --max-rollbacks; without a
  checkpoint to return to, the run aborts instead of training on garbage);
- straggler monitoring: per-step EMA + z-score flags;
- background prefetch of the (deterministic, per-host-shardable) synthetic
  data stream.

Example:
  PYTHONPATH=src python -m repro.launch.train --arch glm4-9b --smoke \
      --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ck --ckpt-every 50
"""
from __future__ import annotations

import argparse
import json
import math
import signal
import sys

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro.data.pipeline import Prefetcher, StepMonitor
from repro.data.synthetic import token_batch_iterator
from repro.launch import mesh as mesh_mod
from repro.models import get_config
from repro.models.config import LMConfig
from repro.optim import AdamW, warmup_cosine
from repro.runtime import sharding as shd
from repro.runtime import steps as steps_mod


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL, e.g. 4x2")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics-out", default=None)
    ap.add_argument("--max-rollbacks", type=int, default=2,
                    help="non-finite-loss recoveries before aborting")
    args = ap.parse_args(argv)

    cfg: LMConfig = get_config(args.arch, smoke=args.smoke)
    data, model = (int(x) for x in args.mesh.split("x"))
    mesh = mesh_mod.make_host_mesh(data, model)
    optimizer = AdamW(
        lr=warmup_cosine(args.lr, args.warmup, args.steps),
        weight_decay=0.01, grad_clip_norm=1.0,
    )

    batch_specs = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
    }
    if cfg.family == "vlm":
        batch_specs["vision"] = jax.ShapeDtypeStruct(
            (args.batch, cfg.vision_seq, cfg.d_model), cfg.dtype
        )
    step_fn, s_shard, b_shard, sspecs = steps_mod.compile_train_step(
        cfg, mesh, batch_specs, optimizer=optimizer, accum_steps=args.accum
    )

    # ---- init or elastic resume ----
    start_step = 0
    if args.ckpt_dir and (last := ckpt.latest_step(args.ckpt_dir)) is not None:
        print(f"[train] resuming from step {last}")
        state = ckpt.restore(
            args.ckpt_dir, last, shd.abstract_like(sspecs), shardings=s_shard
        )
        start_step = last
    else:
        state = steps_mod.init_train_state(cfg, jax.random.PRNGKey(args.seed),
                                           optimizer)
        state = jax.device_put(state, s_shard)

    # ---- preemption handling ----
    stop = {"now": False}

    def _handler(signum, frame):
        print(f"[train] signal {signum}: checkpoint-and-exit")
        stop["now"] = True

    signal.signal(signal.SIGTERM, _handler)
    signal.signal(signal.SIGINT, _handler)

    saver = ckpt.AsyncCheckpointer(args.ckpt_dir, keep=args.keep) \
        if args.ckpt_dir else None
    monitor = StepMonitor()

    def to_device(b):
        if cfg.family == "vlm":
            import numpy as np

            r = np.random.default_rng(0)
            b = dict(b)
            b["vision"] = r.normal(
                0, 1, (args.batch, cfg.vision_seq, cfg.d_model)
            ).astype("float32")
        return jax.device_put(b, b_shard)

    def make_stream(skip: int) -> Prefetcher:
        """Deterministic data stream positioned at step ``skip`` — used at
        start, on resume and again after a non-finite rollback."""
        raw_it = token_batch_iterator(args.batch, args.seq, cfg.vocab,
                                      seed=args.seed)
        for _ in range(skip):  # replay the deterministic stream
            next(raw_it)
        return Prefetcher(raw_it, depth=2, transform=to_device)

    it = make_stream(start_step)
    losses = []
    rollbacks = 0
    i = start_step
    while i < args.steps:
        batch = next(it)
        monitor.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        monitor.stop(i)
        # ---- non-finite guardrail: roll back instead of training on ----
        if not math.isfinite(loss):
            if saver:
                saver.wait()  # in-flight commit may BE the rollback target
            last = ckpt.latest_step(args.ckpt_dir) if args.ckpt_dir else None
            if last is None or rollbacks >= args.max_rollbacks:
                print(f"[train] non-finite loss at step {i} and no "
                      "rollback available; aborting", flush=True)
                raise RuntimeError(f"non-finite loss at step {i}")
            rollbacks += 1
            print(f"[train] non-finite loss at step {i}: rolling back to "
                  f"step {last} ({rollbacks}/{args.max_rollbacks})",
                  flush=True)
            state = ckpt.restore(args.ckpt_dir, last,
                                 shd.abstract_like(sspecs),
                                 shardings=s_shard)
            del losses[max(0, last - start_step):]
            it = make_stream(last)
            i = last
            continue
        losses.append(loss)
        if i % args.log_every == 0:
            print(f"step {i:5d}  loss {loss:.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"dt {monitor.ema:.3f}s", flush=True)
        if saver and ((i + 1) % args.ckpt_every == 0 or stop["now"]):
            saver.save(i + 1, state)
        if stop["now"]:
            if saver:
                saver.wait()
            print("[train] preempted; checkpoint committed")
            sys.exit(143)
        i += 1
    if saver:
        saver.save(args.steps, state)
        saver.wait()
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"stragglers {len(monitor.stragglers)}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump({"losses": losses,
                       "stragglers": monitor.stragglers}, f)
    return losses


if __name__ == "__main__":
    main()
