"""DONN serving launcher: freeze a trained model, serve a request stream.

The deployment end of the train -> freeze -> serve flow: builds a DONN
(optionally quick-trains it on the synthetic set), freezes it into a
``DeployedDONN`` artifact (codesign response + modulation planes folded
once), warms the bucketed AOT executables, then drives a synthetic
request load through the micro-batching dispatcher and reports
requests/sec plus latency percentiles — and the shed/expired counts when
the resilience knobs engage.

Artifact flow (``repro.runtime.resilience``): ``--save-artifact DIR``
persists the frozen deployment after freezing; ``--artifact DIR``
cold-starts serving from a previously saved artifact with **no model
build, training or freezing at all** — the crashed-replica recovery path.
The artifact's format version and architecture spec are validated
*before* any warmup, so a stale or corrupt artifact exits with a clear
error instead of failing mid-deploy.  ``--replicas N`` serves through the
continuous-batching ``FleetRouter`` (``repro.runtime.fleet``) over N
engine replicas instead of the single-engine ``MicroBatcher``.

Offline demo at laptop scale; the same engine objects back the
throughput benchmark (``benchmarks/bench_inference_throughput.py``).

Examples:
  PYTHONPATH=src python -m repro.launch.serve_donn --family classify \
      --n 64 --depth 4 --codesign qat --requests 256 --max-wait-ms 2 \
      --save-artifact /tmp/donn_artifact
  PYTHONPATH=src python -m repro.launch.serve_donn \
      --artifact /tmp/donn_artifact --requests 256 --max-queue 64 \
      --timeout-ms 20
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import numpy as np

from repro.core import DONNConfig, build_model
from repro.runtime.inference import (
    DEFAULT_BUCKETS, InferenceEngine, MicroBatcher, freeze,
)
from repro.runtime.resilience import (
    DeadlineExceededError, OverloadedError, load_deployed, save_deployed,
    validate_artifact,
)


def build_cfg(args) -> DONNConfig:
    kw = dict(
        name=f"serve-{args.family}", n=args.n, depth=args.depth,
        distance=args.distance, det_size=args.det_size,
        codesign=args.codesign, response_gamma=args.response_gamma,
        use_pallas=args.use_pallas,
    )
    if args.family == "rgb":
        kw["channels"] = 3
    elif args.family == "segmentation":
        kw.update(segmentation=True, skip_from=0, layer_norm=True)
    return DONNConfig(**kw)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--family", default="classify",
                    choices=("classify", "rgb", "segmentation"))
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--depth", type=int, default=4)
    ap.add_argument("--distance", type=float, default=0.05)
    ap.add_argument("--det-size", type=int, default=8)
    ap.add_argument("--codesign", default="qat")
    ap.add_argument("--response-gamma", type=float, default=1.2,
                    help="nonlinear device response (1.0 = ideal)")
    ap.add_argument("--use-pallas", action="store_true")
    ap.add_argument("--train-steps", type=int, default=0,
                    help="quick-train on synth digits before freezing")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--buckets", default=",".join(map(str, DEFAULT_BUCKETS)))
    ap.add_argument("--max-wait-ms", type=float, default=2.0)
    ap.add_argument("--max-queue", type=int, default=1024,
                    help="admission bound: beyond this, requests are shed "
                         "with OverloadedError (0 = unbounded)")
    ap.add_argument("--timeout-ms", type=float, default=0.0,
                    help="per-request deadline: undispatched requests fail "
                         "with DeadlineExceededError (0 = none)")
    ap.add_argument("--no-validate", action="store_true",
                    help="skip submit-time shape/dtype validation")
    ap.add_argument("--artifact", default=None,
                    help="serve from a saved artifact dir (skips build/"
                         "train/freeze entirely)")
    ap.add_argument("--save-artifact", default=None,
                    help="persist the frozen deployment to this dir")
    ap.add_argument("--mesh-devices", type=int, default=0,
                    help="data-parallel dispatch over N devices (0 = off)")
    ap.add_argument("--replicas", type=int, default=0,
                    help="serve through a continuous-batching FleetRouter "
                         "over N replicas (0 = single MicroBatcher)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    if args.artifact:
        # Validate format version + architecture spec BEFORE any engine
        # warmup, so a bad artifact exits cleanly instead of mid-deploy.
        try:
            meta = validate_artifact(args.artifact)
        except (FileNotFoundError, ValueError) as e:
            print(f"[serve_donn] ERROR: artifact {args.artifact!r} failed "
                  f"pre-deploy validation: {e}", file=sys.stderr)
            sys.exit(2)
        t0 = time.perf_counter()
        deployed = load_deployed(args.artifact)
        t_freeze = time.perf_counter() - t0
        print(f"[serve_donn] cold-started from {args.artifact} "
              f"(format {meta['format']}, family {meta['family']!r}) in "
              f"{t_freeze * 1e3:.0f}ms (no training state touched)")
        cfg = deployed.cfg
    else:
        cfg = build_cfg(args)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(args.seed))
        if args.train_steps > 0 and args.family == "classify":
            from repro.core.train_utils import train_classifier
            from repro.data import batch_iterator, synth_digits

            xs, ys = synth_digits(512, seed=args.seed)
            res = train_classifier(model, params,
                                   batch_iterator(xs, ys, 32, seed=1),
                                   steps=args.train_steps, lr=0.3,
                                   steps_per_call=8)
            params = res.params
            print(f"[serve_donn] trained {args.train_steps} steps "
                  f"({res.wall_time_s:.1f}s, final loss "
                  f"{res.losses[-1]:.4f})")

        t0 = time.perf_counter()
        deployed = freeze(model, params)
        jax.block_until_ready(deployed.frozen)
        t_freeze = time.perf_counter() - t0
    if args.save_artifact:
        save_deployed(deployed, args.save_artifact)
        print(f"[serve_donn] saved artifact to {args.save_artifact}")
    buckets = tuple(int(b) for b in args.buckets.split(","))
    n_replicas = max(args.replicas, 0)
    engines = []
    for _ in range(n_replicas or 1):
        engine = InferenceEngine(
            deployed, buckets=buckets,
            mesh_devices=args.mesh_devices or None,
        )
        compiles = engine.warmup()
        engines.append(engine)
    engine = engines[0]
    verb = "loaded" if args.artifact else "froze"
    print(f"[serve_donn] {verb} {cfg.name} in {t_freeze * 1e3:.0f}ms; "
          f"warmed {len(compiles)} buckets x{len(engines)} replica(s) in "
          f"{sum(compiles.values()):.2f}s")

    rng = np.random.default_rng(args.seed)
    n = cfg.input_size
    shape = ((cfg.channels, n, n) if deployed.family == "multi" else (n, n))
    reqs = [rng.random(shape, dtype=np.float32)
            for _ in range(args.requests)]

    if n_replicas:
        from repro.runtime.fleet import FleetRouter

        mb = FleetRouter(engines, max_queue=args.max_queue or None,
                         validate=not args.no_validate)
        print(f"[serve_donn] continuous-batching fleet: "
              f"{n_replicas} replica(s)")
    else:
        mb = MicroBatcher(engine, max_wait_ms=args.max_wait_ms,
                          max_queue=args.max_queue or None,
                          validate=not args.no_validate)
    timeout_ms = args.timeout_ms or None
    lat, shed, expired = [], 0, 0
    t0 = time.perf_counter()
    futs = []
    for x in reqs:
        try:
            futs.append((time.perf_counter(),
                         mb.submit(x, timeout_ms=timeout_ms)))
        except OverloadedError:
            shed += 1
    for t_sub, f in futs:
        try:
            f.result(timeout=120)
            lat.append(time.perf_counter() - t_sub)
        except DeadlineExceededError:
            expired += 1
    dt = time.perf_counter() - t0
    clean = mb.close()

    lat_ms = np.sort(np.asarray(lat)) * 1e3
    p50 = lat_ms[len(lat_ms) // 2]
    p99 = lat_ms[min(len(lat_ms) - 1, int(len(lat_ms) * 0.99))]
    rps = len(lat) / dt
    print(f"[serve_donn] {len(lat)}/{args.requests} requests served in "
          f"{dt:.2f}s ({rps:.1f} req/s; p50 {p50:.1f}ms p99 {p99:.1f}ms; "
          f"shed {shed}, expired {expired}; "
          f"{sum(e.stats['batches'] for e in engines)} batches, "
          f"{sum(e.stats['padded_rows'] for e in engines)} padded rows, "
          f"mesh={args.mesh_devices or 1}, replicas={n_replicas or 1}, "
          f"clean_close={clean})")
    return rps


if __name__ == "__main__":
    main()
