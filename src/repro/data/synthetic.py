"""Deterministic procedural datasets (offline stand-ins, DESIGN.md §6).

All generators are pure functions of (seed, index) so every host in a
distributed job can materialize its own shard without I/O, and restarts are
bitwise reproducible.

- ``synth_digits``: 10-class glyph dataset at 28x28 (MNIST/FMNIST stand-in).
  Classes are parametric stroke patterns (bars/crosses/rings/corners...) with
  per-sample jitter, thickness and noise, so the task is learnable but not
  trivial for a linear optical system.
- ``synth_rgb_scenes``: N-class RGB composition dataset (Places365 stand-in).
- ``synth_seg``: binary "buildings" segmentation dataset (CityScapes stand-in).
"""
from __future__ import annotations

import numpy as np


def _rng(seed: int, *idx: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([seed, *idx]))


# ---------------------------------------------------------------- digits ---
def _glyph(cls: int, r: np.random.Generator, size: int = 28) -> np.ndarray:
    img = np.zeros((size, size), np.float32)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32)
    cx = size / 2 + r.uniform(-2, 2)
    cy = size / 2 + r.uniform(-2, 2)
    t = r.uniform(1.6, 2.8)  # stroke thickness
    s = size * r.uniform(0.28, 0.36)  # scale
    if cls == 0:  # ring
        rad = np.hypot(xx - cx, yy - cy)
        img[np.abs(rad - s) < t] = 1.0
    elif cls == 1:  # vertical bar
        img[(np.abs(xx - cx) < t) & (np.abs(yy - cy) < s * 1.3)] = 1.0
    elif cls == 2:  # horizontal bar
        img[(np.abs(yy - cy) < t) & (np.abs(xx - cx) < s * 1.3)] = 1.0
    elif cls == 3:  # cross
        img[(np.abs(xx - cx) < t) & (np.abs(yy - cy) < s)] = 1.0
        img[(np.abs(yy - cy) < t) & (np.abs(xx - cx) < s)] = 1.0
    elif cls == 4:  # diagonal
        img[(np.abs((xx - cx) - (yy - cy)) < t * 1.2)
            & (np.abs(xx - cx) < s) & (np.abs(yy - cy) < s)] = 1.0
    elif cls == 5:  # anti-diagonal
        img[(np.abs((xx - cx) + (yy - cy)) < t * 1.2)
            & (np.abs(xx - cx) < s) & (np.abs(yy - cy) < s)] = 1.0
    elif cls == 6:  # filled square
        img[(np.abs(xx - cx) < s * 0.7) & (np.abs(yy - cy) < s * 0.7)] = 1.0
    elif cls == 7:  # two dots (top/bottom)
        for dy in (-s, s):
            rad = np.hypot(xx - cx, yy - (cy + dy))
            img[rad < t * 1.8] = 1.0
    elif cls == 8:  # L corner
        img[(np.abs(xx - (cx - s * 0.8)) < t) & (np.abs(yy - cy) < s)] = 1.0
        img[(np.abs(yy - (cy + s * 0.8)) < t) & (np.abs(xx - cx) < s)] = 1.0
    else:  # 9: T shape
        img[(np.abs(yy - (cy - s * 0.8)) < t) & (np.abs(xx - cx) < s)] = 1.0
        img[(np.abs(xx - cx) < t) & (np.abs(yy - cy) < s)] = 1.0
    noise = r.uniform(0.0, 0.15, (size, size)).astype(np.float32)
    return np.clip(img + noise * (img == 0), 0.0, 1.0)


def synth_digits(
    num: int, seed: int = 0, size: int = 28, num_classes: int = 10,
    binarize: bool = False,
) -> tuple[np.ndarray, np.ndarray]:
    """Returns (images (num, size, size) f32 in [0,1], labels (num,) i32)."""
    xs = np.empty((num, size, size), np.float32)
    ys = np.empty((num,), np.int32)
    for i in range(num):
        r = _rng(seed, i)
        cls = int(r.integers(0, num_classes))
        xs[i] = _glyph(cls, r, size)
        ys[i] = cls
    if binarize:
        xs = (xs > 0.5).astype(np.float32)
    return xs, ys


# ------------------------------------------------------------ rgb scenes ---
def synth_rgb_scenes(
    num: int, seed: int = 0, size: int = 64, num_classes: int = 6
) -> tuple[np.ndarray, np.ndarray]:
    """(num, 3, size, size) RGB compositions; class = dominant layout/palette."""
    xs = np.empty((num, 3, size, size), np.float32)
    ys = np.empty((num,), np.int32)
    yy, xx = np.mgrid[0:size, 0:size].astype(np.float32) / size
    for i in range(num):
        r = _rng(seed, i, 7)
        cls = int(r.integers(0, num_classes))
        base = r.uniform(0.05, 0.2, (3, 1, 1)).astype(np.float32)
        img = np.broadcast_to(base, (3, size, size)).copy()
        ch = cls % 3  # dominant channel
        if cls < 3:  # horizon split (sky/ground)
            h = r.uniform(0.3, 0.7)
            img[ch] += (yy < h) * r.uniform(0.5, 0.9)
            img[(ch + 1) % 3] += (yy >= h) * r.uniform(0.3, 0.6)
        else:  # radial blob scene
            cx, cy = r.uniform(0.3, 0.7, 2)
            rad = np.hypot(xx - cx, yy - cy)
            img[ch] += np.exp(-(rad**2) / r.uniform(0.02, 0.08))
        img += r.uniform(0, 0.08, img.shape).astype(np.float32)
        xs[i] = np.clip(img, 0, 1)
        ys[i] = cls
    return xs, ys


# ---------------------------------------------------------- segmentation ---
def synth_seg(
    num: int, seed: int = 0, size: int = 64
) -> tuple[np.ndarray, np.ndarray]:
    """(num, size, size) gray scenes + binary 'building' masks (num,size,size)."""
    xs = np.empty((num, size, size), np.float32)
    ms = np.empty((num, size, size), np.float32)
    yy, xx = np.mgrid[0:size, 0:size]
    for i in range(num):
        r = _rng(seed, i, 13)
        img = r.uniform(0.0, 0.25, (size, size)).astype(np.float32)
        mask = np.zeros((size, size), np.float32)
        for _ in range(int(r.integers(1, 4))):  # rectangular "buildings"
            w = int(r.integers(size // 8, size // 3))
            h = int(r.integers(size // 6, size // 2))
            x0 = int(r.integers(0, size - w))
            y0 = int(r.integers(size // 4, size - h))
            img[y0 : y0 + h, x0 : x0 + w] = r.uniform(0.6, 1.0)
            mask[y0 : y0 + h, x0 : x0 + w] = 1.0
        # distractor circles (bright but NOT buildings)
        for _ in range(int(r.integers(0, 3))):
            cx, cy = r.integers(0, size, 2)
            rad = int(r.integers(2, size // 10))
            circ = (xx - cx) ** 2 + (yy - cy) ** 2 < rad * rad
            img[circ] = r.uniform(0.5, 0.9)
        xs[i] = np.clip(img, 0, 1)
        ms[i] = mask
    return xs, ms


# ------------------------------------------------------------ lm tokens ---
def synth_tokens(
    num_seqs: int, seq_len: int, vocab: int, seed: int = 0,
    bigram_frac: float = 0.75,
) -> np.ndarray:
    """Deterministic Zipfian token stream with a planted bigram process.

    ~bigram_frac of transitions follow a fixed random bigram table (so a
    model can visibly reduce loss in a few hundred steps); the rest are
    Zipf-distributed noise.  Pure function of (seed, indices).
    """
    r = np.random.default_rng(np.random.SeedSequence([seed, 17]))
    table = r.integers(0, vocab, size=vocab)  # planted bigram successor
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    zipf_p = (1.0 / ranks) / np.sum(1.0 / ranks)
    out = np.empty((num_seqs, seq_len), np.int32)
    for i in range(num_seqs):
        rr = np.random.default_rng(np.random.SeedSequence([seed, 23, i]))
        toks = np.empty(seq_len, np.int32)
        toks[0] = rr.integers(0, vocab)
        noise = rr.choice(vocab, size=seq_len, p=zipf_p)
        use_bigram = rr.random(seq_len) < bigram_frac
        for t in range(1, seq_len):
            toks[t] = table[toks[t - 1]] if use_bigram[t] else noise[t]
        out[i] = toks
    return out


def token_batch_iterator(batch: int, seq_len: int, vocab: int, seed: int = 0,
                         host_id: int = 0, num_hosts: int = 1):
    """Infinite {"tokens", "labels"} batches; labels = next-token shift."""
    i = host_id
    while True:
        seqs = np.stack([
            synth_tokens(1, seq_len + 1, vocab, seed=seed + 7919 * (i + j))[0]
            for j in range(0, batch * num_hosts, num_hosts)
        ])
        yield {"tokens": seqs[:, :-1].astype(np.int32),
               "labels": seqs[:, 1:].astype(np.int32)}
        i += batch * num_hosts


# ------------------------------------------------------------- iterators ---
def batch_iterator(xs, ys, batch: int, seed: int = 0, host_id: int = 0,
                   num_hosts: int = 1):
    """Infinite shuffled batch iterator, shardable across hosts."""
    n = xs.shape[0]
    idx_host = np.arange(host_id, n, num_hosts)
    r = np.random.default_rng(seed + 1000 * host_id)
    while True:
        order = r.permutation(idx_host)
        for i in range(0, len(order) - batch + 1, batch):
            sel = order[i : i + batch]
            yield xs[sel], ys[sel]
