"""Data pipeline runtime: background + device prefetch, step-time monitor.

- ``Prefetcher``: a worker thread keeps a bounded queue of ready batches
  (host-side overlap); backpressure via queue bound.
- ``device_prefetch``: double-buffered *device* prefetch — ``jax.device_put``
  of batch k+1 is issued while step k computes, so host->device transfer
  overlaps compute (the feeder for the chunked training drivers).
- ``stack_batches``: groups per-step batches into stacked ``(S, B, ...)``
  chunks for the multi-step scanned train drivers
  (``repro.core.train_utils.make_train_chunk``).
- ``bucket_for`` / ``pad_batch``: shape-bucketing helpers for the serving
  path (``repro.runtime.inference``) — requests pad up to the nearest
  compiled bucket, always into a fresh buffer so donation can't alias a
  live request.
- ``StepMonitor``: EMA step-time tracker that flags straggling steps/hosts
  (z-score over a rolling window) — the hook a pod-level controller uses
  for straggler mitigation (re-shard or evict) at scale.
"""
from __future__ import annotations

import collections
import math
import queue
import threading
import time
from typing import Callable, Iterator, Optional

import jax
import numpy as np


class Prefetcher:
    def __init__(self, it: Iterator, depth: int = 2,
                 transform: Optional[Callable] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._transform = transform
        self._done = object()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for item in self._it:
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def device_prefetch(it: Iterator, size: int = 2, sharding=None):
    """Double-buffered device prefetch over an iterator of batch pytrees.

    Keeps up to ``size`` batches in flight on device: ``jax.device_put`` is
    asynchronous, so the transfer of batch k+1 (and beyond) overlaps the
    computation consuming batch k instead of serializing with it — the
    classic two-slot pipeline feeding an accelerator from host memory.
    ``sharding`` optionally places every leaf with a target sharding
    (e.g. the batch sharding of a sharded train step); ``None`` uses the
    default device.

    Yields the same pytrees as ``it``, with every leaf resident on device.
    """
    if size < 1:
        raise ValueError("device_prefetch needs size >= 1")
    put = lambda leaf: jax.device_put(leaf, sharding)
    buf: collections.deque = collections.deque()
    for item in it:
        buf.append(jax.tree.map(put, item))
        if len(buf) >= size:
            yield buf.popleft()
    while buf:
        yield buf.popleft()


def stack_batches(it: Iterator, steps_per_call: int,
                  total: Optional[int] = None):
    """Group per-step batches into stacked ``(S, B, ...)`` chunk pytrees.

    Pulls up to ``total`` batches from ``it`` (all of them when ``None``)
    and yields pytrees whose leaves gained a leading chunk axis of length
    ``steps_per_call`` (the final chunk may be shorter) — the input format
    of the multi-step scanned train drivers, which run one optimizer step
    per leading row inside a single compiled call.
    """
    if steps_per_call < 1:
        raise ValueError("stack_batches needs steps_per_call >= 1")
    chunk: list = []
    pulled = 0
    for batch in it:
        chunk.append(batch)
        pulled += 1
        if len(chunk) == steps_per_call:
            yield jax.tree.map(lambda *xs: np.stack(xs), *chunk)
            chunk = []
        if total is not None and pulled >= total:
            break
    if chunk:
        yield jax.tree.map(lambda *xs: np.stack(xs), *chunk)


def bucket_for(size: int, buckets) -> int:
    """Smallest serving bucket >= ``size`` (the largest bucket if none is).

    Shape-bucketed serving compiles one executable per bucket; a request
    batch is padded up to the bucket it lands in, and batches larger than
    the biggest bucket are chunked by the caller
    (``repro.runtime.inference.InferenceEngine``).
    """
    if size < 1:
        raise ValueError("bucket_for needs size >= 1")
    fitting = [b for b in buckets if b >= size]
    return min(fitting) if fitting else max(buckets)


def pad_batch(x: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad rows of ``x`` (B, ...) up to ``bucket`` rows (fresh buffer).

    Always returns a *new* host array — even when B == bucket — so a
    downstream donated device upload can never alias a live request
    buffer (the caller's array survives the donation; see
    tests/test_inference.py::TestDonationSafety).
    """
    x = np.asarray(x)
    if x.shape[0] > bucket:
        raise ValueError(f"batch of {x.shape[0]} does not fit bucket {bucket}")
    out = np.zeros((bucket,) + x.shape[1:], x.dtype)
    out[: x.shape[0]] = x
    return out


class StepMonitor:
    """EMA + rolling z-score step-time tracker with straggler flags."""

    def __init__(self, alpha: float = 0.1, window: int = 50,
                 z_thresh: float = 3.0):
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.ema: Optional[float] = None
        self.history: collections.deque = collections.deque(maxlen=window)
        self.stragglers: list = []
        self._t0: Optional[float] = None
        self.steps = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: Optional[int] = None) -> float:
        dt = time.perf_counter() - self._t0
        self.record(dt, step)
        return dt

    def record(self, dt: float, step: Optional[int] = None):
        self.steps += 1
        if self.ema is None:
            self.ema = dt
        if len(self.history) >= 5:
            mu = sum(self.history) / len(self.history)
            var = sum((x - mu) ** 2 for x in self.history) / len(self.history)
            sd = math.sqrt(max(var, 1e-12))
            if dt > mu + self.z_thresh * sd:
                self.stragglers.append(
                    {"step": step if step is not None else self.steps,
                     "dt": dt, "mean": mu, "z": (dt - mu) / sd}
                )
        self.history.append(dt)
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt

    @property
    def straggler_fraction(self) -> float:
        return len(self.stragglers) / max(self.steps, 1)
