"""Data pipeline runtime: background prefetch + straggler/step-time monitor.

- ``Prefetcher``: a worker thread keeps a bounded queue of ready batches
  (host->device overlap); backpressure via queue bound.
- ``StepMonitor``: EMA step-time tracker that flags straggling steps/hosts
  (z-score over a rolling window) — the hook a pod-level controller uses
  for straggler mitigation (re-shard or evict) at scale.
"""
from __future__ import annotations

import collections
import math
import queue
import threading
import time
from typing import Callable, Iterator, Optional


class Prefetcher:
    def __init__(self, it: Iterator, depth: int = 2,
                 transform: Optional[Callable] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._it = it
        self._transform = transform
        self._done = object()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        try:
            for item in self._it:
                if self._transform is not None:
                    item = self._transform(item)
                self._q.put(item)
        except BaseException as e:  # noqa: BLE001
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


class StepMonitor:
    """EMA + rolling z-score step-time tracker with straggler flags."""

    def __init__(self, alpha: float = 0.1, window: int = 50,
                 z_thresh: float = 3.0):
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.ema: Optional[float] = None
        self.history: collections.deque = collections.deque(maxlen=window)
        self.stragglers: list = []
        self._t0: Optional[float] = None
        self.steps = 0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self, step: Optional[int] = None) -> float:
        dt = time.perf_counter() - self._t0
        self.record(dt, step)
        return dt

    def record(self, dt: float, step: Optional[int] = None):
        self.steps += 1
        if self.ema is None:
            self.ema = dt
        if len(self.history) >= 5:
            mu = sum(self.history) / len(self.history)
            var = sum((x - mu) ** 2 for x in self.history) / len(self.history)
            sd = math.sqrt(max(var, 1e-12))
            if dt > mu + self.z_thresh * sd:
                self.stragglers.append(
                    {"step": step if step is not None else self.steps,
                     "dt": dt, "mean": mu, "z": (dt - mu) / sd}
                )
        self.history.append(dt)
        self.ema = (1 - self.alpha) * self.ema + self.alpha * dt

    @property
    def straggler_fraction(self) -> float:
        return len(self.stragglers) / max(self.steps, 1)
