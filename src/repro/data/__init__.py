from repro.data.synthetic import (
    batch_iterator,
    synth_digits,
    synth_rgb_scenes,
    synth_seg,
)

__all__ = ["batch_iterator", "synth_digits", "synth_rgb_scenes", "synth_seg"]
