"""Version-compatibility layer over the JAX surface this repo uses.

The codebase targets the newest JAX API names (``jax.shard_map`` with
``check_vma``, ``jax.make_mesh(..., axis_types=...)``,
``Compiled.cost_analysis()`` returning a flat dict).  Older releases --
notably 0.4.x, which the container ships -- spell these differently:

- ``shard_map`` lives in ``jax.experimental.shard_map`` and its replication
  check is called ``check_rep`` instead of ``check_vma``;
- ``jax.make_mesh`` has no ``axis_types`` parameter and
  ``jax.sharding.AxisType`` does not exist;
- ``Compiled.cost_analysis()`` returns a one-element *list* of dicts
  (one per partition) rather than the dict itself.

Everything here degrades gracefully: on a new JAX the wrappers are thin
pass-throughs, on an old one they translate.  All repo code (and the
subprocess test suites) should import these names instead of reaching for
``jax.*`` directly.
"""
from __future__ import annotations

import inspect
from typing import Any, Optional, Sequence

import jax

# ``AxisType`` only exists on newer JAX; None signals "not supported".
AxisType = getattr(jax.sharding, "AxisType", None)

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)
if _NEW_SHARD_MAP is None:
    from jax.experimental.shard_map import shard_map as _OLD_SHARD_MAP
else:
    _OLD_SHARD_MAP = None


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: Optional[bool] = None,
              **kwargs):
    """``jax.shard_map`` with the ``check_vma`` knob on every JAX version.

    On old JAX the knob is forwarded as ``check_rep`` (its former name).
    """
    if _NEW_SHARD_MAP is not None:
        if check_vma is not None:
            kwargs["check_vma"] = check_vma
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kwargs)
    if check_vma is not None:
        kwargs["check_rep"] = check_vma
    return _OLD_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, **kwargs)


_MAKE_MESH_PARAMS = inspect.signature(jax.make_mesh).parameters


def _resolve_axis_types(axis_types: Sequence[Any]):
    """Map "auto"/"explicit"/"manual" strings (or AxisType members) to enums."""
    if AxisType is None:
        return None
    out = []
    for t in axis_types:
        if isinstance(t, str):
            t = getattr(AxisType, t.capitalize())
        out.append(t)
    return tuple(out)


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              axis_types: Optional[Sequence[Any]] = None, **kwargs):
    """``jax.make_mesh`` that tolerates a missing ``axis_types`` parameter.

    ``axis_types`` entries may be ``jax.sharding.AxisType`` members or the
    strings "auto" / "explicit" / "manual"; on JAX versions without mesh
    axis types the argument is dropped (those versions behave as all-Auto,
    which is what every call site here wants).
    """
    if axis_types is not None and "axis_types" in _MAKE_MESH_PARAMS:
        resolved = _resolve_axis_types(axis_types)
        if resolved is not None:
            kwargs["axis_types"] = resolved
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def axis_size(axis_name) -> int:
    """``jax.lax.axis_size`` with a psum fallback for JAX versions without it.

    Must be called under a manual axis binding (shard_map / pmap), like the
    real thing.
    """
    fn = getattr(jax.lax, "axis_size", None)
    if fn is not None:
        return fn(axis_name)
    return jax.lax.psum(1, axis_name)


def compiled_cost_analysis(compiled) -> dict:
    """Normalized ``Compiled.cost_analysis()``: always a flat dict.

    Some JAX versions return a list with one dict per partition; single-
    partition programs get a one-element list.  Multi-partition lists are
    summed key-wise (keys are additive cost counters).
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, dict):
        return ca
    if not ca:
        return {}
    if len(ca) == 1:
        return dict(ca[0])
    out: dict = {}
    for part in ca:
        for k, v in part.items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0) + v
            else:
                out.setdefault(k, v)
    return out
