"""Int8 error-feedback gradient compression for cross-pod reduction.

At 1000+ node scale the cross-pod (DCI) links are the scarcest bandwidth;
quantizing the cross-pod gradient exchange to int8 with per-block scales
cuts that traffic 4x.  Error feedback (Seide et al. '14, Karimireddy et
al. '19) accumulates the quantization residual locally and re-injects it
next step, preserving convergence (tests/test_compression.py).

``compressed_psum_mean`` is the collective used inside a shard_map'd pod
axis: all-gather the int8 payloads + f32 scales (4x fewer bytes than an
f32 ring all-reduce) and reduce locally.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro import compat

BLOCK = 2048


def _pad_to(x, m):
    n = x.size
    pad = (-n) % m
    return jnp.pad(x.reshape(-1), (0, pad)), n


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array, int]:
    """x (any shape) -> (int8 blocks (nb, BLOCK), scales (nb,), true size)."""
    flat, n = _pad_to(x.astype(jnp.float32), BLOCK)
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32), n


def dequantize_int8(q: jax.Array, scale: jax.Array, n: int, shape, dtype):
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)[:n]
    return flat.reshape(shape).astype(dtype)


def ef_quantize(x: jax.Array, err: jax.Array):
    """Error-feedback quantize: returns (q, scale, n, new_err)."""
    comp = x.astype(jnp.float32) + err
    q, scale, n = quantize_int8(comp)
    deq = dequantize_int8(q, scale, n, x.shape, jnp.float32)
    return q, scale, n, comp - deq


def compressed_psum_mean(x: jax.Array, axis_name: str) -> jax.Array:
    """Mean over ``axis_name`` with int8-compressed exchange.

    Must run inside shard_map with ``axis_name`` a manual axis.  Payload:
    int8 blocks + f32 scales (~ x.nbytes/4 + x.nbytes/(4*BLOCK)).
    """
    g = compat.axis_size(axis_name)
    q, scale, n = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis_name)  # (g, nb, BLOCK) int8
    ss = jax.lax.all_gather(scale, axis_name)  # (g, nb)
    total = jnp.sum(qs.astype(jnp.float32) * ss[..., None], axis=0)
    flat = total.reshape(-1)[:n]
    return (flat / g).reshape(x.shape).astype(x.dtype)


def tree_compressed_psum_mean(tree, axis_name: str):
    return jax.tree.map(lambda x: compressed_psum_mean(x, axis_name), tree)


def compression_ratio(x: jax.Array) -> float:
    """Achieved wire-bytes ratio vs f32 all-reduce (per hop)."""
    q, scale, n = quantize_int8(x)
    wire = q.size + scale.size * 4
    return (n * 4) / wire
