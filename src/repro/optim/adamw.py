"""AdamW + SGD optimizers implemented from scratch (optax-style API).

An optimizer is a pair of pure functions:
    init(params) -> state
    update(grads, state, params, step) -> (new_params, new_state)

States are pytrees matching params, so they shard with the same logical-axis
rules as the parameters (ZeRO-style optimizer-state sharding falls out of the
param sharding rules for free).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip_norm: Optional[float] = None
    state_dtype: Any = jnp.float32  # bf16 option halves optimizer memory
    # leaves bigger than this get a blocked (lax.scan over axis 0) update so
    # the f32 working copies are one layer-slice at a time, not the whole
    # stacked tensor (matters for 100B+ MoE expert stacks)
    scan_threshold: int = 1 << 26

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def init(self, params) -> AdamWState:
        z = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(
            mu=jax.tree.map(z, params), nu=jax.tree.map(z, params)
        )

    def update(self, grads, state: AdamWState, params, step):
        if self.grad_clip_norm is not None:
            grads = clip_by_global_norm(grads, self.grad_clip_norm)
        b1, b2 = self.b1, self.b2
        stp = step.astype(jnp.float32) + 1.0
        c1 = 1.0 - b1**stp
        c2 = 1.0 - b2**stp
        lr = self._lr(step)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = (b1 * m.astype(jnp.float32) + (1 - b1) * g)
            v = (b2 * v.astype(jnp.float32) + (1 - b2) * g * g)
            mh = m / c1
            vh = v / c2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            new_p = p.astype(jnp.float32) - lr * (
                delta + self.weight_decay * p.astype(jnp.float32)
            )
            return (
                new_p.astype(p.dtype),
                m.astype(self.state_dtype),
                v.astype(self.state_dtype),
            )

        def _chunks(n: int, cap: int = 32) -> int:
            # largest divisor of n that is <= cap (1 => no blocking)
            for d in range(min(cap, n), 0, -1):
                if n % d == 0:
                    return d
            return 1

        def upd_maybe_scanned(p, g, m, v):
            nb = _chunks(p.shape[0]) if p.ndim >= 2 else 1
            if p.size > self.scan_threshold and nb > 1:
                # blocked in-place update: fori_loop carrying the (donated)
                # buffers and updating one axis-0 block at a time, so f32
                # working copies are block-sized (a scan's stacked ys would
                # double-buffer the whole tensor)
                rows = p.shape[0] // nb

                def body(i, st):
                    P, M, V = st
                    start = i * rows
                    sl = lambda A: jax.lax.dynamic_slice_in_dim(
                        A, start, rows, 0)
                    np_, nm, nv = upd(sl(P), sl(g), sl(M), sl(V))
                    wr = lambda A, val: jax.lax.dynamic_update_slice_in_dim(
                        A, val, start, 0)
                    return wr(P, np_), wr(M, nm), wr(V, nv)

                return jax.lax.fori_loop(0, nb, body, (p, m, v))
            return upd(p, g, m, v)

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state.mu)
        flat_v = treedef.flatten_up_to(state.nu)
        out = [upd_maybe_scanned(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, AdamWState(new_m, new_v)


@dataclasses.dataclass(frozen=True)
class SGD:
    lr: Callable | float = 1e-2
    momentum: float = 0.0
    grad_clip_norm: Optional[float] = None

    def init(self, params):
        if self.momentum == 0.0:
            return ()
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def update(self, grads, state, params, step):
        if self.grad_clip_norm is not None:
            grads = clip_by_global_norm(grads, self.grad_clip_norm)
        lr = self.lr(step) if callable(self.lr) else self.lr
        if self.momentum == 0.0:
            new_p = jax.tree.map(
                lambda p, g: (p.astype(jnp.float32) - lr * g).astype(p.dtype),
                params,
                grads,
            )
            return new_p, ()
        new_s = jax.tree.map(
            lambda s, g: self.momentum * s + g.astype(jnp.float32), state, grads
        )
        new_p = jax.tree.map(
            lambda p, s: (p.astype(jnp.float32) - lr * s).astype(p.dtype),
            params,
            new_s,
        )
        return new_p, new_s


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves)
    )


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: (g * scale).astype(g.dtype), grads)
