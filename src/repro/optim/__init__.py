from repro.optim.adamw import AdamW, SGD, clip_by_global_norm, global_norm
from repro.optim.schedules import constant, step_decay, warmup_cosine

__all__ = [
    "AdamW",
    "SGD",
    "clip_by_global_norm",
    "global_norm",
    "constant",
    "step_decay",
    "warmup_cosine",
]
