"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def warmup_cosine(peak_lr: float, warmup_steps: int, total_steps: int,
                  final_frac: float = 0.1):
    """Linear warmup then cosine decay to final_frac*peak."""

    def fn(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * jnp.minimum(1.0, (step + 1.0) / max(warmup_steps, 1))
        prog = jnp.clip(
            (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
        )
        cos = final_frac + (1.0 - final_frac) * 0.5 * (1.0 + jnp.cos(math.pi * prog))
        return jnp.where(step < warmup_steps, warm, peak_lr * cos)

    return fn


def step_decay(lr: float, decay: float, every: int):
    def fn(step):
        k = jnp.floor(jnp.asarray(step, jnp.float32) / every)
        return jnp.asarray(lr, jnp.float32) * (decay**k)

    return fn
