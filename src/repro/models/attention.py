"""Attention: GQA/MQA/MHA with chunked online-softmax (flash-style) kernels.

- ``chunked_attention``: streams KV in chunks with running (max, denom, acc)
  so the (Sq x Skv) score matrix is never materialized — required for the
  32k prefill cells.  Each chunk body is jax.checkpoint'd so reverse-mode
  stores only the O(S) carries, not the O(S*chunk) probabilities.
- Sliding-window masks (mixtral SWA / recurrentgemma local attention).
- ``decode_attention``: single-token query against a (possibly rolling) KV
  cache.
- Cross-attention (llama-3.2-vision style, with tanh gate).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig
from repro.models.layers import apply_rotary, rope_angles
from repro.nn import ParamSpec

NEG_INF = -1e30


# ------------------------------------------------------------------- specs
def attention_spec(cfg: LMConfig, cross: bool = False):
    d, H, KV, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    spec = {
        "wq": ParamSpec((d, H * Dh), jnp.float32, ("embed", "heads")),
        "wk": ParamSpec((d, KV * Dh), jnp.float32, ("embed", "kv_heads")),
        "wv": ParamSpec((d, KV * Dh), jnp.float32, ("embed", "kv_heads")),
        "wo": ParamSpec((H * Dh, d), jnp.float32, ("heads", "embed")),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((H * Dh,), jnp.float32, ("heads",), init="zeros")
        spec["bk"] = ParamSpec((KV * Dh,), jnp.float32, ("kv_heads",), init="zeros")
        spec["bv"] = ParamSpec((KV * Dh,), jnp.float32, ("kv_heads",), init="zeros")
    if cross:
        spec["gate"] = ParamSpec((1,), jnp.float32, (None,), init="zeros")
    return spec


def qkv_proj(p, x, cfg: LMConfig):
    """x (B, S, d) -> q (B,S,H,Dh), k/v (B,S,KV,Dh)."""
    dt = cfg.dtype
    B, S, _ = x.shape
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(dt)
    k = x @ p["wk"].astype(dt)
    v = x @ p["wv"].astype(dt)
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return (
        q.reshape(B, S, H, Dh),
        k.reshape(B, S, KV, Dh),
        v.reshape(B, S, KV, Dh),
    )


# ------------------------------------------------- chunked online softmax
def chunked_attention(
    q: jax.Array,  # (B, Sq, H, Dh)
    k: jax.Array,  # (B, Skv, KV, Dh)
    v: jax.Array,
    *,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    window: int = 0,
    chunk: int = 1024,
    kv_len: Optional[jax.Array] = None,  # valid cache length (decode)
    p_bf16: bool = False,
) -> jax.Array:
    B, Sq, H, Dh = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV
    chunk = min(chunk, Skv)
    if Skv % chunk:  # pad KV to a chunk multiple; padding is masked off
        pad = chunk - Skv % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        if kv_len is None:
            kv_len = jnp.asarray(Skv)
        Skv = Skv + pad
    nchunks = Skv // chunk
    qg = (q * (Dh**-0.5)).reshape(B, Sq, KV, G, Dh)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, idx):
        m, l, acc = carry
        k_c = jax.lax.dynamic_slice(k, (0, idx * chunk, 0, 0), (B, chunk, KV, Dh))
        v_c = jax.lax.dynamic_slice(v, (0, idx * chunk, 0, 0), (B, chunk, KV, Dh))
        s = jnp.einsum(
            "bqkgd,bckd->bkgqc", qg, k_c, preferred_element_type=jnp.float32
        )
        k_pos = idx * chunk + jnp.arange(chunk)
        allow = jnp.ones((Sq, chunk), bool)
        if causal:
            allow = allow & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            allow = allow & (k_pos[None, :] > q_pos[:, None] - window)
        if kv_len is not None:
            allow = allow & (k_pos[None, :] < kv_len)
        s = jnp.where(allow, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None]) * allow.astype(jnp.float32)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        if p_bf16:
            # halve the dominant HBM term (p round-trips); f32 accumulate
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(jnp.bfloat16),
                            v_c.astype(jnp.bfloat16),
                            preferred_element_type=jnp.float32)
        else:
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p, v_c.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    init = (
        jnp.full((B, KV, G, Sq), NEG_INF, jnp.float32),
        jnp.zeros((B, KV, G, Sq), jnp.float32),
        jnp.zeros((B, KV, G, Sq, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(body), init, jnp.arange(nchunks)
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, Sq, Dh)
    out = jnp.moveaxis(out, 3, 1).reshape(B, Sq, H, Dh)
    return out.astype(q.dtype)


def self_attention(
    p,
    x,
    cfg: LMConfig,
    positions: Optional[jax.Array] = None,
    window: Optional[int] = None,
    use_rope: bool = True,
):
    """Full training/prefill self-attention over x (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = qkv_proj(p, x, cfg)
    if use_rope:
        pos = positions if positions is not None else jnp.arange(S)
        cos, sin = rope_angles(cfg, pos)
        q = apply_rotary(q, cos, sin, cfg)
        k = apply_rotary(k, cos, sin, cfg)
    w = cfg.window if window is None else window
    out = chunked_attention(
        q, k, v, causal=True, window=w, chunk=cfg.attn_chunk,
        p_bf16=cfg.attn_p_bf16,
    )
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim)
    return out @ p["wo"].astype(cfg.dtype)


# ------------------------------------------------------------------ decode
def decode_self_attention(
    p,
    x,  # (B, 1, d)
    cache_k,  # (B, L, KV, Dh) — L = physical cache length
    cache_v,
    pos: jax.Array,  # scalar int32: current absolute position
    cfg: LMConfig,
    window: Optional[int] = None,
    use_rope: bool = True,
):
    """One-token decode against a (possibly rolling) KV cache.

    Returns (out (B, 1, d), new_cache_k, new_cache_v).  For sliding-window
    archs the physical cache is a rolling buffer of size `window`; writes
    wrap (pos % L) and relative positions are handled by the mask.
    """
    B = x.shape[0]
    L = cache_k.shape[1]
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    q, k, v = qkv_proj(p, x, cfg)
    if use_rope:
        posv = jnp.reshape(pos, (1,))
        cos, sin = rope_angles(cfg, posv)
        q = apply_rotary(q, cos, sin, cfg)
        k = apply_rotary(k, cos, sin, cfg)
    w = cfg.window if window is None else window
    rolling = 0 < w <= L
    slot = jnp.mod(pos, L) if rolling else pos
    from repro.runtime.sharding import constrain as _constrain

    kv_axes = ("batch", None, "kv_heads", "head")
    k = _constrain(k, kv_axes)
    v = _constrain(v, kv_axes)
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    # align q / new-kv layouts with the cache sharding so GSPMD computes
    # Dh-partial scores + a tiny all-reduce instead of "involuntarily
    # rematerializing" (all-gathering) the whole cache
    from repro.runtime.sharding import constrain

    qg = (q * (Dh**-0.5)).reshape(B, 1, KV, -1, Dh)
    qg = constrain(qg, ("batch", None, "kv_heads", None, "head"))
    s = jnp.einsum(
        "bqkgd,blkd->bkgql", qg, cache_k, preferred_element_type=jnp.float32
    )
    s = constrain(s, ("batch", "kv_heads", None, None, None))
    # absolute position of each cache slot
    idx = jnp.arange(L)
    if rolling:
        # slot i holds absolute position: largest p <= pos with p % L == i
        # (negative => the slot has never been written — mask it off)
        abs_pos = pos - jnp.mod(pos - idx, L)
    else:
        abs_pos = idx
    allow = (abs_pos >= 0) & (abs_pos <= pos)
    if w > 0:
        allow = allow & (abs_pos > pos - w)
    s = jnp.where(allow[None, None, None, None, :], s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgql,blkd->bkgqd", prob, cache_v.astype(jnp.float32))
    out = jnp.moveaxis(out, 3, 1).reshape(B, 1, cfg.n_heads * Dh).astype(x.dtype)
    return out @ p["wo"].astype(cfg.dtype), cache_k, cache_v


# ----------------------------------------------------------- cross-attend
def cross_attention(p, x, vision_kv, cfg: LMConfig):
    """x (B, S, d) attends over precomputed vision states (B, Sv, d).

    Non-causal; gated with tanh(gate) (llama-3.2-vision style).
    """
    B, S, _ = x.shape
    dt = cfg.dtype
    H, KV, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(dt)).reshape(B, S, H, Dh)
    k = (vision_kv @ p["wk"].astype(dt)).reshape(B, -1, KV, Dh)
    v = (vision_kv @ p["wv"].astype(dt)).reshape(B, -1, KV, Dh)
    out = chunked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk)
    out = out.reshape(B, S, H * Dh) @ p["wo"].astype(dt)
    return out * jnp.tanh(p["gate"].astype(dt))
