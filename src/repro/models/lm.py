"""LM model assembly: param specs, forward, decode step, loss — all families.

Layer application uses lax.scan over stacked per-layer parameters (leading
"layers" axis) so the HLO stays O(1) in depth; each block body is
jax.checkpoint'd when cfg.remat.  Heterogeneous families scan over periods:

- vlm:    periods of (cross_attn_period-1) self blocks + 1 gated cross block
- hybrid: periods of (rec, rec, attn) + trailing rec layers

The loss is a sequence-chunked softmax cross-entropy: logits are never
materialized at (B, S, V); each chunk is recomputed in the backward pass.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rg
from repro.models import ssm as ssm_mod
from repro.models.config import (
    AUDIO, DENSE, HYBRID, MOE, SSM, VLM, LMConfig,
)
from repro.models.layers import (
    apply_mlp, apply_norm, embed_spec, embed_tokens, mlp_spec, norm_spec,
    unembed,
)
from repro.nn import ParamSpec, init_params, is_spec


# ------------------------------------------------------------------ helpers
def stack_specs(spec, n: int):
    """Add a leading stacked-layer axis to every ParamSpec in a tree."""
    return jax.tree.map(
        lambda s: ParamSpec(
            (n,) + s.shape,
            s.dtype,
            ("layers",) + (s.logical_axes or (None,) * len(s.shape)),
            init=s.init,
            scale=s.scale,
        ),
        spec,
        is_leaf=is_spec,
    )


def _maybe_remat(fn, cfg: LMConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


# ------------------------------------------------------------- block specs
def dense_block_spec(cfg: LMConfig):
    return {
        "ln1": norm_spec(cfg),
        "attn": attn.attention_spec(cfg),
        "ln2": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }


def moe_block_spec(cfg: LMConfig):
    return {
        "ln1": norm_spec(cfg),
        "attn": attn.attention_spec(cfg),
        "ln2": norm_spec(cfg),
        "moe": moe_mod.moe_spec(cfg),
    }


def cross_block_spec(cfg: LMConfig):
    return {
        "ln1": norm_spec(cfg),
        "xattn": attn.attention_spec(cfg, cross=True),
        "ln2": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
        "gate_ffn": ParamSpec((1,), jnp.float32, (None,), init="zeros"),
    }


def ssm_block_spec(cfg: LMConfig):
    return {"ln1": norm_spec(cfg), "mamba": ssm_mod.mamba_spec(cfg)}


def rec_block_spec(cfg: LMConfig):
    return {
        "ln1": norm_spec(cfg),
        "rec": rg.rglru_spec(cfg),
        "ln2": norm_spec(cfg),
        "mlp": mlp_spec(cfg),
    }


def _hybrid_counts(cfg: LMConfig):
    p = len(cfg.block_pattern)
    n_periods, tail = divmod(cfg.n_layers, p)
    n_rec_per = sum(1 for b in cfg.block_pattern if b == "rec")
    assert cfg.block_pattern.count("attn") == 1 and tail < p
    return n_periods, n_rec_per, tail


def _vlm_counts(cfg: LMConfig):
    n_periods = cfg.n_layers // cfg.cross_attn_period
    self_per = cfg.cross_attn_period - 1
    assert n_periods * cfg.cross_attn_period == cfg.n_layers
    return n_periods, self_per


def param_specs(cfg: LMConfig):
    spec: dict[str, Any] = {
        "embed": embed_spec(cfg),
        "final_norm": norm_spec(cfg),
    }
    if cfg.family in (DENSE, AUDIO):
        spec["blocks"] = stack_specs(dense_block_spec(cfg), cfg.n_layers)
    elif cfg.family == MOE:
        spec["blocks"] = stack_specs(moe_block_spec(cfg), cfg.n_layers)
    elif cfg.family == SSM:
        spec["blocks"] = stack_specs(ssm_block_spec(cfg), cfg.n_layers)
    elif cfg.family == VLM:
        n_periods, self_per = _vlm_counts(cfg)
        spec["blocks"] = stack_specs(
            stack_specs(dense_block_spec(cfg), self_per), n_periods
        )
        spec["cross_blocks"] = stack_specs(cross_block_spec(cfg), n_periods)
    elif cfg.family == HYBRID:
        n_periods, n_rec_per, tail = _hybrid_counts(cfg)
        spec["rec_blocks"] = stack_specs(
            stack_specs(rec_block_spec(cfg), n_rec_per), n_periods
        )
        spec["attn_blocks"] = stack_specs(dense_block_spec(cfg), n_periods)
        if tail:
            spec["tail_rec"] = stack_specs(rec_block_spec(cfg), tail)
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return spec


def init(cfg: LMConfig, key):
    return init_params(param_specs(cfg), key)


# ---------------------------------------------------------- block applies
def _dense_block(p, x, cfg: LMConfig, window=None):
    x = x + attn.self_attention(p["attn"], apply_norm(p["ln1"], x, cfg), cfg,
                                window=window)
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
    return x, jnp.float32(0.0)


def _moe_block(p, x, cfg: LMConfig):
    x = x + attn.self_attention(p["attn"], apply_norm(p["ln1"], x, cfg), cfg)
    y, aux = moe_mod.apply_moe(p["moe"], apply_norm(p["ln2"], x, cfg), cfg)
    return x + y, aux


def _ssm_block(p, x, cfg: LMConfig):
    y, _ = ssm_mod.apply_mamba(p["mamba"], apply_norm(p["ln1"], x, cfg), cfg)
    return x + y, jnp.float32(0.0)


def _rec_block(p, x, cfg: LMConfig):
    y, _ = rg.apply_rglru_block(p["rec"], apply_norm(p["ln1"], x, cfg), cfg)
    x = x + y
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
    return x, jnp.float32(0.0)


def _cross_block(p, x, vision, cfg: LMConfig):
    x = x + attn.cross_attention(p["xattn"], apply_norm(p["ln1"], x, cfg),
                                 vision, cfg)
    dt = cfg.dtype
    x = x + jnp.tanh(p["gate_ffn"].astype(dt)) * apply_mlp(
        p["mlp"], apply_norm(p["ln2"], x, cfg), cfg
    )
    return x, jnp.float32(0.0)


def _seq_shard(x):
    """Sequence-parallel residual stream at layer boundaries (DESIGN.md §8).

    Saved scan carries shard S over the TP axis; no-op without a mesh
    context or when S doesn't divide (e.g. decode S=1)."""
    from repro.runtime.sharding import constrain

    return constrain(x, ("batch", "seq", None))


# ------------------------------------------------------------ full forward
def forward(params, tokens, cfg: LMConfig, vision: Optional[jax.Array] = None):
    """tokens (B, S) -> final hidden states (B, S, d) [pre-unembed]."""
    x = _seq_shard(embed_tokens(params["embed"], tokens, cfg))

    if cfg.family in (DENSE, AUDIO, MOE, SSM):
        body_fn = {
            DENSE: _dense_block, AUDIO: _dense_block,
            MOE: _moe_block, SSM: _ssm_block,
        }[cfg.family]

        def body(carry, lp):
            x, aux = carry
            x, a = body_fn(lp, x, cfg)
            return (_seq_shard(x), aux + a), None

        (x, aux), _ = jax.lax.scan(
            _maybe_remat(body, cfg), (x, jnp.float32(0.0)), params["blocks"]
        )
    elif cfg.family == VLM:
        if vision is None:
            raise ValueError("vlm forward needs vision embeddings")

        def self_body(carry, lp):
            x, aux = carry
            x, a = _dense_block(lp, x, cfg)
            return (_seq_shard(x), aux + a), None

        def period(carry, lps):
            # remat at the PERIOD level: only period-boundary activations
            # are saved; the inner per-layer carries recompute in backward
            self_p, cross_p = lps
            carry, _ = jax.lax.scan(self_body, carry, self_p)
            x, aux = carry
            x, a = _cross_block(cross_p, x, vision, cfg)
            return (_seq_shard(x), aux + a), None

        (x, aux), _ = jax.lax.scan(
            _maybe_remat(period, cfg),
            (x, jnp.float32(0.0)),
            (params["blocks"], params["cross_blocks"]),
        )
    elif cfg.family == HYBRID:
        n_periods, n_rec_per, tail = _hybrid_counts(cfg)

        def rec_body(carry, lp):
            x, aux = carry
            x, a = _rec_block(lp, x, cfg)
            return (_seq_shard(x), aux + a), None

        def period(carry, lps):
            # period-level remat (see vlm note above)
            rec_p, attn_p = lps
            carry, _ = jax.lax.scan(rec_body, carry, rec_p)
            x, aux = carry
            x, a = _dense_block(attn_p, x, cfg, window=cfg.window)
            return (_seq_shard(x), aux + a), None

        (x, aux), _ = jax.lax.scan(
            _maybe_remat(period, cfg),
            (x, jnp.float32(0.0)),
            (params["rec_blocks"], params["attn_blocks"]),
        )
        if tail:
            (x, aux), _ = jax.lax.scan(
                _maybe_remat(rec_body, cfg), (x, aux), params["tail_rec"]
            )
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    return x, aux


def logits_fn(params, tokens, cfg: LMConfig, vision=None):
    x, _ = forward(params, tokens, cfg, vision)
    return unembed(params["embed"], x, cfg)


# ------------------------------------------------------------------- loss
def chunked_xent(params, x, labels, cfg: LMConfig, chunk: int = 512):
    """Sequence-chunked softmax cross-entropy; never stores (B, S, V)."""
    B, S, d = x.shape
    chunk = max(1, min(chunk, S))
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    Sp = S + pad
    nch = Sp // chunk
    xc = jnp.moveaxis(x.reshape(B, nch, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(B, nch, chunk), 1, 0)

    def body(carry, xs):
        xx, ll = xs
        logits = unembed(params["embed"], xx, cfg).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        valid = ll >= 0
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1
        )[..., 0]
        nll = jnp.where(valid, lse - gold, 0.0)
        return (carry[0] + jnp.sum(nll), carry[1] + jnp.sum(valid)), None

    (tot, cnt), _ = jax.lax.scan(
        jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)), (xc, lc)
    )
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(params, batch, cfg: LMConfig, aux_coef: float = 0.01):
    """batch: {"tokens": (B, S) int32, "labels": (B, S) int32 (-1 = pad)}.

    For vlm, batch also carries {"vision": (B, Sv, d)} (frontend stub).
    """
    vision = batch.get("vision")
    x, aux = forward(params, batch["tokens"], cfg, vision)
    loss = chunked_xent(params, x, batch["labels"], cfg)
    if cfg.family == MOE:
        loss = loss + aux_coef * aux
    return loss


# ------------------------------------------------------------------ cache
def cache_specs(cfg: LMConfig, batch: int, cache_len: int):
    """ShapeDtypeStruct-compatible ParamSpec tree for the decode cache."""
    KV, Dh = cfg.n_kv_heads, cfg.head_dim
    dt = cfg.dtype

    def kv(n_layers, length):
        ax = ("layers", "batch", None, "kv_heads", "head")
        return {
            "k": ParamSpec((n_layers, batch, length, KV, Dh), dt, ax, init="zeros"),
            "v": ParamSpec((n_layers, batch, length, KV, Dh), dt, ax, init="zeros"),
        }

    if cfg.family in (DENSE, AUDIO, MOE):
        L = min(cache_len, cfg.window) if cfg.window else cache_len
        return kv(cfg.n_layers, L)
    if cfg.family == SSM:
        return {
            "conv": ParamSpec(
                (cfg.n_layers, batch, cfg.d_conv - 1, cfg.d_inner),
                dt, ("layers", "batch", None, "mlp"), init="zeros",
            ),
            "h": ParamSpec(
                (cfg.n_layers, batch, cfg.d_inner, cfg.ssm_state),
                jnp.float32, ("layers", "batch", "mlp", None), init="zeros",
            ),
        }
    if cfg.family == VLM:
        n_periods, self_per = _vlm_counts(cfg)
        c = kv(n_periods * self_per, cache_len)
        c["self_shape"] = ()  # marker
        del c["self_shape"]
        # cross-attn K/V over vision states, computed once at prefill
        ax = ("layers", "batch", None, "kv_heads", "head")
        c["xk"] = ParamSpec(
            (n_periods, batch, cfg.vision_seq, KV, Dh), dt, ax, init="zeros"
        )
        c["xv"] = ParamSpec(
            (n_periods, batch, cfg.vision_seq, KV, Dh), dt, ax, init="zeros"
        )
        return c
    if cfg.family == HYBRID:
        n_periods, n_rec_per, tail = _hybrid_counts(cfg)
        L = min(cache_len, cfg.window) if cfg.window else cache_len
        rec_ax = ("layers", None, "batch", None, "mlp")
        c = kv(n_periods, L)
        c["rec_conv"] = ParamSpec(
            (n_periods, n_rec_per, batch, cfg.d_conv - 1, cfg.lru_width),
            dt, rec_ax, init="zeros",
        )
        c["rec_h"] = ParamSpec(
            (n_periods, n_rec_per, batch, cfg.lru_width),
            jnp.float32, ("layers", None, "batch", "mlp"), init="zeros",
        )
        if tail:
            c["tail_conv"] = ParamSpec(
                (tail, batch, cfg.d_conv - 1, cfg.lru_width),
                dt, ("layers", "batch", None, "mlp"), init="zeros",
            )
            c["tail_h"] = ParamSpec(
                (tail, batch, cfg.lru_width),
                jnp.float32, ("layers", "batch", "mlp"), init="zeros",
            )
        return c
    raise ValueError(cfg.family)


def init_cache(cfg: LMConfig, batch: int, cache_len: int):
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, cache_len),
        is_leaf=is_spec,
    )


# ------------------------------------------------------------ decode step
def _decode_dense_block(p, x, ck, cv, pos, cfg, window=None):
    y, ck, cv = attn.decode_self_attention(
        p["attn"], apply_norm(p["ln1"], x, cfg), ck, cv, pos, cfg,
        window=window,
    )
    x = x + y
    x = x + apply_mlp(p["mlp"], apply_norm(p["ln2"], x, cfg), cfg)
    return x, ck, cv


def _idx(a, i):
    return jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False)


def _upd(a, val, i):
    return jax.lax.dynamic_update_index_in_dim(a, val, i, 0)


def decode_step(params, cache, tokens, pos, cfg: LMConfig):
    """One decode step. tokens (B, 1), pos scalar int32.

    Returns (logits (B, 1, V), new_cache).  Mutable cache arrays travel in
    the scan *carry* and are updated in place (dynamic_update_index_in_dim),
    so a donated cache buffer is reused instead of double-buffered through
    scan xs/ys.
    """
    x = embed_tokens(params["embed"], tokens, cfg)

    if cfg.family in (DENSE, AUDIO, MOE):
        def body(carry, xs):
            x, K, V = carry
            lp, i = xs
            ck, cv = _idx(K, i), _idx(V, i)
            if cfg.family == MOE:
                y, ck, cv = attn.decode_self_attention(
                    lp["attn"], apply_norm(lp["ln1"], x, cfg), ck, cv, pos, cfg
                )
                x = x + y
                y2, _ = moe_mod.apply_moe(
                    lp["moe"], apply_norm(lp["ln2"], x, cfg), cfg
                )
                x = x + y2
            else:
                x, ck, cv = _decode_dense_block(lp, x, ck, cv, pos, cfg)
            return (x, _upd(K, ck, i), _upd(V, cv, i)), None

        n = cfg.n_layers
        (x, nk, nv), _ = jax.lax.scan(
            body, (x, cache["k"], cache["v"]),
            (params["blocks"], jnp.arange(n)),
        )
        new_cache = {"k": nk, "v": nv}
    elif cfg.family == SSM:
        def body(carry, xs):
            x, C, H = carry
            lp, i = xs
            y, (nconv, nh) = ssm_mod.apply_mamba(
                lp["mamba"], apply_norm(lp["ln1"], x, cfg), cfg,
                conv_state=_idx(C, i), ssm_state=_idx(H, i),
            )
            return (x + y, _upd(C, nconv, i), _upd(H, nh, i)), None

        (x, nc, nh), _ = jax.lax.scan(
            body, (x, cache["conv"], cache["h"]),
            (params["blocks"], jnp.arange(cfg.n_layers)),
        )
        new_cache = {"conv": nc, "h": nh}
    elif cfg.family == VLM:
        n_periods, self_per = _vlm_counts(cfg)

        def self_body(carry, xs):
            x, K, V = carry
            lp, li = xs  # li = global self-layer index
            ck, cv = _idx(K, li), _idx(V, li)
            x, ck, cv = _decode_dense_block(lp, x, ck, cv, pos, cfg)
            return (x, _upd(K, ck, li), _upd(V, cv, li)), None

        def period(carry, xs):
            x, K, V = carry
            self_p, cross_p, xk, xv, i = xs
            (x, K, V), _ = jax.lax.scan(
                self_body, (x, K, V),
                (self_p, i * self_per + jnp.arange(self_per)),
            )
            # cross-attn against cached vision K/V (non-causal, no rope)
            B = x.shape[0]
            xn = apply_norm(cross_p["ln1"], x, cfg)
            q = xn @ cross_p["xattn"]["wq"].astype(cfg.dtype)
            qg = (q.reshape(B, 1, cfg.n_heads, cfg.head_dim)
                  * (cfg.head_dim**-0.5)).reshape(
                B, 1, cfg.n_kv_heads, -1, cfg.head_dim
            )
            s = jnp.einsum("bqkgd,blkd->bkgql", qg, xk,
                           preferred_element_type=jnp.float32)
            prob = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgql,blkd->bkgqd", prob, xv.astype(jnp.float32))
            o = jnp.moveaxis(o, 3, 1).reshape(B, 1, cfg.n_heads * cfg.head_dim)
            o = (o.astype(cfg.dtype) @ cross_p["xattn"]["wo"].astype(cfg.dtype))
            x = x + o * jnp.tanh(cross_p["xattn"]["gate"].astype(cfg.dtype))
            x = x + jnp.tanh(cross_p["gate_ffn"].astype(cfg.dtype)) * apply_mlp(
                cross_p["mlp"], apply_norm(cross_p["ln2"], x, cfg), cfg
            )
            return (x, K, V), None

        (x, nk, nv), _ = jax.lax.scan(
            period, (x, cache["k"], cache["v"]),
            (params["blocks"], params["cross_blocks"],
             cache["xk"], cache["xv"], jnp.arange(n_periods)),
        )
        new_cache = dict(cache)
        new_cache["k"] = nk
        new_cache["v"] = nv
    elif cfg.family == HYBRID:
        n_periods, n_rec_per, tail = _hybrid_counts(cfg)

        def rec_block_step(lp, x, conv, h):
            y, (nconv, nh) = rg.apply_rglru_block(
                lp["rec"], apply_norm(lp["ln1"], x, cfg), cfg,
                conv_state=conv, lru_state=h,
            )
            x = x + y
            x = x + apply_mlp(lp["mlp"], apply_norm(lp["ln2"], x, cfg), cfg)
            return x, nconv, nh

        def period(carry, xs):
            x, RC, RH, K, V = carry
            rec_p, attn_p, i = xs

            def rec_body(carry2, xs2):
                x, RC, RH = carry2
                lp, j = xs2
                x, nconv, nh = rec_block_step(
                    lp, x, _idx(_idx(RC, i), j), _idx(_idx(RH, i), j)
                )
                RC = _upd(RC, _upd(_idx(RC, i), nconv, j), i)
                RH = _upd(RH, _upd(_idx(RH, i), nh, j), i)
                return (x, RC, RH), None

            (x, RC, RH), _ = jax.lax.scan(
                rec_body, (x, RC, RH), (rec_p, jnp.arange(n_rec_per))
            )
            ck, cv = _idx(K, i), _idx(V, i)
            x, ck, cv = _decode_dense_block(
                attn_p, x, ck, cv, pos, cfg, window=cfg.window
            )
            return (x, RC, RH, _upd(K, ck, i), _upd(V, cv, i)), None

        (x, nrc, nrh, nk, nv), _ = jax.lax.scan(
            period,
            (x, cache["rec_conv"], cache["rec_h"], cache["k"], cache["v"]),
            (params["rec_blocks"], params["attn_blocks"],
             jnp.arange(n_periods)),
        )
        new_cache = {"rec_conv": nrc, "rec_h": nrh, "k": nk, "v": nv}
        if tail:
            def tail_body(carry, xs):
                x, TC, TH = carry
                lp, j = xs
                x, nconv, nh = rec_block_step(lp, x, _idx(TC, j), _idx(TH, j))
                return (x, _upd(TC, nconv, j), _upd(TH, nh, j)), None

            (x, ntc, nth), _ = jax.lax.scan(
                tail_body, (x, cache["tail_conv"], cache["tail_h"]),
                (params["tail_rec"], jnp.arange(tail)),
            )
            new_cache["tail_conv"] = ntc
            new_cache["tail_h"] = nth
    else:
        raise ValueError(cfg.family)

    x = apply_norm(params["final_norm"], x, cfg)
    logits = unembed(params["embed"], x, cfg)
    return logits, new_cache
