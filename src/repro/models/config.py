"""LM architecture configuration + registry for the assigned architectures."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp

DENSE, MOE, VLM, AUDIO, SSM, HYBRID, DONN_FAMILY = (
    "dense", "moe", "vlm", "audio", "ssm", "hybrid", "donn",
)


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Architecture description covering all six assigned families."""

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int  # 0 for attention-free (ssm)
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    mlp: str = "swiglu"  # swiglu | gelu | geglu
    norm: str = "rms"  # rms | ln
    rope_theta: float = 1e4
    partial_rotary: float = 1.0  # glm4: 0.5
    tie_embeddings: bool = False
    # --- attention window (0 = full causal) ---
    window: int = 0
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    expert_d_ff: int = 0
    dense_residual_ff: int = 0  # arctic: parallel dense FFN
    capacity_factor: float = 1.25
    moe_group: int = 0  # token-group size for dispatch (0 = min(S, 4096))
    # --- VLM (cross-attention) ---
    cross_attn_period: int = 0  # 1 cross-attn layer per this many layers
    vision_seq: int = 0  # precomputed patch-embedding length (frontend stub)
    # --- SSM (mamba1) ---
    ssm_state: int = 0
    d_inner: int = 0
    d_conv: int = 4
    dt_rank: int = 0
    # --- hybrid (recurrentgemma) ---
    block_pattern: tuple = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    logit_softcap: float = 0.0
    # --- numerics ---
    norm_eps: float = 1e-6
    dtype: Any = jnp.bfloat16
    # --- runtime hints ---
    attn_chunk: int = 1024  # KV-chunk for online-softmax attention
    attn_p_bf16: bool = False  # store softmax probs bf16 for the PV matmul
    #                            (halves the dominant score-traffic term;
    #                            accumulation stays f32)
    scan_chunk: int = 128  # recurrence chunk for ssm/rglru
    remat: bool = True

    @property
    def head_dim(self) -> int:
        if self.d_head:
            return self.d_head
        return self.d_model // max(self.n_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == SSM

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell? (DESIGN.md §5)."""
        return self.family in (SSM, HYBRID) or self.window > 0


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell of the dry-run matrix."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


LM_SHAPES = (
    ShapeCell("train_4k", 4096, 256, "train"),
    ShapeCell("prefill_32k", 32768, 32, "prefill"),
    ShapeCell("decode_32k", 32768, 128, "decode"),
    ShapeCell("long_500k", 524288, 1, "decode"),
)


_REGISTRY: dict[str, Callable[[], Any]] = {}


def register(name: str):
    def deco(fn):
        _REGISTRY[name] = fn
        return fn

    return deco


def get_config(name: str, smoke: bool = False):
    """Return the registered FULL (or SMOKE) config for an architecture id."""
    if name not in _REGISTRY:
        # late-import the configs package so registration side-effects run
        import repro.configs  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    full, smoke_cfg = _REGISTRY[name]()
    return smoke_cfg if smoke else full


def list_archs() -> list[str]:
    import repro.configs  # noqa: F401

    return sorted(_REGISTRY)
