"""Shared LM building blocks: norms, MLPs, embeddings, RoPE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig
from repro.nn import ParamSpec


# ------------------------------------------------------------------- norms
def norm_spec(cfg: LMConfig, d: Optional[int] = None):
    d = d or cfg.d_model
    return {"scale": ParamSpec((d,), jnp.float32, ("embed",), init="ones")}


def apply_norm(p, x, cfg: LMConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
    else:  # rmsnorm
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- mlps
def mlp_spec(cfg: LMConfig, d_ff: Optional[int] = None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "w_gate": ParamSpec((d, f), jnp.float32, ("embed", "mlp")),
            "w_up": ParamSpec((d, f), jnp.float32, ("embed", "mlp")),
            "w_down": ParamSpec((f, d), jnp.float32, ("mlp", "embed")),
        }
    return {  # plain gelu MLP
        "w_up": ParamSpec((d, f), jnp.float32, ("embed", "mlp")),
        "b_up": ParamSpec((f,), jnp.float32, ("mlp",), init="zeros"),
        "w_down": ParamSpec((f, d), jnp.float32, ("mlp", "embed")),
        "b_down": ParamSpec((d,), jnp.float32, ("embed",), init="zeros"),
    }


def apply_mlp(p, x, cfg: LMConfig):
    dt = cfg.dtype
    if cfg.mlp in ("swiglu", "geglu"):
        g = x @ p["w_gate"].astype(dt)
        u = x @ p["w_up"].astype(dt)
        act = jax.nn.silu(g) if cfg.mlp == "swiglu" else jax.nn.gelu(g)
        return (act * u) @ p["w_down"].astype(dt)
    h = x @ p["w_up"].astype(dt) + p["b_up"].astype(dt)
    h = jax.nn.gelu(h)
    return h @ p["w_down"].astype(dt) + p["b_down"].astype(dt)


# -------------------------------------------------------------- embeddings
def embed_spec(cfg: LMConfig):
    # Sharding choices here are collective-critical (EXPERIMENTS.md §Perf):
    # - table shards on EMBED only, so the token-id gather never all-gathers
    #   the table over the vocab axis;
    # - unembed stays resident vocab-sharded (TP), so the per-chunk xent
    #   matmul is local + a small logsumexp all-reduce, instead of FSDP
    #   re-gathering the unembed inside every loss chunk.
    spec = {
        "table": ParamSpec(
            (cfg.vocab, cfg.d_model), jnp.float32, (None, "embed"),
            init="embed", scale=0.02,
        )
    }
    if not cfg.tie_embeddings:
        spec["unembed"] = ParamSpec(
            (cfg.d_model, cfg.vocab), jnp.float32, (None, "vocab"),
            init="fan_in",
        )
    return spec


def embed_tokens(p, tokens, cfg: LMConfig):
    return jnp.take(p["table"], tokens, axis=0).astype(cfg.dtype)


def unembed(p, x, cfg: LMConfig):
    if cfg.tie_embeddings:
        w = p["table"].astype(cfg.dtype).T
    else:
        w = p["unembed"].astype(cfg.dtype)
    logits = x @ w
    if cfg.logit_softcap > 0.0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c)
    return logits


# -------------------------------------------------------------------- rope
def rope_angles(cfg: LMConfig, positions: jax.Array):
    """cos/sin tables for positions (...,) -> (..., rot_dim//2)."""
    rot = int(cfg.head_dim * cfg.partial_rotary)
    rot -= rot % 2
    inv_freq = 1.0 / (
        cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot)
    )
    ang = positions.astype(jnp.float32)[..., None] * inv_freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rotary(x, cos, sin, cfg: LMConfig, use_pallas: bool = False):
    """x: (B, S, H, Dh); cos/sin: (B?, S, rot//2). Rotate-half convention.

    Partial rotary (glm4): only the first ``rot`` features rotate.
    """
    rot = 2 * cos.shape[-1]
    xr, xp = x[..., :rot], x[..., rot:]
    if use_pallas:
        from repro.kernels import ops as kops

        b, s, h, d = xr.shape
        # kernel expects (..., S, D): fold heads into batch
        xk = jnp.swapaxes(xr, 1, 2).reshape(b * h, s, d)
        ck = cos if cos.ndim == 2 else cos[0]
        out = kops.apply_rope(xk, ck.astype(x.dtype), (sin if sin.ndim == 2 else sin[0]).astype(x.dtype))
        xr = jnp.swapaxes(out.reshape(b, h, s, d), 1, 2)
    else:
        half = rot // 2
        x1, x2 = xr[..., :half], xr[..., half:]
        c = cos[..., None, :].astype(x.dtype)  # (B?, S, 1, half)
        s = sin[..., None, :].astype(x.dtype)
        if c.ndim == 3:  # (S, 1, half) -> broadcast over batch
            c, s = c[None], s[None]
        xr = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    if xp.shape[-1] == 0:
        return xr
    return jnp.concatenate([xr, xp], axis=-1)
