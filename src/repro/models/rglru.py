"""RG-LRU recurrent block (recurrentgemma-9b / Griffin).

Recurrent block: two input branches — (linear -> causal conv -> RG-LRU) and
(linear -> GeLU) — multiplied, then projected out.  The RG-LRU recurrence:

    r_t = sigmoid(blockdiag(W_a) x_t + b_a)          (recurrence gate)
    i_t = sigmoid(blockdiag(W_x) x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(-Lambda) * r_t)          (a = sigmoid(Lambda))
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Gates use block-diagonal weights with n_heads blocks (Griffin's design).
Scan is chunked like the mamba block (checkpointed chunk bodies).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig
from repro.nn import ParamSpec

RG_C = 8.0


def rglru_spec(cfg: LMConfig):
    d, lru, h = cfg.d_model, cfg.lru_width, cfg.n_heads
    bs = lru // h  # gate block size
    return {
        "w_in": ParamSpec((d, lru), jnp.float32, ("embed", "mlp")),
        "w_gate_branch": ParamSpec((d, lru), jnp.float32, ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.d_conv, lru), jnp.float32, (None, "mlp"),
                            init="normal", scale=0.5),
        "conv_b": ParamSpec((lru,), jnp.float32, ("mlp",), init="zeros"),
        "w_a": ParamSpec((h, bs, bs), jnp.float32, ("heads", None, None)),
        "b_a": ParamSpec((lru,), jnp.float32, ("mlp",), init="zeros"),
        "w_x": ParamSpec((h, bs, bs), jnp.float32, ("heads", None, None)),
        "b_x": ParamSpec((lru,), jnp.float32, ("mlp",), init="zeros"),
        "lam": ParamSpec((lru,), jnp.float32, ("mlp",), init="rglru_lambda"),
        "w_out": ParamSpec((lru, d), jnp.float32, ("mlp", "embed")),
    }


def _blockdiag(x, w, b, n_heads: int):
    """x: (B, S, lru) -> block-diagonal linear per head + bias."""
    B, S, lru = x.shape
    bs = lru // n_heads
    xh = x.reshape(B, S, n_heads, bs)
    y = jnp.einsum("bshi,hij->bshj", xh, w.astype(x.dtype))
    return y.reshape(B, S, lru) + b.astype(x.dtype)


def _lru_scan(a_t, gx, h0, chunk: int):
    """h_t = a_t h_{t-1} + gx_t; a_t, gx: (B, S, lru) f32; h0: (B, lru)."""
    B, S, lru = gx.shape
    chunk = max(1, min(chunk, S))
    pad = (-S) % chunk
    if pad:
        a_t = jnp.pad(a_t, ((0, 0), (0, pad), (0, 0)), constant_values=1.0)
        gx = jnp.pad(gx, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nch = Sp // chunk
    a_c = jnp.moveaxis(a_t.reshape(B, nch, chunk, lru), 0, 2)
    g_c = jnp.moveaxis(gx.reshape(B, nch, chunk, lru), 0, 2)

    def chunk_body(h, xs):
        ac, gc = xs

        def step(hh, ss):
            a1, g1 = ss
            hh = a1 * hh + g1
            return hh, hh

        h, ys = jax.lax.scan(step, h, (ac, gc))
        return h, ys

    h, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, (a_c, g_c))
    y = jnp.moveaxis(ys.reshape(Sp, B, lru), 0, 1)[:, :S]
    return y, h


def apply_rglru_block(
    p,
    x,
    cfg: LMConfig,
    conv_state: Optional[jax.Array] = None,
    lru_state: Optional[jax.Array] = None,
):
    """Full Griffin recurrent block. x: (B, S, d).

    Returns (out, (new_conv_state, new_lru_state)).
    """
    from repro.models.ssm import _causal_conv

    B, S, _ = x.shape
    dt = cfg.dtype
    lru = cfg.lru_width
    x1 = x @ p["w_in"].astype(dt)
    x2 = jax.nn.gelu(x @ p["w_gate_branch"].astype(dt))
    x1, new_conv = _causal_conv(x1, p["conv_w"], p["conv_b"], state=conv_state)
    # --- RG-LRU ---
    xf = x1.astype(jnp.float32)
    r = jax.nn.sigmoid(_blockdiag(xf, p["w_a"], p["b_a"], cfg.n_heads))
    i = jax.nn.sigmoid(_blockdiag(xf, p["w_x"], p["b_x"], cfg.n_heads))
    log_a = -RG_C * r * jax.nn.softplus(-p["lam"])  # (B, S, lru)
    a_t = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_t * a_t, 1e-12)) * (i * xf)
    h0 = (
        lru_state
        if lru_state is not None
        else jnp.zeros((B, lru), jnp.float32)
    )
    y, h = _lru_scan(a_t, gated, h0, cfg.scan_chunk)
    out = (y.astype(dt) * x2) @ p["w_out"].astype(dt)
    return out, (new_conv, h)
