"""Mamba-1 selective-SSM block (falcon-mamba-7b).

Training/prefill uses a chunked sequential scan: an outer lax.scan over
sequence chunks (carry = SSM state at the chunk boundary) whose body is
jax.checkpoint'd, so reverse-mode stores only O(S/chunk) boundary states,
with an inner lax.scan over steps computing the per-step discretization
(dA, dB*x) on the fly — the (B, S, d_inner, state) tensor is never
materialized.  Decode keeps (conv_state, ssm_state) and advances one step.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig
from repro.nn import ParamSpec


def mamba_spec(cfg: LMConfig):
    d, di, st, dr, dc = (
        cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.d_conv,
    )
    return {
        "in_proj": ParamSpec((d, 2 * di), jnp.float32, ("embed", "mlp")),
        "conv_w": ParamSpec((dc, di), jnp.float32, (None, "mlp"), init="normal",
                            scale=0.5),
        "conv_b": ParamSpec((di,), jnp.float32, ("mlp",), init="zeros"),
        "x_proj": ParamSpec((di, dr + 2 * st), jnp.float32, ("mlp", None)),
        "dt_w": ParamSpec((dr, di), jnp.float32, (None, "mlp")),
        "dt_b": ParamSpec((di,), jnp.float32, ("mlp",), init="normal",
                          scale=0.1),
        "A_log": ParamSpec((di, st), jnp.float32, ("mlp", None),
                           init="s4d_a_log"),
        "D": ParamSpec((di,), jnp.float32, ("mlp",), init="ones"),
        "out_proj": ParamSpec((di, d), jnp.float32, ("mlp", "embed")),
    }


def _causal_conv(x, w, b, state: Optional[jax.Array] = None):
    """Depthwise causal conv over S. x: (B, S, di), w: (dc, di).

    If ``state`` (B, dc-1, di) is given (decode), it prefixes x.
    Returns (y, new_state).
    """
    dc = w.shape[0]
    if state is not None:
        xx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    else:
        xx = jnp.pad(x, ((0, 0), (dc - 1, 0), (0, 0)))
    y = sum(
        xx[:, i : i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(dc)
    )
    new_state = xx[:, -(dc - 1) :, :] if dc > 1 else None
    return y + b.astype(x.dtype), new_state


def _selective_scan(dt, Bs, Cs, xc, A, h0, chunk: int):
    """h_t = exp(dt A) h_{t-1} + dt B_t x_t ;  y_t = (C_t . h_t).

    dt, xc: (B, S, di); Bs, Cs: (B, S, st); A: (di, st); h0: (B, di, st).
    Returns (y (B, S, di) float32, h_final).
    """
    B, S, di = xc.shape
    st = Bs.shape[-1]
    chunk = max(1, min(chunk, S))
    pad = (-S) % chunk
    if pad:
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        xc = jnp.pad(xc, ((0, 0), (0, pad), (0, 0)))
        Bs = jnp.pad(Bs, ((0, 0), (0, pad), (0, 0)))
        Cs = jnp.pad(Cs, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    nch = Sp // chunk

    def to_chunks(a):  # (B, Sp, F) -> (nch, chunk, B, F)
        return jnp.moveaxis(a.reshape(B, nch, chunk, -1), 0, 2)

    dtc, xcc, Bsc, Csc = map(to_chunks, (dt, xc, Bs, Cs))

    def chunk_body(h, xs):
        dt_c, x_c, B_c, C_c = xs  # (chunk, B, F)

        def step(hh, ss):
            dt_t, x_t, B_t, C_t = ss  # (B, di), (B, di), (B, st), (B, st)
            dA = jnp.exp(dt_t[..., None] * A)  # (B, di, st)
            hh = dA * hh + (dt_t * x_t)[..., None] * B_t[:, None, :]
            y = jnp.einsum("bds,bs->bd", hh, C_t)
            return hh, y

        h, ys = jax.lax.scan(step, h, (dt_c, x_c, B_c, C_c))
        return h, ys

    h, ys = jax.lax.scan(
        jax.checkpoint(chunk_body), h0, (dtc, xcc, Bsc, Csc)
    )
    y = jnp.moveaxis(ys.reshape(Sp, B, di), 0, 1)[:, :S]
    return y, h


def apply_mamba(
    p,
    x,
    cfg: LMConfig,
    conv_state: Optional[jax.Array] = None,
    ssm_state: Optional[jax.Array] = None,
):
    """x: (B, S, d).  Returns (out, (new_conv_state, new_ssm_state)).

    Pass states for incremental decode (S may be 1); states are None for
    training/prefill (zero-initialized internally).
    """
    B, S, _ = x.shape
    di, st, dr = cfg.d_inner, cfg.ssm_state, cfg.dt_rank
    dt_ = cfg.dtype
    xz = x @ p["in_proj"].astype(dt_)
    x_in, z = jnp.split(xz, 2, axis=-1)
    y_conv, new_conv = _causal_conv(
        x_in, p["conv_w"], p["conv_b"],
        state=conv_state,
    )
    xc = jax.nn.silu(y_conv).astype(jnp.float32)
    proj = xc.astype(dt_) @ p["x_proj"].astype(dt_)
    dt_low = proj[..., :dr].astype(jnp.float32)
    B_ssm = proj[..., dr : dr + st].astype(jnp.float32)
    C_ssm = proj[..., dr + st :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dt_low @ p["dt_w"].astype(jnp.float32) + p["dt_b"]
    )
    A = -jnp.exp(p["A_log"])  # (di, st)
    h0 = (
        ssm_state
        if ssm_state is not None
        else jnp.zeros((B, di, st), jnp.float32)
    )
    y, h = _selective_scan(dt, B_ssm, C_ssm, xc, A, h0, cfg.scan_chunk)
    y = y + p["D"] * xc
    y = (y.astype(dt_)) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_)
    return out, (new_conv, h)
