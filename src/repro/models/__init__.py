"""Seed LM model family (attention/MoE/SSM/RG-LRU stacks).

Not on the DONN reproduction path, and kept deliberately: the family is
exercised by tests/test_lm_models.py, test_lm_decode.py and the launch
dryrun/perf tools, and ROADMAP item 4b (hybrid DONN + electronic head,
arXiv 2411.05748) plans to reuse this NN code as the trained electronic
stage behind the detector. lightlint runs over these modules like any
other source — they are live fixtures, not quarantined code.
"""
from repro.models.config import LMConfig, LM_SHAPES, ShapeCell, get_config, list_archs
from repro.models import lm

__all__ = ["LMConfig", "LM_SHAPES", "ShapeCell", "get_config", "list_archs", "lm"]
