from repro.models.config import LMConfig, LM_SHAPES, ShapeCell, get_config, list_archs
from repro.models import lm

__all__ = ["LMConfig", "LM_SHAPES", "ShapeCell", "get_config", "list_archs", "lm"]
