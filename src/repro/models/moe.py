"""Mixture-of-Experts blocks (mixtral-8x7b, arctic-480b).

Capacity-based GShard-style einsum dispatch: routing lowers to one-hot
matmuls whose resharding XLA SPMD schedules (no hand-written all-to-all),
with the expert dim sharded over the "model" mesh axis (EP) and expert-
internal dims over "data" (FSDP).  Tokens are grouped (per-sequence by
default) so the dispatch/combine tensors stay O(group * E * C), and the
dispatch matmul overhead is ~S*k*cf/ (3*f) of the expert FLOPs (logged in
the roofline notes).

Returns an auxiliary load-balancing loss (Switch-style) alongside outputs.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import LMConfig
from repro.models.layers import apply_mlp, mlp_spec
from repro.nn import ParamSpec


def moe_spec(cfg: LMConfig):
    d, E = cfg.d_model, cfg.n_experts
    f = cfg.expert_d_ff or cfg.d_ff
    spec = {
        "router": ParamSpec((d, E), jnp.float32, ("embed", None)),
        "w_gate": ParamSpec((E, d, f), jnp.float32, ("expert", "embed", "mlp")),
        "w_up": ParamSpec((E, d, f), jnp.float32, ("expert", "embed", "mlp")),
        "w_down": ParamSpec((E, f, d), jnp.float32, ("expert", "mlp", "embed")),
    }
    if cfg.dense_residual_ff:
        spec["dense"] = mlp_spec(cfg, cfg.dense_residual_ff)
    return spec


def expert_capacity(cfg: LMConfig, group: int) -> int:
    c = int(math.ceil(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(4, -(-c // 4) * 4)  # multiple of 4, >= 4


def apply_moe(p, x, cfg: LMConfig, group_size: int = 0):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = cfg.dtype
    g = group_size or cfg.moe_group or min(S, 4096)
    T = B * S
    if T % g:
        g = T  # degenerate fallback (smoke shapes)
    xg = x.reshape(T // g, g, d)  # (G, g, d)

    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, g, E)
    weights, idx = jax.lax.top_k(probs, k)  # (G, g, k)
    weights = weights / jnp.maximum(
        jnp.sum(weights, axis=-1, keepdims=True), 1e-9
    )

    C = expert_capacity(cfg, g)
    eh = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # (G, g, k, E)
    # position of each (token, slot) within its expert: slot-major cumsum
    ehf = eh.reshape(-1, g * k, E)
    pos = jnp.cumsum(ehf, axis=1) - ehf  # positions start at 0
    pos = pos.reshape(-1, g, k, E)
    pos_slot = jnp.sum(pos * eh, axis=-1)  # (G, g, k)
    keep = (pos_slot < C).astype(jnp.float32)
    poh = jax.nn.one_hot(pos_slot, C, dtype=jnp.float32)  # (G, g, k, C)
    # combine[b, t, e, c] = sum_k w * keep * onehot_e * onehot_c
    combine = jnp.einsum(
        "gtke,gtkc->gtec", eh * (weights * keep)[..., None], poh
    ).astype(dt)
    dispatch = (combine > 0).astype(dt)

    # Layout (EXPERIMENTS.md §Perf/arctic): expert weights stay fully
    # resident-sharded (expert -> model axis EP, embed -> data axis); the
    # dispatched activations are constrained to match (E on model, d on
    # data) so the expert matmuls run as local partials + small
    # all-reduces instead of GSPMD all-gathering 1.6GB of expert weights
    # per layer per microbatch.
    from repro.runtime.sharding import constrain

    dispatch = constrain(dispatch, (None, None, "expert", None),
                         require="expert")
    xd = jnp.einsum("gtec,gtd->gecd", dispatch, xg.astype(dt))
    xd = constrain(xd, (None, "expert", None, "embed"),
                   require="expert")
    h = jnp.einsum("gecd,edf->gecf", xd, p["w_gate"].astype(dt))
    u = jnp.einsum("gecd,edf->gecf", xd, p["w_up"].astype(dt))
    h = constrain(h, (None, "expert", None, None), require="expert")
    u = constrain(u, (None, "expert", None, None), require="expert")
    eo = jnp.einsum(
        "gecf,efd->gecd", jax.nn.silu(h) * u, p["w_down"].astype(dt)
    )
    eo = constrain(eo, (None, "expert", None, "embed"),
                   require="expert")
    out = jnp.einsum("gtec,gecd->gtd", combine, eo).reshape(B, S, d)

    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=1)  # (G, E) mean router prob
    ce = jnp.mean(eh[:, :, 0, :], axis=1)  # (G, E) top-1 assignment fraction
    aux = E * jnp.mean(jnp.sum(me * ce, axis=-1))

    if cfg.dense_residual_ff:
        out = out + apply_mlp(p["dense"], x, cfg)
    return out, aux
