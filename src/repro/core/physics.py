"""Physics-validity validation for DONN specs (shared lint/build-time).

One validator, two consumers:

- **build time**: ``plan_from_config`` / ``dsl.from_spec`` call
  ``check_config`` on a cache miss, so a physically invalid spec fails
  with a structured :class:`PhysicsValidationError` naming the violated
  criterion instead of a shape error (or a silently aliased kernel) deep
  in ``diffraction.py``;
- **lint time**: ``tools/lightlint`` rule LR201/LR202 statically
  evaluates ``DONNConfig(...)`` call sites and JSON ``to_spec`` artifacts
  and runs the same ``validate_config`` — the criteria can never drift
  between the linter and the runtime because they are one function.

Criteria (severity in brackets):

- ``geometry`` [error] — positive plane sizes / pitches / wavelength,
  non-negative gaps (Fraunhofer needs strictly positive ``z``).
- ``sampling-aliasing`` [error] — the transfer-function sampling
  criterion for rs/fresnel hops *without* band-limiting: H(fx, fy) is
  adequately sampled only up to the critical distance
  ``z_crit = N_eff * dx^2 / wavelength`` (``N_eff = 2N`` under ``pad``);
  beyond it the angular spectrum wraps and the kernel aliases
  (Matsushima & Shimobaba 2009).  With ``band_limit=True`` the mask
  suppresses the wrapped orders, so the criterion does not apply.
- ``device-levels`` [error] — codesign quantization needs at least 2
  phase levels and at most 65536 (the ``to_slm`` uint16 export domain).
- ``stitch-undersample`` [error] — a heterogeneous stitch that resamples
  a field onto a grid more than 2x coarser undersamples it (bilinear
  resampling has no anti-alias filter); finer-or-equal and mildly
  coarser stitches are fine.
- ``fraunhofer-far-field`` [warning] — Fraunhofer hops want Fresnel
  number ``F = a^2/(wavelength*z) <= 1`` (``a`` = half-aperture); in the
  near field the single-FFT far-field pattern is not the physical field.
- ``fresnel-near-field`` [warning] — the parabolic-wavefront expansion
  needs ``z^3 >> pi*a^4/(4*wavelength)``; warn below the cube root.
- ``band-limit-collapse`` [warning] — a band-limited hop whose
  ``f_limit`` falls under 10% of grid Nyquist keeps almost no spectrum:
  the distance/pitch pair is so aggressive the mask erases the field.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import List, Sequence

ERROR = "error"
WARNING = "warning"

# ``to_slm`` exports uint8 phase indices for <=256 levels, uint16 above:
# 65536 levels is the largest device response domain it can address.
MAX_DEVICE_LEVELS = 65536
MIN_DEVICE_LEVELS = 2

# stitches coarser than this pitch ratio alias (no anti-alias filter in
# the bilinear resample operator)
MAX_STITCH_PITCH_RATIO = 2.0

# band-limit mask keeping under this fraction of grid Nyquist erases
# nearly the whole angular spectrum
BAND_LIMIT_COLLAPSE_FRAC = 0.1


@dataclasses.dataclass(frozen=True)
class PhysicsViolation:
    """One violated physics criterion, locatable to a hop in the stack."""

    criterion: str  # e.g. "sampling-aliasing"
    severity: str  # ERROR | WARNING
    where: str  # e.g. "layer 2", "detector hop"
    message: str  # human-readable, includes the numbers

    def __str__(self):
        return f"[{self.criterion}] {self.where}: {self.message}"


class PhysicsValidationError(ValueError):
    """A DONN spec violates hard physics-validity criteria.

    ``violations`` carries the structured list; the message names every
    violated criterion so callers (and users loading JSON specs) see the
    domain error, not a downstream shape/aliasing symptom.
    """

    def __init__(self, violations: Sequence[PhysicsViolation]):
        self.violations = tuple(violations)
        crits = sorted({v.criterion for v in self.violations})
        detail = "; ".join(str(v) for v in self.violations)
        super().__init__(
            f"physically invalid DONN spec ({', '.join(crits)}): {detail}"
        )


class PhysicsWarning(UserWarning):
    """Soft physics-validity criterion violated (approximation regime)."""


def critical_distance(n: int, pixel_size: float, wavelength: float,
                      pad: bool = False) -> float:
    """Max distance before the unmasked TF aliases: ``N_eff*dx^2/lambda``."""
    n_eff = 2 * n if pad else n
    return n_eff * pixel_size * pixel_size / wavelength


def fresnel_number(n: int, pixel_size: float, z: float,
                   wavelength: float) -> float:
    """``a^2/(lambda*z)`` with ``a`` = half-aperture (regime check)."""
    a = n * pixel_size / 2.0
    return a * a / (wavelength * z)


def band_limit_frequency(n: int, pixel_size: float, z: float,
                         wavelength: float, pad: bool = False) -> float:
    """Matsushima & Shimobaba band-limit ``f_limit`` for one hop [1/m]."""
    n_eff = 2 * n if pad else n
    s = n_eff * pixel_size
    return 1.0 / (wavelength * math.sqrt((2.0 * z / s) ** 2 + 1.0))


def _check_hop(out, n: int, pixel_size: float, z: float, wavelength: float,
               method: str, band_limit: bool, pad: bool, where: str):
    """Validate one free-space hop computed on an (n, pixel_size) grid."""
    if method == "fraunhofer":
        if z <= 0.0:
            out.append(PhysicsViolation(
                "geometry", ERROR, where,
                f"fraunhofer propagation needs z > 0, got {z:g} m"))
            return
        fn = fresnel_number(n, pixel_size, z, wavelength)
        if fn > 1.0:
            out.append(PhysicsViolation(
                "fraunhofer-far-field", WARNING, where,
                f"Fresnel number {fn:.3g} > 1 at z={z:g} m: the far-field "
                f"(single-FFT) pattern is not valid this close; use rs or "
                f"fresnel, or z >= {n * pixel_size / 2.0:.3g}**2/lambda = "
                f"{(n * pixel_size / 2.0) ** 2 / wavelength:.3g} m"))
        return
    if z < 0.0:
        out.append(PhysicsViolation(
            "geometry", ERROR, where,
            f"propagation distance must be >= 0, got {z:g} m"))
        return
    if z == 0.0:
        return  # identity hop: H == 1, every criterion trivially holds
    z_crit = critical_distance(n, pixel_size, wavelength, pad)
    if not band_limit and z > z_crit:
        out.append(PhysicsViolation(
            "sampling-aliasing", ERROR, where,
            f"z={z:g} m exceeds the TF sampling limit z_crit="
            f"{z_crit:.4g} m for n={n}, dx={pixel_size:g} m, "
            f"lambda={wavelength:g} m{' (padded)' if pad else ''}: the "
            f"angular-spectrum kernel aliases; enable band_limit, reduce "
            f"z, or refine the grid"))
    if band_limit:
        f_limit = band_limit_frequency(n, pixel_size, z, wavelength, pad)
        f_nyq = 1.0 / (2.0 * pixel_size)
        if f_limit < BAND_LIMIT_COLLAPSE_FRAC * f_nyq:
            out.append(PhysicsViolation(
                "band-limit-collapse", WARNING, where,
                f"band limit f_limit={f_limit:.4g}/m is below "
                f"{BAND_LIMIT_COLLAPSE_FRAC:.0%} of grid Nyquist "
                f"{f_nyq:.4g}/m at z={z:g} m: the mask erases nearly the "
                f"whole spectrum; reduce z or coarsen the grid"))
    if method == "fresnel":
        a = n * pixel_size / 2.0
        z_min = (math.pi * a ** 4 / (4.0 * wavelength)) ** (1.0 / 3.0)
        if z < z_min:
            out.append(PhysicsViolation(
                "fresnel-near-field", WARNING, where,
                f"z={z:g} m is under the Fresnel-approximation bound "
                f"(pi*a^4/(4*lambda))^(1/3)={z_min:.4g} m for half-aperture "
                f"a={a:g} m: parabolic wavefronts are inaccurate this "
                f"close; use rs"))


def validate_config(cfg) -> List[PhysicsViolation]:
    """All physics violations of a ``DONNConfig`` (empty list == valid).

    Pure function of the config value — no jax, no plan building — so it
    is equally callable from the linter's static evaluation of a config
    literal and from ``plan_from_config`` on the real object.
    """
    out: List[PhysicsViolation] = []
    if cfg.n < 2:
        out.append(PhysicsViolation(
            "geometry", ERROR, "system",
            f"system size n must be >= 2, got {cfg.n}"))
    if not cfg.pixel_size > 0.0:
        out.append(PhysicsViolation(
            "geometry", ERROR, "system",
            f"pixel_size must be > 0, got {cfg.pixel_size!r}"))
    if not cfg.wavelength > 0.0:
        out.append(PhysicsViolation(
            "geometry", ERROR, "system",
            f"wavelength must be > 0, got {cfg.wavelength!r}"))
    if out:
        return out  # derived criteria are meaningless on broken geometry

    specs = cfg.resolved_layers()
    gaps = cfg.gap_distances()
    for i, s in enumerate(specs):
        where = f"layer {i}"
        if s.size < 2 or not s.pixel_size > 0.0:
            out.append(PhysicsViolation(
                "geometry", ERROR, where,
                f"plane geometry must be positive, got size={s.size}, "
                f"pixel_size={s.pixel_size!r}"))
            return out
        _check_hop(out, s.size, s.pixel_size, s.distance, cfg.wavelength,
                   s.approximation, cfg.band_limit, cfg.pad, where)
        if s.codesign != "none":
            levels = s.device_levels
            if (levels is None or levels < MIN_DEVICE_LEVELS
                    or levels > MAX_DEVICE_LEVELS):
                out.append(PhysicsViolation(
                    "device-levels", ERROR, where,
                    f"codesign={s.codesign!r} needs "
                    f"{MIN_DEVICE_LEVELS} <= device_levels <= "
                    f"{MAX_DEVICE_LEVELS} (to_slm uint16 export domain), "
                    f"got {levels!r}"))
    # final free-space hop runs on the last layer's grid, then stitches
    # onto the detector grid
    last = specs[-1]
    _check_hop(out, last.size, last.pixel_size, gaps[-1], cfg.wavelength,
               last.approximation, cfg.band_limit, cfg.pad, "detector hop")

    # stitch compatibility along the plane chain: layer i -> layer i+1,
    # then last layer -> detector grid (the source plane IS layer 0's
    # grid, so the input embed never stitches)
    chain = [(f"layer {i}", s.size, s.pixel_size)
             for i, s in enumerate(specs)]
    chain.append(("detector", cfg.n, float(cfg.pixel_size)))
    for (name_a, _, dx_a), (name_b, _, dx_b) in zip(chain, chain[1:]):
        ratio = dx_b / dx_a
        if ratio > MAX_STITCH_PITCH_RATIO:
            out.append(PhysicsViolation(
                "stitch-undersample", ERROR, f"{name_a} -> {name_b}",
                f"resampling onto a {ratio:.3g}x coarser grid "
                f"({dx_a:g} m -> {dx_b:g} m) aliases the field (bilinear "
                f"stitches carry no anti-alias filter); keep the pitch "
                f"ratio <= {MAX_STITCH_PITCH_RATIO:g}"))
    return out


def check_config(cfg, stacklevel: int = 2) -> None:
    """Raise on hard violations, ``warnings.warn`` the soft ones.

    The build-time entry point: ``plan_from_config`` and ``dsl.from_spec``
    route every spec through here (once per plan-cache miss).
    """
    violations = validate_config(cfg)
    errors = [v for v in violations if v.severity == ERROR]
    for v in violations:
        if v.severity == WARNING:
            warnings.warn(str(v), PhysicsWarning, stacklevel=stacklevel)
    if errors:
        raise PhysicsValidationError(errors)
