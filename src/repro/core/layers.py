"""Model-level DONN layers (LightRidge `lr.layers`, Table 2).

- ``DiffractiveLayer``: free-space propagation over z followed by trainable
  phase modulation.  ``codesign="none"`` corresponds to
  ``lr.layers.diffractlayer_raw``; any quantizing mode corresponds to the
  hardware-aware ``lr.layers.diffractlayer``.
- ``Detector``: pre-defined per-class readout regions; converts the field to
  intensity and pools each region (the paper's optical/photon detector + ADC).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codesign as cd
from repro.core import diffraction as df
from repro.nn import ParamSpec


class DiffractiveLayer:
    """One diffractive layer: propagate(z) then phase-modulate.

    The transfer function is precomputed at build time (static geometry); the
    trainable parameter is the (n, n) phase map.
    """

    def __init__(
        self,
        grid: df.Grid,
        z: float,
        wavelength: float,
        method: str = df.RS,
        band_limit: bool = True,
        pad: bool = False,
        device: Optional[cd.DeviceSpec] = None,
        codesign_mode: str = "none",
        gamma: float = 1.0,
        use_pallas: bool = False,
    ):
        self.grid = grid
        self.z = z
        self.wavelength = wavelength
        self.method = method
        self.pad = pad
        self.device = device
        self.codesign_mode = codesign_mode
        self.gamma = gamma
        self.use_pallas = use_pallas
        if method == df.FRAUNHOFER:
            self.h = None  # handled by df.fraunhofer at call time
        else:
            from repro.core.propagation import cached_transfer_function

            self.h = cached_transfer_function(
                grid, z, wavelength, method, band_limit, pad=pad
            )
        self._band_limit = band_limit
        self._h_dev = None  # device-side TF, uploaded once on first use

    def param_spec(self) -> ParamSpec:
        n = self.grid.n
        return ParamSpec(
            (n, n), jnp.float32, ("field_h", "field_w"), init="uniform_phase"
        )

    def propagate(self, u: jax.Array) -> jax.Array:
        if self.method == df.FRAUNHOFER:
            return df.fraunhofer(u, self.grid, self.z, self.wavelength)
        h_dev = self._h_dev
        if h_dev is None:
            h_dev = jnp.asarray(self.h)
            # cache only concrete arrays (a jit trace yields a Tracer here)
            if not isinstance(h_dev, jax.core.Tracer):
                self._h_dev = h_dev
        if self.pad:
            n = self.grid.n
            return df.crop_field(df.propagate_tf(df.pad_field(u, n), h_dev), n)
        return df.propagate_tf(u, h_dev)

    def modulate(
        self, phi: jax.Array, u: jax.Array, rng: Optional[jax.Array] = None
    ) -> jax.Array:
        phi_eff = cd.apply_codesign(phi, self.device, self.codesign_mode, rng)
        if self.use_pallas:
            from repro.kernels import ops as kops

            ur, ui = kops.phase_apply(u.real, u.imag, phi_eff, self.gamma)
            return jax.lax.complex(ur, ui)
        mod = self.gamma * jnp.exp(1j * phi_eff.astype(jnp.complex64))
        return u * mod

    def __call__(
        self, phi: jax.Array, u: jax.Array, rng: Optional[jax.Array] = None
    ) -> jax.Array:
        return self.modulate(phi, self.propagate(u), rng)


def detector_region_coords(
    n: int, num_classes: int, det_size: int, layout: str = "grid"
) -> list[tuple[int, int]]:
    """Top-left (y, x) corners of per-class detector regions.

    "grid": classes arranged in balanced rows centered on the plane (the
    3-4-3 style layout of Lin et al. for 10 classes generalized).
    "ring": regions on a circle (alternative layout for many classes).
    """
    coords: list[tuple[int, int]] = []
    if layout == "ring":
        r = 0.33 * n
        for c in range(num_classes):
            a = 2.0 * math.pi * c / num_classes
            y = int(n / 2 + r * math.sin(a)) - det_size // 2
            x = int(n / 2 + r * math.cos(a)) - det_size // 2
            coords.append((y, x))
        return coords
    rows = max(1, int(round(math.sqrt(num_classes))))
    base, extra = divmod(num_classes, rows)
    counts = [base + (1 if i < extra else 0) for i in range(rows)]
    # interleave so middle rows get the extras (3-4-3 for 10/3)
    counts.sort()
    mid = len(counts) // 2
    ordered = sorted(range(rows), key=lambda i: abs(i - mid))
    row_counts = [0] * rows
    for cnt, i in zip(sorted(counts, reverse=True), ordered):
        row_counts[i] = cnt
    lo, hi = 0.18 * n, 0.82 * n
    ys = np.linspace(lo, hi, rows + 1)
    ys = 0.5 * (ys[:-1] + ys[1:])
    for ri, cnt in enumerate(row_counts):
        xs = np.linspace(lo, hi, cnt + 1)
        xs = 0.5 * (xs[:-1] + xs[1:])
        for x in xs:
            coords.append((int(ys[ri]) - det_size // 2, int(x) - det_size // 2))
    return coords[:num_classes]


class Detector:
    """lr.layers.detector: per-class region intensity pooling."""

    def __init__(
        self,
        grid: df.Grid,
        num_classes: int,
        det_size: int,
        layout: str = "grid",
        x_loc=None,
        y_loc=None,
        use_pallas: bool = False,
    ):
        n = grid.n
        self.grid = grid
        self.num_classes = num_classes
        self.det_size = det_size
        self.use_pallas = use_pallas
        if x_loc is not None and y_loc is not None:
            coords = list(zip(list(y_loc), list(x_loc)))
        else:
            coords = detector_region_coords(n, num_classes, det_size, layout)
        self.coords = coords
        masks = np.zeros((num_classes, n, n), np.float32)
        for c, (y, x) in enumerate(coords):
            masks[c, y : y + det_size, x : x + det_size] = 1.0
        self.masks = masks

    def __call__(self, u: jax.Array) -> jax.Array:
        """Field (..., n, n) -> per-class intensities (..., C)."""
        if self.use_pallas:
            from repro.kernels import ops as kops

            return kops.intensity_readout(u.real, u.imag, jnp.asarray(self.masks))
        inten = df.intensity(u)
        return jnp.einsum("...hw,chw->...c", inten, jnp.asarray(self.masks))

    def intensity_image(self, u: jax.Array) -> jax.Array:
        return df.intensity(u)
