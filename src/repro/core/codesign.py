"""Hardware-software codesign algorithms (LightRidge challenge 2 / §3.3).

Covers:
- SLM / device response curves: discrete phase levels with a (possibly
  nonlinear, non-unity) voltage->phase mapping, differentiably interpolated.
- Gumbel-Softmax differentiable discrete phase training ([31] in the paper).
- Quantization-aware training (straight-through rounding).
- Post-training quantization ``weight_fab`` and hardware export helpers
  (``to_slm`` level maps, ``to_3d_render`` thickness maps for THz masks).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

TWO_PI = 2.0 * math.pi


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """A phase-modulation device (SLM pixel array or printed mask).

    ``levels`` discrete states span ``phase_range``; ``response_gamma`` models
    a nonlinear voltage->phase response curve phi(v) = range * (v/(L-1))^g —
    g=1 is ideal, measured SLMs deviate (paper §2.2).
    """

    levels: int = 256
    phase_range: float = TWO_PI
    response_gamma: float = 1.0
    name: str = "slm-lc2012"

    def level_phases(self) -> np.ndarray:
        # L states tile [0, phase_range) with spacing range/L (the top state
        # wraps to 0 on the phase torus), matching the QAT rounding grid.
        v = np.arange(self.levels) / self.levels
        return (self.phase_range * v**self.response_gamma).astype(np.float32)


def slm(levels: int = 256, response_gamma: float = 1.0,
        name: str = "slm-lc2012") -> DeviceSpec:
    """High-precision spatial light modulator preset (visible-range SLM)."""
    return DeviceSpec(levels=levels, response_gamma=response_gamma, name=name)


def printed_mask(levels: int = 4, response_gamma: float = 1.0,
                 name: str = "printed-mask") -> DeviceSpec:
    """Low-precision 3D-printed THz mask preset (few thickness levels)."""
    return DeviceSpec(levels=levels, response_gamma=response_gamma, name=name)


def device_for_layer(codesign: str, levels: int,
                     response_gamma: float = 1.0) -> Optional[DeviceSpec]:
    """The DeviceSpec one layer's codesign knobs describe, or None.

    The per-layer resolver behind heterogeneous stacks: each layer of a
    mixed-device DONN (e.g. 256-level SLM front layers feeding 4-level
    printed-mask back layers) maps its own (codesign mode, levels,
    response) triple to a device, and all layers train jointly — the
    quantizers differ per layer but share one backward pass.
    """
    if codesign == "none":
        return None
    return DeviceSpec(levels=int(levels), response_gamma=float(response_gamma))


def wrap_phase(phi: jax.Array, phase_range: float = TWO_PI) -> jax.Array:
    return jnp.mod(phi, phase_range)


def quantize_qat(phi: jax.Array, dev: DeviceSpec) -> jax.Array:
    """Straight-through-estimator quantization-aware phase (QAT [28])."""
    phi_w = wrap_phase(phi, dev.phase_range)
    if dev.response_gamma == 1.0:
        step = dev.phase_range / dev.levels
        q = jnp.mod(jnp.round(phi_w / step), dev.levels) * step
    else:
        levels = jnp.asarray(dev.level_phases())
        idx = jnp.argmin(
            jnp.abs(phi_w[..., None] - levels[(None,) * phi_w.ndim]), axis=-1
        )
        q = levels[idx]
    return phi_w + jax.lax.stop_gradient(q - phi_w)


def quantize_gumbel(
    phi: jax.Array,
    dev: DeviceSpec,
    rng: Optional[jax.Array],
    tau: float = 1.0,
    hard: bool = False,
) -> jax.Array:
    """Gumbel-Softmax differentiable discrete phase ([25, 36, 31]).

    Scores are negative squared circular distances between the continuous
    phase parameter and each device level; a Gumbel-Softmax over levels gives
    a differentiable soft assignment (hard=True uses straight-through argmax).
    rng=None gives the deterministic (no-noise) relaxation — used at eval.
    """
    levels = jnp.asarray(dev.level_phases())  # (L,)
    phi_w = wrap_phase(phi, dev.phase_range)
    d = phi_w[..., None] - levels  # (..., L)
    # circular distance on the phase torus
    d = jnp.minimum(jnp.abs(d), dev.phase_range - jnp.abs(d))
    logits = -(d * d) / (0.1 * dev.phase_range / dev.levels + 1e-12)
    if rng is not None:
        g = jax.random.gumbel(rng, logits.shape, logits.dtype)
        logits = logits + g
    soft = jax.nn.softmax(logits / tau, axis=-1)
    phi_soft = jnp.sum(soft * levels, axis=-1)
    if hard:
        idx = jnp.argmax(logits, axis=-1)
        phi_hard = levels[idx]
        phi_soft = phi_soft + jax.lax.stop_gradient(phi_hard - phi_soft)
    return phi_soft


def weight_fab(phi: jax.Array, dev: DeviceSpec) -> tuple[jax.Array, jax.Array]:
    """Post-training quantization to fabrication levels (lr.layers.weight_fab).

    Returns (level_indices int32, achieved_phase float32).
    """
    levels = jnp.asarray(dev.level_phases())
    phi_w = wrap_phase(phi, dev.phase_range)
    d = phi_w[..., None] - levels
    d = jnp.minimum(jnp.abs(d), dev.phase_range - jnp.abs(d))
    idx = jnp.argmin(d, axis=-1)
    return idx.astype(jnp.int32), levels[idx]


def to_slm(phi: jax.Array, dev: DeviceSpec) -> np.ndarray:
    """Export phase map as device level indices (uint8/uint16 image)."""
    idx, _ = weight_fab(phi, dev)
    arr = np.asarray(idx)
    return arr.astype(np.uint8 if dev.levels <= 256 else np.uint16)


def to_3d_render(
    phi: jax.Array, wavelength: float, delta_n: float = 0.52
) -> np.ndarray:
    """Phase -> printed-mask thickness map t = phi * lambda / (2 pi dn) [m].

    delta_n: refractive-index contrast of the UV-curable resin (THz systems,
    paper §2.2 / Lin et al. [34]).
    """
    phi_w = np.asarray(wrap_phase(phi))
    return (phi_w * wavelength / (TWO_PI * delta_n)).astype(np.float32)


def deployed_phase(
    phi: jax.Array, dev: Optional[DeviceSpec], mode: str
) -> jax.Array:
    """Deploy-time (rng-free) device response: the phase the hardware holds.

    At deployment the device state is *statically known* — the SLM is
    programmed / the mask is printed once — so the codesign response is
    resolved a single time instead of per forward pass.  Stochastic
    training modes resolve to their deterministic eval form (Gumbel with
    no noise), matching ``apply_codesign(..., rng=None)`` bit-for-bit;
    this is the fold behind ``PropagationPlan.frozen_modulation`` and the
    ``repro.runtime.inference`` deployment engine.
    """
    return apply_codesign(phi, dev, mode, rng=None)


def apply_codesign(
    phi: jax.Array,
    dev: Optional[DeviceSpec],
    mode: str,
    rng: Optional[jax.Array] = None,
    tau: float = 1.0,
) -> jax.Array:
    """Dispatch used by the hardware-aware diffractive layer.

    mode: "none" | "qat" | "gumbel" | "gumbel_hard" | "ptq".
    """
    if dev is None or mode == "none":
        return phi
    if mode == "qat":
        return quantize_qat(phi, dev)
    if mode == "gumbel":
        return quantize_gumbel(phi, dev, rng, tau=tau, hard=False)
    if mode == "gumbel_hard":
        return quantize_gumbel(phi, dev, rng, tau=tau, hard=True)
    if mode == "ptq":
        return weight_fab(phi, dev)[1]
    raise ValueError(f"unknown codesign mode {mode!r}")
