"""LightRidge core: the paper's contribution as composable JAX modules."""
from repro.core.config import DONNConfig, LayerSpec
from repro.core.diffraction import (
    FRAUNHOFER,
    FRESNEL,
    RS,
    Grid,
    fraunhofer,
    intensity,
    propagate,
    propagate_tf,
    transfer_function,
)
from repro.core.laser import Laser, data_to_cplex
from repro.core.layers import Detector, DiffractiveLayer
from repro.core.physics import (
    PhysicsValidationError,
    PhysicsViolation,
    PhysicsWarning,
    validate_config,
)
from repro.core.models import (
    DONN,
    MultiChannelDONN,
    SegmentationDONN,
    build_model,
    cached_apply,
    cached_model,
    clear_emulation_caches,
    emulate_batch,
)
from repro.core.propagation import (
    PropagationPlan,
    SegmentedPlan,
    clear_plan_cache,
    clear_tf_cache,
    plan_cache_stats,
    plan_from_config,
    tf_cache_stats,
)

__all__ = [
    "DONNConfig", "LayerSpec", "SegmentedPlan",
    "FRAUNHOFER", "FRESNEL", "RS", "Grid", "fraunhofer",
    "intensity", "propagate", "propagate_tf", "transfer_function",
    "Laser", "data_to_cplex", "Detector", "DiffractiveLayer",
    "DONN", "MultiChannelDONN", "SegmentationDONN", "build_model",
    "cached_apply", "cached_model", "clear_emulation_caches", "emulate_batch",
    "PropagationPlan", "plan_from_config", "plan_cache_stats",
    "clear_plan_cache", "tf_cache_stats", "clear_tf_cache",
    "PhysicsValidationError", "PhysicsViolation", "PhysicsWarning",
    "validate_config",
]
