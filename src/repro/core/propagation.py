"""Fused scan-based propagation engine (the LightRidge hot path, Fig. 9).

The eager model forward is a per-layer Python loop: every layer re-uploads
its transfer function, traces its own FFT2 / complex-multiply / iFFT2 /
phase-modulation chain, and ``MultiChannelDONN`` runs its channels as
separate unbatched stacks.  This module replaces that loop with a
*propagation plan*:

1.  **TF cache** — transfer functions are precomputed once per geometry and
    cached process-wide, keyed by ``(grid, z, wavelength, method,
    band_limit, pad)``.  They are stored as split real/imag float32 planes
    (the Pallas kernels are struct-of-arrays) together with the derived
    polar form ``(arg H, |H|)`` consumed by the fused kernel; band-limit
    masks and evanescent decay fold into ``|H|``.
2.  **Stacked scan** — all layer TFs and phase maps stack into ``(L, N,
    N)`` tensors and the forward becomes a single ``jax.lax.scan`` whose
    body is traced once: FFT2 -> spectral multiply -> iFFT2 -> phase
    modulation.  Compile time and HLO size stop scaling with depth.
3.  **Fused elementwise kernel** — with ``use_pallas`` both elementwise
    sites in the scan body (the spectral TF multiply and the trainable
    phase modulation) route through one Pallas kernel,
    ``repro.kernels.ops.phase_tf_apply``, which performs the cos/sin phase
    rotation and the amplitude-weighted complex multiply in a single VMEM
    pass (the TF multiply *is* a phase modulation by ``arg H`` scaled by
    ``|H|``).
4.  **Batched channels** — multi-channel inputs keep their channel axis and
    propagate as one ``(..., C, N, N)`` tensor through shared kernels; the
    per-channel phase planes ride the scan as ``(L, C, N, N)`` stacks and
    the detector accumulates all channels in one fused readout
    (``repro.core.models.MultiChannelDONN``).

The eager path remains available via ``DONNConfig(engine="eager")`` and
must agree with the plan path to rtol <= 1e-5
(tests/test_propagation_plan.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codesign as cd
from repro.core import diffraction as df

# --------------------------------------------------------------------------
# Transfer-function cache
# --------------------------------------------------------------------------
# key -> dict with split-plane float32 arrays: hr, hi (cartesian) and
# theta, amp (polar, for the fused kernel).  All numpy: build-time consts.
# Bounded FIFO so DSE sweeps over many geometries can't grow host memory
# without limit (dicts iterate in insertion order).
_TF_CACHE: dict = {}
_TF_CACHE_MAX = 512
_TF_STATS = {"hits": 0, "misses": 0}


def tf_cache_key(grid: df.Grid, z: float, wavelength: float, method: str,
                 band_limit: bool, pad: bool) -> tuple:
    return (grid.n, float(grid.pixel_size), float(z), float(wavelength),
            method, bool(band_limit), bool(pad))


def tf_cache_stats() -> dict:
    return dict(_TF_STATS)


def clear_tf_cache() -> None:
    _TF_CACHE.clear()
    _TF_STATS["hits"] = 0
    _TF_STATS["misses"] = 0


def transfer_planes(grid: df.Grid, z: float, wavelength: float,
                    method: str = df.RS, band_limit: bool = True,
                    pad: bool = False) -> dict:
    """Cached split-plane transfer function for one propagation gap.

    Returns {"hr", "hi", "theta", "amp"} float32 numpy arrays on the
    (possibly padded) grid; for ``method="fraunhofer"`` the planes describe
    the far-field quadratic output factor instead (its amplitude carries
    the 1/(lambda z) scaling, so the polar form covers it too).
    """
    key = tf_cache_key(grid, z, wavelength, method, band_limit, pad)
    hit = _TF_CACHE.get(key)
    if hit is not None:
        _TF_STATS["hits"] += 1
        return hit
    _TF_STATS["misses"] += 1
    if method == df.FRAUNHOFER:
        h = df.fraunhofer_quad(grid, z, wavelength)
    else:
        h = df.transfer_function(grid, z, wavelength, method, band_limit,
                                 pad=pad)
    entry = {
        "hr": np.ascontiguousarray(h.real.astype(np.float32)),
        "hi": np.ascontiguousarray(h.imag.astype(np.float32)),
        "theta": np.angle(h).astype(np.float32),
        "amp": np.abs(h).astype(np.float32),
    }
    while len(_TF_CACHE) >= _TF_CACHE_MAX:
        _TF_CACHE.pop(next(iter(_TF_CACHE)))
    _TF_CACHE[key] = entry
    return entry


def cached_transfer_function(grid: df.Grid, z: float, wavelength: float,
                             method: str = df.RS, band_limit: bool = True,
                             pad: bool = False) -> np.ndarray:
    """Complex64 view of the cached transfer function (eager-path layers)."""
    p = transfer_planes(grid, z, wavelength, method, band_limit, pad)
    return p["hr"] + 1j * p["hi"]


# --------------------------------------------------------------------------
# Propagation plan
# --------------------------------------------------------------------------
class PropagationPlan:
    """Stacked, scan-based forward pipeline for a diffractive stack.

    Covers ``depth`` modulated layers (gap i then phase plane i) plus the
    final free-space hop to the detector plane.  ``forward`` runs a slice
    of the modulated layers as one ``lax.scan``; ``propagate_final`` runs
    the last hop.  Phase stacks may be ``(L, N, N)`` (single channel) or
    ``(L, C, N, N)`` (multi-channel; fields keep their channel axis).
    """

    def __init__(
        self,
        grid: df.Grid,
        gaps,  # depth+1 propagation distances (last = hop to detector)
        wavelength: float,
        method: str = df.RS,
        band_limit: bool = True,
        pad: bool = False,
        gamma: float = 1.0,
        device: Optional[cd.DeviceSpec] = None,
        codesign_mode: str = "none",
        use_pallas: bool = False,
    ):
        if method not in df.METHODS:
            raise ValueError(f"unknown method {method!r}")
        self.grid = grid
        self.gaps = tuple(float(g) for g in gaps)
        self.depth = len(self.gaps) - 1
        self.wavelength = wavelength
        self.method = method
        self.band_limit = band_limit
        self.pad = pad and method != df.FRAUNHOFER
        self.gamma = float(gamma)
        self.device = device
        self.codesign_mode = codesign_mode
        self.use_pallas = use_pallas
        planes = [
            transfer_planes(grid, z, wavelength, method, band_limit, self.pad)
            for z in self.gaps
        ]
        # stacked numpy constants; uploaded lazily (imports stay device-free)
        self._np = {
            k: np.stack([p[k] for p in planes]) for k in
            (("theta", "amp") if use_pallas else ("hr", "hi"))
        }
        self._jax: dict = {}

    # --- constants ---
    def _const(self, name: str) -> jax.Array:
        arr = self._jax.get(name)
        if arr is None:
            if name == "h":  # complex TF stack for the jnp path
                arr = jnp.asarray(self._np["hr"] + 1j * self._np["hi"])
            else:
                arr = jnp.asarray(self._np[name])
            # under a jit trace jnp.asarray yields a Tracer — caching it
            # across traces would leak; cache only concrete device arrays
            if not isinstance(arr, jax.core.Tracer):
                self._jax[name] = arr
        return arr

    # --- elementwise sites ---
    def _spectral_mul(self, s: jax.Array, h_or_polar) -> jax.Array:
        """Multiply a spectrum (or far-field plane) by one layer's TF."""
        if not self.use_pallas:
            return s * h_or_polar
        from repro.kernels import ops as kops

        theta, amp = h_or_polar
        tr, ti = kops.phase_tf_apply(s.real, s.imag, theta, amp)
        return jax.lax.complex(tr, ti)

    def _modulate(self, u: jax.Array, phi: jax.Array) -> jax.Array:
        """gamma * u * exp(j phi); phi (N, N) or per-channel (C, N, N)."""
        if not self.use_pallas:
            return u * (self.gamma * jnp.exp(1j * phi.astype(jnp.complex64)))
        from repro.kernels import ops as kops

        amp = jnp.full(phi.shape, self.gamma, phi.dtype)
        ur, ui = kops.phase_tf_apply(u.real, u.imag, phi, amp)
        return jax.lax.complex(ur, ui)

    def _hop(self, u: jax.Array, h_or_polar) -> jax.Array:
        """One free-space gap with a prepared TF."""
        if self.method == df.FRAUNHOFER:
            spec = jnp.fft.fftshift(jnp.fft.fft2(u), axes=(-2, -1))
            return self._spectral_mul(spec, h_or_polar)
        if self.pad:
            n = self.grid.n
            up = df.pad_field(u, n)
            out = jnp.fft.ifft2(self._spectral_mul(jnp.fft.fft2(up), h_or_polar))
            return df.crop_field(out, n)
        return jnp.fft.ifft2(self._spectral_mul(jnp.fft.fft2(u), h_or_polar))

    def _layer_tfs(self, start: int, stop: int):
        if self.use_pallas:
            return (self._const("theta")[start:stop],
                    self._const("amp")[start:stop])
        return (self._const("h")[start:stop],)

    # --- codesign ---
    def _codesign_stack(self, phis: jax.Array, rngs) -> jax.Array:
        """Per-layer hardware quantization on a stacked phase tensor.

        Matches the eager path: layer i uses key rngs[i]; in the multi-
        channel layout every channel of a layer shares that layer's key
        (the eager reference passes one rng into each channel's stack).
        """
        if self.device is None or self.codesign_mode == "none":
            return phis

        def per_layer(phi, rng):
            fn = lambda p: cd.apply_codesign(p, self.device,
                                             self.codesign_mode, rng)
            if phi.ndim > 2:  # (C, N, N): share the layer key across channels
                return jax.vmap(fn)(phi)
            return fn(phi)

        if rngs is None:
            return jax.vmap(lambda p: per_layer(p, None))(phis)
        return jax.vmap(per_layer)(phis, rngs)

    # --- forward ---
    def forward(self, phis: jax.Array, u: jax.Array, rngs=None,
                start: int = 0, stop: Optional[int] = None) -> jax.Array:
        """Scan layers [start, stop) over the field u.

        phis: full (L, ...) phase stack (codesign is applied to the whole
        stack so per-layer rng alignment is independent of the slice);
        rngs: optional (L, key) stack from ``jax.random.split``.
        """
        stop = self.depth if stop is None else stop
        phi_eff = self._codesign_stack(phis, rngs)
        xs = self._layer_tfs(start, stop) + (phi_eff[start:stop],)

        def body(carry, layer):
            h_or_polar, phi = layer[:-1], layer[-1]
            if not self.use_pallas:
                h_or_polar = h_or_polar[0]
            carry = self._modulate(self._hop(carry, h_or_polar), phi)
            return carry, None

        u, _ = jax.lax.scan(body, u, xs)
        return u

    def propagate_final(self, u: jax.Array) -> jax.Array:
        """The last free-space hop (layer plane -> detector, no modulation)."""
        tfs = self._layer_tfs(self.depth, self.depth + 1)
        if self.use_pallas:
            h_or_polar = (tfs[0][0], tfs[1][0])
        else:
            h_or_polar = tfs[0][0]
        return self._hop(u, h_or_polar)

    def apply(self, phis: jax.Array, u: jax.Array, rng=None) -> jax.Array:
        """Full stack: scan all layers then the final hop.

        rng is a single key (split into per-layer keys here, mirroring the
        eager model) or None.
        """
        rngs = jax.random.split(rng, self.depth) if rng is not None else None
        return self.propagate_final(self.forward(phis, u, rngs))


def plan_from_config(cfg, gamma: float) -> PropagationPlan:
    """Build the plan the same way ``_build_layers`` builds the eager stack."""
    dev = (
        cd.DeviceSpec(levels=cfg.device_levels,
                      response_gamma=cfg.response_gamma)
        if cfg.codesign != "none"
        else None
    )
    return PropagationPlan(
        df.Grid(cfg.n, cfg.pixel_size),
        cfg.gap_distances(),
        cfg.wavelength,
        method=cfg.approximation,
        band_limit=cfg.band_limit,
        pad=cfg.pad,
        gamma=gamma,
        device=dev,
        codesign_mode=cfg.codesign,
        use_pallas=cfg.use_pallas,
    )
