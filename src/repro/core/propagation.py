"""Fused scan-based propagation engine (the LightRidge hot path, Fig. 9).

The eager model forward is a per-layer Python loop: every layer re-uploads
its transfer function, traces its own FFT2 / complex-multiply / iFFT2 /
phase-modulation chain, and ``MultiChannelDONN`` runs its channels as
separate unbatched stacks.  This module replaces that loop with a
*propagation plan* and a compile-once emulation runtime on top of it:

1.  **TF cache** — transfer functions are precomputed once per geometry and
    cached process-wide (LRU), keyed by ``(grid, z, wavelength, method,
    band_limit, pad)``.  They are stored as split real/imag float32 planes
    (the Pallas kernels are struct-of-arrays) together with the derived
    polar form ``(arg H, |H|)`` consumed by the fused kernel; band-limit
    masks and evanescent decay fold into ``|H|``.
2.  **Stacked scan** — all layer TFs and phase maps stack into ``(L, N,
    N)`` tensors and the forward becomes a single ``jax.lax.scan`` whose
    body is traced once: FFT2 -> spectral multiply -> iFFT2 -> phase
    modulation.  The scan carries an ``unroll`` knob
    (``DONNConfig.scan_unroll``; default from ``default_scan_unroll``) that
    claws back XLA:CPU's while-loop overhead in steady state, and TF planes
    may be stored bf16 with f32 accumulation (``DONNConfig.tf_dtype``).
3.  **Fused elementwise kernel** — with ``use_pallas`` both elementwise
    sites in the scan body (the spectral TF multiply and the trainable
    phase modulation) route through one Pallas kernel,
    ``repro.kernels.ops.phase_tf_apply``, which performs the cos/sin phase
    rotation and the amplitude-weighted complex multiply in a single VMEM
    pass (the TF multiply *is* a phase modulation by ``arg H`` scaled by
    ``|H|``).
4.  **Batched channels and candidates** — multi-channel inputs keep their
    channel axis and propagate as one ``(..., C, N, N)`` tensor through
    shared kernels with ``(L, C, N, N)`` phase stacks
    (``repro.core.models.MultiChannelDONN``).  The same machinery batches
    *candidates*: ``PropagationPlan.apply_batch`` vmaps a ``(K, L, N, N)``
    (or ``(K, L, C, N, N)``) stack of K phase configurations through one
    shared compiled forward, and ``forward``/``apply`` accept externally
    supplied transfer planes (``tfs=...``) so per-candidate *geometries*
    ride the same executable as traced inputs instead of baked constants
    (``repro.core.models.emulate_batch``, the DSE verification path).
5.  **Plan and executable caches** — ``plan_from_config`` memoizes
    ``PropagationPlan`` instances per geometry tuple and
    ``cached_executable`` memoizes AOT-compiled programs keyed by
    ``(statics, input shapes/dtypes)``; ``plan_cache_stats()`` /
    ``clear_plan_cache()`` mirror the TF-cache API.  Repeated emulation
    (DSE verification sweeps, sensitivity analysis, codesign loops) stops
    paying trace+compile per candidate.

6.  **Segmented plans for heterogeneous stacks** — configs with per-layer
    ``LayerSpec`` overrides (mixed plane sizes, pixel sizes, approximation
    methods, codesign devices) compile to a ``SegmentedPlan``: maximal
    runs of fusable layers each become one scan segment, stitched by
    eager hops with field resampling at grid boundaries.  Uniform configs
    keep the single-segment ``PropagationPlan`` (identical HLO and cache
    keys as before).

The eager path remains available via ``DONNConfig(engine="eager")`` and
must agree with the plan path to rtol <= 1e-5
(tests/test_propagation_plan.py, tests/test_hetero.py).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codesign as cd
from repro.core import diffraction as df
from repro.core import physics
from repro.core.cache import lru_get, lru_put

# --------------------------------------------------------------------------
# Process-wide caches (TF planes, plans, executables)
# --------------------------------------------------------------------------
# All three are bounded LRU maps (repro.core.cache): lookups reinsert the
# hit entry at the back, eviction pops the front — a DSE sweep alternating
# more geometries than the bound can hold no longer evicts its own hot
# entries (the old FIFO did).
_TF_CACHE: dict = {}
_TF_CACHE_MAX = 512
_TF_STATS = {"hits": 0, "misses": 0}

_PLAN_CACHE: dict = {}
_PLAN_CACHE_MAX = 64
_PLAN_STATS = {"hits": 0, "misses": 0}

_EXEC_CACHE: dict = {}
_EXEC_CACHE_MAX = 64
_EXEC_STATS = {"hits": 0, "misses": 0}


# shared bounded-LRU implementation (repro.core.cache)
_cache_get = lru_get
_cache_put = lru_put


def tf_cache_key(grid: df.Grid, z: float, wavelength: float, method: str,
                 band_limit: bool, pad: bool) -> tuple:
    return (grid.n, float(grid.pixel_size), float(z), float(wavelength),
            method, bool(band_limit), bool(pad))


def tf_cache_stats() -> dict:
    return dict(_TF_STATS)


def clear_tf_cache() -> None:
    _TF_CACHE.clear()
    _TF_STATS["hits"] = 0
    _TF_STATS["misses"] = 0


def plan_cache_stats() -> dict:
    """Plan + executable cache counters (mirrors ``tf_cache_stats``)."""
    return {
        "hits": _PLAN_STATS["hits"],
        "misses": _PLAN_STATS["misses"],
        "size": len(_PLAN_CACHE),
        "exec_hits": _EXEC_STATS["hits"],
        "exec_misses": _EXEC_STATS["misses"],
        "exec_size": len(_EXEC_CACHE),
    }


def clear_plan_cache() -> None:
    """Drop all cached plans and compiled executables, reset counters."""
    _PLAN_CACHE.clear()
    _EXEC_CACHE.clear()
    for s in (_PLAN_STATS, _EXEC_STATS):
        s["hits"] = 0
        s["misses"] = 0


def transfer_planes(grid: df.Grid, z: float, wavelength: float,
                    method: str = df.RS, band_limit: bool = True,
                    pad: bool = False) -> dict:
    """Cached split-plane transfer function for one propagation gap.

    Returns {"hr", "hi", "theta", "amp"} float32 numpy arrays on the
    (possibly padded) grid; for ``method="fraunhofer"`` the planes describe
    the far-field quadratic output factor instead (its amplitude carries
    the 1/(lambda z) scaling, so the polar form covers it too).
    """
    key = tf_cache_key(grid, z, wavelength, method, band_limit, pad)
    hit = _cache_get(_TF_CACHE, key, _TF_STATS)
    if hit is not None:
        return hit
    if method == df.FRAUNHOFER:
        h = df.fraunhofer_quad(grid, z, wavelength)
    else:
        h = df.transfer_function(grid, z, wavelength, method, band_limit,
                                 pad=pad)
    entry = {
        "hr": np.ascontiguousarray(h.real.astype(np.float32)),
        "hi": np.ascontiguousarray(h.imag.astype(np.float32)),
        "theta": np.angle(h).astype(np.float32),
        "amp": np.abs(h).astype(np.float32),
    }
    _cache_put(_TF_CACHE, key, entry, _TF_CACHE_MAX)
    return entry


def cached_transfer_function(grid: df.Grid, z: float, wavelength: float,
                             method: str = df.RS, band_limit: bool = True,
                             pad: bool = False) -> np.ndarray:
    """Complex64 view of the cached transfer function (eager-path layers)."""
    p = transfer_planes(grid, z, wavelength, method, band_limit, pad)
    return p["hr"] + 1j * p["hi"]


# --------------------------------------------------------------------------
# Executable cache (AOT compile-once layer)
# --------------------------------------------------------------------------
def _aval_key(args) -> tuple:
    leaves, treedef = jax.tree.flatten(args)
    return (treedef,) + tuple(
        (np.shape(leaf), jnp.result_type(leaf).name,
         bool(getattr(leaf, "weak_type", False)))
        for leaf in leaves
    )


def cached_executable(static_key: tuple, fn: Callable, *args,
                      donate_argnums: tuple = ()):
    """AOT-compiled ``fn`` for the shapes/dtypes of ``args``.

    Keyed by ``(static_key, donation, input avals)`` — the compile-once
    layer above the TF/plan caches.  Repeated emulations with identical
    statics and input shapes reuse one XLA executable instead of re-tracing
    a fresh closure (what every ``build_model``+``jit(apply)`` cycle used
    to pay).  ``donate_argnums`` compiles the executable with those
    positional inputs donated (the chunked training drivers donate params
    and optimizer state so step k+1 reuses step k's buffers in place).
    """
    donate_argnums = tuple(donate_argnums)
    key = (static_key, donate_argnums, _aval_key(args))
    compiled = _cache_get(_EXEC_CACHE, key, _EXEC_STATS)
    if compiled is None:
        compiled = jax.jit(
            fn, donate_argnums=donate_argnums
        ).lower(*args).compile()
        _cache_put(_EXEC_CACHE, key, compiled, _EXEC_CACHE_MAX)
    return compiled


# --------------------------------------------------------------------------
# Frozen-plane storage dtypes (deployment serving path)
# --------------------------------------------------------------------------
PLANE_DTYPES = ("float32", "bfloat16", "int8")


def quantize_frozen_planes(pair, plane_dtype: str = "float32") -> tuple:
    """Reduce a frozen modulation plane pair to its storage dtype.

    The ``tf_dtype`` idea generalized to the serving path: planes are
    *stored* small and every consumer accumulates in f32
    (``dequant_frozen_layer`` inside the scan body).

    - ``"float32"``  -> the pair unchanged (bit-identical fast path);
    - ``"bfloat16"`` -> the same 2-tuple cast to bf16 storage;
    - ``"int8"``     -> a 4-tuple ``(qa, qb, sa, sb)``: symmetric per-layer
      linear quantization ``q = round(x / s)`` with f32 scales
      ``s = max|x| / 127`` kept per layer (shape ``(L, 1, 1[, 1])``), so
      each modulation plane dequantizes independently.
    """
    if plane_dtype not in PLANE_DTYPES:
        raise ValueError(
            f"unknown plane_dtype {plane_dtype!r} (expected one of "
            f"{PLANE_DTYPES})"
        )
    if plane_dtype == "float32":
        return tuple(pair)
    if plane_dtype == "bfloat16":
        return tuple(jnp.asarray(p).astype(jnp.bfloat16) for p in pair)
    qs, ss = [], []
    for p in pair:
        p = jnp.asarray(p, jnp.float32)
        red = tuple(range(1, p.ndim))
        s = jnp.max(jnp.abs(p), axis=red, keepdims=True) / 127.0
        s = jnp.maximum(s, jnp.float32(1e-12))
        qs.append(jnp.round(p / s).astype(jnp.int8))
        ss.append(s)
    return (qs[0], qs[1], ss[0], ss[1])


def dequant_frozen_layer(leaves) -> tuple:
    """One layer's frozen-plane leaves -> f32 ``(a, b)`` (f32 accumulation).

    ``leaves`` is one scan step's slice of the frozen tuple: ``(a, b)``
    for float32/bfloat16 storage, ``(qa, qb, sa, sb)`` for int8.
    """
    if len(leaves) == 2:
        a, b = leaves
        return a.astype(jnp.float32), b.astype(jnp.float32)
    qa, qb, sa, sb = leaves
    return qa.astype(jnp.float32) * sa, qb.astype(jnp.float32) * sb


def frozen_plane_dtype(frozen) -> str:
    """Storage dtype of a frozen pair/4-tuple (inverse of quantization)."""
    frozen = tuple(frozen)
    if len(frozen) == 4:
        return "int8"
    return "bfloat16" if frozen[0].dtype == jnp.bfloat16 else "float32"


# --------------------------------------------------------------------------
# Scan tuning
# --------------------------------------------------------------------------
def default_scan_unroll(depth: int) -> int:
    """Scan unroll heuristic (measured on XLA:CPU, BENCH_propagation_plan).

    The rolled while-loop form costs ~4-15% steady-state vs the eager
    unrolled HLO; unrolling by 8 recovers it (best of the depth-16 sweep,
    ~1.06x vs eager, ahead of both the rolled loop and full unroll) while
    the body is still traced once, so first-call stays ahead of eager too.
    Shallower stacks unroll fully; deeper stacks keep the cap so compile
    time stays bounded — the plan/executable caches make that first
    compile a one-time cost per (statics, shapes) anyway.
    """
    return min(depth, 8)


# --------------------------------------------------------------------------
# Propagation plan
# --------------------------------------------------------------------------
class PropagationPlan:
    """Stacked, scan-based forward pipeline for a diffractive stack.

    Covers ``depth`` modulated layers (gap i then phase plane i) plus the
    final free-space hop to the detector plane.  ``forward`` runs a slice
    of the modulated layers as one ``jax.lax.scan``; ``propagate_final``
    runs the last hop.  Phase stacks may be ``(L, N, N)`` (single channel)
    or ``(L, C, N, N)`` (multi-channel; fields keep their channel axis).

    Transfer planes default to the plan's baked constants, but ``forward``
    / ``propagate_final`` / ``apply`` also accept an external plane pair
    (``tfs``) with the same ``(depth+1, ...)`` layout, possibly traced —
    that is how ``apply_batch`` and the DSE ``emulate_batch`` path push
    per-candidate geometries through one shared executable.
    """

    def __init__(
        self,
        grid: df.Grid,
        gaps,  # depth+1 propagation distances (last = hop to detector)
        wavelength: float,
        method: str = df.RS,
        band_limit: bool = True,
        pad: bool = False,
        gamma: float = 1.0,
        device: Optional[cd.DeviceSpec] = None,
        codesign_mode: str = "none",
        use_pallas: bool = False,
        unroll: Optional[int] = None,
        tf_dtype: str = "float32",
        final_hop: bool = True,
        remat: str = "none",
    ):
        """``final_hop=False`` builds an *inner segment* of a heterogeneous
        stack: every gap is a modulated layer's gap and ``propagate_final``
        is unavailable (the next segment owns the following hop).

        ``remat`` threads a ``jax.checkpoint`` policy into the scan:
        ``"layer"`` checkpoints the scan body (the backward pass recomputes
        each layer's FFT chain from its carry instead of storing it),
        ``"segment"`` checkpoints the whole scan region.  Both trade
        recompute for activation memory — the knob that keeps deep or
        large-plane *training* from OOMing."""
        if method not in df.METHODS:
            raise ValueError(f"unknown method {method!r}")
        if tf_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"unknown tf_dtype {tf_dtype!r}")
        if remat not in ("none", "layer", "segment"):
            raise ValueError(f"unknown remat {remat!r}")
        self.grid = grid
        self.gaps = tuple(float(g) for g in gaps)
        self.final_hop = final_hop
        self.depth = len(self.gaps) - 1 if final_hop else len(self.gaps)
        self.wavelength = wavelength
        self.method = method
        self.band_limit = band_limit
        self.pad = pad and method != df.FRAUNHOFER
        self.gamma = float(gamma)
        self.device = device
        self.codesign_mode = codesign_mode
        self.use_pallas = use_pallas
        self.unroll = unroll
        self.tf_dtype = tf_dtype
        self.remat = remat
        # split-plane pair consumed by the scan body: polar for the fused
        # Pallas kernel, cartesian for the jnp path
        self._plane_keys = ("theta", "amp") if use_pallas else ("hr", "hi")
        # whole-hop fusion (kernels.ops.fused_spectral_hop): TF multiply +
        # modulation as one VMEM pass per FFT side.  Needs the polar plane
        # convention and the plain fft2/ifft2 hop structure — fraunhofer
        # (single shifted FFT) and padded hops keep the two-site path.
        self._fuse = bool(use_pallas) and method != df.FRAUNHOFER \
            and not self.pad
        planes = [
            transfer_planes(grid, z, wavelength, method, band_limit, self.pad)
            for z in self.gaps
        ]
        # stacked numpy constants; uploaded lazily (imports stay device-free)
        self._np = {
            k: np.stack([p[k] for p in planes]) for k in self._plane_keys
        }
        self._jax: dict = {}

    # --- constants ---
    def _const(self, name: str) -> jax.Array:
        arr = self._jax.get(name)
        if arr is None:
            arr = jnp.asarray(self._np[name])
            if self.tf_dtype != "float32":
                # storage dtype only: every consumer upcasts to f32 before
                # the complex multiply (f32 accumulation)
                arr = arr.astype(self.tf_dtype)
            # under a jit trace jnp.asarray yields a Tracer — caching it
            # across traces would leak; cache only concrete device arrays
            if not isinstance(arr, jax.core.Tracer):
                self._jax[name] = arr
        return arr

    def _tf_pair(self) -> tuple:
        """Full (depth+1, N, N) split-plane stacks (baked constants)."""
        return (self._const(self._plane_keys[0]),
                self._const(self._plane_keys[1]))

    # --- elementwise sites ---
    def _spectral_mul(self, s: jax.Array, pair) -> jax.Array:
        """Multiply a spectrum (or far-field plane) by one layer's TF pair."""
        a, b = pair
        a = a.astype(jnp.float32)
        b = b.astype(jnp.float32)
        if not self.use_pallas:
            return s * jax.lax.complex(a, b)  # (hr, hi)
        from repro.kernels import ops as kops

        tr, ti = kops.phase_tf_apply(s.real, s.imag, a, b)  # (theta, amp)
        return jax.lax.complex(tr, ti)

    def _modulate(self, u: jax.Array, phi: jax.Array) -> jax.Array:
        """gamma * u * exp(j phi); phi (N, N) or per-channel (C, N, N)."""
        if not self.use_pallas:
            return u * (self.gamma * jnp.exp(1j * phi.astype(jnp.complex64)))
        from repro.kernels import ops as kops

        amp = jnp.full(phi.shape, self.gamma, phi.dtype)
        ur, ui = kops.phase_tf_apply(u.real, u.imag, phi, amp)
        return jax.lax.complex(ur, ui)

    def _fused_layer(self, u: jax.Array, tf_pair, mod=None,
                     phi=None) -> jax.Array:
        """One whole modulated layer as the fused spectral-hop kernel.

        ``M . ifft2(Hc . fft2(u))`` with both elementwise sites (TF
        multiply, modulation) fused into one VMEM pass per FFT side
        (``kernels.ops.fused_spectral_hop``).  ``tf_pair`` is the polar
        ``(arg H, |H|)`` pair (possibly bf16 storage — upcast here, f32
        accumulation); the modulation is either a trainable phase ``phi``
        (amp = gamma, the custom VJP carries d phi) or a frozen polar
        ``mod`` pair from ``frozen_modulation``.  TF planes are static
        geometry: their cotangents are zero, exactly like the ``amp``
        argument of ``phase_tf_apply``.
        """
        from repro.kernels import ops as kops

        th_h, amp_h = (p.astype(jnp.float32) for p in tf_pair)
        if phi is not None:
            th_m = phi
            amp_m = jnp.full(phi.shape, self.gamma, jnp.float32)
        else:
            th_m, amp_m = mod
        ur, ui = kops.fused_spectral_hop(u.real, u.imag, th_h, amp_h,
                                         th_m, amp_m)
        return jax.lax.complex(ur, ui)

    def _modulate_frozen(self, u: jax.Array, pair) -> jax.Array:
        """Modulate by one layer's *precomputed* modulation plane pair.

        The deployment fast path: codesign response and ``gamma * exp(j
        theta)`` were folded once at freeze time (``frozen_modulation``),
        so per-request work is a single fused multiply — the polar pair
        feeds the fused Pallas kernel directly, the cartesian pair a bare
        complex multiply.  Numerics are bit-identical to ``_modulate`` on
        the codesign-resolved phase (same kernels, same operand values).
        """
        a, b = pair
        if not self.use_pallas:
            return u * jax.lax.complex(a, b)  # (mr, mi) = gamma * exp(j phi)
        from repro.kernels import ops as kops

        ur, ui = kops.phase_tf_apply(u.real, u.imag, a, b)  # (theta, amp)
        return jax.lax.complex(ur, ui)

    def frozen_modulation(self, phis: jax.Array,
                          plane_dtype: str = "float32") -> tuple:
        """Deploy-time fold: device response + ``gamma*exp(j phi)`` once.

        ``phis`` is the trained (L, ...) phase stack.  The codesign device
        response is resolved rng-free (``codesign.deployed_phase`` — the
        statically-known state the fabricated hardware holds) and the
        modulation ``gamma * exp(j phi_eff)`` is precomputed into a split
        plane pair in the plan's kernel convention: polar ``(theta, amp)``
        consumed directly by the fused Pallas kernels under ``use_pallas``,
        cartesian ``(mr, mi)`` for the jnp path.  Feed the result to
        ``forward``/``apply`` via ``frozen=`` — the per-request hot path
        then skips phase-stack construction, quantization and codesign rng
        entirely (bit-identical to the training-path forward at eval,
        tests/test_inference.py).

        ``plane_dtype`` selects the *storage* precision of the folded
        planes (``quantize_frozen_planes``): ``"float32"`` is bit-identical
        to the historical pair, ``"bfloat16"``/``"int8"`` shrink the
        serving artifact 2x/4x with f32 accumulation in the scan body
        (accuracy deltas measured in BENCH_inference_throughput).
        """

        def fold(p):
            eff = self._codesign_stack(p, None)
            if self.use_pallas:
                return eff, jnp.full(eff.shape, self.gamma, eff.dtype)
            m = self.gamma * jnp.exp(1j * eff.astype(jnp.complex64))
            return m.real, m.imag

        a, b = jax.jit(fold)(jnp.asarray(phis))
        return quantize_frozen_planes((a, b), plane_dtype)

    def _hop(self, u: jax.Array, pair, spectral=None) -> jax.Array:
        """One free-space gap with a prepared TF plane pair.

        ``spectral`` optionally overrides the (fft2, ifft2) pair — the hook
        distributed spectral hops use: ``repro.runtime.pencil_fft.
        local_spectral_pair`` runs the pencil-decomposed local FFT *inside*
        the scan body when fields (and TF planes) are row-sharded under an
        enclosing ``shard_map``.
        """
        if spectral is not None:
            if self.method == df.FRAUNHOFER or self.pad:
                raise NotImplementedError(
                    "spectral-hop overrides support unpadded angular-"
                    "spectrum methods only (no fraunhofer, no pad)"
                )
            fft2, ifft2 = spectral
            return ifft2(self._spectral_mul(fft2(u), pair))
        if self.method == df.FRAUNHOFER:
            spec = jnp.fft.fftshift(jnp.fft.fft2(u), axes=(-2, -1))
            return self._spectral_mul(spec, pair)
        if self.pad:
            n = self.grid.n
            up = df.pad_field(u, n)
            out = jnp.fft.ifft2(self._spectral_mul(jnp.fft.fft2(up), pair))
            return df.crop_field(out, n)
        return jnp.fft.ifft2(self._spectral_mul(jnp.fft.fft2(u), pair))

    # --- codesign ---
    def _codesign_stack(self, phis: jax.Array, rngs) -> jax.Array:
        """Per-layer hardware quantization on a stacked phase tensor.

        Matches the eager path: layer i uses key rngs[i]; in the multi-
        channel layout every channel of a layer shares that layer's key
        (the eager reference passes one rng into each channel's stack).
        """
        if self.device is None or self.codesign_mode == "none":
            return phis

        def per_layer(phi, rng):
            fn = lambda p: cd.apply_codesign(p, self.device,
                                             self.codesign_mode, rng)
            if phi.ndim > 2:  # (C, N, N): share the layer key across channels
                return jax.vmap(fn)(phi)
            return fn(phi)

        if rngs is None:
            return jax.vmap(lambda p: per_layer(p, None))(phis)
        return jax.vmap(per_layer)(phis, rngs)

    # --- forward ---
    def _scan_unroll(self, length: int) -> int:
        unroll = (self.unroll if self.unroll is not None
                  else default_scan_unroll(self.depth))
        return max(1, min(int(unroll), max(length, 1)))

    # --- phase-stack assembly (uniform: one stack; see SegmentedPlan) ---
    @property
    def segment_slices(self) -> tuple:
        """Global layer-index ranges of each fused scan segment."""
        return ((0, self.depth),)

    def stack_phases(self, phases) -> jax.Array:
        """Per-layer phase arrays -> the (L, ...) stack ``forward`` scans."""
        return jnp.stack(list(phases))

    def forward(self, phis: jax.Array, u: jax.Array, rngs=None,
                start: int = 0, stop: Optional[int] = None,
                tfs=None, mask=None, pre=None, spectral=None,
                frozen=None) -> jax.Array:
        """Scan layers [start, stop) over the field u.

        phis: full (L, ...) phase stack (codesign is applied to the whole
        stack so per-layer rng alignment is independent of the slice);
        rngs: optional (L, key) stack from ``jax.random.split``;
        tfs: optional external split-plane pair, each (depth+1, ...) —
        defaults to the plan's baked constants;
        mask: optional (L,) bool vector — masked-out layers are identity
        hops (the carry passes through untouched), which is how depth-
        padded candidate stacks emulate shallower architectures through
        one shared scan (``repro.core.models.emulate_batch``);
        pre: optional callable applied to the initial carry *inside* this
        forward (``SegmentedPlan`` folds boundary stitch resamples into the
        adjacent segment this way, so the stitch fuses with the segment's
        first hop instead of running as a detached einsum);
        spectral: optional (fft2, ifft2) override for every hop in the
        scan body — the distributed pencil-FFT path
        (``repro.runtime.pencil_fft.local_spectral_pair``);
        frozen: optional precomputed (L, ...) modulation plane pair from
        ``frozen_modulation`` — the deployment fast path.  With it the
        scan skips phase-stack codesign (quantization, rng) entirely and
        each layer is one hop plus one fused multiply; ``phis``/``rngs``/
        ``mask`` are ignored (pass None).

        The plan's ``remat`` policy wraps the body (``"layer"``) or the
        whole scan (``"segment"``) in ``jax.checkpoint``.
        """
        stop = self.depth if stop is None else stop
        if pre is not None:
            u = pre(u)
        a, b = self._tf_pair() if tfs is None else tfs
        # whole-hop fusion applies whenever the body is the plain
        # fft2 -> multiply -> ifft2 -> modulate chain on local spectra
        fuse = self._fuse and spectral is None
        if frozen is not None:
            frozen = tuple(frozen)
            xs = (a[start:stop], b[start:stop]) + tuple(
                f[start:stop] for f in frozen
            )

            def body(carry, layer):
                a_l, b_l = layer[0], layer[1]
                mod = dequant_frozen_layer(layer[2:])
                if fuse:
                    carry = self._fused_layer(carry, (a_l, b_l), mod=mod)
                else:
                    carry = self._modulate_frozen(
                        self._hop(carry, (a_l, b_l), spectral), mod
                    )
                return carry, None

            if self.remat == "layer":
                body = jax.checkpoint(body)

            def run(u0, xs_):
                out, _ = jax.lax.scan(body, u0, xs_,
                                      unroll=self._scan_unroll(stop - start))
                return out

            if self.remat == "segment":
                run = jax.checkpoint(run)
            return run(u, xs)
        phi_eff = self._codesign_stack(phis, rngs)
        if mask is None:
            xs = (a[start:stop], b[start:stop], phi_eff[start:stop])

            def body(carry, layer):
                a_l, b_l, phi = layer
                if fuse:
                    carry = self._fused_layer(carry, (a_l, b_l), phi=phi)
                else:
                    carry = self._modulate(
                        self._hop(carry, (a_l, b_l), spectral), phi
                    )
                return carry, None
        else:
            xs = (a[start:stop], b[start:stop], phi_eff[start:stop],
                  mask[start:stop])

            def body(carry, layer):
                a_l, b_l, phi, m = layer
                if fuse:
                    new = self._fused_layer(carry, (a_l, b_l), phi=phi)
                else:
                    new = self._modulate(
                        self._hop(carry, (a_l, b_l), spectral), phi
                    )
                carry = jnp.where(m, new, carry)
                return carry, None

        if self.remat == "layer":
            body = jax.checkpoint(body)

        def run(u0, xs_):
            out, _ = jax.lax.scan(body, u0, xs_,
                                  unroll=self._scan_unroll(stop - start))
            return out

        if self.remat == "segment":
            run = jax.checkpoint(run)
        return run(u, xs)

    def propagate_final(self, u: jax.Array, tfs=None,
                        spectral=None) -> jax.Array:
        """The last free-space hop (layer plane -> detector, no modulation)."""
        if not self.final_hop:
            raise ValueError(
                "this plan is an inner segment (final_hop=False); the next "
                "segment owns the following hop"
            )
        a, b = self._tf_pair() if tfs is None else tfs
        return self._hop(u, (a[self.depth], b[self.depth]), spectral)

    # --- real-to-complex first hop -------------------------------------
    def rfft_first_supported(self) -> bool:
        """Whether the half-spectrum first hop applies to this plan.

        Needs the plain fft2/ifft2 hop structure (no fraunhofer, no pad)
        and an even transfer function ``H(-f) = H(f)`` — true for every
        angular-spectrum TF here since they are functions of ``fx^2 +
        fy^2`` on the symmetric ``fftfreq`` grid (verified numerically at
        first use; ``first_layer_real`` raises otherwise).
        """
        return self.method != df.FRAUNHOFER and not self.pad

    def _rfft_half(self) -> tuple:
        """Cached half-spectrum cartesian TF planes for gap 0.

        A real input field has a conjugate-symmetric spectrum, and the TF
        is even, so hop 0 needs only the ``(N, N//2 + 1)`` rfft2 half
        grid: ``ifft2(U.H) = irfft2(U_half.Hr_half) + j irfft2(U_half.
        Hi_half)`` (each product is conjugate-symmetric because Hr/Hi are
        real and even).  1 rfft2 + 2 irfft2 ~ 1.5 full complex FFTs for
        the most common entry hop (intensity/amplitude encoded data).
        """
        cached = self._jax.get("_rhalf")
        if cached is not None:
            return cached
        if not self.rfft_first_supported():
            raise ValueError(
                "rfft first hop needs an unpadded non-fraunhofer plan"
            )
        p = transfer_planes(self.grid, self.gaps[0], self.wavelength,
                            self.method, self.band_limit, self.pad)
        half = self.grid.n // 2 + 1
        for h in (p["hr"], p["hi"]):
            folded = np.roll(np.flip(h, (-2, -1)), (1, 1), (-2, -1))
            if not np.allclose(h, folded, atol=1e-5):
                raise ValueError(
                    "transfer function is not even in frequency; the "
                    "half-spectrum first hop does not apply"
                )
        pair = (jnp.asarray(p["hr"][..., :half]),
                jnp.asarray(p["hi"][..., :half]))
        self._jax["_rhalf"] = pair
        return pair

    def first_layer_real(self, x: jax.Array, frozen) -> jax.Array:
        """Layer 0 (hop + frozen modulation) for a *real* input field.

        ``x`` is the real field amplitude (imag exactly zero — intensity/
        amplitude-encoded data through a real source); ``frozen`` the full
        frozen tuple from ``frozen_modulation``.  Continue with
        ``forward(None, u, start=1, frozen=frozen)``.
        """
        hr, hi = self._rfft_half()
        s = jnp.fft.rfft2(x)
        n = (self.grid.n, self.grid.n)
        u = jax.lax.complex(jnp.fft.irfft2(s * hr, s=n),
                            jnp.fft.irfft2(s * hi, s=n))
        mod = dequant_frozen_layer(tuple(f[0] for f in tuple(frozen)))
        return self._modulate_frozen(u, mod)

    def apply(self, phis: jax.Array, u: jax.Array, rng=None,
              tfs=None, mask=None, spectral=None, frozen=None) -> jax.Array:
        """Full stack: scan all layers then the final hop.

        rng is a single key (split into per-layer keys here, mirroring the
        eager model) or None.  ``frozen`` takes a precomputed modulation
        plane pair (``frozen_modulation``) — the deployment fast path; rng
        and phis are then unused.
        """
        if frozen is not None:
            return self.propagate_final(
                self.forward(None, u, tfs=tfs, spectral=spectral,
                             frozen=frozen),
                tfs=tfs, spectral=spectral,
            )
        rngs = jax.random.split(rng, self.depth) if rng is not None else None
        return self.propagate_final(
            self.forward(phis, u, rngs, tfs=tfs, mask=mask,
                         spectral=spectral),
            tfs=tfs, spectral=spectral,
        )

    def apply_batch(self, phis: jax.Array, u: jax.Array, rng=None,
                    tfs=None, per_candidate_inputs: bool = False,
                    mask=None) -> jax.Array:
        """Vmapped multi-candidate forward: K phase configs, one program.

        phis: (K, L, N, N) or (K, L, C, N, N) stack of K candidate phase
        configurations; u: one shared input field broadcast to every
        candidate, or a per-candidate (K, ...) stack when
        ``per_candidate_inputs``; tfs: optional per-candidate plane pair
        with leading K axis (each (K, depth+1, ...)) — the DSE path where
        candidate *geometries* differ but ride one compiled forward;
        rng: one key, split across candidates; mask: optional (K, L) bool
        layer-validity matrix for depth-padded (ragged-depth) candidate
        sets.  Returns the stacked (K, ...) detector-plane fields.
        """
        inp = {"phis": phis, "u": u}
        axes = {"phis": 0, "u": 0 if per_candidate_inputs else None}
        if rng is not None:
            inp["rng"] = jax.random.split(rng, phis.shape[0])
            axes["rng"] = 0
        if tfs is not None:
            inp["tfs"] = tuple(tfs)
            axes["tfs"] = (0, 0)
        if mask is not None:
            inp["mask"] = mask
            axes["mask"] = 0

        def one(d):
            return self.apply(d["phis"], d["u"], d.get("rng"),
                              tfs=d.get("tfs"), mask=d.get("mask"))

        return jax.vmap(one, in_axes=(axes,))(inp)


# --------------------------------------------------------------------------
# Segmented plan (heterogeneous per-layer architectures)
# --------------------------------------------------------------------------
def segment_layers(resolved_layers) -> tuple:
    """Group resolved ``LayerSpec``s into maximal fusable runs.

    Consecutive layers sharing (size, pixel_size, approximation, codesign
    device) compile into one fused ``lax.scan`` segment; a boundary is cut
    wherever any of those change.  Returns ``((start, stop), ...)`` global
    layer-index slices.
    """
    def seg_key(s):
        return (s.size, s.pixel_size, s.approximation, s.codesign,
                s.device_levels, s.response_gamma)

    slices, start = [], 0
    for i in range(1, len(resolved_layers)):
        if seg_key(resolved_layers[i]) != seg_key(resolved_layers[i - 1]):
            slices.append((start, i))
            start = i
    slices.append((start, len(resolved_layers)))
    return tuple(slices)


class SegmentedPlan:
    """Scan-based forward for a *heterogeneous* diffractive stack.

    Maximal runs of layers sharing (plane size, pixel size, approximation,
    codesign device) each compile to one fused ``lax.scan`` segment —
    exactly the uniform ``PropagationPlan`` machinery — with eager stitch
    hops between segments: when adjacent segments live on different grids
    the field is resampled (bilinear over physical coordinates, exact
    crop/pad for equal pixel sizes) at the boundary.  A uniform model is a
    single segment and never takes this path (``plan_from_config`` keeps
    returning the plain ``PropagationPlan`` for it), so the homogeneous
    HLO/perf is untouched.

    Phase stacks are *pytrees*: one ``(L_k, ...)`` stack per segment
    (``stack_phases`` assembles them from per-layer arrays; shapes are
    ragged across segments when plane sizes differ).
    """

    def __init__(self, cfg, gamma: float = 1.0):
        cfg = cfg.canonical()
        if cfg.layers is None:
            raise ValueError("SegmentedPlan needs a heterogeneous config; "
                             "use PropagationPlan for uniform stacks")
        specs = cfg.resolved_layers()
        self.cfg = cfg
        self.gamma = float(gamma)
        self.depth = len(specs)
        self.slices = segment_layers(specs)
        self.det_grid = df.Grid(cfg.n, cfg.pixel_size)
        self.segments = []
        for k, (lo, hi) in enumerate(self.slices):
            s0 = specs[lo]
            last = k == len(self.slices) - 1
            gaps = [specs[i].distance for i in range(lo, hi)]
            if last:
                gaps.append(cfg.gap_distances()[-1])
            self.segments.append(PropagationPlan(
                df.Grid(s0.size, s0.pixel_size),
                gaps,
                cfg.wavelength,
                method=s0.approximation,
                band_limit=cfg.band_limit,
                pad=cfg.pad,
                gamma=gamma,
                device=cd.device_for_layer(s0.codesign, s0.device_levels,
                                           s0.response_gamma),
                codesign_mode=s0.codesign,
                use_pallas=cfg.use_pallas,
                unroll=cfg.scan_unroll,
                tf_dtype=cfg.tf_dtype,
                final_hop=last,
                remat=cfg.remat,
            ))
        self.input_grid = self.segments[0].grid
        self.layer_grids = tuple(df.Grid(s.size, s.pixel_size) for s in specs)

    # --- phase-stack assembly ---
    @property
    def segment_slices(self) -> tuple:
        return self.slices

    def stack_phases(self, phases) -> tuple:
        """Per-layer phase arrays -> per-segment stacks (ragged pytree)."""
        phases = list(phases)
        if len(phases) != self.depth:
            raise ValueError(f"expected {self.depth} phase maps, "
                             f"got {len(phases)}")
        return tuple(
            jnp.stack(phases[lo:hi]) for lo, hi in self.slices
        )

    def frozen_modulation(self, phis, plane_dtype: str = "float32") -> tuple:
        """Per-segment deploy-time fold (see ``PropagationPlan``'s).

        ``phis`` is the per-segment pytree from ``stack_phases``; returns
        one modulation plane tuple per segment, in segment order — the
        ``frozen=`` input of this plan's ``forward``/``apply``.
        ``plane_dtype`` applies to every segment (int8 scales stay
        per-layer within each segment).
        """
        return tuple(
            seg.frozen_modulation(p, plane_dtype)
            for seg, p in zip(self.segments, phis)
        )

    # --- forward ---
    def forward(self, phis, u: jax.Array, rngs=None, start: int = 0,
                stop: Optional[int] = None, tfs=None,
                frozen=None) -> jax.Array:
        """Run global layers [start, stop); ``phis`` is the per-segment
        pytree from ``stack_phases``.  The incoming field must live on the
        grid of layer ``start - 1`` (the input grid when start == 0); the
        returned field lives on the grid of layer ``stop - 1``.
        ``frozen`` takes the per-segment pair tuple from this plan's
        ``frozen_modulation`` (deployment fast path; phis/rngs unused)."""
        if tfs is not None:
            raise NotImplementedError(
                "external transfer planes are a uniform-plan feature "
                "(batched DSE); segmented plans bake their constants"
            )
        stop = self.depth if stop is None else stop
        cur_grid = (self.layer_grids[start - 1] if start > 0
                    else self.input_grid)
        for k, (lo, hi) in enumerate(self.slices):
            a, b = max(lo, start), min(hi, stop)
            if a >= b:
                continue
            seg = self.segments[k]
            stitch = None
            if seg.grid != cur_grid:
                # boundary stitch folded into the adjacent segment: the
                # resample runs inside ``seg.forward`` (split real/imag
                # matmuls, exact slicing at equal pitch) so it fuses with
                # the segment's first hop instead of sitting between scans
                src = cur_grid
                stitch = lambda v, s=src, g=seg.grid: df.resample_field(
                    v, s, g)
            if frozen is not None:
                u = seg.forward(None, u, start=a - lo, stop=b - lo,
                                pre=stitch, frozen=frozen[k])
            else:
                seg_rngs = rngs[lo:hi] if rngs is not None else None
                u = seg.forward(phis[k], u, seg_rngs, start=a - lo,
                                stop=b - lo, pre=stitch)
            cur_grid = seg.grid
        return u

    def propagate_final(self, u: jax.Array, tfs=None) -> jax.Array:
        """Last free-space hop (on the last layer's grid), then the stitch
        onto the detector grid if it differs."""
        if tfs is not None:
            raise NotImplementedError("segmented plans bake their constants")
        u = self.segments[-1].propagate_final(u)
        return df.resample_field(u, self.segments[-1].grid, self.det_grid)

    def apply(self, phis, u: jax.Array, rng=None, tfs=None,
              frozen=None) -> jax.Array:
        if frozen is not None:
            return self.propagate_final(
                self.forward(None, u, tfs=tfs, frozen=frozen)
            )
        rngs = jax.random.split(rng, self.depth) if rng is not None else None
        return self.propagate_final(self.forward(phis, u, rngs, tfs=tfs))


def device_spec_from_config(cfg) -> Optional[cd.DeviceSpec]:
    """The (frozen, hashable) codesign device a config describes, or None."""
    return cd.device_for_layer(cfg.codesign, cfg.device_levels,
                               cfg.response_gamma)


def plan_cache_key(cfg, gamma: float) -> tuple:
    """Geometry tuple identifying one plan build.

    Configs are canonicalized first, so a uniform architecture spelled via
    ``layers`` hits the *identical* cache entry as the scalar spelling;
    genuinely heterogeneous configs key on the fully-resolved per-layer
    tuple instead.
    """
    cfg = cfg.canonical()
    if cfg.layers is not None:
        per_layer = tuple(
            (l.size, float(l.pixel_size), float(l.distance), l.approximation,
             l.codesign, l.device_levels, float(l.response_gamma))
            for l in cfg.layers
        )
        return ("seg", per_layer, cfg.n, float(cfg.pixel_size),
                float(cfg.distance), float(cfg.wavelength),
                bool(cfg.band_limit), bool(cfg.pad), float(gamma),
                bool(cfg.use_pallas), cfg.scan_unroll, cfg.tf_dtype,
                cfg.remat)
    dev = device_spec_from_config(cfg)
    return (cfg.n, float(cfg.pixel_size), cfg.gap_distances(),
            float(cfg.wavelength), cfg.approximation, bool(cfg.band_limit),
            bool(cfg.pad), float(gamma), dev, cfg.codesign,
            bool(cfg.use_pallas), cfg.scan_unroll, cfg.tf_dtype, cfg.remat)


def plan_from_config(cfg, gamma: float):
    """Build (or fetch) the plan for a config — memoized per geometry tuple.

    Uniform configs get the fused single-scan ``PropagationPlan``;
    heterogeneous configs (``cfg.layers`` surviving canonicalization) get a
    ``SegmentedPlan``.  Plans are immutable once built (stacked numpy
    constants + lazily uploaded device arrays), so every model/step/
    benchmark sharing a geometry shares one plan instead of rebuilding and
    re-uploading it.
    """
    key = plan_cache_key(cfg, gamma)
    plan = _cache_get(_PLAN_CACHE, key, _PLAN_STATS)
    if plan is not None:
        return plan
    # validate once per plan-cache miss: physically invalid geometry
    # raises a structured PhysicsValidationError naming the criterion
    # before any TF plane is built (soft regime violations warn)
    physics.check_config(cfg)
    cfg = cfg.canonical()
    if cfg.layers is not None:
        plan = SegmentedPlan(cfg, gamma)
    else:
        dev = device_spec_from_config(cfg)
        plan = PropagationPlan(
            df.Grid(cfg.n, cfg.pixel_size),
            cfg.gap_distances(),
            cfg.wavelength,
            method=cfg.approximation,
            band_limit=cfg.band_limit,
            pad=cfg.pad,
            gamma=gamma,
            device=dev,
            codesign_mode=cfg.codesign,
            use_pallas=cfg.use_pallas,
            unroll=cfg.scan_unroll,
            tf_dtype=cfg.tf_dtype,
            remat=cfg.remat,
        )
    _cache_put(_PLAN_CACHE, key, plan, _PLAN_CACHE_MAX)
    return plan
