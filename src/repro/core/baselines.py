"""Reproduced baselines the paper compares against.

1. ``LightPipesLikeEngine`` — an emulation engine with the limitations the
   paper attributes to LightPipes (Table 1 / §5.3): no batched tensor
   representation (python loop over samples), no operator fusion or kernel
   caching (the transfer function is rebuilt every call), float64 complex
   arithmetic, eager execution (no jit).  Used by the Fig. 8/9 runtime
   benchmarks as the comparison point.

2. Training-method baseline of [34, 67]: DONN training *without* the
   physics-aware complex-valued regularization — i.e. our DONN with
   gamma=1.0 — used by the Fig. 7 / Table 5 / Fig. 13 comparisons.
"""
from __future__ import annotations

import math

import numpy as np

from repro.core.diffraction import Grid


class LightPipesLikeEngine:
    """Deliberately-unoptimized scalar diffraction emulation (numpy, eager)."""

    def __init__(self, grid: Grid, wavelength: float):
        self.grid = grid
        self.wavelength = wavelength

    # -- every step below is its own un-fused operator, rebuilt per call --
    def _transfer(self, z: float) -> np.ndarray:
        n, dx = self.grid.n, self.grid.pixel_size
        f = np.fft.fftfreq(n, d=dx)
        fx, fy = np.meshgrid(f, f, indexing="ij")
        k = 2.0 * math.pi / self.wavelength
        arg = 1.0 - (self.wavelength * fx) ** 2 - (self.wavelength * fy) ** 2
        kz = k * np.sqrt(np.maximum(arg, 0.0))
        kappa = k * np.sqrt(np.maximum(-arg, 0.0))
        return np.where(arg >= 0, np.exp(1j * kz * z), np.exp(-kappa * abs(z)))

    def fft2(self, u: np.ndarray) -> np.ndarray:
        return np.fft.fft2(u.astype(np.complex128))

    def ifft2(self, u: np.ndarray) -> np.ndarray:
        return np.fft.ifft2(u)

    def complex_mm(self, a: np.ndarray, b: np.ndarray) -> np.ndarray:
        return a * b

    def propagate_one(self, u: np.ndarray, z: float) -> np.ndarray:
        h = self._transfer(z)  # rebuilt every call (no caching)
        return self.ifft2(self.complex_mm(self.fft2(u), h))

    def propagate_batch(self, u_batch: np.ndarray, z: float) -> np.ndarray:
        # no tensor representation: python loop over the batch
        return np.stack(
            [self.propagate_one(u_batch[i], z) for i in range(u_batch.shape[0])]
        )

    def modulate_one(self, u: np.ndarray, phi: np.ndarray) -> np.ndarray:
        return self.complex_mm(u, np.exp(1j * phi.astype(np.complex128)))

    def donn_forward(self, x: np.ndarray, phases, distances) -> np.ndarray:
        """Full DONN forward, sample-by-sample (x: (B, n, n) real)."""
        out = []
        for i in range(x.shape[0]):
            u = x[i].astype(np.complex128)
            for li, phi in enumerate(phases):
                u = self.propagate_one(u, distances[li])
                u = self.modulate_one(u, np.asarray(phi))
            u = self.propagate_one(u, distances[-1])
            out.append(np.abs(u) ** 2)
        return np.stack(out)
