"""Physics-aware complex-valued regularization (paper §3.2).

The detected intensity decays roughly geometrically with DONN depth (energy
leaks out of the band-limited aperture and into un-read regions), which
starves amplitude gradients relative to phase gradients.  The paper's fix is
a scalar factor gamma applied to the amplitude in the forward function
(Eq. 9), re-balancing gradient scales between amplitude and phase.

``calibrate_gamma`` measures the actual per-layer energy decay of a model on
a sample batch and returns the gamma that keeps mean field energy ~constant
across depth — the "auto" policy used by our configs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_gamma(u: jax.Array, gamma: float) -> jax.Array:
    """Scale field amplitude by gamma (phase untouched)."""
    return u * gamma


def energy(u: jax.Array) -> jax.Array:
    return jnp.sum(u.real**2 + u.imag**2, axis=(-2, -1))


def calibrate_gamma(model, params, x, target_logit: float = 2.0) -> float:
    """Calibrate the amplitude factor gamma for healthy training dynamics.

    Two physical effects starve gradients as depth grows (paper §3.2):
    (a) field energy leaks out of the band-limited/padded aperture, and
    (b) the detector logits feed an MSE(softmax(I)) loss, so their absolute
    scale acts as an inverse softmax temperature — too large saturates the
    softmax (vanishing gradients), too small flattens it.

    Both are fixed by one knob: choose gamma so the mean per-class detector
    intensity hits ``target_logit``.  Intensity scales as gamma^(2*depth),
    hence gamma = (target / measured)^(1 / (2*depth)).
    """
    logits = model.apply(params, x)
    m = float(jnp.mean(logits))
    depth = model.cfg.depth
    g0 = getattr(model, "gamma", 1.0)
    return float(g0 * (target_logit / max(m, 1e-30)) ** (1.0 / (2.0 * depth)))


def recalibrated(model_cls, cfg, params, x, laser=None):
    """Rebuild a model with calibrated gamma (returns new model)."""
    base = model_cls(cfg, laser)
    g = calibrate_gamma(base, params, x)
    import dataclasses

    cfg2 = dataclasses.replace(cfg, gamma=g)
    return model_cls(cfg2, laser), g
