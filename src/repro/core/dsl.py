"""LightRidge front-end DSL (paper §3.3, Table 2).

Mirrors the paper's `lr.*` surface: ``lr.laser``, ``lr.layers.diffractlayer``
/ ``diffractlayer_raw`` / ``detector``, ``lr.models.sequential``.  Layer specs
are plain data; ``sequential`` assembles them into a ``DONNConfig`` + model.
A JSON-able ``from_spec`` entry point supports config-file driven builds
(used by the launcher).

Example (5-layer hardware-aware classifier, the paper's §5.1 system):

    import repro.core.dsl as lr
    src = lr.laser(wavelength=532e-9)
    layers = [lr.layers.diffractlayer(distance=0.3, pixel_size=36e-6,
                                      size=200, precision=256)
              for _ in range(5)]
    det = lr.layers.detector(num_classes=10, det_size=20)
    model, cfg = lr.models.sequential(layers, det, laser=src)
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Optional, Sequence

from repro.core.config import DONNConfig
from repro.core.laser import Laser
from repro.core.models import build_model


def laser(wavelength: float = 532e-9, profile: str = "plane",
          waist: Optional[float] = None, power: float = 1.0) -> Laser:
    return Laser(wavelength=wavelength, profile=profile, waist=waist, power=power)


def _diffractlayer(distance: float = 0.3, pixel_size: float = 36e-6,
                   size: int = 200, approximation: str = "rs",
                   precision: Optional[int] = None, codesign: str = "qat",
                   pad: bool = False, band_limit: bool = True) -> dict:
    return dict(
        kind="diffract",
        distance=distance,
        pixel_size=pixel_size,
        size=size,
        approximation=approximation,
        precision=precision,
        codesign=codesign if precision else "none",
        pad=pad,
        band_limit=band_limit,
    )


def _diffractlayer_raw(**kw) -> dict:
    kw.setdefault("precision", None)
    kw["codesign"] = "none"
    return _diffractlayer(**kw)


def _detector(num_classes: int = 10, det_size: int = 20, layout: str = "grid",
              x_loc=None, y_loc=None, distance: float = 0.3) -> dict:
    return dict(
        kind="detector",
        num_classes=num_classes,
        det_size=det_size,
        layout=layout,
        x_loc=x_loc,
        y_loc=y_loc,
        distance=distance,
    )


def _sequential(layer_specs: Sequence[dict], detector_spec: dict,
                laser: Optional[Laser] = None, name: str = "donn-dsl",
                gamma: Optional[float] = None, use_pallas: bool = False,
                segmentation: bool = False, skip_from: Optional[int] = None,
                channels: int = 1, input_size: int = 28):
    """Assemble layer + detector specs into (model, DONNConfig)."""
    if not layer_specs:
        raise ValueError("need at least one diffractive layer")
    first = layer_specs[0]
    for spec in layer_specs[1:]:
        for k in ("pixel_size", "size", "approximation", "pad", "band_limit"):
            if spec[k] != first[k]:
                raise ValueError(f"heterogeneous {k} across layers unsupported")
    distances = [s["distance"] for s in layer_specs] + [detector_spec["distance"]]
    precision = first.get("precision")
    cfg = DONNConfig(
        name=name,
        n=first["size"],
        pixel_size=first["pixel_size"],
        wavelength=(laser.wavelength if laser else 532e-9),
        distances=tuple(distances),
        depth=len(layer_specs),
        approximation=first["approximation"],
        band_limit=first["band_limit"],
        pad=first["pad"],
        num_classes=detector_spec["num_classes"],
        det_size=detector_spec["det_size"],
        detector_layout=detector_spec["layout"],
        gamma=gamma,
        codesign=first["codesign"] if precision else "none",
        device_levels=precision or 256,
        channels=channels,
        segmentation=segmentation,
        skip_from=skip_from,
        layer_norm=segmentation,
        use_pallas=use_pallas,
        input_size=input_size,
    )
    return build_model(cfg, laser), cfg


def from_spec(spec: dict):
    """Build a model from a JSON-able spec dict: {laser, layers, detector,...}."""
    src = laser(**spec.get("laser", {}))
    layer_specs = [
        _diffractlayer(**{k: v for k, v in s.items() if k != "kind"})
        for s in spec["layers"]
    ]
    det = _detector(**{k: v for k, v in spec["detector"].items() if k != "kind"})
    opts = {
        k: spec[k]
        for k in (
            "name", "gamma", "use_pallas", "segmentation", "skip_from",
            "channels", "input_size",
        )
        if k in spec
    }
    return _sequential(layer_specs, det, laser=src, **opts)


def from_config(cfg: DONNConfig, laser_: Optional[Laser] = None):
    return build_model(cfg, laser_)


layers = SimpleNamespace(
    diffractlayer=_diffractlayer,
    diffractlayer_raw=_diffractlayer_raw,
    detector=_detector,
)
models = SimpleNamespace(sequential=_sequential)
