"""LightRidge front-end DSL (paper §3.3, Table 2).

Mirrors the paper's `lr.*` surface: ``lr.laser``, ``lr.layers.diffractlayer``
/ ``diffractlayer_raw`` / ``detector``, ``lr.models.sequential``.  Layer specs
are plain data; ``sequential`` assembles them into a ``DONNConfig`` + model.
A JSON-able ``from_spec`` entry point supports config-file driven builds
(used by the launcher); ``to_spec`` is its inverse, so DSE winners and
heterogeneous architectures round-trip through JSON artifacts.

Layer specs may be *heterogeneous*: per-layer distance, plane size, pixel
size, approximation method and device precision are all free (mixed
SLM + printed-mask stacks, shrinking plane pyramids, ...).  Uniform specs
compile to the classic scalar ``DONNConfig`` (identical plan-cache keys);
mixed specs compile to a ``DONNConfig.layers`` tuple of ``LayerSpec``s and
run on the segmented scan engine.  ``pad`` and ``band_limit`` remain global
knobs (they change the FFT grid protocol, not a layer property).

Example (5-layer hardware-aware classifier, the paper's §5.1 system):

    import repro.core.dsl as lr
    src = lr.laser(wavelength=532e-9)
    layers = [lr.layers.diffractlayer(distance=0.3, pixel_size=36e-6,
                                      size=200, precision=256)
              for _ in range(5)]
    det = lr.layers.detector(num_classes=10, det_size=20)
    model, cfg = lr.models.sequential(layers, det, laser=src)

Mixed-precision, mixed-size stack (SLM front end, printed-mask back end):

    front = [lr.layers.diffractlayer(distance=0.10, size=200, precision=256)
             for _ in range(3)]
    back = [lr.layers.diffractlayer(distance=0.05, size=128, precision=4)
            for _ in range(2)]
    model, cfg = lr.models.sequential(front + back, det, laser=src)
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Optional, Sequence

from repro.core import physics
from repro.core.config import DONNConfig, LayerSpec
from repro.core.laser import Laser
from repro.core.models import build_model


def laser(wavelength: float = 532e-9, profile: str = "plane",
          waist: Optional[float] = None, power: float = 1.0) -> Laser:
    return Laser(wavelength=wavelength, profile=profile, waist=waist, power=power)


def _diffractlayer(distance: float = 0.3, pixel_size: float = 36e-6,
                   size: int = 200, approximation: str = "rs",
                   precision: Optional[int] = None, codesign: str = "qat",
                   response_gamma: float = 1.0,
                   pad: bool = False, band_limit: bool = True) -> dict:
    return dict(
        kind="diffract",
        distance=distance,
        pixel_size=pixel_size,
        size=size,
        approximation=approximation,
        precision=precision,
        codesign=codesign if precision else "none",
        response_gamma=response_gamma,
        pad=pad,
        band_limit=band_limit,
    )


def _diffractlayer_raw(**kw) -> dict:
    kw.setdefault("precision", None)
    kw["codesign"] = "none"
    return _diffractlayer(**kw)


def _detector(num_classes: int = 10, det_size: int = 20, layout: str = "grid",
              x_loc=None, y_loc=None, distance: float = 0.3) -> dict:
    return dict(
        kind="detector",
        num_classes=num_classes,
        det_size=det_size,
        layout=layout,
        x_loc=x_loc,
        y_loc=y_loc,
        distance=distance,
    )


# layer-spec keys that may vary per layer vs. the global grid-protocol knobs
_PER_LAYER_KEYS = ("pixel_size", "size", "approximation", "precision",
                   "codesign", "response_gamma")
_GLOBAL_KEYS = ("pad", "band_limit")


def _sequential(layer_specs: Sequence[dict], detector_spec: dict,
                laser: Optional[Laser] = None, **opts):
    """Assemble layer + detector specs into (model, DONNConfig).

    ``n`` / ``pixel_size`` in ``opts`` set the detector/system grid
    explicitly; they default to the first layer's plane (the uniform
    convention).  See ``_sequential_config`` for the full option list.
    """
    cfg = _sequential_config(layer_specs, detector_spec, laser=laser, **opts)
    # fail physically invalid specs with a domain error naming the
    # criterion, not a shape/aliasing symptom deep in diffraction.py
    physics.check_config(cfg)
    return build_model(cfg, laser), cfg


def _sequential_config(layer_specs: Sequence[dict], detector_spec: dict,
                       laser: Optional[Laser] = None, name: str = "donn-dsl",
                       gamma: Optional[float] = None, use_pallas: bool = False,
                       segmentation: bool = False,
                       skip_from: Optional[int] = None,
                       channels: int = 1, input_size: int = 28,
                       engine: str = "scan", scan_unroll: Optional[int] = None,
                       tf_dtype: str = "float32", remat: str = "none",
                       layer_norm: Optional[bool] = None,
                       n: Optional[int] = None,
                       pixel_size: Optional[float] = None) -> DONNConfig:
    """Config-assembly half of ``sequential`` — no model build, no
    validation; shared by the DSL, ``from_spec`` and the lint-time spec
    validator (``spec_to_config``)."""
    if not layer_specs:
        raise ValueError("need at least one diffractive layer")
    first = layer_specs[0]
    for spec in layer_specs[1:]:
        for k in _GLOBAL_KEYS:
            if spec[k] != first[k]:
                raise ValueError(
                    f"heterogeneous {k} across layers unsupported: it is a "
                    "grid-protocol knob, set it once for the whole stack"
                )
    det_n = n if n is not None else first["size"]
    det_pixel = pixel_size if pixel_size is not None else first["pixel_size"]
    # layers are heterogeneous when they differ from each other OR when the
    # (uniform) stack lives off the detector/system grid — the scalar config
    # form cannot express a plane grid != detector grid
    hetero = any(
        spec[k] != first[k]
        for spec in layer_specs[1:] for k in _PER_LAYER_KEYS
    ) or first["size"] != det_n or first["pixel_size"] != det_pixel
    common = dict(
        name=name,
        n=det_n,
        pixel_size=det_pixel,
        wavelength=(laser.wavelength if laser else 532e-9),
        depth=len(layer_specs),
        band_limit=first["band_limit"],
        pad=first["pad"],
        num_classes=detector_spec["num_classes"],
        det_size=detector_spec["det_size"],
        detector_layout=detector_spec["layout"],
        gamma=gamma,
        channels=channels,
        segmentation=segmentation,
        skip_from=skip_from,
        layer_norm=segmentation if layer_norm is None else layer_norm,
        use_pallas=use_pallas,
        input_size=input_size,
        engine=engine,
        scan_unroll=scan_unroll,
        tf_dtype=tf_dtype,
        remat=remat,
    )
    precision = first.get("precision")
    if not hetero:
        distances = ([s["distance"] for s in layer_specs]
                     + [detector_spec["distance"]])
        cfg = DONNConfig(
            distances=tuple(distances),
            approximation=first["approximation"],
            codesign=first["codesign"] if precision else "none",
            device_levels=precision or 256,
            response_gamma=first["response_gamma"],
            **common,
        )
    else:
        layers = tuple(
            LayerSpec(
                distance=s["distance"],
                approximation=s["approximation"],
                codesign=s["codesign"] if s.get("precision") else "none",
                device_levels=s.get("precision") or 256,
                response_gamma=s["response_gamma"],
                size=s["size"],
                pixel_size=s["pixel_size"],
            )
            for s in layer_specs
        )
        cfg = DONNConfig(
            distance=detector_spec["distance"],  # final hop to the detector
            layers=layers,
            approximation=first["approximation"],
            codesign=first["codesign"] if precision else "none",
            device_levels=precision or 256,
            response_gamma=first["response_gamma"],
            **common,
        )
    return cfg


_SEQUENTIAL_OPTS = (
    "name", "gamma", "use_pallas", "segmentation", "skip_from", "channels",
    "input_size", "engine", "scan_unroll", "tf_dtype", "remat",
    "layer_norm", "n", "pixel_size",
)


def spec_to_config(spec: dict) -> DONNConfig:
    """Assemble the ``DONNConfig`` a JSON spec describes — no model build,
    no physics validation (the lint-time / artifact-audit entry point;
    run ``repro.core.physics.validate_config`` on the result)."""
    src = laser(**spec.get("laser", {}))
    layer_specs = [
        _diffractlayer(**{k: v for k, v in s.items() if k != "kind"})
        for s in spec["layers"]
    ]
    det = _detector(**{k: v for k, v in spec["detector"].items() if k != "kind"})
    opts = {k: spec[k] for k in _SEQUENTIAL_OPTS if k in spec}
    return _sequential_config(layer_specs, det, laser=src, **opts)


def from_spec(spec: dict):
    """Build a model from a JSON-able spec dict: {laser, layers, detector,...}.

    Physically invalid specs raise ``PhysicsValidationError`` naming the
    violated criterion before any layer is built.
    """
    src = laser(**spec.get("laser", {}))
    cfg = spec_to_config(spec)
    physics.check_config(cfg)
    return build_model(cfg, src), cfg


def to_spec(cfg: DONNConfig, laser_: Optional[Laser] = None) -> dict:
    """Inverse of ``from_spec``: DONNConfig -> JSON-able spec dict.

    ``from_spec(to_spec(cfg))`` rebuilds an architecturally identical
    config (same ``canonical()`` form / plan-cache key), uniform or
    heterogeneous — the persistence format for DSE winners and logged
    architectures.
    """
    layers = [
        dict(
            kind="diffract",
            distance=s.distance,
            pixel_size=s.pixel_size,
            size=s.size,
            approximation=s.approximation,
            precision=s.device_levels,
            codesign=s.codesign,
            response_gamma=s.response_gamma,
            pad=cfg.pad,
            band_limit=cfg.band_limit,
        )
        for s in cfg.resolved_layers()
    ]
    laser_spec = (
        dict(wavelength=laser_.wavelength, profile=laser_.profile,
             waist=laser_.waist, power=laser_.power)
        if laser_ is not None else {"wavelength": cfg.wavelength}
    )
    spec = {
        "name": cfg.name,
        "laser": laser_spec,
        "n": cfg.n,  # detector/system grid (may differ from layer planes)
        "pixel_size": cfg.pixel_size,
        "layers": layers,
        "detector": dict(
            kind="detector",
            num_classes=cfg.num_classes,
            det_size=cfg.det_size,
            layout=cfg.detector_layout,
            distance=cfg.gap_distances()[-1],
        ),
        "gamma": cfg.gamma,
        "use_pallas": cfg.use_pallas,
        "segmentation": cfg.segmentation,
        "skip_from": cfg.skip_from,
        "channels": cfg.channels,
        "input_size": cfg.input_size,
        "engine": cfg.engine,
        "scan_unroll": cfg.scan_unroll,
        "tf_dtype": cfg.tf_dtype,
        "remat": cfg.remat,
        "layer_norm": cfg.layer_norm,
    }
    # exported artifacts must be loadable: run the same validator
    # ``from_spec`` applies, so invalid specs fail at export time too
    physics.check_config(cfg)
    return spec


def from_config(cfg: DONNConfig, laser_: Optional[Laser] = None):
    return build_model(cfg, laser_)


layers = SimpleNamespace(
    diffractlayer=_diffractlayer,
    diffractlayer_raw=_diffractlayer_raw,
    detector=_detector,
)
models = SimpleNamespace(sequential=_sequential)
