"""Shared bounded-LRU helpers for the process-wide constant caches.

One implementation behind every cache in the compile pipeline (transfer
planes, plans, executables, models, batched inputs, resample matrices):
plain dicts in insertion order, where a lookup reinserts the hit entry at
the back (most recently used) and eviction pops the front — a DSE sweep
alternating more geometries than a bound can hold never evicts its own
hot entries.
"""
from __future__ import annotations

from typing import Optional


def lru_get(cache: dict, key, stats: Optional[dict] = None):
    """LRU lookup: refresh recency on hit (dicts iterate in insertion order)."""
    entry = cache.pop(key, None)
    if entry is None:
        if stats is not None:
            stats["misses"] += 1
        return None
    if stats is not None:
        stats["hits"] += 1
    cache[key] = entry  # reinsert at the back: most recently used
    return entry


def lru_put(cache: dict, key, value, max_size: int) -> None:
    while len(cache) >= max_size:
        cache.pop(next(iter(cache)))  # front = least recently used
    cache[key] = value
