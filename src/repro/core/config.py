"""Configuration dataclasses for DONN systems (the paper's architectures)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class DONNConfig:
    """Full architectural + fabrication description of a DONN system.

    Mirrors the knobs exposed by the LightRidge DSL (Table 2): system size,
    diffraction unit size, wavelength, per-gap distances, approximation
    method, device precision, detector geometry, codesign mode.
    """

    name: str = "donn"
    n: int = 200  # system size / resolution per side
    pixel_size: float = 36e-6  # diffraction unit size [m]
    wavelength: float = 532e-9  # [m]
    distance: float = 0.30  # uniform inter-plane distance [m]
    distances: Optional[Sequence[float]] = None  # per-gap override (depth+1 gaps)
    depth: int = 3  # number of diffractive layers
    approximation: str = "rs"  # rs | fresnel | fraunhofer
    band_limit: bool = True
    pad: bool = False  # 2x zero-padding for linear convolution
    # --- detector ---
    num_classes: int = 10
    det_size: int = 20  # detector region side [pixels]
    detector_layout: str = "grid"
    # --- training physics ---
    gamma: Optional[float] = None  # complex-valued regularization factor
    # --- hardware codesign ---
    codesign: str = "none"  # none | qat | gumbel | gumbel_hard | ptq
    device_levels: int = 256
    response_gamma: float = 1.0
    # --- advanced architectures ---
    channels: int = 1  # multi-channel (RGB) DONN
    segmentation: bool = False
    skip_from: Optional[int] = None  # optical-skip source layer index
    layer_norm: bool = False  # train-time LN before detector (segmentation)
    # --- runtime ---
    use_pallas: bool = False  # Pallas kernels for modulation/readout
    engine: str = "scan"  # "scan" (fused PropagationPlan) | "eager" (per-layer loop)
    input_size: int = 28  # native input image side (embedded/upsampled to n)
    # scan-engine steady-state tuning: unroll factor for the layer scan
    # (None = depth heuristic, see propagation.default_scan_unroll)
    scan_unroll: Optional[int] = None
    # TF-plane storage dtype: "float32" (reference) | "bfloat16" (half the
    # constant memory; accumulation stays f32, agreement tolerance loosens)
    tf_dtype: str = "float32"

    def __post_init__(self):
        if self.engine not in ("scan", "eager"):
            raise ValueError(
                f"engine must be 'scan' or 'eager', got {self.engine!r}"
            )
        if self.tf_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"tf_dtype must be 'float32' or 'bfloat16', got {self.tf_dtype!r}"
            )
        if self.scan_unroll is not None and self.scan_unroll < 1:
            raise ValueError("scan_unroll must be >= 1")

    def gap_distances(self) -> tuple:
        """depth+1 propagation gaps: source->L1, L_i->L_{i+1}, L_last->det."""
        if self.distances is not None:
            ds = tuple(float(d) for d in self.distances)
            if len(ds) != self.depth + 1:
                raise ValueError(
                    f"distances must have depth+1={self.depth + 1} entries"
                )
            return ds
        return (float(self.distance),) * (self.depth + 1)
