"""Configuration dataclasses for DONN systems (the paper's architectures)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

_METHODS = ("rs", "fresnel", "fraunhofer")
_CODESIGN_MODES = ("none", "qat", "gumbel", "gumbel_hard", "ptq")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """Per-layer architecture description (heterogeneous DONN stacks).

    Every field except ``distance`` may be ``None``, meaning "inherit the
    config-level scalar" — a ``DONNConfig`` whose ``layers`` all resolve to
    the config scalars is *canonically identical* to the uniform config
    (same plan-cache key, same compiled program).

    - ``distance``: propagation gap *into* this layer (from the previous
      plane — the source plane for layer 0) [m].
    - ``approximation``: rs | fresnel | fraunhofer.
    - ``codesign`` / ``device_levels`` / ``response_gamma``: per-layer
      fabrication device (e.g. a 256-level SLM front stack driving 4-level
      printed-mask back layers, trained jointly).
    - ``size`` / ``pixel_size``: per-layer plane geometry; fields are
      resampled between planes whose grids differ.
    """

    distance: float = 0.30
    approximation: Optional[str] = None
    codesign: Optional[str] = None
    device_levels: Optional[int] = None
    response_gamma: Optional[float] = None
    size: Optional[int] = None
    pixel_size: Optional[float] = None

    def __post_init__(self):
        if self.approximation is not None and self.approximation not in _METHODS:
            raise ValueError(
                f"LayerSpec.approximation must be one of {_METHODS}, "
                f"got {self.approximation!r}"
            )
        if self.codesign is not None and self.codesign not in _CODESIGN_MODES:
            raise ValueError(
                f"LayerSpec.codesign must be one of {_CODESIGN_MODES}, "
                f"got {self.codesign!r}"
            )

    def resolve(self, cfg: "DONNConfig") -> "LayerSpec":
        """Fill inherited (None) fields from the config scalars."""
        return LayerSpec(
            distance=float(self.distance),
            approximation=self.approximation or cfg.approximation,
            codesign=self.codesign if self.codesign is not None else cfg.codesign,
            device_levels=(self.device_levels if self.device_levels is not None
                           else cfg.device_levels),
            response_gamma=(float(self.response_gamma)
                            if self.response_gamma is not None
                            else float(cfg.response_gamma)),
            size=self.size if self.size is not None else cfg.n,
            pixel_size=(float(self.pixel_size) if self.pixel_size is not None
                        else float(cfg.pixel_size)),
        )


@dataclasses.dataclass(frozen=True)
class DONNConfig:
    """Full architectural + fabrication description of a DONN system.

    Mirrors the knobs exposed by the LightRidge DSL (Table 2): system size,
    diffraction unit size, wavelength, per-gap distances, approximation
    method, device precision, detector geometry, codesign mode.

    Heterogeneous stacks are described by ``layers`` — one ``LayerSpec``
    per diffractive layer, each overriding the config scalars per layer
    (plane size, pixel size, approximation, codesign device, distance).
    With ``layers`` set, ``distance`` is the final layer -> detector gap
    and ``distances`` must be None.  A ``layers`` tuple that resolves to
    the uniform scalars canonicalizes back to the scalar form
    (``canonical()``) and shares its plan cache entry.
    """

    name: str = "donn"
    n: int = 200  # system size / resolution per side
    pixel_size: float = 36e-6  # diffraction unit size [m]
    wavelength: float = 532e-9  # [m]
    distance: float = 0.30  # uniform inter-plane distance [m]
    distances: Optional[Sequence[float]] = None  # per-gap override (depth+1 gaps)
    depth: int = 3  # number of diffractive layers
    approximation: str = "rs"  # rs | fresnel | fraunhofer
    band_limit: bool = True
    pad: bool = False  # 2x zero-padding for linear convolution
    # --- detector ---
    num_classes: int = 10
    det_size: int = 20  # detector region side [pixels]
    detector_layout: str = "grid"
    # --- training physics ---
    gamma: Optional[float] = None  # complex-valued regularization factor
    # --- hardware codesign ---
    codesign: str = "none"  # none | qat | gumbel | gumbel_hard | ptq
    device_levels: int = 256
    response_gamma: float = 1.0
    # --- advanced architectures ---
    channels: int = 1  # multi-channel (RGB) DONN
    segmentation: bool = False
    skip_from: Optional[int] = None  # optical-skip source layer index
    layer_norm: bool = False  # train-time LN before detector (segmentation)
    # --- heterogeneous per-layer architecture ---
    layers: Optional[Sequence[LayerSpec]] = None  # per-layer overrides
    # --- runtime ---
    use_pallas: bool = False  # Pallas kernels for modulation/readout
    engine: str = "scan"  # "scan" (fused PropagationPlan) | "eager" (per-layer loop)
    input_size: int = 28  # native input image side (embedded/upsampled to n)
    # scan-engine steady-state tuning: unroll factor for the layer scan
    # (None = depth heuristic, see propagation.default_scan_unroll)
    scan_unroll: Optional[int] = None
    # TF-plane storage dtype: "float32" (reference) | "bfloat16" (half the
    # constant memory; accumulation stays f32, agreement tolerance loosens)
    tf_dtype: str = "float32"
    # Rematerialization policy for the layer scan (training memory knob):
    #   "none"    — store every layer's activations for the backward pass
    #               (fastest, highest memory; the default);
    #   "layer"   — jax.checkpoint the scan body, so the backward pass
    #               recomputes each layer's FFT chain from its carry
    #               (activation memory drops from O(depth) fields to O(1)
    #               per scan segment — the deep/large-plane training knob);
    #   "segment" — jax.checkpoint each fused scan segment as a whole
    #               (per-segment boundaries only; for uniform stacks this
    #               checkpoints the entire layer stack).
    remat: str = "none"

    def __post_init__(self):
        if self.engine not in ("scan", "eager"):
            raise ValueError(
                f"engine must be 'scan' or 'eager', got {self.engine!r}"
            )
        if self.remat not in ("none", "layer", "segment"):
            raise ValueError(
                f"remat must be 'none', 'layer' or 'segment', "
                f"got {self.remat!r}"
            )
        if self.tf_dtype not in ("float32", "bfloat16"):
            raise ValueError(
                f"tf_dtype must be 'float32' or 'bfloat16', got {self.tf_dtype!r}"
            )
        if self.scan_unroll is not None and self.scan_unroll < 1:
            raise ValueError("scan_unroll must be >= 1")
        if self.distances is not None and len(self.distances) != self.depth + 1:
            raise ValueError(
                f"distances must have depth+1={self.depth + 1} entries "
                f"(source->L1, inter-layer gaps, L_last->detector); got "
                f"{len(self.distances)}"
            )
        if self.layers is not None:
            if self.distances is not None:
                raise ValueError(
                    "layers and distances are mutually exclusive: per-layer "
                    "gaps live in LayerSpec.distance and `distance` is the "
                    "final layer->detector gap"
                )
            if len(self.layers) != self.depth:
                raise ValueError(
                    f"layers must have depth={self.depth} entries, got "
                    f"{len(self.layers)}"
                )
            if not all(isinstance(l, LayerSpec) for l in self.layers):
                raise ValueError("layers entries must be LayerSpec instances")
            # normalize to a tuple so frozen configs hash/compare by value
            object.__setattr__(self, "layers", tuple(self.layers))

    def gap_distances(self) -> tuple:
        """depth+1 propagation gaps: source->L1, L_i->L_{i+1}, L_last->det."""
        if self.layers is not None:
            return tuple(float(l.distance) for l in self.layers) + (
                float(self.distance),
            )
        if self.distances is not None:
            return tuple(float(d) for d in self.distances)
        return (float(self.distance),) * (self.depth + 1)

    def resolved_layers(self) -> tuple:
        """Fully-resolved per-layer specs (inherits filled from scalars)."""
        gaps = self.gap_distances()
        if self.layers is not None:
            return tuple(l.resolve(self) for l in self.layers)
        return tuple(
            LayerSpec(distance=gaps[i]).resolve(self) for i in range(self.depth)
        )

    def canonical(self) -> "DONNConfig":
        """Normal form: uniform ``layers`` fold back into the scalar fields.

        A config whose per-layer specs all resolve to the config scalars is
        the *same architecture* as the scalar config — ``canonical()`` maps
        both spellings to one value so plan/model/executable caches key
        identically.  Heterogeneous configs normalize their ``layers`` to
        the fully-resolved form (inherited Nones filled in).
        """
        if self.layers is None:
            return self
        resolved = self.resolved_layers()
        common = dataclasses.replace(resolved[0], distance=0.0)
        if (all(dataclasses.replace(l, distance=0.0) == common
                for l in resolved)
                and common.size == self.n
                and common.pixel_size == float(self.pixel_size)):
            # every layer equals every other (up to distance) and lives on
            # the detector/system grid: this IS the scalar architecture —
            # fold onto the layers' common values (not the possibly
            # different inheritance scalars)
            return dataclasses.replace(
                self,
                layers=None,
                distances=self.gap_distances(),
                approximation=common.approximation,
                codesign=common.codesign,
                device_levels=common.device_levels,
                response_gamma=common.response_gamma,
            )
        # once layers are fully resolved, the per-layer inheritance scalars
        # are shadowed — reset them so equivalent spellings key identically
        shadowed = dict(approximation="rs", codesign="none",
                        device_levels=256, response_gamma=1.0)
        if resolved == self.layers and all(
            getattr(self, k) == v for k, v in shadowed.items()
        ):
            return self
        return dataclasses.replace(self, layers=resolved, **shadowed)

    def is_heterogeneous(self) -> bool:
        return self.canonical().layers is not None
