"""DONN model containers (LightRidge `lr.models`).

- ``DONN``: sequential stack of diffractive layers + detector (classification).
- ``MultiChannelDONN``: the paper's RGB architecture (Fig. 12) — parallel
  optical channels whose output intensities merge on one detector.
- ``SegmentationDONN``: the paper's image-segmentation architecture (Fig. 13)
  with *optical skip connection* (complex-field beam-splitter sum) and
  train-time layer normalization.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codesign as cd
from repro.core import diffraction as df
from repro.core.config import DONNConfig
from repro.core.laser import Laser, data_to_cplex
from repro.core.layers import Detector, DiffractiveLayer
from repro.core.propagation import plan_from_config
from repro.nn import ParamSpec, init_params


def _build_layers(cfg: DONNConfig, grid: df.Grid, gamma: float):
    dev = (
        cd.DeviceSpec(levels=cfg.device_levels, response_gamma=cfg.response_gamma)
        if cfg.codesign != "none"
        else None
    )
    gaps = cfg.gap_distances()
    layers = []
    for i in range(cfg.depth):
        layers.append(
            DiffractiveLayer(
                grid,
                gaps[i],
                cfg.wavelength,
                method=cfg.approximation,
                band_limit=cfg.band_limit,
                pad=cfg.pad,
                device=dev,
                codesign_mode=cfg.codesign,
                gamma=gamma,
                use_pallas=cfg.use_pallas,
            )
        )
    # final free-space hop: last layer -> detector plane (no modulation)
    final = DiffractiveLayer(
        grid,
        gaps[-1],
        cfg.wavelength,
        method=cfg.approximation,
        band_limit=cfg.band_limit,
        pad=cfg.pad,
        gamma=1.0,
        use_pallas=cfg.use_pallas,
    )
    return layers, final


class DONN:
    """Sequential DONN classifier."""

    def __init__(self, cfg: DONNConfig, laser: Optional[Laser] = None):
        if cfg.channels != 1:
            raise ValueError("use MultiChannelDONN for channels > 1")
        self.cfg = cfg
        self.grid = df.Grid(cfg.n, cfg.pixel_size)
        self.laser = laser or Laser(wavelength=cfg.wavelength)
        self.gamma = 1.0 if cfg.gamma is None else float(cfg.gamma)
        self.layers, self.final = _build_layers(cfg, self.grid, self.gamma)
        self._plan = None  # built on first scan-path use
        self.detector = Detector(
            self.grid,
            cfg.num_classes,
            cfg.det_size,
            cfg.detector_layout,
            use_pallas=cfg.use_pallas,
        )
        self.source = self.laser.field(self.grid)  # (n, n) complex64 const

    @property
    def plan(self):
        if self._plan is None:
            self._plan = plan_from_config(self.cfg, self.gamma)
        return self._plan

    # --- params ---
    def param_specs(self):
        return {
            "phase": {
                f"layer_{i}": layer.param_spec()
                for i, layer in enumerate(self.layers)
            }
        }

    def init(self, key: jax.Array):
        return init_params(self.param_specs(), key)

    # --- forward ---
    def encode(self, x: jax.Array) -> jax.Array:
        u = data_to_cplex(x, self.cfg.n)
        return u * jnp.asarray(self.source)

    def fields(self, params, x, rng: Optional[jax.Array] = None):
        """All intermediate fields (lr.model.prop_view)."""
        u = self.encode(x)
        out = [u]
        rngs = (
            jax.random.split(rng, len(self.layers)) if rng is not None else
            [None] * len(self.layers)
        )
        for i, layer in enumerate(self.layers):
            u = layer(params["phase"][f"layer_{i}"], u, rngs[i])
            out.append(u)
        u = self.final.propagate(u)
        out.append(u)
        return out

    def stacked_phases(self, params) -> jax.Array:
        return jnp.stack(
            [params["phase"][f"layer_{i}"] for i in range(len(self.layers))]
        )

    def apply(self, params, x, rng: Optional[jax.Array] = None) -> jax.Array:
        """Images (..., h, w) -> per-class detector intensities (..., C)."""
        if self.cfg.engine == "eager":
            u = self.fields(params, x, rng)[-1]
        else:
            u = self.plan.apply(self.stacked_phases(params), self.encode(x),
                                rng)
        return self.detector(u)

    def prop_view(self, params, x, rng=None):
        return [df.intensity(u) for u in self.fields(params, x, rng)]


class MultiChannelDONN:
    """Multi-channel (RGB) DONN (paper Fig. 12).

    ``channels`` parallel optical stacks; each encodes one input channel; all
    output beams project onto a single shared detector where intensities add.
    """

    def __init__(self, cfg: DONNConfig, laser: Optional[Laser] = None):
        self.cfg = cfg
        sub = DONNConfig(**{**cfg.__dict__, "channels": 1})
        self.channel_model = DONN(sub, laser)

    def param_specs(self):
        spec = self.channel_model.param_specs()["phase"]
        c = self.cfg.channels
        return {
            "phase": {
                name: ParamSpec(
                    (c,) + s.shape,
                    s.dtype,
                    ("channel",) + s.logical_axes,
                    init=s.init,
                )
                for name, s in spec.items()
            }
        }

    def init(self, key):
        return init_params(self.param_specs(), key)

    def apply(self, params, x, rng: Optional[jax.Array] = None) -> jax.Array:
        """x: (..., C, h, w) multi-channel images -> (..., num_classes)."""
        cm = self.channel_model
        if self.cfg.engine == "eager":
            def one_channel(phases, xc):
                p = {"phase": phases}
                u = cm.fields(p, xc, rng)[-1]
                return df.intensity(u)

            # vmap over the channel axis of both params and inputs
            inten = jax.vmap(one_channel, in_axes=(0, -3), out_axes=0)(
                params["phase"], x
            )
            total = jnp.sum(inten, axis=0)  # incoherent sum on shared detector
            masks = jnp.asarray(cm.detector.masks)
            return jnp.einsum("...hw,chw->...c", total, masks)
        # batched plan path: all channels propagate as one (..., C, N, N)
        # tensor through shared kernels (the TFs are channel-independent;
        # the (L, C, N, N) phase stack rides the scan).
        phis = jnp.stack(
            [params["phase"][f"layer_{i}"] for i in range(len(cm.layers))]
        )
        u = data_to_cplex(x, self.cfg.n) * jnp.asarray(cm.source)
        u = cm.plan.apply(phis, u, rng)
        masks = jnp.asarray(cm.detector.masks)
        if self.cfg.use_pallas:
            from repro.kernels import ops as kops

            per_ch = kops.intensity_readout(u.real, u.imag, masks)
            return jnp.sum(per_ch, axis=-2)
        # one fused accumulation: channel sum + detector pooling in a
        # single contraction over (channel, h, w)
        return jnp.einsum("...dhw,chw->...c", df.intensity(u), masks)


class SegmentationDONN:
    """All-optical image segmentation DONN (paper Fig. 13a).

    Optical skip connection: the field exiting layer ``skip_from`` is split
    off, propagated directly to the detector plane, and coherently recombined
    (beam-splitter sum, 1/sqrt(2) each) with the main path.  LayerNorm on the
    output intensity is applied only during training.
    """

    def __init__(self, cfg: DONNConfig, laser: Optional[Laser] = None):
        self.cfg = cfg
        self.grid = df.Grid(cfg.n, cfg.pixel_size)
        self.laser = laser or Laser(wavelength=cfg.wavelength)
        self.gamma = 1.0 if cfg.gamma is None else float(cfg.gamma)
        self.layers, self.final = _build_layers(cfg, self.grid, self.gamma)
        self._plan = None  # built on first scan-path use
        self.skip_from = cfg.skip_from
        if self.skip_from is not None:
            # skip hop covers the remaining distance to the detector plane
            gaps = cfg.gap_distances()
            z_skip = float(sum(gaps[self.skip_from + 1 :]))
            self.skip_hop = DiffractiveLayer(
                self.grid,
                z_skip,
                cfg.wavelength,
                method=cfg.approximation,
                band_limit=cfg.band_limit,
                pad=cfg.pad,
            )
        self.source = self.laser.field(self.grid)

    @property
    def plan(self):
        if self._plan is None:
            self._plan = plan_from_config(self.cfg, self.gamma)
        return self._plan

    def param_specs(self):
        return {
            "phase": {
                f"layer_{i}": layer.param_spec()
                for i, layer in enumerate(self.layers)
            }
        }

    def init(self, key):
        return init_params(self.param_specs(), key)

    def apply(
        self, params, x, rng: Optional[jax.Array] = None, train: bool = False
    ) -> jax.Array:
        """Images (..., h, w) -> per-pixel intensity map (..., n, n)."""
        u = data_to_cplex(x, self.cfg.n) * jnp.asarray(self.source)
        skip_u = None
        if self.cfg.engine == "eager":
            rngs = (
                jax.random.split(rng, len(self.layers)) if rng is not None
                else [None] * len(self.layers)
            )
            for i, layer in enumerate(self.layers):
                u = layer(params["phase"][f"layer_{i}"], u, rngs[i])
                if self.skip_from is not None and i == self.skip_from:
                    skip_u = u
            u = self.final.propagate(u)
        else:
            phis = jnp.stack(
                [params["phase"][f"layer_{i}"]
                 for i in range(len(self.layers))]
            )
            rngs = (
                jax.random.split(rng, len(self.layers)) if rng is not None
                else None
            )
            if self.skip_from is None:
                u = self.plan.forward(phis, u, rngs)
            else:
                u = self.plan.forward(phis, u, rngs,
                                      stop=self.skip_from + 1)
                skip_u = u
                u = self.plan.forward(phis, u, rngs,
                                      start=self.skip_from + 1)
            u = self.plan.propagate_final(u)
        if skip_u is not None:
            u = (u + self.skip_hop.propagate(skip_u)) / jnp.sqrt(2.0).astype(
                jnp.complex64
            )
        inten = df.intensity(u)
        if train and self.cfg.layer_norm:
            mean = jnp.mean(inten, axis=(-2, -1), keepdims=True)
            var = jnp.var(inten, axis=(-2, -1), keepdims=True)
            inten = (inten - mean) * jax.lax.rsqrt(var + 1e-6)
        return inten


def build_model(cfg: DONNConfig, laser: Optional[Laser] = None):
    """Factory used by the DSL and configs."""
    if cfg.segmentation:
        return SegmentationDONN(cfg, laser)
    if cfg.channels > 1:
        return MultiChannelDONN(cfg, laser)
    return DONN(cfg, laser)
