"""DONN model containers (LightRidge `lr.models`).

- ``DONN``: sequential stack of diffractive layers + detector (classification).
- ``MultiChannelDONN``: the paper's RGB architecture (Fig. 12) — parallel
  optical channels whose output intensities merge on one detector.
- ``SegmentationDONN``: the paper's image-segmentation architecture (Fig. 13)
  with *optical skip connection* (complex-field beam-splitter sum) and
  train-time layer normalization.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import codesign as cd
from repro.core import diffraction as df
from repro.core import propagation as pp
from repro.core.cache import lru_get, lru_put
from repro.core.config import DONNConfig
from repro.core.laser import Laser, data_to_cplex
from repro.core.layers import Detector, DiffractiveLayer
from repro.core.propagation import plan_from_config
from repro.nn import ParamSpec, init_params


def channel_readout(u: jax.Array, masks, use_pallas: bool) -> jax.Array:
    """Multi-channel detector accumulation, shared by every path.

    (..., C, n, n) per-channel output fields -> (..., num_classes): the
    incoherent channel sum pooled over the per-class detector regions,
    through the fused Pallas kernel under ``use_pallas`` or a single jnp
    contraction otherwise.  One definition serves training
    (``MultiChannelDONN.apply``, both engines), batched DSE emulation
    (``emulate_batch``) and the deployment engine
    (``repro.runtime.inference``), so the fallback contraction and kernel
    routing cannot drift between them.
    """
    masks = jnp.asarray(masks)
    if use_pallas:
        from repro.kernels import ops as kops

        return kops.channel_intensity_readout(u.real, u.imag, masks)
    return jnp.einsum("...dhw,chw->...c", df.intensity(u), masks)


def _build_layers(cfg: DONNConfig, gamma: float):
    """Eager per-layer stack from the (possibly heterogeneous) config.

    Each layer owns its *own* grid / approximation / codesign device
    (resolved from ``cfg.layers`` or the uniform scalars); the final
    free-space hop to the detector runs on the last layer's grid.
    """
    specs = cfg.resolved_layers()
    layers = []
    for s in specs:
        layers.append(
            DiffractiveLayer(
                df.Grid(s.size, s.pixel_size),
                s.distance,
                cfg.wavelength,
                method=s.approximation,
                band_limit=cfg.band_limit,
                pad=cfg.pad,
                device=cd.device_for_layer(s.codesign, s.device_levels,
                                           s.response_gamma),
                codesign_mode=s.codesign,
                gamma=gamma,
                use_pallas=cfg.use_pallas,
            )
        )
    # final free-space hop: last layer -> detector plane (no modulation)
    final = DiffractiveLayer(
        layers[-1].grid,
        cfg.gap_distances()[-1],
        cfg.wavelength,
        method=specs[-1].approximation,
        band_limit=cfg.band_limit,
        pad=cfg.pad,
        gamma=1.0,
        use_pallas=cfg.use_pallas,
    )
    return layers, final


class DONN:
    """Sequential DONN classifier."""

    def __init__(self, cfg: DONNConfig, laser: Optional[Laser] = None):
        if cfg.channels != 1:
            raise ValueError("use MultiChannelDONN for channels > 1")
        self.cfg = cfg
        self.grid = df.Grid(cfg.n, cfg.pixel_size)  # detector/system grid
        self.laser = laser or Laser(wavelength=cfg.wavelength)
        self.gamma = 1.0 if cfg.gamma is None else float(cfg.gamma)
        self.layers, self.final = _build_layers(cfg, self.gamma)
        self.in_grid = self.layers[0].grid  # source plane (first layer size)
        self._plan = None  # built on first scan-path use
        self.detector = Detector(
            self.grid,
            cfg.num_classes,
            cfg.det_size,
            cfg.detector_layout,
            use_pallas=cfg.use_pallas,
        )
        self.source = self.laser.field(self.in_grid)  # (n, n) complex64 const

    @property
    def plan(self):
        if self._plan is None:
            self._plan = plan_from_config(self.cfg, self.gamma)
        return self._plan

    # --- params ---
    def param_specs(self):
        return {
            "phase": {
                f"layer_{i}": layer.param_spec()
                for i, layer in enumerate(self.layers)
            }
        }

    def init(self, key: jax.Array):
        return init_params(self.param_specs(), key)

    # --- forward ---
    def encode(self, x: jax.Array) -> jax.Array:
        u = data_to_cplex(x, self.in_grid.n)
        return u * jnp.asarray(self.source)

    def fields(self, params, x, rng: Optional[jax.Array] = None):
        """All intermediate fields (lr.model.prop_view)."""
        u = self.encode(x)
        out = [u]
        rngs = (
            jax.random.split(rng, len(self.layers)) if rng is not None else
            [None] * len(self.layers)
        )
        cur = self.in_grid
        for i, layer in enumerate(self.layers):
            u = df.resample_field(u, cur, layer.grid)  # no-op on equal grids
            u = layer(params["phase"][f"layer_{i}"], u, rngs[i])
            cur = layer.grid
            out.append(u)
        u = self.final.propagate(u)
        u = df.resample_field(u, self.final.grid, self.grid)
        out.append(u)
        return out

    def stacked_phases(self, params):
        """Phase stack in the plan's layout: one (L, N, N) array for
        uniform stacks, a per-segment pytree for heterogeneous ones."""
        return self.plan.stack_phases(
            params["phase"][f"layer_{i}"] for i in range(len(self.layers))
        )

    def apply(self, params, x, rng: Optional[jax.Array] = None) -> jax.Array:
        """Images (..., h, w) -> per-class detector intensities (..., C)."""
        if self.cfg.engine == "eager":
            u = self.fields(params, x, rng)[-1]
        else:
            u = self.plan.apply(self.stacked_phases(params), self.encode(x),
                                rng)
        return self.detector(u)

    def prop_view(self, params, x, rng=None):
        return [df.intensity(u) for u in self.fields(params, x, rng)]


class MultiChannelDONN:
    """Multi-channel (RGB) DONN (paper Fig. 12).

    ``channels`` parallel optical stacks; each encodes one input channel; all
    output beams project onto a single shared detector where intensities add.
    """

    def __init__(self, cfg: DONNConfig, laser: Optional[Laser] = None):
        self.cfg = cfg
        sub = DONNConfig(**{**cfg.__dict__, "channels": 1})
        self.channel_model = DONN(sub, laser)

    def param_specs(self):
        spec = self.channel_model.param_specs()["phase"]
        c = self.cfg.channels
        return {
            "phase": {
                name: ParamSpec(
                    (c,) + s.shape,
                    s.dtype,
                    ("channel",) + s.logical_axes,
                    init=s.init,
                )
                for name, s in spec.items()
            }
        }

    def init(self, key):
        return init_params(self.param_specs(), key)

    def apply(self, params, x, rng: Optional[jax.Array] = None) -> jax.Array:
        """x: (..., C, h, w) multi-channel images -> (..., num_classes)."""
        cm = self.channel_model
        if self.cfg.engine == "eager":
            def one_channel(phases, xc):
                p = {"phase": phases}
                u = cm.fields(p, xc, rng)[-1]
                return u

            # vmap over the channel axis of both params and inputs
            u = jax.vmap(one_channel, in_axes=(0, -3), out_axes=-3)(
                params["phase"], x
            )  # (..., C, n, n) per-channel output fields
            return channel_readout(u, cm.detector.masks, self.cfg.use_pallas)
        # batched plan path: all channels propagate as one (..., C, N, N)
        # tensor through shared kernels (the TFs are channel-independent;
        # the (L, C, N, N) phase stack rides the scan — per segment for
        # heterogeneous stacks).
        phis = cm.plan.stack_phases(
            params["phase"][f"layer_{i}"] for i in range(len(cm.layers))
        )
        u = data_to_cplex(x, cm.in_grid.n) * jnp.asarray(cm.source)
        u = cm.plan.apply(phis, u, rng)
        return channel_readout(u, cm.detector.masks, self.cfg.use_pallas)


class SegmentationDONN:
    """All-optical image segmentation DONN (paper Fig. 13a).

    Optical skip connection: the field exiting layer ``skip_from`` is split
    off, propagated directly to the detector plane, and coherently recombined
    (beam-splitter sum, 1/sqrt(2) each) with the main path.  LayerNorm on the
    output intensity is applied only during training.
    """

    def __init__(self, cfg: DONNConfig, laser: Optional[Laser] = None):
        self.cfg = cfg
        self.grid = df.Grid(cfg.n, cfg.pixel_size)  # detector/system grid
        self.laser = laser or Laser(wavelength=cfg.wavelength)
        self.gamma = 1.0 if cfg.gamma is None else float(cfg.gamma)
        self.layers, self.final = _build_layers(cfg, self.gamma)
        self.in_grid = self.layers[0].grid
        self._plan = None  # built on first scan-path use
        self.skip_from = cfg.skip_from
        if self.skip_from is not None:
            # skip hop covers the remaining distance to the detector plane,
            # computed on the skip plane's own grid
            gaps = cfg.gap_distances()
            z_skip = float(sum(gaps[self.skip_from + 1 :]))
            skip_grid = self.layers[self.skip_from].grid
            self.skip_hop = DiffractiveLayer(
                skip_grid,
                z_skip,
                cfg.wavelength,
                method=cfg.resolved_layers()[self.skip_from].approximation,
                band_limit=cfg.band_limit,
                pad=cfg.pad,
            )
        self.source = self.laser.field(self.in_grid)

    @property
    def plan(self):
        if self._plan is None:
            self._plan = plan_from_config(self.cfg, self.gamma)
        return self._plan

    def param_specs(self):
        return {
            "phase": {
                f"layer_{i}": layer.param_spec()
                for i, layer in enumerate(self.layers)
            }
        }

    def init(self, key):
        return init_params(self.param_specs(), key)

    def apply(
        self, params, x, rng: Optional[jax.Array] = None, train: bool = False
    ) -> jax.Array:
        """Images (..., h, w) -> per-pixel intensity map (..., n, n)."""
        u = data_to_cplex(x, self.in_grid.n) * jnp.asarray(self.source)
        skip_u = None
        if self.cfg.engine == "eager":
            rngs = (
                jax.random.split(rng, len(self.layers)) if rng is not None
                else [None] * len(self.layers)
            )
            cur = self.in_grid
            for i, layer in enumerate(self.layers):
                u = df.resample_field(u, cur, layer.grid)
                u = layer(params["phase"][f"layer_{i}"], u, rngs[i])
                cur = layer.grid
                if self.skip_from is not None and i == self.skip_from:
                    skip_u = u
            u = self.final.propagate(u)
            u = df.resample_field(u, self.final.grid, self.grid)
        else:
            phis = self.plan.stack_phases(
                params["phase"][f"layer_{i}"]
                for i in range(len(self.layers))
            )
            rngs = (
                jax.random.split(rng, len(self.layers)) if rng is not None
                else None
            )
            if self.skip_from is None:
                u = self.plan.forward(phis, u, rngs)
            else:
                u = self.plan.forward(phis, u, rngs,
                                      stop=self.skip_from + 1)
                skip_u = u
                u = self.plan.forward(phis, u, rngs,
                                      start=self.skip_from + 1)
            u = self.plan.propagate_final(u)
        if skip_u is not None:
            # beam-splitter recombination on the detector grid
            sk = self.skip_hop.propagate(skip_u)
            sk = df.resample_field(sk, self.skip_hop.grid, self.grid)
            u = (u + sk) / jnp.sqrt(2.0).astype(jnp.complex64)
        inten = df.intensity(u)
        if train and self.cfg.layer_norm:
            mean = jnp.mean(inten, axis=(-2, -1), keepdims=True)
            var = jnp.var(inten, axis=(-2, -1), keepdims=True)
            inten = (inten - mean) * jax.lax.rsqrt(var + 1e-6)
        return inten


def build_model(cfg: DONNConfig, laser: Optional[Laser] = None):
    """Factory used by the DSL and configs."""
    if cfg.segmentation:
        return SegmentationDONN(cfg, laser)
    if cfg.channels > 1:
        return MultiChannelDONN(cfg, laser)
    return DONN(cfg, laser)


# --------------------------------------------------------------------------
# Compile-once emulation runtime
# --------------------------------------------------------------------------
_MODEL_CACHE: dict = {}
_MODEL_CACHE_MAX = 64
_MODEL_STATS = {"hits": 0, "misses": 0}

# geometry knobs free to vary across one emulate_batch candidate set; every
# other config field is an architecture static shared by the batch.  depth
# rides along via depth-padded + masked candidate stacks.
_GEOMETRY_FIELDS = ("name", "wavelength", "pixel_size", "distance",
                    "distances", "depth")


def config_static_key(cfg: DONNConfig) -> tuple:
    """Hashable config key (canonicalized, drops the cosmetic name).

    ``name`` never reaches the compiled program, so configs identical up
    to it share models and executables — a DSE sweep naming its candidates
    uniquely still compiles once per geometry.  The key is built on the
    *canonical* config (``DONNConfig.canonical``): uniform architectures
    spelled via ``layers`` collapse onto the scalar spelling, ``distance``
    / ``distances`` normalize through ``gap_distances()``, and surviving
    heterogeneous ``layers`` flatten to hashable per-layer tuples.
    """
    cfg = cfg.canonical()
    d = dataclasses.asdict(cfg)
    d.pop("name")
    d["distances"] = cfg.gap_distances()
    d["distance"] = 0.0  # folded into the normalized distances
    if d["layers"] is not None:
        d["layers"] = tuple(
            tuple(sorted(l.items())) for l in d["layers"]
        )
    return tuple(sorted(d.items()))


def _shared_statics_key(cfg: DONNConfig) -> tuple:
    d = dict(config_static_key(cfg))
    for f in _GEOMETRY_FIELDS:
        d.pop(f, None)
    return tuple(sorted(d.items()))


def clear_emulation_caches() -> None:
    """Clear the model + batched-input memos and the plan/exec caches."""
    _MODEL_CACHE.clear()
    _MODEL_STATS.update(hits=0, misses=0)
    _BATCH_INPUT_CACHE.clear()
    _BATCH_INPUT_STATS.update(hits=0, misses=0)
    pp.clear_plan_cache()


def model_cache_key(model) -> Optional[tuple]:
    """Executable-cache identity of a model, or None when not keyable.

    A model may share cached (training) executables iff its numerics are a
    pure function of its config: it exposes ``cfg`` and was built with the
    default laser (``Laser`` is a frozen dataclass, so default-equivalent
    explicit lasers compare equal).  Custom-profile models return None and
    fall back to per-closure jit.  Used by the train-step factories in
    ``repro.core.train_utils``.
    """
    cfg = getattr(model, "cfg", None)
    if cfg is None:
        return None
    inner = getattr(model, "channel_model", model)  # MultiChannelDONN
    if getattr(inner, "laser", None) != Laser(wavelength=cfg.wavelength):
        return None
    return config_static_key(cfg)


def cached_model(cfg: DONNConfig, laser: Optional[Laser] = None):
    """Memoized ``build_model`` (default laser only).

    DSE sweeps, retraced train-step factories and repeated benchmarks reuse
    one layer stack + detector per config instead of rebuilding them.
    Models are stateless w.r.t. params, so sharing is safe.
    """
    if laser is not None:
        return build_model(cfg, laser)
    key = config_static_key(cfg)
    model = lru_get(_MODEL_CACHE, key, _MODEL_STATS)
    if model is None:
        model = build_model(cfg)
        lru_put(_MODEL_CACHE, key, model, _MODEL_CACHE_MAX)
    return model


def cached_apply(cfg: DONNConfig):
    """Compile-once ``model.apply``: f(params, x, rng=None).

    Backed by the process-wide executable cache — keyed by config statics
    plus input shapes/dtypes — so repeated emulations of one geometry pay
    trace+compile exactly once per shape, however many times the model is
    (re)built around it.
    """
    model = cached_model(cfg)
    skey = ("donn_apply", config_static_key(cfg))

    def run(params, x, rng=None):
        x = jnp.asarray(x)
        if rng is None:
            ex = pp.cached_executable(
                skey + ("norng",), lambda p, xx: model.apply(p, xx),
                params, x,
            )
            return ex(params, x)
        ex = pp.cached_executable(
            skey + ("rng",), lambda p, xx, r: model.apply(p, xx, r),
            params, x, rng,
        )
        return ex(params, x, rng)

    return run


def _stack_phases(params, depth: int, pad_to: Optional[int] = None) -> jax.Array:
    """(L, ...) phase stack; zero-padded along L to ``pad_to`` if given."""
    phis = jnp.stack(
        [params["phase"][f"layer_{i}"] for i in range(depth)]
    )
    if pad_to is not None and pad_to > depth:
        phis = jnp.pad(
            phis, [(0, pad_to - depth)] + [(0, 0)] * (phis.ndim - 1)
        )
    return phis


def _pad_planes(planes: np.ndarray, depth: int, pad_to: int) -> np.ndarray:
    """Pad a (depth+1, ...) TF-plane stack to (pad_to+1, ...).

    Rows [0, depth) are the real layer gaps, row ``depth`` the final hop.
    Dummy rows (copies of the final-hop plane — any finite plane works,
    the layer mask makes them identity hops) are inserted *between* the
    layer gaps and the final hop so the shared scan-plus-final program
    reads every candidate's final plane at the same index ``pad_to``.
    """
    if depth == pad_to:
        return planes
    dummy = np.repeat(planes[depth:depth + 1], pad_to - depth, axis=0)
    return np.concatenate([planes[:depth], dummy, planes[depth:]], axis=0)


# candidate-set geometry -> stacked device inputs (TF planes, sources, skip
# planes).  They are deterministic in the geometry tuple, so warm
# emulate_batch calls skip the per-candidate host rebuild + re-upload.
_BATCH_INPUT_CACHE: dict = {}
_BATCH_INPUT_CACHE_MAX = 32
_BATCH_INPUT_STATS = {"hits": 0, "misses": 0}


def _batched_inputs(cfgs, base, gamma: float, template, has_skip: bool):
    """Stacked (K, ...) transfer planes, sources and skip planes (memoized).

    Candidates of unequal depth are padded to the deepest one
    (``template.depth``): dummy gap planes fill the tail of each TF stack
    (masked to identity hops by the caller's layer mask) and every
    candidate's final hop lands at the shared index ``template.depth``.
    """
    key = ("emulate_inputs",
           tuple(pp.plan_cache_key(c, gamma) for c in cfgs),
           base.skip_from if has_skip else None)
    hit = lru_get(_BATCH_INPUT_CACHE, key, _BATCH_INPUT_STATS)
    if hit is not None:
        return hit
    plans = [pp.plan_from_config(c, gamma) for c in cfgs]
    k0, k1 = template._plane_keys
    L = template.depth
    tf_a = jnp.asarray(
        np.stack([_pad_planes(p._np[k0], p.depth, L) for p in plans])
    )
    tf_b = jnp.asarray(
        np.stack([_pad_planes(p._np[k1], p.depth, L) for p in plans])
    )
    if base.tf_dtype != "float32":
        tf_a = tf_a.astype(base.tf_dtype)
        tf_b = tf_b.astype(base.tf_dtype)
    sources = jnp.asarray(np.stack([
        Laser(wavelength=c.wavelength).field(df.Grid(c.n, c.pixel_size))
        for c in cfgs
    ]))
    skip_pair = None
    if has_skip:
        # skip hop covers the remaining distance to the detector plane,
        # per candidate geometry
        def _skip_planes(c):
            gaps = c.gap_distances()
            z = float(sum(gaps[base.skip_from + 1:]))
            return pp.transfer_planes(
                df.Grid(c.n, c.pixel_size), z, c.wavelength,
                method=base.approximation, band_limit=base.band_limit,
                pad=template.pad,
            )
        sk = [_skip_planes(c) for c in cfgs]
        skip_pair = (jnp.asarray(np.stack([p[k0] for p in sk])),
                     jnp.asarray(np.stack([p[k1] for p in sk])))
    entry = (tf_a, tf_b, sources, skip_pair)
    lru_put(_BATCH_INPUT_CACHE, key, entry, _BATCH_INPUT_CACHE_MAX)
    return entry


def emulate_batch(cfgs: Sequence[DONNConfig], params, x, rng=None,
                  train: bool = False) -> jax.Array:
    """Emulate K candidate DONN configs in one compiled, vmapped forward.

    The DSE verification primitive: all cfgs must share architecture
    statics (n, channels, detector geometry, engine flags), while
    per-candidate *geometry* — wavelength, pixel_size, distance(s), and
    **depth** — is free.  Per-candidate transfer planes and source fields
    enter the compiled program as traced inputs (not baked constants), so
    every candidate set with the same statics and shapes reuses one cached
    executable: K emulations cost one trace+compile plus one device call,
    instead of K sequential ``build_model`` + ``jit(apply)`` cycles.

    Ragged-depth candidate sets are depth-padded to the deepest candidate
    and masked: padded layers are identity hops inside the shared scan, so
    a 2-layer and a 5-layer architecture score in the *same* device call
    (per-candidate params required; with rng-driven codesign the per-layer
    key split uses the padded depth, so stochastic modes are deterministic
    but not bitwise-aligned with a sequential per-depth emulation).

    params: one pytree shared by every candidate, or a sequence of K
    pytrees (required when depths differ).  x: one shared input batch.
    rng: one key, split across candidates (candidate i sees
    ``jax.random.split(rng, K)[i]``).

    Returns the stacked (K, ...) outputs of ``build_model(cfg).apply`` per
    candidate: per-class intensities for classifiers, intensity maps for
    segmentation (``train=True`` applies the train-time layer norm).
    """
    cfgs = [c.canonical() for c in cfgs]
    if not cfgs:
        raise ValueError("emulate_batch needs at least one candidate")
    for c in cfgs:
        if c.layers is not None:
            raise ValueError(
                "emulate_batch candidates must be per-candidate-uniform "
                f"stacks; {c.name!r} has heterogeneous per-layer specs "
                "(cfg.layers), which cannot share one vmapped scan yet"
            )
    base = cfgs[0]
    skey = _shared_statics_key(base)
    for c in cfgs[1:]:
        if _shared_statics_key(c) != skey:
            raise ValueError(
                "emulate_batch candidates must share all non-geometry "
                "statics (n, channels, detector, engine flags); "
                f"{c.name!r} differs from {base.name!r}"
            )
    K = len(cfgs)
    n = base.n
    gamma = 1.0 if base.gamma is None else float(base.gamma)
    depths = [c.depth for c in cfgs]
    mixed_depth = len(set(depths)) > 1
    # the template plan supplies the shared scan program; its depth is the
    # padded depth every candidate rides (shallower ones mask their tail)
    template = pp.plan_from_config(cfgs[int(np.argmax(depths))], gamma)
    has_skip = base.segmentation and base.skip_from is not None
    if has_skip and base.skip_from >= min(depths):
        raise ValueError(
            f"skip_from={base.skip_from} must precede the shallowest "
            f"candidate (min depth {min(depths)})"
        )
    tf_a, tf_b, sources, skip_pair = _batched_inputs(
        cfgs, base, gamma, template, has_skip
    )
    if isinstance(params, (list, tuple)):
        if len(params) != K:
            raise ValueError(f"got {len(params)} params for {K} candidates")
        phis = jnp.stack([
            _stack_phases(p, c.depth, pad_to=template.depth)
            for p, c in zip(params, cfgs)
        ])
    else:
        if mixed_depth:
            raise ValueError(
                "mixed-depth candidate sets need per-candidate params "
                "(one pytree per depth); got a single shared pytree"
            )
        one = _stack_phases(params, base.depth)
        phis = jnp.broadcast_to(one[None], (K,) + one.shape)
    x = jnp.asarray(x)

    family = ("seg" if base.segmentation
              else "multi" if base.channels > 1 else "cls")
    use_rng = rng is not None
    if family == "cls":
        det = cached_model(base).detector
    elif family == "multi":
        det = cached_model(base).channel_model.detector
    else:
        det = None

    # one dict pytree in, so jit/vmap handle the optional inputs natively
    # (no positional-argument protocol to keep in sync)
    inputs = {"tf_a": tf_a, "tf_b": tf_b, "src": sources, "phis": phis,
              "x": x}
    if use_rng:
        inputs["rngs"] = jax.random.split(rng, K)
    if has_skip:
        inputs["skip_a"], inputs["skip_b"] = skip_pair
    if mixed_depth:
        # (K, L_max) layer-validity mask: padded tail layers become
        # identity hops inside the shared scan
        inputs["mask"] = jnp.asarray(
            np.arange(template.depth)[None, :] < np.asarray(depths)[:, None]
        )

    def fn(inp):
        u0 = data_to_cplex(inp["x"], n)  # shared encoded input batch

        def candidate(a, b, src, p, r=None, sa=None, sb=None, m=None):
            u = u0 * src
            tfs = (a, b)
            if family == "seg":
                rngs_l = (jax.random.split(r, template.depth)
                          if r is not None else None)
                if has_skip:
                    u = template.forward(p, u, rngs_l,
                                         stop=base.skip_from + 1, tfs=tfs,
                                         mask=m)
                    skip_u = u
                    u = template.forward(p, u, rngs_l,
                                         start=base.skip_from + 1, tfs=tfs,
                                         mask=m)
                    u = template.propagate_final(u, tfs=tfs)
                    u = (u + template._hop(skip_u, (sa, sb))) / jnp.sqrt(
                        2.0
                    ).astype(jnp.complex64)
                else:
                    u = template.forward(p, u, rngs_l, tfs=tfs, mask=m)
                    u = template.propagate_final(u, tfs=tfs)
                inten = df.intensity(u)
                if train and base.layer_norm:
                    mean = jnp.mean(inten, axis=(-2, -1), keepdims=True)
                    var = jnp.var(inten, axis=(-2, -1), keepdims=True)
                    inten = (inten - mean) * jax.lax.rsqrt(var + 1e-6)
                return inten
            u = template.apply(p, u, r, tfs=tfs, mask=m)
            if family == "multi":
                return channel_readout(u, det.masks, base.use_pallas)
            return det(u)

        per_cand = {k: v for k, v in inp.items() if k != "x"}

        def one(c):
            return candidate(c["tf_a"], c["tf_b"], c["src"], c["phis"],
                             c.get("rngs"), c.get("skip_a"), c.get("skip_b"),
                             c.get("mask"))

        return jax.vmap(one)(per_cand)

    static_key = ("emulate_batch", family, skey, use_rng, bool(train),
                  mixed_depth)
    ex = pp.cached_executable(static_key, fn, inputs)
    return ex(inputs)
