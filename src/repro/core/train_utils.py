"""DONN training utilities (LightRidge `lr.train.utils`).

Loss per the paper (§2.1): L = || softmax(I) - onehot(t) ||_2^2 over the
per-class detector intensities I.  Also: accuracy, detector-noise injection
(Fig. 7 confidence study), and the training drivers used by the examples
and benchmarks:

- ``make_train_step``: the classic one-batch step (params, opt_state,
  step, xb, yb, rng) -> (params, opt_state, loss, acc) — routed through
  the process-wide executable cache when the model/optimizer are
  cache-keyable, so rebuilding a model around the same config stops
  re-tracing an identical training program.
- ``make_train_chunk``: the throughput driver — one jit runs
  ``steps_per_call`` optimizer steps as a ``lax.scan`` over a stacked
  batch chunk with (params, opt_state) *donated*, losses/metrics
  accumulated on device, and exactly one host sync per chunk.
- ``train_classifier(steps_per_call=...)``: epoch loop on top, fed by the
  double-buffered device prefetcher (``repro.data.pipeline``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamW


def mse_softmax_loss(logits: jax.Array, labels: jax.Array, num_classes: int):
    """Paper loss: MSE between softmax(detector intensities) and one-hot."""
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=probs.dtype)
    return jnp.mean(jnp.sum((probs - onehot) ** 2, axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def add_detector_noise(
    logits_or_intensity: jax.Array, rng: jax.Array, frac: float
) -> jax.Array:
    """Uniform intensity noise bounded by ``frac`` of the max (Fig. 7)."""
    scale = frac * jnp.max(logits_or_intensity, axis=-1, keepdims=True)
    noise = jax.random.uniform(
        rng, logits_or_intensity.shape, logits_or_intensity.dtype, 0.0, 1.0
    )
    return logits_or_intensity + scale * noise


def bce_segmentation_loss(intensity: jax.Array, mask: jax.Array):
    """Per-pixel BCE on normalized intensity (segmentation DONN)."""
    logits = intensity  # already layer-normed in train mode
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * mask + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def iou(intensity: jax.Array, mask: jax.Array, thresh: float = 0.0):
    pred = (intensity > thresh).astype(jnp.float32)
    inter = jnp.sum(pred * mask, axis=(-2, -1))
    union = jnp.sum(jnp.maximum(pred, mask), axis=(-2, -1))
    return jnp.mean(inter / jnp.maximum(union, 1.0))


@dataclasses.dataclass
class TrainResult:
    params: Any
    losses: list
    accs: list
    wall_time_s: float
    skipped_steps: int = 0  # guarded steps dropped for non-finite loss/grads
    rollbacks: int = 0      # checkpoint restores triggered by the guard


def optimizer_cache_key(optimizer) -> Optional[tuple]:
    """Hashable identity of an optimizer, or None when not cache-keyable.

    Frozen optimizer dataclasses whose fields are all plain primitives (or
    dtypes) key the executable cache; schedules and other callables fall
    back to per-closure jit (their identity is not value-comparable).
    """
    if not dataclasses.is_dataclass(optimizer):
        return None
    vals = []
    for f in dataclasses.fields(optimizer):
        v = getattr(optimizer, f.name)
        if not isinstance(v, (int, float, str, bool, type(None), type)):
            return None
        vals.append((f.name, v))
    return (type(optimizer).__name__, tuple(vals))


def _train_static_key(tag: str, model, optimizer, *extras) -> Optional[tuple]:
    from repro.core.models import model_cache_key

    mkey = model_cache_key(model)
    okey = optimizer_cache_key(optimizer)
    if mkey is None or okey is None:
        return None
    return (tag, mkey, okey) + tuple(extras)


def make_train_step(model, optimizer, num_classes: int, needs_rng: bool = False):
    """jit'd (params, opt_state, step, batch[, rng]) -> (params, opt, loss, acc).

    Routed through ``repro.core.propagation.cached_executable`` (keyed by
    the model's config statics + optimizer values + input avals) whenever
    the model/optimizer are cache-keyable, so examples and benchmarks that
    rebuild identical models stop re-tracing the same training program.
    """

    def loss_fn(params, xb, yb, rng):
        logits = model.apply(params, xb, rng) if needs_rng else model.apply(
            params, xb
        )
        return mse_softmax_loss(logits, yb, num_classes), logits

    def step_impl(params, opt_state, step, xb, yb, rng):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, xb, yb, rng
        )
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss, accuracy(logits, yb)

    skey = _train_static_key("donn_train_step", model, optimizer,
                             num_classes, needs_rng)
    if skey is None:
        return jax.jit(step_impl)
    from repro.core import propagation as pp

    def step_fn(params, opt_state, step, xb, yb, rng):
        args = (params, opt_state, jnp.asarray(step), jnp.asarray(xb),
                jnp.asarray(yb), rng)
        return pp.cached_executable(skey, step_impl, *args)(*args)

    return step_fn


def make_train_chunk(model, optimizer, num_classes: int,
                     needs_rng: bool = False, donate: bool = True,
                     guard: bool = False):
    """Donated multi-step scanned training driver (the throughput engine).

    Returns ``chunk_fn(params, opt_state, step0, xs, ys, rng) -> (params,
    opt_state, rng, losses, accs)`` running one optimizer step per leading
    ``xs``/``ys`` row as a single ``lax.scan`` inside one jit:

    - (params, opt_state) are **donated** — step k+1 updates step k's
      buffers in place instead of re-allocating the whole state;
    - per-step losses/accuracies accumulate on device and come back as
      (S,) arrays — one host sync per chunk instead of per step;
    - the rng chain matches the per-step loop exactly (``rng, sub =
      split(rng)`` before each step), so chunked training is numerically
      identical to ``make_train_step`` iterated S times.

    ``guard=True`` adds **device-side non-finite detection** to every
    step: when the loss or any gradient leaf is non-finite, the update is
    dropped wholesale (params, opt_state and the bias-correction step
    counter stay at their pre-step values — a skipped step is a no-op)
    and the step is flagged.  The chunk then returns two extra metrics,
    ``(..., losses, accs, skipped, params_ok)`` with ``skipped`` an (S,)
    bool array and ``params_ok`` a scalar "all params finite" flag — both
    accumulate on device and ride the existing one-sync-per-chunk
    metrics, adding **zero** host syncs to the hot loop.

    Like ``make_train_step`` it rides the process-wide executable cache
    when the model/optimizer are cache-keyable.
    """

    def loss_fn(params, xb, yb, rng):
        logits = model.apply(params, xb, rng) if needs_rng else model.apply(
            params, xb
        )
        return mse_softmax_loss(logits, yb, num_classes), logits

    def chunk_impl(params, opt_state, step0, xs, ys, rng):
        def body(carry, batch):
            params, opt_state, step, rng = carry
            xb, yb = batch
            rng, sub = jax.random.split(rng)
            (loss, logits), grads = jax.value_and_grad(
                loss_fn, has_aux=True
            )(params, xb, yb, sub)
            if not guard:
                params, opt_state = optimizer.update(
                    grads, opt_state, params, step
                )
                return ((params, opt_state, step + 1, rng),
                        (loss, accuracy(logits, yb)))
            ok = jnp.isfinite(loss)
            for g in jax.tree.leaves(grads):
                ok &= jnp.all(jnp.isfinite(g))
            new_params, new_opt = optimizer.update(grads, opt_state, params,
                                                   step)
            keep = lambda new, old: jax.tree.map(
                lambda a, b: jnp.where(ok, a, b), new, old
            )
            # a skipped step is a full no-op: state, optimizer moments AND
            # the bias-correction step counter all stay pre-step
            return ((keep(new_params, params), keep(new_opt, opt_state),
                     jnp.where(ok, step + 1, step), rng),
                    (loss, accuracy(logits, yb), ~ok))

        carry = (params, opt_state, jnp.asarray(step0, jnp.int32), rng)
        (params, opt_state, _, rng), metrics = jax.lax.scan(
            body, carry, (xs, ys)
        )
        if not guard:
            losses, accs = metrics
            return params, opt_state, rng, losses, accs
        losses, accs, skipped = metrics
        params_ok = jnp.array(True)
        for p in jax.tree.leaves(params):
            params_ok &= jnp.all(jnp.isfinite(p))
        return params, opt_state, rng, losses, accs, skipped, params_ok

    donate_n = (0, 1) if donate else ()
    skey = _train_static_key("donn_train_chunk", model, optimizer,
                             num_classes, needs_rng, donate, guard)
    if skey is None:
        return jax.jit(chunk_impl, donate_argnums=donate_n)
    from repro.core import propagation as pp

    def chunk_fn(params, opt_state, step0, xs, ys, rng):
        args = (params, opt_state, jnp.asarray(step0), jnp.asarray(xs),
                jnp.asarray(ys), rng)
        ex = pp.cached_executable(skey, chunk_impl, *args,
                                  donate_argnums=donate_n)
        return ex(*args)

    return chunk_fn


def train_classifier(
    model,
    params,
    data_iter,
    steps: int,
    lr: float = 0.1,
    num_classes: int = 10,
    needs_rng: bool = False,
    rng: Optional[jax.Array] = None,
    log_every: int = 0,
    steps_per_call: int = 1,
    prefetch: int = 2,
    guard: bool = False,
    ckpt_dir=None,
    ckpt_every: int = 0,
    max_rollbacks: int = 2,
) -> TrainResult:
    """Compact Adam training loop for DONN classifiers (paper uses Adam+MSE).

    ``steps_per_call > 1`` switches to the chunked throughput driver
    (``make_train_chunk``): batches stack into device-resident chunks fed
    through the double-buffered device prefetcher, each chunk runs
    ``steps_per_call`` donated optimizer steps inside one compiled scan,
    and the host syncs once per chunk.  Numerics (losses, rng chain, final
    params) are identical to the per-step path.  ``prefetch`` bounds the
    prefetcher's in-flight chunk count (0 disables it).

    ``guard=True`` (chunked path only) turns on the non-finite guardrails:
    poisoned steps (NaN/inf loss or grads) are skipped device-side as
    exact no-ops and counted in ``TrainResult.skipped_steps``.  With
    ``ckpt_dir`` set, (params, opt_state, rng, step) checkpoint through
    ``repro.checkpoint`` every ``ckpt_every`` steps (plus once at step 0),
    and a chunk that comes back fully skipped or with non-finite params
    **rolls back** to the last good checkpoint and resumes — at most
    ``max_rollbacks`` times (counted in ``TrainResult.rollbacks``);
    beyond that a ``RuntimeError`` surfaces the divergence.
    """
    optimizer = AdamW(lr=lr)
    opt_state = optimizer.init(params)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    losses, accs = [], []
    t0 = time.perf_counter()
    if guard and steps_per_call <= 1:
        raise ValueError("guard=True requires the chunked driver "
                         "(steps_per_call > 1)")
    if steps_per_call <= 1:
        step_fn = make_train_step(model, optimizer, num_classes, needs_rng)
        for i in range(steps):
            xb, yb = next(data_iter)
            rng, sub = jax.random.split(rng)
            params, opt_state, loss, acc = step_fn(
                params, opt_state, jnp.asarray(i), xb, yb, sub
            )
            losses.append(float(loss))
            accs.append(float(acc))
            if log_every and (i % log_every == 0):
                print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                      f"acc {accs[-1]:.3f}")
        return TrainResult(params, losses, accs, time.perf_counter() - t0)

    from repro.data.pipeline import device_prefetch, stack_batches

    # the chunk driver donates its state buffers; copy the caller's params
    # once so their reference stays valid after training
    params = jax.tree.map(jnp.array, params)
    opt_state = jax.tree.map(jnp.array, opt_state)
    chunk_fn = make_train_chunk(model, optimizer, num_classes, needs_rng,
                                guard=guard)
    chunks = stack_batches(data_iter, steps_per_call, total=steps)
    if prefetch:
        chunks = device_prefetch(chunks, size=prefetch)

    skipped_total, rollbacks = 0, 0
    last_good: Optional[int] = None
    # i indexes the data stream / metric lists; opt_step is the optimizer's
    # bias-correction counter — they diverge when guarded steps are skipped
    # (a skipped step consumes a batch but must not advance the optimizer)
    i, opt_step = 0, 0
    if ckpt_dir is not None:
        from repro import checkpoint as ckpt

        def _ckpt_state():
            return {"params": params, "opt": opt_state, "rng": rng,
                    "opt_step": jnp.asarray(opt_step, jnp.int32)}

        # a rollback target must exist before the first chunk can fail
        ckpt.save(ckpt_dir, 0, _ckpt_state(), keep=3)
        last_good = 0
    for xs, ys in chunks:
        out = chunk_fn(params, opt_state, opt_step, xs, ys, rng)
        if guard:
            params, opt_state, rng, closs, cacc, skipped, params_ok = out
            skipped = np.asarray(skipped)  # chunk sync (with the metrics)
            bad_chunk = (not bool(params_ok)) or bool(skipped.all())
            if bad_chunk and last_good is not None:
                if rollbacks >= max_rollbacks:
                    raise RuntimeError(
                        f"training diverged at step {i} and the rollback "
                        f"budget ({max_rollbacks}) is exhausted"
                    )
                state = ckpt.restore(ckpt_dir, last_good, _ckpt_state())
                params = jax.tree.map(jnp.array, state["params"])
                opt_state = jax.tree.map(jnp.array, state["opt"])
                rng = jnp.asarray(state["rng"])
                opt_step = int(state["opt_step"])
                del losses[last_good:], accs[last_good:]  # rolled-back steps
                i = last_good
                rollbacks += 1
                continue
            n_skip = int(skipped.sum())
            skipped_total += n_skip
            opt_step += int(xs.shape[0]) - n_skip
        else:
            params, opt_state, rng, closs, cacc = out
            opt_step += int(xs.shape[0])
        closs, cacc = np.asarray(closs), np.asarray(cacc)  # one sync/chunk
        losses.extend(closs.tolist())
        accs.extend(cacc.tolist())
        if log_every:
            # same lines the per-step path prints, emitted at chunk sync
            for j in range(int(xs.shape[0])):
                if (i + j) % log_every == 0:
                    print(f"step {i + j:4d}  loss {closs[j]:.4f}  "
                          f"acc {cacc[j]:.3f}")
        i += int(xs.shape[0])
        if (last_good is not None and ckpt_every
                and i - last_good >= ckpt_every):
            ckpt.save(ckpt_dir, i, _ckpt_state(), keep=3)
            last_good = i
    return TrainResult(params, losses, accs, time.perf_counter() - t0,
                       skipped_steps=skipped_total, rollbacks=rollbacks)


def evaluate_classifier(model, params, data_iter, batches: int,
                        rng: Optional[jax.Array] = None,
                        noise_frac: float = 0.0) -> float:
    apply = jax.jit(lambda p, x: model.apply(p, x))
    correct, total = 0.0, 0
    rng = rng if rng is not None else jax.random.PRNGKey(1)
    for _ in range(batches):
        xb, yb = next(data_iter)
        logits = apply(params, xb)
        if noise_frac > 0.0:
            rng, sub = jax.random.split(rng)
            logits = add_detector_noise(logits, sub, noise_frac)
        correct += float(jnp.sum(jnp.argmax(logits, -1) == yb))
        total += int(yb.shape[0])
    return correct / max(total, 1)
