"""DONN training utilities (LightRidge `lr.train.utils`).

Loss per the paper (§2.1): L = || softmax(I) - onehot(t) ||_2^2 over the
per-class detector intensities I.  Also: accuracy, detector-noise injection
(Fig. 7 confidence study), and a jit'd training loop used by the examples and
benchmarks.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.optim import AdamW


def mse_softmax_loss(logits: jax.Array, labels: jax.Array, num_classes: int):
    """Paper loss: MSE between softmax(detector intensities) and one-hot."""
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=probs.dtype)
    return jnp.mean(jnp.sum((probs - onehot) ** 2, axis=-1))


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))


def add_detector_noise(
    logits_or_intensity: jax.Array, rng: jax.Array, frac: float
) -> jax.Array:
    """Uniform intensity noise bounded by ``frac`` of the max (Fig. 7)."""
    scale = frac * jnp.max(logits_or_intensity, axis=-1, keepdims=True)
    noise = jax.random.uniform(
        rng, logits_or_intensity.shape, logits_or_intensity.dtype, 0.0, 1.0
    )
    return logits_or_intensity + scale * noise


def bce_segmentation_loss(intensity: jax.Array, mask: jax.Array):
    """Per-pixel BCE on normalized intensity (segmentation DONN)."""
    logits = intensity  # already layer-normed in train mode
    return jnp.mean(
        jnp.maximum(logits, 0.0) - logits * mask + jnp.log1p(jnp.exp(-jnp.abs(logits)))
    )


def iou(intensity: jax.Array, mask: jax.Array, thresh: float = 0.0):
    pred = (intensity > thresh).astype(jnp.float32)
    inter = jnp.sum(pred * mask, axis=(-2, -1))
    union = jnp.sum(jnp.maximum(pred, mask), axis=(-2, -1))
    return jnp.mean(inter / jnp.maximum(union, 1.0))


@dataclasses.dataclass
class TrainResult:
    params: Any
    losses: list
    accs: list
    wall_time_s: float


def make_train_step(model, optimizer, num_classes: int, needs_rng: bool = False):
    """jit'd (params, opt_state, step, batch[, rng]) -> (params, opt, loss, acc)."""

    def loss_fn(params, xb, yb, rng):
        logits = model.apply(params, xb, rng) if needs_rng else model.apply(
            params, xb
        )
        return mse_softmax_loss(logits, yb, num_classes), logits

    @jax.jit
    def step_fn(params, opt_state, step, xb, yb, rng):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, xb, yb, rng
        )
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss, accuracy(logits, yb)

    return step_fn


def train_classifier(
    model,
    params,
    data_iter,
    steps: int,
    lr: float = 0.1,
    num_classes: int = 10,
    needs_rng: bool = False,
    rng: Optional[jax.Array] = None,
    log_every: int = 0,
) -> TrainResult:
    """Compact Adam training loop for DONN classifiers (paper uses Adam+MSE)."""
    optimizer = AdamW(lr=lr)
    opt_state = optimizer.init(params)
    step_fn = make_train_step(model, optimizer, num_classes, needs_rng)
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    losses, accs = [], []
    t0 = time.perf_counter()
    for i in range(steps):
        xb, yb = next(data_iter)
        rng, sub = jax.random.split(rng)
        params, opt_state, loss, acc = step_fn(
            params, opt_state, jnp.asarray(i), xb, yb, sub
        )
        losses.append(float(loss))
        accs.append(float(acc))
        if log_every and (i % log_every == 0):
            print(f"step {i:4d}  loss {losses[-1]:.4f}  acc {accs[-1]:.3f}")
    return TrainResult(params, losses, accs, time.perf_counter() - t0)


def evaluate_classifier(model, params, data_iter, batches: int,
                        rng: Optional[jax.Array] = None,
                        noise_frac: float = 0.0) -> float:
    apply = jax.jit(lambda p, x: model.apply(p, x))
    correct, total = 0.0, 0
    rng = rng if rng is not None else jax.random.PRNGKey(1)
    for _ in range(batches):
        xb, yb = next(data_iter)
        logits = apply(params, xb)
        if noise_frac > 0.0:
            rng, sub = jax.random.split(rng)
            logits = add_detector_noise(logits, sub, noise_frac)
        correct += float(jnp.sum(jnp.argmax(logits, -1) == yb))
        total += int(yb.shape[0])
    return correct / max(total, 1)
