"""LightRidge-DSE: analytical-model design space exploration (paper §4).

The paper trains a gradient-boosted regression model on (wavelength, unit
size, distance) -> accuracy grids from two wavelengths and transfers it to
a nearby third, replacing a 121-point grid search with a few verification
emulations (~60x fewer).  sklearn is unavailable offline, so the GBDT
(least-squares boosting over depth-limited regression trees, the paper's
n_estimators/learning_rate/max_depth hyperparameters) is implemented here
from scratch in numpy.

Beyond paper: ``ShardingDSE`` reuses the same engine over the roofline
analytical model to rank distributed-layout candidates for the LM stack
(DESIGN.md §5 note (b)).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np


# --------------------------------------------------------------- trees ---
@dataclasses.dataclass
class _Node:
    feature: int = -1
    thresh: float = 0.0
    left: Optional["_Node"] = None
    right: Optional["_Node"] = None
    value: float = 0.0


def _fit_tree(X: np.ndarray, y: np.ndarray, depth: int, min_leaf: int = 2):
    node = _Node(value=float(np.mean(y)))
    if depth == 0 or len(y) < 2 * min_leaf or np.allclose(y, y[0]):
        return node
    best = (0.0, None, None)  # (gain, feature, thresh)
    base = np.sum((y - y.mean()) ** 2)
    for f in range(X.shape[1]):
        order = np.argsort(X[:, f])
        xs, ys = X[order, f], y[order]
        csum = np.cumsum(ys)
        csq = np.cumsum(ys * ys)
        n = len(ys)
        for i in range(min_leaf, n - min_leaf):
            if xs[i] == xs[i - 1]:
                continue
            nl, nr = i, n - i
            sl, sr = csum[i - 1], csum[-1] - csum[i - 1]
            ql, qr = csq[i - 1], csq[-1] - csq[i - 1]
            sse = (ql - sl * sl / nl) + (qr - sr * sr / nr)
            gain = base - sse
            if gain > best[0]:
                best = (gain, f, 0.5 * (xs[i] + xs[i - 1]))
    if best[1] is None:
        return node
    _, f, t = best
    mask = X[:, f] <= t
    node.feature, node.thresh = f, t
    node.left = _fit_tree(X[mask], y[mask], depth - 1, min_leaf)
    node.right = _fit_tree(X[~mask], y[~mask], depth - 1, min_leaf)
    return node


def _predict_tree(node: _Node, X: np.ndarray) -> np.ndarray:
    if node.left is None:
        return np.full(len(X), node.value)
    mask = X[:, node.feature] <= node.thresh
    out = np.empty(len(X))
    out[mask] = _predict_tree(node.left, X[mask])
    out[~mask] = _predict_tree(node.right, X[~mask])
    return out


class GradientBoostingRegressor:
    """Least-squares GBDT (paper: n_estimators=3500, lr=0.2, max_depth=3)."""

    def __init__(self, n_estimators: int = 3500, learning_rate: float = 0.2,
                 max_depth: int = 3, random_state: int = 25,
                 subsample: float = 1.0):
        self.n_estimators = n_estimators
        self.learning_rate = learning_rate
        self.max_depth = max_depth
        self.random_state = random_state
        self.subsample = subsample
        self.trees: list = []
        self.base: float = 0.0

    def fit(self, X, y):
        X = np.asarray(X, np.float64)
        y = np.asarray(y, np.float64)
        rng = np.random.default_rng(self.random_state)
        self.base = float(np.mean(y))
        resid = y - self.base
        self.trees = []
        for _ in range(self.n_estimators):
            if self.subsample < 1.0:
                idx = rng.random(len(y)) < self.subsample
                if idx.sum() < 4:
                    idx = np.ones(len(y), bool)
            else:
                idx = np.ones(len(y), bool)
            tree = _fit_tree(X[idx], resid[idx], self.max_depth)
            pred = _predict_tree(tree, X)
            resid = resid - self.learning_rate * pred
            self.trees.append(tree)
            if np.max(np.abs(resid)) < 1e-8:
                break
        return self

    def predict(self, X):
        X = np.asarray(X, np.float64)
        out = np.full(len(X), self.base)
        for tree in self.trees:
            out += self.learning_rate * _predict_tree(tree, X)
        return out


# ---------------------------------------------------------- DONN DSE -----
@dataclasses.dataclass
class DSEResult:
    best_point: dict
    predicted_acc: float
    verified_acc: float
    emulations_used: int
    grid_size: int

    @property
    def speedup(self) -> float:
        return self.grid_size / max(self.emulations_used, 1)


class LightRidgeDSE:
    """Analytical-model DSE over (wavelength, unit_size, distance).

    train with grids from reference wavelengths, predict the landscape at a
    new nearby wavelength, verify only the top-k candidates by emulation.
    Validity: the analytical model only transfers within the same spectral
    neighbourhood (maximum half-cone diffraction angle theory [5]) — the
    engine refuses extrapolation beyond ``max_wavelength_ratio``.
    """

    def __init__(self, n_estimators: int = 400, learning_rate: float = 0.2,
                 max_depth: int = 3, max_wavelength_ratio: float = 1.6):
        self.model = GradientBoostingRegressor(
            n_estimators, learning_rate, max_depth
        )
        self.max_wavelength_ratio = max_wavelength_ratio
        self._lams: list = []

    @staticmethod
    def _features(lam, d, D, depth=None):
        # physics-aware features: raw + the Fresnel-number-ish couplings;
        # optional ragged-depth axis for architecture-depth exploration
        base = [lam * 1e9, d * 1e6, D, d / lam, d * d / (lam * D)]
        if depth is not None:
            base.append(float(depth))
        return base

    def fit(self, points: Sequence[tuple], accs: Sequence[float]):
        """points: iterable of (wavelength, unit_size, distance[, depth]).

        All points must share one arity — either the classic 3-tuple grid
        or the depth-extended 4-tuple grid (mixed arities would silently
        misalign the feature matrix).
        """
        if len({len(p) for p in points}) > 1:
            raise ValueError("mix of 3- and 4-tuple DSE points")
        X = np.array([self._features(*p) for p in points])
        self.model.fit(X, np.asarray(accs))
        self._lams = sorted({p[0] for p in points})
        return self

    def predict(self, points: Sequence[tuple]) -> np.ndarray:
        lams = {p[0] for p in points}
        for lam in lams:
            ratio = max(lam / self._lams[0], self._lams[-1] / lam)
            if ratio > self.max_wavelength_ratio:
                raise ValueError(
                    f"wavelength {lam} outside the validity neighbourhood "
                    f"of the training data (theory-violating extrapolation)"
                )
        X = np.array([self._features(*p) for p in points])
        return self.model.predict(X)

    def explore(self, lam: float, candidates: Sequence[tuple],
                emulate: Optional[Callable[[tuple], float]] = None,
                top_k: int = 2, *,
                emulate_batch: Optional[Callable] = None) -> DSEResult:
        """Predict the landscape at ``lam``; emulate only the top_k points.

        candidates: (unit_size, distance) pairs, or — for architecture
        exploration over ragged stack depths — (unit_size, distance,
        depth) triples.  Verification runs through ``emulate`` (one point
        -> one score, called top_k times) or — preferred —
        ``emulate_batch`` (all top_k points -> scores in one call, e.g.
        built on ``repro.core.models.emulate_batch`` so the candidates
        share one compiled vmapped forward instead of K
        trace+compile+run cycles; with depth-extended candidates the
        shared program depth-pads + masks the shallower stacks).
        """
        if emulate is None and emulate_batch is None:
            raise ValueError("explore needs emulate or emulate_batch")
        pts = [(lam,) + tuple(c) for c in candidates]
        preds = self.predict(pts)
        order = np.argsort(-preds)[:top_k]
        if emulate_batch is not None:
            accs = list(emulate_batch([pts[i] for i in order]))
            if len(accs) != len(order):
                raise ValueError(
                    f"emulate_batch returned {len(accs)} scores for "
                    f"{len(order)} candidates"
                )
        else:
            accs = [emulate(pts[i]) for i in order]
        best_acc, best_pt, best_pred = -1.0, None, 0.0
        for i, acc in zip(order, accs):
            if acc > best_acc:
                best_acc, best_pt, best_pred = acc, pts[i], preds[i]
        best_point = {"wavelength": best_pt[0], "unit_size": best_pt[1],
                      "distance": best_pt[2]}
        if len(best_pt) > 3:
            best_point["depth"] = best_pt[3]
        return DSEResult(
            best_point=best_point,
            predicted_acc=float(best_pred),
            verified_acc=float(best_acc),
            emulations_used=int(top_k),
            grid_size=len(candidates),
        )


def sensitivity_analysis(emulate: Optional[Callable[[tuple], float]],
                         best: tuple,
                         deltas=(-0.10, -0.05, 0.0, 0.05, 0.10),
                         emulate_batch: Optional[Callable] = None) -> dict:
    """Single-parameter control-variable tests (paper Table 3).

    With ``emulate_batch`` every delta point of every parameter is scored
    in one batched call (3 * len(deltas) candidates share one compiled
    forward) instead of one sequential emulation per point.
    """
    if emulate is None and emulate_batch is None:
        raise ValueError("sensitivity_analysis needs emulate or emulate_batch")
    lam, d, D = best
    params = (("wavelength", 0), ("unit_size", 1), ("distance", 2))
    pts = []
    for _, idx in params:
        for delta in deltas:
            p = [lam, d, D]
            p[idx] = p[idx] * (1.0 + delta)
            pts.append(tuple(p))
    if emulate_batch is not None:
        accs = list(emulate_batch(pts))
        if len(accs) != len(pts):
            raise ValueError(
                f"emulate_batch returned {len(accs)} scores for "
                f"{len(pts)} points"
            )
    else:
        accs = [emulate(p) for p in pts]
    out = {}
    k = len(deltas)
    for j, (name, _) in enumerate(params):
        out[name] = [(delta, accs[j * k + i])
                     for i, delta in enumerate(deltas)]
    return out


# ------------------------------------------------ sharding DSE (beyond) --
@dataclasses.dataclass(frozen=True)
class LayoutCandidate:
    name: str
    rules: dict
    accum_steps: int = 1


def rank_layouts(records: Sequence[dict]) -> list:
    """Rank dry-run records (one per layout candidate) by the roofline
    bound max(compute, memory, collective); ties broken by collective."""
    def key(r):
        t = r["terms"]
        return (max(t.values()), t["collective_s"])

    return sorted(records, key=key)
