"""FFT-based scalar-diffraction physics kernels (LightRidge §3.1).

Implements the three approximations of the paper as *transfer functions* over
a uniform sampling grid, plus the propagation primitive

    U_out = iFFT2( FFT2(U_in) * H(fx, fy; z, lambda) )

- Rayleigh-Sommerfeld (exact angular-spectrum solution, Eq. 1): valid in both
  near and far field; highest fidelity.
- Fresnel (parabolic wavefronts, Eq. 3): near-field approximation.
- Fraunhofer (planar wavefronts, Eq. 4): far field; implemented as a single
  scaled FFT (its output grid is rescaled by lambda*z/(N*dx^2)).

All transfer functions are precomputed with numpy at model-build time (they
depend only on static geometry) and embedded as constants, so jit'd forward
passes contain only FFT2 / complex-multiply / iFFT2 — the three operators the
paper identifies as the DONN hot spots (Fig. 9).

Optional band-limiting (Matsushima & Shimobaba 2009) suppresses aliasing of
the angular spectrum for long propagation distances; optional 2x zero-padding
turns the circular convolution into a linear one.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cache import lru_get, lru_put

RS = "rs"
FRESNEL = "fresnel"
FRAUNHOFER = "fraunhofer"
METHODS = (RS, FRESNEL, FRAUNHOFER)


@dataclasses.dataclass(frozen=True)
class Grid:
    """Uniform square sampling grid for an optical field."""

    n: int  # samples per side (system size / resolution)
    pixel_size: float  # diffraction unit size [m]

    @property
    def extent(self) -> float:
        return self.n * self.pixel_size

    def freqs(self, pad: bool = False) -> np.ndarray:
        n = 2 * self.n if pad else self.n
        return np.fft.fftfreq(n, d=self.pixel_size)

    def coords(self) -> np.ndarray:
        # centered spatial coordinates of sample centers
        return (np.arange(self.n) - (self.n - 1) / 2.0) * self.pixel_size


def fresnel_tf_centered(
    grid: Grid, z: float, wavelength: float, pad: bool = False
) -> np.ndarray:
    """Fresnel transfer function over *centered* (fftshift-ordered) freqs.

    The textbook spelling: H lives on the centered frequency grid, so a hop
    using it must bracket the spectral multiply with an fftshift/ifftshift
    pair — ``ifft2(ifftshift(H_c * fftshift(fft2(u))))``.  The propagation
    engine never pays those two shifts per layer: ``transfer_function``
    pre-folds the pair into the cached plane at build time
    (``ifftshift(H_c)`` is stored, which is exactly H over natural fftfreq
    ordering), so the runtime hop is a bare ``ifft2(fft2(u) * H)``.
    Parity between the two spellings is pinned by
    tests/test_diffraction.py::test_fresnel_prefolded_shift_pair.
    """
    f = np.fft.fftshift(grid.freqs(pad=pad))
    fx, fy = np.meshgrid(f, f, indexing="ij")
    k = 2.0 * math.pi / wavelength
    return (
        np.exp(1j * k * z)
        * np.exp(-1j * math.pi * wavelength * z * (fx**2 + fy**2))
    ).astype(np.complex64)


def transfer_function(
    grid: Grid,
    z: float,
    wavelength: float,
    method: str = RS,
    band_limit: bool = True,
    pad: bool = False,
) -> np.ndarray:
    """Free-space transfer function H(fx, fy) on the (possibly padded) grid.

    Returned as a numpy complex64 array (static geometry => build-time
    const).  Planes are stored *pre-shifted* — natural ``fftfreq`` ordering
    — so the runtime hop is shift-free; see ``fresnel_tf_centered`` for the
    centered spelling the fold starts from.
    """
    if method not in (RS, FRESNEL):
        raise ValueError(f"transfer_function supports rs|fresnel, got {method}")
    f = grid.freqs(pad=pad)
    fx, fy = np.meshgrid(f, f, indexing="ij")
    k = 2.0 * math.pi / wavelength
    if method == RS:
        # exact angular spectrum: H = exp(j k z sqrt(1 - (l fx)^2 - (l fy)^2))
        arg = 1.0 - (wavelength * fx) ** 2 - (wavelength * fy) ** 2
        prop = arg >= 0.0
        kz = k * np.sqrt(np.maximum(arg, 0.0))
        kappa = k * np.sqrt(np.maximum(-arg, 0.0))
        h = np.where(prop, np.exp(1j * kz * z), np.exp(-kappa * abs(z)))
    else:
        # centered Fresnel plane with the fftshift/ifftshift pair folded in
        # at build time: each cached fresnel hop drops two shifts per layer
        # (the shift is a permutation, so the fold is bit-exact)
        h = np.fft.ifftshift(fresnel_tf_centered(grid, z, wavelength, pad))
    if band_limit:
        # Matsushima & Shimobaba band-limited angular spectrum
        n = 2 * grid.n if pad else grid.n
        s = n * grid.pixel_size
        f_limit = 1.0 / (wavelength * math.sqrt((2.0 * z / s) ** 2 + 1.0))
        h = h * ((np.abs(fx) <= f_limit) & (np.abs(fy) <= f_limit))
    return h.astype(np.complex64)


def propagate_tf(u: jax.Array, h: jax.Array) -> jax.Array:
    """Angular-spectrum propagation of field(s) u (..., N, N) by TF h."""
    spec = jnp.fft.fft2(u)
    out = jnp.fft.ifft2(spec * h)
    return out


def propagate(
    u: jax.Array,
    grid: Grid,
    z: float,
    wavelength: float,
    method: str = RS,
    band_limit: bool = True,
    pad: bool = False,
) -> jax.Array:
    """One-shot propagation (builds H; prefer precomputing H in layers)."""
    if method == FRAUNHOFER:
        return fraunhofer(u, grid, z, wavelength)
    if pad:
        return _propagate_padded(u, grid, z, wavelength, method, band_limit)
    h = jnp.asarray(transfer_function(grid, z, wavelength, method, band_limit))
    return propagate_tf(u, h)


def pad_field(u: jax.Array, n: int) -> jax.Array:
    """Center-embed an (..., n, n) field into the 2x zero-padded grid."""
    widths = [(0, 0)] * (u.ndim - 2) + [
        (n // 2, n - n // 2), (n // 2, n - n // 2)
    ]
    return jnp.pad(u, widths)


def crop_field(u: jax.Array, n: int) -> jax.Array:
    """Inverse of ``pad_field``: recover the central (..., n, n) window."""
    lo = n // 2
    return u[..., lo : lo + n, lo : lo + n]


def _propagate_padded(u, grid, z, wavelength, method, band_limit):
    n = grid.n
    h = jnp.asarray(
        transfer_function(grid, z, wavelength, method, band_limit, pad=True)
    )
    return crop_field(propagate_tf(pad_field(u, n), h), n)


def fraunhofer_quad(grid: Grid, z: float, wavelength: float) -> np.ndarray:
    """Far-field output-plane factor of Eq. 4 (quadratic phase + scaling).

    Shared by the eager path (``fraunhofer``) and the propagation-plan
    cache so the two can never diverge.
    """
    n = grid.n
    k = 2.0 * math.pi / wavelength
    x = np.fft.fftshift(np.fft.fftfreq(n, d=grid.pixel_size)) * wavelength * z
    xx, yy = np.meshgrid(x, x, indexing="ij")
    quad = np.exp(1j * k * z) * np.exp(1j * k / (2.0 * z) * (xx**2 + yy**2))
    scale = grid.pixel_size**2 / (1j * wavelength * z)
    return (quad * scale).astype(np.complex64)


def fraunhofer(
    u: jax.Array, grid: Grid, z: float, wavelength: float
) -> jax.Array:
    """Far-field (Fraunhofer) propagation, Eq. 4.

    Output samples live on the rescaled far-field grid with spacing
    lambda*z/(N*dx); the quadratic output phase and 1/(j lambda z) scaling are
    applied so intensities are physical.
    """
    spec = jnp.fft.fftshift(jnp.fft.fft2(u), axes=(-2, -1))
    return spec * jnp.asarray(fraunhofer_quad(grid, z, wavelength))


# bounded LRU, same shared discipline as the propagation TF/plan caches
_RESAMPLE_CACHE: dict = {}
_RESAMPLE_CACHE_MAX = 256


def resample_matrix(grid_in: Grid, grid_out: Grid) -> np.ndarray:
    """Bilinear field-resampling operator between two plane grids.

    Returns the (n_out, n_in) separable 1-D interpolation matrix ``A`` such
    that ``u_out = A @ u_in @ A.T`` resamples a field over *physical*
    coordinates (both grids are centered; samples falling outside the input
    aperture read zero).  For equal pixel sizes *and* matching sample
    alignment (n_in and n_out of the same parity, so the centered grids
    coincide) the matrix degenerates to an exact centered crop / zero-pad
    (0/1 entries) and aperture-only stitches are lossless; an odd<->even
    stitch at equal pitch interpolates half-sample-shifted values instead.
    Static geometry => numpy constant (cached process-wide LRU, embedded
    into jit programs like the TF planes).
    """
    key = (grid_in.n, float(grid_in.pixel_size),
           grid_out.n, float(grid_out.pixel_size))
    hit = lru_get(_RESAMPLE_CACHE, key)
    if hit is not None:
        return hit
    # output sample positions in input index space
    t = (grid_out.coords() / grid_in.pixel_size) + (grid_in.n - 1) / 2.0
    i0 = np.floor(t).astype(np.int64)
    w = (t - i0).astype(np.float64)
    A = np.zeros((grid_out.n, grid_in.n), np.float64)
    rows = np.arange(grid_out.n)
    for idx, wt in ((i0, 1.0 - w), (i0 + 1, w)):
        ok = (idx >= 0) & (idx < grid_in.n)
        A[rows[ok], idx[ok]] += wt[ok]
    A = A.astype(np.float32)
    lru_put(_RESAMPLE_CACHE, key, A, _RESAMPLE_CACHE_MAX)
    return A


def _is_exact_crop_pad(grid_in: Grid, grid_out: Grid) -> bool:
    """True when the stitch degenerates to a centered crop / zero-pad:
    equal pitch and same parity, so the centered sample grids coincide."""
    return (float(grid_in.pixel_size) == float(grid_out.pixel_size)
            and (grid_in.n - grid_out.n) % 2 == 0)


def resample_field(u: jax.Array, grid_in: Grid, grid_out: Grid) -> jax.Array:
    """Resample field(s) (..., n_in, n_in) onto ``grid_out`` (bilinear).

    Two fast paths keep boundary stitches off the matmul unit where
    possible: exact crop/pad stitches (equal pitch, matching parity) are
    pure slicing, and genuinely bilinear stitches of complex fields run as
    split real/imag float32 contractions — half the real FLOPs of the
    complex-promoted einsum (a float32 operator against a complex64 field
    upcasts the operator and multiplies zeros otherwise).
    """
    if grid_in == grid_out:
        return u
    if _is_exact_crop_pad(grid_in, grid_out):
        # centered grids coincide: output[o] = input[o + (n_in - n_out)/2]
        # (zero outside the input aperture) — pure slicing / padding,
        # bit-identical to the degenerate 0/1 resample matrix
        n_in, n_out = grid_in.n, grid_out.n
        if n_in >= n_out:
            off = (n_in - n_out) // 2
            return u[..., off:off + n_out, off:off + n_out]
        lo = (n_out - n_in) // 2
        hi = n_out - n_in - lo
        return jnp.pad(u, [(0, 0)] * (u.ndim - 2) + [(lo, hi), (lo, hi)])
    A = jnp.asarray(resample_matrix(grid_in, grid_out))
    if jnp.iscomplexobj(u):
        re = jnp.einsum("oi,...ij,pj->...op", A, u.real, A)
        im = jnp.einsum("oi,...ij,pj->...op", A, u.imag, A)
        return jax.lax.complex(re, im)
    return jnp.einsum("oi,...ij,pj->...op", A, u, A)


def fresnel_number(grid: Grid, z: float, wavelength: float) -> float:
    """Fresnel number a^2/(lambda z) with a = half-aperture (regime check)."""
    a = grid.extent / 2.0
    return a * a / (wavelength * z)


def phase_to_field(phi: jax.Array) -> jax.Array:
    """exp(j phi) as complex64 from a real phase array."""
    return jnp.exp(1j * phi.astype(jnp.complex64))


def intensity(u: jax.Array) -> jax.Array:
    """|U|^2 — detector-plane light intensity."""
    return (u.real**2 + u.imag**2).astype(jnp.float32)
