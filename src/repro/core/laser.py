"""Laser-source modeling (LightRidge `lr.laser`).

Coherent CW sources with configurable wavelength and beam profile, plus the
input-encoding utility ``data_to_cplex`` (paper §3.1: information is encoded
on the amplitude, phase initialized to zero).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.diffraction import Grid

PLANE = "plane"
GAUSSIAN = "gaussian"
BESSEL = "bessel"


@dataclasses.dataclass(frozen=True)
class Laser:
    """CW laser source: wavelength [m] + spatial beam profile."""

    wavelength: float = 532e-9
    profile: str = PLANE
    waist: Optional[float] = None  # 1/e^2 waist for gaussian / radial scale for bessel
    power: float = 1.0

    def field(self, grid: Grid) -> np.ndarray:
        """Complex source field on the grid (build-time constant)."""
        c = grid.coords()
        xx, yy = np.meshgrid(c, c, indexing="ij")
        r2 = xx**2 + yy**2
        if self.profile == PLANE:
            amp = np.ones((grid.n, grid.n))
        elif self.profile == GAUSSIAN:
            w = self.waist if self.waist is not None else grid.extent / 4.0
            amp = np.exp(-r2 / (w * w))
        elif self.profile == BESSEL:
            from numpy import sqrt

            w = self.waist if self.waist is not None else grid.extent / 8.0
            kr = sqrt(r2) / w
            # J0 via series-free numpy special-free approximation:
            # use np.sinc-based small-grid J0 approximation is poor; use
            # integral definition sampled coarsely (exact enough for a source
            # profile): J0(x) = (1/pi) int_0^pi cos(x sin t) dt
            t = np.linspace(0.0, math.pi, 64)
            amp = np.trapezoid(
                np.cos(kr[..., None] * np.sin(t)), t, axis=-1
            ) / math.pi
        else:
            raise ValueError(f"unknown beam profile {self.profile!r}")
        amp = amp * math.sqrt(self.power)
        return amp.astype(np.complex64)


def data_to_cplex(x: jax.Array, grid_n: Optional[int] = None) -> jax.Array:
    """Encode real-valued inputs (..., H, W) as complex fields (paper §3.1).

    Amplitude = input value, phase = 0.  If ``grid_n`` is given and larger
    than the image, the image is embedded centered into the grid (the paper
    embeds 28x28 MNIST into the 200x200 SLM plane by upsampling; we support
    both embed and nearest-upsample).
    """
    x = x.astype(jnp.float32)
    if grid_n is not None and x.shape[-1] != grid_n:
        x = resize_to_grid(x, grid_n)
    return x.astype(jnp.complex64)


def data_to_real(x: jax.Array, grid_n: Optional[int] = None) -> jax.Array:
    """``data_to_cplex`` without the complex cast (imag is exactly zero).

    The real-to-complex first-hop serving path (``DeployedDONN`` with
    ``rfft_first``) keeps the encoded field real so hop 0 can run as
    half-spectrum rFFTs; same resize/embed semantics as ``data_to_cplex``.
    """
    x = x.astype(jnp.float32)
    if grid_n is not None and x.shape[-1] != grid_n:
        x = resize_to_grid(x, grid_n)
    return x


def resize_to_grid(x: jax.Array, n: int, mode: str = "upsample") -> jax.Array:
    """Nearest-neighbour upsample (or center-embed) (..., h, w) -> (..., n, n)."""
    h, w = x.shape[-2], x.shape[-1]
    if mode == "embed" or n < h:
        if n < h:
            raise ValueError("grid smaller than image")
        out = jnp.zeros(x.shape[:-2] + (n, n), x.dtype)
        oy, ox = (n - h) // 2, (n - w) // 2
        return jax.lax.dynamic_update_slice(
            out, x, (0,) * (x.ndim - 2) + (oy, ox)
        )
    # nearest-neighbour upsample then center-pad remainder
    sy, sx = n // h, n // w
    up = jnp.repeat(jnp.repeat(x, sy, axis=-2), sx, axis=-1)
    uh, uw = up.shape[-2], up.shape[-1]
    py, px = n - uh, n - uw
    pads = [(0, 0)] * (x.ndim - 2) + [(py // 2, py - py // 2), (px // 2, px - px // 2)]
    return jnp.pad(up, pads)
