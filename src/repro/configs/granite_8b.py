"""granite-8b [dense]: 36L d4096 32H (GQA kv=8) ff14336 v49152 — llama-arch, code."""
import dataclasses
from repro.models.config import LMConfig, register


@register("granite-8b")
def cfgs():
    full = LMConfig(
        name="granite-8b", family="dense", n_layers=36, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=49152,
        mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="granite-8b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, attn_chunk=32,
    )
    return full, smoke
