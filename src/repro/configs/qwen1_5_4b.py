"""qwen1.5-4b [dense]: 40L d2560 20H (kv=20, MHA) ff6912 v151936 — QKV bias."""
import dataclasses
from repro.models.config import LMConfig, register


@register("qwen1.5-4b")
def cfgs():
    full = LMConfig(
        name="qwen1.5-4b", family="dense", n_layers=40, d_model=2560,
        n_heads=20, n_kv_heads=20, d_ff=6912, vocab=151936,
        qkv_bias=True, mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="qwen1.5-4b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256, attn_chunk=32,
    )
    return full, smoke
