"""Architecture registry: importing this package registers every config."""
from repro.configs import (  # noqa: F401
    arctic_480b,
    donn,
    falcon_mamba_7b,
    glm4_9b,
    granite_8b,
    llama_3_2_vision_11b,
    mixtral_8x7b,
    musicgen_medium,
    qwen1_5_4b,
    qwen2_5_14b,
    recurrentgemma_9b,
)

LM_ARCHS = (
    "glm4-9b", "granite-8b", "qwen1.5-4b", "qwen2.5-14b", "mixtral-8x7b",
    "arctic-480b", "llama-3.2-vision-11b", "musicgen-medium",
    "falcon-mamba-7b", "recurrentgemma-9b",
)
DONN_ARCHS = (
    "donn-mnist-3l", "donn-mnist-5l", "donn-chip", "donn-rgb", "donn-seg",
    "donn-xl-500",
)
