"""glm4-9b [dense]: 40L d4096 32H (GQA kv=2) ff13696 v151552 — RoPE(partial 0.5), GQA."""
import dataclasses
from repro.models.config import LMConfig, register


@register("glm4-9b")
def cfgs():
    full = LMConfig(
        name="glm4-9b", family="dense", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=2, d_ff=13696, vocab=151552,
        partial_rotary=0.5, mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="glm4-9b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, attn_chunk=32,
    )
    return full, smoke
