"""recurrentgemma-9b [hybrid]: 38L d4096 16H (MQA kv=1) ff12288 v256000.

RG-LRU recurrent blocks + local attention (window 2048), pattern
(rec, rec, attn) — 1 attention per 3 layers; 38 = 12 periods + 2 tail rec.
"""
import dataclasses
from repro.models.config import LMConfig, register


@register("recurrentgemma-9b")
def cfgs():
    full = LMConfig(
        name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
        n_heads=16, n_kv_heads=1, d_head=256, d_ff=12288, vocab=256000,
        block_pattern=("rec", "rec", "attn"), window=2048, lru_width=4096,
        mlp="geglu", norm="rms", logit_softcap=30.0,
    )
    smoke = dataclasses.replace(
        full, name="recurrentgemma-9b-smoke", n_layers=5, d_model=64,
        n_heads=4, n_kv_heads=1, d_head=16, d_ff=128, vocab=256,
        window=16, lru_width=64, scan_chunk=8, attn_chunk=32,
    )
    return full, smoke
