"""arctic-480b [moe]: 35L d7168 56H (GQA kv=8) 128e top-2 expert_ff 4864 + dense residual."""
import dataclasses
from repro.models.config import LMConfig, register


@register("arctic-480b")
def cfgs():
    full = LMConfig(
        name="arctic-480b", family="moe", n_layers=35, d_model=7168,
        n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000,
        n_experts=128, top_k=2, expert_d_ff=4864, dense_residual_ff=4864,
        mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="arctic-480b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=96, expert_d_ff=96, dense_residual_ff=96,
        n_experts=8, vocab=256, attn_chunk=32,
    )
    return full, smoke
