"""qwen2.5-14b [dense]: 48L d5120 40H (GQA kv=8) ff13824 v152064 — GQA, QKV bias."""
import dataclasses
from repro.models.config import LMConfig, register


@register("qwen2.5-14b")
def cfgs():
    full = LMConfig(
        name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
        n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064,
        qkv_bias=True, mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="qwen2.5-14b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, attn_chunk=32,
    )
    return full, smoke
