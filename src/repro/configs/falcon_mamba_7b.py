"""falcon-mamba-7b [ssm]: 64L d4096 attn-free mamba1, ssm_state=16, v65024."""
import dataclasses
from repro.models.config import LMConfig, register


@register("falcon-mamba-7b")
def cfgs():
    full = LMConfig(
        name="falcon-mamba-7b", family="ssm", n_layers=64, d_model=4096,
        n_heads=0, n_kv_heads=0, d_ff=0, vocab=65024,
        ssm_state=16, d_inner=8192, d_conv=4, dt_rank=256, norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="falcon-mamba-7b-smoke", n_layers=2, d_model=64,
        vocab=256, ssm_state=4, d_inner=128, dt_rank=8, scan_chunk=8,
    )
    return full, smoke
