"""llama-3.2-vision-11b [vlm]: 40L d4096 32H (GQA kv=8) ff14336 v128256.

Cross-attn image layers: 1 per 5 layers (8 cross + 32 self).  The vision
frontend is a STUB — input_specs() provides precomputed patch embeddings
(B, vision_seq, d_model), per the assignment.
"""
import dataclasses
from repro.models.config import LMConfig, register


@register("llama-3.2-vision-11b")
def cfgs():
    full = LMConfig(
        name="llama-3.2-vision-11b", family="vlm", n_layers=40, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=128256,
        cross_attn_period=5, vision_seq=1600, mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="llama-3.2-vision-11b-smoke", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
        cross_attn_period=2, vision_seq=8, attn_chunk=32,
    )
    return full, smoke
