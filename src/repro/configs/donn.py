"""The paper's own DONN architectures as first-class configs.

- donn-mnist-3l : the physically-prototyped 3-layer system (paper §5.1):
                  200x200, 36um pixels, 532nm, z=0.28m (11 in).
- donn-mnist-5l : the DSE-explored 5-layer system (paper §4/§5.2), z=0.30m.
- donn-chip     : the on-chip integration case study (paper §5.5):
                  3.45um CMOS pixels, z=532um, 200x200.
- donn-rgb      : the multi-channel RGB classifier (paper Fig. 12).
- donn-seg      : the segmentation DONN with optical skip + LN (Fig. 13).
- donn-xl-500   : the large-scale emulation workload (Fig. 10): 500^2, 30 layers.
"""
from repro.core.config import DONNConfig
from repro.models.config import register


@register("donn-mnist-3l")
def donn3():
    full = DONNConfig(
        name="donn-mnist-3l", n=200, pixel_size=36e-6, wavelength=532e-9,
        distance=0.28, depth=3, num_classes=10, det_size=20,
    )
    smoke = DONNConfig(
        name="donn-mnist-3l-smoke", n=64, depth=3, distance=0.05, det_size=8,
    )
    return full, smoke


@register("donn-mnist-5l")
def donn5():
    full = DONNConfig(
        name="donn-mnist-5l", n=200, pixel_size=36e-6, wavelength=532e-9,
        distance=0.30, depth=5, num_classes=10, det_size=20, gamma=1.12,
        codesign="qat", device_levels=256,
    )
    smoke = DONNConfig(
        name="donn-mnist-5l-smoke", n=64, depth=5, distance=0.05, det_size=8,
        gamma=1.12, codesign="qat",
    )
    return full, smoke


@register("donn-chip")
def donn_chip():
    full = DONNConfig(
        name="donn-chip", n=200, pixel_size=3.45e-6, wavelength=532e-9,
        distance=532e-6, depth=5, num_classes=10, det_size=20,
        codesign="qat", device_levels=256,
    )
    smoke = DONNConfig(
        name="donn-chip-smoke", n=64, pixel_size=3.45e-6, distance=532e-6,
        depth=3, det_size=8, codesign="qat",
    )
    return full, smoke


@register("donn-rgb")
def donn_rgb():
    full = DONNConfig(
        name="donn-rgb", n=200, pixel_size=36e-6, wavelength=532e-9,
        distance=0.30, depth=5, num_classes=6, det_size=20, channels=3,
        gamma=1.12,
    )
    smoke = DONNConfig(
        name="donn-rgb-smoke", n=64, depth=2, distance=0.05, det_size=8,
        num_classes=6, channels=3,
    )
    return full, smoke


@register("donn-seg")
def donn_seg():
    full = DONNConfig(
        name="donn-seg", n=350, pixel_size=36e-6, wavelength=532e-9,
        distance=0.30, depth=5, segmentation=True, skip_from=0,
        layer_norm=True, gamma=1.12,
    )
    smoke = DONNConfig(
        name="donn-seg-smoke", n=64, depth=3, distance=0.05,
        segmentation=True, skip_from=0, layer_norm=True,
    )
    return full, smoke


@register("donn-xl-500")
def donn_xl():
    full = DONNConfig(
        name="donn-xl-500", n=500, pixel_size=36e-6, wavelength=532e-9,
        distance=0.30, depth=30, num_classes=10, det_size=40, gamma=1.05,
    )
    smoke = DONNConfig(
        name="donn-xl-500-smoke", n=96, depth=10, distance=0.05, det_size=8,
    )
    return full, smoke
