"""mixtral-8x7b [moe]: 32L d4096 32H (GQA kv=8) expert_ff 14336, 8e top-2, SWA 4096."""
import dataclasses
from repro.models.config import LMConfig, register


@register("mixtral-8x7b")
def cfgs():
    full = LMConfig(
        name="mixtral-8x7b", family="moe", n_layers=32, d_model=4096,
        n_heads=32, n_kv_heads=8, d_ff=14336, vocab=32000,
        n_experts=8, top_k=2, expert_d_ff=14336, window=4096,
        mlp="swiglu", norm="rms",
    )
    smoke = dataclasses.replace(
        full, name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, expert_d_ff=128, n_experts=4, vocab=256,
        window=16, attn_chunk=32,
    )
    return full, smoke
