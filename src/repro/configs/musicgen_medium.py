"""musicgen-medium [audio]: 48L d1536 24H (MHA) ff6144 v2048 — decoder over EnCodec tokens.

EnCodec frontend is a STUB: input_specs() provides precomputed frame token
ids; backbone is a LayerNorm+GELU decoder-only transformer.
"""
import dataclasses
from repro.models.config import LMConfig, register


@register("musicgen-medium")
def cfgs():
    full = LMConfig(
        name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
        n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
        mlp="gelu", norm="ln",
    )
    smoke = dataclasses.replace(
        full, name="musicgen-medium-smoke", n_layers=2, d_model=64,
        n_heads=4, n_kv_heads=4, d_ff=128, vocab=64, attn_chunk=32,
    )
    return full, smoke
