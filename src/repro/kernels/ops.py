"""jit'd public wrappers around the Pallas kernels.

Handles: zero-padding to block multiples (zero is the identity element for
every kernel here), block-size selection (128-lane / 8-sublane alignment),
broadcasting, and backend dispatch (interpret=True off-TPU so the kernels
are exercised everywhere; compiled Mosaic path on TPU).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import complex_mul as _cm
from repro.kernels import intensity_readout as _ir
from repro.kernels import rope as _rp
from repro.kernels import spectral_hop as _sh
from repro.kernels import ref


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pick_blocks(H: int, W: int, max_h: int = 64, max_w: int = 512):
    bw = min(_ceil_to(W, 128), max_w)
    bh = min(_ceil_to(H, 8), max_h)
    return bh, bw


def _pad2d(x, Hp, Wp):
    H, W = x.shape[-2], x.shape[-1]
    if H == Hp and W == Wp:
        return x
    return jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(0, Hp - H), (0, Wp - W)])


# --------------------------------------------------------------------------
# complex_mul: (B?, H, W) x (H, W) split-plane complex multiply.
# custom VJP: da = g * conj(b); db = sum_batch g * conj(a).
# --------------------------------------------------------------------------
def _complex_mul_raw(ar, ai, br, bi):
    B, H, W = ar.shape
    bh, bw = _pick_blocks(H, W)
    Hp, Wp = _ceil_to(H, bh), _ceil_to(W, bw)
    out_r, out_i = _cm.complex_mul_pallas(
        _pad2d(ar, Hp, Wp), _pad2d(ai, Hp, Wp),
        _pad2d(br, Hp, Wp), _pad2d(bi, Hp, Wp),
        bh=bh, bw=bw, interpret=_interpret(),
    )
    return out_r[..., :H, :W], out_i[..., :H, :W]


@jax.custom_vjp
def _complex_mul(ar, ai, br, bi):
    return _complex_mul_raw(ar, ai, br, bi)


def _complex_mul_fwd(ar, ai, br, bi):
    return _complex_mul_raw(ar, ai, br, bi), (ar, ai, br, bi)


def _complex_mul_bwd(res, g):
    ar, ai, br, bi = res
    gr, gi = g
    # d a = g * conj(b);  d b = sum_B g * conj(a)
    dar, dai = _complex_mul_raw(gr, gi, br, -bi)
    dbr = jnp.sum(gr * ar + gi * ai, axis=0)
    dbi = jnp.sum(gi * ar - gr * ai, axis=0)
    return dar, dai, dbr, dbi


_complex_mul.defvjp(_complex_mul_fwd, _complex_mul_bwd)


@jax.jit
def complex_mul(ar, ai, br, bi):
    """(B?, H, W) x (H, W) split-plane complex multiply via Pallas."""
    squeeze = ar.ndim == 2
    if squeeze:
        ar, ai = ar[None], ai[None]
    out_r, out_i = _complex_mul(ar, ai, br, bi)
    if squeeze:
        out_r, out_i = out_r[0], out_i[0]
    return out_r, out_i


# --------------------------------------------------------------------------
# phase_apply: gamma * u * exp(j phi).  VJP:
#   d u   = g * conj(gamma e^{j phi}) = rotation of g by -phi times gamma
#   d phi = sum_B ( gi * out_r - gr * out_i )   [since d out/d phi = j out]
# --------------------------------------------------------------------------
def _phase_apply_raw(ur, ui, phi, gamma):
    B, H, W = ur.shape
    bh, bw = _pick_blocks(H, W)
    Hp, Wp = _ceil_to(H, bh), _ceil_to(W, bw)
    out_r, out_i = _cm.phase_apply_pallas(
        _pad2d(ur, Hp, Wp), _pad2d(ui, Hp, Wp), _pad2d(phi, Hp, Wp),
        float(gamma), bh=bh, bw=bw, interpret=_interpret(),
    )
    return out_r[..., :H, :W], out_i[..., :H, :W]


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def _phase_apply(ur, ui, phi, gamma):
    return _phase_apply_raw(ur, ui, phi, gamma)


def _phase_apply_fwd(ur, ui, phi, gamma):
    out = _phase_apply_raw(ur, ui, phi, gamma)
    return out, (phi, out)


def _phase_apply_bwd(gamma, res, g):
    phi, (our, oui) = res
    gr, gi = g
    dur, dui = _phase_apply_raw(gr, gi, -phi, gamma)
    dphi = jnp.sum(gi * our - gr * oui, axis=0)
    return dur, dui, dphi


_phase_apply.defvjp(_phase_apply_fwd, _phase_apply_bwd)


@partial(jax.jit, static_argnames=("gamma",))
def phase_apply(ur, ui, phi, gamma: float = 1.0):
    """gamma * u * exp(j phi) on split planes (paper Eq. 9 hot spot)."""
    squeeze = ur.ndim == 2
    if squeeze:
        ur, ui = ur[None], ui[None]
    lead = ur.shape[:-2]
    H, W = ur.shape[-2:]
    out_r, out_i = _phase_apply(
        ur.reshape((-1, H, W)), ui.reshape((-1, H, W)), phi, float(gamma)
    )
    out_r = out_r.reshape(lead + (H, W))
    out_i = out_i.reshape(lead + (H, W))
    if squeeze:
        out_r, out_i = out_r[0], out_i[0]
    return out_r, out_i


# --------------------------------------------------------------------------
# phase_tf_apply: x * amp * exp(j theta) — the propagation engine's single
# fused elementwise op (cos/sin rotation + amplitude-weighted complex
# multiply in one VMEM pass).  Serves both scan-body call sites: the
# spectral TF multiply (theta=arg H, amp=|H|, constants) and the phase
# modulation (theta=phi, trainable; amp=gamma).  VJP:
#   d x     = g * amp * exp(-j theta)          (same kernel, rotated back)
#   d theta = sum_B (gi * out_r - gr * out_i)  (d out/d theta = j out)
#   d amp   = 0  (always static geometry: TF magnitudes, band-limit masks,
#                 gamma planes — mirrors the masks argument of readout)
# --------------------------------------------------------------------------
def _phase_tf_apply_raw(xr, xi, theta, amp, nb):
    PB, H, W = xr.shape
    bh, bw = _pick_blocks(H, W)
    Hp, Wp = _ceil_to(H, bh), _ceil_to(W, bw)
    out_r, out_i = _cm.phase_tf_apply_pallas(
        _pad2d(xr, Hp, Wp), _pad2d(xi, Hp, Wp),
        _pad2d(theta, Hp, Wp), _pad2d(amp, Hp, Wp),
        nb=nb, bh=bh, bw=bw, interpret=_interpret(),
    )
    return out_r[..., :H, :W], out_i[..., :H, :W]


@partial(jax.custom_vjp, nondiff_argnums=(4,))
def _phase_tf_apply(xr, xi, theta, amp, nb):
    return _phase_tf_apply_raw(xr, xi, theta, amp, nb)


def _phase_tf_apply_fwd(xr, xi, theta, amp, nb):
    out = _phase_tf_apply_raw(xr, xi, theta, amp, nb)
    return out, (theta, amp, out)


def _phase_tf_apply_bwd(nb, res, g):
    theta, amp, (our, oui) = res
    gr, gi = g
    dxr, dxi = _phase_tf_apply_raw(gr, gi, -theta, amp, nb)
    P, H, W = theta.shape
    cot = (gi * our - gr * oui).reshape((P, nb, H, W))
    dtheta = jnp.sum(cot, axis=1)
    return dxr, dxi, dtheta, jnp.zeros_like(amp)


_phase_tf_apply.defvjp(_phase_tf_apply_fwd, _phase_tf_apply_bwd)


@jax.jit
def phase_tf_apply(xr, xi, theta, amp):
    """x * amp * exp(j theta) on split planes via the fused Pallas kernel.

    x: (..., H, W); theta/amp: (H, W) shared by every field, or a plane
    stack (*P, H, W) with x: (..., *P, H, W) so plane p modulates the
    fields in slot p.  The plane axes may be any number of leading dims —
    (C, H, W) is the multi-channel DONN layout (one phase plane per
    optical channel), (K, H, W) / (K, C, H, W) are the batched
    multi-candidate layouts (one TF/phase plane per DSE candidate [and
    channel]); they all flatten to one plane-major axis internally.
    """
    pdims = theta.ndim - 2
    H, W = theta.shape[-2:]
    if pdims > 0:
        pshape = theta.shape[:-2]
        if xr.shape[xr.ndim - 2 - pdims: xr.ndim - 2] != pshape:
            raise ValueError(
                f"plane axes {pshape} of theta/amp must match the "
                f"corresponding axes of x {xr.shape}"
            )
        squeeze = xr.ndim == pdims + 2
        if squeeze:
            xr, xi = xr[None], xi[None]
        P = math.prod(pshape)
        lead = xr.shape[: xr.ndim - pdims - 2]
        # (..., *P, H, W) -> (P, B, H, W) -> (P*B, H, W): plane-major slabs
        xr3 = jnp.moveaxis(xr.reshape((-1, P, H, W)), 1, 0)
        xi3 = jnp.moveaxis(xi.reshape((-1, P, H, W)), 1, 0)
        B = xr3.shape[1]
        out_r, out_i = _phase_tf_apply(
            xr3.reshape((P * B, H, W)), xi3.reshape((P * B, H, W)),
            theta.reshape((P, H, W)), amp.reshape((P, H, W)), B,
        )
        out_r = jnp.moveaxis(out_r.reshape((P, B, H, W)), 0, 1)
        out_i = jnp.moveaxis(out_i.reshape((P, B, H, W)), 0, 1)
        out_r = out_r.reshape(lead + pshape + (H, W))
        out_i = out_i.reshape(lead + pshape + (H, W))
    else:
        squeeze = xr.ndim == 2
        if squeeze:
            xr, xi = xr[None], xi[None]
        lead = xr.shape[:-2]
        flat_r = xr.reshape((-1, H, W))
        out_r, out_i = _phase_tf_apply(
            flat_r, xi.reshape((-1, H, W)), theta[None], amp[None],
            flat_r.shape[0],
        )
        out_r = out_r.reshape(lead + (H, W))
        out_i = out_i.reshape(lead + (H, W))
    if squeeze:
        out_r, out_i = out_r[0], out_i[0]
    return out_r, out_i


# --------------------------------------------------------------------------
# fused_spectral_hop: one full propagation hop + modulation,
#   out = M . ifft2(Hc . fft2(x)),   Hc = amp_h e^{j th_h}, M = amp_m e^{j th_m}
# as fft2 -> conj-kernel(-th_h) -> fft2 -> conj-kernel(+th_m, 1/(H*W)) via
# ifft2(y) = conj(fft2(conj(y)))/(H*W).  Everything between/after the two
# forward FFTs is a single fused VMEM pass (see kernels/spectral_hop.py).
# VJP (the hop is C-linear in x; adjoint convention matches phase_tf_apply,
# d x = A^H g):
#   d x    = ifft2( conj(Hc) . fft2( conj(M) . g ) )   [reuses phase_tf kernel]
#   d th_m = sum_nb (gi * out_r - gr * out_i)          [d out/d th_m = j out]
#   d th_h = d amp_h = d amp_m = 0   (TF/band-limit/gamma: static geometry)
# --------------------------------------------------------------------------
def _conj_ps_raw(xr, xi, theta, amp, nb, sign, scale):
    PB, H, W = xr.shape
    bh, bw = _pick_blocks(H, W)
    Hp, Wp = _ceil_to(H, bh), _ceil_to(W, bw)
    out_r, out_i = _sh.conj_phase_scale_pallas(
        _pad2d(xr, Hp, Wp), _pad2d(xi, Hp, Wp),
        _pad2d(theta, Hp, Wp), _pad2d(amp, Hp, Wp),
        sign=sign, scale=scale, nb=nb, bh=bh, bw=bw, interpret=_interpret(),
    )
    return out_r[..., :H, :W], out_i[..., :H, :W]


def _fused_hop_raw(xr, xi, th_h, amp_h, th_m, amp_m, nb):
    H, W = xr.shape[-2:]
    s = jnp.fft.fft2(jax.lax.complex(xr, xi))
    tr, ti = _conj_ps_raw(s.real, s.imag, th_h, amp_h, nb, -1.0, 1.0)
    w = jnp.fft.fft2(jax.lax.complex(tr, ti))
    return _conj_ps_raw(w.real, w.imag, th_m, amp_m, nb, 1.0, 1.0 / (H * W))


@partial(jax.custom_vjp, nondiff_argnums=(6,))
def _fused_hop(xr, xi, th_h, amp_h, th_m, amp_m, nb):
    return _fused_hop_raw(xr, xi, th_h, amp_h, th_m, amp_m, nb)


def _fused_hop_fwd(xr, xi, th_h, amp_h, th_m, amp_m, nb):
    out = _fused_hop_raw(xr, xi, th_h, amp_h, th_m, amp_m, nb)
    return out, (th_h, amp_h, th_m, amp_m, out)


def _fused_hop_bwd(nb, res, g):
    th_h, amp_h, th_m, amp_m, (our, oui) = res
    gr, gi = g
    # conj(M) . g, back through the spectral hop, conj(Hc) ., inverse FFT
    vr, vi = _phase_tf_apply_raw(gr, gi, -th_m, amp_m, nb)
    v = jnp.fft.fft2(jax.lax.complex(vr, vi))
    wr, wi = _phase_tf_apply_raw(v.real, v.imag, -th_h, amp_h, nb)
    dx = jnp.fft.ifft2(jax.lax.complex(wr, wi))
    P, H, W = th_m.shape
    cot = (gi * our - gr * oui).reshape((P, nb, H, W))
    dth_m = jnp.sum(cot, axis=1)
    return (dx.real, dx.imag, jnp.zeros_like(th_h), jnp.zeros_like(amp_h),
            dth_m, jnp.zeros_like(amp_m))


_fused_hop.defvjp(_fused_hop_fwd, _fused_hop_bwd)


@jax.jit
def fused_spectral_hop(xr, xi, theta_h, amp_h, theta_m, amp_m):
    """One hop + modulation, M . ifft2(Hc . fft2(x)), on split planes.

    x: (..., H, W); the four planes share one shape — (H, W) applied to
    every field, or a plane stack (*P, H, W) with x: (..., *P, H, W) so
    plane p transforms the fields in slot p (same stack-axis contract as
    ``phase_tf_apply``: (C, H, W) multi-channel, (K, ..., H, W) batched
    DSE candidates).  theta_h/amp_h are the transfer-function phase and
    magnitude (band-limit folded into amp); theta_m/amp_m the modulation
    phase and amplitude (gamma / codesign folded into amp_m).
    """
    # the TF and modulation planes may have different stack shapes (e.g.
    # multi-channel: TF (H, W) shared, phases (C, H, W)) — broadcast to one
    planes = (theta_h, amp_h, theta_m, amp_m)
    bshape = jnp.broadcast_shapes(*(p.shape for p in planes))
    planes = tuple(jnp.broadcast_to(p, bshape) for p in planes)
    pdims = len(bshape) - 2
    H, W = bshape[-2:]
    if pdims > 0:
        pshape = bshape[:-2]
        if xr.shape[xr.ndim - 2 - pdims: xr.ndim - 2] != pshape:
            raise ValueError(
                f"plane axes {pshape} of the TF/modulation planes must "
                f"match the corresponding axes of x {xr.shape}"
            )
        squeeze = xr.ndim == pdims + 2
        if squeeze:
            xr, xi = xr[None], xi[None]
        P = math.prod(pshape)
        lead = xr.shape[: xr.ndim - pdims - 2]
        xr3 = jnp.moveaxis(xr.reshape((-1, P, H, W)), 1, 0)
        xi3 = jnp.moveaxis(xi.reshape((-1, P, H, W)), 1, 0)
        B = xr3.shape[1]
        out_r, out_i = _fused_hop(
            xr3.reshape((P * B, H, W)), xi3.reshape((P * B, H, W)),
            *(p.reshape((P, H, W)) for p in planes), B,
        )
        out_r = jnp.moveaxis(out_r.reshape((P, B, H, W)), 0, 1)
        out_i = jnp.moveaxis(out_i.reshape((P, B, H, W)), 0, 1)
        out_r = out_r.reshape(lead + pshape + (H, W))
        out_i = out_i.reshape(lead + pshape + (H, W))
    else:
        squeeze = xr.ndim == 2
        if squeeze:
            xr, xi = xr[None], xi[None]
        lead = xr.shape[:-2]
        flat_r = xr.reshape((-1, H, W))
        out_r, out_i = _fused_hop(
            flat_r, xi.reshape((-1, H, W)),
            *(p[None] for p in planes), flat_r.shape[0],
        )
        out_r = out_r.reshape(lead + (H, W))
        out_i = out_i.reshape(lead + (H, W))
    if squeeze:
        out_r, out_i = out_r[0], out_i[0]
    return out_r, out_i


# --------------------------------------------------------------------------
# intensity_readout: out[b,c] = sum_hw masks[c] * (ur^2 + ui^2).
# VJP (masks are non-trainable detector geometry):
#   d ur = 2 ur * (g @ masks),  d ui = 2 ui * (g @ masks)
# --------------------------------------------------------------------------
def _readout_raw(ur, ui, masks):
    B, H, W = ur.shape
    bh, bw = _pick_blocks(H, W, max_h=32, max_w=256)
    Hp, Wp = _ceil_to(H, bh), _ceil_to(W, bw)
    return _ir.intensity_readout_pallas(
        _pad2d(ur, Hp, Wp), _pad2d(ui, Hp, Wp),
        _pad2d(masks.astype(ur.dtype), Hp, Wp),
        bh=bh, bw=bw, interpret=_interpret(),
    )


@jax.custom_vjp
def _readout(ur, ui, masks):
    return _readout_raw(ur, ui, masks)


def _readout_fwd(ur, ui, masks):
    return _readout_raw(ur, ui, masks), (ur, ui, masks)


def _readout_bwd(res, g):
    ur, ui, masks = res
    w = jnp.einsum("bc,chw->bhw", g, masks)
    return 2.0 * ur * w, 2.0 * ui * w, jnp.zeros_like(masks)


_readout.defvjp(_readout_fwd, _readout_bwd)


@jax.jit
def intensity_readout(ur, ui, masks):
    """(B?, H, W) field planes + (C, H, W) masks -> (B?, C) intensities."""
    squeeze = ur.ndim == 2
    if squeeze:
        ur, ui = ur[None], ui[None]
    lead = ur.shape[:-2]
    H, W = ur.shape[-2:]
    out = _readout(ur.reshape((-1, H, W)), ui.reshape((-1, H, W)), masks)
    out = out.reshape(lead + (masks.shape[0],))
    if squeeze:
        out = out[0]
    return out


@jax.jit
def channel_intensity_readout(ur, ui, masks):
    """(..., C, H, W) multi-channel fields + (K, H, W) masks -> (..., K).

    The multi-channel DONN detector accumulation: per-channel fused
    intensity readout (one Pallas pass per plane slab) followed by the
    incoherent channel sum.  Shared by ``MultiChannelDONN`` (plan and
    eager paths), ``emulate_batch`` and the deployment inference engine so
    every batched path accumulates through the same fused kernel.

    Coverage audit (ISSUE-5): with this helper in place every scan-plan /
    batched detector accumulation routes through ``intensity_readout``
    under ``use_pallas`` — ``Detector.__call__`` (classify, DSE ``cls``
    family), this channel sum (RGB plan + eager + ``multi`` family).  The
    remaining jnp einsum readouts are the documented non-Pallas fallbacks
    and the spatially-sharded step (``donn_steps.make_donn_spatial_loss``),
    which gates ``use_pallas`` off because its planes are row shards.
    """
    per_ch = intensity_readout(ur, ui, masks)  # (..., C, K)
    return jnp.sum(per_ch, axis=-2)


# --------------------------------------------------------------------------
# apply_rope: unitary rotation; VJP rotates cotangent by -theta.
# --------------------------------------------------------------------------
def _rope_raw(x3, cos, sin):
    BN, S, D = x3.shape
    bs = min(_ceil_to(S, 8), 256)
    Sp = _ceil_to(S, bs)
    if Sp != S:
        x3 = jnp.pad(x3, [(0, 0), (0, Sp - S), (0, 0)])
        cos = jnp.pad(cos, [(0, Sp - S), (0, 0)])
        sin = jnp.pad(sin, [(0, Sp - S), (0, 0)])
    out = _rp.rope_pallas(x3, cos, sin, bs=bs, interpret=_interpret())
    return out[:, :S, :]


@jax.custom_vjp
def _rope(x3, cos, sin):
    return _rope_raw(x3, cos, sin)


def _rope_fwd(x3, cos, sin):
    return _rope_raw(x3, cos, sin), (cos, sin)


def _rope_bwd(res, g):
    cos, sin = res
    return _rope_raw(g, cos, -sin), jnp.zeros_like(cos), jnp.zeros_like(sin)


_rope.defvjp(_rope_fwd, _rope_bwd)


@jax.jit
def apply_rope(x, cos, sin):
    """x: (..., S, D) rotate-half RoPE with cos/sin (S, D//2)."""
    lead = x.shape[:-2]
    S, D = x.shape[-2:]
    out = _rope(x.reshape((-1, S, D)), cos, sin)
    return out.reshape(lead + (S, D))


# re-export oracles for tests/benchmarks
complex_mul_ref = ref.complex_mul_ref
phase_apply_ref = ref.phase_apply_ref
phase_tf_apply_ref = ref.phase_tf_apply_ref
fused_spectral_hop_ref = ref.fused_spectral_hop_ref
intensity_readout_ref = ref.intensity_readout_ref
rope_ref = ref.rope_ref


# --------------------------------------------------------------------------
# selective_scan: mamba-1 SSM forward (inference path; no custom VJP —
# training uses the chunked jnp scan in repro.models.ssm).
# --------------------------------------------------------------------------
@jax.jit
def selective_scan(dt, x, bs, cs, a):
    """dt/x (B, S, D); bs/cs (B, S, N); a (D, N) -> y (B, S, D) float32."""
    from repro.kernels import selective_scan as _ss

    B, S, D = x.shape
    bd = min(_ceil_to(D, 128), 512)
    Dp = _ceil_to(D, bd)
    if Dp != D:
        pad = [(0, 0), (0, 0), (0, Dp - D)]
        dt = jnp.pad(dt, pad)
        x = jnp.pad(x, pad)
        a = jnp.pad(a, [(0, Dp - D), (0, 0)])
    y = _ss.selective_scan_pallas(
        dt.astype(jnp.float32), x.astype(jnp.float32),
        bs.astype(jnp.float32), cs.astype(jnp.float32),
        a.astype(jnp.float32), bd=bd, interpret=_interpret(),
    )
    return y[..., :D]


def selective_scan_ref(dt, x, bs, cs, a):
    """Pure-jnp oracle (wraps the model's chunked scan, zero init)."""
    from repro.models.ssm import _selective_scan

    B, S, D = x.shape
    h0 = jnp.zeros((B, D, a.shape[-1]), jnp.float32)
    y, _ = _selective_scan(dt.astype(jnp.float32), bs.astype(jnp.float32),
                           cs.astype(jnp.float32), x.astype(jnp.float32),
                           a.astype(jnp.float32), h0, chunk=64)
    return y
