"""Pallas TPU kernel: mamba-1 selective-scan forward (inference path).

The SSM recurrence

    h_t = exp(dt_t * A) h_{t-1} + (dt_t * x_t) B_t ;   y_t = C_t . h_t

is sequential in t but elementwise in d_inner, so the kernel blocks
d_inner across the grid (each block carries its private h in VMEM through
a fori_loop over time) — the (B, S, d, state) discretization tensors are
never materialized in HBM, which is what makes the pure-jnp path
memory-bound (EXPERIMENTS.md §Roofline / ssm note).

Scope: forward only (prefill/serving).  Training keeps the chunked-scan
jnp path (`repro.models.ssm`), whose backward is handled by jax.checkpoint;
a fused backward kernel is the natural next step.  Validated against
`repro.models.ssm._selective_scan` in tests/test_selective_scan_kernel.py.

Layout: dt/x (B, S, D), Bs/Cs (B, S, N), A (D, N); D is tiled to the
128-lane dim, state N (16) lives on the sublane dim of the carried h.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(dt_ref, x_ref, bs_ref, cs_ref, a_ref, y_ref, *, seq_len):
    # blocks: dt/x (1, S, bd); bs/cs (1, S, N); a (bd, N); y (1, S, bd)
    a = a_ref[...]  # (bd, N)
    bd, n = a.shape

    def step(t, h):
        dt_t = dt_ref[0, t, :]  # (bd,)
        x_t = x_ref[0, t, :]
        b_t = bs_ref[0, t, :]  # (N,)
        c_t = cs_ref[0, t, :]
        da = jnp.exp(dt_t[:, None] * a)  # (bd, N)
        h = da * h + (dt_t * x_t)[:, None] * b_t[None, :]
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1)
        return h

    jax.lax.fori_loop(0, seq_len, step, jnp.zeros((bd, n), jnp.float32))


def selective_scan_pallas(dt, x, bs, cs, a, *, bd: int, interpret: bool):
    """dt/x: (B, S, D) f32; bs/cs: (B, S, N) f32; a: (D, N) f32 -> y (B,S,D)."""
    B, S, D = x.shape
    N = bs.shape[-1]
    grid = (B, D // bd)
    dx_spec = pl.BlockSpec((1, S, bd), lambda b, j: (b, 0, j))
    bc_spec = pl.BlockSpec((1, S, N), lambda b, j: (b, 0, 0))
    a_spec = pl.BlockSpec((bd, N), lambda b, j: (j, 0))
    return pl.pallas_call(
        functools.partial(_scan_kernel, seq_len=S),
        grid=grid,
        in_specs=[dx_spec, dx_spec, bc_spec, bc_spec, a_spec],
        out_specs=dx_spec,
        out_shape=jax.ShapeDtypeStruct((B, S, D), jnp.float32),
        interpret=interpret,
    )(dt, x, bs, cs, a)
