"""Pallas kernel: detector intensity readout (|U|^2 + region pooling).

Fuses the squared-magnitude and the per-class masked reduction — the
paper's detector/ADC interface — into one pass over the field, instead of
materializing the (B, H, W) intensity image in HBM and re-reading it for the
(C, H, W) mask contraction.

Grid: (B, nH, nW); the (H, W) tiles are reduction steps that accumulate into
the (1, C) output block (TPU grids execute sequentially, so revisiting the
output block across reduction steps is well-defined).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _readout_kernel(ur_ref, ui_ref, m_ref, o_ref):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ur, ui = ur_ref[0], ui_ref[0]  # (bh, bw)
    inten = ur * ur + ui * ui
    m = m_ref[...]  # (C, bh, bw)
    contrib = jnp.sum(m * inten[None], axis=(1, 2))  # (C,)
    o_ref[...] = o_ref[...] + contrib[None]


def intensity_readout_pallas(ur, ui, masks, *, bh: int, bw: int, interpret: bool):
    """ur/ui: (B, H, W), masks: (C, H, W) -> (B, C) pooled intensities."""
    B, H, W = ur.shape
    C = masks.shape[0]
    grid = (B, H // bh, W // bw)
    u_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
    m_spec = pl.BlockSpec((C, bh, bw), lambda b, i, j: (0, i, j))
    o_spec = pl.BlockSpec((1, C), lambda b, i, j: (b, 0))
    return pl.pallas_call(
        _readout_kernel,
        grid=grid,
        in_specs=[u_spec, u_spec, m_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((B, C), jnp.float32),
        interpret=interpret,
    )(ur, ui, masks)
