"""Pallas TPU kernels for the paper's compute hot spots (Fig. 9):
complex multiply / phase modulation / detector readout, plus the shared
complex-rotation kernel reused for RoPE in the LM stack (DESIGN.md §3).

Each kernel has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py;
off-TPU the kernels run in interpret mode so they are validated everywhere.
"""
from repro.kernels import ops

__all__ = ["ops"]
