"""Pallas TPU kernel for the fused spectral hop (one VMEM pass per side).

One propagation hop + modulation is ``M . ifft2(H . fft2(u))`` — four XLA
ops between which split real/imag planes get re-materialized as complex
temporaries.  Rewriting the inverse transform with the conjugation
identity ``ifft2(y) = conj(fft2(conj(y))) / (H*W)`` turns the hop into

    s = fft2(u)
    t = conj(s) * |H| * exp(-j arg H)          # pass 1: TF multiply + conj
    w = fft2(t)
    out = conj(w) * (|M| / (H*W)) * exp(+j arg M)   # pass 2: scale + modulate

so *everything between and after the two forward FFTs* is exactly one
fused elementwise kernel each: ``out = conj(x) * amp * scale * exp(sign *
j * theta)``.  The conjugations, the iFFT normalization and the
band-limit/evanescent amplitude all fold into the kernel constants
instead of surfacing as separate HLO ops.

Block layout matches ``complex_mul.py``: plane-major ``(P*nb, H, W)``
field slabs against ``(P, H, W)`` plane stacks, W tiled to the 128-lane
dimension, H to the 8-sublane dimension.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conj_phase_scale_kernel(xr_ref, xi_ref, th_ref, amp_ref, or_ref, oi_ref,
                             *, sign, scale):
    # out = conj(x) * amp * scale * exp(sign * j * theta)
    #     = (xr - j xi) * (c + j s_)   with c = amp*scale*cos, s_ = sign*...
    xr, xi = xr_ref[...], xi_ref[...]
    th = th_ref[0]
    amp = amp_ref[0]
    c = jnp.cos(th) * amp * scale
    s_ = jnp.sin(th) * (amp * (sign * scale))
    or_ref[...] = xr * c + xi * s_
    oi_ref[...] = xr * s_ - xi * c


def conj_phase_scale_pallas(xr, xi, theta, amp, *, sign: float, scale: float,
                            nb: int, bh: int, bw: int, interpret: bool):
    """x: (P*nb, H, W) split planes; theta/amp: (P, H, W) real planes.

    Computes ``conj(x) * amp * scale * exp(sign * j * theta)`` in one VMEM
    pass.  Plane p applies to the contiguous slab ``x[p*nb:(p+1)*nb]``;
    ``sign``/``scale`` are trace-time constants folded into the cos/sin
    weights (no extra device ops).
    """
    PB, H, W = xr.shape
    grid = (PB, H // bh, W // bw)
    x_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
    p_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b // nb, i, j))
    out_shape = [
        jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        jax.ShapeDtypeStruct(xr.shape, xr.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_conj_phase_scale_kernel, sign=float(sign),
                          scale=float(scale)),
        grid=grid,
        in_specs=[x_spec, x_spec, p_spec, p_spec],
        out_specs=[x_spec, x_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, theta, amp)
