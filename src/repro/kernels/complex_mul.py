"""Pallas TPU kernels for the paper's ComplexMM hot spot (Fig. 9).

TPU Pallas has no native complex arithmetic, so wavefields are carried as
separate real/imaginary planes (struct-of-arrays); the kernels fuse the four
real multiplies + two adds of a complex multiply (and, for ``phase_apply``,
the cos/sin transcendentals) into one VMEM-resident pass instead of the
6+ separate HLO ops XLA would otherwise materialize between FFTs.

Block layout: fields are (..., H, W); W is tiled to the 128-lane dimension,
H to the 8-sublane dimension.  The ops.py wrappers zero-pad to block
multiples (zero is the identity for every kernel here) and slice back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# --------------------------------------------------------- complex multiply
def _complex_mul_kernel(ar_ref, ai_ref, br_ref, bi_ref, or_ref, oi_ref):
    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[0], bi_ref[0]  # b block has no batch dim content
    or_ref[...] = ar * br - ai * bi
    oi_ref[...] = ar * bi + ai * br


def complex_mul_pallas(ar, ai, br, bi, *, bh: int, bw: int, interpret: bool):
    """a: (B, H, W) split planes; b: (H, W) split planes (broadcast over B)."""
    B, H, W = ar.shape
    grid = (B, H // bh, W // bw)
    a_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
    b_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (0, i, j))
    out_shape = [
        jax.ShapeDtypeStruct(ar.shape, ar.dtype),
        jax.ShapeDtypeStruct(ar.shape, ar.dtype),
    ]
    return pl.pallas_call(
        _complex_mul_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[a_spec, a_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(ar, ai, br[None], bi[None])


# ----------------------------------------------------------- phase modulate
def _phase_apply_kernel(ur_ref, ui_ref, phi_ref, or_ref, oi_ref, *, gamma):
    ur, ui = ur_ref[...], ui_ref[...]
    phi = phi_ref[0]
    c = jnp.cos(phi) * gamma
    s = jnp.sin(phi) * gamma
    or_ref[...] = ur * c - ui * s
    oi_ref[...] = ur * s + ui * c


def phase_apply_pallas(ur, ui, phi, gamma, *, bh: int, bw: int, interpret: bool):
    """u: (B, H, W) split planes, phi: (H, W) -> gamma * u * exp(j phi)."""
    B, H, W = ur.shape
    grid = (B, H // bh, W // bw)
    u_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
    p_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (0, i, j))
    out_shape = [
        jax.ShapeDtypeStruct(ur.shape, ur.dtype),
        jax.ShapeDtypeStruct(ur.shape, ur.dtype),
    ]
    return pl.pallas_call(
        functools.partial(_phase_apply_kernel, gamma=gamma),
        grid=grid,
        in_specs=[u_spec, u_spec, p_spec],
        out_specs=[u_spec, u_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(ur, ui, phi[None])


# ------------------------------------------------- fused phase + TF multiply
def _phase_tf_apply_kernel(xr_ref, xi_ref, th_ref, amp_ref, or_ref, oi_ref):
    xr, xi = xr_ref[...], xi_ref[...]
    th = th_ref[0]
    amp = amp_ref[0]
    c = jnp.cos(th) * amp
    s = jnp.sin(th) * amp
    or_ref[...] = xr * c - xi * s
    oi_ref[...] = xr * s + xi * c


def phase_tf_apply_pallas(xr, xi, theta, amp, *, nb: int, bh: int, bw: int,
                          interpret: bool):
    """x: (P*nb, H, W) split planes; theta/amp: (P, H, W) real planes.

    Computes x * amp * exp(j theta) — the cos/sin phase rotation and the
    amplitude-weighted complex multiply in one VMEM pass.  Plane p applies
    to the contiguous batch slab x[p*nb:(p+1)*nb]; the propagation engine
    uses this for both the trainable phase-modulation planes (theta=phi,
    amp=gamma) and the cached spectral transfer functions (theta=arg H,
    amp=|H| — the band-limit mask and evanescent decay fold into amp).
    """
    PB, H, W = xr.shape
    grid = (PB, H // bh, W // bw)
    x_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b, i, j))
    p_spec = pl.BlockSpec((1, bh, bw), lambda b, i, j: (b // nb, i, j))
    out_shape = [
        jax.ShapeDtypeStruct(xr.shape, xr.dtype),
        jax.ShapeDtypeStruct(xr.shape, xr.dtype),
    ]
    return pl.pallas_call(
        _phase_tf_apply_kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, p_spec, p_spec],
        out_specs=[x_spec, x_spec],
        out_shape=out_shape,
        interpret=interpret,
    )(xr, xi, theta, amp)
