"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp


def complex_mul_ref(ar, ai, br, bi):
    """(a_r + j a_i) * (b_r + j b_i), split-plane complex multiply.

    b broadcasts against a (e.g. a: (B, H, W), b: (H, W)).
    """
    return ar * br - ai * bi, ar * bi + ai * br


def phase_apply_ref(ur, ui, phi, gamma=1.0):
    """gamma * u * exp(j phi): the paper's phase-modulation hot spot (Eq. 9)."""
    c = jnp.cos(phi) * gamma
    s = jnp.sin(phi) * gamma
    return ur * c - ui * s, ur * s + ui * c


def phase_tf_apply_ref(xr, xi, theta, amp):
    """x * amp * exp(j theta): fused phase rotation + amplitude multiply.

    theta/amp broadcast against x (e.g. x: (B, H, W), theta/amp: (H, W)).
    Covers both propagation-engine call sites: trainable phase planes
    (theta=phi, amp=gamma) and spectral transfer functions (theta=arg H,
    amp=|H|, which absorbs band-limit masks and evanescent decay).
    """
    c = jnp.cos(theta) * amp
    s = jnp.sin(theta) * amp
    return xr * c - xi * s, xr * s + xi * c


def intensity_readout_ref(ur, ui, masks):
    """|u|^2 pooled per detector region: (B,H,W)x(C,H,W) -> (B,C)."""
    inten = ur * ur + ui * ui
    return jnp.einsum("bhw,chw->bc", inten, masks)


def rope_ref(x, cos, sin):
    """Rotate-half RoPE: x (B, S, D), cos/sin (S, D//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
