"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax.numpy as jnp


def complex_mul_ref(ar, ai, br, bi):
    """(a_r + j a_i) * (b_r + j b_i), split-plane complex multiply.

    b broadcasts against a (e.g. a: (B, H, W), b: (H, W)).
    """
    return ar * br - ai * bi, ar * bi + ai * br


def phase_apply_ref(ur, ui, phi, gamma=1.0):
    """gamma * u * exp(j phi): the paper's phase-modulation hot spot (Eq. 9)."""
    c = jnp.cos(phi) * gamma
    s = jnp.sin(phi) * gamma
    return ur * c - ui * s, ur * s + ui * c


def phase_tf_apply_ref(xr, xi, theta, amp):
    """x * amp * exp(j theta): fused phase rotation + amplitude multiply.

    theta/amp broadcast against x (e.g. x: (B, H, W), theta/amp: (H, W)).
    Covers both propagation-engine call sites: trainable phase planes
    (theta=phi, amp=gamma) and spectral transfer functions (theta=arg H,
    amp=|H|, which absorbs band-limit masks and evanescent decay).
    """
    c = jnp.cos(theta) * amp
    s = jnp.sin(theta) * amp
    return xr * c - xi * s, xr * s + xi * c


def fused_spectral_hop_ref(x, theta_h, amp_h, theta_m, amp_m):
    """One propagation hop + modulation: M . ifft2(Hc . fft2(x)).

    x: complex (..., H, W); planes broadcast against x.  Hc = amp_h *
    exp(j theta_h) is the (band-limited) spectral transfer function, M =
    amp_m * exp(j theta_m) the modulation plane (gamma/codesign folded
    into amp_m).  This is the unfused four-op hop the Pallas kernel
    (`ops.fused_spectral_hop`) collapses to two FFTs + two fused passes.
    """
    hc = amp_h * jnp.exp(1j * theta_h.astype(jnp.complex64))
    m = amp_m * jnp.exp(1j * theta_m.astype(jnp.complex64))
    return m * jnp.fft.ifft2(hc * jnp.fft.fft2(x))


def intensity_readout_ref(ur, ui, masks):
    """|u|^2 pooled per detector region: (B,H,W)x(C,H,W) -> (B,C)."""
    inten = ur * ur + ui * ui
    return jnp.einsum("bhw,chw->bc", inten, masks)


def rope_ref(x, cos, sin):
    """Rotate-half RoPE: x (B, S, D), cos/sin (S, D//2)."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
