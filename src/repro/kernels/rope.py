"""Pallas kernel: rotary position embedding (rotate-half convention).

RoPE is the same computation as the paper's phase-modulation hot spot —
an elementwise complex rotation (DESIGN.md §3) — so it shares this kernel
family.  x is viewed as (x1 + j x2) pairs and rotated by exp(j theta_s,d):

    out1 = x1 cos - x2 sin,  out2 = x2 cos + x1 sin

Fusing the rotation avoids the concat/slice/mul/add chain XLA emits for the
unfused formulation.  Layout: (BN, S, D) with D the lane dim (head_dim, a
multiple of 2; padded to 128 lanes by the wrapper when needed).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_kernel(x_ref, cos_ref, sin_ref, o_ref):
    x = x_ref[0]  # (bs, D)
    c = cos_ref[...]  # (bs, D//2)
    s = sin_ref[...]
    d2 = x.shape[-1] // 2
    x1 = x[:, :d2]
    x2 = x[:, d2:]
    o_ref[0] = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def rope_pallas(x, cos, sin, *, bs: int, interpret: bool):
    """x: (BN, S, D); cos/sin: (S, D//2)."""
    BN, S, D = x.shape
    grid = (BN, S // bs)
    x_spec = pl.BlockSpec((1, bs, D), lambda b, i: (b, i, 0))
    cs_spec = pl.BlockSpec((bs, D // 2), lambda b, i: (i, 0))
    return pl.pallas_call(
        _rope_kernel,
        grid=grid,
        in_specs=[x_spec, cs_spec, cs_spec],
        out_specs=x_spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, cos, sin)
