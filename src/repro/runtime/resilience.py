"""Serving resilience layer: serialized artifacts + engine supervision.

The deployment engine (``repro.runtime.inference``) made frozen DONNs fast;
this module makes them *survivable*.  Real deployments face process crashes,
node swaps and reconfigurable hardware that is reprogrammed in the field
(arXiv 2411.05748), so a served model must outlive the process that froze
it:

1.  **Serialized frozen artifacts** — ``save_deployed(deployed, dir)``
    persists everything serving needs: the architecture as a JSON spec
    (``dsl.to_spec``), the precomputed modulation planes and the resolved
    laser source field through the integrity-checked ``checkpoint.store``
    (atomic commit, per-chunk crc32).  ``load_deployed(dir)`` cold-starts a
    ``DeployedDONN`` from disk with **no training state** — no params
    pytree, no optimizer, no codesign resolution — and bit-identical
    outputs to the original ``freeze()`` (tests/test_resilience.py).

2.  **Typed serving failures** — ``OverloadedError`` (bounded admission
    queue full: load is shed instead of queued unboundedly) and
    ``DeadlineExceededError`` (a request's ``timeout_ms`` expired before
    dispatch), raised by the hardened ``MicroBatcher``.

3.  **Engine supervision** — ``EngineSupervisor`` owns an engine built
    from a serialized artifact, health-checks it with probe requests,
    restarts it from the artifact when it fails (bounded restart budget)
    and exposes readiness + error-rate stats for load balancers.

Fault scenarios are driven end-to-end by ``repro.testing.faults`` and
measured by ``benchmarks/bench_resilience.py``.
"""
from __future__ import annotations

import json
import os
import pathlib
import random
import threading
import time
from typing import Optional, Sequence

import numpy as np

# Format history:
#   1 — f32 modulation plane pairs only (PR 7).
#   2 — adds "plane_dtype" (float32 | bfloat16 | int8 frozen-plane storage;
#       int8 planes are 4-tuples with per-layer scales) and "rfft_first"
#       (half-spectrum real entry hop).  Format-1 artifacts still load
#       (their planes are implicitly float32 pairs); unknown formats are
#       rejected before any deserialization.
ARTIFACT_FORMAT = 2
KNOWN_FORMATS = (1, 2)
ARTIFACT_FILE = "ARTIFACT.json"
PLANES_DIR = "planes"


class OverloadedError(RuntimeError):
    """Admission queue full: the request was shed, not enqueued."""


class DeadlineExceededError(TimeoutError):
    """The request's deadline expired before it could be dispatched."""


class DrainingError(RuntimeError):
    """The router is draining (or swapping): no new requests are admitted.

    In-flight and queued requests are still flushed — only *new* admissions
    are refused, so callers can retry on another fleet or after the swap.
    """


class RetriesExhaustedError(RuntimeError):
    """A request failed on every retry its budget allowed.

    Raised into the request's own future only — neighbors that shared a
    failed dispatch group are re-dispatched and served normally.
    """


# --------------------------------------------------------------------------
# Serialized frozen artifacts
# --------------------------------------------------------------------------
def save_deployed(deployed, artifact_dir) -> pathlib.Path:
    """Persist a ``DeployedDONN`` as a cold-startable serving artifact.

    Layout::

        artifact_dir/
          ARTIFACT.json   # format version, family, dsl.to_spec(cfg)
          planes/         # checkpoint.store tree: modulation planes + source

    The modulation planes ride the checkpoint store's atomic-commit +
    crc32 protocol, so a torn write or bit-rot is detected at load time
    rather than silently serving a corrupted model.  ``ARTIFACT.json`` is
    committed last via tmp+rename: a directory with a manifest is a
    complete artifact.
    """
    from repro.checkpoint import store
    from repro.core import dsl

    artifact_dir = pathlib.Path(artifact_dir)
    artifact_dir.mkdir(parents=True, exist_ok=True)
    frozen = deployed.frozen
    meta = {
        "format": ARTIFACT_FORMAT,
        "family": deployed.family,
        # None for uniform plans (one plane tuple); segment count for
        # segmented plans (tuple of tuples) — fixes the restore treedef
        "segments": len(frozen) if deployed.heterogeneous else None,
        "plane_dtype": deployed.plane_dtype,
        "rfft_first": deployed.rfft_first,
        "spec": dsl.to_spec(deployed.cfg),
    }
    store.save(artifact_dir / PLANES_DIR, 0,
               {"frozen": frozen, "source": deployed.source}, keep=1)
    tmp = artifact_dir / (ARTIFACT_FILE + ".tmp")
    with open(tmp, "w") as f:
        json.dump(meta, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, artifact_dir / ARTIFACT_FILE)
    return artifact_dir


def load_deployed(artifact_dir, *, verify: bool = True):
    """Cold-start a ``DeployedDONN`` from a serialized artifact.

    Rebuilds the architecture from the JSON spec (``dsl.from_spec`` — the
    same validated path config-file builds use) and restores the frozen
    modulation planes + source field from the checkpoint store (crc32
    verified by default).  No trained params, optimizer state or codesign
    resolution is touched: the artifact alone is the deployment.  Outputs
    are bit-identical to the ``DeployedDONN`` that was saved.
    """
    from repro.checkpoint import store
    from repro.core import dsl
    from repro.runtime import inference as inf

    artifact_dir = pathlib.Path(artifact_dir)
    meta_path = artifact_dir / ARTIFACT_FILE
    if not meta_path.exists():
        raise FileNotFoundError(f"no {ARTIFACT_FILE} under {artifact_dir}")
    meta = json.loads(meta_path.read_text())
    if meta.get("format") not in KNOWN_FORMATS:
        raise ValueError(
            f"unsupported artifact format {meta.get('format')!r} "
            f"(this build reads formats {KNOWN_FORMATS})"
        )
    model, _cfg = dsl.from_spec(meta["spec"])
    nseg = meta.get("segments")
    # restore target fixes the *treedef* only (leaf dtypes/shapes come
    # from the store manifest): 2 leaves per plane tuple for f32/bf16
    # storage, 4 for int8 (quantized planes + per-layer scales).
    # Format-1 artifacts predate plane_dtype and are always f32 pairs.
    plane_dtype = meta.get("plane_dtype", "float32")
    tup = (0.0, 0.0, 0.0, 0.0) if plane_dtype == "int8" else (0.0, 0.0)
    target = {
        "frozen": tup if nseg is None else tuple(tup for _ in range(nseg)),
        "source": 0.0,
    }
    state = store.restore(artifact_dir / PLANES_DIR, 0, target, verify=verify)
    return inf.deployed_from_model(model, state["frozen"],
                                   source=state["source"],
                                   rfft_first=bool(meta.get("rfft_first",
                                                            False)))


def validate_artifact(artifact_dir) -> dict:
    """Pre-deployment artifact check: metadata + architecture, no planes.

    Validates everything that can fail *before* warmup commits compile
    time — the manifest exists and parses, the format version is one this
    build reads, the family is known, the architecture spec round-trips
    through the validated DSL path (``dsl.spec_to_config`` +
    ``physics.validate_config``, the same checks a build would run) and
    the plane store has a restorable step.  Raises ``FileNotFoundError`` /
    ``ValueError`` (incl. ``PhysicsValidationError``) naming the problem;
    returns the parsed metadata on success.  The frozen planes themselves
    are *not* deserialized — crc32 verification stays a load-time check.
    """
    from repro import checkpoint as ckpt
    from repro.core import dsl, physics

    artifact_dir = pathlib.Path(artifact_dir)
    meta_path = artifact_dir / ARTIFACT_FILE
    if not meta_path.exists():
        raise FileNotFoundError(
            f"no {ARTIFACT_FILE} under {artifact_dir} — not a serving "
            "artifact (or an interrupted save: the manifest commits last)"
        )
    try:
        meta = json.loads(meta_path.read_text())
    except ValueError as e:
        raise ValueError(f"unparseable {ARTIFACT_FILE}: {e}") from e
    if meta.get("format") not in KNOWN_FORMATS:
        raise ValueError(
            f"unsupported artifact format {meta.get('format')!r} "
            f"(this build reads formats {KNOWN_FORMATS})"
        )
    if meta.get("family") not in ("cls", "multi", "seg"):
        raise ValueError(f"unknown model family {meta.get('family')!r}")
    if meta.get("plane_dtype", "float32") not in ("float32", "bfloat16",
                                                  "int8"):
        raise ValueError(
            f"unknown plane_dtype {meta.get('plane_dtype')!r}"
        )
    spec = meta.get("spec")
    if not isinstance(spec, dict):
        raise ValueError(f"artifact spec missing/malformed in {meta_path}")
    try:
        cfg = dsl.spec_to_config(spec)
    except (KeyError, TypeError, ValueError) as e:
        raise ValueError(f"architecture spec does not assemble: {e!r}") from e
    errors = [v for v in physics.validate_config(cfg)
              if v.severity == physics.ERROR]
    if errors:
        raise physics.PhysicsValidationError(errors)
    if ckpt.latest_step(artifact_dir / PLANES_DIR) is None:
        raise ValueError(
            f"no restorable plane store under {artifact_dir / PLANES_DIR} "
            "(missing or damaged checkpoint manifests)"
        )
    return meta


# --------------------------------------------------------------------------
# Engine supervision
# --------------------------------------------------------------------------
class EngineSupervisor:
    """Owns a serving engine; health-checks, restarts, reports.

    Built around a *serialized artifact* rather than a live model: a
    crashed engine is recovered by reloading the artifact from disk
    (``load_deployed`` + fresh ``InferenceEngine`` + warmup), exactly the
    path a cold-started replacement process would take — so a supervisor
    restart proves the artifact is sufficient to serve.

    - ``infer(x)`` proxies to the engine; on failure it records the error,
      restarts from the artifact (bounded by ``max_restarts``) and retries
      the request once on the fresh engine.
    - ``health_check()`` pushes a probe batch through the engine and
      updates readiness without touching request stats.
    - ``stats()`` exposes ``ready``, ``restarts``, ``requests``,
      ``errors``, ``error_rate`` and the per-attempt ``restart_history``
      (attempt number + backoff slept) for balancers / dashboards.

    Restarts back off **exponentially with jitter** instead of retrying
    in a tight loop: attempt k sleeps
    ``min(backoff_base_ms * 2**(k-1), backoff_max_ms)`` scaled by a
    uniform ``[1, 1+backoff_jitter]`` factor, so a fleet of supervisors
    recovering from a shared fault (a bad node, a torn artifact push)
    doesn't hammer the artifact store in lockstep.  ``backoff_base_ms=0``
    restores immediate restarts (tests).

    ``engine_factory(deployed) -> engine`` customizes engine construction
    (extra buckets, multi-device dispatch, or fault injection in tests).
    """

    def __init__(self, artifact_dir, *, buckets: Optional[Sequence[int]] = None,
                 engine_factory=None, max_restarts: int = 3,
                 warmup_buckets: Optional[Sequence[int]] = None,
                 verify: bool = True, backoff_base_ms: float = 50.0,
                 backoff_max_ms: float = 2000.0,
                 backoff_jitter: float = 0.25, seed: Optional[int] = None):
        self.artifact_dir = pathlib.Path(artifact_dir)
        self.buckets = buckets
        self.engine_factory = engine_factory
        self.max_restarts = int(max_restarts)
        self.warmup_buckets = warmup_buckets
        self.verify = verify
        self.backoff_base_ms = float(backoff_base_ms)
        self.backoff_max_ms = float(backoff_max_ms)
        self.backoff_jitter = float(backoff_jitter)
        self._rng = random.Random(seed)
        self.engine = None
        self._ready = False
        self._lock = threading.Lock()
        self._stats = {"requests": 0, "errors": 0, "restarts": 0,
                       "last_start_s": None, "restart_history": []}

    # --- lifecycle ---
    def _build_engine(self):
        from repro.runtime.inference import DEFAULT_BUCKETS, InferenceEngine

        deployed = load_deployed(self.artifact_dir, verify=self.verify)
        if self.engine_factory is not None:
            engine = self.engine_factory(deployed)
        else:
            engine = InferenceEngine(
                deployed, buckets=self.buckets or DEFAULT_BUCKETS
            )
        if hasattr(engine, "warmup"):
            engine.warmup(self.warmup_buckets)
        return engine

    def start(self):
        """Cold-start the engine from the artifact (idempotent)."""
        with self._lock:
            if self.engine is None:
                t0 = time.perf_counter()
                self.engine = self._build_engine()
                self._stats["last_start_s"] = time.perf_counter() - t0
                self._ready = True
        return self

    def restart_backoff_s(self, attempt: int) -> float:
        """Backoff before restart ``attempt`` (1-indexed): exp + jitter."""
        if self.backoff_base_ms <= 0:
            return 0.0
        base = min(self.backoff_base_ms * 2.0 ** (attempt - 1),
                   self.backoff_max_ms)
        return base * (1.0 + self.backoff_jitter * self._rng.random()) / 1e3

    def restart(self):
        """Tear down the engine and rebuild it from the artifact.

        Each attempt sleeps its exponential backoff first (see the class
        docstring) and is recorded in ``stats()["restart_history"]``.
        """
        with self._lock:
            if self._stats["restarts"] >= self.max_restarts:
                self._ready = False
                raise RuntimeError(
                    f"engine restart budget exhausted "
                    f"({self.max_restarts} restarts)"
                )
            self._stats["restarts"] += 1
            attempt = self._stats["restarts"]
            self._ready = False
            backoff_s = self.restart_backoff_s(attempt)
            if backoff_s > 0:
                time.sleep(backoff_s)
            t0 = time.perf_counter()
            self.engine = self._build_engine()
            self._stats["last_start_s"] = time.perf_counter() - t0
            self._stats["restart_history"].append(
                {"attempt": attempt, "backoff_s": round(backoff_s, 4),
                 "rebuild_s": round(self._stats["last_start_s"], 4)}
            )
            self._ready = True
        return self

    # --- serving ---
    def infer(self, x) -> np.ndarray:
        """Serve through the engine; restart from the artifact on failure.

        The failed request is retried once on the restarted engine; a
        second failure (or an exhausted restart budget) propagates to the
        caller with the supervisor marked not-ready.
        """
        if self.engine is None:
            self.start()
        self._stats["requests"] += 1
        try:
            return self.engine.infer(x)
        except Exception:
            self._stats["errors"] += 1
            self._ready = False
            self.restart()  # raises when the budget is exhausted
            try:
                return self.engine.infer(x)
            except Exception:
                self._stats["errors"] += 1
                self._ready = False
                raise

    def health_check(self) -> bool:
        """Probe the engine with a zero batch; update + return readiness."""
        if self.engine is None:
            return False
        try:
            probe = self.engine._example(self.engine.buckets[0])
            self.engine.infer(probe)
            self._ready = True
        except Exception:
            self._ready = False
        return self._ready

    # --- introspection ---
    @property
    def ready(self) -> bool:
        return self._ready and self.engine is not None

    def stats(self) -> dict:
        s = dict(self._stats)
        s["restart_history"] = list(s["restart_history"])
        s["ready"] = self.ready
        s["error_rate"] = s["errors"] / max(s["requests"], 1)
        return s
