"""Pencil-decomposed distributed 2-D FFT (beyond-paper DONN parallelism).

The paper's emulation engine is single-device (multi-GPU is future work,
§6).  For optical fields too large for one chip (e.g. 500^2+ at large
batch), we shard field ROWS over the "model" axis and implement FFT2 as:

    FFT along W (local)  ->  all-to-all row/col transpose
    -> FFT along H (local)  ->  all-to-all transpose back

which is the classic pencil/slab decomposition used by distributed FFT
libraries, expressed with jax.shard_map + lax.all_to_all.  Each FFT2 moves
2 x (field bytes) x (k-1)/k over the interconnect.

Validated against jnp.fft.fft2 in tests/test_pencil_fft.py.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map


def _local_fft2(x, *, axis: str, k: int, inverse: bool):
    fft = jnp.fft.ifft if inverse else jnp.fft.fft
    B, h, W = x.shape
    x = fft(x, axis=-1)  # along W (full locally)
    x = x.reshape(B, h, k, W // k)
    x = jax.lax.all_to_all(x, axis, split_axis=2, concat_axis=1, tiled=True)
    x = x[:, :, 0, :]  # (B, H, W/k): rows gathered, cols sharded
    x = fft(x, axis=1)  # along H (full locally)
    B2, H, Wk = x.shape
    x = x.reshape(B2, k, H // k, Wk)
    x = jax.lax.all_to_all(x, axis, split_axis=1, concat_axis=3, tiled=True)
    return x[:, 0]  # (B, H/k, W)


def local_spectral_pair(axis: str, k: int):
    """(fft2, ifft2) callables for *in-scan* pencil-decomposed hops.

    Unlike ``pencil_fft2`` (which wraps its own ``shard_map``), these run
    the per-shard body directly, for use *inside* an enclosing ``shard_map``
    whose fields are row-sharded ``(B, H/k, W)`` over mesh axis ``axis`` —
    e.g. as the ``spectral=`` override of ``PropagationPlan.forward`` /
    ``apply``, which puts the distributed FFT inside the fused layer scan
    (the sharded training path, ``repro.runtime.donn_steps.
    compile_donn_train_step_spatial``).  Both return row-sharded spectra /
    fields in the same layout, so the spectral TF multiply works on the
    matching row shard of the transfer planes with no extra communication.
    """
    return (partial(_local_fft2, axis=axis, k=k, inverse=False),
            partial(_local_fft2, axis=axis, k=k, inverse=True))


def pencil_fft2(u, mesh: Mesh, axis: str = "model", inverse: bool = False):
    """FFT2 of u (B, H, W) with H sharded over ``axis`` on ``mesh``."""
    k = mesh.shape[axis]
    spec = P(None, axis, None)
    fn = shard_map(
        partial(_local_fft2, axis=axis, k=k, inverse=inverse),
        mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False,
    )
    return fn(u)


def pencil_ifft2(u, mesh: Mesh, axis: str = "model"):
    return pencil_fft2(u, mesh, axis, inverse=True)


def propagate_tf_distributed(u, h_tf, mesh: Mesh, axis: str = "model"):
    """Row-sharded angular-spectrum propagation: iFFT2(FFT2(u) * H).

    The transfer function multiply is elementwise, so it runs on the
    row-sharded spectrum without any extra communication.
    """
    spec = pencil_fft2(u, mesh, axis)
    spec = spec * h_tf
    return pencil_ifft2(spec, mesh, axis)
