"""Pencil-decomposed distributed 2-D FFT (beyond-paper DONN parallelism).

The paper's emulation engine is single-device (multi-GPU is future work,
§6).  For optical fields too large for one chip (e.g. 500^2+ at large
batch), we shard field ROWS over the "model" axis and implement FFT2 as:

    FFT along W (local)  ->  all-to-all row/col transpose
    -> FFT along H (local)  ->  all-to-all transpose back

which is the classic pencil/slab decomposition used by distributed FFT
libraries, expressed with jax.shard_map + lax.all_to_all.  Each FFT2 moves
2 x (field bytes) x (k-1)/k over the interconnect.

The supported entry point is :func:`local_spectral_pair` — the composed
*in-scan* form fed to ``PropagationPlan.forward/apply`` as ``spectral=``
inside an enclosing ``shard_map`` (see ``donn_steps.make_donn_sharded_
loss`` and ``InferenceEngine(model_devices=...)``).  The standalone
``pencil_fft2`` wrapper is deprecated: one shard_map per FFT call can
never fuse with the modulation between hops.

Validated against jnp.fft.fft2 in tests/test_pencil_fft.py.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.compat import shard_map
from repro.runtime import sharding as shd


def _local_fft2(x, *, axis: str, k: int, inverse: bool):
    """Per-shard pencil FFT2 over the trailing (H/k, W) axes.

    Any number of leading dims (batch, channel, candidate stacks) ride
    along untouched — the all-to-all transposes address the trailing
    axes positionally, so (B, H/k, W) and (B, C, H/k, W) share one body.
    """
    fft = jnp.fft.ifft if inverse else jnp.fft.fft
    lead = x.ndim - 2  # dims left of (rows, W)
    h, W = x.shape[-2], x.shape[-1]
    x = fft(x, axis=-1)  # along W (full locally)
    x = x.reshape(x.shape[:-1] + (k, W // k))
    x = jax.lax.all_to_all(x, axis, split_axis=lead + 1,
                           concat_axis=lead, tiled=True)
    x = x[..., 0, :]  # (..., H, W/k): rows gathered, cols sharded
    x = fft(x, axis=-2)  # along H (full locally)
    H = x.shape[-2]
    x = x.reshape(x.shape[:-2] + (k, H // k, x.shape[-1]))
    x = jax.lax.all_to_all(x, axis, split_axis=lead,
                           concat_axis=lead + 2, tiled=True)
    return x[..., 0, :, :]  # (..., H/k, W)


def local_spectral_pair(axis: str, k: int):
    """(fft2, ifft2) callables for *in-scan* pencil-decomposed hops.

    Unlike ``pencil_fft2`` (which wraps its own ``shard_map``), these run
    the per-shard body directly, for use *inside* an enclosing ``shard_map``
    whose fields are row-sharded ``(..., H/k, W)`` over mesh axis ``axis``
    — e.g. as the ``spectral=`` override of ``PropagationPlan.forward`` /
    ``apply``, which puts the distributed FFT inside the fused layer scan
    (the sharded training path, ``repro.runtime.donn_steps.
    make_donn_sharded_loss``).  Both return row-sharded spectra / fields
    in the same layout, so the spectral TF multiply works on the matching
    row shard of the transfer planes with no extra communication.
    """
    return (partial(_local_fft2, axis=axis, k=k, inverse=False),
            partial(_local_fft2, axis=axis, k=k, inverse=True))


def _row_spec(axis: str):
    # (B, H, W) with H over `axis`, via the one rules table (LR109)
    return shd.rules_pspec((None, "field_h", None), {"field_h": axis})


def pencil_fft2(u, mesh: Mesh, axis: str = "model", inverse: bool = False):
    """DEPRECATED standalone FFT2 of u (B, H, W) with H sharded over ``axis``.

    One shard_map per FFT call cannot fuse with the inter-hop modulation;
    compose :func:`local_spectral_pair` into an enclosing ``shard_map``
    (the ``spectral=`` plan override) instead.  Kept one deprecation
    cycle for external callers.
    """
    warnings.warn(
        "pencil_fft2/pencil_ifft2 are deprecated: pass "
        "local_spectral_pair(axis, k) as the plan's spectral= override "
        "inside your own shard_map (see donn_steps.make_donn_sharded_loss)",
        DeprecationWarning, stacklevel=2,
    )
    k = mesh.shape[axis]
    spec = _row_spec(axis)
    fn = shard_map(
        partial(_local_fft2, axis=axis, k=k, inverse=inverse),
        mesh=mesh, in_specs=spec, out_specs=spec, check_vma=False,
    )
    return fn(u)


def pencil_ifft2(u, mesh: Mesh, axis: str = "model"):
    return pencil_fft2(u, mesh, axis, inverse=True)


def propagate_tf_distributed(u, h_tf, mesh: Mesh, axis: str = "model"):
    """Row-sharded angular-spectrum propagation: iFFT2(FFT2(u) * H).

    The transfer function multiply is elementwise, so it runs on the
    row-sharded spectrum without any extra communication — one composed
    shard_map around the whole hop (FFT2 -> multiply -> iFFT2), not one
    per FFT.
    """
    k = mesh.shape[axis]
    fft2, ifft2 = local_spectral_pair(axis, k)

    def hop(u_loc, h_loc):
        return ifft2(fft2(u_loc) * h_loc)

    spec = _row_spec(axis)
    h_spec = shd.rules_pspec(
        ("field_h", None), {"field_h": axis}
    ) if h_tf.ndim == 2 else spec
    fn = shard_map(hop, mesh=mesh, in_specs=(spec, h_spec),
                   out_specs=spec, check_vma=False)
    return fn(u, h_tf)
