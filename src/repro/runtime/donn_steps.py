"""pjit train step for the paper's DONN workloads (beyond-paper distribution).

The paper trains on a single GPU (multi-GPU is named as future work, §6);
here DONN training runs on the one 2-D ``(data, model)`` mesh
(``sharding.make_mesh_2d`` + the ``sharding.donn_rules`` logical-axis
table): the batch shards over ``data``, field rows (``field_h``) shard
over ``model`` with the pencil-decomposed FFT inside the fused layer
scan, and both compose — spatial x data-parallel gradients through one
``shard_map`` (``make_donn_sharded_loss`` /
``compile_donn_train_step_sharded``, every model family including
heterogeneous ``SegmentedPlan`` stacks).

Heterogeneous per-layer architectures (``DONNConfig.layers``) ride the
same steps unchanged: the phase params form a *ragged* pytree (one
(n_i, n_i) leaf per layer, shapes varying across segments), and every
state/sharding transform here is a ``jax.tree`` map over ParamSpec
leaves, so per-layer plane sizes need no special casing
(tests/test_hetero.py::TestHeterogeneousForward::test_train_step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import DONNConfig
from repro.core.models import cached_model
from repro.core.train_utils import bce_segmentation_loss, mse_softmax_loss
from repro.nn import ParamSpec, is_spec
from repro.optim import AdamW
from repro.optim.adamw import AdamWState
from repro.runtime import sharding as shd

DONN_RULES = {**shd.DEFAULT_RULES, "batch": ("pod", "data", "model")}


def donn_state_specs(cfg: DONNConfig):
    model = cached_model(cfg)
    pspecs = model.param_specs()

    def opt_spec(s):
        return ParamSpec(s.shape, jnp.float32, s.logical_axes, init="zeros")

    return {
        "params": pspecs,
        "mu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "nu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }


def make_donn_train_step(cfg: DONNConfig, optimizer: AdamW):
    model = cached_model(cfg)

    def loss_fn(params, batch):
        if cfg.segmentation:
            inten = model.apply(params, batch["images"], train=True)
            return bce_segmentation_loss(inten, batch["masks"])
        logits = model.apply(params, batch["images"])
        return mse_softmax_loss(logits, batch["labels"], cfg.num_classes)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    return step


def make_donn_train_chunk(cfg: DONNConfig, optimizer: AdamW = None):
    """Multi-step scanned driver over a stacked batch chunk.

    Returns ``chunk(state, batches) -> (state, {"loss": (S,)})`` running
    one optimizer step per leading row of ``batches`` (every leaf carries
    a leading chunk axis, see ``repro.data.pipeline.stack_batches``) as a
    single ``lax.scan`` — epochs, not forwards, become the unit of
    compiled work.  Covers every ``make_donn_train_step`` workload
    (classification and segmentation, any engine/codesign config).  Wrap
    in ``jax.jit(..., donate_argnums=(0,))`` — or use
    ``compile_donn_train_chunk`` — so the state is donated and per-step
    losses come back as one device-resident (S,) array (one host sync per
    chunk).
    """
    optimizer = optimizer or AdamW(lr=0.01)
    return _chunk_over(make_donn_train_step(cfg, optimizer))


def _chunk_over(step):
    """Lift a ``step(state, batch)`` fn to a scan over a stacked chunk."""

    def chunk(state, batches):
        def body(st, b):
            st, metrics = step(st, b)
            return st, metrics["loss"]

        state, losses = jax.lax.scan(body, state, batches)
        return state, {"loss": losses}

    return chunk


def _batch_shardings(cfg: DONNConfig, mesh, rules, global_batch=None):
    """Per-workload batch shardings (dim 0 over the DP axes)."""
    bs = lambda ndim: shd.batch_sharding(mesh, ndim, rules,
                                         batch_size=global_batch)
    if cfg.segmentation:
        return {"images": bs(3), "masks": bs(3)}
    if cfg.channels > 1:
        return {"images": bs(4), "labels": bs(1)}
    return {"images": bs(3), "labels": bs(1)}


def compile_donn_train_chunk(cfg: DONNConfig, mesh, optimizer=None,
                             donate: bool = True,
                             global_batch: int | None = None):
    """Compiled chunked training: scan ``S`` donated steps per device call.

    The chunked sibling of ``compile_donn_train_step``: batches arrive
    stacked ``(S, B, ...)`` (batch axis data-parallel over the mesh, chunk
    axis unsharded), (params, opt buffers, step) are donated so chunk k+1
    reuses chunk k's state allocations, and the per-step losses return as
    one (S,) array.  Returns ``(fn, state_shardings, batch_shardings,
    state_specs)`` like its sibling.
    """
    from jax.sharding import NamedSharding

    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, DONN_RULES)
    b_shard = _batch_shardings(cfg, mesh, DONN_RULES, global_batch)
    # shift the batch sharding right of the leading (unsharded) chunk axis
    b_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, shd.with_leading(s.spec)), b_shard
    )
    chunk = make_donn_train_chunk(cfg, optimizer)

    def run(state, batches):
        # activation constraints (SegmentedPlan stitch carries stay
        # batch-sharded) resolve against this mesh at trace time
        with shd.activation_sharding(mesh, DONN_RULES):
            return chunk(state, batches)

    fn = jax.jit(
        run,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": shd.scalar_sharding(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs


def compile_donn_train_step_shardmap(cfg: DONNConfig, mesh, optimizer=None,
                                     donate: bool = True,
                                     global_batch: int | None = None):
    """Optimized DONN training: shard_map data parallelism.

    GSPMD cannot partition the FFT HLO even over pure batch dims — the
    auto-sharded (pjit) step all-gathers the whole global field for every
    FFT2/iFFT2 (see EXPERIMENTS.md §Perf).  Under shard_map each device
    runs the *entire* optical forward/backward on its local batch shard
    (local FFTs), and only the (tiny, phase-sized) gradients are psum'd —
    the textbook DP layout for a small-parameter model.
    """
    from repro.compat import shard_map

    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, {})  # params replicated
    dp_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    if global_batch is not None:  # drop axes until the batch divides
        import math as _math

        while dp_axes and global_batch % _math.prod(
            mesh.shape[a] for a in dp_axes
        ) != 0:
            dp_axes = dp_axes[:-1]
        if not dp_axes:
            raise ValueError(f"batch {global_batch} unshardable on {mesh}")

    # hoisted out of the loss closure: shard_map retraces (and fresh meshes)
    # reuse one cached layer stack instead of rebuilding it per trace
    model = cached_model(cfg)

    def local_step(state, batch):
        def loss_fn(params, b):
            if cfg.segmentation:
                inten = model.apply(params, b["images"], train=True)
                return bce_segmentation_loss(inten, b["masks"])
            logits = model.apply(params, b["images"])
            return mse_softmax_loss(logits, b["labels"], cfg.num_classes)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        loss = jax.lax.pmean(loss, dp_axes)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axes), grads)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    batch_spec = shd.dim0_pspec(dp_axes, 1)
    target = "masks" if cfg.segmentation else "labels"
    b_specs = {"images": batch_spec, target: batch_spec}
    state_specs_sm = jax.tree.map(lambda _: shd.replicated_pspec(), sspecs)
    fn = jax.jit(
        shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs_sm, b_specs),
            out_specs=(state_specs_sm, {"loss": shd.replicated_pspec()}),
            check_vma=False,
        ),
        donate_argnums=(0,) if donate else (),
    )
    b_shard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), b_specs
    )
    return fn, s_shard, b_shard, sspecs


def _check_sharded_support(cfg: DONNConfig) -> None:
    """Config gates shared by every spatially-sharded path."""
    resolved = cfg.resolved_layers()
    if cfg.pad or any(l.approximation == "fraunhofer" for l in resolved):
        raise NotImplementedError(
            "spatial sharding needs unpadded angular-spectrum hops"
        )
    if any(l.codesign in ("gumbel", "gumbel_hard") for l in resolved):
        raise NotImplementedError(
            "stochastic codesign draws per-element noise: row shards "
            "would sample different streams than the single-device step"
        )
    if cfg.use_pallas:
        raise NotImplementedError(
            "the fused Pallas kernels operate on full planes"
        )
    if cfg.tf_dtype != "float32":
        raise NotImplementedError(
            "spatial sharding reads the plan's f32 TF planes; the bf16 "
            "storage path would silently diverge from the single-device "
            "reference tolerance"
        )


def _plan_tf_stacks(plan):
    """The plan's baked split TF planes as traced shard_map operands."""
    key_a, key_b = plan._plane_keys
    return jnp.asarray(plan._np[key_a]), jnp.asarray(plan._np[key_b])


def make_donn_sharded_loss(cfg: DONNConfig, mesh, rules=None):
    """Unified spatial x data-parallel loss on the 2-D ``(data, model)`` mesh.

    Returns ``loss_fn(params, batch) -> scalar`` whose optical forward
    runs under ``shard_map`` with the batch sharded over the ``data``
    axis and every plane (field, TF stacks, trainable phases, detector
    masks) row-sharded over the ``model`` axis, each hop of the fused
    layer scan using the pencil-decomposed local FFT
    (``repro.runtime.pencil_fft.local_spectral_pair`` as the plan's
    ``spectral=`` override).  One rules table
    (``sharding.donn_rules``) decides both layouts; either axis may be
    absent from the mesh — batch-only meshes give pure DP, model-only
    meshes the PR-4 spatial layout, and the 2-D mesh composes them
    (spatial x DP gradients: the shard_map transpose psums phase
    cotangents over ``data`` automatically).

    Covers every model family:

    - **classification** (single channel): detector readout psums the
      per-class partial intensities over ``model``;
    - **multi-channel / RGB**: the ``(L, C, N, N)`` phase stack and the
      ``(B, C, N, N)`` field ride the same scan with ``channel``
      replicated (the generalized pencil FFT carries leading dims);
    - **segmentation with optical skip**: the skip hop runs the same
      local spectral pair on its row shard; the intensity map returns
      batch x row sharded, and layer-norm + BCE run outside the
      shard_map in auto (GSPMD) land;
    - **heterogeneous `SegmentedPlan`**: one shard_map per scan segment
      (per-segment specs), the resampling stitches run *between* the
      manual regions where GSPMD reshards them (``constrain`` keeps the
      stitched carry batch-sharded).

    Differentiable: ``jax.value_and_grad`` agrees with the single-device
    loss to rtol <= 1e-5 for all families (tests/test_distributed.py).
    See ``compile_donn_train_step_sharded`` for the compiled step.
    """
    from repro.compat import shard_map
    from repro.core import diffraction as df
    from repro.core import propagation as pp
    from repro.core.laser import data_to_cplex
    from repro.core.train_utils import mse_softmax_loss as _mse

    cfg = cfg.canonical()
    rules = shd.check_rules(dict(rules or shd.donn_rules()))
    _check_sharded_support(cfg)

    model_axis = shd.present_axes(mesh, rules.get("field_h"))
    if model_axis is not None and not isinstance(model_axis, str):
        raise shd.ShardingRulesError(
            f"field_h must map to a single mesh axis for the pencil FFT "
            f"(all_to_all transposes over one named axis), got {model_axis!r}"
        )
    k = int(mesh.shape[model_axis]) if model_axis is not None else 1
    spectral = None
    if k > 1:
        from repro.runtime.pencil_fft import local_spectral_pair

        spectral = local_spectral_pair(model_axis, k)

    model = cached_model(cfg)
    rp = lambda names: shd.rules_pspec(names, rules, mesh)
    plane = rp(("layers", "field_h", "field_w"))  # (L, n/k rows, n) stacks

    def _psum_model(x):
        return jax.lax.psum(x, model_axis) if k > 1 else x

    if cfg.layers is not None:
        # ---- heterogeneous SegmentedPlan: one manual region per scan
        # segment, stitches reshard between them in auto land ----
        if cfg.segmentation or cfg.channels > 1:
            raise NotImplementedError(
                "sharded SegmentedPlan covers the classification family"
            )
        plan = model.plan
        if k > 1:
            for j, seg in enumerate(plan.segments):
                if seg.grid.n % k != 0:
                    raise ValueError(
                        f"segment {j} grid n={seg.grid.n} rows must divide "
                        f"the {k}-way {model_axis!r} axis"
                    )
        seg_tfs = [_plan_tf_stacks(s) for s in plan.segments]
        masks = jnp.asarray(model.detector.masks)
        source = jnp.asarray(model.source)
        in_n, depth = plan.input_grid.n, plan.depth
        u_spec = rp(("batch", "field_h", "field_w"))
        field_axes = ("batch", "field_h", "field_w")

        def make_seg_fn(seg, last):
            def body(phis, a, b, u):
                u = seg.forward(phis, u, None, tfs=(a, b), spectral=spectral)
                if last:
                    u = seg.propagate_final(u, tfs=(a, b), spectral=spectral)
                return u

            return shard_map(body, mesh=mesh,
                             in_specs=(plane, plane, plane, u_spec),
                             out_specs=u_spec, check_vma=False)

        seg_fns = [make_seg_fn(s, j == len(plan.segments) - 1)
                   for j, s in enumerate(plan.segments)]

        def loss_fn(params, batch):
            with shd.activation_sharding(mesh, rules):
                phis = plan.stack_phases(
                    [params["phase"][f"layer_{i}"] for i in range(depth)]
                )
                u = data_to_cplex(batch["images"], in_n) * source
                u = shd.constrain(u, field_axes)
                cur = plan.input_grid
                for j, seg in enumerate(plan.segments):
                    if seg.grid != cur:
                        u = df.resample_field(u, cur, seg.grid)
                        u = shd.constrain(u, field_axes)
                    a, b = seg_tfs[j]
                    u = seg_fns[j](phis[j], a, b, u)
                    cur = seg.grid
                if plan.det_grid != cur:
                    u = df.resample_field(u, cur, plan.det_grid)
                    u = shd.constrain(u, field_axes)
                logits = jnp.einsum("...hw,chw->...c", df.intensity(u), masks)
                return _mse(logits, batch["labels"], cfg.num_classes)

        return loss_fn

    # ---- uniform stacks: one manual region around the whole forward ----
    if k > 1 and cfg.n % k != 0:
        raise ValueError(f"n={cfg.n} rows must divide the {k}-way "
                         f"{model_axis!r} axis")

    if cfg.segmentation:
        plan = model.plan
        tf_a, tf_b = _plan_tf_stacks(plan)
        source = jnp.asarray(model.source)
        in_n, depth = model.in_grid.n, plan.depth
        u_spec = rp(("batch", "field_h", "field_w"))
        skip_from = cfg.skip_from
        sqrt2 = jnp.sqrt(2.0).astype(jnp.complex64)
        if skip_from is not None:
            gaps = cfg.gap_distances()
            z_skip = float(sum(gaps[skip_from + 1:]))
            planes = pp.transfer_planes(
                model.layers[skip_from].grid, z_skip, cfg.wavelength,
                cfg.resolved_layers()[skip_from].approximation,
                cfg.band_limit, cfg.pad,
            )
            sk_a = jnp.asarray(planes["hr"])
            sk_b = jnp.asarray(planes["hi"])

            def local_map(phis, a, b, sa, sb, u):
                u1 = plan.forward(phis, u, None, stop=skip_from + 1,
                                  tfs=(a, b), spectral=spectral)
                u2 = plan.forward(phis, u1, None, start=skip_from + 1,
                                  tfs=(a, b), spectral=spectral)
                u2 = plan.propagate_final(u2, tfs=(a, b), spectral=spectral)
                sk = plan._hop(u1, (sa, sb), spectral)
                return df.intensity((u2 + sk) / sqrt2)

            row2 = rp(("field_h", "field_w"))
            sharded_map = shard_map(
                local_map, mesh=mesh,
                in_specs=(plane, plane, plane, row2, row2, u_spec),
                out_specs=u_spec, check_vma=False,
            )
            fwd = lambda phis, u0: sharded_map(phis, tf_a, tf_b,
                                               sk_a, sk_b, u0)
        else:

            def local_map(phis, a, b, u):
                u = plan.forward(phis, u, None, tfs=(a, b), spectral=spectral)
                u = plan.propagate_final(u, tfs=(a, b), spectral=spectral)
                return df.intensity(u)

            sharded_map = shard_map(
                local_map, mesh=mesh,
                in_specs=(plane, plane, plane, u_spec),
                out_specs=u_spec, check_vma=False,
            )
            fwd = lambda phis, u0: sharded_map(phis, tf_a, tf_b, u0)

        def loss_fn(params, batch):
            with shd.activation_sharding(mesh, rules):
                phis = jnp.stack(
                    [params["phase"][f"layer_{i}"] for i in range(depth)]
                )
                u0 = data_to_cplex(batch["images"], in_n) * source
                inten = fwd(phis, u0)
                if cfg.layer_norm:  # train=True semantics (the step's loss)
                    mean = jnp.mean(inten, axis=(-2, -1), keepdims=True)
                    var = jnp.var(inten, axis=(-2, -1), keepdims=True)
                    inten = (inten - mean) * jax.lax.rsqrt(var + 1e-6)
                return bce_segmentation_loss(inten, batch["masks"])

        return loss_fn

    # classification: single channel or multi-channel/RGB
    if cfg.channels > 1:
        host = model.channel_model
        phi_spec = rp(("layers", "channel", "field_h", "field_w"))
        u_spec = rp(("batch", "channel", "field_h", "field_w"))
        readout = lambda u, m: jnp.einsum("...dhw,chw->...c",
                                          df.intensity(u), m)
    else:
        host = model
        phi_spec = plane
        u_spec = rp(("batch", "field_h", "field_w"))
        readout = lambda u, m: jnp.einsum("...hw,chw->...c",
                                          df.intensity(u), m)
    plan = host.plan
    tf_a, tf_b = _plan_tf_stacks(plan)
    masks = jnp.asarray(host.detector.masks)
    source = jnp.asarray(host.source)
    in_n, depth = host.in_grid.n, plan.depth
    mask_spec = rp(("classes", "field_h", "field_w"))

    def local_logits(phis, a, b, m, u):
        """Per-shard forward core: all plane operands are local row blocks."""
        u = plan.forward(phis, u, None, tfs=(a, b), spectral=spectral)
        u = plan.propagate_final(u, tfs=(a, b), spectral=spectral)
        return _psum_model(readout(u, m))

    sharded_logits = shard_map(
        local_logits, mesh=mesh,
        in_specs=(phi_spec, plane, plane, mask_spec, u_spec),
        out_specs=rp(("batch", None)),
        check_vma=False,
    )

    def loss_fn(params, batch):
        with shd.activation_sharding(mesh, rules):
            phis = jnp.stack(
                [params["phase"][f"layer_{i}"] for i in range(depth)]
            )
            u0 = data_to_cplex(batch["images"], in_n) * source
            logits = sharded_logits(phis, tf_a, tf_b, masks, u0)
            return _mse(logits, batch["labels"], cfg.num_classes)

    return loss_fn


def make_donn_spatial_loss(cfg: DONNConfig, mesh, axis: str = "model"):
    """Back-compat spatial-only loss: rows over ``axis``, batch replicated.

    Thin wrapper over :func:`make_donn_sharded_loss` with the batch rule
    disabled — the PR-4 layout.  New code should pass a 2-D mesh and the
    full ``sharding.donn_rules`` table instead.
    """
    rules = {**shd.donn_rules(model=axis), "batch": None, "population": None}
    return make_donn_sharded_loss(cfg, mesh, rules=rules)


def compile_donn_train_step_sharded(cfg: DONNConfig, mesh, rules=None,
                                    optimizer=None, donate: bool = True,
                                    steps_per_call: int = 1,
                                    global_batch: int | None = None):
    """Spatial x data-parallel DONN training on the unified 2-D mesh.

    The train-step compiler over :func:`make_donn_sharded_loss`: state
    (phases + optimizer moments) shards by the same rules table — rows
    over ``model``, replicated over ``data`` (each data shard owns the
    full row block; the shard_map transpose psums the batch-shard
    gradient contributions over ``data``) — and the batch shards over
    the DP axes.  For optical planes too large for one chip (n=1024+
    fields, arXiv:2302.10905-scale scientific workloads) this is the
    only runnable training path: no device ever materializes a full
    plane.  ``steps_per_call > 1`` scans a stacked batch chunk per
    device call (state donated).

    Returns ``(fn, state_shardings, batch_shardings, state_specs)``:
    ``fn(state, batch)`` for ``steps_per_call == 1`` (metrics
    ``{"loss": ()}``), ``fn(state, batches)`` with a leading chunk axis
    and ``{"loss": (S,)}`` otherwise.  Validated against the
    single-device step — loss and grads agree to rtol <= 1e-5 for all
    model families (tests/test_distributed.py).
    """
    from jax.sharding import NamedSharding

    optimizer = optimizer or AdamW(lr=0.01)
    rules = shd.check_rules(dict(rules or shd.donn_rules()))
    loss_fn = make_donn_sharded_loss(cfg, mesh, rules=rules)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    if steps_per_call > 1:
        step = _chunk_over(step)

    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, rules)
    b_shard = _batch_shardings(cfg, mesh, rules, global_batch)
    if steps_per_call > 1:
        b_shard = jax.tree.map(
            lambda s: NamedSharding(mesh, shd.with_leading(s.spec)), b_shard
        )
    fn = jax.jit(
        step,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": shd.scalar_sharding(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs


def compile_donn_train_step_spatial(cfg: DONNConfig, mesh, axis: str = "model",
                                    optimizer=None, donate: bool = True,
                                    steps_per_call: int = 1):
    """Back-compat spatial-only compiled step (batch replicated).

    Delegates to :func:`compile_donn_train_step_sharded` with the batch
    rule disabled — the PR-4 single-axis layout.  New code should build
    a ``make_mesh_2d`` mesh and call the sharded compiler directly.
    """
    rules = {**shd.donn_rules(model=axis), "batch": None, "population": None}
    return compile_donn_train_step_sharded(
        cfg, mesh, rules=rules, optimizer=optimizer, donate=donate,
        steps_per_call=steps_per_call,
    )


def compile_donn_train_step(cfg: DONNConfig, mesh, optimizer=None,
                            donate: bool = True,
                            global_batch: int | None = None):
    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, DONN_RULES)
    b_shard = _batch_shardings(cfg, mesh, DONN_RULES, global_batch)
    step = make_donn_train_step(cfg, optimizer)

    def run(state, batch):
        with shd.activation_sharding(mesh, DONN_RULES):
            return step(state, batch)

    fn = jax.jit(
        run,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": shd.scalar_sharding(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs
