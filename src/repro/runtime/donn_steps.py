"""pjit train step for the paper's DONN workloads (beyond-paper distribution).

The paper trains on a single GPU (multi-GPU is named as future work, §6);
here DONN training is data-parallel across the full production mesh — the
batch shards over every mesh axis, phase parameters replicate (they are
tiny: depth x n^2), and gradients all-reduce.  Spatial (field) model-
parallelism via a pencil-decomposed FFT is implemented separately in
`repro.runtime.pencil_fft` and evaluated in the §Perf hillclimb.

Heterogeneous per-layer architectures (``DONNConfig.layers``) ride the
same steps unchanged: the phase params form a *ragged* pytree (one
(n_i, n_i) leaf per layer, shapes varying across segments), and every
state/sharding transform here is a ``jax.tree`` map over ParamSpec
leaves, so per-layer plane sizes need no special casing
(tests/test_hetero.py::TestHeterogeneousForward::test_train_step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import DONNConfig
from repro.core.models import cached_model
from repro.core.train_utils import bce_segmentation_loss, mse_softmax_loss
from repro.nn import ParamSpec, is_spec
from repro.optim import AdamW
from repro.optim.adamw import AdamWState
from repro.runtime import sharding as shd

DONN_RULES = {**shd.DEFAULT_RULES, "batch": ("pod", "data", "model")}


def donn_state_specs(cfg: DONNConfig):
    model = cached_model(cfg)
    pspecs = model.param_specs()

    def opt_spec(s):
        return ParamSpec(s.shape, jnp.float32, s.logical_axes, init="zeros")

    return {
        "params": pspecs,
        "mu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "nu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }


def make_donn_train_step(cfg: DONNConfig, optimizer: AdamW):
    model = cached_model(cfg)

    def loss_fn(params, batch):
        if cfg.segmentation:
            inten = model.apply(params, batch["images"], train=True)
            return bce_segmentation_loss(inten, batch["masks"])
        logits = model.apply(params, batch["images"])
        return mse_softmax_loss(logits, batch["labels"], cfg.num_classes)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    return step


def make_donn_train_chunk(cfg: DONNConfig, optimizer: AdamW = None):
    """Multi-step scanned driver over a stacked batch chunk.

    Returns ``chunk(state, batches) -> (state, {"loss": (S,)})`` running
    one optimizer step per leading row of ``batches`` (every leaf carries
    a leading chunk axis, see ``repro.data.pipeline.stack_batches``) as a
    single ``lax.scan`` — epochs, not forwards, become the unit of
    compiled work.  Covers every ``make_donn_train_step`` workload
    (classification and segmentation, any engine/codesign config).  Wrap
    in ``jax.jit(..., donate_argnums=(0,))`` — or use
    ``compile_donn_train_chunk`` — so the state is donated and per-step
    losses come back as one device-resident (S,) array (one host sync per
    chunk).
    """
    optimizer = optimizer or AdamW(lr=0.01)
    return _chunk_over(make_donn_train_step(cfg, optimizer))


def _chunk_over(step):
    """Lift a ``step(state, batch)`` fn to a scan over a stacked chunk."""

    def chunk(state, batches):
        def body(st, b):
            st, metrics = step(st, b)
            return st, metrics["loss"]

        state, losses = jax.lax.scan(body, state, batches)
        return state, {"loss": losses}

    return chunk


def compile_donn_train_chunk(cfg: DONNConfig, mesh, optimizer=None,
                             donate: bool = True,
                             global_batch: int | None = None):
    """Compiled chunked training: scan ``S`` donated steps per device call.

    The chunked sibling of ``compile_donn_train_step``: batches arrive
    stacked ``(S, B, ...)`` (batch axis data-parallel over the mesh, chunk
    axis unsharded), (params, opt buffers, step) are donated so chunk k+1
    reuses chunk k's state allocations, and the per-step losses return as
    one (S,) array.  Returns ``(fn, state_shardings, batch_shardings,
    state_specs)`` like its sibling.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, DONN_RULES)
    bs = lambda ndim: shd.batch_sharding(mesh, ndim, DONN_RULES,
                                         batch_size=global_batch)
    if cfg.segmentation:
        b_shard = {"images": bs(3), "masks": bs(3)}
    elif cfg.channels > 1:
        b_shard = {"images": bs(4), "labels": bs(1)}
    else:
        b_shard = {"images": bs(3), "labels": bs(1)}
    # shift the batch sharding right of the leading (unsharded) chunk axis
    b_shard = jax.tree.map(
        lambda s: NamedSharding(mesh, P(None, *s.spec)), b_shard
    )
    fn = jax.jit(
        make_donn_train_chunk(cfg, optimizer),
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": shd.scalar_sharding(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs


def compile_donn_train_step_shardmap(cfg: DONNConfig, mesh, optimizer=None,
                                     donate: bool = True,
                                     global_batch: int | None = None):
    """Optimized DONN training: shard_map data parallelism.

    GSPMD cannot partition the FFT HLO even over pure batch dims — the
    auto-sharded (pjit) step all-gathers the whole global field for every
    FFT2/iFFT2 (see EXPERIMENTS.md §Perf).  Under shard_map each device
    runs the *entire* optical forward/backward on its local batch shard
    (local FFTs), and only the (tiny, phase-sized) gradients are psum'd —
    the textbook DP layout for a small-parameter model.
    """

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, {})  # params replicated
    dp_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    if global_batch is not None:  # drop axes until the batch divides
        import math as _math

        while dp_axes and global_batch % _math.prod(
            mesh.shape[a] for a in dp_axes
        ) != 0:
            dp_axes = dp_axes[:-1]
        if not dp_axes:
            raise ValueError(f"batch {global_batch} unshardable on {mesh}")

    # hoisted out of the loss closure: shard_map retraces (and fresh meshes)
    # reuse one cached layer stack instead of rebuilding it per trace
    model = cached_model(cfg)

    def local_step(state, batch):
        def loss_fn(params, b):
            if cfg.segmentation:
                inten = model.apply(params, b["images"], train=True)
                return bce_segmentation_loss(inten, b["masks"])
            logits = model.apply(params, b["images"])
            return mse_softmax_loss(logits, b["labels"], cfg.num_classes)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        loss = jax.lax.pmean(loss, dp_axes)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axes), grads)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    batch_spec = P(dp_axes)
    target = "masks" if cfg.segmentation else "labels"
    b_specs = {"images": batch_spec, target: batch_spec}
    state_specs_sm = jax.tree.map(lambda _: P(), sspecs)
    fn = jax.jit(
        shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs_sm, b_specs),
            out_specs=(state_specs_sm, {"loss": P()}),
            check_vma=False,
        ),
        donate_argnums=(0,) if donate else (),
    )
    b_shard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), b_specs
    )
    return fn, s_shard, b_shard, sspecs


def make_donn_spatial_loss(cfg: DONNConfig, mesh, axis: str = "model"):
    """Row-sharded classification loss with pencil FFT inside the scan.

    Returns ``loss_fn(params, batch) -> scalar`` whose optical forward
    runs under ``shard_map`` with every plane (field, TF stacks, phases,
    detector masks) row-sharded over mesh axis ``axis`` and each hop of
    the fused layer scan using the pencil-decomposed local FFT
    (``repro.runtime.pencil_fft.local_spectral_pair``).  Differentiable:
    ``jax.value_and_grad`` agrees with the single-device loss to
    rtol <= 1e-5 (tests/test_distributed.py) — the grads flow through the
    all-to-all transposes and the detector psum.

    See ``compile_donn_train_step_spatial`` for the supported-config
    gates and the compiled step built on top.
    """
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map
    from repro.core import diffraction as df
    from repro.core.laser import data_to_cplex
    from repro.core.train_utils import mse_softmax_loss as _mse
    from repro.runtime.pencil_fft import local_spectral_pair

    cfg = cfg.canonical()
    if cfg.layers is not None:
        raise NotImplementedError(
            "spatial sharding covers uniform stacks (heterogeneous "
            "segments resample between grids, which does not row-shard)"
        )
    if cfg.segmentation or cfg.channels > 1:
        raise NotImplementedError(
            "spatial sharding covers the classification stack"
        )
    if cfg.pad or cfg.approximation == "fraunhofer":
        raise NotImplementedError(
            "spatial sharding needs unpadded angular-spectrum hops"
        )
    if cfg.codesign in ("gumbel", "gumbel_hard"):
        raise NotImplementedError(
            "stochastic codesign draws per-element noise: row shards "
            "would sample different streams than the single-device step"
        )
    if cfg.use_pallas:
        raise NotImplementedError(
            "the fused Pallas kernels operate on full planes"
        )
    if cfg.tf_dtype != "float32":
        raise NotImplementedError(
            "spatial sharding reads the plan's f32 TF planes; the bf16 "
            "storage path would silently diverge from the single-device "
            "reference tolerance"
        )
    k = int(mesh.shape[axis])
    if cfg.n % k != 0:
        raise ValueError(f"n={cfg.n} rows must divide the {k}-way "
                         f"{axis!r} axis")
    model = cached_model(cfg)
    plan = model.plan
    fft2, ifft2 = local_spectral_pair(axis, k)
    key_a, key_b = plan._plane_keys
    tf_a = jnp.asarray(plan._np[key_a])  # (depth+1, n, n)
    tf_b = jnp.asarray(plan._np[key_b])
    masks = jnp.asarray(model.detector.masks)  # (C, n, n)
    source = jnp.asarray(model.source)
    depth, n = plan.depth, cfg.n

    def local_logits(phis, a, b, m, u):
        """Per-shard forward core: all plane operands are local row blocks."""
        u = plan.forward(phis, u, None, tfs=(a, b), spectral=(fft2, ifft2))
        u = plan.propagate_final(u, tfs=(a, b), spectral=(fft2, ifft2))
        logits = jnp.einsum("...hw,chw->...c", df.intensity(u), m)
        return jax.lax.psum(logits, axis)

    rows = P(None, axis, None)  # (L|C|B, n/k rows, n) plane stacks
    sharded_logits = shard_map(
        local_logits, mesh=mesh,
        in_specs=(rows, rows, rows, rows, rows),
        out_specs=P(None, None),
        check_vma=False,
    )

    def loss_fn(params, batch):
        phis = jnp.stack(
            [params["phase"][f"layer_{i}"] for i in range(depth)]
        )
        u0 = data_to_cplex(batch["images"], n) * source
        logits = sharded_logits(phis, tf_a, tf_b, masks, u0)
        return _mse(logits, batch["labels"], cfg.num_classes)

    return loss_fn


def compile_donn_train_step_spatial(cfg: DONNConfig, mesh, axis: str = "model",
                                    optimizer=None, donate: bool = True,
                                    steps_per_call: int = 1):
    """Spatially-sharded DONN training: pencil FFT *inside* the layer scan.

    For optical planes too large for one chip (500^2+ fields, arXiv:
    2302.10905-scale scientific workloads): every plane — field, transfer
    functions, trainable phases, detector masks — row-shards over mesh
    axis ``axis``, and each hop of the fused layer scan runs the
    pencil-decomposed local FFT (``repro.runtime.pencil_fft.
    local_spectral_pair``: FFT along W, all-to-all transpose, FFT along H,
    transpose back).  The spectral TF multiply and the phase modulation
    are elementwise on the local row shard, so the only communication per
    hop is the two all-to-alls; the detector readout psums the per-class
    partial intensities.  The batch replicates over ``axis`` (this is
    spatial model parallelism, not data parallelism), phase gradients
    stay row-sharded — each device owns and updates its own rows.

    Supports the uniform classification stack (single channel, unpadded
    angular-spectrum methods, deterministic codesign); ``steps_per_call >
    1`` additionally scans a stacked batch chunk per device call (the
    chunked throughput driver, state donated).

    Returns ``(fn, state_shardings, batch_shardings, state_specs)``:
    ``fn(state, batch)`` for ``steps_per_call == 1`` (metrics
    ``{"loss": ()}``), ``fn(state, batches)`` with a leading chunk axis
    and ``{"loss": (S,)}`` otherwise.  Validated against the
    single-device step — loss and grads agree to rtol <= 1e-5
    (tests/test_distributed.py).
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    optimizer = optimizer or AdamW(lr=0.01)
    loss_fn = make_donn_spatial_loss(cfg, mesh, axis)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    if steps_per_call > 1:
        step = _chunk_over(step)

    sspecs = donn_state_specs(cfg)
    # logical-axis resolution: phase planes are (field_h, field_w) — rows
    # shard over `axis`, optimizer moments follow the same rules
    s_shard = shd.tree_shardings(sspecs, mesh, shd.spatial_rules(axis))
    rep = NamedSharding(mesh, P())
    lead = (None,) if steps_per_call > 1 else ()
    b_shard = {
        "images": NamedSharding(mesh, P(*lead, None, None, None)),
        "labels": NamedSharding(mesh, P(*lead, None)),
    }
    fn = jax.jit(
        step,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": rep}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs


def compile_donn_train_step(cfg: DONNConfig, mesh, optimizer=None,
                            donate: bool = True,
                            global_batch: int | None = None):
    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, DONN_RULES)
    bs = lambda ndim: shd.batch_sharding(mesh, ndim, DONN_RULES,
                                         batch_size=global_batch)
    if cfg.segmentation:
        b_shard = {"images": bs(3), "masks": bs(3)}
    elif cfg.channels > 1:
        b_shard = {"images": bs(4), "labels": bs(1)}
    else:
        b_shard = {"images": bs(3), "labels": bs(1)}
    fn = jax.jit(
        make_donn_train_step(cfg, optimizer),
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": shd.scalar_sharding(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs
