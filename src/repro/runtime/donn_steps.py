"""pjit train step for the paper's DONN workloads (beyond-paper distribution).

The paper trains on a single GPU (multi-GPU is named as future work, §6);
here DONN training is data-parallel across the full production mesh — the
batch shards over every mesh axis, phase parameters replicate (they are
tiny: depth x n^2), and gradients all-reduce.  Spatial (field) model-
parallelism via a pencil-decomposed FFT is implemented separately in
`repro.runtime.pencil_fft` and evaluated in the §Perf hillclimb.

Heterogeneous per-layer architectures (``DONNConfig.layers``) ride the
same steps unchanged: the phase params form a *ragged* pytree (one
(n_i, n_i) leaf per layer, shapes varying across segments), and every
state/sharding transform here is a ``jax.tree`` map over ParamSpec
leaves, so per-layer plane sizes need no special casing
(tests/test_hetero.py::TestHeterogeneousForward::test_train_step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.config import DONNConfig
from repro.core.models import cached_model
from repro.core.train_utils import bce_segmentation_loss, mse_softmax_loss
from repro.nn import ParamSpec, is_spec
from repro.optim import AdamW
from repro.optim.adamw import AdamWState
from repro.runtime import sharding as shd

DONN_RULES = {**shd.DEFAULT_RULES, "batch": ("pod", "data", "model")}


def donn_state_specs(cfg: DONNConfig):
    model = cached_model(cfg)
    pspecs = model.param_specs()

    def opt_spec(s):
        return ParamSpec(s.shape, jnp.float32, s.logical_axes, init="zeros")

    return {
        "params": pspecs,
        "mu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "nu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }


def make_donn_train_step(cfg: DONNConfig, optimizer: AdamW):
    model = cached_model(cfg)

    def loss_fn(params, batch):
        if cfg.segmentation:
            inten = model.apply(params, batch["images"], train=True)
            return bce_segmentation_loss(inten, batch["masks"])
        logits = model.apply(params, batch["images"])
        return mse_softmax_loss(logits, batch["labels"], cfg.num_classes)

    def step(state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    return step


def compile_donn_train_step_shardmap(cfg: DONNConfig, mesh, optimizer=None,
                                     donate: bool = True,
                                     global_batch: int | None = None):
    """Optimized DONN training: shard_map data parallelism.

    GSPMD cannot partition the FFT HLO even over pure batch dims — the
    auto-sharded (pjit) step all-gathers the whole global field for every
    FFT2/iFFT2 (see EXPERIMENTS.md §Perf).  Under shard_map each device
    runs the *entire* optical forward/backward on its local batch shard
    (local FFTs), and only the (tiny, phase-sized) gradients are psum'd —
    the textbook DP layout for a small-parameter model.
    """
    from functools import partial

    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, {})  # params replicated
    dp_axes = tuple(a for a in ("pod", "data", "model") if a in mesh.shape)
    if global_batch is not None:  # drop axes until the batch divides
        import math as _math

        while dp_axes and global_batch % _math.prod(
            mesh.shape[a] for a in dp_axes
        ) != 0:
            dp_axes = dp_axes[:-1]
        if not dp_axes:
            raise ValueError(f"batch {global_batch} unshardable on {mesh}")

    # hoisted out of the loss closure: shard_map retraces (and fresh meshes)
    # reuse one cached layer stack instead of rebuilding it per trace
    model = cached_model(cfg)

    def local_step(state, batch):
        def loss_fn(params, b):
            if cfg.segmentation:
                inten = model.apply(params, b["images"], train=True)
                return bce_segmentation_loss(inten, b["masks"])
            logits = model.apply(params, b["images"])
            return mse_softmax_loss(logits, b["labels"], cfg.num_classes)

        loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        loss = jax.lax.pmean(loss, dp_axes)
        grads = jax.tree.map(lambda g: jax.lax.pmean(g, dp_axes), grads)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        return (
            {"params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
             "step": state["step"] + 1},
            {"loss": loss},
        )

    batch_spec = P(dp_axes)
    target = "masks" if cfg.segmentation else "labels"
    b_specs = {"images": batch_spec, target: batch_spec}
    state_specs_sm = jax.tree.map(lambda _: P(), sspecs)
    fn = jax.jit(
        shard_map(
            local_step, mesh=mesh,
            in_specs=(state_specs_sm, b_specs),
            out_specs=(state_specs_sm, {"loss": P()}),
            check_vma=False,
        ),
        donate_argnums=(0,) if donate else (),
    )
    b_shard = jax.tree.map(
        lambda s: jax.sharding.NamedSharding(mesh, s), b_specs
    )
    return fn, s_shard, b_shard, sspecs


def compile_donn_train_step(cfg: DONNConfig, mesh, optimizer=None,
                            donate: bool = True,
                            global_batch: int | None = None):
    optimizer = optimizer or AdamW(lr=0.01)
    sspecs = donn_state_specs(cfg)
    s_shard = shd.tree_shardings(sspecs, mesh, DONN_RULES)
    bs = lambda ndim: shd.batch_sharding(mesh, ndim, DONN_RULES,
                                         batch_size=global_batch)
    if cfg.segmentation:
        b_shard = {"images": bs(3), "masks": bs(3)}
    elif cfg.channels > 1:
        b_shard = {"images": bs(4), "labels": bs(1)}
    else:
        b_shard = {"images": bs(3), "labels": bs(1)}
    fn = jax.jit(
        make_donn_train_step(cfg, optimizer),
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, {"loss": shd.scalar_sharding(mesh)}),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs
