"""Continuous batching + a fault-tolerant multi-replica serving fleet.

``MicroBatcher`` (PR 5/7) serves one engine with launch-on-deadline
batching: a group dispatches when the largest bucket fills or the oldest
request has waited ``max_wait_ms``.  That leaves two gaps on the road to
real traffic (ROADMAP item 1b+c): requests arriving while a batch is in
flight wait out a fixed deadline even though the device will be free much
sooner, and one engine is one process — a crash, a drain or a model swap
stops the world.  This module closes both:

1.  **Continuous batching** — an admission loop instead of a deadline.
    Arrivals are admitted straight into the *open slot*: the group that
    will dispatch the moment a replica frees.  When any replica is idle
    and work is queued, the dispatcher launches immediately with whatever
    is queued (padded to the nearest compiled bucket —
    ``data.pipeline.bucket_for`` / ``pad_batch``, served through the same
    donated-buffer bucket executables as ``InferenceEngine.infer``); when
    every replica is busy, arrivals coalesce into the open slot and ride
    the next free replica as one batch.  Batch size adapts to load with
    no tuning knob: idle fleet -> batch 1 at minimum latency, saturated
    fleet -> full buckets at maximum throughput.

2.  **Fleet dispatch** — ``FleetRouter`` manages N replicas (built from a
    serialized artifact via ``EngineSupervisor``, or any engine-likes)
    with health-aware **least-loaded placement**: dispatch picks the
    accepting replica with the fewest in-flight requests, tie-broken by
    error rate.  A replica that fails a group is circuit-broken with
    exponential-backoff probation (plus jitter, so replicas recovering
    from a shared fault don't retry in lockstep) and probed with a solo
    group before regaining full traffic.

3.  **Bounded retries, zero drops** — a failed group is never dropped:
    groups of more than one request split in half and re-dispatch (a
    poison request isolates in log2(B) splits and fails *only its own
    future* with ``RetriesExhaustedError``); solo failures recharge the
    request's budget (``max_retries``) and requeue with exponential
    backoff + jitter.  A mid-run replica crash therefore re-serves its
    in-flight group on a healthy replica bit-identically
    (``benchmarks/bench_serving_fleet.py`` asserts it under Poisson load).

4.  **Graceful drain + warm swap** — ``drain()`` stops admission (typed
    ``DrainingError``) and flushes every queued + in-flight request;
    ``swap_artifact(dir)`` validates the new artifact *first*
    (``resilience.validate_artifact``), then rolls the fleet one replica
    at a time: stop placement on it, wait out its in-flight work, rebuild
    it warm from the new artifact while the rest of the fleet keeps
    serving, then return it to rotation — zero dropped requests and no
    serving gap (with ``rolling=False``: drain-the-world, swap all,
    resume).

The scheduled, capacity-aware dispatch idiom follows the traffic-aware
routing of optical-link schedulers (openoptics time-flow tables); the
fault model it survives is the device-noise codesign line the paper
validates on physical SLMs (arXiv 2209.14252), injected here by
``repro.testing.faults``.
"""
from __future__ import annotations

import pathlib
import random
import threading
import time
from concurrent.futures import Future
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.runtime.resilience import (
    DeadlineExceededError, DrainingError, OverloadedError,
    RetriesExhaustedError, validate_artifact,
)


def _deployed_of(engine):
    """The ``DeployedDONN`` behind an engine-like (supervisor/proxy-aware)."""
    for hop in range(4):
        dep = getattr(engine, "deployed", None)
        if dep is not None:
            return dep
        engine = getattr(engine, "engine", None)
        if engine is None:
            return None
    return None


def _buckets_of(engine) -> tuple:
    """The serving buckets behind an engine-like (supervisor/proxy-aware)."""
    from repro.runtime.inference import DEFAULT_BUCKETS

    for hop in range(4):
        if engine is None:
            break
        b = getattr(engine, "buckets", None)
        if b:
            return tuple(sorted(int(x) for x in b))
        engine = getattr(engine, "engine", None)
    return tuple(DEFAULT_BUCKETS)


class _FleetRequest:
    """One queued request (slots: the admission loop is the hot path)."""

    __slots__ = ("x", "future", "t_arrival", "deadline", "attempts",
                 "not_before")

    def __init__(self, x, future, t_arrival, deadline):
        self.x = x
        self.future = future
        self.t_arrival = t_arrival
        self.deadline = deadline  # absolute perf_counter time, or None
        self.attempts = 0  # failed dispatches so far
        self.not_before = 0.0  # retry backoff: ineligible until then


class _Replica:
    """One engine replica + its placement/health state (router-locked)."""

    def __init__(self, name: str, engine, build: Optional[Callable] = None):
        self.name = name
        self.engine = engine
        self.build = build  # build(artifact_dir) -> fresh warmed engine
        self.inflight = 0  # requests currently placed on this replica
        self.accepting = True  # False while draining for a swap
        self.healthy = True
        self.fail_streak = 0
        self.probation_until = 0.0
        self.served = 0
        self.errors = 0
        self.work: List = []  # dispatched groups awaiting this worker
        self.cv: Optional[threading.Condition] = None  # router's cv

    @property
    def engine_ready(self) -> bool:
        return bool(getattr(self.engine, "ready", True))

    def eligible(self, now: float) -> bool:
        """Can dispatch place new work here right now?"""
        if not self.accepting or self.work or self.inflight:
            return False
        if self.healthy and self.engine_ready:
            return True
        # circuit-broken: eligible again once probation expires (the
        # dispatcher sends a solo probe group first)
        return now >= self.probation_until

    def stats(self) -> dict:
        out = {"served": self.served, "errors": self.errors,
               "inflight": self.inflight, "healthy": self.healthy,
               "accepting": self.accepting,
               "fail_streak": self.fail_streak}
        sub = getattr(self.engine, "stats", None)
        if callable(sub):
            try:
                out["engine"] = sub()
            except Exception:  # noqa: BLE001 - stats must never raise
                pass
        return out


class FleetRouter:
    """Continuous-batching admission loop over N serving replicas.

    ``replicas`` is a sequence of engine-likes (anything with
    ``infer(batch)``: ``InferenceEngine``, ``EngineSupervisor``, the
    fault-injection proxies in ``repro.testing.faults``) or
    ``(engine, build)`` pairs where ``build(artifact_dir)`` constructs a
    fresh warmed replacement engine (required for ``swap_artifact``).
    ``FleetRouter.from_artifact`` builds a supervised fleet from a
    serialized artifact directory.

    ``submit(x, timeout_ms=...)`` returns a ``Future``; typed failures:

    - ``OverloadedError`` — admission queue full (bounded by
      ``max_queue``), request shed at the door;
    - ``DrainingError`` — fleet is draining/swapping, not admitting;
    - ``DeadlineExceededError`` — ``timeout_ms`` expired while the
      request was still queued in an open slot;
    - ``RetriesExhaustedError`` — the request failed ``max_retries + 1``
      solo dispatches (its group-mates are unaffected).
    """

    def __init__(self, replicas: Sequence, *, max_queue: Optional[int] = 1024,
                 max_retries: int = 3, backoff_base_ms: float = 5.0,
                 backoff_max_ms: float = 500.0, backoff_jitter: float = 0.5,
                 probation_base_ms: float = 20.0,
                 probation_max_ms: float = 2000.0, validate: bool = True,
                 seed: Optional[int] = 0):
        if not replicas:
            raise ValueError("FleetRouter needs at least one replica")
        self.max_queue = None if not max_queue else int(max_queue)
        self.max_retries = int(max_retries)
        self.backoff_base_ms = float(backoff_base_ms)
        self.backoff_max_ms = float(backoff_max_ms)
        self.backoff_jitter = float(backoff_jitter)
        self.probation_base_ms = float(probation_base_ms)
        self.probation_max_ms = float(probation_max_ms)
        self.validate = validate
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._replicas: List[_Replica] = []
        for i, item in enumerate(replicas):
            engine, build = item if isinstance(item, tuple) else (item, None)
            rep = _Replica(f"r{i}", engine, build)
            rep.cv = self._cv
            self._replicas.append(rep)
        self._deployed = next(
            (d for d in map(_deployed_of, (r.engine for r in self._replicas))
             if d is not None), None)
        self.bucket_max = max(
            max(_buckets_of(r.engine)) for r in self._replicas
        )
        # pending units: (pinned, [requests]); non-pinned units are always
        # single requests and coalesce at dispatch; pinned units are retry
        # groups that dispatch exactly as-is (poison isolation)
        self._pending: List = []
        self._queued = 0
        self._draining = False
        self._closed = False
        self.stats_counters = {
            "submitted": 0, "served": 0, "shed": 0, "expired": 0,
            "failed": 0, "retried": 0, "splits": 0, "rejected_draining": 0,
            "replica_failures": 0, "dispatches": 0, "swaps": 0,
        }
        self._workers = [
            threading.Thread(target=self._worker, args=(rep,), daemon=True)
            for rep in self._replicas
        ]
        for t in self._workers:
            t.start()
        self._dispatcher = threading.Thread(target=self._dispatch_loop,
                                            daemon=True)
        self._dispatcher.start()

    # ------------------------------------------------------------------
    # construction helpers
    # ------------------------------------------------------------------
    @classmethod
    def from_artifact(cls, artifact_dir, *, replicas: int = 2,
                      buckets: Optional[Sequence[int]] = None,
                      engine_factory=None, max_restarts: int = 3,
                      supervisor_backoff_base_ms: float = 50.0,
                      verify: bool = True,
                      warmup_buckets: Optional[Sequence[int]] = None,
                      **router_kw) -> "FleetRouter":
        """A fleet of N ``EngineSupervisor``-wrapped replicas from disk.

        The artifact is validated (format version + architecture spec)
        before any replica warms up; each replica supervises its own
        engine (restart-from-artifact with backoff), and each carries a
        ``build`` factory so ``swap_artifact`` can roll it onto a new
        artifact warm.
        """
        from repro.runtime.resilience import EngineSupervisor

        validate_artifact(artifact_dir)
        artifact_dir = pathlib.Path(artifact_dir)

        def build(target_dir, _seed):
            return EngineSupervisor(
                target_dir, buckets=buckets, engine_factory=engine_factory,
                max_restarts=max_restarts,
                backoff_base_ms=supervisor_backoff_base_ms,
                warmup_buckets=warmup_buckets, verify=verify, seed=_seed,
            ).start()

        pairs = []
        for i in range(int(replicas)):
            mk = (lambda s: lambda d: build(d, s))(i)
            pairs.append((build(artifact_dir, i), mk))
        router = cls(pairs, **router_kw)
        router.artifact_dir = artifact_dir
        return router

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def submit(self, x, timeout_ms: Optional[float] = None) -> Future:
        """Admit one request into the open slot; returns its ``Future``."""
        from repro.runtime.inference import validate_request

        x = np.asarray(x)
        if self.validate and self._deployed is not None:
            validate_request(self._deployed, x)
        now = time.perf_counter()
        deadline = None if timeout_ms is None else now + timeout_ms / 1e3
        fut: Future = Future()
        req = _FleetRequest(x, fut, now, deadline)
        with self._cv:
            if self._closed:
                raise RuntimeError("FleetRouter is closed")
            if self._draining:
                self.stats_counters["rejected_draining"] += 1
                raise DrainingError(
                    "fleet is draining: new requests are not admitted "
                    "(queued and in-flight requests are still served)"
                )
            if self.max_queue is not None and self._queued >= self.max_queue:
                self.stats_counters["shed"] += 1
                raise OverloadedError(
                    f"admission queue full ({self.max_queue} pending)"
                )
            self._pending.append((False, [req]))
            self._queued += 1
            self.stats_counters["submitted"] += 1
            self._cv.notify_all()
        return fut

    # ------------------------------------------------------------------
    # dispatch: the continuous-batching admission loop
    # ------------------------------------------------------------------
    def _request_backoff_s(self, attempts: int) -> float:
        base = min(self.backoff_base_ms * 2.0 ** max(attempts - 1, 0),
                   self.backoff_max_ms)
        return base * (1.0 + self.backoff_jitter * self._rng.random()) / 1e3

    def _probation_s(self, fail_streak: int) -> float:
        base = min(self.probation_base_ms * 2.0 ** max(fail_streak - 1, 0),
                   self.probation_max_ms)
        return base * (1.0 + self.backoff_jitter * self._rng.random()) / 1e3

    def _expire_locked(self, now: float) -> List[_FleetRequest]:
        """Pop deadline-expired requests out of the pending units."""
        expired: List[_FleetRequest] = []
        kept: List = []
        for pinned, reqs in self._pending:
            live = []
            for r in reqs:
                if r.deadline is not None and now >= r.deadline:
                    expired.append(r)
                else:
                    live.append(r)
            if live:
                kept.append((pinned, live))
        if expired:
            self._pending = kept
            self._queued -= len(expired)
            self.stats_counters["expired"] += len(expired)
        return expired

    def _pick_replica(self, now: float) -> Optional[_Replica]:
        """Least-loaded placement over ready replicas; error-rate tiebreak."""
        best, best_key = None, None
        for rep in self._replicas:
            if not rep.eligible(now):
                continue
            err_rate = rep.errors / max(rep.served + rep.errors, 1)
            key = (rep.inflight, not rep.healthy, err_rate)
            if best_key is None or key < best_key:
                best, best_key = rep, key
        return best

    def _form_group_locked(self, rep: _Replica,
                           now: float) -> Optional[List[_FleetRequest]]:
        """Take the next dispatchable group off the pending queue.

        The first eligible unit decides: a pinned retry unit dispatches
        exactly as-is; otherwise eligible singles coalesce up to the
        bucket limit (a circuit-broken replica on probation gets a solo
        probe instead of a full group).
        """
        limit = 1 if not rep.healthy else self.bucket_max
        group: List[_FleetRequest] = []
        taken: List[int] = []
        pinned_take = None
        for i, (pinned, reqs) in enumerate(self._pending):
            if any(r.not_before > now for r in reqs):
                continue
            if pinned:
                if not group:
                    pinned_take = i
                break
            for r in reqs:
                group.append(r)
                taken.append(i)
                if len(group) >= limit:
                    break
            if len(group) >= limit:
                break
        if pinned_take is not None:
            _, group = self._pending.pop(pinned_take)
        elif group:
            for i in reversed(taken):
                self._pending.pop(i)
        else:
            return None
        self._queued -= len(group)
        return group

    def _next_timer_locked(self, now: float) -> Optional[float]:
        """Seconds until the next retry/deadline/probation timer fires."""
        ts = []
        for _, reqs in self._pending:
            for r in reqs:
                if r.not_before > now:
                    ts.append(r.not_before)
                if r.deadline is not None:
                    ts.append(r.deadline)
        if self._pending:
            for rep in self._replicas:
                if (rep.accepting and not rep.work and not rep.inflight
                        and not rep.healthy and rep.probation_until > now):
                    ts.append(rep.probation_until)
        return max(min(ts) - now, 0.0) if ts else None

    def _dispatch_loop(self):
        while True:
            resolve: List = []
            with self._cv:
                while True:
                    now = time.perf_counter()
                    expired = self._expire_locked(now)
                    if expired:
                        resolve = expired
                        break
                    if self._closed and not self._pending:
                        return
                    rep = self._pick_replica(now) if self._pending else None
                    group = (self._form_group_locked(rep, now)
                             if rep is not None else None)
                    if group is not None:
                        rep.inflight += len(group)
                        rep.work.append(group)
                        self.stats_counters["dispatches"] += 1
                        self._cv.notify_all()
                        continue  # more pending work may dispatch now
                    self._cv.wait(timeout=self._next_timer_locked(now) or 0.1)
            for r in resolve:
                if not r.future.done():
                    r.future.set_exception(DeadlineExceededError(
                        "request deadline expired while queued in an open "
                        "slot"
                    ))

    # ------------------------------------------------------------------
    # replica workers
    # ------------------------------------------------------------------
    def _worker(self, rep: _Replica):
        while True:
            with self._cv:
                while not rep.work and not self._closed:
                    self._cv.wait(timeout=0.1)
                if rep.work:
                    group = rep.work.pop(0)
                elif self._closed:
                    return
                else:
                    continue
            try:
                xs = np.stack([r.x for r in group])
                outs = rep.engine.infer(xs)
            except Exception as e:  # noqa: BLE001 - any replica fault
                self._backoff_and_requeue(rep, group, e)
                continue
            with self._cv:
                rep.inflight -= len(group)
                rep.served += len(group)
                rep.fail_streak = 0
                rep.healthy = True
                self.stats_counters["served"] += len(group)
                self._cv.notify_all()
            for r, out in zip(group, outs):
                if not r.future.done():
                    r.future.set_result(out)

    def _backoff_and_requeue(self, rep: _Replica, group: List[_FleetRequest],
                             exc: Exception):
        """Failure path: circuit-break the replica, never drop a request.

        Groups split in half and requeue pinned (isolating a poison
        request in log2(B) splits); solo failures charge the request's
        retry budget and requeue with exponential backoff + jitter.
        """
        now = time.perf_counter()
        failed: List[_FleetRequest] = []
        with self._cv:
            rep.inflight -= len(group)
            rep.errors += 1
            rep.fail_streak += 1
            rep.healthy = False
            rep.probation_until = now + self._probation_s(rep.fail_streak)
            self.stats_counters["replica_failures"] += 1
            for r in group:
                r.attempts += 1
            if self._closed:
                # shutdown already swept the queue: fail rather than
                # strand a requeued future nobody will ever dispatch
                self.stats_counters["failed"] += len(group)
                failed = group
            elif len(group) == 1:
                r = group[0]
                if r.attempts > self.max_retries:
                    self.stats_counters["failed"] += 1
                    failed.append(r)
                else:
                    r.not_before = now + self._request_backoff_s(r.attempts)
                    self._pending.insert(0, (True, [r]))
                    self._queued += 1
                    self.stats_counters["retried"] += 1
            else:
                mid = len(group) // 2
                nb = now + self._request_backoff_s(
                    min(r.attempts for r in group))
                for half in (group[mid:], group[:mid]):
                    for r in half:
                        r.not_before = nb
                    self._pending.insert(0, (True, half))
                    self._queued += len(half)
                self.stats_counters["splits"] += 1
                self.stats_counters["retried"] += len(group)
            self._cv.notify_all()
        for r in failed:
            if not r.future.done():
                r.future.set_exception(RetriesExhaustedError(
                    f"request failed {r.attempts} dispatch attempts "
                    f"(budget max_retries={self.max_retries}); last "
                    f"replica error: {exc!r}"
                ))

    # ------------------------------------------------------------------
    # drain / swap / close
    # ------------------------------------------------------------------
    def _flushed_locked(self) -> bool:
        return (not self._pending
                and all(r.inflight == 0 and not r.work
                        for r in self._replicas))

    def drain(self, timeout: float = 60.0) -> bool:
        """Stop admitting; flush every queued + in-flight request.

        New ``submit`` calls raise ``DrainingError`` until ``resume()``.
        Returns True when the fleet is fully flushed within ``timeout``.
        """
        with self._cv:
            self._draining = True
            self._cv.notify_all()
            return self._cv.wait_for(self._flushed_locked, timeout=timeout)

    def resume(self):
        """Reopen admission after a ``drain()``."""
        with self._cv:
            self._draining = False
            self._cv.notify_all()

    @property
    def draining(self) -> bool:
        return self._draining

    def swap_artifact(self, artifact_dir, *, rolling: bool = True,
                      timeout: float = 120.0) -> dict:
        """Warm model swap from a (validated) serialized artifact.

        ``rolling=True`` (default) swaps one replica at a time: placement
        stops on it, its in-flight work flushes, a fresh engine is built
        + warmed from the new artifact *while the rest of the fleet keeps
        serving*, then it returns to rotation — admission never closes
        and no request is dropped.  ``rolling=False`` drains the whole
        fleet first (admission closed for the duration), swaps every
        replica, then resumes.  Either way the artifact's format version
        and architecture spec are validated before any replica is
        touched.  Returns the artifact metadata.
        """
        meta = validate_artifact(artifact_dir)
        no_build = [r.name for r in self._replicas if r.build is None]
        if no_build:
            raise RuntimeError(
                f"replicas {no_build} have no build factory; construct the "
                "router with (engine, build) pairs or from_artifact() to "
                "enable swaps"
            )
        if not rolling:
            if not self.drain(timeout=timeout):
                raise TimeoutError("fleet did not flush within the swap "
                                   "timeout; swap aborted before rebuild")
        for rep in self._replicas:
            with self._cv:
                rep.accepting = False
                ok = self._cv.wait_for(
                    lambda: rep.inflight == 0 and not rep.work,
                    timeout=timeout,
                )
            if not ok:
                with self._cv:
                    rep.accepting = True
                raise TimeoutError(
                    f"replica {rep.name} did not flush within the swap "
                    "timeout; it was returned to rotation on the old model"
                )
            engine = rep.build(artifact_dir)  # built + warmed outside the lock
            with self._cv:
                rep.engine = engine
                rep.healthy = True
                rep.fail_streak = 0
                rep.probation_until = 0.0
                rep.accepting = True
                self._cv.notify_all()
        self._deployed = next(
            (d for d in map(_deployed_of, (r.engine for r in self._replicas))
             if d is not None), None)
        self.artifact_dir = pathlib.Path(artifact_dir)
        if not rolling:
            self.resume()
        self.stats_counters["swaps"] += 1
        return meta

    def close(self, timeout: float = 30.0) -> bool:
        """Flush and stop the fleet.

        Returns True on a clean flush + join; on timeout every unresolved
        queued/in-flight future is failed with ``RuntimeError`` and False
        is returned.
        """
        with self._cv:
            self._draining = True
            self._cv.notify_all()
        deadline = time.monotonic() + timeout
        with self._cv:
            flushed = self._cv.wait_for(
                self._flushed_locked,
                timeout=max(deadline - time.monotonic(), 0.01),
            )
            self._closed = True
            self._cv.notify_all()
        self._dispatcher.join(timeout=max(deadline - time.monotonic(), 0.01))
        for t in self._workers:
            t.join(timeout=max(deadline - time.monotonic(), 0.01))
        clean = flushed and not self._dispatcher.is_alive() and not any(
            t.is_alive() for t in self._workers
        )
        if clean:
            return True
        with self._cv:
            stranded = [r for _, reqs in self._pending for r in reqs]
            self._pending = []
            self._queued = 0
        err = RuntimeError(
            f"FleetRouter shutdown unclean: {len(stranded)} queued "
            f"request(s) abandoned after {timeout}s"
        )
        for r in stranded:
            if not r.future.done():
                r.future.set_exception(err)
        return False

    # ------------------------------------------------------------------
    # introspection
    # ------------------------------------------------------------------
    def health_check(self) -> dict:
        """Probe every idle replica with a tiny zero batch; {name: ok}.

        A replica that passes is returned to rotation immediately
        (probation cleared); busy replicas are skipped (reported as their
        current health) rather than queued behind live traffic.
        """
        from repro.runtime.inference import expected_request_shape

        out = {}
        for rep in self._replicas:
            with self._cv:
                if rep.inflight or rep.work:
                    out[rep.name] = rep.healthy
                    continue
                rep.inflight += 1  # hold the slot while probing
            try:
                if self._deployed is not None:
                    probe = np.zeros(
                        (1,) + expected_request_shape(self._deployed),
                        np.float32)
                    rep.engine.infer(probe)
                ok = True
            except Exception:  # noqa: BLE001 - the probe IS the check
                ok = False
            with self._cv:
                rep.inflight -= 1
                rep.healthy = ok
                if ok:
                    rep.fail_streak = 0
                    rep.probation_until = 0.0
                self._cv.notify_all()
            out[rep.name] = ok
        return out

    @property
    def replicas(self) -> tuple:
        return tuple(self._replicas)

    def stats(self) -> dict:
        with self._cv:
            s = dict(self.stats_counters)
            s["queued"] = self._queued
            s["draining"] = self._draining
            s["replicas"] = {r.name: r.stats() for r in self._replicas}
        return s


class ContinuousBatcher(FleetRouter):
    """Single-engine continuous batching: ``MicroBatcher`` without the
    launch deadline.

    The same admission loop as the fleet, over one replica: an idle
    engine dispatches the instant a request arrives (batch 1, minimum
    latency); under load, arrivals coalesce into the open slot and the
    next dispatch carries them as one bucket-padded batch.  Drop-in for
    ``MicroBatcher(engine)`` minus ``max_wait_ms`` — there is nothing to
    tune.
    """

    def __init__(self, engine, **kw):
        super().__init__([engine], **kw)
