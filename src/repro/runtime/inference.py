"""Deployment inference engine: frozen DONNs served fast (LightRidge pillar 3).

PRs 1-4 optimized training, emulation and DSE; a *deployed* model still
paid the full training-path forward on every request — per-call codesign
quantization (a 256-level argmin/softmax per layer for realistic nonlinear
devices), per-call ``exp(j theta)``, phase-stack construction, a fresh jit
dispatch per request, and no batching across requests.  All of that is
statically known at deploy time (the SLM is programmed / the mask is
printed once — cf. the hybrid reconfigurable DONNs of arXiv 2411.05748 and
the physics-aware discrete codesign of arXiv 2209.14252), so this module
folds it out of the hot path entirely:

1.  **Frozen artifact** — ``freeze(model, params)`` resolves the codesign
    device response once (``codesign.deployed_phase``) and precomputes the
    ``gamma * exp(j theta)`` modulation planes per layer
    (``PropagationPlan.frozen_modulation``), in the kernel's native
    convention (polar for the fused ``phase_tf_apply`` Pallas kernel,
    cartesian split planes for the jnp path).  Per-request work shrinks to
    the FFT hops plus one fused multiply per layer, via the
    ``forward(frozen=...)`` fast path — bit-identical to the training-path
    forward at eval (tests/test_inference.py).
2.  **Bucketed AOT executables** — one compiled program per batch bucket,
    riding ``cached_executable`` with the request buffer donated.
    ``warmup(buckets=...)`` pays every compile at deploy time, so the
    first request is served from a warm executable.
3.  **Micro-batching** — ``MicroBatcher`` queues single requests and
    launches on batch-full-or-deadline, padding the queued set to the
    nearest bucket (``repro.data.pipeline.bucket_for`` / ``pad_batch``).
4.  **Multi-device dispatch** — buckets at least ``dp_min_bucket`` wide
    run data-parallel over the host mesh via ``shard_map`` on the batch
    axis (each device runs the whole optical forward on its batch shard;
    a DONN's phases are tiny, so pure DP is the right layout).
5.  **Row-sharded (model-parallel) serving** — ``model_devices=k`` puts
    the engine on the canonical 2-D ``(data, model)`` mesh
    (``sharding.make_mesh_2d`` + the ``donn_rules`` table): frozen
    modulation stacks, TF planes and detector masks shard their field
    rows over ``model`` and every hop runs the in-scan pencil FFT
    (``pencil_fft.local_spectral_pair``), so planes too large for one
    chip serve through the same bucketed executables; composes with the
    batch-axis DP above on one mesh.

Measured in ``benchmarks/bench_inference_throughput.py``; served by
``repro.launch.serve_donn``.
"""
from __future__ import annotations

import threading
import time
import warnings
from concurrent.futures import Future
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import diffraction as df
from repro.core.laser import data_to_cplex, data_to_real
from repro.data.pipeline import bucket_for, pad_batch
from repro.runtime import sharding as shd
from repro.runtime.resilience import DeadlineExceededError, OverloadedError

DEFAULT_BUCKETS = (1, 2, 4, 8, 16, 32)


# --------------------------------------------------------------------------
# Frozen deployment artifact
# --------------------------------------------------------------------------
class DeployedDONN:
    """A trained DONN frozen for serving.

    Holds the propagation plan, the precomputed modulation planes and the
    (config-static) detector geometry — everything ``forward`` needs, and
    nothing of the training machinery (params pytree, codesign rng,
    quantizers).  Build with ``freeze(model, params)``.
    """

    def __init__(self, cfg, family: str, plan, frozen, source, in_n: int,
                 detector=None, skip_from=None, skip_hop=None,
                 out_grid=None, rfft_first: bool = False):
        from repro.core import propagation as pp

        self.cfg = cfg
        self.family = family  # "cls" | "multi" | "seg"
        self.plan = plan
        self.frozen = frozen
        self.source = jnp.asarray(source)
        self.in_n = in_n
        self.detector = detector
        self.skip_from = skip_from
        self.skip_hop = skip_hop
        self.out_grid = out_grid
        self.heterogeneous = cfg.is_heterogeneous()
        # storage precision of the modulation planes (derived, so restored
        # artifacts report it without trusting their metadata)
        self.plane_dtype = pp.frozen_plane_dtype(
            frozen[0] if self.heterogeneous else frozen
        )
        self.rfft_first = bool(rfft_first)
        if self.rfft_first:
            if self.heterogeneous:
                raise ValueError(
                    "rfft_first covers uniform plans (the segmented first "
                    "hop is a follow-on)"
                )
            if not plan.rfft_first_supported():
                raise ValueError(
                    "rfft_first needs an unpadded non-fraunhofer plan"
                )
            if plan.depth < 1:
                raise ValueError("rfft_first needs at least one layer")
            if not np.allclose(np.asarray(self.source).imag, 0.0):
                raise ValueError(
                    "rfft_first needs a real source field (amplitude-"
                    "encoded inputs keep the entry field real)"
                )
            # half-spectrum TF planes build (and evenness-check) eagerly
            plan._rfft_half()

    # --- the deployment forward (bit-identical to model.apply at eval) ---
    def forward(self, x: jax.Array, frozen=None) -> jax.Array:
        """Batched frozen forward: images -> logits / intensity maps.

        ``frozen`` optionally overrides the artifact's modulation planes —
        the ``InferenceEngine`` passes them as *traced inputs* so every
        deployment of one architecture shares a single compiled program
        (same statics, different trained params).
        """
        frozen = self.frozen if frozen is None else frozen
        if self.rfft_first:
            # real-to-complex entry: amplitude-encoded data through a real
            # source keeps the field real, so layer 0 runs as half-spectrum
            # rFFTs (plan.first_layer_real); the scan continues at layer 1
            xr = data_to_real(x, self.in_n) * self.source.real
            u = self.plan.first_layer_real(xr, frozen)
            start = 1
        else:
            u = data_to_cplex(x, self.in_n) * self.source
            start = 0
        if self.family == "seg":
            plan = self.plan
            if self.skip_from is None:
                u = plan.forward(None, u, start=start, frozen=frozen)
                skip_u = None
            else:
                u = plan.forward(None, u, start=start,
                                 stop=self.skip_from + 1, frozen=frozen)
                skip_u = u
                u = plan.forward(None, u, start=self.skip_from + 1,
                                 frozen=frozen)
            u = plan.propagate_final(u)
            if skip_u is not None:
                sk = self.skip_hop.propagate(skip_u)
                sk = df.resample_field(sk, self.skip_hop.grid, self.out_grid)
                u = (u + sk) / jnp.sqrt(2.0).astype(jnp.complex64)
            return df.intensity(u)  # eval path: no train-time layer norm
        u = self.plan.forward(None, u, start=start, frozen=frozen)
        u = self.plan.propagate_final(u)
        if self.family == "multi":
            from repro.core.models import channel_readout

            return channel_readout(u, self.detector.masks,
                                   self.cfg.use_pallas)
        return self.detector(u)

    def static_key(self) -> tuple:
        """Executable-cache identity: config statics only.

        The trained modulation planes enter compiled programs as traced
        inputs, so deployments of the same architecture with different
        params share executables (and can never read each other's baked
        constants).  ``rfft_first`` changes the program *structure* (the
        entry hop), so it is part of the identity; plane storage dtypes
        already differ in the frozen-input avals.
        """
        from repro.core.models import config_static_key

        return ("deployed_donn", self.family, config_static_key(self.cfg),
                self.rfft_first)


def deployed_from_model(model, frozen, source=None,
                        rfft_first: bool = False) -> DeployedDONN:
    """Assemble a ``DeployedDONN`` around a built model + ready-made planes.

    The structural half of ``freeze``: plan, detector, grids and skip
    wiring come from the model; the modulation planes are supplied by the
    caller (``freeze`` computes them from trained params;
    ``runtime.resilience.load_deployed`` restores them from a serialized
    artifact without touching params or codesign at all).  ``source``
    optionally overrides the model's laser field (artifacts persist the
    resolved field so non-default lasers survive the round-trip).
    """
    from repro.core import models as md

    if isinstance(model, md.MultiChannelDONN):
        cm = model.channel_model
        return DeployedDONN(
            model.cfg, "multi", cm.plan, frozen,
            cm.source if source is None else source, cm.in_grid.n,
            detector=cm.detector, rfft_first=rfft_first,
        )
    if isinstance(model, md.SegmentationDONN):
        return DeployedDONN(
            model.cfg, "seg", model.plan, frozen,
            model.source if source is None else source, model.in_grid.n,
            skip_from=model.skip_from,
            skip_hop=getattr(model, "skip_hop", None), out_grid=model.grid,
            rfft_first=rfft_first,
        )
    if not isinstance(model, md.DONN):
        raise TypeError(f"cannot freeze {type(model).__name__}")
    return DeployedDONN(
        model.cfg, "cls", model.plan, frozen,
        model.source if source is None else source, model.in_grid.n,
        detector=model.detector, rfft_first=rfft_first,
    )


def freeze(model, params, plane_dtype: str = "float32",
           rfft_first: bool = False) -> DeployedDONN:
    """Fold a trained model + params into a serving artifact.

    Covers all three model families (classify / RGB multi-channel /
    segmentation incl. the optical skip), uniform and heterogeneous
    (segmented-plan) stacks, every codesign mode (stochastic modes resolve
    to their deterministic eval form, see ``codesign.deployed_phase``).

    ``plane_dtype`` selects the storage precision of the frozen modulation
    planes (``"float32"`` bit-identical | ``"bfloat16"`` | ``"int8"``,
    both with f32 accumulation — accuracy deltas measured per family in
    BENCH_inference_throughput).  ``rfft_first`` opts the serving forward
    into the half-spectrum real-to-complex first hop (uniform unpadded
    non-fraunhofer plans with a real source; raises otherwise).
    """
    from repro.core import models as md

    if isinstance(model, md.MultiChannelDONN):
        cm = model.channel_model
        phis = cm.plan.stack_phases(
            params["phase"][f"layer_{i}"] for i in range(len(cm.layers))
        )
        frozen = cm.plan.frozen_modulation(phis, plane_dtype)
    elif isinstance(model, md.SegmentationDONN) or isinstance(model, md.DONN):
        if isinstance(model, md.DONN):
            phis = model.stacked_phases(params)
        else:
            phis = model.plan.stack_phases(
                params["phase"][f"layer_{i}"]
                for i in range(len(model.layers))
            )
        frozen = model.plan.frozen_modulation(phis, plane_dtype)
    else:
        raise TypeError(f"cannot freeze {type(model).__name__}")
    return deployed_from_model(model, frozen, rfft_first=rfft_first)


# --------------------------------------------------------------------------
# Bucketed, donated, (optionally) data-parallel serving engine
# --------------------------------------------------------------------------
class InferenceEngine:
    """Shape-bucketed AOT serving around a ``DeployedDONN``.

    - one compiled executable per batch bucket (``cached_executable``:
      deployments sharing architecture statics + bucket share programs);
    - the padded request buffer is **donated** (requests are always padded
      into a fresh buffer first — ``pad_batch`` — so donation can never
      alias a live caller array);
    - ``warmup()`` pays every bucket's compile at deploy time;
    - buckets of at least ``dp_min_bucket`` rows dispatch data-parallel
      over ``mesh_devices`` devices via ``shard_map`` on the batch axis;
    - ``model_devices=k`` row-shards the frozen planes / TF stacks /
      detector masks over the ``model`` axis of the 2-D ``(data, model)``
      mesh and runs pencil-FFT hops — frozen stacks too large for one
      chip serve without replicating any plane (classify family, unpadded
      angular-spectrum plans).
    """

    def __init__(self, deployed: DeployedDONN,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 donate: bool = True, mesh_devices: Optional[int] = None,
                 dp_min_bucket: int = 8,
                 model_devices: Optional[int] = None):
        self.deployed = deployed
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError("buckets must be positive ints")
        self.donate = donate
        self.dp_min_bucket = int(dp_min_bucket)
        self.ndev = int(mesh_devices) if mesh_devices else 1
        self.mp = int(model_devices) if model_devices else 1
        if self.ndev < 1 or self.mp < 1:
            raise ValueError("mesh_devices/model_devices must be >= 1")
        if self.ndev * self.mp > jax.device_count():
            raise ValueError(
                f"mesh needs {self.ndev * self.mp} devices ({self.ndev} "
                f"data x {self.mp} model), have {jax.device_count()}"
            )
        if (self.ndev > 1 or self.mp > 1) and deployed.heterogeneous:
            raise NotImplementedError(
                "multi-device dispatch covers uniform plans (segmented "
                "frozen planes are a ragged pytree; flatten is a follow-on)"
            )
        if self.mp > 1:
            cfg = deployed.cfg
            if deployed.family != "cls":
                raise NotImplementedError(
                    "row-sharded serving covers the classify family; RGB "
                    "and segmentation row-shard on the training path only "
                    "for now (donn_steps.make_donn_sharded_loss)"
                )
            if deployed.rfft_first:
                raise NotImplementedError(
                    "rfft_first's half-spectrum entry hop is not row-"
                    "shardable; freeze with rfft_first=False to serve "
                    "model-parallel"
                )
            if cfg.use_pallas:
                raise NotImplementedError(
                    "the fused Pallas kernels operate on full planes"
                )
            if cfg.pad or any(l.approximation == "fraunhofer"
                              for l in cfg.resolved_layers()):
                raise NotImplementedError(
                    "row-sharded serving needs unpadded angular-spectrum "
                    "hops (the spectral-override contract, plan._hop)"
                )
            n = deployed.plan.grid.n
            if n % self.mp:
                raise ValueError(
                    f"field rows n={n} not divisible by "
                    f"model_devices={self.mp}"
                )
        self._mesh = None
        self._rules = None
        self._x_sharding = None
        if self.ndev > 1 or self.mp > 1:
            from jax.sharding import NamedSharding

            self._mesh = shd.make_mesh_2d(data=self.ndev, model=self.mp)
            self._rules = shd.donn_rules()
            if self.ndev > 1:
                self._x_sharding = NamedSharding(
                    self._mesh, shd.dim0_pspec("data", self._x_ndim())
                )
        # hot-path pin: {(input shape, dtype): compiled} — infer() does a
        # plain dict lookup; cached_executable stays the cross-engine
        # sharing layer behind it (first build per shape goes through it)
        self._compiled: dict = {}
        self.stats = {"requests": 0, "batches": 0, "padded_rows": 0}

    # --- shapes ---
    def _x_ndim(self) -> int:
        return 4 if self.deployed.family == "multi" else 3

    def _example(self, bucket: int) -> np.ndarray:
        n = self.deployed.cfg.input_size
        shape = ((bucket, self.deployed.cfg.channels, n, n)
                 if self.deployed.family == "multi" else (bucket, n, n))
        return np.zeros(shape, np.float32)

    def _dp(self, bucket: int) -> bool:
        return (self.ndev > 1 and bucket >= self.dp_min_bucket
                and bucket % self.ndev == 0)

    # --- compiled program per bucket ---
    def _executable(self, xp: jax.Array):
        from repro.core import propagation as pp

        pin_key = (tuple(xp.shape), jnp.result_type(xp).name)
        pinned = self._compiled.get(pin_key)
        if pinned is not None:
            return pinned
        bucket = xp.shape[0]
        dp = self._dp(bucket)
        dep = self.deployed

        def fwd(x, frozen):
            return dep.forward(x, frozen=frozen)

        if self.mp > 1:
            from repro.compat import shard_map
            from repro.runtime.donn_steps import _plan_tf_stacks
            from repro.runtime.pencil_fft import local_spectral_pair

            # Row-sharded serving: the frozen modulation stacks, the TF
            # planes and the detector masks all shard field rows over
            # "model"; every hop of the frozen scan runs the in-scan
            # pencil FFT and the per-class partial readout psums over
            # "model".  Composes with batch DP over "data" on the same
            # mesh (u0 is built in auto land so GSPMD places the entry
            # encode; tf/mask stacks are config statics, closed over like
            # the baked plan constants they replace).
            mesh, rules, mp = self._mesh, self._rules, self.mp
            plan = dep.plan
            spectral = local_spectral_pair("model", mp)
            tf_a, tf_b = _plan_tf_stacks(plan)
            masks = jnp.asarray(dep.detector.masks)
            bax = "batch" if dp else None
            u_spec = shd.rules_pspec((bax, "field_h", "field_w"),
                                     rules, mesh)
            tf_spec = shd.rules_pspec(("layers", "field_h", "field_w"),
                                      rules, mesh)
            m_spec = shd.rules_pspec(("classes", "field_h", "field_w"),
                                     rules, mesh)
            frozen_specs = jax.tree.map(
                lambda a: shd.operand_pspec(
                    jnp.shape(a), ("layers", "field_h", "field_w"),
                    mesh, rules,
                ),
                tuple(dep.frozen),
            )
            out_spec = shd.rules_pspec((bax, None), rules, mesh)

            def local_logits(u, a, b, m, fz):
                u = plan.forward(None, u, tfs=(a, b), spectral=spectral,
                                 frozen=fz)
                u = plan.propagate_final(u, tfs=(a, b), spectral=spectral)
                part = jnp.einsum("...hw,chw->...c", df.intensity(u), m)
                return jax.lax.psum(part, "model")

            sharded = shard_map(
                local_logits, mesh=mesh,
                in_specs=(u_spec, tf_spec, tf_spec, m_spec, frozen_specs),
                out_specs=out_spec, check_vma=False,
            )

            def run(x, frozen):
                u = data_to_cplex(x, dep.in_n) * dep.source
                return sharded(u, tf_a, tf_b, masks, tuple(frozen))

            fn = run
        elif dp:
            from repro.compat import shard_map

            mesh = self._mesh
            x_spec = shd.dim0_pspec("data", self._x_ndim())
            # frozen planes replicate; the batch axis shards.  Every device
            # runs the full optical forward on its local rows — pure DP,
            # zero cross-device collectives in the hot loop.  The spec tree
            # mirrors the frozen tuple (2 leaves f32/bf16 storage, 4 with
            # int8 quantized planes + their per-layer scales).
            frozen_specs = jax.tree.map(
                lambda a: shd.replicated_pspec(jnp.ndim(a)),
                tuple(dep.frozen),
            )
            out_spec = shd.dim0_pspec(
                "data", 3 if dep.family == "seg" else 2
            )

            def run(x, frozen):
                return shard_map(
                    fwd, mesh=mesh, in_specs=(x_spec, frozen_specs),
                    out_specs=out_spec, check_vma=False,
                )(x, frozen)

            fn = run
        else:
            fn = fwd
        key = dep.static_key() + (
            "dp", self.ndev if dp else 1, "mp", self.mp, self.donate
        )
        with warnings.catch_warnings():
            # donation only pays when an output aval matches the request
            # buffer (e.g. full-res segmentation maps); elsewhere it just
            # releases the buffer early — silence XLA's per-compile nag
            warnings.filterwarnings(
                "ignore", message=".*donated buffers were not usable.*"
            )
            ex = pp.cached_executable(
                key, fn, xp, dep.frozen,
                donate_argnums=(0,) if self.donate else (),
            )
        self._compiled[pin_key] = ex
        return ex

    def _place(self, xp: np.ndarray) -> jax.Array:
        if self._dp(xp.shape[0]):
            return jax.device_put(xp, self._x_sharding)
        return jnp.asarray(xp)

    def warmup(self, buckets: Optional[Sequence[int]] = None) -> dict:
        """AOT-compile (and cache) every bucket's executable now.

        Deploy-time cost instead of first-request latency.  Returns
        {bucket: compile_seconds}.
        """
        out = {}
        for b in (self.buckets if buckets is None else buckets):
            xp = self._place(self._example(b))
            t0 = time.perf_counter()
            self._executable(xp)
            out[b] = time.perf_counter() - t0
        return out

    def infer(self, x) -> np.ndarray:
        """Serve one request batch: pad to bucket, run, slice.

        ``x``: (B, h, w) images ((B, C, h, w) for the RGB family), any B.
        Batches wider than the largest bucket chunk through it.  Returns
        the (B, ...) outputs as numpy (the host sync is the response).
        """
        x = np.asarray(x)
        if x.ndim == self._x_ndim() - 1:
            x = x[None]
        b_max = self.buckets[-1]
        outs = []
        for lo in range(0, x.shape[0], b_max):
            chunk = x[lo: lo + b_max]
            bucket = bucket_for(chunk.shape[0], self.buckets)
            xp = self._place(pad_batch(chunk, bucket))
            ex = self._executable(xp)
            out = ex(xp, self.deployed.frozen)
            outs.append(np.asarray(out)[: chunk.shape[0]])
            self.stats["batches"] += 1
            self.stats["requests"] += int(chunk.shape[0])
            self.stats["padded_rows"] += bucket - int(chunk.shape[0])
        return np.concatenate(outs, axis=0)


def expected_request_shape(deployed: DeployedDONN) -> tuple:
    """Per-request input shape a deployment serves ((C,n,n) for RGB)."""
    cfg = deployed.cfg
    n = cfg.input_size
    if deployed.family == "multi":
        return (cfg.channels, n, n)
    return (n, n)


def validate_request(deployed: DeployedDONN, x: np.ndarray) -> None:
    """Admission-time request validation shared by every dispatcher.

    Raises ``TypeError``/``ValueError`` on a request that could poison a
    batch (wrong dtype kind / per-request shape) — the door check both
    ``MicroBatcher.submit`` and ``runtime.fleet.FleetRouter.submit`` run.
    """
    if not (np.issubdtype(x.dtype, np.floating)
            or np.issubdtype(x.dtype, np.integer)
            or np.issubdtype(x.dtype, np.bool_)):
        raise TypeError(
            f"request dtype {x.dtype} is not castable to float32"
        )
    exp = expected_request_shape(deployed)
    if x.shape != exp:
        raise ValueError(
            f"request shape {x.shape} != expected per-request shape "
            f"{exp} for the {deployed.family!r} family"
        )


class _Request:
    """One queued inference request (slots: this sits on the hot path)."""

    __slots__ = ("x", "future", "t_arrival", "deadline")

    def __init__(self, x, future, t_arrival, deadline):
        self.x = x
        self.future = future
        self.t_arrival = t_arrival
        self.deadline = deadline  # absolute perf_counter time, or None


class MicroBatcher:
    """Batch-full-or-deadline request dispatcher over an ``InferenceEngine``.

    ``submit(x)`` enqueues one request (a single image / image stack) and
    returns a ``concurrent.futures.Future``; a background worker drains
    the queue whenever the largest bucket fills or the oldest queued
    request has waited ``max_wait_ms``, pads the group to the nearest
    bucket and serves it as one device call.

    Hardened for real traffic (``repro.runtime.resilience``):

    - **bounded admission** — at most ``max_queue`` requests wait; beyond
      that ``submit`` sheds with ``OverloadedError`` instead of growing
      the queue (and the tail latency) without bound;
    - **per-request deadlines** — ``submit(x, timeout_ms=...)`` fails the
      future with ``DeadlineExceededError`` once the deadline passes
      undispatched, instead of waiting forever behind a stall;
    - **submit-time validation** — shape/dtype mismatches are rejected at
      the door (``ValueError``/``TypeError``) before they can poison a
      batch (``validate=False`` restores trust-the-caller behavior);
    - **group bisection** — a group that fails to serve is split in half
      and retried, so one poison request fails only its own future while
      the rest of the group still gets results;
    - **accounted shutdown** — ``close()`` returns True for a clean drain;
      on an unclean join it fails every unresolved future and returns
      False instead of silently stranding callers.
    """

    def __init__(self, engine: InferenceEngine, max_wait_ms: float = 2.0,
                 max_queue: Optional[int] = 1024, validate: bool = True):
        self.engine = engine
        self.max_wait_s = max_wait_ms / 1e3
        self.max_queue = None if not max_queue else int(max_queue)
        self.validate = validate
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._pending: list = []  # [_Request]
        self._inflight: list = []  # group currently being served
        self._closed = False
        self.stats = {"submitted": 0, "served": 0, "shed": 0, "expired": 0,
                      "failed": 0}
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()

    # --- admission ---
    def _expected_shape(self) -> tuple:
        return expected_request_shape(self.engine.deployed)

    def _validate(self, x: np.ndarray):
        validate_request(self.engine.deployed, x)

    def submit(self, x, timeout_ms: Optional[float] = None) -> Future:
        """Enqueue one request; returns a Future resolving to its output.

        Raises ``OverloadedError`` when the admission queue is full (load
        shedding — the caller should back off / retry elsewhere) and
        ``ValueError``/``TypeError`` on malformed requests when
        ``validate`` is on.  With ``timeout_ms`` set, the future fails
        with ``DeadlineExceededError`` if still undispatched then.
        """
        x = np.asarray(x)
        if self.validate:
            self._validate(x)
        now = time.perf_counter()
        deadline = None if timeout_ms is None else now + timeout_ms / 1e3
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("MicroBatcher is closed")
            if (self.max_queue is not None
                    and len(self._pending) >= self.max_queue):
                self.stats["shed"] += 1
                raise OverloadedError(
                    f"admission queue full ({self.max_queue} pending)"
                )
            self._pending.append(_Request(x, fut, now, deadline))
            self.stats["submitted"] += 1
            self._cv.notify()
        return fut

    # --- dispatch ---
    def _split_expired(self, now: float) -> list:
        """Pop expired requests off the queue (caller holds the lock)."""
        expired = [r for r in self._pending
                   if r.deadline is not None and now >= r.deadline]
        if expired:
            self._pending = [r for r in self._pending if r not in expired]
        return expired

    def _take(self) -> tuple:
        """Block until work is ready: (group_to_serve, expired_requests).

        Both empty means the batcher is closed and drained.
        """
        b_max = self.engine.buckets[-1]
        with self._cv:
            while True:
                now = time.perf_counter()
                expired = self._split_expired(now)
                if expired:
                    return [], expired
                if self._closed and not self._pending:
                    return [], []
                if self._pending:
                    if len(self._pending) >= b_max or self._closed:
                        break
                    timeout = self.max_wait_s - (now - self._pending[0].t_arrival)
                    dls = [r.deadline for r in self._pending
                           if r.deadline is not None]
                    if dls:
                        timeout = min(timeout, min(dls) - now)
                    if timeout <= 0:
                        break
                    self._cv.wait(timeout=timeout)
                else:
                    self._cv.wait(timeout=0.1)
            group = self._pending[:b_max]
            del self._pending[:len(group)]
            self._inflight = group
            return group, []

    def _serve(self, group: list):
        """Serve a group; on failure bisect so only poison requests fail."""
        try:
            # the stack is inside the try: a malformed request (e.g. a
            # mismatched image shape with validate off) must fail, not
            # kill the worker and hang every later submit
            xs = np.stack([r.x for r in group])
            outs = self.engine.infer(xs)
        except Exception as e:  # noqa: BLE001 - propagate to callers
            if len(group) == 1:
                if not group[0].future.done():
                    group[0].future.set_exception(e)
                self.stats["failed"] += 1
                return
            mid = len(group) // 2
            self._serve(group[:mid])
            self._serve(group[mid:])
            return
        for r, out in zip(group, outs):
            if not r.future.done():
                r.future.set_result(out)
            self.stats["served"] += 1

    def _run(self):
        while True:
            group, expired = self._take()
            for r in expired:
                if not r.future.done():
                    r.future.set_exception(DeadlineExceededError(
                        "request deadline expired before dispatch"
                    ))
                self.stats["expired"] += 1
            if not group and not expired:
                return
            if group:
                self._serve(group)
                with self._cv:
                    self._inflight = []

    def close(self, timeout: float = 30.0) -> bool:
        """Drain the queue and stop the worker.

        Returns True on a clean drain.  If the worker fails to join
        within ``timeout`` seconds (e.g. wedged inside a device call),
        every unresolved pending/in-flight future is failed with a
        ``RuntimeError`` so no caller blocks forever, and False is
        returned — callers that care must check it.
        """
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        self._worker.join(timeout=timeout)
        if not self._worker.is_alive():
            return True
        with self._cv:
            stranded = self._pending + self._inflight
            self._pending = []
        err = RuntimeError(
            f"MicroBatcher shutdown unclean: worker did not join within "
            f"{timeout}s; {len(stranded)} request(s) abandoned"
        )
        for r in stranded:
            if not r.future.done():
                r.future.set_exception(err)
        return False
