"""Trip-count-aware cost analysis over compiled (post-SPMD) HLO text.

Why: ``compiled.cost_analysis()`` does NOT multiply while-loop bodies by
their trip counts, so for scan-over-layers models it reports ~one layer of
FLOPs.  This module re-derives FLOPs / HBM bytes / collective bytes from
``compiled.as_text()`` with proper loop accounting:

1. parse every computation and its ops (dtype, shape, opcode, attrs);
2. walk the call graph from ENTRY, accumulating execution multipliers —
   while bodies multiply by the trip count recovered from the loop-bound
   ``constant(N)`` in their condition computation; fusion/call/reduce
   recurse with multiplier x1;
3. FLOPs: dot = 2*prod(out)*K (K from lhs contracting dims); elementwise
   arithmetic = prod(out); reduce = prod(in);
4. HBM bytes: operands+outputs of ops at fusion boundaries only (ops inside
   fused computations are compute-counted but not byte-counted);
5. collective bytes per device with ring-transfer factors:
   all-gather (g-1)/g * out, all-reduce 2*(g-1)/g * out,
   reduce-scatter (g-1)*out, all-to-all (g-1)/g * out, permute = out.

Validated against compiled.cost_analysis() on loop-free programs
(tests/test_hlo_analysis.py).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "log", "log-plus-one", "exponential-minus-one", "tanh",
    "rsqrt", "sqrt", "power", "negate", "abs", "floor", "ceil", "cosine",
    "sine", "logistic", "remainder", "atan2", "cbrt", "erf", "sign",
    "and", "or", "xor", "not", "shift-left", "shift-right-logical",
    "shift-right-arithmetic", "clamp", "select",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}

_SKIP_BYTES = {
    "parameter", "tuple", "get-tuple-element", "constant", "while",
    "conditional", "bitcast", "bitcast-convert", "partition-id",
    "replica-id", "after-all", "iota",
}

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%(?P<name>[^\s=]+)\s*=\s*"
    r"(?P<type>\([^()]*\)|[\w]+\[[^\]]*\](?:\{[^}]*\})?)\s+"
    r"(?P<opcode>[\w-]+)\((?P<rest>.*)$"
)
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?(?P<name>[^\s(]+)\s+\(.*->")
_SHAPE_RE = re.compile(r"^(?P<dtype>\w+)\[(?P<dims>[\d,]*)\]")


@dataclasses.dataclass
class Op:
    name: str
    dtype: str
    shape: tuple
    opcode: str
    rest: str  # operands + attributes

    @property
    def nbytes(self) -> int:
        if self.dtype is None:
            return 0
        return math.prod(self.shape) * _DTYPE_BYTES.get(self.dtype, 4)

    @property
    def nelems(self) -> int:
        return math.prod(self.shape)


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    symtab: Dict[str, Op]


def _parse_type(t: str):
    m = _SHAPE_RE.match(t)
    if not m:
        return None, ()
    dims = tuple(int(d) for d in m.group("dims").split(",") if d)
    return m.group("dtype"), dims


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.rstrip().endswith("{"):
                name = m.group("name").lstrip("%")
                cur = Computation(name, [], {})
                if line.startswith("ENTRY"):
                    entry = name
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        dtype, shape = _parse_type(m.group("type"))
        op = Op(m.group("name"), dtype, shape, m.group("opcode"), m.group("rest"))
        cur.ops.append(op)
        cur.symtab[op.name] = op
    if cur is not None:
        comps[cur.name] = cur
    comps["__entry__"] = comps[entry]  # alias
    return comps


def _const_value(op: Op) -> Optional[int]:
    m = re.match(r"(-?\d+)\)", op.rest)
    return int(m.group(1)) if m else None


def _trip_count(cond: Computation) -> int:
    """Loop bound = the constant operand of the root comparison.

    The condition computation's root is either a `compare(iv, N)` or a
    fusion wrapping one; follow the root's operands to a constant.  (A
    max-over-all-constants heuristic misfires on XLA's "wide" loops whose
    conditions carry unrelated shape constants.)
    """
    if not cond.ops:
        return 1
    root = cond.ops[-1]
    candidates = []
    # direct operands of the root that are constants
    for name in _operand_names(root.rest):
        sym = cond.symtab.get(name)
        if sym is not None and sym.opcode == "constant":
            v = _const_value(sym)
            if v is not None and v > 0:
                candidates.append(v)
    if not candidates and root.opcode == "fusion":
        called = re.search(r"calls=%?([\w.\-]+)", root.rest)
        # fused compare: the constant is still a fusion operand (param)
        for name in _operand_names(root.rest):
            sym = cond.symtab.get(name)
            if sym is not None and sym.opcode == "constant":
                v = _const_value(sym)
                if v is not None and v > 0:
                    candidates.append(v)
    if candidates:
        return min(candidates)  # compare bound, not stray shape constants
    consts = [
        v for op in cond.ops if op.opcode == "constant"
        for v in [_const_value(op)] if v is not None and v > 0
    ]
    return max(consts) if consts else 1


_CALL_ATTRS = re.compile(
    r"(?:calls=|to_apply=|body=)%?([\w.\-]+)|condition=%?([\w.\-]+)"
)


def _multipliers(comps: Dict[str, Computation]):
    """(comp -> exec multiplier, comp -> reached_via_fusion flag)."""
    mult: Dict[str, float] = {}
    fused: Dict[str, bool] = {}
    entry = comps["__entry__"].name

    def visit(cname: str, m: float, via_fusion: bool):
        mult[cname] = mult.get(cname, 0.0) + m
        fused[cname] = fused.get(cname, True) and via_fusion
        comp = comps[cname]
        for op in comp.ops:
            if op.opcode == "while":
                body = re.search(r"body=%?([\w.\-]+)", op.rest)
                cond = re.search(r"condition=%?([\w.\-]+)", op.rest)
                trips = _trip_count(comps[cond.group(1)]) if cond else 1
                if body:
                    visit(body.group(1), m * trips, via_fusion)
                if cond:
                    visit(cond.group(1), m * trips, via_fusion)
            elif op.opcode in ("fusion",):
                c = re.search(r"calls=%?([\w.\-]+)", op.rest)
                if c:
                    visit(c.group(1), m, True)
            elif op.opcode in ("call", "custom-call", "async-start"):
                c = re.search(r"(?:calls|called_computation)=%?([\w.\-]+)", op.rest)
                if c:
                    visit(c.group(1), m, via_fusion)
            elif op.opcode == "conditional":
                for c in re.findall(r"%([\w.\-]+)", op.rest):
                    if c in comps:
                        visit(c, m, via_fusion)
            # reduce/scatter/sort to_apply: scalar combiners — skipped.

    visit(entry, 1.0, False)
    fused[entry] = False
    return mult, fused


def _operand_names(rest: str) -> list:
    # operands are before the first "), " attr separator
    depth, out, cur = 0, [], []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        cur.append(ch)
    head = "".join(cur)
    return re.findall(r"%([\w.\-]+)", head)


def _dot_flops(op: Op, comp: Computation) -> float:
    ops = _operand_names(op.rest)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if ops and m and ops[0] in comp.symtab:
        lhs = comp.symtab[ops[0]]
        for d in m.group(1).split(","):
            if d:
                k *= lhs.shape[int(d)]
    return 2.0 * op.nelems * k


def _group_size(op: Op, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=", op.rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.rest)
    if m:
        return len(m.group(1).split(","))
    return default


_COLL_FACTOR = {
    "all-gather": lambda b, g: b * (g - 1) / g,
    "all-gather-start": lambda b, g: b * (g - 1) / g,
    "all-reduce": lambda b, g: 2.0 * b * (g - 1) / g,
    "all-reduce-start": lambda b, g: 2.0 * b * (g - 1) / g,
    "reduce-scatter": lambda b, g: b * (g - 1),
    "all-to-all": lambda b, g: b * (g - 1) / g,
    "collective-permute": lambda b, g: float(b),
    "collective-permute-start": lambda b, g: float(b),
}


def _op_bytes(op: Op, comp: Computation, comps: Dict[str, Computation]) -> float:
    """HBM traffic of one boundary op, modelling in-place slice/update.

    dynamic-update-slice runs in place on TPU (traffic ~ 2x the window);
    dynamic-slice reads only the window.  Both frequently live *inside*
    fusions, so for fusion ops we inspect the fused computation: parameters
    feeding a dynamic-slice are charged at window size, parameters aliased
    by a dynamic-update-slice are charged ~0 (the in-place buffer), and a
    DUS at the root suppresses the output charge.
    """
    oc = op.opcode
    if oc == "dynamic-update-slice":
        names = _operand_names(op.rest)
        upd = comp.symtab.get(names[1]) if len(names) > 1 else None
        return 2.0 * (upd.nbytes if upd is not None else 0)
    if oc == "dynamic-slice":
        return 2.0 * op.nbytes
    if oc == "convert":
        # XLA:CPU materializes bf16<->f32 casts around dots that TPU
        # performs natively in the MXU path; exclude this artifact traffic.
        return 0.0
    if oc == "fusion":
        called = re.search(r"calls=%?([\w.\-]+)", op.rest)
        fc = comps.get(called.group(1)) if called else None
        if fc is not None:
            body_ops = [
                o for o in fc.ops
                if o.opcode not in ("parameter", "constant")
            ]
            if body_ops and all(
                o.opcode in _FORWARDING for o in body_ops
            ):
                return 0.0  # pure cast/layout fusion: native on TPU
            ds_params, dus_params, dus_update_bytes, root_is_dus = (
                _fusion_slice_info(fc)
            )
            total = 0.0
            names = _operand_names(op.rest)
            for i, name in enumerate(names):
                sym = comp.symtab.get(name)
                if sym is None:
                    continue
                if i in dus_params:
                    continue  # aliased in-place buffer
                if i in ds_params:
                    total += ds_params[i]  # window-sized read
                else:
                    total += sym.nbytes
            total += dus_update_bytes * 2.0
            if not root_is_dus:
                total += op.nbytes
            return total
    total = float(op.nbytes)
    for name in _operand_names(op.rest):
        sym = comp.symtab.get(name)
        if sym is not None:
            total += sym.nbytes
    return total


_FORWARDING = {"copy", "bitcast", "bitcast-convert", "transpose", "reshape",
               "convert"}


def _fusion_slice_info(fc: Computation):
    """(param_idx -> window bytes for DS, set of DUS-aliased param idxs,
    total DUS update bytes, root-is-DUS flag) for a fused computation.

    Chains of trivial forwarding ops (copy/bitcast/transpose/...) between a
    parameter and the slice/update op are traced through, since TPU layout
    assignment performs these in place on the donated buffer.
    """
    param_idx = {}
    for o in fc.ops:
        if o.opcode == "parameter":
            # _OP_RE consumed the opening paren: rest looks like "1), ..."
            mnum = re.match(r"(\d+)\)", o.rest)
            if mnum:
                param_idx[o.name] = int(mnum.group(1))

    def resolve(name, depth=0):
        while depth < 8:
            o = fc.symtab.get(name)
            if o is None or o.opcode not in _FORWARDING:
                return name
            names = _operand_names(o.rest)
            if not names:
                return name
            name = names[0]
            depth += 1
        return name

    ds_params: Dict[int, float] = {}
    dus_params = set()
    dus_update_bytes = 0.0
    dus_names = set()
    for o in fc.ops:
        names = _operand_names(o.rest)
        if o.opcode == "dynamic-slice" and names:
            src = resolve(names[0])
            if src in param_idx:
                i = param_idx[src]
                ds_params[i] = ds_params.get(i, 0.0) + 2.0 * o.nbytes
        elif o.opcode == "dynamic-update-slice" and names:
            src = resolve(names[0])
            if src in param_idx:
                dus_params.add(param_idx[src])
            upd = fc.symtab.get(names[1]) if len(names) > 1 else None
            if upd is not None:
                dus_update_bytes += upd.nbytes
            dus_names.add(o.name)
    root = fc.ops[-1] if fc.ops else None
    root_is_dus = root is not None and resolve(root.name) in dus_names
    return ds_params, dus_params, dus_update_bytes, root_is_dus


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_breakdown: dict = dataclasses.field(default_factory=dict)
    dot_flops: float = 0.0
    elementwise_flops: float = 0.0

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(text: str, default_group: int = 1) -> HloCost:
    comps = parse_hlo(text)
    mult, fused = _multipliers(comps)
    cost = HloCost()
    for cname, m in mult.items():
        if cname == "__entry__":
            continue
        comp = comps[cname]
        in_fusion = fused.get(cname, False)
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                f = _dot_flops(op, comp) * m
                cost.flops += f
                cost.dot_flops += f
            elif oc in _ELEMENTWISE:
                cost.flops += op.nelems * m
                cost.elementwise_flops += op.nelems * m
            elif oc == "reduce":
                onames = _operand_names(op.rest)
                if onames and onames[0] in comp.symtab:
                    cost.flops += comp.symtab[onames[0]].nelems * m
            if oc in _COLLECTIVES:
                g = _group_size(op, default_group)
                b = _COLL_FACTOR[oc](op.nbytes, max(g, 1))
                cost.collective_bytes += b * m
                key = oc.replace("-start", "")
                cost.collective_breakdown[key] = (
                    cost.collective_breakdown.get(key, 0.0) + b * m
                )
            # HBM bytes: fusion-boundary accounting
            if not in_fusion and oc not in _SKIP_BYTES:
                cost.bytes += _op_bytes(op, comp, comps) * m
    return cost
