"""Logical-axis sharding rules (MaxText-style) with divisibility fallback.

Parameters and activations carry *logical* axis names (see nn.ParamSpec);
rules map logical names to mesh axes.  ``resolve_pspec`` drops a mapping
when the dim is not divisible by the mesh-axis extent (e.g. kv_heads=2 on a
16-way model axis -> replicated), which keeps one rule set valid across all
10 architectures.

Default layout (DESIGN.md §8):
  batch      -> (pod, data)   data parallel
  embed      -> data          FSDP: params + optimizer states sharded
  vocab/heads/kv_heads/mlp/expert -> model   TP / EP
  field_w    -> model         DONN spatial model-parallel (pencil FFT)
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.nn import ParamSpec, is_spec

class ShardingRulesError(ValueError):
    """A rules table maps conflicting logical axes onto one mesh axis.

    Raised (rather than silently picking a winner) when ``batch`` and
    ``field_h`` — the two axes that define the 2-D ``(data, model)``
    layout — claim the same mesh axis: sharding the batch and the field
    rows over one axis would make every device see a *different* row
    block of a *different* batch shard, which is never the intended
    layout and produces silently wrong psums.
    """


DEFAULT_RULES: dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": "model",  # Megatron-style sequence parallelism: the residual
    #                  stream between layers shards S over the TP axis, so
    #                  saved layer-boundary activations are 1/TP the size;
    #                  GSPMD inserts the AG/RS pair around each block.
    "embed": ("data", "pod"),  # FSDP + ZeRO-across-pods: parameters and
    #                  optimizer moments shard over data AND pod axes
    #                  (32-way on the 512-chip mesh) — cross-pod traffic is
    #                  the per-layer gather, compressible (optim.compression)
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head": "model",  # head_dim fallback: shards KV caches when kv_heads
    #                   is not divisible by the model axis (GQA/MQA archs)
    "mlp": "model",
    "expert": "model",
    "channel": None,
    "layers": None,
    "field_h": None,
    "field_w": "model",
    "population": ("pod", "data"),  # DSE candidate stacks: generations of
    #                  K candidates shard over the DP axes, composing with
    #                  field_h -> model (population x spatial on one mesh)
    "classes": None,
}


def donn_rules(*, data="data", model="model") -> dict:
    """THE unified DONN rules table for the 2-D ``(data, model)`` mesh.

    One table consumed by training (``donn_steps.make_donn_sharded_loss``),
    serving (``InferenceEngine(model_devices=...)``) and DSE stacks:

      batch / population -> (pod, data)   data parallel
      field_h            -> model         spatial rows (pencil FFT)
      field_w / channel  -> replicated    (W is the locally-full FFT axis)

    Validated by :func:`check_rules` — ``batch`` and ``field_h`` on the
    same mesh axis raise :class:`ShardingRulesError`.
    """
    return check_rules({
        **DEFAULT_RULES,
        "batch": ("pod", data),
        "population": ("pod", data),
        "field_h": model,
        "field_w": None,
    })


def check_rules(rules: Mapping[str, Any]) -> Mapping[str, Any]:
    """Typed validation of a rules table: batch/field_h must not collide."""
    def flat(v):
        return () if v is None else ((v,) if isinstance(v, str) else tuple(v))

    overlap = set(flat(rules.get("batch"))) & set(flat(rules.get("field_h")))
    if overlap:
        raise ShardingRulesError(
            f"'batch' and 'field_h' both map onto mesh axis "
            f"{sorted(overlap)[0]!r}: the data and spatial layouts would "
            f"alias — give each its own mesh axis (see make_mesh_2d)"
        )
    return rules


def make_mesh_2d(data: int = 1, model: int = 1, *, devices=None) -> Mesh:
    """The canonical 2-D ``(data, model)`` mesh every DONN consumer uses.

    ``data`` x ``model`` devices (defaults: 1x1, valid on a single host
    device): batch/population shard over ``data``, field rows over
    ``model`` (pencil FFT).  Replaces the ad-hoc per-call-site
    ``compat.make_mesh`` constructions — one entry point, one axis-name
    spelling, paired with the :func:`donn_rules` table.
    """
    devs = list(devices if devices is not None else jax.devices())
    need = int(data) * int(model)
    if need > len(devs):
        raise ValueError(
            f"make_mesh_2d needs {need} devices "
            f"({data} data x {model} model), have {len(devs)}"
        )
    arr = np.asarray(devs[:need], dtype=object).reshape(int(data), int(model))
    return Mesh(arr, ("data", "model"))


def spatial_rules(axis: str = "model") -> dict:
    """Row-sharded DONN spatial layout (pencil FFT inside the scan body).

    The in-scan distributed spectral hop keeps fields, TF planes and
    trainable phases sharded along H (``field_h``) over one mesh axis —
    ``repro.runtime.pencil_fft.local_spectral_pair`` transposes to/from
    the W-sharded layout internally per FFT.  ``field_w`` replicates (it
    is the locally-full axis between transposes).
    """
    return {**DEFAULT_RULES, "field_h": axis, "field_w": None}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        if a not in mesh.shape:
            return 0  # axis not present in this mesh -> unmappable
        size *= mesh.shape[a]
    return size


def _present(mesh: Mesh, axes):
    """Filter an axis (or tuple) down to axes present in the mesh."""
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes if axes in mesh.shape else None
    kept = tuple(a for a in axes if a in mesh.shape)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def present_axes(mesh: Mesh, axes):
    """Public form of :func:`_present` (rule axes filtered to the mesh)."""
    return _present(mesh, axes)


def _flat_axes(axes) -> tuple:
    return () if axes is None else (
        (axes,) if isinstance(axes, str) else tuple(axes)
    )


def _check_batch_field_collision(logical_axes, mesh, rules) -> None:
    """Typed error when batch and field_h resolve onto one mesh axis."""
    names = [n for n in logical_axes if n]
    if "batch" not in names or "field_h" not in names:
        return
    b = set(_flat_axes(_present(mesh, rules.get("batch"))))
    h = set(_flat_axes(_present(mesh, rules.get("field_h"))))
    if b & h:
        raise ShardingRulesError(
            f"'batch' and 'field_h' both resolve to mesh axis "
            f"{sorted(b & h)[0]!r} on {tuple(mesh.shape)}: refusing to "
            f"silently pick a winner — fix the rules table (donn_rules "
            f"gives batch->data, field_h->model)"
        )


def rules_pspec(
    logical_axes: Sequence[Optional[str]],
    rules: Optional[Mapping[str, Any]] = None,
    mesh: Optional[Mesh] = None,
) -> P:
    """Logical axis names -> PartitionSpec through the rules table.

    The shard_map companion of :func:`resolve_pspec`: manual-region
    in/out specs must divide exactly (shard_map checks shapes itself),
    so there is no shape/divisibility fallback here — but duplicate mesh
    -axis use across dims raises :class:`ShardingRulesError` instead of
    silently mis-sharding.  With ``mesh`` given, rule axes absent from
    the mesh drop to replicated (so one spec spelling serves 1-D and
    2-D meshes).
    """
    rules = rules or DEFAULT_RULES
    out, used = [], set()
    for name in logical_axes:
        axes = rules.get(name) if name else None
        if mesh is not None:
            axes = _present(mesh, axes)
        flat = _flat_axes(axes)
        dup = sorted(set(flat) & used)
        if dup:
            raise ShardingRulesError(
                f"mesh axis {dup[0]!r} claimed by more than one logical "
                f"axis in {tuple(logical_axes)}"
            )
        used.update(flat)
        out.append(axes if flat else None)
    return P(*out)


def dim0_pspec(axes, ndim: int) -> P:
    """PartitionSpec sharding dim 0 over ``axes``, rest replicated."""
    if not _flat_axes(axes):
        return P(*([None] * ndim))
    return P(axes, *([None] * (ndim - 1)))


def replicated_pspec(ndim: int = 0) -> P:
    return P(*([None] * ndim))


def with_leading(spec: P, lead: int = 1) -> P:
    """Shift a spec right of ``lead`` unsharded leading axes (chunk dims)."""
    return P(*((None,) * lead + tuple(spec)))


def resolve_pspec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Mapping[str, Any]] = None,
) -> P:
    """Map logical axes to mesh axes; drop non-divisible or duplicate uses.

    A mesh axis is consumed at most once per array (first dim wins), so
    fallback rules — e.g. kv_heads and head both mapping to "model" — give
    "shard whichever dim divides, preferring the earlier one".  The one
    pair that does NOT silently fall back is ``batch``/``field_h``: both
    resolving to one mesh axis is a rules-table bug (the 2-D layouts
    alias) and raises :class:`ShardingRulesError`.  A ``field_h`` dim not
    divisible by the model-axis extent cleanly drops to replicated like
    every other dim.
    """
    rules = rules or DEFAULT_RULES
    _check_batch_field_collision(logical_axes, mesh, rules)
    out = []
    used: set = set()
    for dim, name in zip(shape, logical_axes):
        axes = _present(mesh, rules.get(name)) if name else None
        if axes is not None:
            flat = (axes,) if isinstance(axes, str) else tuple(axes)
            if any(a in used for a in flat):
                axes = None
        size = _axis_size(mesh, axes) if axes else 1
        if axes is None or size <= 1 or dim % size != 0:
            out.append(None)  # replicate: unmapped, non-divisible, or dup
        else:
            out.append(axes)
            used.update((axes,) if isinstance(axes, str) else axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def operand_pspec(
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    mesh: Mesh,
    rules: Optional[Mapping[str, Any]] = None,
) -> P:
    """:func:`resolve_pspec` without the trailing-None trim.

    shard_map operand specs must be full rank, but still want the
    divisibility fallback (e.g. the (L, 1, 1) int8 plane scales riding a
    row-sharded frozen stack replicate instead of erroring).
    """
    spec = tuple(resolve_pspec(shape, logical_axes, mesh, rules))
    return P(*(spec + (None,) * (len(tuple(shape)) - len(spec))))


def spec_sharding(spec: ParamSpec, mesh: Mesh, rules=None) -> NamedSharding:
    axes = spec.logical_axes or (None,) * len(spec.shape)
    return NamedSharding(mesh, resolve_pspec(spec.shape, axes, mesh, rules))


def tree_shardings(specs, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: spec_sharding(s, mesh, rules), specs, is_leaf=is_spec
    )


def tree_pspecs(specs, mesh: Mesh, rules=None):
    return jax.tree.map(
        lambda s: resolve_pspec(
            s.shape, s.logical_axes or (None,) * len(s.shape), mesh, rules
        ),
        specs,
        is_leaf=is_spec,
    )


def batch_sharding(mesh: Mesh, ndim: int, rules=None,
                   batch_size: Optional[int] = None) -> NamedSharding:
    """Shard dim 0 (global batch) over the DP axes; rest replicated.

    If ``batch_size`` is given, axes are dropped (right-to-left) until the
    remaining product divides it (e.g. global_batch=1 -> replicated).
    """
    rules = rules or DEFAULT_RULES
    axes = _present(mesh, rules.get("batch"))
    if axes is None:
        return NamedSharding(mesh, P(*([None] * ndim)))
    flat = (axes,) if isinstance(axes, str) else tuple(axes)
    if batch_size is not None:
        while flat and batch_size % _axis_size(mesh, flat) != 0:
            flat = flat[:-1]
    if not flat:
        return NamedSharding(mesh, P(*([None] * ndim)))
    axes = flat if len(flat) > 1 else flat[0]
    return NamedSharding(mesh, P(axes, *([None] * (ndim - 1))))


def scalar_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# Activation sharding constraints.  Model code calls ``constrain(x, axes)``
# with logical axis names; it is a no-op unless a mesh context is active
# (set by the runtime step builders at trace time), so pure model code
# stays mesh-agnostic and works on a single device.
# ----------------------------------------------------------------------
import contextlib
import contextvars

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_active_mesh", default=None
)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules=None):
    token = _ACTIVE.set((mesh, rules or DEFAULT_RULES))
    try:
        yield
    finally:
        _ACTIVE.reset(token)


def constrain(x, logical_axes: Sequence[Optional[str]],
              require: Optional[str] = None):
    """Apply a logical-axis sharding constraint if it resolves.

    - no mesh context (single-device tests): no-op;
    - nothing maps: no-op (don't force replication);
    - ``require=<name>``: apply only if that logical axis actually mapped —
      used for all-or-nothing layouts (e.g. the EP-resident MoE constraints
      are wrong when n_experts < TP degree).
    """
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = resolve_pspec(x.shape, logical_axes, mesh, rules)
    padded = tuple(spec) + (None,) * (len(logical_axes) - len(spec))
    if all(s is None for s in padded):
        return x
    if require is not None:
        idx = list(logical_axes).index(require)
        if padded[idx] is None:
            return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def abstract_like(specs):
    """ParamSpec tree -> ShapeDtypeStruct tree (dry-run stand-ins)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
    )


def sharded_zeros(specs, mesh: Mesh, rules=None):
    """Materialize a zeroed, sharded pytree from specs (for real runs)."""
    def mk(s):
        sh = spec_sharding(s, mesh, rules)
        return jax.make_array_from_callback(
            s.shape, sh, lambda idx: np.zeros(
                tuple(len(range(*i.indices(d))) for i, d in zip(idx, s.shape)),
                s.dtype,
            )
        )
    return jax.tree.map(mk, specs, is_leaf=is_spec)
