"""pjit train / prefill / decode steps shared by the launcher and dry-run.

``TrainState`` is a plain dict {params, mu, nu, step}; optimizer states
reuse the parameter ParamSpecs so ZeRO-style optimizer sharding follows the
same logical-axis rules (FSDP over "data", and over "pod" too when the rule
maps batch across pods).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.models import lm
from repro.models.config import LMConfig
from repro.nn import ParamSpec, is_spec
from repro.optim import AdamW
from repro.runtime import sharding as shd


# ----------------------------------------------------------------- specs
def train_state_specs(cfg: LMConfig, state_dtype=jnp.float32,
                      param_dtype=None):
    pspecs = lm.param_specs(cfg)
    if param_dtype is not None:  # e.g. bf16 params for memory-bound cells
        pspecs = jax.tree.map(
            lambda s: ParamSpec(s.shape, param_dtype, s.logical_axes,
                                init=s.init, scale=s.scale),
            pspecs, is_leaf=is_spec,
        )

    def opt_spec(s: ParamSpec) -> ParamSpec:
        return ParamSpec(s.shape, state_dtype, s.logical_axes, init="zeros")

    return {
        "params": pspecs,
        "mu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "nu": jax.tree.map(opt_spec, pspecs, is_leaf=is_spec),
        "step": ParamSpec((), jnp.int32, (), init="zeros"),
    }


def init_train_state(cfg: LMConfig, key, optimizer: AdamW):
    params = lm.init(cfg, key)
    opt = optimizer.init(params)
    return {
        "params": params, "mu": opt.mu, "nu": opt.nu,
        "step": jnp.zeros((), jnp.int32),
    }


# ----------------------------------------------------------------- steps
def make_train_step(
    cfg: LMConfig,
    optimizer: AdamW,
    accum_steps: int = 1,
    accum_dtype=jnp.float32,
    cast_params_to=None,
) -> Callable:
    """(state, batch) -> (state, metrics). batch dim 0 = global batch.

    ``cast_params_to=bf16`` casts the f32 master params once per step
    before the forward, so FSDP weight all-gathers (and remat re-gathers)
    move half the bytes; grads flow back through the cast to f32 masters.
    """

    def loss_fn(params, batch):
        if cast_params_to is not None:
            params = jax.tree.map(
                lambda x: x.astype(cast_params_to)
                if jnp.issubdtype(x.dtype, jnp.floating) else x,
                params,
            )
        return lm.lm_loss(params, batch, cfg)

    def step(state, batch):
        from repro.optim.adamw import AdamWState

        if accum_steps > 1:
            def micro(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(state["params"], mb)
                gsum = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32)).astype(a.dtype),
                    gsum, g,
                )
                return (gsum, lsum + l), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch
            )
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), state["params"]
            )
            (grads, loss), _ = jax.lax.scan(
                micro, (zeros, jnp.float32(0.0)), mb_batch
            )
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
            loss = loss / accum_steps
        else:
            loss, grads = jax.value_and_grad(loss_fn)(state["params"], batch)
        new_p, new_opt = optimizer.update(
            grads, AdamWState(state["mu"], state["nu"]),
            state["params"], state["step"],
        )
        new_state = {
            "params": new_p, "mu": new_opt.mu, "nu": new_opt.nu,
            "step": state["step"] + 1,
        }
        metrics = {"loss": loss, "grad_norm": _global_norm(grads)}
        return new_state, metrics

    return step


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def make_prefill_step(cfg: LMConfig) -> Callable:
    def prefill(params, batch):
        vision = batch.get("vision")
        return lm.logits_fn(params, batch["tokens"], cfg, vision)

    return prefill


def make_decode_step(cfg: LMConfig) -> Callable:
    def decode(params, cache, tokens, pos):
        return lm.decode_step(params, cache, tokens, pos, cfg)

    return decode


# ----------------------------------------------------- jit compilation
def compile_train_step(
    cfg: LMConfig,
    mesh: Mesh,
    batch_specs: dict,
    optimizer: Optional[AdamW] = None,
    rules=None,
    accum_steps: int = 1,
    donate: bool = True,
    state_dtype=jnp.float32,
    param_dtype=None,
    accum_dtype=jnp.float32,
    cast_params_to=None,
):
    """Returns (jitted_fn, state_shardings, batch_shardings, state_specs)."""
    optimizer = optimizer or AdamW(lr=1e-4, grad_clip_norm=1.0,
                                   state_dtype=state_dtype)
    sspecs = train_state_specs(cfg, state_dtype=state_dtype,
                               param_dtype=param_dtype)
    s_shard = shd.tree_shardings(sspecs, mesh, rules)
    b_shard = jax.tree.map(
        lambda s: shd.batch_sharding(mesh, len(s.shape), rules,
                                     batch_size=s.shape[0]), batch_specs
    )
    metrics_shard = {
        "loss": shd.scalar_sharding(mesh),
        "grad_norm": shd.scalar_sharding(mesh),
    }
    base = make_train_step(cfg, optimizer, accum_steps, accum_dtype,
                           cast_params_to)

    def with_ctx(state, batch):
        with shd.activation_sharding(mesh, rules):
            return base(state, batch)

    fn = jax.jit(
        with_ctx,
        in_shardings=(s_shard, b_shard),
        out_shardings=(s_shard, metrics_shard),
        donate_argnums=(0,) if donate else (),
    )
    return fn, s_shard, b_shard, sspecs


def serving_param_specs(cfg: LMConfig, param_dtype=None):
    """Inference params (no masters needed): optionally bf16."""
    pspecs = lm.param_specs(cfg)
    if param_dtype is not None:
        pspecs = jax.tree.map(
            lambda s: ParamSpec(s.shape, param_dtype, s.logical_axes,
                                init=s.init, scale=s.scale),
            pspecs, is_leaf=is_spec,
        )
    return pspecs


def compile_prefill_step(cfg: LMConfig, mesh: Mesh, batch_specs, rules=None,
                         param_dtype=None):
    pspecs = serving_param_specs(cfg, param_dtype)
    p_shard = shd.tree_shardings(pspecs, mesh, rules)
    b_shard = jax.tree.map(
        lambda s: shd.batch_sharding(mesh, len(s.shape), rules,
                                     batch_size=s.shape[0]), batch_specs
    )
    b0 = next(iter(batch_specs.values())).shape[0]
    logits_shard = NamedSharding(
        mesh, shd.resolve_pspec((b0, 1, cfg.vocab),
                                ("batch", None, "vocab"), mesh, rules)
    )
    base = make_prefill_step(cfg)

    def with_ctx(params, batch):
        with shd.activation_sharding(mesh, rules):
            return base(params, batch)

    fn = jax.jit(
        with_ctx,
        in_shardings=(p_shard, b_shard),
        out_shardings=logits_shard,
    )
    return fn, p_shard, b_shard, pspecs


def compile_decode_step(
    cfg: LMConfig, mesh: Mesh, batch: int, cache_len: int, rules=None,
    donate: bool = True,
):
    pspecs = lm.param_specs(cfg)
    cspecs = lm.cache_specs(cfg, batch, cache_len)
    p_shard = shd.tree_shardings(pspecs, mesh, rules)
    c_shard = shd.tree_shardings(cspecs, mesh, rules)
    tok_shard = shd.batch_sharding(mesh, 2, rules, batch_size=batch)
    pos_shard = shd.scalar_sharding(mesh)
    logits_shard = NamedSharding(
        mesh, shd.resolve_pspec((batch, 1, cfg.vocab),
                                ("batch", None, "vocab"), mesh, rules)
    )
    base = make_decode_step(cfg)

    def with_ctx(params, cache, tokens, pos):
        with shd.activation_sharding(mesh, rules):
            return base(params, cache, tokens, pos)

    fn = jax.jit(
        with_ctx,
        in_shardings=(p_shard, c_shard, tok_shard, pos_shard),
        out_shardings=(logits_shard, c_shard),
        donate_argnums=(1,) if donate else (),
    )
    return fn, p_shard, c_shard, cspecs
