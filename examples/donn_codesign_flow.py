"""End-to-end driver: the paper's four-step design flow (Fig. 3).

  (1) LightRidge-DSE explores (unit size, distance) for the target task;
  (2) codesign training with hardware quantization (QAT, 256-level SLM);
  (3) fabrication export (weight_fab -> SLM levels / 3D-print thickness);
  (4) deployment check: hard-quantized inference accuracy ~ trained.

    PYTHONPATH=src python examples/donn_codesign_flow.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import DONNConfig, build_model
from repro.core import codesign as cd
from repro.core.dse import LightRidgeDSE
from repro.core.regularization import calibrate_gamma
from repro.core.train_utils import evaluate_classifier, train_classifier
from repro.data import batch_iterator, synth_digits

N, TRAIN_STEPS = 64, 300
xs, ys = synth_digits(1024, seed=0)


def short_emulation(point) -> float:
    """Fast accuracy proxy used by the DSE engine."""
    lam, d, D = point
    cfg = DONNConfig(name="dse", n=N, pixel_size=float(d),
                     wavelength=float(lam), distance=float(D), depth=2,
                     det_size=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    res = train_classifier(model, params,
                           batch_iterator(xs, ys, 64, seed=1), steps=12,
                           lr=0.5)
    return evaluate_classifier(model, res.params,
                               batch_iterator(xs, ys, 64, seed=2), 2)


def main():
    # ---- (1) DSE: train the analytical model at 2 wavelengths, apply at 532
    print("== step 1: LightRidge-DSE ==")
    grid_d = np.linspace(12e-6, 48e-6, 4)
    grid_D = np.linspace(0.02, 0.08, 4)
    pts, accs = [], []
    for lam in (432e-9, 632e-9):
        for d in grid_d:
            for D in grid_D:
                pts.append((lam, float(d), float(D)))
                accs.append(short_emulation(pts[-1]))
    dse = LightRidgeDSE(n_estimators=200).fit(pts, accs)
    res = dse.explore(532e-9, [(float(d), float(D)) for d in grid_d
                               for D in grid_D],
                      emulate=short_emulation, top_k=2)
    best = res.best_point
    print(f"DSE chose unit={best['unit_size']*1e6:.0f}um "
          f"distance={best['distance']*100:.0f}cm "
          f"(verified acc {res.verified_acc:.3f}, "
          f"{res.speedup:.0f}x fewer emulations than grid search)")

    # ---- (2) codesign training with QAT on the chosen design
    print("== step 2: hardware-aware (QAT) training ==")
    cfg = DONNConfig(name="codesign", n=N, pixel_size=best["unit_size"],
                     wavelength=532e-9, distance=best["distance"], depth=3,
                     det_size=8, codesign="qat", device_levels=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    g = calibrate_gamma(model, params, jnp.asarray(xs[:16]))
    cfg = dataclasses.replace(cfg, gamma=g)
    model = build_model(cfg)
    res_t = train_classifier(model, params,
                             batch_iterator(xs, ys, 64, seed=3),
                             steps=TRAIN_STEPS, lr=0.5, log_every=60)
    acc_train = evaluate_classifier(model, res_t.params,
                                    batch_iterator(xs, ys, 128, seed=4), 4)
    print(f"QAT-trained accuracy: {acc_train:.3f}")

    # ---- (3) fabrication export
    print("== step 3: fabrication export ==")
    dev = cd.DeviceSpec(levels=256)
    for name, phi in res_t.params["phase"].items():
        slm = cd.to_slm(phi, dev)
        thick = cd.to_3d_render(phi, cfg.wavelength)
        print(f"  {name}: SLM uint8 {slm.shape}; "
              f"3D-print thickness max {thick.max()*1e6:.2f}um")

    # ---- (4) post-fab deployment check (hard PTQ inference)
    print("== step 4: deployment (hard-quantized) check ==")
    cfg_dep = dataclasses.replace(cfg, codesign="ptq")
    model_dep = build_model(cfg_dep)
    acc_dep = evaluate_classifier(model_dep, res_t.params,
                                  batch_iterator(xs, ys, 128, seed=5), 4)
    print(f"deployed accuracy: {acc_dep:.3f} "
          f"(codesign gap {acc_train - acc_dep:+.3f})")


if __name__ == "__main__":
    main()
