"""Train a reduced LM config for a few hundred steps with checkpointing,
then serve it with the continuous-batching decode loop.

    PYTHONPATH=src python examples/lm_train_and_serve.py [arch]

The same launchers scale to the production meshes (launch/dryrun.py proves
compilation for the full configs on 512 chips).
"""
import sys
import tempfile

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def main():
    arch = sys.argv[1] if len(sys.argv) > 1 else "granite-8b"
    ckpt = tempfile.mkdtemp(prefix="lm_ck_")
    print(f"== training {arch} (smoke config, 200 steps) ==")
    train_mod.main([
        "--arch", arch, "--smoke", "--steps", "200", "--batch", "8",
        "--seq", "128", "--lr", "3e-3", "--warmup", "20",
        "--ckpt-dir", ckpt, "--ckpt-every", "50", "--log-every", "25",
    ])
    print(f"== resuming from checkpoint for 50 more steps ==")
    train_mod.main([
        "--arch", arch, "--smoke", "--steps", "250", "--batch", "8",
        "--seq", "128", "--lr", "3e-3", "--warmup", "20",
        "--ckpt-dir", ckpt, "--ckpt-every", "50", "--log-every", "25",
    ])
    print(f"== serving {arch} ==")
    serve_mod.main([
        "--arch", arch, "--smoke", "--slots", "8", "--requests", "16",
        "--prompt-len", "8", "--max-new", "16", "--cache-len", "128",
    ])


if __name__ == "__main__":
    main()
