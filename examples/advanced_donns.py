"""Advanced DONN architectures (paper §5.6): multi-channel RGB
classification (Fig. 12), all-optical segmentation with an optical
skip connection (Fig. 13), and a heterogeneous mixed-precision /
mixed-distance stack built through the DSL (segmented scan engine).

    PYTHONPATH=src python examples/advanced_donns.py
"""
import dataclasses

import jax
import jax.numpy as jnp

import repro.core.dsl as lr
from repro.core import DONNConfig, build_model
from repro.core.regularization import calibrate_gamma
from repro.core.train_utils import (
    bce_segmentation_loss, evaluate_classifier, iou, train_classifier,
)
from repro.data import batch_iterator, synth_digits, synth_rgb_scenes, synth_seg
from repro.optim import AdamW


def rgb_classifier():
    print("== multi-channel RGB DONN (Fig. 12) ==")
    cfg = DONNConfig(name="rgb", n=64, depth=3, distance=0.05, det_size=8,
                     num_classes=6, channels=3)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    xs, ys = synth_rgb_scenes(768, seed=0)
    g = calibrate_gamma(model, params, jnp.asarray(xs[:8]))
    model = build_model(dataclasses.replace(cfg, gamma=g))
    res = train_classifier(model, params,
                           batch_iterator(xs, ys, 64, seed=1),
                           steps=120, lr=0.3, num_classes=6, log_every=30)
    acc = evaluate_classifier(model, res.params,
                              batch_iterator(xs, ys, 128, seed=2), 3)
    print(f"RGB top-1 accuracy: {acc:.3f}\n")


def segmentation():
    print("== all-optical segmentation with optical skip (Fig. 13) ==")
    cfg = DONNConfig(name="seg", n=64, depth=3, distance=0.05,
                     segmentation=True, skip_from=0, layer_norm=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    xs, ms = synth_seg(512, seed=0)
    opt = AdamW(lr=0.05)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, i, xb, mb):
        def loss(p):
            return bce_segmentation_loss(model.apply(p, xb, train=True), mb)
        l, grads = jax.value_and_grad(loss)(params)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, l

    for i in range(100):
        s = (i * 32) % 448
        params, opt_state, l = step(params, opt_state, jnp.asarray(i),
                                    jnp.asarray(xs[s:s + 32]),
                                    jnp.asarray(ms[s:s + 32]))
        if i % 25 == 0:
            print(f"  step {i:3d} bce {float(l):.4f}")
    out = model.apply(params, jnp.asarray(xs[448:]), train=True)
    print(f"held-out IoU: {float(iou(out, jnp.asarray(ms[448:]))):.3f}")


def mixed_precision_hetero():
    """A physically composable hybrid stack: three 256-level SLM layers at
    0.10 m spacing feed two 4-level printed-mask layers on a smaller,
    coarser plane at 0.05 m spacing — per-layer precision, distance, plane
    size and pixel size all differ, trained jointly end to end.  The scan
    engine compiles it as two fused segments with a resampling stitch."""
    print("== heterogeneous mixed-precision DONN (SLM front + printed back) ==")
    src = lr.laser(wavelength=532e-9)
    front = [lr.layers.diffractlayer(distance=0.10, pixel_size=36e-6,
                                     size=64, precision=256)
             for _ in range(3)]
    back = [lr.layers.diffractlayer(distance=0.05, pixel_size=48e-6,
                                    size=48, precision=4)
            for _ in range(2)]
    det = lr.layers.detector(num_classes=10, det_size=8, distance=0.06)
    model, cfg = lr.models.sequential(front + back, det, laser=src,
                                      name="hybrid-slm-printed")
    segs = model.plan.segment_slices
    print(f"  {cfg.depth} layers -> {len(segs)} fused scan segments {segs}")
    params = model.init(jax.random.PRNGKey(0))
    xs, ys = synth_digits(768, seed=0)
    res = train_classifier(model, params,
                           batch_iterator(xs, ys, 64, seed=1),
                           steps=120, lr=0.3, log_every=30)
    acc = evaluate_classifier(model, res.params,
                              batch_iterator(xs, ys, 128, seed=2), 3)
    print(f"hybrid top-1 accuracy: {acc:.3f}")
    # the architecture round-trips through the JSON spec format
    _, cfg2 = lr.from_spec(lr.to_spec(cfg))
    assert cfg2.resolved_layers() == cfg.resolved_layers()
    print("to_spec/from_spec round-trip OK\n")


if __name__ == "__main__":
    mixed_precision_hetero()
    rgb_classifier()
    segmentation()
