"""Advanced DONN architectures (paper §5.6): multi-channel RGB
classification (Fig. 12) and all-optical segmentation with an optical
skip connection (Fig. 13).

    PYTHONPATH=src python examples/advanced_donns.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.core import DONNConfig, build_model
from repro.core.regularization import calibrate_gamma
from repro.core.train_utils import (
    bce_segmentation_loss, evaluate_classifier, iou, train_classifier,
)
from repro.data import batch_iterator, synth_rgb_scenes, synth_seg
from repro.optim import AdamW


def rgb_classifier():
    print("== multi-channel RGB DONN (Fig. 12) ==")
    cfg = DONNConfig(name="rgb", n=64, depth=3, distance=0.05, det_size=8,
                     num_classes=6, channels=3)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    xs, ys = synth_rgb_scenes(768, seed=0)
    g = calibrate_gamma(model, params, jnp.asarray(xs[:8]))
    model = build_model(dataclasses.replace(cfg, gamma=g))
    res = train_classifier(model, params,
                           batch_iterator(xs, ys, 64, seed=1),
                           steps=120, lr=0.3, num_classes=6, log_every=30)
    acc = evaluate_classifier(model, res.params,
                              batch_iterator(xs, ys, 128, seed=2), 3)
    print(f"RGB top-1 accuracy: {acc:.3f}\n")


def segmentation():
    print("== all-optical segmentation with optical skip (Fig. 13) ==")
    cfg = DONNConfig(name="seg", n=64, depth=3, distance=0.05,
                     segmentation=True, skip_from=0, layer_norm=True)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    xs, ms = synth_seg(512, seed=0)
    opt = AdamW(lr=0.05)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, i, xb, mb):
        def loss(p):
            return bce_segmentation_loss(model.apply(p, xb, train=True), mb)
        l, grads = jax.value_and_grad(loss)(params)
        params, opt_state = opt.update(grads, opt_state, params, i)
        return params, opt_state, l

    for i in range(100):
        s = (i * 32) % 448
        params, opt_state, l = step(params, opt_state, jnp.asarray(i),
                                    jnp.asarray(xs[s:s + 32]),
                                    jnp.asarray(ms[s:s + 32]))
        if i % 25 == 0:
            print(f"  step {i:3d} bce {float(l):.4f}")
    out = model.apply(params, jnp.asarray(xs[448:]), train=True)
    print(f"held-out IoU: {float(iou(out, jnp.asarray(ms[448:]))):.3f}")


if __name__ == "__main__":
    rgb_classifier()
    segmentation()
