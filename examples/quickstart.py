"""Quickstart: build, train, evaluate and export a DONN with the DSL.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the paper's front-end flow (Table 2): lr.laser -> lr.layers ->
lr.models.sequential -> train -> lr.layers.weight_fab export.
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.dsl as lr
from repro.core import codesign as cd
from repro.core.regularization import calibrate_gamma
from repro.core.train_utils import evaluate_classifier, train_classifier
from repro.data import batch_iterator, synth_digits


def main():
    # 1. describe the optical system (reduced 64x64 for CPU speed)
    src = lr.laser(wavelength=532e-9, profile="plane")
    layers = [
        lr.layers.diffractlayer_raw(distance=0.05, pixel_size=36e-6, size=64)
        for _ in range(3)
    ]
    det = lr.layers.detector(num_classes=10, det_size=8, distance=0.05)
    model, cfg = lr.models.sequential(layers, det, laser=src, name="quickstart")
    print(f"built {cfg.name}: {cfg.depth} layers @ {cfg.n}x{cfg.n}, "
          f"lambda={cfg.wavelength*1e9:.0f}nm")

    # 2. physics-aware gamma calibration (paper §3.2)
    params = model.init(jax.random.PRNGKey(0))
    xs, ys = synth_digits(1024, seed=0)
    g = calibrate_gamma(model, params, jnp.asarray(xs[:16]))
    import dataclasses

    model = lr.from_config(dataclasses.replace(cfg, gamma=g))
    print(f"calibrated gamma = {g:.3f}")

    # 3. train (Adam + MSE-softmax, per the paper) with the chunked
    # throughput driver: each compiled call scans 10 donated optimizer
    # steps over a prefetched batch chunk — numerically identical to the
    # per-step loop, one host sync per chunk
    res = train_classifier(
        model, params, batch_iterator(xs, ys, 64, seed=1),
        steps=150, lr=0.5, log_every=30, steps_per_call=10,
    )
    acc = evaluate_classifier(model, res.params,
                              batch_iterator(xs, ys, 128, seed=2), 4)
    print(f"train {res.wall_time_s:.1f}s; eval accuracy {acc:.3f}")

    # 4. hardware export: quantize phases to 8-bit SLM levels
    dev = cd.DeviceSpec(levels=256)
    for name, phi in res.params["phase"].items():
        img = cd.to_slm(phi, dev)
        print(f"  {name}: SLM pattern {img.shape} uint8, "
              f"levels used {len(np.unique(img))}")

    # 5. deploy: freeze the trained model (codesign response + modulation
    # planes folded once) and serve micro-batched requests through the
    # bucketed AOT engine — see repro.launch.serve_donn for the full loop
    from repro.runtime.inference import InferenceEngine, freeze

    engine = InferenceEngine(freeze(model, res.params), buckets=(1, 8, 32))
    engine.warmup()  # compiles paid at deploy time, not on request 1
    import time

    t0 = time.perf_counter()
    preds = engine.infer(xs[:32]).argmax(-1)
    dt = time.perf_counter() - t0
    print(f"served 32 requests in {dt*1e3:.1f}ms "
          f"({32 / dt:.0f} req/s), acc {np.mean(preds == ys[:32]):.3f}")


if __name__ == "__main__":
    main()
