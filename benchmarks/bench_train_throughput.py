"""Training throughput: donated multi-step scanned driver vs per-step loop.

The seed trains DONNs with a per-batch Python loop: every step pays a jit
dispatch, a host rng split, a non-donated state re-allocation and two
blocking ``float()`` syncs.  The throughput engine makes *chunks* the unit
of compiled work: ``make_train_chunk`` scans ``steps_per_call`` optimizer
steps inside one jit with (params, opt_state) donated, metrics accumulate
on device, and the double-buffered device prefetcher keeps batch k+1 in
flight while step k computes.

Cells (CPU, depth-8 / n=64 classify — the ISSUE-4 acceptance cell), each
with two baselines so the win is attributable:

- ``per_step`` (the *seed-style* number): a fresh ``@jax.jit`` step
  closure per training run, exactly what the seed's ``train_classifier``
  builds on every call — so each run re-pays trace+compile, the overhead
  the executable cache kills.  Best-of-reps = its steady state.
- ``per_step_warm``: the same loop with the step closure hoisted across
  runs (compile excluded entirely) — the pure per-step host overhead
  (jit dispatch, host rng split, two blocking ``float()`` syncs,
  non-donated state realloc) vs the chunked driver.  At this cell's
  sizes the FFT chain dominates per-step compute, so this ratio is the
  conservative lower bound (batch 2 is the overhead-dominated regime,
  batch 8 compute-bound; on accelerators the crossover batch is far
  larger).

``train/segmentation`` and ``train/rng_codesign`` cover the other two
training families through the chunked drivers (agreement + a smaller
timing).  Every cell checks the chunked final params against the
per-step loop (identical rng chain; max |delta| / max |ref| <= 1e-5, in
practice bit-exact).  Rows persist to
``artifacts/bench/BENCH_train_throughput.json``.

    PYTHONPATH=src:. python benchmarks/bench_train_throughput.py
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, write_bench_json
from repro.core import DONNConfig, build_model
from repro.core.train_utils import (
    accuracy, make_train_chunk, mse_softmax_loss,
)
from repro.data import batch_iterator, synth_digits, synth_seg
from repro.data.pipeline import device_prefetch, stack_batches
from repro.optim import AdamW

REPO = pathlib.Path(__file__).resolve().parent.parent


def _seed_style_step(model, optimizer, num_classes: int,
                     needs_rng: bool = False):
    """The seed's train step: plain per-closure jit, no donation/caching."""

    def loss_fn(params, xb, yb, rng):
        logits = (model.apply(params, xb, rng) if needs_rng
                  else model.apply(params, xb))
        return mse_softmax_loss(logits, yb, num_classes), logits

    @jax.jit
    def step_fn(params, opt_state, step, xb, yb, rng):
        (loss, logits), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, xb, yb, rng
        )
        params, opt_state = optimizer.update(grads, opt_state, params, step)
        return params, opt_state, loss, accuracy(logits, yb)

    return step_fn


def _per_step_loop(step_fn, optimizer, params, it, steps: int):
    """Seed-style loop: host rng split + two float() syncs per step."""
    opt_state = optimizer.init(params)
    params = jax.tree.map(jnp.array, params)
    rng = jax.random.PRNGKey(0)
    losses = []
    t0 = time.perf_counter()
    for i in range(steps):
        xb, yb = next(it)
        rng, sub = jax.random.split(rng)
        params, opt_state, loss, acc = step_fn(
            params, opt_state, jnp.asarray(i), xb, yb, sub
        )
        losses.append(float(loss))
        float(acc)
    return params, losses, time.perf_counter() - t0


def _chunked_loop(chunk_fn, optimizer, params, it, steps: int,
                  steps_per_call: int):
    """Chunked driver fed by the device prefetcher; one sync per chunk."""
    opt_state = optimizer.init(params)
    params = jax.tree.map(jnp.array, params)
    opt_state = jax.tree.map(jnp.array, opt_state)
    rng = jax.random.PRNGKey(0)
    losses = []
    i = 0
    t0 = time.perf_counter()
    chunks = device_prefetch(stack_batches(it, steps_per_call, total=steps))
    for xs, ys in chunks:
        params, opt_state, rng, closs, cacc = chunk_fn(
            params, opt_state, i, xs, ys, rng
        )
        losses.extend(np.asarray(closs).tolist())
        i += int(xs.shape[0])
    return params, losses, time.perf_counter() - t0


def _rel_err(got, want) -> float:
    """max |delta| / max |ref| across the param pytree."""
    num = max(float(jnp.max(jnp.abs(a - b))) for a, b in
              zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    den = max(float(jnp.max(jnp.abs(b))) for b in jax.tree.leaves(want))
    return num / max(den, 1e-12)


def _bench_classify(batch: int, rows: list, reps: int = 3,
                    steps: int = 96, steps_per_call: int = 16) -> dict:
    label = f"classify_b{batch}"
    cfg = DONNConfig(name="tt", n=64, depth=8, distance=0.05, det_size=8)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    xs, ys = synth_digits(512, seed=0)
    opt = AdamW(lr=0.3)

    warm_step = _seed_style_step(model, opt, 10)  # hoisted across runs
    chunk_fn = make_train_chunk(model, opt, 10)  # executable-cached

    def run(kind):
        best, final, losses = None, None, None
        for _ in range(reps):
            it = batch_iterator(xs, ys, batch, seed=1)
            if kind == "per_step":
                # seed behavior: a fresh jit closure per training run
                final, losses, dt = _per_step_loop(
                    _seed_style_step(model, opt, 10), opt, params, it,
                    steps)
            elif kind == "per_step_warm":
                final, losses, dt = _per_step_loop(
                    warm_step, opt, params, it, steps)
            else:
                final, losses, dt = _chunked_loop(
                    chunk_fn, opt, params, it, steps, steps_per_call)
            best = dt if best is None else min(best, dt)
        return final, losses, steps / best  # steps/sec, best-of-reps

    p_ref, l_ref, sps_ref = run("per_step")
    _, _, sps_warm = run("per_step_warm")
    p_new, l_new, sps_new = run("chunked")
    err = _rel_err(p_new, p_ref)
    match = bool(err <= 1e-5 and np.allclose(l_ref, l_new, rtol=1e-6,
                                             atol=1e-7))
    for kind, sps in (("per_step", sps_ref), ("per_step_warm", sps_warm),
                      ("chunked", sps_new)):
        name = f"train/{label}/{kind}"
        derived = (f"steps_per_sec={sps:.1f},batch={batch},depth=8,n=64,"
                   f"steps_per_call={steps_per_call}")
        row(name, 1e6 / sps, derived)
        rows.append({"name": name, "us": 1e6 / sps, "derived": derived})
    speedup = sps_new / sps_ref
    warm_speedup = sps_new / sps_warm
    name = f"train/{label}/speedup"
    derived = (f"chunked_vs_seed_style={speedup:.2f}x,"
               f"chunked_vs_warm_loop={warm_speedup:.2f}x,"
               f"param_rel_err={err:.2e},match={match}")
    row(name, 1e6 / sps_new, derived)
    rows.append({"name": name, "us": 1e6 / sps_new, "derived": derived})
    return {"steady": round(speedup, 3),
            "warm_loop": round(warm_speedup, 3),
            "steps_per_sec": round(sps_new, 1),
            "param_rel_err": err, "match": match}


def _bench_segmentation(rows: list) -> dict:
    """Chunked coverage: segmentation rides the donn_steps chunk driver."""
    from repro.nn import init_params
    from repro.runtime import donn_steps as ds
    from repro.runtime import sharding as shd

    cfg = DONNConfig(name="tt-seg", n=64, depth=4, distance=0.05,
                     segmentation=True, skip_from=0, layer_norm=True)
    mesh = shd.make_mesh_2d(data=1)
    opt = AdamW(lr=0.05)
    steps, spc = 24, 8
    xs, ms = synth_seg(64, seed=1)
    it = batch_iterator(xs, ms, 8, seed=2)
    batches = [dict(zip(("images", "masks"), next(it)))
               for _ in range(steps)]
    sspecs = ds.donn_state_specs(cfg)
    st_ref = init_params(sspecs, jax.random.PRNGKey(0))
    step_fn = jax.jit(ds.make_donn_train_step(cfg, opt))
    l_ref = []
    t0 = time.perf_counter()
    for b in batches:
        st_ref, m = step_fn(st_ref, b)
        l_ref.append(float(m["loss"]))
    dt_ref = time.perf_counter() - t0

    fn, s_sh, b_sh, _ = ds.compile_donn_train_chunk(cfg, mesh, optimizer=opt)
    st = jax.device_put(init_params(sspecs, jax.random.PRNGKey(0)), s_sh)
    l_new = []
    t0 = time.perf_counter()
    for chunk in stack_batches(iter(batches), spc):
        st, m = fn(st, chunk)
        l_new.extend(np.asarray(m["loss"]).tolist())
    dt_new = time.perf_counter() - t0
    err = _rel_err(st["params"], st_ref["params"])
    match = bool(err <= 1e-5 and np.allclose(l_ref, l_new, rtol=1e-6,
                                             atol=1e-7))
    name = "train/segmentation/chunked"
    derived = (f"chunked_vs_per_step={dt_ref / dt_new:.2f}x,"
               f"param_rel_err={err:.2e},match={match},steps_per_call={spc}")
    row(name, dt_new / steps * 1e6, derived)
    rows.append({"name": name, "us": dt_new / steps * 1e6,
                 "derived": derived})
    return {"match": match, "param_rel_err": err,
            "speedup": round(dt_ref / dt_new, 3)}


def _bench_rng_codesign(rows: list) -> dict:
    """Chunked coverage: stochastic (gumbel) codesign, rng chain aligned."""
    cfg = DONNConfig(name="tt-rng", n=64, depth=4, distance=0.05, det_size=8,
                     codesign="gumbel")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    xs, ys = synth_digits(256, seed=0)
    opt = AdamW(lr=0.3)
    steps, spc = 24, 8
    step_fn = _seed_style_step(model, opt, 10, needs_rng=True)
    chunk_fn = make_train_chunk(model, opt, 10, needs_rng=True)
    p_ref, l_ref, dt_ref = _per_step_loop(
        step_fn, opt, params, batch_iterator(xs, ys, 4, seed=1), steps)
    p_new, l_new, dt_new = _chunked_loop(
        chunk_fn, opt, params, batch_iterator(xs, ys, 4, seed=1), steps, spc)
    err = _rel_err(p_new, p_ref)
    match = bool(err <= 1e-5 and np.allclose(l_ref, l_new, rtol=1e-6,
                                             atol=1e-7))
    name = "train/rng_codesign/chunked"
    derived = (f"chunked_vs_per_step={dt_ref / dt_new:.2f}x,"
               f"param_rel_err={err:.2e},match={match},steps_per_call={spc}")
    row(name, dt_new / steps * 1e6, derived)
    rows.append({"name": name, "us": dt_new / steps * 1e6,
                 "derived": derived})
    return {"match": match, "param_rel_err": err,
            "speedup": round(dt_ref / dt_new, 3)}


def _bench_large_plane(rows: list) -> dict:
    """n=1024 plane, 4-way-spatial x 2-way-data on 8 forced host devices.

    The ISSUE-10 acceptance cell: a field too large for one chip's plane
    budget trains through ``compile_donn_train_step_sharded`` on the 2-D
    ``(data, model)`` mesh — each device holds a 256-row pencil of every
    1024^2 plane (fields, TF stacks, phases, optimizer moments).  The
    single-device row is recorded as skipped: at the per-chip budget this
    cell models (1/4 of the plane stack per device), no single device can
    materialize the full 1024^2 TF + phase + moment stacks, so the
    sharded path is the only runnable one.
    """
    code = """
import json, time
import jax, numpy as np
from repro.core import DONNConfig
from repro.nn import init_params
from repro.optim import AdamW
from repro.runtime import donn_steps as ds
from repro.runtime import sharding as shd

assert jax.device_count() == 8, jax.device_count()
cfg = DONNConfig(name="tt-1024", n=1024, depth=2, det_size=64)
mesh = shd.make_mesh_2d(data=2, model=4)
B = 4
fn, s_shard, b_shard, sspecs = ds.compile_donn_train_step_sharded(
    cfg, mesh, optimizer=AdamW(lr=0.1), global_batch=B)
state = jax.device_put(init_params(sspecs, jax.random.PRNGKey(0)), s_shard)
r = np.random.default_rng(0)
batch = jax.device_put(
    {"images": r.random((B, 28, 28)).astype(np.float32),
     "labels": r.integers(0, 10, (B,)).astype(np.int32)}, b_shard)
state, m = fn(state, batch)  # compile + warm
jax.block_until_ready(state)
losses, steps = [float(m["loss"])], 2
t0 = time.perf_counter()
for _ in range(steps):
    state, m = fn(state, batch)
    losses.append(float(m["loss"]))
dt = time.perf_counter() - t0
rows_dev = cfg.n // mesh.shape["model"]
print("RESULT " + json.dumps({
    "steps_per_sec": steps / dt, "losses": losses,
    "rows_per_device": rows_dev,
    "finite": bool(np.all(np.isfinite(losses)))}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = str(REPO / "src")
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(f"large-plane cell failed:\n{r.stderr}")
    res = json.loads(r.stdout.split("RESULT ")[1])
    if not res["finite"]:
        raise AssertionError(f"non-finite losses: {res['losses']}")
    sps = res["steps_per_sec"]
    name = "train/large_plane_n1024/sharded_2x4"
    derived = (f"steps_per_sec={sps:.3f},mesh=2data_x_4model,n=1024,"
               f"depth=2,batch=4,rows_per_device={res['rows_per_device']},"
               f"finite={res['finite']},host_devices=8")
    row(name, 1e6 / sps, derived)
    rows.append({"name": name, "us": 1e6 / sps, "derived": derived})
    name1 = "train/large_plane_n1024/single_device"
    derived1 = ("status=skipped,reason=infeasible_at_modeled_chip_budget:"
                "full 1024^2 TF+phase+moment stacks exceed the quarter-"
                "plane per-device budget this cell models; only the row-"
                "sharded path runs")
    row(name1, 0.0, derived1)
    rows.append({"name": name1, "us": 0.0, "derived": derived1})
    return {"steps_per_sec": round(sps, 3), "mesh": "2x4",
            "rows_per_device": res["rows_per_device"],
            "single_device": "skipped"}


def main() -> None:
    rows: list = []
    speedups = {
        "classify_b2": _bench_classify(2, rows),
        "classify_b8": _bench_classify(8, rows),
        "segmentation": _bench_segmentation(rows),
        "rng_codesign": _bench_rng_codesign(rows),
        "large_plane_n1024": _bench_large_plane(rows),
    }
    meta = {
        "backend": jax.default_backend(),
        "depth": 8,
        "n": 64,
        "steps_per_call": 16,
        "speedups": speedups,
    }
    write_bench_json("train_throughput", rows, meta)


if __name__ == "__main__":
    main()
