"""Fig. 9: per-operator speedup breakdown (FFT2 / iFFT2 / ComplexMM).

LightRidge path: jit'd batched complex64 ops (+ the fused Pallas
phase-modulation kernel for ComplexMM).  Baseline path: per-sample eager
numpy complex128 (the LightPipes-style limitations).

Rows print in the standard CSV schema and persist to
``artifacts/bench/BENCH_kernel_breakdown.json`` (tier-1: the CI --check
gate requires this artifact fresh in every checked invocation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn, time_host_fn, write_bench_json
from repro.kernels import ops as kops

INTERP_NOTE = "(interpret-mode-on-CPU;wall-clock-meaningful-on-TPU-only)"


def _emit(rows: list, name: str, us: float, derived: str):
    row(name, us, derived)
    rows.append({"name": name, "us": us, "derived": derived})


def main():
    rows: list = []
    speeds = {}
    n, batch = 256, 8
    r = np.random.default_rng(0)
    u = (r.normal(size=(batch, n, n)) + 1j * r.normal(size=(batch, n, n)))
    uj = jnp.asarray(u, jnp.complex64)
    phi = r.uniform(0, 6.28, (n, n)).astype(np.float32)
    phij = jnp.asarray(phi)
    hj = jnp.exp(1j * phij.astype(jnp.complex64))

    # FFT2
    f_ours = jax.jit(jnp.fft.fft2)
    us = time_fn(f_ours, uj)
    us_b = time_host_fn(
        lambda: np.stack([np.fft.fft2(u[i]) for i in range(batch)])
    )
    _emit(rows, "fig9/fft2/lightridge", us, f"speedup={us_b / us:.1f}x")
    _emit(rows, "fig9/fft2/baseline", us_b, "per-sample numpy c128")
    speeds["fft2"] = round(us_b / us, 2)

    # iFFT2
    fi_ours = jax.jit(jnp.fft.ifft2)
    us = time_fn(fi_ours, uj)
    us_b = time_host_fn(
        lambda: np.stack([np.fft.ifft2(u[i]) for i in range(batch)])
    )
    _emit(rows, "fig9/ifft2/lightridge", us, f"speedup={us_b / us:.1f}x")
    _emit(rows, "fig9/ifft2/baseline", us_b, "per-sample numpy c128")
    speeds["ifft2"] = round(us_b / us, 2)

    # ComplexMM (phase modulation): fused Pallas kernel vs eager loop
    ur, ui = jnp.real(uj), jnp.imag(uj)
    cm_ours = jax.jit(lambda a, b, p: kops.phase_apply(a, b, p, 1.0))
    us = time_fn(cm_ours, ur, ui, phij)
    us_b = time_host_fn(
        lambda: np.stack([u[i] * np.exp(1j * phi.astype(np.complex128))
                          for i in range(batch)])
    )
    _emit(rows, "fig9/complex_mm/lightridge_pallas_interpret", us,
          f"speedup={us_b / us:.1f}x{INTERP_NOTE}")
    cm_jnp = jax.jit(lambda v, h: v * h)
    us2 = time_fn(cm_jnp, uj, hj)
    _emit(rows, "fig9/complex_mm/lightridge_jnp", us2,
          f"speedup={us_b / us2:.1f}x")
    _emit(rows, "fig9/complex_mm/baseline", us_b, "per-sample numpy c128")
    speeds["complex_mm"] = round(us_b / us2, 2)

    # fused phase+TF elementwise op (the scan-body site of the propagation
    # engine): cos/sin rotation + amplitude complex-multiply in one pass
    theta_h = jnp.asarray(np.angle(np.asarray(hj)).astype(np.float32))
    amp_h = jnp.asarray(np.abs(np.asarray(hj)).astype(np.float32))
    ptf = jax.jit(lambda a, b, t, m: kops.phase_tf_apply(a, b, t, m))
    us3 = time_fn(ptf, ur, ui, theta_h, amp_h)
    h_np = np.asarray(hj).astype(np.complex128)
    us3_b = time_host_fn(
        lambda: np.stack([u[i] * h_np for i in range(batch)])
    )
    _emit(rows, "fig9/phase_tf/lightridge_pallas_interpret", us3,
          f"speedup={us3_b / us3:.1f}x{INTERP_NOTE}")
    _emit(rows, "fig9/phase_tf/baseline", us3_b,
          "per-sample numpy c128 TF multiply")

    # fused spectral hop (TF multiply + inverse transform + modulation
    # collapsed into two conj-kernel passes between FFTs) vs the unfused
    # jnp chain the propagation plan runs with use_pallas=False
    theta_m = jnp.asarray(r.uniform(0, 6.28, (n, n)).astype(np.float32))
    amp_m = jnp.ones((n, n), jnp.float32)
    fused = jax.jit(lambda a, b, th, ah, tm, am:
                    kops.fused_spectral_hop(a, b, th, ah, tm, am))
    us4 = time_fn(fused, ur, ui, theta_h, amp_h, theta_m, amp_m)
    unfused = jax.jit(lambda x, th, ah, tm, am:
                      kops.fused_spectral_hop_ref(x, th, ah, tm, am))
    us4_b = time_fn(unfused, uj, theta_h, amp_h, theta_m, amp_m)
    _emit(rows, "fig9/fused_hop/lightridge_pallas_interpret", us4,
          f"speedup={us4_b / us4:.2f}x{INTERP_NOTE}")
    _emit(rows, "fig9/fused_hop/lightridge_jnp", us4_b,
          "unfused jnp hop (fft2,tf-mul,ifft2,mod-mul)")
    speeds["fused_hop_vs_jnp"] = round(us4_b / us4, 2)

    write_bench_json(
        "kernel_breakdown", rows,
        meta={"backend": jax.default_backend(), "n": n, "batch": batch,
              "pallas_interpret": jax.default_backend() != "tpu",
              "speedups": speeds},
    )


if __name__ == "__main__":
    main()
